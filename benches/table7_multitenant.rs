//! Multi-tenant extension of the Table 7 serving bench: throughput vs
//! tenant count over one device-resident frozen base (registry → scheduler
//! → engine), the merged-vs-unmerged per-tenant serving cost the paper's
//! §2.5 argument turns on, the decode hot path (device-cached tenant
//! adapters vs per-step host upload, with thread-scoped PJRT upload-byte
//! accounting, plus a KV-cache seq-length sweep over the
//! sqft-tiny-s96/-s192 serve variants — `kv_cached` vs `full_forward`
//! legs with exact byte ledgers; full runs assert the cached curve stays
//! ~flat while full forward degrades — all → `BENCH_decode.json`), and
//! the worker-pool scaling sweep
//! (1/2/4/8 per-thread engine replicas over the sharded work-stealing
//! scheduler → `BENCH_serve_scaling.json`; answers asserted
//! byte-identical to 1 worker, and full runs assert >1.5x aggregate
//! tokens/s at 4 workers).
//!
//! The mixed-batch section measures the gathered adapter banks on the
//! S-LoRA long tail (every tenant sends one request): one mixed session
//! with per-row `adapter_idx` vs one session per tenant
//! (`BENCH_mixed_batch.json`; answers asserted identical, and full runs
//! assert >2x tokens/s for the mixed shape).
//!
//! Also measures the cost of the serving telemetry itself: the same
//! closed-loop pool workload runs once fully instrumented (metrics
//! registry + JSONL trace spans) and once through `ServeObs::disabled()`
//! — the instrumented run must stay within 3% decode tokens/s of the
//! baseline (`BENCH_obs_overhead.json`).
//!
//! `SQFT_BENCH_SMOKE=1` shrinks every iteration count to 1 and the
//! worker sweep to `[1, 2]` (CI smoke); `-- --workers N` pins the sweep
//! to `[1, N]`; `-- --metrics-out PATH` writes the instrumented run's
//! final metrics snapshot (Prometheus text + JSON + trace JSONL).

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::{init_base, ParamSet};
use sqft::nls::SearchSpace;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::report::Table;
use sqft::runtime::{DeviceStore, Runtime, UploadScope};
use sqft::serve::{
    benchmark_router, serve_pool, serve_pool_obs, AdapterRegistry, Engine, EngineSpec,
    PoolOpts, Request, Router, SchedulerOpts, ServeObs, SharedAdapterSource,
};
use sqft::tensor::Rng;
use sqft::train::TrainOpts;
use sqft::util::bench::{bench_throughput, smoke_iters};
use sqft::util::json::Json;
use std::path::Path;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

/// `--workers N` (passed through `cargo bench --bench table7_multitenant
/// -- --workers N`) pins the sweep to `[1, N]` — CI smoke uses 2 so the
/// multi-worker path is exercised on every PR without paying for the
/// full 1/2/4/8 sweep.
fn cli_workers() -> Option<usize> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter()
        .position(|a| a == "--workers")
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
}

/// `--metrics-out PATH`: dump the instrumented overhead run's final
/// metrics snapshot — what CI's bench-smoke greps for the
/// `serve_requests_total` sentinel and uploads as an artifact.
fn cli_metrics_out() -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter().position(|a| a == "--metrics-out").and_then(|i| argv.get(i + 1)).cloned()
}

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let config = "sqft-tiny";
    let hyper = rt.model(config)?.clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 600, 0, 50, 7);
    let base = init_base(&hyper, &mut Rng::new(7));

    println!("# table7 multitenant bench: throughput vs tenant count");
    let tenant_steps = smoke_iters(5);
    let prepared = pipeline::prepare(&rt, config, &base, Method::SparsePeft, 0.5,
                                     &ds.train, &tok, 2, &mut Rng::new(9))?;
    let frozen = prepared.frozen_set()?;
    let max_tenants = 4usize;
    let entries = pipeline::tenant_adapters(&rt, config, &prepared, max_tenants,
                                            &ds.train, &tok, tenant_steps, 77)?;

    // --- throughput vs tenant count over one frozen base ---------------
    // tenants are registered device-resident: serving batches take the
    // cached path (adapter buffers already on device)
    let n_requests = if sqft::util::bench::smoke() { 12usize } else { 48 };
    let mut table = Table::new(
        "Throughput vs tenant count (one device-resident base)",
        &["tenants", "served", "req/s", "avg batch fill", "batches", "aged"],
    );
    for &k in &[1usize, 2, 4] {
        let engine = Engine::new(&rt, config, &frozen, None, "eval", 4)?;
        let mut registry = AdapterRegistry::new(max_tenants);
        let ids: Vec<String> = entries[..k].iter().map(|e| e.id.clone()).collect();
        for e in &entries[..k] {
            registry.register_resident(&rt, &hyper, e.clone())?;
        }
        let mut router = Router::new(engine, registry);
        let mut grng = Rng::new(11 + k as u64);
        let requests: Vec<(Option<String>, String)> = (0..n_requests)
            .map(|i| (Some(ids[i % k].clone()), task.gen_sample(&mut grng).prompt))
            .collect();
        let opts = SchedulerOpts { max_batch: hyper.batch,
                                   aging: Duration::from_millis(20),
                                   ..Default::default() };
        let stats = benchmark_router(&mut router, requests,
                                     Duration::from_millis(1), opts)?;
        table.row(vec![
            k.to_string(),
            stats.total.served.to_string(),
            format!("{:.1}", stats.total.throughput),
            format!("{:.2}", stats.scheduler.avg_fill()),
            stats.scheduler.batches.to_string(),
            stats.scheduler.aged_batches.to_string(),
        ]);
    }
    print!("{}", table.render());

    // --- worker-pool scaling: per-thread engine replicas, sharded
    // work-stealing scheduler; answers must be byte-identical to the
    // 1-worker run and aggregate tokens/s must scale with workers -------
    let sweep: Vec<usize> = match cli_workers() {
        Some(w) if w > 1 => vec![1, w],
        Some(_) => vec![1],
        None if sqft::util::bench::smoke() => vec![1, 2],
        None => vec![1, 2, 4, 8],
    };
    println!("# serve scaling: worker sweep {sweep:?}");
    let source = SharedAdapterSource::new(hyper.clone(), max_tenants);
    source.register_all(entries.clone())?;
    let spec = EngineSpec {
        artifacts: dir.clone(),
        config: config.to_string(),
        frozen: frozen.clone(),
        eval_kind: "eval".to_string(),
        max_new_tokens: 4,
        registry_capacity: max_tenants,
        device_budget: 0,
        degrade_ranks: Vec::new(),
    };
    let n_scale = if sqft::util::bench::smoke() { 16usize } else { 96 };
    let mut grng = Rng::new(31);
    let scale_reqs: Vec<(Option<String>, String)> = (0..n_scale)
        .map(|i| {
            (Some(entries[i % entries.len()].id.clone()), task.gen_sample(&mut grng).prompt)
        })
        .collect();
    // closed loop (everything enqueued up front): measures capacity, and
    // keeps every worker busy so stealing and sharding both matter
    let run_pool = |workers: usize| -> anyhow::Result<(Vec<String>, sqft::serve::PoolServeStats)> {
        let (tx, rx) = channel::<Request>();
        let mut replies = Vec::new();
        for (id, p) in &scale_reqs {
            let (rtx, rrx) = channel();
            let _ = tx.send(Request::new(id.clone(), p.clone(), rtx));
            replies.push(rrx);
        }
        drop(tx);
        let popts = PoolOpts {
            workers,
            sched: SchedulerOpts { max_batch: hyper.batch,
                                   aging: Duration::from_millis(20),
                                   ..Default::default() },
            ..Default::default()
        };
        let stats = serve_pool(&spec, &source, rx, popts)?;
        let answers: Vec<String> =
            replies.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();
        Ok((answers, stats))
    };
    let mut scale_table = Table::new(
        "Worker-pool scaling (one base, 4 tenants, closed loop)",
        &["workers", "served", "tok/s", "occupancy", "steals", "wall s"],
    );
    let mut sweep_json: Vec<Json> = Vec::new();
    let mut ref_answers: Vec<String> = Vec::new();
    let mut tps_by_workers: Vec<(usize, f64)> = Vec::new();
    for &w in &sweep {
        let (answers, stats) = run_pool(w)?;
        if w == 1 {
            ref_answers = answers;
        } else {
            assert_eq!(answers, ref_answers,
                "{w}-worker answers diverged from the single-worker reference");
        }
        assert_eq!(stats.serve.total.errors, 0, "pool run had errors at {w} workers");
        // steady-state window: replica setup (per-worker compile) is a
        // constant cost, not a serving cost, and must not dilute scaling
        let wall = stats.serving_wall_secs;
        let tps = stats.serve.generated_tokens as f64 / wall.max(1e-12);
        tps_by_workers.push((w, tps));
        scale_table.row(vec![
            w.to_string(),
            stats.serve.total.served.to_string(),
            format!("{tps:.1}"),
            format!("{:.2}", stats.serve.occupancy),
            stats.steals.to_string(),
            format!("{wall:.3}"),
        ]);
        sweep_json.push(Json::obj(vec![
            ("workers", Json::Num(w as f64)),
            ("served", Json::Num(stats.serve.total.served as f64)),
            ("generated_tokens", Json::Num(stats.serve.generated_tokens as f64)),
            ("tokens_per_s", Json::Num(tps)),
            ("occupancy", Json::Num(stats.serve.occupancy)),
            ("steals", Json::Num(stats.steals as f64)),
            ("decode_steps", Json::Num(stats.serve.decode_steps as f64)),
            ("avg_fill", Json::Num(stats.serve.scheduler.avg_fill())),
            ("serving_wall_secs", Json::Num(wall)),
            ("total_wall_secs", Json::Num(stats.serve.total.wall_secs)),
        ]));
    }
    print!("{}", scale_table.render());
    let tps_at = |w: usize| tps_by_workers.iter().find(|(k, _)| *k == w).map(|(_, t)| *t);
    let speedup_4v1 = match (tps_at(1), tps_at(4)) {
        (Some(t1), Some(t4)) => {
            let s = t4 / t1.max(1e-12);
            println!("worker scaling speedup 4v1: {s:.2}x");
            // the whole point of the pool: >1.5x aggregate throughput at 4
            // workers (timing assert, so full runs only — smoke runs on
            // shared CI boxes where wall-clock means nothing)
            if !sqft::util::bench::smoke() {
                assert!(s > 1.5,
                    "4-worker aggregate tokens/s must beat 1 worker by >1.5x, got {s:.2}x");
            }
            Some(s)
        }
        _ => None,
    };
    let mut scaling_report = vec![
        ("bench", Json::Str("serve_scaling".into())),
        ("config", Json::Str(config.into())),
        ("batch", Json::Num(hyper.batch as f64)),
        ("requests", Json::Num(n_scale as f64)),
        ("tenants", Json::Num(entries.len() as f64)),
        ("smoke", Json::Num(sqft::util::bench::smoke() as u8 as f64)),
        ("sweep", Json::Arr(sweep_json)),
    ];
    if let Some(s) = speedup_4v1 {
        scaling_report.push(("speedup_4_workers_vs_1", Json::Num(s)));
    }
    std::fs::write("BENCH_serve_scaling.json", Json::obj(scaling_report).to_string_pretty())?;
    println!("wrote BENCH_serve_scaling.json");

    // --- observability overhead: full telemetry vs disabled -------------
    // The same closed-loop workload through the same pool, once with the
    // metrics registry + per-request trace spans and once through
    // `ServeObs::disabled()` (every record call early-returns — the
    // uninstrumented baseline).  Tokens are counted from the returned
    // answers, not the registry, so both runs measure identically.
    let obs_workers = cli_workers().unwrap_or(2).max(1);
    let run_obs = |obs: ServeObs| -> anyhow::Result<(f64, ServeObs)> {
        let (tx, rx) = channel::<Request>();
        let mut replies = Vec::new();
        for (id, p) in &scale_reqs {
            let (rtx, rrx) = channel();
            let _ = tx.send(Request::new(id.clone(), p.clone(), rtx));
            replies.push(rrx);
        }
        drop(tx);
        let popts = PoolOpts {
            workers: obs_workers,
            sched: SchedulerOpts { max_batch: hyper.batch,
                                   aging: Duration::from_millis(20),
                                   ..Default::default() },
            ..Default::default()
        };
        let kept = obs.clone();
        let stats = serve_pool_obs(&spec, &source, rx, popts, obs)?;
        let mut toks = 0usize;
        for r in replies {
            toks += r.recv().unwrap().unwrap().len() + 1; // answer + stop token
        }
        Ok((toks as f64 / stats.serving_wall_secs.max(1e-12), kept))
    };
    let obs_reps = smoke_iters(3);
    let (mut without_tps, mut with_tps) = (0.0f64, 0.0f64);
    let mut last_obs: Option<ServeObs> = None;
    for _ in 0..obs_reps {
        let (t, _) = run_obs(ServeObs::disabled())?;
        without_tps = without_tps.max(t);
        let (t, o) = run_obs(ServeObs::with_trace())?;
        with_tps = with_tps.max(t);
        last_obs = Some(o);
    }
    let obs_ratio = with_tps / without_tps.max(1e-12);
    println!(
        "bench obs_overhead: without {without_tps:.1} tok/s, with {with_tps:.1} tok/s \
(ratio {obs_ratio:.3})"
    );
    // timing assert, so full runs only (smoke shares CI boxes)
    if !sqft::util::bench::smoke() {
        assert!(obs_ratio >= 0.97,
            "telemetry costs more than 3% decode tokens/s (ratio {obs_ratio:.3})");
    }
    let obs_report = Json::obj(vec![
        ("bench", Json::Str("obs_overhead".into())),
        ("config", Json::Str(config.into())),
        ("workers", Json::Num(obs_workers as f64)),
        ("requests", Json::Num(n_scale as f64)),
        ("reps", Json::Num(obs_reps as f64)),
        ("without_tokens_per_s", Json::Num(without_tps)),
        ("with_tokens_per_s", Json::Num(with_tps)),
        ("ratio", Json::Num(obs_ratio)),
        ("gate", Json::Num(0.97)),
        ("gate_enforced", Json::Num(!sqft::util::bench::smoke() as u8 as f64)),
        ("smoke", Json::Num(sqft::util::bench::smoke() as u8 as f64)),
    ]);
    std::fs::write("BENCH_obs_overhead.json", obs_report.to_string_pretty())?;
    println!("wrote BENCH_obs_overhead.json");
    if let Some(path) = cli_metrics_out() {
        let obs = last_obs.as_ref().expect("instrumented rep ran");
        let trace = obs.trace().map(|t| t.as_ref());
        sqft::obs::expose::write_files(obs.registry(), trace, Path::new(&path))?;
        println!("wrote metrics snapshot to {path} (+ .json, .trace.jsonl)");
    }

    // --- decode hot path: cached device-resident adapters vs host upload
    // Steady-state criterion: a registered tenant's decode step ships only
    // the token batch across the PJRT boundary (asserted below, exactly).
    let max_new = 4usize;
    let engine = Engine::new(&rt, config, &frozen, None, "eval", max_new)?;
    // This section (and the continuous-batching one below) measures
    // ADAPTER residency, so pin the legacy full-forward decode: its
    // upload contract is exactly one token batch per step, and every
    // forward costs the same whether a slot was just refilled or not.
    // The KV split's prefill/frontier ledger is asserted in
    // tests/serve_kv_cache.rs and measured in the seq sweep below.
    engine.set_full_forward(true);
    let mut registry = AdapterRegistry::new(max_tenants);
    registry.register_resident(&rt, &hyper, entries[0].clone())?;
    let tenant = &entries[0];
    let sets: Vec<&ParamSet> = tenant.host_sets.iter().collect();
    let mut grng = Rng::new(23);
    let prompts: Vec<String> =
        (0..hyper.batch).map(|_| task.gen_sample(&mut grng).prompt).collect();

    // equivalence gate: the cached path must answer byte-identically
    let host_ans = engine.generate_batch_for(&sets, &tenant.eval_kind, &prompts)?;
    let dev = registry.device_set(&tenant.id).expect("tenant is device-resident");
    let cached_ans =
        engine.generate_batch_cached(Some(dev), &[], &tenant.eval_kind, &prompts)?;
    assert_eq!(host_ans, cached_ans, "cached decode path diverged from host path");

    let gen_tokens = |ans: &[String]| -> usize { ans.iter().map(|a| a.len() + 1).sum() };
    let iters = smoke_iters(8);
    let run = |dev: Option<&DeviceStore>,
               hs: &[&ParamSet]|
     -> anyhow::Result<(f64, u64, usize)> {
        engine.generate_batch_cached(dev, hs, &tenant.eval_kind, &prompts)?; // warmup
        let scope = UploadScope::begin(); // thread-scoped: exact even if
                                          // other threads upload
        let t0 = Instant::now();
        let (mut toks, mut steps) = (0usize, 0usize);
        for _ in 0..iters {
            let ans = engine.generate_batch_cached(dev, hs, &tenant.eval_kind, &prompts)?;
            toks += gen_tokens(&ans);
            steps += engine.last_decode_steps();
        }
        let secs = t0.elapsed().as_secs_f64();
        Ok((toks as f64 / secs.max(1e-12), scope.bytes(), steps))
    };
    let (host_tps, host_bytes, host_steps) = run(None, &sets)?;
    let (cached_tps, cached_bytes, cached_steps) = run(Some(dev), &[])?;
    let token_batch_bytes = (hyper.batch * hyper.seq_len * 4) as u64;
    let host_per_step = host_bytes / host_steps.max(1) as u64;
    let cached_per_step = cached_bytes / cached_steps.max(1) as u64;
    // hard invariants, independent of timing noise
    assert_eq!(
        cached_bytes,
        cached_steps as u64 * token_batch_bytes,
        "cached decode uploaded more than the token batch per step"
    );
    assert!(host_per_step > cached_per_step,
        "host path should upload strictly more per step");
    let adapter_bytes: usize = tenant.host_sets.iter().map(|s| s.total_bytes()).sum();
    println!(
        "bench decode_host_upload   {host_tps:>10.1} tok/s  {host_per_step:>8} B/step"
    );
    println!(
        "bench decode_cached        {cached_tps:>10.1} tok/s  {cached_per_step:>8} B/step"
    );
    println!(
        "decode speedup {:.2}x; per-step upload cut {} -> {} bytes (token batch = {} B, \
tenant adapter payload = {} B)",
        cached_tps / host_tps.max(1e-12),
        host_per_step, cached_per_step, token_batch_bytes, adapter_bytes
    );
    // --- continuous batching vs run-to-completion, mixed short/long -----
    // One long request (min == max pins its decode length) per three
    // one-token requests: run-to-completion pays the long row for every
    // slot in its batch, continuous batching retires short slots and
    // re-fills them between forwards.  Per-forward cost is identical
    // (same artifact, full batch), so occupancy and tokens/s gains are
    // structural, and per-request answers must stay byte-identical.
    let b = hyper.batch;
    let n_mixed = if sqft::util::bench::smoke() { 2 * b } else { 4 * b };
    let mut grng = Rng::new(29);
    let specs: Vec<(String, Option<usize>, usize)> = (0..n_mixed)
        .map(|i| {
            let prompt = task.gen_sample(&mut grng).prompt;
            if i % 4 == 0 {
                (prompt, Some(max_new), max_new) // long: exactly max_new tokens
            } else {
                (prompt, Some(1), 0) // short: one token
            }
        })
        .collect();
    // run-to-completion reference over the host-upload path
    let run_rtc = |dev: Option<&DeviceStore>,
                   hs: &[&ParamSet]|
     -> anyhow::Result<(Vec<String>, usize, usize, f64)> {
        let mut answers = vec![String::new(); specs.len()];
        let (mut steps, mut slot_steps) = (0usize, 0usize);
        let t0 = Instant::now();
        for (ci, chunk) in specs.chunks(b).enumerate() {
            let mut s = engine.begin_decode()?;
            for (prompt, mx, mn) in chunk {
                engine.admit(&mut s, prompt, *mx, *mn)?;
            }
            while s.active_slots() > 0 {
                for (slot, ans) in engine.decode_step(&mut s, dev, hs, &tenant.eval_kind)? {
                    answers[ci * b + slot] = ans;
                }
            }
            steps += s.steps();
            slot_steps += s.slot_steps();
        }
        Ok((answers, steps, slot_steps, t0.elapsed().as_secs_f64()))
    };
    // continuous: one session, freed slots re-filled between forwards
    let run_continuous = |dev: Option<&DeviceStore>,
                          hs: &[&ParamSet]|
     -> anyhow::Result<(Vec<String>, usize, usize, f64)> {
        let mut s = engine.begin_decode()?;
        let mut answers = vec![String::new(); specs.len()];
        let mut slot_req = vec![0usize; b];
        let mut next = 0usize;
        let t0 = Instant::now();
        loop {
            while s.free_slots() > 0 && next < specs.len() {
                let (prompt, mx, mn) = &specs[next];
                let slot = engine.admit(&mut s, prompt, *mx, *mn)?;
                slot_req[slot] = next;
                next += 1;
            }
            if s.active_slots() == 0 {
                break;
            }
            for (slot, ans) in engine.decode_step(&mut s, dev, hs, &tenant.eval_kind)? {
                answers[slot_req[slot]] = ans;
            }
        }
        Ok((answers, s.steps(), s.slot_steps(), t0.elapsed().as_secs_f64()))
    };
    let (rtc_ans, rtc_steps, rtc_tokens, rtc_secs) = run_rtc(None, &sets)?;
    let (cont_ans, cont_steps, cont_tokens, cont_secs) = run_continuous(Some(dev), &[])?;
    assert_eq!(cont_ans, rtc_ans,
        "continuous-batching answers diverged from the run-to-completion host reference");
    assert_eq!(cont_tokens, rtc_tokens, "paths generated different token counts");
    assert!(cont_steps < rtc_steps,
        "continuous batching must need fewer forwards ({cont_steps} vs {rtc_steps})");
    let rtc_occ = rtc_tokens as f64 / (rtc_steps * b) as f64;
    let cont_occ = cont_tokens as f64 / (cont_steps * b) as f64;
    let rtc_tps = rtc_tokens as f64 / rtc_secs.max(1e-12);
    let cont_tps = cont_tokens as f64 / cont_secs.max(1e-12);
    assert!(cont_occ > rtc_occ, "occupancy must improve: {cont_occ:.3} vs {rtc_occ:.3}");
    assert!(cont_tps > rtc_tps, "tokens/s must improve: {cont_tps:.1} vs {rtc_tps:.1}");
    println!(
        "bench decode_run_to_completion {rtc_tps:>10.1} tok/s  occupancy {rtc_occ:.2}  \
({rtc_steps} forwards)"
    );
    println!(
        "bench decode_continuous        {cont_tps:>10.1} tok/s  occupancy {cont_occ:.2}  \
({cont_steps} forwards)"
    );
    println!(
        "continuous batching speedup {:.2}x on {} mixed requests",
        cont_tps / rtc_tps.max(1e-12),
        n_mixed
    );

    // --- KV-cache split: tokens/s vs artifact sequence length -----------
    // The resident-cache claim: after prefill, a cached decode step does
    // O(1) fresh work per row (one-token frontier attending against the
    // device-resident K/V pages), so tokens/s stays ~flat as the compiled
    // sequence length grows; the legacy full forward re-runs the whole
    // O(S) prefix every step and degrades.  The sqft-tiny-s96/-s192
    // serve-only variants share sqft-tiny's weight shapes (RoPE carries
    // the positions — there is no learned positional table), so the one
    // frozen set and resident tenant entry above serve all three configs;
    // configs or prefill kinds absent from the artifact dir are skipped.
    let sweep_iters = smoke_iters(4);
    let mut sweep_rows: Vec<Json> = Vec::new();
    let mut curve: Vec<(usize, f64, f64)> = Vec::new(); // (seq, kv tok/s, full tok/s)
    for sweep_cfg in ["sqft-tiny", "sqft-tiny-s96", "sqft-tiny-s192"] {
        let Ok(h) = rt.model(sweep_cfg) else {
            println!("seq sweep: {sweep_cfg} not in the artifact dir, skipping");
            continue;
        };
        let h = h.clone();
        let eng = Engine::new(&rt, sweep_cfg, &frozen, None, "eval", max_new)?;
        if !eng.kv_cache_active("eval") {
            println!("seq sweep: {sweep_cfg} has no prefill/decode artifacts, skipping");
            continue;
        }
        let mut prng = Rng::new(31);
        let sweep_prompts: Vec<String> =
            (0..h.batch).map(|_| task.gen_sample(&mut prng).prompt).collect();
        let time_leg = |full: bool| -> anyhow::Result<(f64, u64, usize, usize)> {
            eng.set_full_forward(full);
            eng.generate_batch_cached(
                Some(dev), &[], &tenant.eval_kind, &sweep_prompts)?; // warmup
            let scope = UploadScope::begin();
            let t0 = Instant::now();
            let (mut toks, mut steps, mut prefills) = (0usize, 0usize, 0usize);
            for _ in 0..sweep_iters {
                let ans = eng.generate_batch_cached(
                    Some(dev), &[], &tenant.eval_kind, &sweep_prompts)?;
                toks += gen_tokens(&ans);
                steps += eng.last_decode_steps();
                prefills += eng.last_decode_prefills();
            }
            let secs = t0.elapsed().as_secs_f64();
            Ok((toks as f64 / secs.max(1e-12), scope.bytes(), steps, prefills))
        };
        let (full_tps, full_bytes, full_steps, full_prefills) = time_leg(true)?;
        let (kv_tps, kv_bytes, kv_steps, kv_prefills) = time_leg(false)?;
        let tok_bytes = (h.batch * h.seq_len * 4) as u64;
        let vec_bytes = (h.batch * 4) as u64;
        // exact byte ledgers, independent of timing noise
        assert_eq!(full_prefills, 0, "{sweep_cfg}: legacy leg must not prefill");
        assert_eq!(full_bytes, full_steps as u64 * tok_bytes,
            "{sweep_cfg}: legacy leg must upload one token batch per step");
        assert!(kv_prefills >= sweep_iters,
            "{sweep_cfg}: every generate must prefill its admitted rows");
        assert_eq!(
            kv_bytes,
            kv_prefills as u64 * (tok_bytes + vec_bytes)
                + (kv_steps - kv_prefills) as u64 * 2 * vec_bytes,
            "{sweep_cfg}: cached decode must ship only the one-token frontier \
after prefill"
        );
        println!(
            "bench kv_seq_sweep {sweep_cfg:<14} S={:>3}  kv_cached {kv_tps:>9.1} tok/s  \
full_forward {full_tps:>9.1} tok/s",
            h.seq_len
        );
        sweep_rows.push(Json::obj(vec![
            ("config", Json::Str(sweep_cfg.into())),
            ("seq_len", Json::Num(h.seq_len as f64)),
            ("kv_cached", Json::obj(vec![
                ("tokens_per_s", Json::Num(kv_tps)),
                ("upload_bytes_total", Json::Num(kv_bytes as f64)),
                ("decode_steps", Json::Num(kv_steps as f64)),
                ("prefills", Json::Num(kv_prefills as f64)),
            ])),
            ("full_forward", Json::obj(vec![
                ("tokens_per_s", Json::Num(full_tps)),
                ("upload_bytes_total", Json::Num(full_bytes as f64)),
                ("decode_steps", Json::Num(full_steps as f64)),
            ])),
        ]));
        curve.push((h.seq_len, kv_tps, full_tps));
    }
    if curve.len() >= 2 && !sqft::util::bench::smoke() {
        let (s0, kv0, full0) = curve[0];
        let (s1, kv1, full1) = *curve.last().unwrap();
        let kv_drop = kv0 / kv1.max(1e-12);
        let full_drop = full0 / full1.max(1e-12);
        assert!(kv_drop < 2.0,
            "kv_cached curve must stay ~flat across sequence lengths: \
{kv0:.1} tok/s @S{s0} vs {kv1:.1} @S{s1}");
        assert!(full_drop > kv_drop,
            "full forward must degrade faster with S than cached decode \
(full {full_drop:.2}x vs cached {kv_drop:.2}x over S{s0}->S{s1})");
    }

    let report = Json::obj(vec![
        ("bench", Json::Str("decode_hot_path".into())),
        ("config", Json::Str(config.into())),
        ("batch", Json::Num(hyper.batch as f64)),
        ("seq_len", Json::Num(hyper.seq_len as f64)),
        ("max_new_tokens", Json::Num(max_new as f64)),
        ("iters", Json::Num(iters as f64)),
        ("token_batch_bytes", Json::Num(token_batch_bytes as f64)),
        ("tenant_adapter_bytes", Json::Num(adapter_bytes as f64)),
        ("host_upload", Json::obj(vec![
            ("tokens_per_s", Json::Num(host_tps)),
            ("upload_bytes_total", Json::Num(host_bytes as f64)),
            ("upload_bytes_per_step", Json::Num(host_per_step as f64)),
        ])),
        ("cached", Json::obj(vec![
            ("tokens_per_s", Json::Num(cached_tps)),
            ("upload_bytes_total", Json::Num(cached_bytes as f64)),
            ("upload_bytes_per_step", Json::Num(cached_per_step as f64)),
        ])),
        ("speedup_tokens_per_s", Json::Num(cached_tps / host_tps.max(1e-12))),
        ("mixed_workload_requests", Json::Num(n_mixed as f64)),
        ("run_to_completion", Json::obj(vec![
            ("forwards", Json::Num(rtc_steps as f64)),
            ("generated_tokens", Json::Num(rtc_tokens as f64)),
            ("slot_occupancy", Json::Num(rtc_occ)),
            ("tokens_per_s", Json::Num(rtc_tps)),
        ])),
        ("continuous", Json::obj(vec![
            ("forwards", Json::Num(cont_steps as f64)),
            ("generated_tokens", Json::Num(cont_tokens as f64)),
            ("slot_occupancy", Json::Num(cont_occ)),
            ("tokens_per_s", Json::Num(cont_tps)),
        ])),
        ("continuous_speedup_tokens_per_s", Json::Num(cont_tps / rtc_tps.max(1e-12))),
        ("kv_cache_seq_sweep", Json::Arr(sweep_rows)),
    ]);
    std::fs::write("BENCH_decode.json", report.to_string_pretty())?;
    println!("wrote BENCH_decode.json");

    // --- mixed-tenant long tail: gathered banks vs same-tenant sessions --
    // The S-LoRA long tail: every tenant sends exactly ONE request.
    // Same-tenant serving pays one session per tenant (occupancy 1/b
    // each); the gathered adapter banks decode every tenant's row in a
    // single mixed session (per-row `adapter_idx` into the stacked
    // banks), so the forward count drops ~Nx at identical per-forward
    // cost.  Answers must not move between the two shapes.
    let engine_g = Engine::new(&rt, config, &frozen, None, "eval", max_new)?;
    if !engine_g.supports_gathered() {
        println!("skipping mixed-batch bench: artifacts lack the gathered kind");
    } else {
        let tail_new = max_new; // min == max pins every row's length
        let mut grng = Rng::new(41);
        let tail: Vec<(String, String)> = entries
            .iter()
            .map(|e| (e.id.clone(), task.gen_sample(&mut grng).prompt))
            .collect();
        let reps = smoke_iters(3);

        // same-tenant baseline: one device-cached session per tenant, so
        // the comparison isolates batching structure, not upload traffic
        let mut st_registry = AdapterRegistry::new(max_tenants);
        for e in &entries {
            st_registry.register_resident(&rt, &hyper, e.clone())?;
        }
        let (mut st_answers, mut st_forwards, mut st_tokens) = (Vec::new(), 0usize, 0usize);
        let mut st_secs = f64::MAX;
        for _ in 0..reps {
            let (mut answers, mut forwards, mut tokens) = (Vec::new(), 0usize, 0usize);
            let t0 = Instant::now();
            for (e, (_, prompt)) in entries.iter().zip(&tail) {
                let dev = st_registry.device_set(&e.id).expect("tenant is resident");
                let mut s = engine_g.begin_decode()?;
                engine_g.admit(&mut s, prompt, Some(tail_new), tail_new)?;
                while s.active_slots() > 0 {
                    for (_, ans) in engine_g.decode_step(&mut s, Some(dev), &[], &e.eval_kind)? {
                        answers.push(ans);
                    }
                }
                forwards += s.steps();
                tokens += s.slot_steps();
            }
            st_secs = st_secs.min(t0.elapsed().as_secs_f64());
            st_answers = answers;
            st_forwards = forwards;
            st_tokens = tokens;
        }

        // mixed: the same requests through the router's gathered session
        let mut mx_stats: Option<sqft::serve::MultiServeStats> = None;
        let mut mx_answers: Vec<String> = Vec::new();
        for _ in 0..reps {
            let engine = Engine::new(&rt, config, &frozen, None, "eval", max_new)?;
            let mut registry = AdapterRegistry::new(max_tenants);
            for e in &entries {
                registry.register_resident(&rt, &hyper, e.clone())?;
            }
            let mut router = Router::new(engine, registry);
            let (tx, rx) = channel::<Request>();
            let mut replies = Vec::new();
            for (id, p) in &tail {
                let (rtx, rrx) = channel();
                let mut req = Request::new(Some(id.clone()), p.clone(), rtx);
                req.max_new_tokens = Some(tail_new);
                req.min_new_tokens = tail_new;
                let _ = tx.send(req);
                replies.push(rrx);
            }
            drop(tx);
            let opts = SchedulerOpts { max_batch: hyper.batch,
                                       aging: Duration::from_millis(20),
                                       ..Default::default() };
            let stats = router.serve(rx, opts)?;
            assert_eq!(stats.total.errors, 0, "mixed long-tail run had errors");
            mx_answers = replies.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();
            if mx_stats.as_ref().map_or(true, |b| stats.total.wall_secs < b.total.wall_secs) {
                mx_stats = Some(stats);
            }
        }
        let mx = mx_stats.expect("mixed rep ran");
        assert_eq!(mx_answers, st_answers,
            "mixed-session answers diverged from the same-tenant sessions");
        assert!(mx.scheduler.mixed_batches >= 1, "long tail must dispatch mixed");
        assert_eq!(mx.generated_tokens, st_tokens, "paths generated different token counts");
        assert!(mx.decode_steps < st_forwards,
            "one mixed session must need fewer forwards ({} vs {st_forwards})",
            mx.decode_steps);
        let st_occ = st_tokens as f64 / (st_forwards * hyper.batch) as f64;
        let st_tps = st_tokens as f64 / st_secs.max(1e-12);
        let mx_tps = mx.generated_tokens as f64 / mx.total.wall_secs.max(1e-12);
        let speedup = mx_tps / st_tps.max(1e-12);
        println!(
            "bench serve_same_tenant_tail {st_tps:>10.1} tok/s  occupancy {st_occ:.2}  \
({st_forwards} forwards)"
        );
        println!(
            "bench serve_mixed_tail       {mx_tps:>10.1} tok/s  occupancy {:.2}  \
({} forwards)",
            mx.occupancy, mx.decode_steps
        );
        println!("mixed-batch speedup {speedup:.2}x on the {}-tenant long tail", tail.len());
        // structural gain: N rows per forward instead of 1 — timing
        // assert, so full runs only (smoke shares CI boxes)
        if !sqft::util::bench::smoke() {
            assert!(speedup > 2.0,
                "mixed long-tail tokens/s must beat same-tenant sessions by >2x, \
got {speedup:.2}x");
        }
        let mixed_report = Json::obj(vec![
            ("bench", Json::Str("mixed_batch".into())),
            ("config", Json::Str(config.into())),
            ("batch", Json::Num(hyper.batch as f64)),
            ("tenants", Json::Num(tail.len() as f64)),
            ("requests", Json::Num(tail.len() as f64)),
            ("new_tokens_per_request", Json::Num(tail_new as f64)),
            ("reps", Json::Num(reps as f64)),
            ("same_tenant", Json::obj(vec![
                ("forwards", Json::Num(st_forwards as f64)),
                ("generated_tokens", Json::Num(st_tokens as f64)),
                ("slot_occupancy", Json::Num(st_occ)),
                ("tokens_per_s", Json::Num(st_tps)),
            ])),
            ("mixed", Json::obj(vec![
                ("forwards", Json::Num(mx.decode_steps as f64)),
                ("generated_tokens", Json::Num(mx.generated_tokens as f64)),
                ("slot_occupancy", Json::Num(mx.occupancy)),
                ("tokens_per_s", Json::Num(mx_tps)),
                ("mixed_batches", Json::Num(mx.scheduler.mixed_batches as f64)),
            ])),
            ("speedup_tokens_per_s", Json::Num(speedup)),
            ("gate", Json::Num(2.0)),
            ("gate_enforced", Json::Num(!sqft::util::bench::smoke() as u8 as f64)),
            ("smoke", Json::Num(sqft::util::bench::smoke() as u8 as f64)),
        ]);
        std::fs::write("BENCH_mixed_batch.json", mixed_report.to_string_pretty())?;
        println!("wrote BENCH_mixed_batch.json");
    }

    // --- merged vs unmerged per-tenant serving cost ---------------------
    let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
    let space = SearchSpace::new(&prepared.hyper, choices, alpha)?;
    let topts = TrainOpts { steps: tenant_steps, lr: 1e-3, log_every: tenant_steps.max(1),
                            seed: 1, fixed_rank: false };
    let (trainer, _) = pipeline::finetune(&rt, config, &prepared, space,
                                          &ds.train, &tok, &topts)?;
    let cfg = trainer.space.heuristic_config();
    let merged = pipeline::merged_state(&prepared, &trainer, &cfg)?;
    let mut frozen_m = sqft::model::ParamSet::new();
    for (n, v) in merged.base.iter() {
        frozen_m.insert(n, v.clone());
    }
    for (n, v) in pipeline::dense_adapter_masks(&hyper).iter() {
        frozen_m.insert(n, v.clone());
    }
    let engine_un = Engine::new(&rt, config, &frozen,
                                Some((&trainer.adapters, &trainer.space, &cfg)),
                                "eval", 4)?;
    let engine_m = Engine::new(&rt, config, &frozen_m, None, "eval", 4)?;
    let mut grng = Rng::new(3);
    let prompts: Vec<String> =
        (0..8).map(|_| task.gen_sample(&mut grng).prompt).collect();
    let bench_iters = smoke_iters(8);
    let t_un = bench_throughput("serve_unmerged_per_tenant", 1, bench_iters, || {
        engine_un.generate_batch(&prompts).unwrap();
        prompts.len()
    });
    let t_m = bench_throughput("serve_merged_per_tenant", 1, bench_iters, || {
        engine_m.generate_batch(&prompts).unwrap();
        prompts.len()
    });
    println!("merged/unmerged per-tenant speedup: {:.2}x (paper §2.5: merged serves cheaper)",
             t_m / t_un);
    Ok(())
}
