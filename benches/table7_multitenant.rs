//! Multi-tenant extension of the Table 7 serving bench: throughput vs
//! tenant count over one device-resident frozen base (registry → scheduler
//! → engine), plus the merged-vs-unmerged per-tenant serving cost the
//! paper's §2.5 argument turns on.

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::init_base;
use sqft::nls::SearchSpace;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::report::Table;
use sqft::runtime::Runtime;
use sqft::serve::{benchmark_router, AdapterRegistry, Engine, Router, SchedulerOpts};
use sqft::tensor::Rng;
use sqft::train::TrainOpts;
use sqft::util::bench::bench_throughput;
use std::path::Path;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let config = "sqft-tiny";
    let hyper = rt.model(config)?.clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 600, 0, 50, 7);
    let base = init_base(&hyper, &mut Rng::new(7));

    println!("# table7 multitenant bench: throughput vs tenant count");
    let prepared = pipeline::prepare(&rt, config, &base, Method::SparsePeft, 0.5,
                                     &ds.train, &tok, 2, &mut Rng::new(9))?;
    let frozen = prepared.frozen_set()?;
    let max_tenants = 4usize;
    let entries = pipeline::tenant_adapters(&rt, config, &prepared, max_tenants,
                                            &ds.train, &tok, 5, 77)?;

    // --- throughput vs tenant count over one frozen base ---------------
    let n_requests = 48usize;
    let mut table = Table::new(
        "Throughput vs tenant count (one device-resident base)",
        &["tenants", "served", "req/s", "avg batch fill", "batches", "aged"],
    );
    for &k in &[1usize, 2, 4] {
        let engine = Engine::new(&rt, config, &frozen, None, "eval", 4)?;
        let mut registry = AdapterRegistry::new(max_tenants);
        let ids: Vec<String> = entries[..k].iter().map(|e| e.id.clone()).collect();
        for e in &entries[..k] {
            registry.register(&hyper, e.clone())?;
        }
        let mut router = Router::new(engine, registry);
        let mut grng = Rng::new(11 + k as u64);
        let requests: Vec<(Option<String>, String)> = (0..n_requests)
            .map(|i| (Some(ids[i % k].clone()), task.gen_sample(&mut grng).prompt))
            .collect();
        let opts = SchedulerOpts { max_batch: hyper.batch,
                                   aging: Duration::from_millis(20) };
        let stats = benchmark_router(&mut router, requests,
                                     Duration::from_millis(1), opts)?;
        table.row(vec![
            k.to_string(),
            stats.total.served.to_string(),
            format!("{:.1}", stats.total.throughput),
            format!("{:.2}", stats.scheduler.avg_fill()),
            stats.scheduler.batches.to_string(),
            stats.scheduler.aged_batches.to_string(),
        ]);
    }
    print!("{}", table.render());

    // --- merged vs unmerged per-tenant serving cost ---------------------
    let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
    let space = SearchSpace::new(&prepared.hyper, choices, alpha)?;
    let topts = TrainOpts { steps: 5, lr: 1e-3, log_every: 5, seed: 1,
                            fixed_rank: false };
    let (trainer, _) = pipeline::finetune(&rt, config, &prepared, space,
                                          &ds.train, &tok, &topts)?;
    let cfg = trainer.space.heuristic_config();
    let merged = pipeline::merged_state(&prepared, &trainer, &cfg)?;
    let mut frozen_m = sqft::model::ParamSet::new();
    for (n, v) in merged.base.iter() {
        frozen_m.insert(n, v.clone());
    }
    for (n, v) in pipeline::dense_adapter_masks(&hyper).iter() {
        frozen_m.insert(n, v.clone());
    }
    let engine_un = Engine::new(&rt, config, &frozen,
                                Some((&trainer.adapters, &trainer.space, &cfg)),
                                "eval", 4)?;
    let engine_m = Engine::new(&rt, config, &frozen_m, None, "eval", 4)?;
    let mut grng = Rng::new(3);
    let prompts: Vec<String> =
        (0..8).map(|_| task.gen_sample(&mut grng).prompt).collect();
    let t_un = bench_throughput("serve_unmerged_per_tenant", 1, 8, || {
        engine_un.generate_batch(&prompts).unwrap();
        prompts.len()
    });
    let t_m = bench_throughput("serve_merged_per_tenant", 1, 8, || {
        engine_m.generate_batch(&prompts).unwrap();
        prompts.len()
    });
    println!("merged/unmerged per-tenant speedup: {:.2}x (paper §2.5: merged serves cheaper)",
             t_m / t_un);
    Ok(())
}
