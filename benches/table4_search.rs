//! Bench for Table 4's cost driver: configuration-evaluation throughput
//! during hill-climbing (Algorithm 1's Eval step dominates the search
//! budget) and the search bookkeeping itself.

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::init_base;
use sqft::nls::{hill_climb, SearchSpace};
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::Runtime;
use sqft::tensor::Rng;
use sqft::train::TrainOpts;
use sqft::util::bench::bench;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let config = "sqft-tiny";
    let tok = Tokenizer::new();
    let ds = Dataset::generate(Task::SynArcE, 400, 100, 50, 7);
    let hyper = rt.model(config)?.clone();
    let base = init_base(&hyper, &mut Rng::new(7));

    println!("# table4 bench: NLS config-eval throughput + search bookkeeping");
    let prepared = pipeline::prepare(&rt, config, &base, Method::SparsePeft, 0.5,
                                     &ds.train, &tok, 2, &mut Rng::new(9))?;
    let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
    let space = SearchSpace::new(&prepared.hyper, choices, alpha)?;
    let opts = TrainOpts { steps: 5, lr: 1e-3, log_every: 5, seed: 1,
                           fixed_rank: false };
    let (trainer, _) = pipeline::finetune(&rt, config, &prepared, space,
                                          &ds.train, &tok, &opts)?;
    let cfg = trainer.space.heuristic_config();

    bench("eval_one_config_100val", 1, 5, || {
        pipeline::evaluate_unmerged(&rt, config, &prepared, &trainer, &cfg,
                                    &ds.val, &tok).unwrap();
    });
    bench("realize_rank_masks", 2, 50, || {
        trainer.space.realize(&cfg).unwrap();
    });
    // pure search bookkeeping with a synthetic objective
    let space2 = trainer.space.clone();
    bench("hill_climb_bookkeeping_t10_n8", 1, 5, || {
        let mut rng = Rng::new(5);
        let s = space2.clone();
        hill_climb(&s, s.heuristic_config(), 10, 8, 2,
                   |c| Ok(c.iter().sum::<usize>() as f64), &mut rng).unwrap();
    });
    Ok(())
}
