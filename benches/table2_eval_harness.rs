//! Bench for Tables 2/3's cost driver: multi-task evaluation throughput
//! (batched eval artifact + exact-match scoring) and batcher encoding.

use sqft::data::{Batcher, Dataset, Task, Tokenizer};
use sqft::model::init_base;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::Runtime;
use sqft::tensor::Rng;
use sqft::util::bench::{bench, bench_throughput};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let config = "sqft-tiny";
    let hyper = rt.model(config)?.clone();
    let tok = Tokenizer::new();

    println!("# table2/3 bench: eval harness + batcher throughput");
    // batcher encoding throughput (host-side substrate)
    let ds_all = Dataset::generate(Task::SynGsm, 2000, 0, 0, 7);
    bench_throughput("batcher_encode_2000", 1, 5, || {
        let mut b = Batcher::new(&ds_all.train, &tok, hyper.seq_len, hyper.batch);
        let mut n = 0;
        while let Some(batch) = b.next_batch().unwrap() {
            n += batch.real;
        }
        n
    });

    // eval throughput per task family
    let base = init_base(&hyper, &mut Rng::new(7));
    let prepared = pipeline::prepare(&rt, config, &base, Method::Lora, 0.0,
                                     &Dataset::generate(Task::SynGsm, 100, 0, 0, 7).train,
                                     &tok, 0, &mut Rng::new(9))?;
    for task in [Task::SynGsm, Task::SynBoolq] {
        let ds = Dataset::generate(task, 0, 0, 200, 7);
        bench(&format!("eval_200/{}", task.name()), 1, 3, || {
            pipeline::evaluate_base(&rt, config, &prepared, &ds.test, &tok).unwrap();
        });
    }
    Ok(())
}
