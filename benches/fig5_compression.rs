//! Bench for Figure 5's substrate: Wanda mask construction and GPTQ
//! quantization cost across sparsity levels and layer shapes.

use sqft::quant::{gptq_quantize, rtn_quantize};
use sqft::sparsity::{nm_mask, topk_row_mask, wanda_mask_host};
use sqft::tensor::{Rng, Tensor};
use sqft::util::bench::bench;

fn main() {
    println!("# fig5 bench: compression substrate across shapes/sparsities");
    let mut rng = Rng::new(1);
    for (m, n) in [(256, 256), (1024, 256), (256, 1024)] {
        let w = Tensor::randn(&mut rng, &[m, n], 0.5);
        let norms = Tensor::rand_uniform(&mut rng, &[n], 0.1, 2.0);
        for sp in [0.3, 0.5, 0.7] {
            bench(&format!("wanda_mask/{m}x{n}/s{sp}"), 1, 5, || {
                wanda_mask_host(&w, &norms, sp);
            });
        }
        bench(&format!("nm_mask_2_4/{m}x{n}"), 1, 5, || {
            nm_mask(&w, 2, 4).unwrap();
        });
        let scores = Tensor::rand_uniform(&mut rng, &[m, n], 0.0, 1.0);
        bench(&format!("topk_row_mask/{m}x{n}"), 1, 5, || {
            topk_row_mask(&scores, 0.5);
        });
    }
    // GPTQ vs RTN at a transformer-layer shape
    let n = 256;
    let w = Tensor::randn(&mut rng, &[256, n], 0.5);
    let x = Tensor::randn(&mut rng, &[512, n], 1.0);
    let mut h = Tensor::zeros(&[n, n]);
    x.accumulate_gram(&mut h);
    bench("rtn_quantize/256x256", 1, 5, || {
        rtn_quantize(&w, 32, 4, None).unwrap();
    });
    bench("gptq_quantize/256x256", 1, 3, || {
        gptq_quantize(&w, &h, 32, 4, None, 0.01).unwrap();
    });
}
