//! Bench for Table 5's axis: the cost of NLS elastic-rank sampling vs
//! fixed-rank LoRA in the train step (paper: "slightly slower due to the
//! additional mask and adapter calculations").

use sqft::data::{Batcher, Dataset, Task, Tokenizer};
use sqft::model::init_base;
use sqft::nls::SearchSpace;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::Runtime;
use sqft::tensor::Rng;
use sqft::train::TrainOpts;
use sqft::util::bench::bench;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let config = "sqft-tiny";
    let hyper = rt.model(config)?.clone();
    let tok = Tokenizer::new();
    let ds = Dataset::generate(Task::SynGsm, 600, 0, 50, 7);
    let base = init_base(&hyper, &mut Rng::new(7));

    println!("# table5 bench: LoRA vs NLS step cost, dense vs masked adapters");
    for (label, method, fixed) in [
        ("lora_fixed_rank", Method::Shears, true),
        ("nls_sampled_rank", Method::Shears, false),
        ("sparsepeft_nls", Method::SparsePeft, false),
        ("qa_sparsepeft_nls", Method::QaSparsePeft, false),
    ] {
        let prepared = pipeline::prepare(&rt, config, &base, method, 0.5,
                                         &ds.train, &tok, 2, &mut Rng::new(9))?;
        let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
        let space = SearchSpace::new(&prepared.hyper, choices, alpha)?;
        let opts = TrainOpts { steps: 1, lr: 1e-3, log_every: 1, seed: 1,
                               fixed_rank: fixed };
        let (mut trainer, _) =
            pipeline::finetune(&rt, config, &prepared, space, &ds.train, &tok, &opts)?;
        let batcher = Batcher::new(&ds.train, &tok, hyper.seq_len, hyper.batch);
        let mut brng = Rng::new(3);
        bench(label, 2, 15, || {
            let b = batcher.random_batch(&mut brng).unwrap();
            trainer.step_batch(&b, 1e-3).unwrap();
        });
    }
    Ok(())
}
