//! Chaos / graceful-degradation bench for the fault-isolated serving
//! stack (`BENCH_chaos.json`): the same closed-loop multi-tenant pool
//! workload as the Table 7 serving bench, run under a seeded
//! fault-injection plan (`sqft::faults`).
//!
//! Four legs, all deterministic under the plan seed:
//!
//!   1. **Isolation** — exactly one persistent decode-forward failure
//!      (retry budget 0, `FaultRule::window`) must fail at most one
//!      session's resident requests, all from one tenant, while every
//!      other request's answer stays byte-identical to the fault-free
//!      baseline.  The failed/total ratio is asserted and recorded as
//!      the error-isolation ratio.
//!   2. **Prefill isolation** — one persistent cache-page prefill
//!      failure (`SITE_PREFILL`, fired at a mid-session refill rebuild,
//!      retry budget 0) must fail only the requests being admitted:
//!      in-flight rows keep their resident K/V pages and answer
//!      baseline bytes.  Skipped against artifact dirs that predate the
//!      KV-cache split.
//!   3. **Crash recovery** — an injected worker panic
//!      (`SITE_WORKER_PANIC`) must lose no requests: the crashed
//!      worker's claimed batch is requeued to siblings and every answer
//!      still matches the baseline.
//!   4. **Degradation sweep** — goodput (delivered answers / requests)
//!      vs forward fault rate 0% / 1% / 5% with the default retry
//!      budget; each nonzero rate also pins one guaranteed transient
//!      forward failure (`FaultRule::nth`) — plus one transient
//!      cached-decode upload failure (`SITE_CACHE_UPLOAD`) when the KV
//!      split is live — so `serve_retries_total > 0` is a deterministic
//!      assertion, not a coin flip.
//!
//! `SQFT_BENCH_SMOKE=1` shrinks the request counts (CI smoke);
//! `-- --metrics-out PATH` writes the final sweep run's metrics
//! snapshot (Prometheus text + JSON + trace JSONL) — what the CI
//! chaos-smoke job greps for a nonzero `serve_retries_total`.

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::faults::{
    FaultInjector, FaultKind, FaultRule, SITE_CACHE_UPLOAD, SITE_FORWARD, SITE_PREFILL,
    SITE_WORKER_PANIC,
};
use sqft::model::init_base;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::report::Table;
use sqft::runtime::Runtime;
use sqft::serve::{
    serve_pool_obs, Engine, EngineSpec, PoolOpts, Request, SchedulerOpts, ServeError, ServeObs,
    SharedAdapterSource,
};
use sqft::tensor::Rng;
use sqft::util::json::Json;
use std::path::Path;
use std::sync::mpsc::channel;
use std::time::Duration;

fn cli_metrics_out() -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter().position(|a| a == "--metrics-out").and_then(|i| argv.get(i + 1)).cloned()
}

/// One pool run of `reqs` under `faults`: per-request reply results (in
/// request order) plus the kept observability context.
type RunOut = (Vec<anyhow::Result<String>>, ServeObs, f64);

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let config = "sqft-tiny";
    let hyper = rt.model(config)?.clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 600, 0, 50, 7);
    let base = init_base(&hyper, &mut Rng::new(7));

    println!("# table7 chaos bench: serving degradation under injected faults");
    let tenant_steps = sqft::util::bench::smoke_iters(5);
    let prepared = pipeline::prepare(&rt, config, &base, Method::SparsePeft, 0.5,
                                     &ds.train, &tok, 2, &mut Rng::new(9))?;
    let frozen = prepared.frozen_set()?;
    let tenants = 3usize;
    let entries = pipeline::tenant_adapters(&rt, config, &prepared, tenants,
                                            &ds.train, &tok, tenant_steps, 77)?;
    let source = SharedAdapterSource::new(hyper.clone(), tenants);
    source.register_all(entries.clone())?;
    let spec = EngineSpec {
        artifacts: dir.clone(),
        config: config.to_string(),
        frozen: frozen.clone(),
        eval_kind: "eval".to_string(),
        max_new_tokens: 4,
        registry_capacity: tenants,
        device_budget: 0,
        degrade_ranks: Vec::new(),
    };

    let n_requests = if sqft::util::bench::smoke() { 18usize } else { 48 };
    let mut grng = Rng::new(131);
    let reqs: Vec<(Option<String>, String)> = (0..n_requests)
        .map(|i| {
            (Some(entries[i % tenants].id.clone()), task.gen_sample(&mut grng).prompt)
        })
        .collect();
    let tenant_of = |i: usize| entries[i % tenants].id.clone();

    // closed loop over the worker pool; `max_retries` and `faults` are
    // the knobs each leg varies
    let run = |workers: usize, max_retries: usize, faults: FaultInjector| -> anyhow::Result<RunOut> {
        let (tx, rx) = channel::<Request>();
        let mut replies = Vec::new();
        for (id, p) in &reqs {
            let (rtx, rrx) = channel();
            let _ = tx.send(Request::new(id.clone(), p.clone(), rtx));
            replies.push(rrx);
        }
        drop(tx);
        let popts = PoolOpts {
            workers,
            sched: SchedulerOpts { max_batch: hyper.batch,
                                   aging: Duration::from_millis(20),
                                   max_retries,
                                   ..Default::default() },
            faults,
        };
        let obs = ServeObs::with_trace();
        let kept = obs.clone();
        let stats = serve_pool_obs(&spec, &source, rx, popts, obs)?;
        let results: Vec<anyhow::Result<String>> =
            replies.into_iter().map(|r| r.recv().expect("reply channel closed")).collect();
        Ok((results, kept, stats.serving_wall_secs))
    };

    // --- fault-free baseline --------------------------------------------
    let (baseline, _, _) = run(1, 2, FaultInjector::disabled())?;
    let baseline: Vec<String> = baseline
        .into_iter()
        .map(|r| r.expect("baseline run must not error"))
        .collect();
    println!("baseline: {} requests served clean", baseline.len());

    // --- leg 1: single persistent failure, blast radius ≤ one session ---
    // Retry budget 0 turns the single injected forward failure into a
    // persistent session failure: its residents fail typed, everything
    // else must be untouched.
    let inj = FaultInjector::seeded(42)
        .with_rule(FaultRule::window(SITE_FORWARD, FaultKind::Error, 1, 1));
    let (results, _, _) = run(1, 0, inj.clone())?;
    assert_eq!(inj.fires(SITE_FORWARD), 1, "exactly one fault must have fired");
    let mut failed = 0usize;
    let mut failed_tenants: Vec<String> = Vec::new();
    for (i, r) in results.iter().enumerate() {
        match r {
            Ok(ans) => assert_eq!(
                ans, &baseline[i],
                "request {i} (unaffected) diverged from the fault-free baseline"
            ),
            Err(e) => {
                let se = ServeError::of(e).expect("failure must carry a typed ServeError");
                assert!(
                    matches!(se, ServeError::EngineFailure { .. }),
                    "persistent fault must surface as EngineFailure, got {se}"
                );
                failed += 1;
                failed_tenants.push(tenant_of(i));
            }
        }
    }
    failed_tenants.dedup();
    assert!(failed >= 1, "the persistent failure must fail its residents");
    assert!(
        failed <= hyper.batch,
        "blast radius exceeded one session: {failed} failures > batch {}",
        hyper.batch
    );
    assert_eq!(
        failed_tenants.len(),
        1,
        "failures crossed tenants: {failed_tenants:?} (sessions are same-adapter)"
    );
    let isolation_ratio = failed as f64 / n_requests as f64;
    println!(
        "isolation: 1 injected failure -> {failed}/{n_requests} failed \
(ratio {isolation_ratio:.3}), tenant {:?}, all others byte-identical",
        failed_tenants[0]
    );

    // --- leg 2: prefill failure fails only the admitted requests --------
    // The 2nd prefill of the run is a mid-session refill rebuild (the
    // overflow wave beyond the first dispatched batch is admitted into
    // freed slots); failing it with budget 0 must error exactly the
    // requests being admitted while every in-flight row keeps its
    // resident K/V pages and answers baseline bytes.
    let kv_active =
        Engine::new(&rt, config, &frozen, None, "eval", 4)?.kv_cache_active("eval");
    let prefill_isolation = if kv_active {
        let inj = FaultInjector::seeded(43)
            .with_rule(FaultRule::nth(SITE_PREFILL, FaultKind::Error, 1));
        let (results, _, _) = run(1, 0, inj.clone())?;
        assert_eq!(inj.fires(SITE_PREFILL), 1, "exactly one prefill fault must fire");
        let mut pf_failed = 0usize;
        for (i, r) in results.iter().enumerate() {
            match r {
                Ok(ans) => assert_eq!(
                    ans, &baseline[i],
                    "in-flight request {i} diverged after a refill-prefill failure"
                ),
                Err(e) => {
                    let se = ServeError::of(e).expect("failure must carry a typed ServeError");
                    assert!(
                        matches!(se, ServeError::EngineFailure { .. }),
                        "prefill fault must surface as EngineFailure, got {se}"
                    );
                    pf_failed += 1;
                }
            }
        }
        assert!(pf_failed >= 1, "the faulted prefill must fail its admitted requests");
        assert!(
            pf_failed <= hyper.batch,
            "prefill blast radius exceeded one admission wave: {pf_failed} > batch {}",
            hyper.batch
        );
        println!(
            "prefill isolation: 1 injected prefill failure -> {pf_failed}/{n_requests} \
failed, every in-flight row byte-identical"
        );
        Json::obj(vec![
            ("injected_failures", Json::Num(1.0)),
            ("failed_requests", Json::Num(pf_failed as f64)),
            ("session_capacity", Json::Num(hyper.batch as f64)),
            ("in_flight_byte_identical", Json::Num(1.0)),
        ])
    } else {
        println!("prefill isolation: skipped (artifacts predate the KV-cache split)");
        Json::Null
    };

    // --- leg 3: worker crash loses nothing ------------------------------
    // The panic fires after the worker claims its batch and before the
    // batch leaves the recovery pen, so the claimed requests are requeued
    // to the surviving session path and every answer still matches.
    let inj = FaultInjector::seeded(7)
        .with_rule(FaultRule::nth(SITE_WORKER_PANIC, FaultKind::Panic, 0));
    let (results, obs, _) = run(2, 2, inj.clone())?;
    assert_eq!(inj.fires(SITE_WORKER_PANIC), 1, "exactly one worker panic must fire");
    for (i, r) in results.iter().enumerate() {
        let ans = r.as_ref().expect("crash recovery must not lose requests");
        assert_eq!(ans, &baseline[i], "request {i} diverged after worker-crash recovery");
    }
    let snap = obs.registry().snapshot();
    let crashes = snap.sum("serve_worker_crashes_total");
    let rebuilt = snap.sum("serve_sessions_rebuilt_total");
    assert!(crashes >= 1.0, "crash must be counted (serve_worker_crashes_total)");
    println!(
        "crash recovery: {crashes:.0} crash, {rebuilt:.0} session rebuilds, \
{}/{n_requests} answers byte-identical",
        results.len()
    );

    // --- leg 4: goodput vs fault rate -----------------------------------
    let rates = [0.0f64, 0.01, 0.05];
    let mut table = Table::new(
        "Goodput vs injected forward fault rate (retry budget 2)",
        &["fault rate", "served", "errors", "goodput", "retries", "rebuilds", "wall s"],
    );
    let mut sweep_json: Vec<Json> = Vec::new();
    let mut last_obs: Option<ServeObs> = None;
    for &rate in &rates {
        let inj = if rate > 0.0 {
            // the rate rule models background flakiness; the nth rules
            // pin guaranteed transient failures (one mid-forward, and —
            // when the KV split is live — one cached-decode frontier
            // upload) so the retry path is exercised (and asserted) at
            // every nonzero rate
            let mut inj = FaultInjector::seeded(1234)
                .with_rule(FaultRule::new(SITE_FORWARD, FaultKind::Error, rate))
                .with_rule(FaultRule::nth(SITE_FORWARD, FaultKind::Error, 2));
            if kv_active {
                inj = inj.with_rule(FaultRule::nth(SITE_CACHE_UPLOAD, FaultKind::Error, 3));
            }
            inj
        } else {
            FaultInjector::disabled()
        };
        let (results, obs, wall) = run(2, 2, inj.clone())?;
        let served = results.iter().filter(|r| r.is_ok()).count();
        let errors = results.len() - served;
        for (i, r) in results.iter().enumerate() {
            if let Ok(ans) = r {
                assert_eq!(ans, &baseline[i],
                    "request {i} diverged from baseline at fault rate {rate}");
            }
        }
        let snap = obs.registry().snapshot();
        let retries = snap.sum("serve_retries_total");
        let rebuilt = snap.sum("serve_sessions_rebuilt_total");
        let goodput = served as f64 / n_requests as f64;
        if rate == 0.0 {
            assert_eq!(errors, 0, "fault-free sweep leg must not error");
        } else {
            assert!(retries >= 1.0,
                "pinned transient failure at rate {rate} must drive serve_retries_total > 0");
        }
        table.row(vec![
            format!("{:.0}%", rate * 100.0),
            served.to_string(),
            errors.to_string(),
            format!("{goodput:.3}"),
            format!("{retries:.0}"),
            format!("{rebuilt:.0}"),
            format!("{wall:.3}"),
        ]);
        sweep_json.push(Json::obj(vec![
            ("fault_rate", Json::Num(rate)),
            ("requests", Json::Num(n_requests as f64)),
            ("served", Json::Num(served as f64)),
            ("errors", Json::Num(errors as f64)),
            ("goodput", Json::Num(goodput)),
            ("retries", Json::Num(retries)),
            ("sessions_rebuilt", Json::Num(rebuilt)),
            ("forward_fires", Json::Num(inj.fires(SITE_FORWARD) as f64)),
            ("cache_upload_fires", Json::Num(inj.fires(SITE_CACHE_UPLOAD) as f64)),
            ("wall_secs", Json::Num(wall)),
        ]));
        last_obs = Some(obs);
    }
    print!("{}", table.render());

    let report = Json::obj(vec![
        ("bench", Json::Str("chaos".into())),
        ("config", Json::Str(config.into())),
        ("batch", Json::Num(hyper.batch as f64)),
        ("requests", Json::Num(n_requests as f64)),
        ("tenants", Json::Num(tenants as f64)),
        ("smoke", Json::Num(sqft::util::bench::smoke() as u8 as f64)),
        ("isolation", Json::obj(vec![
            ("injected_failures", Json::Num(1.0)),
            ("failed_requests", Json::Num(failed as f64)),
            ("session_capacity", Json::Num(hyper.batch as f64)),
            ("affected_tenants", Json::Num(failed_tenants.len() as f64)),
            ("isolation_ratio", Json::Num(isolation_ratio)),
            ("unaffected_byte_identical", Json::Num(1.0)),
        ])),
        ("prefill_isolation", prefill_isolation),
        ("crash_recovery", Json::obj(vec![
            ("worker_crashes", Json::Num(crashes)),
            ("sessions_rebuilt", Json::Num(rebuilt)),
            ("lost_requests", Json::Num(0.0)),
        ])),
        ("sweep", Json::Arr(sweep_json)),
    ]);
    std::fs::write("BENCH_chaos.json", report.to_string_pretty())?;
    println!("wrote BENCH_chaos.json");

    if let Some(path) = cli_metrics_out() {
        let obs = last_obs.as_ref().expect("sweep ran");
        let trace = obs.trace().map(|t| t.as_ref());
        sqft::obs::expose::write_files(obs.registry(), trace, Path::new(&path))?;
        println!("wrote metrics snapshot to {path} (+ .json, .trace.jsonl)");
    }
    Ok(())
}
