//! Bench for Table 1's underlying work: per-method pipeline stage timings
//! (prepare = calib + wanda + gptq; one fine-tune step; one eval pass) on
//! the tiny config.  Run via `cargo bench --bench table1_pipeline`.

use sqft::data::{Batcher, Dataset, Task, Tokenizer};
use sqft::model::init_base;
use sqft::nls::SearchSpace;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::Runtime;
use sqft::tensor::Rng;
use sqft::train::TrainOpts;
use sqft::util::bench::bench;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let config = "sqft-tiny";
    let hyper = rt.model(config)?.clone();
    let tok = Tokenizer::new();
    let ds = Dataset::generate(Task::SynGsm, 600, 0, 100, 7);
    let mut rng = Rng::new(7);
    let base = init_base(&hyper, &mut rng);

    println!("# table1 bench: pipeline stages, {config}");
    for method in [Method::SparsePeft, Method::QaSparsePeft] {
        bench(&format!("prepare/{}", method.cli_name()), 1, 3, || {
            let mut r = Rng::new(9);
            pipeline::prepare(&rt, config, &base, method, 0.5, &ds.train, &tok,
                              2, &mut r).unwrap();
        });
    }

    // one train step + one eval pass per method
    for method in [Method::Lora, Method::SparsePeft, Method::QaSparsePeft] {
        let prepared = pipeline::prepare(&rt, config, &base, method, 0.5,
                                         &ds.train, &tok, 2, &mut Rng::new(9))?;
        let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
        let space = SearchSpace::new(&prepared.hyper, choices, alpha)?;
        let opts = TrainOpts { steps: 1, lr: 1e-3, log_every: 1, seed: 1,
                               fixed_rank: false };
        let (mut trainer, _) =
            pipeline::finetune(&rt, config, &prepared, space, &ds.train, &tok, &opts)?;
        let batcher = Batcher::new(&ds.train, &tok, hyper.seq_len, hyper.batch);
        let mut brng = Rng::new(3);
        bench(&format!("train_step/{}", method.cli_name()), 2, 10, || {
            let b = batcher.random_batch(&mut brng).unwrap();
            trainer.step_batch(&b, 1e-3).unwrap();
        });
        let cfg = trainer.space.heuristic_config();
        bench(&format!("eval_100/{}", method.cli_name()), 1, 3, || {
            pipeline::evaluate_unmerged(&rt, config, &prepared, &trainer, &cfg,
                                        &ds.test, &tok).unwrap();
        });
    }
    Ok(())
}
