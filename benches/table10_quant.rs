//! Bench for Table 10's substrate: GPTQ vs RTN quantization quality *and*
//! cost at every layer shape of the tiny/small configs, plus fake-quant
//! merge kernels through the runtime.

use sqft::quant::{gptq_quantize, rtn_quantize};
use sqft::runtime::Runtime;
use sqft::tensor::{Rng, Tensor};
use sqft::util::bench::bench;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    println!("# table10 bench: quantization substrate");
    let mut rng = Rng::new(1);
    for (m, n) in [(64, 64), (128, 64), (64, 128), (256, 256)] {
        let w = Tensor::randn(&mut rng, &[m, n], 0.4);
        let x = Tensor::randn(&mut rng, &[4 * n, n], 1.0);
        let mut h = Tensor::zeros(&[n, n]);
        x.accumulate_gram(&mut h);
        let g = gptq_quantize(&w, &h, 32.min(n), 4, None, 0.01)?;
        let r = rtn_quantize(&w, 32.min(n), 4, None)?;
        println!("quality {m}x{n}: gptq weighted_err {:.4e} vs rtn {:.4e} ({:.2}x better)",
            g.weighted_err(&w, &h), r.weighted_err(&w, &h),
            r.weighted_err(&w, &h) / g.weighted_err(&w, &h).max(1e-12));
        bench(&format!("gptq/{m}x{n}"), 1, 3, || {
            gptq_quantize(&w, &h, 32.min(n), 4, None, 0.01).unwrap();
        });
    }

    // fakequant artifact through the runtime (merge path)
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = Runtime::new(&dir)?;
        let exe = rt.shape_executable("fakequant_64x64g2")?;
        let w = Tensor::randn(&mut rng, &[64, 64], 0.4);
        let scales = Tensor::full(&[64, 2], 0.05);
        let zeros = Tensor::full(&[64, 2], 8.0);
        let qmax = Tensor::scalar(15.0);
        bench("fakequant_artifact/64x64", 2, 10, || {
            exe.run(&rt.client, &[w.clone().into(), scales.clone().into(),
                                  zeros.clone().into(), qmax.clone().into()])
                .unwrap();
        });
    }
    Ok(())
}
