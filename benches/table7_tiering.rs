//! Tiered-residency bench (`BENCH_cold_start.json`): what the host tier
//! buys on the promotion path, and the degradation ladder under a tight
//! device budget.
//!
//! Two legs:
//!
//!   1. **Cold-start latency** — p50/p99 of making one tenant
//!      device-serveable, starting from the disk tier (catalog →
//!      `prefetch_host` → `ensure_device`: file read, integrity check,
//!      validation, upload) vs the host tier (`demote_device` →
//!      `ensure_device`: upload only).  The host tier exists so device
//!      eviction does not send re-promotion back to disk, so host must
//!      beat disk on p99.
//!   2. **Degradation smoke** — a 3-tenant pool under a device budget
//!      that cannot hold everyone at full rank (`degrade_ranks 4,2`):
//!      every request must still be answered and
//!      `registry_degraded_total` must move.
//!
//! `SQFT_BENCH_SMOKE=1` shrinks iteration counts (CI smoke);
//! `-- --metrics-out PATH` writes the degradation run's metrics
//! snapshot — what the CI degradation-smoke job greps for the
//! `registry_degraded_total` sentinel.

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::checkpoint::save_adapter;
use sqft::model::init_base;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::report::Table;
use sqft::runtime::Runtime;
use sqft::serve::{
    serve_pool_obs, AdapterRegistry, EngineSpec, PoolOpts, Request, SchedulerOpts, ServeObs,
    SharedAdapterSource,
};
use sqft::tensor::Rng;
use sqft::util::json::Json;
use std::path::Path;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

fn cli_metrics_out() -> Option<String> {
    let argv: Vec<String> = std::env::args().collect();
    argv.iter().position(|a| a == "--metrics-out").and_then(|i| argv.get(i + 1)).cloned()
}

fn percentile(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * p).round() as usize;
    sorted_ms[idx.min(sorted_ms.len() - 1)]
}

fn leg_stats(mut ms: Vec<f64>) -> (f64, f64, f64) {
    ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = ms.iter().sum::<f64>() / ms.len() as f64;
    (percentile(&ms, 0.5), percentile(&ms, 0.99), mean)
}

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let config = "sqft-tiny";
    let hyper = rt.model(config)?.clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 600, 0, 50, 7);
    let base = init_base(&hyper, &mut Rng::new(7));

    println!("# table7 tiering bench: cold-start latency by residency tier");
    let tenant_steps = sqft::util::bench::smoke_iters(5);
    let prepared = pipeline::prepare(&rt, config, &base, Method::Lora, 0.0,
                                     &ds.train, &tok, 2, &mut Rng::new(9))?;
    let frozen = prepared.frozen_set()?;
    let tenants = 3usize;
    let entries = pipeline::tenant_adapters(&rt, config, &prepared, tenants,
                                            &ds.train, &tok, tenant_steps, 77)?;

    // disk tier fixture: each tenant's checkpoint under a temp catalog dir
    let ckpt_dir = std::env::temp_dir().join("sqft_bench_tiering");
    std::fs::remove_dir_all(&ckpt_dir).ok();
    std::fs::create_dir_all(&ckpt_dir)?;
    let mut paths = Vec::new();
    for e in &entries {
        let path = ckpt_dir.join(format!("{}.ckpt", e.id));
        save_adapter(&path, &e.host_sets[0], &e.host_sets[1], config, &e.eval_kind,
                     &e.id, "lora", 0.0)?;
        paths.push((e.id.clone(), path));
    }

    // one observability context spans every leg, so the final snapshot
    // carries the quarantine + degradation sentinels CI greps for
    let obs = ServeObs::with_trace();
    let kept = obs.clone();

    // --- leg 1: cold-start latency, disk vs host -----------------------
    let iters = if sqft::util::bench::smoke() { 12usize } else { 40 };
    let subject = entries[0].id.clone();
    let mut reg = AdapterRegistry::new(tenants + 1);
    reg.bind_obs(kept.registry(), 0);
    for (id, path) in &paths {
        reg.catalog_disk(id, path.clone());
    }
    let mut disk_ms = Vec::with_capacity(iters);
    for _ in 0..iters {
        reg.evict(&subject); // back to the disk tier: host copy dropped
        let t0 = Instant::now();
        reg.prefetch_host(&hyper, &subject)?;
        reg.ensure_device(&rt, &subject)?;
        disk_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        assert!(reg.device_set(&subject).is_some());
    }
    let mut host_ms = Vec::with_capacity(iters);
    for _ in 0..iters {
        assert!(reg.demote_device(&subject)); // host copy survives
        let t0 = Instant::now();
        reg.ensure_device(&rt, &subject)?;
        host_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        assert!(reg.device_set(&subject).is_some());
    }
    let (disk_p50, disk_p99, disk_mean) = leg_stats(disk_ms);
    let (host_p50, host_p99, host_mean) = leg_stats(host_ms);
    let mut table = Table::new(
        "Cold-start latency by starting tier (one tenant, ms)",
        &["tier", "p50", "p99", "mean", "iters"],
    );
    table.row(vec!["disk".into(), format!("{disk_p50:.3}"), format!("{disk_p99:.3}"),
                   format!("{disk_mean:.3}"), iters.to_string()]);
    table.row(vec!["host".into(), format!("{host_p50:.3}"), format!("{host_p99:.3}"),
                   format!("{host_mean:.3}"), iters.to_string()]);
    print!("{}", table.render());
    assert!(
        host_p99 < disk_p99,
        "host re-promotion (p99 {host_p99:.3} ms) must beat disk re-registration \
(p99 {disk_p99:.3} ms) — the host tier is pure upload, disk adds read+verify+validate"
    );

    // --- leg 2: corrupt checkpoint quarantines exactly one tenant -------
    // a bit-flipped copy of tenant0's checkpoint, cataloged as a fourth
    // tenant: the integrity check must refuse it at prefetch, quarantine
    // it, and leave every intact sibling untouched
    let torn_path = ckpt_dir.join("torn.ckpt");
    let mut torn_bytes = std::fs::read(&paths[0].1)?;
    let n = torn_bytes.len();
    torn_bytes[n - 8] ^= 0x10;
    std::fs::write(&torn_path, &torn_bytes)?;
    reg.catalog_disk("torn", torn_path);
    assert!(reg.prefetch_host(&hyper, "torn").is_err(),
        "corrupt checkpoint must refuse to load");
    assert!(reg.is_quarantined("torn"));
    for (id, _) in &paths {
        assert!(!reg.is_quarantined(id), "quarantine must not spread to '{id}'");
    }
    println!("quarantine: 1 torn checkpoint -> 1 tenant refused, {} intact", paths.len());

    // --- leg 3: degradation smoke under a tight budget ------------------
    let full = AdapterRegistry::entry_logical_bytes(&entries[0], None);
    let at4 = AdapterRegistry::entry_logical_bytes(&entries[0], Some(4));
    let budget = (2 * full).max(tenants * at4);
    let source = SharedAdapterSource::new(hyper.clone(), tenants);
    source.register_all(entries.clone())?;
    let spec = EngineSpec {
        artifacts: dir.clone(),
        config: config.to_string(),
        frozen: frozen.clone(),
        eval_kind: "eval".to_string(),
        max_new_tokens: 4,
        registry_capacity: tenants,
        device_budget: budget,
        degrade_ranks: vec![4, 2],
    };
    let n_requests = if sqft::util::bench::smoke() { 12usize } else { 30 };
    let mut grng = Rng::new(131);
    let (tx, rx) = channel::<Request>();
    let mut replies = Vec::new();
    for i in 0..n_requests {
        let (rtx, rrx) = channel();
        let id = Some(entries[i % tenants].id.clone());
        let _ = tx.send(Request::new(id, task.gen_sample(&mut grng).prompt, rtx));
        replies.push(rrx);
    }
    drop(tx);
    let stats = serve_pool_obs(
        &spec,
        &source,
        rx,
        PoolOpts {
            workers: 1,
            sched: SchedulerOpts { max_batch: hyper.batch,
                                   aging: Duration::from_millis(20),
                                   ..Default::default() },
            ..Default::default()
        },
        obs,
    )?;
    let served = replies.iter().filter(|r| matches!(r.recv(), Ok(Ok(_)))).count();
    assert_eq!(served, n_requests, "a tight budget must degrade, never refuse");
    assert_eq!(stats.serve.total.errors, 0);
    let snap = kept.registry().snapshot();
    let degraded = snap.sum("registry_degraded_total");
    let restored = snap.sum("registry_restored_total");
    let quarantined = snap.sum("registry_quarantined_total");
    assert!(
        degraded >= 1.0,
        "budget {budget} cannot hold {tenants} tenants at full rank ({full} B each); \
registry_degraded_total must move"
    );
    assert!(quarantined >= 1.0, "the torn-checkpoint leg must be counted");
    println!(
        "degradation: budget {budget} B, {served}/{n_requests} served, \
{degraded:.0} degrades, {restored:.0} restores, {quarantined:.0} quarantines"
    );

    let report = Json::obj(vec![
        ("bench", Json::Str("cold_start".into())),
        ("config", Json::Str(config.into())),
        ("tenants", Json::Num(tenants as f64)),
        ("iters", Json::Num(iters as f64)),
        ("smoke", Json::Num(sqft::util::bench::smoke() as u8 as f64)),
        ("disk", Json::obj(vec![
            ("p50_ms", Json::Num(disk_p50)),
            ("p99_ms", Json::Num(disk_p99)),
            ("mean_ms", Json::Num(disk_mean)),
        ])),
        ("host", Json::obj(vec![
            ("p50_ms", Json::Num(host_p50)),
            ("p99_ms", Json::Num(host_p99)),
            ("mean_ms", Json::Num(host_mean)),
        ])),
        ("host_speedup_p99", Json::Num(disk_p99 / host_p99.max(1e-9))),
        ("degradation", Json::obj(vec![
            ("device_budget_bytes", Json::Num(budget as f64)),
            ("full_rank_bytes", Json::Num(full as f64)),
            ("rank4_bytes", Json::Num(at4 as f64)),
            ("requests", Json::Num(n_requests as f64)),
            ("served", Json::Num(served as f64)),
            ("degraded_total", Json::Num(degraded)),
            ("restored_total", Json::Num(restored)),
            ("quarantined_total", Json::Num(quarantined)),
        ])),
    ]);
    std::fs::write("BENCH_cold_start.json", report.to_string_pretty())?;
    println!("wrote BENCH_cold_start.json");

    if let Some(path) = cli_metrics_out() {
        let trace = kept.trace().map(|t| t.as_ref());
        sqft::obs::expose::write_files(kept.registry(), trace, Path::new(&path))?;
        println!("wrote metrics snapshot to {path} (+ .json, .trace.jsonl)");
    }
    std::fs::remove_dir_all(&ckpt_dir).ok();
    Ok(())
}
