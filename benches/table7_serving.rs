//! Bench for Table 7's inference columns: serving throughput of merged vs
//! unmerged models (the paper's adapter-overhead claim) and the merge /
//! pack costs themselves.

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::init_base;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::quant::pack::pack_int4;
use sqft::runtime::Runtime;
use sqft::serve::Engine;
use sqft::tensor::Rng;
use sqft::util::bench::{bench, bench_throughput};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let config = "sqft-tiny";
    let hyper = rt.model(config)?.clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 600, 0, 50, 7);
    let base = init_base(&hyper, &mut Rng::new(7));

    println!("# table7 bench: merged vs unmerged serving + merge/pack costs");
    let prepared = pipeline::prepare(&rt, config, &base, Method::QaSparsePeft,
                                     0.5, &ds.train, &tok, 2, &mut Rng::new(9))?;
    let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
    let space = sqft::nls::SearchSpace::new(&prepared.hyper, choices, alpha)?;
    let opts = sqft::train::TrainOpts { steps: 5, lr: 1e-3, log_every: 5,
                                        seed: 1, fixed_rank: false };
    let (trainer, _) = pipeline::finetune(&rt, config, &prepared, space,
                                          &ds.train, &tok, &opts)?;
    let cfg = trainer.space.heuristic_config();

    bench("merge_qa_sparsepeft", 1, 5, || {
        pipeline::merged_state(&prepared, &trainer, &cfg).unwrap();
    });
    let merged = pipeline::merged_state(&prepared, &trainer, &cfg)?;
    let codes = merged.codes.as_ref().unwrap().get("codes_q").unwrap().index0(0);
    bench("pack_int4/64x64", 2, 10, || {
        pack_int4(&codes).unwrap();
    });

    // unmerged engine (adapter path) vs merged engine
    let frozen_un = prepared.frozen_set()?;
    let engine_un = Engine::new(&rt, config, &frozen_un,
                                Some((&trainer.adapters, &trainer.space, &cfg)),
                                "eval_qa", 6)?;
    let mut frozen_m = sqft::model::ParamSet::new();
    for (n, v) in merged.base.iter() {
        frozen_m.insert(n, v.clone());
    }
    for (n, v) in pipeline::dense_adapter_masks(&hyper).iter() {
        frozen_m.insert(n, v.clone());
    }
    let engine_m = Engine::new(&rt, config, &frozen_m, None, "eval", 6)?;

    let mut grng = Rng::new(11);
    let prompts: Vec<String> =
        (0..8).map(|_| task.gen_sample(&mut grng).prompt).collect();
    let t_un = bench_throughput("serve_unmerged_batch8", 1, 8, || {
        engine_un.generate_batch(&prompts).unwrap();
        prompts.len()
    });
    let t_m = bench_throughput("serve_merged_batch8", 1, 8, || {
        engine_m.generate_batch(&prompts).unwrap();
        prompts.len()
    });
    println!("merged/unmerged inference speedup: {:.2}x (paper: 4 > 1)",
             t_m / t_un);
    Ok(())
}
