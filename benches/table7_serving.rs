//! Bench for Table 7's inference columns: serving throughput of merged vs
//! unmerged models (the paper's adapter-overhead claim), the merge / pack
//! costs themselves, and the packed-INT4 serving path (true 4-bit resident
//! weights vs the dense fake-quant f32 engine → `BENCH_int4_serving.json`;
//! asserts ≥3.5x lower resident weight bytes and identical answers).

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::init_base;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::quant::pack::pack_int4;
use sqft::runtime::{Runtime, UploadScope};
use sqft::serve::Engine;
use sqft::tensor::Rng;
use sqft::util::bench::{bench, bench_throughput};
use sqft::util::json::Json;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::new(&dir)?;
    let config = "sqft-tiny";
    let hyper = rt.model(config)?.clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 600, 0, 50, 7);
    let base = init_base(&hyper, &mut Rng::new(7));

    println!("# table7 bench: merged vs unmerged serving + merge/pack costs");
    let prepared = pipeline::prepare(&rt, config, &base, Method::QaSparsePeft,
                                     0.5, &ds.train, &tok, 2, &mut Rng::new(9))?;
    let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
    let space = sqft::nls::SearchSpace::new(&prepared.hyper, choices, alpha)?;
    let opts = sqft::train::TrainOpts { steps: 5, lr: 1e-3, log_every: 5,
                                        seed: 1, fixed_rank: false };
    let (trainer, _) = pipeline::finetune(&rt, config, &prepared, space,
                                          &ds.train, &tok, &opts)?;
    let cfg = trainer.space.heuristic_config();

    bench("merge_qa_sparsepeft", 1, 5, || {
        pipeline::merged_state(&prepared, &trainer, &cfg).unwrap();
    });
    let merged = pipeline::merged_state(&prepared, &trainer, &cfg)?;
    let codes = merged.codes.as_ref().unwrap().get("codes_q").unwrap().index0(0);
    bench("pack_int4/64x64", 2, 10, || {
        pack_int4(&codes).unwrap();
    });

    // unmerged engine (adapter path) vs merged engine
    let frozen_un = prepared.frozen_set()?;
    let engine_un = Engine::new(&rt, config, &frozen_un,
                                Some((&trainer.adapters, &trainer.space, &cfg)),
                                "eval_qa", 6)?;
    let mut frozen_m = sqft::model::ParamSet::new();
    for (n, v) in merged.base.iter() {
        frozen_m.insert(n, v.clone());
    }
    for (n, v) in pipeline::dense_adapter_masks(&hyper).iter() {
        frozen_m.insert(n, v.clone());
    }
    let engine_m = Engine::new(&rt, config, &frozen_m, None, "eval", 6)?;

    let mut grng = Rng::new(11);
    let prompts: Vec<String> =
        (0..8).map(|_| task.gen_sample(&mut grng).prompt).collect();
    let iters = sqft::util::bench::smoke_iters(8);
    let t_un = bench_throughput("serve_unmerged_batch8", 1, iters, || {
        engine_un.generate_batch(&prompts).unwrap();
        prompts.len()
    });
    let t_m = bench_throughput("serve_merged_batch8", 1, iters, || {
        engine_m.generate_batch(&prompts).unwrap();
        prompts.len()
    });
    println!("merged/unmerged inference speedup: {:.2}x (paper: 4 > 1)",
             t_m / t_un);

    // --- packed-INT4 serving: true 4-bit resident weights ---------------
    // The same merged QA model, served from packed u8 codes + f32 group
    // params through eval_int4 instead of a dense fake-quant f32 upload.
    // Resident footprint and answers are deterministic, so both asserts
    // run in smoke mode too.
    let int4 = pipeline::int4_model(&prepared, &merged)?;
    let engine_i4 = Engine::new_int4(&rt, config, &int4, 6)?;
    let ans_f32 = engine_m.generate_batch(&prompts)?;
    let ans_i4 = engine_i4.generate_batch(&prompts)?;
    assert_eq!(
        ans_i4, ans_f32,
        "packed-INT4 serving diverged from the fake-quant f32 reference"
    );
    let f32_resident = engine_m.resident_weight_bytes();
    let i4_resident = engine_i4.resident_weight_bytes();
    let ratio = f32_resident as f64 / i4_resident.max(1) as f64;
    println!(
        "resident model weights: f32 fake-quant {:.1} KB vs packed INT4 {:.1} KB \
         ({ratio:.2}x smaller)",
        f32_resident as f64 / 1e3, i4_resident as f64 / 1e3
    );
    assert!(
        ratio >= 3.5,
        "INT4-resident serving must cut device weight bytes >=3.5x, got {ratio:.2}x \
         ({f32_resident} vs {i4_resident})"
    );
    // steady-state decode ships the token batch only: every weight input
    // resolves to a device-resident buffer, none is re-uploaded per step
    let scope = UploadScope::begin();
    let _ = engine_i4.generate_batch(&prompts)?;
    let token_batch_bytes = (hyper.batch * hyper.seq_len * 4) as u64;
    assert_eq!(
        scope.bytes(),
        engine_i4.last_decode_uploads() as u64 * token_batch_bytes,
        "INT4 decode uploaded more than the token batch per step"
    );
    let t_i4 = bench_throughput("serve_merged_int4_batch8", 1, iters, || {
        engine_i4.generate_batch(&prompts).unwrap();
        prompts.len()
    });
    let packed_bytes: usize = int4.packed.values().map(|p| p.data.len()).sum();
    let group_param_bytes: usize = int4
        .params
        .iter()
        .filter(|(n, _)| n.starts_with("qscales_") || n.starts_with("qzeros_"))
        .map(|(_, t)| t.len() * 4)
        .sum();
    let report = Json::obj(vec![
        ("bench", Json::Str("int4_serving".into())),
        ("config", Json::Str(config.into())),
        ("batch", Json::Num(hyper.batch as f64)),
        ("seq_len", Json::Num(hyper.seq_len as f64)),
        ("smoke", Json::Num(sqft::util::bench::smoke() as u8 as f64)),
        ("resident_bytes", Json::obj(vec![
            ("fake_quant_f32", Json::Num(f32_resident as f64)),
            ("packed_int4", Json::Num(i4_resident as f64)),
            ("packed_codes", Json::Num(packed_bytes as f64)),
            ("group_params_f32", Json::Num(group_param_bytes as f64)),
            ("ratio", Json::Num(ratio)),
        ])),
        ("decode_upload_bytes_per_step", Json::Num(token_batch_bytes as f64)),
        ("requests_per_s", Json::obj(vec![
            ("fake_quant_f32", Json::Num(t_m)),
            ("packed_int4", Json::Num(t_i4)),
        ])),
        ("answers_match", Json::Num(1.0)),
    ]);
    std::fs::write("BENCH_int4_serving.json", report.to_string_pretty())?;
    println!("wrote BENCH_int4_serving.json");
    Ok(())
}
