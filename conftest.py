"""Repo-root pytest hook: make `python/` importable so
`pytest python/tests/` works from the repository root."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent / "python"))
