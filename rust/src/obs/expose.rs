//! Exposition: render a [`Snapshot`] as Prometheus-style text and as a
//! JSON document, and rewrite both (plus the JSONL trace) periodically
//! from a background thread while a serve run is live.
//!
//! `--metrics-out PATH` on `sqft serve` treats `PATH` as the text dump
//! and writes two siblings next to it: `PATH.json` (the JSON snapshot)
//! and `PATH.trace.jsonl` (the per-request span log).  Files are
//! rewritten whole every `--metrics-interval-ms` and once more at run
//! end, so the on-disk view is always a consistent point-in-time dump.

use super::{Registry, Sample, Snapshot, TraceLog, Value};
use crate::util::json::Json;
use crate::util::summarize;
use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Process-level metrics sourced outside any registry: the runtime's
/// host→device upload accounting (`runtime::host_upload_bytes`) folded
/// into every exposition dump, so the registry view and the legacy
/// counter can't drift apart.
pub fn process_samples() -> Vec<Sample> {
    vec![Sample {
        name: "runtime_host_upload_bytes_total".to_string(),
        labels: Vec::new(),
        value: Value::Counter(crate::runtime::host_upload_bytes()),
    }]
}

fn write_labels(out: &mut String, labels: &[(String, String)], extra: Option<(&str, &str)>) {
    if labels.is_empty() && extra.is_none() {
        return;
    }
    out.push('{');
    let mut first = true;
    for (k, v) in labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).chain(extra) {
        if !first {
            out.push(',');
        }
        first = false;
        let escaped = v.replace('\\', "\\\\").replace('"', "\\\"");
        let _ = write!(out, "{k}=\"{escaped}\"");
    }
    out.push('}');
}

fn type_of(v: &Value) -> &'static str {
    match v {
        Value::Counter(_) | Value::FloatCounter(_) => "counter",
        Value::Gauge { .. } => "gauge",
        Value::Histogram { .. } => "histogram",
        Value::Series(_) => "summary",
    }
}

/// Prometheus-style text rendering: `# TYPE` headers per family, then
/// one line per label set (histograms expand to `_bucket`/`_sum`/
/// `_count`, series to quantile lines, gauges also emit a `_peak`
/// family with their high-watermarks).
pub fn prometheus_text(snap: &Snapshot) -> String {
    let mut out = String::new();
    let mut last: Option<&str> = None;
    for s in &snap.samples {
        if last != Some(s.name.as_str()) {
            let _ = writeln!(out, "# TYPE {} {}", s.name, type_of(&s.value));
            last = Some(s.name.as_str());
        }
        match &s.value {
            Value::Counter(v) => {
                out.push_str(&s.name);
                write_labels(&mut out, &s.labels, None);
                let _ = writeln!(out, " {v}");
            }
            Value::FloatCounter(v) | Value::Gauge { value: v, .. } => {
                out.push_str(&s.name);
                write_labels(&mut out, &s.labels, None);
                let _ = writeln!(out, " {v}");
            }
            Value::Histogram { bounds, buckets, sum, count } => {
                let mut cum = 0u64;
                for (i, b) in buckets.iter().enumerate() {
                    cum += b;
                    let le = match bounds.get(i) {
                        Some(bound) => format!("{bound}"),
                        None => "+Inf".to_string(),
                    };
                    let _ = write!(out, "{}_bucket", s.name);
                    write_labels(&mut out, &s.labels, Some(("le", le.as_str())));
                    let _ = writeln!(out, " {cum}");
                }
                let _ = write!(out, "{}_sum", s.name);
                write_labels(&mut out, &s.labels, None);
                let _ = writeln!(out, " {sum}");
                let _ = write!(out, "{}_count", s.name);
                write_labels(&mut out, &s.labels, None);
                let _ = writeln!(out, " {count}");
            }
            Value::Series(xs) => {
                if !xs.is_empty() {
                    let summ = summarize(xs.clone());
                    for (q, v) in [("0.5", summ.p50), ("0.95", summ.p95), ("0.99", summ.p99)] {
                        out.push_str(&s.name);
                        write_labels(&mut out, &s.labels, Some(("quantile", q)));
                        let _ = writeln!(out, " {v}");
                    }
                }
                let _ = write!(out, "{}_sum", s.name);
                write_labels(&mut out, &s.labels, None);
                let _ = writeln!(out, " {}", xs.iter().sum::<f64>());
                let _ = write!(out, "{}_count", s.name);
                write_labels(&mut out, &s.labels, None);
                let _ = writeln!(out, " {}", xs.len());
            }
        }
    }
    // gauge high-watermarks as their own families, after the main dump
    let mut last: Option<&str> = None;
    for s in &snap.samples {
        if let Value::Gauge { peak, .. } = &s.value {
            if last != Some(s.name.as_str()) {
                let _ = writeln!(out, "# TYPE {}_peak gauge", s.name);
                last = Some(s.name.as_str());
            }
            let _ = write!(out, "{}_peak", s.name);
            write_labels(&mut out, &s.labels, None);
            let _ = writeln!(out, " {peak}");
        }
    }
    out
}

/// JSON snapshot: `{"metrics": [{name, labels, type, ...}, ...]}` with
/// exact per-type payloads (series include their summary percentiles).
pub fn json_snapshot(snap: &Snapshot) -> Json {
    let metrics: Vec<Json> = snap
        .samples
        .iter()
        .map(|s| {
            let labels =
                Json::Obj(s.labels.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect());
            let mut fields = vec![
                ("name", Json::Str(s.name.clone())),
                ("labels", labels),
                ("type", Json::Str(type_of(&s.value).to_string())),
            ];
            match &s.value {
                Value::Counter(v) => fields.push(("value", Json::Num(*v as f64))),
                Value::FloatCounter(v) => fields.push(("value", Json::Num(*v))),
                Value::Gauge { value, peak } => {
                    fields.push(("value", Json::Num(*value)));
                    fields.push(("peak", Json::Num(*peak)));
                }
                Value::Histogram { bounds, buckets, sum, count } => {
                    fields.push(("bounds", Json::arr_f64(bounds)));
                    fields.push((
                        "buckets",
                        Json::Arr(buckets.iter().map(|&b| Json::Num(b as f64)).collect()),
                    ));
                    fields.push(("sum", Json::Num(*sum)));
                    fields.push(("count", Json::Num(*count as f64)));
                }
                Value::Series(xs) => {
                    fields.push(("count", Json::Num(xs.len() as f64)));
                    fields.push(("sum", Json::Num(xs.iter().sum())));
                    if !xs.is_empty() {
                        let summ = summarize(xs.clone());
                        for (k, v) in [
                            ("mean", summ.mean),
                            ("p50", summ.p50),
                            ("p95", summ.p95),
                            ("p99", summ.p99),
                            ("min", summ.min),
                            ("max", summ.max),
                        ] {
                            fields.push((k, Json::Num(v)));
                        }
                    }
                }
            }
            Json::obj(fields)
        })
        .collect();
    Json::obj(vec![("metrics", Json::Arr(metrics))])
}

/// Write the three exposition files for `registry` (+ optional trace):
/// `path` (Prometheus text), `path.json`, `path.trace.jsonl`.
pub fn write_files(registry: &Registry, trace: Option<&TraceLog>, path: &Path) -> Result<()> {
    let mut snap = registry.snapshot();
    snap.samples.extend(process_samples());
    let write = |p: &Path, body: String| {
        std::fs::write(p, body).with_context(|| format!("writing metrics file {p:?}"))
    };
    write(path, prometheus_text(&snap))?;
    write(&sibling(path, "json"), json_snapshot(&snap).to_string_pretty())?;
    if let Some(t) = trace {
        write(&sibling(path, "trace.jsonl"), t.to_jsonl())?;
    }
    Ok(())
}

fn sibling(path: &Path, ext: &str) -> PathBuf {
    let mut s = path.as_os_str().to_os_string();
    s.push(".");
    s.push(ext);
    PathBuf::from(s)
}

/// Background snapshot writer: rewrites the exposition files every
/// `interval` while the serve run is live, then once more on `finish`
/// (the final write supersedes the hand-rolled end-of-run files).
pub struct MetricsWriter {
    stop: Arc<AtomicBool>,
    handle: JoinHandle<Result<()>>,
    path: PathBuf,
}

impl MetricsWriter {
    pub fn spawn(
        registry: Arc<Registry>,
        trace: Option<Arc<TraceLog>>,
        path: PathBuf,
        interval: Duration,
    ) -> MetricsWriter {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let out = path.clone();
        let handle = std::thread::spawn(move || {
            let interval = interval.max(Duration::from_millis(10));
            loop {
                write_files(&registry, trace.as_deref(), &out)?;
                if stop2.load(Ordering::Relaxed) {
                    return Ok(());
                }
                // sleep in short slices so finish() isn't held up by a
                // long interval; the final write happens on loop re-entry
                let mut slept = Duration::ZERO;
                while slept < interval && !stop2.load(Ordering::Relaxed) {
                    let slice = (interval - slept).min(Duration::from_millis(25));
                    std::thread::sleep(slice);
                    slept += slice;
                }
            }
        });
        MetricsWriter { stop, handle, path }
    }

    /// Stop the writer, perform the final write, and return the text
    /// dump's path.
    pub fn finish(self) -> Result<PathBuf> {
        self.stop.store(true, Ordering::Relaxed);
        match self.handle.join() {
            Ok(r) => r?,
            Err(_) => anyhow::bail!("metrics writer thread panicked"),
        }
        Ok(self.path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn demo_registry() -> Registry {
        let reg = Registry::new();
        reg.counter("serve_requests_total", &[("tenant", "a"), ("worker", "0")]).add(4);
        reg.gauge("sched_queue_depth", &[("shard", "0")]).set(3.0);
        reg.histogram("serve_decode_step_ms", &[("worker", "0")], &[1.0, 10.0]).observe(2.0);
        let s = reg.series("serve_latency_ms", &[("tenant", "a")]);
        s.record(5.0);
        s.record(9.0);
        reg
    }

    #[test]
    fn prometheus_text_exposes_sentinel_metric() {
        let snap = demo_registry().snapshot();
        let text = prometheus_text(&snap);
        // the CI smoke job greps the dump for this exact family name
        assert!(text.contains("# TYPE serve_requests_total counter"));
        assert!(text.contains("serve_requests_total{tenant=\"a\",worker=\"0\"} 4"));
        assert!(text.contains("serve_decode_step_ms_bucket{worker=\"0\",le=\"10\"} 1"));
        assert!(text.contains("serve_decode_step_ms_bucket{worker=\"0\",le=\"+Inf\"} 1"));
        assert!(text.contains("serve_latency_ms_count{tenant=\"a\"} 2"));
        assert!(text.contains("sched_queue_depth_peak{shard=\"0\"} 3"));
    }

    #[test]
    fn json_snapshot_parses_and_carries_values() {
        let snap = demo_registry().snapshot();
        let j = json_snapshot(&snap);
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        let metrics = parsed.req("metrics").unwrap().as_arr().unwrap();
        let counter = metrics
            .iter()
            .find(|m| m.get("name").and_then(|n| n.as_str().ok()) == Some("serve_requests_total"))
            .unwrap();
        assert_eq!(counter.req("value").unwrap().as_usize().unwrap(), 4);
        let series = metrics
            .iter()
            .find(|m| m.get("name").and_then(|n| n.as_str().ok()) == Some("serve_latency_ms"))
            .unwrap();
        assert_eq!(series.req("count").unwrap().as_usize().unwrap(), 2);
        assert!((series.req("mean").unwrap().as_f64().unwrap() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn write_files_produces_all_three_siblings() {
        let reg = demo_registry();
        let trace = TraceLog::new();
        trace.event("enqueue", vec![("req", Json::Num(1.0))]);
        let dir = std::env::temp_dir().join(format!("sqft_obs_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("metrics.prom");
        write_files(&reg, Some(&trace), &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("serve_requests_total"));
        assert!(text.contains("runtime_host_upload_bytes_total"));
        let json = std::fs::read_to_string(sibling(&path, "json")).unwrap();
        assert!(Json::parse(&json).is_ok());
        let jsonl = std::fs::read_to_string(sibling(&path, "trace.jsonl")).unwrap();
        assert_eq!(jsonl.lines().count(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
