//! Per-request trace spans: a structured JSONL event log following the
//! slot lifecycle (enqueue → dispatch/steal → admit → first token →
//! retire/error).
//!
//! Events are preformatted into JSON lines at emit time (requests are
//! rare relative to decode forwards, so per-event allocation is cheap)
//! and buffered behind one mutex; the exposition writer rewrites the
//! `.trace.jsonl` file from the buffer periodically and at run end.
//!
//! Every event carries `ev` (the phase name) and `t_ms` (milliseconds
//! since the log was created); phase-specific fields — `req`, `tenant`,
//! `worker`, `slot`, `batch`, `stolen`, `queue_ms`, `ttft_ms`,
//! `latency_ms`, `tokens`, `error` — come from the serve layer.  Keys
//! are emitted in sorted order (the JSON layer stores objects as
//! `BTreeMap`), so the log is stable and grep-able.

use crate::util::json::Json;
use std::sync::Mutex;
use std::time::Instant;

pub struct TraceLog {
    epoch: Instant,
    lines: Mutex<Vec<String>>,
}

impl TraceLog {
    #[allow(clippy::new_without_default)]
    pub fn new() -> TraceLog {
        TraceLog { epoch: Instant::now(), lines: Mutex::new(Vec::new()) }
    }

    /// Record one event.  `fields` are appended to the standard
    /// `ev`/`t_ms` pair; duplicate keys keep the caller's value.
    pub fn event(&self, ev: &str, mut fields: Vec<(&str, Json)>) {
        let t_ms = self.epoch.elapsed().as_secs_f64() * 1e3;
        fields.push(("ev", Json::Str(ev.to_string())));
        fields.push(("t_ms", Json::Num(t_ms)));
        let line = Json::obj(fields).to_string();
        crate::util::sync::lock_recover(&self.lines).push(line);
    }

    /// Events recorded so far, one JSON document per line.
    pub fn lines(&self) -> Vec<String> {
        crate::util::sync::lock_recover(&self.lines).clone()
    }

    pub fn len(&self) -> usize {
        crate::util::sync::lock_recover(&self.lines).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The whole log as one JSONL string (trailing newline included when
    /// non-empty).
    pub fn to_jsonl(&self) -> String {
        let lines = crate::util::sync::lock_recover(&self.lines);
        let mut out = String::new();
        for l in lines.iter() {
            out.push_str(l);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_round_trip_as_json_lines() {
        let log = TraceLog::new();
        log.event(
            "admit",
            vec![
                ("req", Json::Num(7.0)),
                ("tenant", Json::Str("a".into())),
                ("slot", Json::Num(2.0)),
            ],
        );
        log.event("retire", vec![("req", Json::Num(7.0)), ("tokens", Json::Num(3.0))]);
        assert_eq!(log.len(), 2);
        let lines = log.lines();
        let first = Json::parse(&lines[0]).unwrap();
        assert_eq!(first.req("ev").unwrap().as_str().unwrap(), "admit");
        assert_eq!(first.req("req").unwrap().as_usize().unwrap(), 7);
        assert!(first.req("t_ms").unwrap().as_f64().unwrap() >= 0.0);
        let second = Json::parse(&lines[1]).unwrap();
        assert_eq!(second.req("tokens").unwrap().as_usize().unwrap(), 3);
        assert_eq!(log.to_jsonl().lines().count(), 2);
    }
}
