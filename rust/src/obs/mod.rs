//! Process-wide serving telemetry: a metrics registry of cheap
//! shared-atomic instruments, per-request trace spans, and exposition.
//!
//! The serving stack reports through per-subsystem structs
//! (`ServeStats`, `SchedulerMetrics`, `PoolServeStats`) printed once at
//! end of run — unusable for watching occupancy collapse, registry
//! thrash, or aging starvation *while* they happen.  This module is the
//! neutral instrument those reports (and live exposition) both read:
//!
//!   - **Instruments** ([`Counter`], [`FloatCounter`], [`Gauge`],
//!     [`Histogram`], [`Series`]) are lock-free atomics (plus a striped
//!     mutex for raw-sample series), safe to hit from the `!Send`
//!     per-worker engine replicas and the dispatcher thread without
//!     contending: counters stripe their cells by thread so two workers
//!     never bounce one cache line.
//!   - **One instrument, many views**: an owner (scheduler shard, decode
//!     session) holds `Arc`s to its instruments and *registers* them in a
//!     [`Registry`] under a stable name + label set.  `metrics()`-style
//!     accessors and [`Registry::snapshot`] then read the *same* atomics
//!     — per-run reports and live exposition cannot disagree, and there
//!     is no double bookkeeping.
//!   - **Spans** ([`TraceLog`]) record the slot lifecycle of every
//!     request (enqueue → dispatch/steal → admit → first token →
//!     retire/error) as JSONL events keyed by request id.
//!   - **Exposition** ([`expose`]) renders a snapshot as Prometheus-style
//!     text and as JSON, and a background [`expose::MetricsWriter`]
//!     rewrites both periodically during a serve run
//!     (`sqft serve --metrics-out PATH --metrics-interval-ms N`).
//!
//! Instruments are owned by their run: a serve run creates a fresh
//! registry (via `serve::ServeObs`), so counters start at zero per run
//! and end-of-run stats are exact.  A process that exposes successive
//! runs under one registry simply shows Prometheus-legal counter resets.

pub mod expose;
pub mod trace;

pub use trace::TraceLog;

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Stripes per counter/series: enough that a handful of worker threads
/// rarely collide, small enough that a registry of ~30 metrics stays
/// cache-resident.
const STRIPES: usize = 8;

static NEXT_THREAD_SLOT: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// Each thread's stable stripe index (assigned on first use).
    static THREAD_SLOT: Cell<usize> = const { Cell::new(usize::MAX) };
}

fn thread_stripe() -> usize {
    THREAD_SLOT.with(|s| {
        let mut v = s.get();
        if v == usize::MAX {
            v = NEXT_THREAD_SLOT.fetch_add(1, Ordering::Relaxed);
            s.set(v);
        }
        v % STRIPES
    })
}

/// One cache line per stripe so two workers incrementing the same
/// counter never write-share a line.
#[repr(align(64))]
struct Stripe(AtomicU64);

/// Monotonic event counter, striped across cache lines by thread.
pub struct Counter {
    stripes: Vec<Stripe>,
}

impl Counter {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Counter {
        Counter { stripes: (0..STRIPES).map(|_| Stripe(AtomicU64::new(0))).collect() }
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.stripes[thread_stripe()].0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.stripes.iter().map(|s| s.0.load(Ordering::Relaxed)).sum()
    }
}

/// Monotonic f64 accumulator (CAS loop over the bit pattern) for sums
/// that aren't integral — e.g. the scheduler's batch-fill ratios.
pub struct FloatCounter {
    bits: AtomicU64,
}

impl FloatCounter {
    #[allow(clippy::new_without_default)]
    pub fn new() -> FloatCounter {
        FloatCounter { bits: AtomicU64::new(0f64.to_bits()) }
    }

    pub fn add(&self, v: f64) {
        let _ = self.bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
            Some((f64::from_bits(b) + v).to_bits())
        });
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Last-write-wins level (occupancy, queue depth, resident bytes) with a
/// high-watermark: `peak()` is the largest value ever `set`/`add`ed —
/// how `max_queue_depth` survives the end-of-run snapshot.  Values are
/// assumed non-negative (the watermark starts at 0).
pub struct Gauge {
    bits: AtomicU64,
    peak_bits: AtomicU64,
}

impl Gauge {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Gauge {
        Gauge { bits: AtomicU64::new(0f64.to_bits()), peak_bits: AtomicU64::new(0f64.to_bits()) }
    }

    pub fn set(&self, v: f64) {
        self.bits.store(v.to_bits(), Ordering::Relaxed);
        let _ = self.peak_bits.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| {
            if v > f64::from_bits(b) { Some(v.to_bits()) } else { None }
        });
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    pub fn peak(&self) -> f64 {
        f64::from_bits(self.peak_bits.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket histogram: atomic per-bucket counts over caller-chosen
/// upper bounds (an implicit `+Inf` bucket catches the tail).  The cheap
/// instrument for hot-path observations (decode-step latency, upload
/// bytes per step) where raw samples would cost allocation per forward.
pub struct Histogram {
    bounds: Vec<f64>,
    buckets: Vec<AtomicU64>,
    sum: FloatCounter,
    count: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            sum: FloatCounter::new(),
            count: AtomicU64::new(0),
        }
    }

    pub fn observe(&self, v: f64) {
        let mut i = 0;
        while i < self.bounds.len() && v > self.bounds[i] {
            i += 1;
        }
        self.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.sum.add(v);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        self.sum.get()
    }
}

/// Raw-sample series for per-request observations (latency, TTFT, queue
/// wait) where the reports need *exact* percentiles, not bucket edges.
/// Pushes go to a per-thread-striped mutex lane — requests are orders of
/// magnitude rarer than decode steps, so a short lock is fine.
pub struct Series {
    lanes: Vec<Mutex<Vec<f64>>>,
}

impl Series {
    #[allow(clippy::new_without_default)]
    pub fn new() -> Series {
        Series { lanes: (0..STRIPES).map(|_| Mutex::new(Vec::new())).collect() }
    }

    pub fn record(&self, v: f64) {
        crate::util::sync::lock_recover(&self.lanes[thread_stripe()]).push(v);
    }

    /// All samples recorded so far (order unspecified across threads).
    pub fn samples(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for lane in &self.lanes {
            out.extend_from_slice(&crate::util::sync::lock_recover(lane));
        }
        out
    }

    pub fn count(&self) -> usize {
        self.lanes.iter().map(|l| crate::util::sync::lock_recover(l).len()).sum()
    }
}

/// A registered instrument (shared handle; the registry and every owner
/// hold the same `Arc`, so all views read the same storage).
#[derive(Clone)]
pub enum Instrument {
    Counter(Arc<Counter>),
    FloatCounter(Arc<FloatCounter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
    Series(Arc<Series>),
}

type Key = (String, Vec<(String, String)>);

fn key_of(name: &str, labels: &[(&str, &str)]) -> Key {
    let mut l: Vec<(String, String)> =
        labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    l.sort();
    (name.to_string(), l)
}

/// Name + label-set → instrument map.  `counter`/`gauge`/… are
/// get-or-create: the first caller allocates, later callers (and the
/// snapshot) share the same atomics.  `Sync`, so the exposition writer
/// thread snapshots while workers record.
pub struct Registry {
    metrics: RwLock<BTreeMap<Key, Instrument>>,
}

impl Default for Registry {
    fn default() -> Self {
        Registry::new()
    }
}

impl Registry {
    pub fn new() -> Registry {
        Registry { metrics: RwLock::new(BTreeMap::new()) }
    }

    fn get_or_insert(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Instrument,
    ) -> Instrument {
        let key = key_of(name, labels);
        if let Some(m) = self.metrics.read().unwrap().get(&key) {
            return m.clone();
        }
        self.metrics.write().unwrap().entry(key).or_insert_with(make).clone()
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.get_or_insert(name, labels, || Instrument::Counter(Arc::new(Counter::new()))) {
            Instrument::Counter(c) => c,
            _ => panic!("metric '{name}' is registered with a different type"),
        }
    }

    pub fn float_counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<FloatCounter> {
        match self
            .get_or_insert(name, labels, || Instrument::FloatCounter(Arc::new(FloatCounter::new())))
        {
            Instrument::FloatCounter(c) => c,
            _ => panic!("metric '{name}' is registered with a different type"),
        }
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.get_or_insert(name, labels, || Instrument::Gauge(Arc::new(Gauge::new()))) {
            Instrument::Gauge(g) => g,
            _ => panic!("metric '{name}' is registered with a different type"),
        }
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)], bounds: &[f64]) -> Arc<Histogram> {
        match self
            .get_or_insert(name, labels, || Instrument::Histogram(Arc::new(Histogram::new(bounds))))
        {
            Instrument::Histogram(h) => h,
            _ => panic!("metric '{name}' is registered with a different type"),
        }
    }

    pub fn series(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Series> {
        match self.get_or_insert(name, labels, || Instrument::Series(Arc::new(Series::new()))) {
            Instrument::Series(s) => s,
            _ => panic!("metric '{name}' is registered with a different type"),
        }
    }

    /// Point-in-time copy of every registered instrument's state, in
    /// stable `(name, labels)` order.
    pub fn snapshot(&self) -> Snapshot {
        let metrics = self.metrics.read().unwrap();
        let samples = metrics
            .iter()
            .map(|((name, labels), m)| Sample {
                name: name.clone(),
                labels: labels.clone(),
                value: match m {
                    Instrument::Counter(c) => Value::Counter(c.get()),
                    Instrument::FloatCounter(c) => Value::FloatCounter(c.get()),
                    Instrument::Gauge(g) => Value::Gauge { value: g.get(), peak: g.peak() },
                    Instrument::Histogram(h) => Value::Histogram {
                        bounds: h.bounds.clone(),
                        buckets: h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
                        sum: h.sum(),
                        count: h.count(),
                    },
                    Instrument::Series(s) => Value::Series(s.samples()),
                },
            })
            .collect();
        Snapshot { samples }
    }
}

/// One instrument's state at snapshot time.
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: Value,
}

impl Sample {
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

pub enum Value {
    Counter(u64),
    FloatCounter(f64),
    Gauge { value: f64, peak: f64 },
    Histogram { bounds: Vec<f64>, buckets: Vec<u64>, sum: f64, count: u64 },
    Series(Vec<f64>),
}

impl Value {
    /// A single scalar per instrument, used by the `sum*` helpers:
    /// counters report their count, gauges their current value,
    /// histograms their sum, series their sample sum.
    fn scalar(&self) -> f64 {
        match self {
            Value::Counter(v) => *v as f64,
            Value::FloatCounter(v) => *v,
            Value::Gauge { value, .. } => *value,
            Value::Histogram { sum, .. } => *sum,
            Value::Series(xs) => xs.iter().sum(),
        }
    }
}

/// The view side of the registry: aggregation helpers the stats structs
/// (`ServeStats`, `PoolServeStats`) are derived through.
pub struct Snapshot {
    pub samples: Vec<Sample>,
}

impl Snapshot {
    fn named(&self, name: &str) -> impl Iterator<Item = &Sample> {
        self.samples.iter().filter(move |s| s.name == name)
    }

    /// Sum a metric's scalar across every label combination.
    pub fn sum(&self, name: &str) -> f64 {
        self.named(name).map(|s| s.value.scalar()).sum()
    }

    /// Sum a metric's scalar grouped by one label's values.
    pub fn sum_by(&self, name: &str, label: &str) -> BTreeMap<String, f64> {
        let mut out = BTreeMap::new();
        for s in self.named(name) {
            if let Some(v) = s.label(label) {
                *out.entry(v.to_string()).or_insert(0.0) += s.value.scalar();
            }
        }
        out
    }

    /// Concatenate a series metric's samples grouped by one label.
    pub fn series_by(&self, name: &str, label: &str) -> BTreeMap<String, Vec<f64>> {
        let mut out: BTreeMap<String, Vec<f64>> = BTreeMap::new();
        for s in self.named(name) {
            if let (Some(l), Value::Series(xs)) = (s.label(label), &s.value) {
                out.entry(l.to_string()).or_default().extend_from_slice(xs);
            }
        }
        out
    }

    /// Largest current value of a gauge across label combinations.
    pub fn gauge_max(&self, name: &str) -> f64 {
        self.named(name)
            .filter_map(|s| match &s.value {
                Value::Gauge { value, .. } => Some(*value),
                _ => None,
            })
            .fold(0.0, f64::max)
    }

    /// Largest high-watermark of a gauge across label combinations.
    pub fn gauge_peak_max(&self, name: &str) -> f64 {
        self.named(name)
            .filter_map(|s| match &s.value {
                Value::Gauge { peak, .. } => Some(*peak),
                _ => None,
            })
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_sums_across_threads() {
        let c = Arc::new(Counter::new());
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let c = c.clone();
                scope.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 4000);
    }

    #[test]
    fn float_counter_accumulates_under_contention() {
        let c = Arc::new(FloatCounter::new());
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let c = c.clone();
                scope.spawn(move || {
                    for _ in 0..100 {
                        c.add(0.5);
                    }
                });
            }
        });
        assert!((c.get() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn gauge_tracks_peak() {
        let g = Gauge::new();
        g.set(3.0);
        g.set(7.0);
        g.set(2.0);
        assert_eq!(g.get(), 2.0);
        assert_eq!(g.peak(), 7.0);
    }

    #[test]
    fn histogram_buckets_le_bounds() {
        let h = Histogram::new(&[1.0, 10.0]);
        for v in [0.5, 1.0, 2.0, 100.0] {
            h.observe(v);
        }
        let counts: Vec<u64> =
            h.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        assert_eq!(counts, vec![2, 1, 1]); // le=1, le=10, +Inf
        assert_eq!(h.count(), 4);
        assert!((h.sum() - 103.5).abs() < 1e-9);
    }

    #[test]
    fn registry_get_or_create_shares_storage() {
        let reg = Registry::new();
        let a = reg.counter("x_total", &[("tenant", "a")]);
        let b = reg.counter("x_total", &[("tenant", "a")]);
        assert!(Arc::ptr_eq(&a, &b), "same name+labels must share one instrument");
        a.add(3);
        assert_eq!(b.get(), 3);
        let other = reg.counter("x_total", &[("tenant", "b")]);
        assert_eq!(other.get(), 0);
    }

    #[test]
    fn snapshot_sums_and_groups() {
        let reg = Registry::new();
        reg.counter("req_total", &[("tenant", "a"), ("worker", "0")]).add(2);
        reg.counter("req_total", &[("tenant", "a"), ("worker", "1")]).add(3);
        reg.counter("req_total", &[("tenant", "b"), ("worker", "0")]).add(5);
        reg.series("lat_ms", &[("tenant", "a")]).record(4.0);
        reg.gauge("depth", &[("shard", "0")]).set(9.0);
        reg.gauge("depth", &[("shard", "0")]).set(1.0);
        let snap = reg.snapshot();
        assert_eq!(snap.sum("req_total") as u64, 10);
        let by_tenant = snap.sum_by("req_total", "tenant");
        assert_eq!(*by_tenant.get("a").unwrap() as u64, 5);
        assert_eq!(*by_tenant.get("b").unwrap() as u64, 5);
        let by_worker = snap.sum_by("req_total", "worker");
        assert_eq!(*by_worker.get("0").unwrap() as u64, 7);
        assert_eq!(snap.series_by("lat_ms", "tenant").get("a").unwrap(), &vec![4.0]);
        assert_eq!(snap.gauge_max("depth"), 1.0);
        assert_eq!(snap.gauge_peak_max("depth"), 9.0);
    }
}
