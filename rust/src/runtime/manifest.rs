//! artifacts/manifest.json parsing: the contract between `python/compile`
//! (which writes it) and the rust runtime (which validates every buffer it
//! feeds PJRT against these specs).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    /// packed INT4 weight bytes (two codes per element) — the eval_int4
    /// serving artifacts' weight inputs
    U8,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u8" => Ok(DType::U8),
            _ => bail!("unknown dtype '{s}'"),
        }
    }
}

/// One artifact input or output tensor spec.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl IoSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<String>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|s| s.name == name)
            .with_context(|| format!("artifact {} has no input '{name}'", self.file))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|s| s == name)
            .with_context(|| format!("artifact {} has no output '{name}'", self.file))
    }
}

/// Model hyperparameters mirrored from python/compile/model.py.
#[derive(Clone, Debug)]
pub struct ModelHyper {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub r_max: usize,
    pub group_size: usize,
    pub param_count: usize,
    pub mods: Vec<String>,
    /// (out_features, in_features) per adapted module
    pub mod_dims: BTreeMap<String, (usize, usize)>,
}

impl ModelHyper {
    pub fn mod_dims(&self, m: &str) -> (usize, usize) {
        self.mod_dims[m]
    }

    pub fn mod_groups(&self, m: &str) -> usize {
        self.mod_dims[m].1 / self.group_size
    }

    /// base weight key adapted by module `m` ("q" -> "wq", ...)
    pub fn weight_key(m: &str) -> &'static str {
        match m {
            "q" => "wq",
            "k" => "wk",
            "v" => "wv",
            "up" => "wup",
            "down" => "wdown",
            _ => panic!("unknown module {m}"),
        }
    }
}

/// One model config's artifact set.
#[derive(Clone, Debug)]
pub struct ConfigEntry {
    pub model: ModelHyper,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigEntry>,
    pub shape_artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_iospec(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.req("name")?.as_str()?.to_string(),
        shape: j.req("shape")?.as_arr()?.iter().map(|x| x.as_usize().unwrap()).collect(),
        dtype: DType::parse(j.req("dtype")?.as_str()?)?,
    })
}

fn parse_artifact(j: &Json) -> Result<ArtifactSpec> {
    Ok(ArtifactSpec {
        file: j.req("file")?.as_str()?.to_string(),
        inputs: j.req("inputs")?.as_arr()?.iter().map(parse_iospec).collect::<Result<_>>()?,
        outputs: j
            .req("outputs")?
            .as_arr()?
            .iter()
            .map(|x| Ok(x.as_str()?.to_string()))
            .collect::<Result<_>>()?,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let mut configs = BTreeMap::new();
        for (name, entry) in j.req("configs")?.as_obj()? {
            let m = entry.req("model")?;
            let mods: Vec<String> = m
                .req("mods")?
                .as_arr()?
                .iter()
                .map(|x| Ok(x.as_str()?.to_string()))
                .collect::<Result<_>>()?;
            let mut mod_dims = BTreeMap::new();
            for (k, v) in m.req("mod_dims")?.as_obj()? {
                let a = v.as_arr()?;
                mod_dims.insert(k.clone(), (a[0].as_usize()?, a[1].as_usize()?));
            }
            let model = ModelHyper {
                name: name.clone(),
                vocab: m.req("vocab")?.as_usize()?,
                d_model: m.req("d_model")?.as_usize()?,
                n_layers: m.req("n_layers")?.as_usize()?,
                n_heads: m.req("n_heads")?.as_usize()?,
                d_ff: m.req("d_ff")?.as_usize()?,
                seq_len: m.req("seq_len")?.as_usize()?,
                batch: m.req("batch")?.as_usize()?,
                r_max: m.req("r_max")?.as_usize()?,
                group_size: m.req("group_size")?.as_usize()?,
                param_count: m.req("param_count")?.as_usize()?,
                mods,
                mod_dims,
            };
            let mut artifacts = BTreeMap::new();
            for (k, v) in entry.req("artifacts")?.as_obj()? {
                artifacts.insert(k.clone(), parse_artifact(v)?);
            }
            configs.insert(name.clone(), ConfigEntry { model, artifacts });
        }
        let mut shape_artifacts = BTreeMap::new();
        for (k, v) in j.req("shape_artifacts")?.as_obj()? {
            shape_artifacts.insert(k.clone(), parse_artifact(v)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), configs, shape_artifacts })
    }

    pub fn config(&self, name: &str) -> Result<&ConfigEntry> {
        self.configs
            .get(name)
            .with_context(|| format!("manifest has no config '{name}' (have: {:?})",
                self.configs.keys().collect::<Vec<_>>()))
    }

    /// Per-shape artifact lookup, e.g. wanda_256x1024 / fakequant_256x1024g32.
    pub fn shape_artifact(&self, key: &str) -> Result<&ArtifactSpec> {
        self.shape_artifacts
            .get(key)
            .with_context(|| format!("manifest has no shape artifact '{key}'"))
    }
}
