//! Positional-argument assembly: maps an artifact's manifest input list to
//! concrete values drawn from device-resident buffer sets (frozen base
//! weights, cached tenant adapters, the decode loop's token buffer), host
//! ParamSets (adapter/opt/quant state), the current data batch, and scalar
//! knobs (step, lr, qmax).
//!
//! Every artifact call in the coordinator goes through here, so input-order
//! bugs are impossible by construction: the manifest order *is* the order.
//!
//! Resolution order per input name:
//!   1. `devices`, earlier stores first — anything already resident on the
//!      device crosses the PJRT boundary as a borrowed handle (zero bytes);
//!   2. `host_sets`, first hit wins — uploaded per call without cloning;
//!   3. batch fields (`tokens`/`targets`/`loss_mask`/`adapter_idx`) —
//!      borrowed slices, uploaded per call without cloning (the train loop
//!      calls this every step);
//!   4. scalar knobs.
//!
//! The KV-cached decode path rides rule 1: the packed state produced by a
//! `prefill`/`decode` artifact goes straight back into the session's
//! device store under its input name (`kv_state`), and the per-step
//! `frontier`/`positions`/`seq_lens` vectors are `put_i32` into the same
//! store right before the call — so a decode step resolves every hot input
//! as a resident handle and the only host→device traffic is two
//! `(slots,)` i32 vectors.

use super::{Arg, ArtifactSpec, DeviceStore, DType, HostValue};
use crate::data::Batch;
use crate::model::ParamSet;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

pub fn build_args<'a>(
    spec: &ArtifactSpec,
    devices: &[&'a DeviceStore],
    host_sets: &[&'a ParamSet],
    batch: Option<&'a Batch>,
    scalars: &[(&str, f32)],
) -> Result<Vec<Arg<'a>>> {
    let mut out = Vec::with_capacity(spec.inputs.len());
    'next: for input in &spec.inputs {
        let name = input.name.as_str();
        // 1. device-resident buffers win, earlier stores first
        for d in devices {
            if d.contains(name) {
                out.push(Arg::Buf(d.get(name)?));
                continue 'next;
            }
        }
        // 2. host parameter sets, first hit wins
        for set in host_sets {
            if set.contains(name) {
                let t = set.get(name)?;
                if t.shape() != input.shape.as_slice() {
                    bail!("input '{name}': host tensor shape {:?} != spec {:?}",
                        t.shape(), input.shape);
                }
                out.push(Arg::HostRef(t));
                continue 'next;
            }
        }
        // 3. batch fields — borrowed, never cloned per call
        if let Some(b) = batch {
            match name {
                "tokens" => {
                    out.push(Arg::I32Ref(vec![b.batch, b.seq], &b.tokens));
                    continue 'next;
                }
                "targets" => {
                    out.push(Arg::I32Ref(vec![b.batch, b.seq], &b.targets));
                    continue 'next;
                }
                "loss_mask" => {
                    out.push(Arg::F32Ref(vec![b.batch, b.seq], &b.loss_mask));
                    continue 'next;
                }
                // per-row adapter-bank slots (eval_gathered); an empty vec
                // means the caller didn't build a mixed batch — fall through
                // so the bail below names the missing input
                "adapter_idx" if !b.adapter_idx.is_empty() => {
                    out.push(Arg::I32Ref(vec![b.batch], &b.adapter_idx));
                    continue 'next;
                }
                _ => {}
            }
        }
        // 4. scalar knobs
        for (k, v) in scalars {
            if *k == name {
                if input.dtype != DType::F32 || input.shape != vec![1] {
                    bail!("scalar input '{name}' has non-scalar spec {:?}", input.shape);
                }
                out.push(Arg::Host(HostValue::F32(Tensor::scalar(*v))));
                continue 'next;
            }
        }
        bail!("no source for artifact input '{name}' ({:?})", input.shape);
    }
    Ok(out)
}
