//! PJRT runtime: loads AOT HLO-text artifacts and executes them on the CPU
//! client.  This is the only module that touches the `xla` crate; everything
//! above it works with host `Tensor`s and named buffers.
//!
//! Design notes:
//!   - HLO *text* interchange (manifest-declared), parsed by
//!     `HloModuleProto::from_text_file` — see DESIGN.md §5 / aot.py.
//!   - Executables are compiled once and cached per (config, kind).
//!   - Training keeps all parameters device-resident (`DeviceStore`):
//!     each step passes `PjRtBuffer` handles via `execute_b`, so the host
//!     only round-trips the scalar loss.

pub mod manifest;
pub mod args;

pub use manifest::{ArtifactSpec, ConfigEntry, DType, IoSpec, Manifest, ModelHyper};

use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::path::Path;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide count of host→device bytes crossing the PJRT boundary.
/// Every upload path (`host_to_buffer`, borrowed-slice args, DeviceStore
/// puts) feeds it, so benches and tests can read deltas around a hot path
/// and prove e.g. that a steady-state decode step ships only the token
/// batch.  Relaxed ordering: this is a metric, not a synchronization point.
static HOST_UPLOAD_BYTES: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// Per-thread count of the same bytes.  Uploads happen on the thread
    /// that calls into PJRT, so with one engine replica per worker thread
    /// this counter is exact per worker even while siblings upload
    /// concurrently — the process-wide counter is only an aggregate then.
    static THREAD_UPLOAD_BYTES: Cell<u64> = const { Cell::new(0) };
}

/// Total host→device bytes uploaded so far (monotonic; read deltas).
/// Process-wide: under multi-worker serving this sums all threads — use
/// [`UploadScope`] / [`thread_upload_bytes`] for exact per-path asserts.
pub fn host_upload_bytes() -> u64 {
    HOST_UPLOAD_BYTES.load(Ordering::Relaxed)
}

/// Host→device bytes uploaded *by the calling thread* so far (monotonic).
pub fn thread_upload_bytes() -> u64 {
    THREAD_UPLOAD_BYTES.with(|c| c.get())
}

/// Scoped delta of the calling thread's upload bytes: create before the
/// code under measurement, read `bytes()` after.  Exact under parallel
/// workers and parallel tests — other threads' uploads never leak in —
/// which is what lets upload-accounting tests share a test binary.
///
/// The scope is `!Send` (the counter is thread-local, so a scope begun
/// on one thread is meaningless on another — the type makes that misuse
/// impossible rather than silently underflowing).
pub struct UploadScope {
    start: u64,
    _not_send: std::marker::PhantomData<*mut ()>,
}

impl UploadScope {
    pub fn begin() -> UploadScope {
        UploadScope { start: thread_upload_bytes(), _not_send: std::marker::PhantomData }
    }

    /// Bytes uploaded by this thread since `begin`.
    pub fn bytes(&self) -> u64 {
        thread_upload_bytes().saturating_sub(self.start)
    }
}

fn note_upload(bytes: usize) {
    HOST_UPLOAD_BYTES.fetch_add(bytes as u64, Ordering::Relaxed);
    THREAD_UPLOAD_BYTES.with(|c| c.set(c.get() + bytes as u64));
}

/// A host-side value crossing the PJRT boundary.
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(Tensor),
    I32(Vec<usize>, Vec<i32>),
    /// packed INT4 weight bytes (eval_int4 inputs)
    U8(Vec<usize>, Vec<u8>),
}

impl HostValue {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => t.shape(),
            HostValue::I32(s, _) => s,
            HostValue::U8(s, _) => s,
        }
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostValue::F32(_) => DType::F32,
            HostValue::I32(..) => DType::I32,
            HostValue::U8(..) => DType::U8,
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            _ => bail!("expected f32 host value"),
        }
    }
}

impl From<Tensor> for HostValue {
    fn from(t: Tensor) -> Self {
        HostValue::F32(t)
    }
}

/// One compiled artifact plus its manifest spec (for validation).
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    fn check_inputs(&self, shapes: &[(&[usize], DType)]) -> Result<()> {
        if shapes.len() != self.spec.inputs.len() {
            bail!("{}: expected {} inputs, got {}",
                self.spec.file, self.spec.inputs.len(), shapes.len());
        }
        for (i, ((shape, dtype), spec)) in shapes.iter().zip(&self.spec.inputs).enumerate() {
            if *shape != spec.shape.as_slice() || *dtype != spec.dtype {
                bail!("{}: input #{i} ('{}') wants {:?} {:?}, got {:?} {:?}",
                    self.spec.file, spec.name, spec.shape, spec.dtype, shape, dtype);
            }
        }
        Ok(())
    }

    /// Execute with host values; returns host f32 tensors in output order.
    /// (All SQFT artifact outputs are f32.)
    pub fn run(&self, client: &xla::PjRtClient, inputs: &[HostValue]) -> Result<Vec<Tensor>> {
        let args: Vec<Arg> = inputs.iter().map(|v| Arg::Host(v.clone())).collect();
        self.run_mixed(client, &args)
    }

    /// Execute with a mix of device-resident buffers (frozen base weights)
    /// and host values (adapter state, batch).  The classic artifacts are
    /// lowered with `return_tuple=True`, so PJRT hands back one tuple
    /// buffer which we decompose on the host.
    pub fn run_mixed(&self, client: &xla::PjRtClient, inputs: &[Arg]) -> Result<Vec<Tensor>> {
        let outs = self.execute_raw(client, inputs)?;
        let buf = outs.into_iter().next().context("no output buffer")?;
        let mut lit = buf.to_literal_sync()?;
        let parts = lit.decompose_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!("{}: got {} tuple elements for {} declared outputs",
                self.spec.file, parts.len(), self.spec.outputs.len());
        }
        parts.into_iter().map(literal_to_tensor).collect()
    }

    /// Execute and hand back the raw replica-0 output buffers *without*
    /// downloading them.  This is the cached-decode hot path: the KV-state
    /// artifacts are lowered with an array root (`tuple_out=False` in
    /// aot.py), so the single returned buffer is the packed per-slot state
    /// itself and stays device-resident — the caller re-feeds it as the
    /// next step's `Arg::Buf` input with zero host traffic in between.
    pub fn run_device(
        &self,
        client: &xla::PjRtClient,
        inputs: &[Arg],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        self.execute_raw(client, inputs)
    }

    fn execute_raw(
        &self,
        client: &xla::PjRtClient,
        inputs: &[Arg],
    ) -> Result<Vec<xla::PjRtBuffer>> {
        let shapes: Vec<(Vec<usize>, DType)> = inputs
            .iter()
            .map(|a| match a {
                Arg::Host(v) => Ok((v.shape().to_vec(), v.dtype())),
                Arg::HostRef(t) => Ok((t.shape().to_vec(), DType::F32)),
                Arg::I32Ref(s, d) => {
                    if s.iter().product::<usize>() != d.len() {
                        bail!("i32 arg: shape {:?} wants {} elems, got {}",
                            s, s.iter().product::<usize>(), d.len());
                    }
                    Ok((s.clone(), DType::I32))
                }
                Arg::F32Ref(s, d) => {
                    if s.iter().product::<usize>() != d.len() {
                        bail!("f32 arg: shape {:?} wants {} elems, got {}",
                            s, s.iter().product::<usize>(), d.len());
                    }
                    Ok((s.clone(), DType::F32))
                }
                Arg::U8Ref(s, d) => {
                    if s.iter().product::<usize>() != d.len() {
                        bail!("u8 arg: shape {:?} wants {} elems, got {}",
                            s, s.iter().product::<usize>(), d.len());
                    }
                    Ok((s.clone(), DType::U8))
                }
                Arg::Buf(b) => {
                    let s = b.on_device_shape()?;
                    match &s {
                        xla::Shape::Array(arr) => Ok((
                            arr.dims().iter().map(|&d| d as usize).collect(),
                            match arr.ty() {
                                xla::ElementType::S32 => DType::I32,
                                xla::ElementType::U8 => DType::U8,
                                _ => DType::F32,
                            },
                        )),
                        _ => bail!("tuple buffer passed as input"),
                    }
                }
            })
            .collect::<Result<_>>()?;
        let shape_refs: Vec<(&[usize], DType)> =
            shapes.iter().map(|(s, d)| (s.as_slice(), d.clone())).collect();
        self.check_inputs(&shape_refs)?;

        // chaos-harness failpoint for the host→device upload path (a
        // thread-local no-op unless a serving worker installed a plan)
        crate::faults::check_thread(crate::faults::SITE_UPLOAD)?;
        // upload host values, then assemble the positional arg list
        let mut owned: Vec<xla::PjRtBuffer> = Vec::new();
        let mut order: Vec<usize> = Vec::new(); // index into owned, usize::MAX = borrow
        for a in inputs {
            match a {
                Arg::Host(v) => {
                    owned.push(host_to_buffer(client, v)?);
                    order.push(owned.len() - 1);
                }
                Arg::HostRef(t) => {
                    note_upload(t.len() * 4);
                    owned.push(client.buffer_from_host_buffer(t.data(), t.shape(), None)?);
                    order.push(owned.len() - 1);
                }
                Arg::I32Ref(s, d) => {
                    note_upload(d.len() * 4);
                    owned.push(client.buffer_from_host_buffer(d, s, None)?);
                    order.push(owned.len() - 1);
                }
                Arg::F32Ref(s, d) => {
                    note_upload(d.len() * 4);
                    owned.push(client.buffer_from_host_buffer(d, s, None)?);
                    order.push(owned.len() - 1);
                }
                Arg::U8Ref(s, d) => {
                    note_upload(d.len());
                    owned.push(client.buffer_from_host_buffer(d, s, None)?);
                    order.push(owned.len() - 1);
                }
                Arg::Buf(_) => order.push(usize::MAX),
            }
        }
        let mut refs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for (a, &o) in inputs.iter().zip(&order) {
            match a {
                Arg::Buf(b) => refs.push(b),
                _ => refs.push(&owned[o]),
            }
        }
        let out = self.exe.execute_b(&refs)?;
        out.into_iter().next().context("no output replica")
    }
}

/// Dims of an array-shaped device buffer; errors on tuple shapes.  The
/// engine probes a freshly produced KV-state buffer through this before
/// trusting it — a stale artifact set lowered with a tuple root fails the
/// probe and the session falls back to the full-forward path.
pub fn buffer_array_dims(buf: &xla::PjRtBuffer) -> Result<Vec<usize>> {
    match buf.on_device_shape()? {
        xla::Shape::Array(arr) => Ok(arr.dims().iter().map(|&d| d as usize).collect()),
        _ => bail!("tuple-shaped buffer (artifact lowered without an array root)"),
    }
}

/// One positional artifact argument.
pub enum Arg<'a> {
    /// owned host value (scalars, one-off tensors)
    Host(HostValue),
    /// borrowed host tensor (adapter/opt state) — uploaded without cloning
    /// the host buffer first (perf: saves one memcpy per tensor per step)
    HostRef(&'a Tensor),
    /// borrowed i32 slice + owned (tiny) shape — the batch token/target
    /// rows, uploaded straight from the caller's buffer every step
    I32Ref(Vec<usize>, &'a [i32]),
    /// borrowed f32 slice + owned shape (batch loss masks)
    F32Ref(Vec<usize>, &'a [f32]),
    /// borrowed u8 slice + owned shape (packed INT4 weight bytes)
    U8Ref(Vec<usize>, &'a [u8]),
    Buf(&'a xla::PjRtBuffer),
}

fn literal_to_tensor(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data: Vec<f32> = lit.to_vec::<f32>()?;
    Tensor::new(&dims, data)
}

pub fn host_to_buffer(client: &xla::PjRtClient, v: &HostValue) -> Result<xla::PjRtBuffer> {
    match v {
        HostValue::F32(t) => {
            note_upload(t.len() * 4);
            Ok(client.buffer_from_host_buffer(t.data(), t.shape(), None)?)
        }
        HostValue::I32(shape, data) => {
            note_upload(data.len() * 4);
            Ok(client.buffer_from_host_buffer(data, shape, None)?)
        }
        HostValue::U8(shape, data) => {
            note_upload(data.len());
            Ok(client.buffer_from_host_buffer(data, shape, None)?)
        }
    }
}

/// Download one (array) buffer to a host Tensor with an expected shape.
pub fn buffer_to_tensor(buf: &xla::PjRtBuffer, shape: &[usize]) -> Result<Tensor> {
    let t = literal_to_tensor(buf.to_literal_sync()?)?;
    if t.shape() != shape {
        bail!("buffer shape {:?} != expected {:?}", t.shape(), shape);
    }
    Ok(t)
}

/// Loads + compiles + caches artifacts for one artifacts/ directory.
///
/// Thread-safety contract: a `Runtime` (and everything holding its
/// buffers — `DeviceStore`, `Engine`) is deliberately `!Send`/`!Sync`:
/// the executable cache is `Rc`/`RefCell` and PJRT handles are not
/// `Sync`.  Multi-threaded serving therefore never shares a `Runtime`;
/// each worker thread constructs its own replica from the same artifact
/// dir (see `serve::pool`), which compiles per worker and keeps every
/// PJRT call thread-local by construction.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<BTreeMap<String, Rc<Executable>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, manifest, cache: RefCell::new(BTreeMap::new()) })
    }

    fn compile(&self, spec: &ArtifactSpec) -> Result<Executable> {
        let path = self.manifest.dir.join(&spec.file);
        let path_str = path.to_str().context("non-utf8 artifact path")?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable { spec: spec.clone(), exe })
    }

    /// Get (compile-once) a per-config artifact: kind in
    /// {train, train_qa, eval, eval_qa, calib}.
    pub fn executable(&self, config: &str, kind: &str) -> Result<Rc<Executable>> {
        let key = format!("{config}/{kind}");
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let entry = self.manifest.config(config)?;
        let spec = entry
            .artifacts
            .get(kind)
            .with_context(|| format!("config {config} has no artifact kind '{kind}'"))?;
        let exe = Rc::new(self.compile(spec)?);
        self.cache.borrow_mut().insert(key, exe.clone());
        Ok(exe)
    }

    /// Get (compile-once) a shape artifact: e.g. "wanda_256x1024".
    pub fn shape_executable(&self, key: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.cache.borrow().get(key) {
            return Ok(e.clone());
        }
        let spec = self.manifest.shape_artifact(key)?;
        let exe = Rc::new(self.compile(spec)?);
        self.cache.borrow_mut().insert(key.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn model(&self, config: &str) -> Result<&ModelHyper> {
        Ok(&self.manifest.config(config)?.model)
    }
}

/// Named device-resident buffers (parameters, optimizer state).
pub struct DeviceStore {
    bufs: BTreeMap<String, xla::PjRtBuffer>,
}

impl DeviceStore {
    pub fn new() -> DeviceStore {
        DeviceStore { bufs: BTreeMap::new() }
    }

    pub fn put(&mut self, name: &str, buf: xla::PjRtBuffer) {
        self.bufs.insert(name.to_string(), buf);
    }

    pub fn put_host(&mut self, client: &xla::PjRtClient, name: &str, v: &HostValue) -> Result<()> {
        self.bufs.insert(name.to_string(), host_to_buffer(client, v)?);
        Ok(())
    }

    /// Upload a borrowed f32 tensor without cloning its host buffer first
    /// (the registration/startup bulk-upload path).
    pub fn put_tensor(&mut self, client: &xla::PjRtClient, name: &str, t: &Tensor) -> Result<()> {
        note_upload(t.len() * 4);
        self.bufs
            .insert(name.to_string(), client.buffer_from_host_buffer(t.data(), t.shape(), None)?);
        Ok(())
    }

    /// Upload a borrowed i32 slice (the decode loop's token batch).
    /// Replacing an existing buffer drops the old device allocation.
    pub fn put_i32(
        &mut self,
        client: &xla::PjRtClient,
        name: &str,
        shape: &[usize],
        data: &[i32],
    ) -> Result<()> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("'{name}': shape {:?} wants {} elems, got {}",
                shape, shape.iter().product::<usize>(), data.len());
        }
        note_upload(data.len() * 4);
        self.bufs.insert(name.to_string(), client.buffer_from_host_buffer(data, shape, None)?);
        Ok(())
    }

    /// Upload a borrowed u8 slice (packed INT4 weight bytes — the
    /// INT4-resident serving base).
    pub fn put_u8(
        &mut self,
        client: &xla::PjRtClient,
        name: &str,
        shape: &[usize],
        data: &[u8],
    ) -> Result<()> {
        if shape.iter().product::<usize>() != data.len() {
            bail!("'{name}': shape {:?} wants {} elems, got {}",
                shape, shape.iter().product::<usize>(), data.len());
        }
        note_upload(data.len());
        self.bufs.insert(name.to_string(), client.buffer_from_host_buffer(data, shape, None)?);
        Ok(())
    }

    /// Drop one buffer (freeing its device allocation); true if present.
    pub fn remove(&mut self, name: &str) -> bool {
        self.bufs.remove(name).is_some()
    }

    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }

    pub fn get(&self, name: &str) -> Result<&xla::PjRtBuffer> {
        self.bufs.get(name).with_context(|| format!("device store missing '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.bufs.contains_key(name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.bufs.keys().map(|s| s.as_str()).collect()
    }

    /// Download one buffer to host with shape validation.
    pub fn fetch(&self, name: &str, shape: &[usize]) -> Result<Tensor> {
        buffer_to_tensor(self.get(name)?, shape)
    }
}

impl Default for DeviceStore {
    fn default() -> Self {
        Self::new()
    }
}
