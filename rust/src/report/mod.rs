//! Paper-style markdown table rendering for the reproduction harness.

use std::fmt::Write as _;

pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                let _ = write!(s, " {c:<w$} |");
            }
            s
        };
        let _ = writeln!(out, "{}", line(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for r in &self.rows {
            let _ = writeln!(out, "{}", line(r, &widths));
        }
        let _ = writeln!(out);
        assert_eq!(ncol, widths.len());
        out
    }
}

pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

pub fn yesno(b: bool) -> String {
    (if b { "yes" } else { "no" }).to_string()
}

pub fn check(mergeable: bool) -> String {
    (if mergeable { "[x]" } else { "[ ]" }).to_string()
}

/// Append a section to EXPERIMENTS-style log files.
pub fn append_to(path: &std::path::Path, content: &str) -> anyhow::Result<()> {
    use std::io::Write;
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    f.write_all(content.as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("Demo", &["Method", "Acc"]);
        t.row(vec!["LoRA".into(), "50.6".into()]);
        t.row(vec!["SQFT + SparsePEFT".into(), "52.5".into()]);
        let s = t.render();
        assert!(s.contains("### Demo"));
        assert!(s.contains("| Method "));
        assert!(s.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    #[should_panic]
    fn rejects_ragged_rows() {
        let mut t = Table::new("X", &["A", "B"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.525), "52.5");
        assert_eq!(yesno(true), "yes");
        assert_eq!(check(false), "[ ]");
    }
}
