//! Miniature property-testing harness (proptest is unavailable offline).
//!
//! `forall(seed, cases, gen, check)` runs `check` over `cases` random inputs
//! drawn by `gen`; on failure it reports the failing case index and the
//! case's debug form, then re-runs a simple shrink loop when the generator
//! supports it (numeric tuples shrink toward small values by re-drawing
//! with a halved size hint).

use crate::tensor::Rng;

/// A size-hinted generator: draws a case given (rng, size).
pub trait Gen<T> {
    fn draw(&self, rng: &mut Rng, size: usize) -> T;
}

impl<T, F: Fn(&mut Rng, usize) -> T> Gen<T> for F {
    fn draw(&self, rng: &mut Rng, size: usize) -> T {
        self(rng, size)
    }
}

/// Run a property over `cases` random inputs.  Panics with a reproducible
/// seed + shrunk case on violation.
pub fn forall<T: std::fmt::Debug, G: Gen<T>>(
    name: &str,
    seed: u64,
    cases: usize,
    gen: G,
    check: impl Fn(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for i in 0..cases {
        let size = 2 + (i * 30) / cases.max(1); // grow sizes over the run
        let case = gen.draw(&mut rng, size);
        if let Err(msg) = check(&case) {
            // shrink: re-draw at smaller sizes from the same stream until
            // we find a smaller failing case (bounded effort)
            let mut smallest: Option<(usize, T)> = None;
            let mut srng = Rng::new(seed ^ 0xDEAD);
            for s in (2..=size).rev() {
                for _ in 0..20 {
                    let c = gen.draw(&mut srng, s);
                    if check(&c).is_err() {
                        smallest = Some((s, c));
                    }
                }
            }
            match smallest {
                Some((s, c)) => panic!(
                    "property '{name}' failed at case {i} (seed {seed}): {msg}\n\
                     shrunk (size {s}): {c:#?}"),
                None => panic!(
                    "property '{name}' failed at case {i} (seed {seed}): {msg}\n\
                     case: {case:#?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        forall("abs_nonneg", 1, 100,
            |rng: &mut Rng, size| rng.normal() * size as f32,
            |x| if x.abs() >= 0.0 { Ok(()) } else { Err("neg".into()) });
    }

    #[test]
    #[should_panic(expected = "property 'always_small' failed")]
    fn catches_violation() {
        forall("always_small", 2, 200,
            |rng: &mut Rng, size| rng.next_f32() * size as f32,
            |x| if *x < 5.0 { Ok(()) } else { Err(format!("{x} >= 5")) });
    }
}
