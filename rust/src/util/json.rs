//! Minimal JSON parser/serializer (no external deps — the build is offline).
//!
//! Consumes artifacts/manifest.json (written by python/compile/aot.py) and
//! serializes checkpoint metadata + experiment reports.  Supports the full
//! JSON grammar except exotic number forms beyond f64.

use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json> {
        let bytes = s.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            bail!("trailing bytes at {}", p.i);
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing key '{key}'"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    // -- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_str(xs: &[String]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Str(x.clone())).collect())
    }

    // -- serialization ----------------------------------------------------

    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    x.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        if self.i >= self.b.len() {
            bail!("unexpected end of input");
        }
        Ok(self.b[self.i])
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at {}, found '{}'", c as char, self.i, self.b[self.i] as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c => {
                    // handle multi-byte utf-8 by copying raw bytes
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        self.i = start + width;
                        s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[0], Json::Num(1.0));
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[1].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x": [1, 2.5, "s", true, null], "y": {"z": -3}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café déjà""#).unwrap();
        assert_eq!(j, Json::Str("café déjà".into()));
        let rt = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, rt);
    }
}
