//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, and positional arguments; subcommands
//! are handled by `main.rs` dispatching on argv[1].

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse argv-style args. `flag_names` lists boolean flags (no value).
    pub fn parse(argv: &[String], flag_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if flag_names.contains(&name) {
                    out.flags.push(name.to_string());
                    i += 1;
                } else {
                    if i + 1 >= argv.len() {
                        bail!("option --{name} needs a value");
                    }
                    out.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                }
            } else {
                out.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse(&sv(&["run", "--steps", "100", "--quiet", "extra"]), &["quiet"])
            .unwrap();
        assert_eq!(a.positional, vec!["run", "extra"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert!(a.has_flag("quiet"));
        assert_eq!(a.get_usize("steps", 5).unwrap(), 100);
        assert_eq!(a.get_usize("missing", 5).unwrap(), 5);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&sv(&["--steps"]), &[]).is_err());
    }
}
