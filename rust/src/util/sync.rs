//! Poison-recovering lock helpers.
//!
//! `std::sync::Mutex` poisons itself when a holder panics, and every
//! subsequent `.lock().unwrap()` then panics too — one crashing worker
//! cascades into the dispatcher and every sibling that touches the same
//! shard.  All of this crate's shared state is counters, queues, and
//! logs whose invariants hold between individual mutations (a panicking
//! holder can at worst lose its own in-flight item), so the right policy
//! is to *recover*: take the guard out of the `PoisonError` and keep
//! serving.  The serve layer pairs this with `catch_unwind` around decode
//! sessions, so a crashed session neither wedges the scheduler nor takes
//! the process down.

use std::sync::{Condvar, Mutex, MutexGuard, WaitTimeoutResult};
use std::time::Duration;

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// `Condvar::wait_timeout` with the same poison-recovery policy.
pub fn wait_timeout_recover<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> (MutexGuard<'a, T>, WaitTimeoutResult) {
    cv.wait_timeout(guard, dur).unwrap_or_else(|p| p.into_inner())
}

/// `Mutex::get_mut` (exclusive access, no guard) with poison recovery.
pub fn get_mut_recover<T>(m: &mut Mutex<T>) -> &mut T {
    m.get_mut().unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recover_survives_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(0usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 1);
    }

    #[test]
    fn get_mut_recover_survives_poison() {
        let mut m = Mutex::new(5usize);
        // poison via a scoped thread panicking while holding the guard
        std::thread::scope(|s| {
            let r = &m;
            let _ = s
                .spawn(move || {
                    let _g = r.lock().unwrap();
                    panic!("poison it");
                })
                .join();
        });
        assert_eq!(*get_mut_recover(&mut m), 5);
    }
}
