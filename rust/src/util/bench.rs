//! Minimal benchmark harness (criterion is unavailable offline; benches are
//! `harness = false` binaries run by `cargo bench`).
//!
//! Prints one line per benchmark in a stable, grep-able format:
//!   bench <name> ... mean 12.34ms  p50 12.10ms  min 11.80ms  max 13.20ms  (n=20)

use super::{summarize, Summary};
use std::time::Instant;

/// Bench smoke mode (`SQFT_BENCH_SMOKE=1`): CI runs every bench with tiny
/// iteration counts so regressions in bench *code* are caught without
/// paying for (or trusting) timing numbers from shared runners.
pub fn smoke() -> bool {
    std::env::var("SQFT_BENCH_SMOKE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false)
}

/// `full` normally, 1 in smoke mode.
pub fn smoke_iters(full: usize) -> usize {
    if smoke() { 1 } else { full }
}

pub struct BenchReport {
    pub name: String,
    pub iters: usize,
    pub ms: Summary,
}

impl BenchReport {
    pub fn print(&self) {
        println!(
            "bench {:<44} mean {:>9.3}ms  p50 {:>9.3}ms  p99 {:>9.3}ms  min {:>9.3}ms  max {:>9.3}ms  (n={})",
            self.name, self.ms.mean, self.ms.p50, self.ms.p99, self.ms.min, self.ms.max, self.iters
        );
    }
}

/// Time `f` for `iters` iterations after `warmup` warmup runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchReport {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let report = BenchReport { name: name.to_string(), iters, ms: summarize(samples) };
    report.print();
    report
}

/// Throughput variant: returns items/sec from the mean.
pub fn bench_throughput<F: FnMut() -> usize>(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: F,
) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    let mut items_total = 0usize;
    for _ in 0..iters {
        let t0 = Instant::now();
        items_total += f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let s = summarize(samples.clone());
    let total_secs: f64 = samples.iter().sum::<f64>() / 1e3;
    let thr = items_total as f64 / total_secs.max(1e-12);
    println!(
        "bench {:<44} mean {:>9.3}ms  p50 {:>9.3}ms  throughput {:>10.1}/s  (n={})",
        name, s.mean, s.p50, thr, iters
    );
    thr
}
