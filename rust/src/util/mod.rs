//! Shared utilities: offline JSON, CLI arg parsing, timing helpers.

pub mod bench;
pub mod cli;
pub mod hash;
pub mod prop;
pub mod json;
pub mod sync;

use std::time::Instant;

/// Simple scoped timer for the perf logs (EXPERIMENTS.md §Perf).
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn ms(&self) -> f64 {
        self.secs() * 1e3
    }
}

/// Mean/std/percentile summary for latency series.
#[derive(Debug, Clone)]
pub struct Summary {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
    pub count: usize,
}

pub fn summarize(mut xs: Vec<f64>) -> Summary {
    assert!(!xs.is_empty());
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    let pct = |p: f64| xs[((xs.len() as f64 - 1.0) * p).round() as usize];
    Summary {
        mean,
        p50: pct(0.5),
        p95: pct(0.95),
        p99: pct(0.99),
        min: xs[0],
        max: xs[xs.len() - 1],
        count: xs.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_percentiles() {
        let s = summarize((1..=100).map(|x| x as f64).collect());
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p95 - 95.0).abs() <= 1.0);
        assert!((s.p99 - 99.0).abs() <= 1.0);
        assert_eq!(s.count, 100);
    }
}
