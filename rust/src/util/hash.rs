//! CRC32 (IEEE 802.3 polynomial, the zlib/PNG variant) — integrity
//! checksums for the checkpoint container.  Implemented in-repo (byte-wise
//! table driven) so the crate stays dependency-free; throughput is far from
//! the hot path (checksums run once per checkpoint save/load, not per
//! decode step).

use std::sync::OnceLock;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// Incremental CRC32 over a sequence of byte chunks (checkpoint payloads
/// are written tensor-by-tensor, so the checksum streams alongside).
#[derive(Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let t = table();
        for &b in bytes {
            self.state = t[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // reference values from the zlib crc32 implementation
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn incremental_matches_one_shot() {
        let data = b"SQFT checkpoint integrity section";
        let mut c = Crc32::new();
        c.update(&data[..7]);
        c.update(&data[7..20]);
        c.update(&data[20..]);
        assert_eq!(c.finish(), crc32(data));
    }

    #[test]
    fn single_bit_flip_changes_checksum() {
        let base: Vec<u8> = (0..64u8).collect();
        let want = crc32(&base);
        for i in 0..base.len() {
            for bit in 0..8 {
                let mut flipped = base.clone();
                flipped[i] ^= 1 << bit;
                assert_ne!(crc32(&flipped), want, "flip at byte {i} bit {bit}");
            }
        }
    }
}
