//! Synthetic task suite + tokenizer + batcher.
//!
//! Stand-ins for the paper's datasets (DESIGN.md §1): three generative math
//! tasks (GSM8K / MAWPS / SVAMP analogues, exact-match digit answers) and
//! seven multiple-choice "commonsense" tasks (BoolQ..OBQA analogues,
//! one-token answers).  Each task is a deterministic rule over random
//! instances, so accuracy is a real generalization signal with a
//! well-defined ceiling of 1.0, a learnable structure for the model, and a
//! verifiable answer — the same harness shape as lm-eval-harness.

pub mod tasks;
pub mod tokenizer;

pub use tasks::{Sample, Task};
pub use tokenizer::Tokenizer;

use crate::tensor::Rng;
use anyhow::{bail, Result};

/// One tokenized batch ready for a train/eval artifact.
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Vec<i32>,    // (batch, seq)
    pub targets: Vec<i32>,   // (batch, seq) next-token targets
    pub loss_mask: Vec<f32>, // (batch, seq) 1.0 where target is an answer char
    /// (batch,) per-row adapter-bank slot for the gathered mixed-tenant
    /// eval artifact; empty for the train/eval paths that don't use it
    /// (slot 0 = identity adapter, so an all-zero vector is the base model)
    pub adapter_idx: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
    /// number of real (non-padding-duplicate) samples in this batch
    pub real: usize,
}

/// Train/val/test split of generated samples.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub task: Task,
    pub train: Vec<Sample>,
    pub val: Vec<Sample>,
    pub test: Vec<Sample>,
}

impl Dataset {
    /// Generate a dataset with independent RNG streams per split.
    pub fn generate(task: Task, n_train: usize, n_val: usize, n_test: usize,
                    seed: u64) -> Dataset {
        let mut root = Rng::new(seed ^ task.id());
        let gen = |rng: &mut Rng, n: usize| -> Vec<Sample> {
            (0..n).map(|_| task.gen_sample(rng)).collect()
        };
        let mut r_train = root.fork(1);
        let mut r_val = root.fork(2);
        let mut r_test = root.fork(3);
        Dataset {
            task,
            train: gen(&mut r_train, n_train),
            val: gen(&mut r_val, n_val),
            test: gen(&mut r_test, n_test),
        }
    }

    /// The paper's "unified commonsense training set": concat + shuffle.
    pub fn unified(datasets: &[Dataset], seed: u64) -> Vec<Sample> {
        let mut all: Vec<Sample> = datasets.iter().flat_map(|d| d.train.clone()).collect();
        Rng::new(seed).shuffle(&mut all);
        all
    }
}

/// Encode one sample into (tokens, targets, loss_mask) rows of length `seq`.
pub fn encode_sample(tok: &Tokenizer, s: &Sample, seq: usize)
                     -> Result<(Vec<i32>, Vec<i32>, Vec<f32>)> {
    let text = format!("{}{}", s.prompt, s.answer);
    let ids = tok.encode(&text)?;
    // +1 for BOS
    if ids.len() + 1 > seq {
        bail!("sample too long ({} + BOS > {seq}): {text:?}", ids.len());
    }
    let mut tokens = vec![0i32; seq];
    tokens[0] = Tokenizer::BOS;
    for (i, &id) in ids.iter().enumerate() {
        tokens[i + 1] = id;
    }
    // next-token targets
    let mut targets = vec![0i32; seq];
    for i in 0..seq - 1 {
        targets[i] = tokens[i + 1];
    }
    // answer region: positions whose *target* is an answer char
    let ans_start = 1 + tok.encode(&s.prompt)?.len(); // first answer token idx
    let ans_end = 1 + ids.len(); // one past last answer token idx
    let mut loss_mask = vec![0f32; seq];
    for i in ans_start..ans_end {
        // target at position i-1 predicts token i
        loss_mask[i - 1] = 1.0;
    }
    Ok((tokens, targets, loss_mask))
}

/// Deterministic batcher with tail padding (repeats the last sample; the
/// `real` count lets eval ignore the duplicates).
pub struct Batcher<'a> {
    samples: &'a [Sample],
    tok: &'a Tokenizer,
    seq: usize,
    batch: usize,
    pos: usize,
}

impl<'a> Batcher<'a> {
    pub fn new(samples: &'a [Sample], tok: &'a Tokenizer, seq: usize, batch: usize)
               -> Batcher<'a> {
        Batcher { samples, tok, seq, batch, pos: 0 }
    }

    pub fn num_batches(&self) -> usize {
        self.samples.len().div_ceil(self.batch)
    }

    pub fn reset(&mut self) {
        self.pos = 0;
    }

    /// Next sequential batch (None when exhausted).
    pub fn next_batch(&mut self) -> Result<Option<Batch>> {
        if self.pos >= self.samples.len() {
            return Ok(None);
        }
        let end = (self.pos + self.batch).min(self.samples.len());
        let real = end - self.pos;
        let mut b = Batch {
            tokens: Vec::with_capacity(self.batch * self.seq),
            targets: Vec::with_capacity(self.batch * self.seq),
            loss_mask: Vec::with_capacity(self.batch * self.seq),
            adapter_idx: Vec::new(),
            batch: self.batch,
            seq: self.seq,
            real,
        };
        for i in 0..self.batch {
            let s = &self.samples[(self.pos + i).min(self.samples.len() - 1)];
            let (t, tg, lm) = encode_sample(self.tok, s, self.seq)?;
            b.tokens.extend(t);
            b.targets.extend(tg);
            b.loss_mask.extend(lm);
        }
        self.pos = end;
        Ok(Some(b))
    }

    /// A uniformly random batch (for training).
    pub fn random_batch(&self, rng: &mut Rng) -> Result<Batch> {
        let mut b = Batch {
            tokens: Vec::with_capacity(self.batch * self.seq),
            targets: Vec::with_capacity(self.batch * self.seq),
            loss_mask: Vec::with_capacity(self.batch * self.seq),
            adapter_idx: Vec::new(),
            batch: self.batch,
            seq: self.seq,
            real: self.batch,
        };
        for _ in 0..self.batch {
            let s = &self.samples[rng.below(self.samples.len())];
            let (t, tg, lm) = encode_sample(self.tok, s, self.seq)?;
            b.tokens.extend(t);
            b.targets.extend(tg);
            b.loss_mask.extend(lm);
        }
        Ok(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_masks_answer_region() {
        let tok = Tokenizer::new();
        let s = Sample { prompt: "Q:1+2=?A:".into(), answer: "3.".into() };
        let (tokens, targets, mask) = encode_sample(&tok, &s, 24).unwrap();
        assert_eq!(tokens[0], Tokenizer::BOS);
        // positions predicting '3' and '.' are masked
        let n_mask = mask.iter().filter(|&&m| m == 1.0).count();
        assert_eq!(n_mask, 2);
        // the masked targets decode to the answer
        let ans: String = mask
            .iter()
            .enumerate()
            .filter(|(_, &m)| m == 1.0)
            .map(|(i, _)| tok.decode_one(targets[i]).unwrap())
            .collect();
        assert_eq!(ans, "3.");
    }

    #[test]
    fn encode_rejects_overlong() {
        let tok = Tokenizer::new();
        let s = Sample { prompt: "Q:".repeat(40), answer: "1.".into() };
        assert!(encode_sample(&tok, &s, 16).is_err());
    }

    #[test]
    fn batcher_covers_all_samples() {
        let tok = Tokenizer::new();
        let ds = Dataset::generate(Task::SynGsm, 19, 0, 0, 7);
        let mut b = Batcher::new(&ds.train, &tok, 48, 8);
        assert_eq!(b.num_batches(), 3);
        let mut total_real = 0;
        while let Some(batch) = b.next_batch().unwrap() {
            assert_eq!(batch.tokens.len(), 8 * 48);
            total_real += batch.real;
        }
        assert_eq!(total_real, 19);
    }

    #[test]
    fn dataset_splits_are_deterministic() {
        let a = Dataset::generate(Task::SynBoolq, 5, 5, 5, 42);
        let b = Dataset::generate(Task::SynBoolq, 5, 5, 5, 42);
        assert_eq!(a.train[0].prompt, b.train[0].prompt);
        assert_eq!(a.test[4].answer, b.test[4].answer);
        let c = Dataset::generate(Task::SynBoolq, 5, 5, 5, 43);
        assert!(a.train.iter().zip(&c.train).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn all_tasks_generate_encodable_samples() {
        let tok = Tokenizer::new();
        for task in Task::all() {
            let mut rng = Rng::new(11);
            for _ in 0..200 {
                let s = task.gen_sample(&mut rng);
                let (_, _, mask) = encode_sample(&tok, &s, 48)
                    .unwrap_or_else(|e| panic!("{task:?}: {e}"));
                assert!(mask.iter().any(|&m| m == 1.0), "{task:?} empty answer");
            }
        }
    }
}
