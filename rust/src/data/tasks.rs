//! Synthetic task generators — the paper's dataset stand-ins.
//!
//! Math tasks (generative, multi-char digit answers, exact match):
//!   SynGsm   "Q:17+3*42=?A:143."        (GSM8K analogue: two-step arithmetic)
//!   SynMawps "TOM HAS 25, GETS 17. ALL?A:42."  (MAWPS: templated word problem)
//!   SynSvamp "JO HAS 31. ADDS 9, SEES 4. NOW?A:40."  (SVAMP: distractor number)
//!
//! Commonsense tasks (multiple-choice, single-token answers):
//!   SynBoolq  "IS 17 OVER 9?A:Y."          yes/no comparison
//!   SynPiqa   "FIT 7 IN BOX 5?A:N."        physical capacity rule
//!   SynHellas "NEXT 2,4,6?A:8."            sequence continuation
//!   SynWinog  "B BEATS F. WINNER?A:B."     referent selection
//!   SynArcE   "MAX 3,9,5?A:9."             easy reasoning
//!   SynArcC   "3+8 THEN *7, LAST DIGIT?A:7." harder reasoning
//!   SynObqa   "IS F IN ADF?A:Y."           knowledge lookup
//!
//! Every task is deterministic given its instance, answers are verifiable,
//! and the instance space is large enough that test accuracy measures
//! generalization of the rule, not memorization of strings.

use crate::tensor::Rng;

#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub prompt: String,
    pub answer: String,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    SynGsm,
    SynMawps,
    SynSvamp,
    SynBoolq,
    SynPiqa,
    SynHellas,
    SynWinog,
    SynArcE,
    SynArcC,
    SynObqa,
}

impl Task {
    pub fn all() -> [Task; 10] {
        [
            Task::SynGsm, Task::SynMawps, Task::SynSvamp,
            Task::SynBoolq, Task::SynPiqa, Task::SynHellas, Task::SynWinog,
            Task::SynArcE, Task::SynArcC, Task::SynObqa,
        ]
    }

    pub fn math() -> [Task; 3] {
        [Task::SynGsm, Task::SynMawps, Task::SynSvamp]
    }

    pub fn commonsense() -> [Task; 7] {
        [
            Task::SynBoolq, Task::SynPiqa, Task::SynHellas, Task::SynWinog,
            Task::SynArcE, Task::SynArcC, Task::SynObqa,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Task::SynGsm => "syn-gsm",
            Task::SynMawps => "syn-mawps",
            Task::SynSvamp => "syn-svamp",
            Task::SynBoolq => "syn-boolq",
            Task::SynPiqa => "syn-piqa",
            Task::SynHellas => "syn-hellas",
            Task::SynWinog => "syn-winog",
            Task::SynArcE => "syn-arce",
            Task::SynArcC => "syn-arcc",
            Task::SynObqa => "syn-obqa",
        }
    }

    pub fn from_name(s: &str) -> Option<Task> {
        Task::all().into_iter().find(|t| t.name() == s)
    }

    /// Stable id used to derive per-task RNG streams.
    pub fn id(&self) -> u64 {
        Task::all().iter().position(|t| t == self).unwrap() as u64 + 1
    }

    pub fn is_multiple_choice(&self) -> bool {
        !matches!(self, Task::SynGsm | Task::SynMawps | Task::SynSvamp)
    }

    /// The paper only has validation splits for Arc-e, Arc-c and OBQA
    /// (§3.3) — the hill-climbing search uses exactly these.
    pub fn has_validation(&self) -> bool {
        matches!(self, Task::SynArcE | Task::SynArcC | Task::SynObqa)
    }

    pub fn gen_sample(&self, rng: &mut Rng) -> Sample {
        match self {
            Task::SynGsm => {
                let a = rng.range(0, 30);
                let b = rng.range(0, 9);
                let c = rng.range(0, 9);
                Sample {
                    prompt: format!("Q:{a}+{b}*{c}=?A:"),
                    answer: format!("{}.", a + b * c),
                }
            }
            Task::SynMawps => {
                let name = ["TOM", "ANN", "BEN", "SUE", "MAX", "EVA"];
                let n = rng.choose(&name);
                let a = rng.range(1, 60);
                let b = rng.range(1, 39);
                let (verb, ans) = if rng.next_f32() < 0.5 {
                    ("GETS", a + b)
                } else if a >= b {
                    ("LOSES", a - b)
                } else {
                    ("GETS", a + b)
                };
                Sample {
                    prompt: format!("{n} HAS {a}, {verb} {b}. ALL?A:"),
                    answer: format!("{ans}."),
                }
            }
            Task::SynSvamp => {
                let name = ["JO", "AL", "KIM", "LEE"];
                let n = rng.choose(&name);
                let a = rng.range(1, 60);
                let b = rng.range(1, 30);
                let d = rng.range(1, 9); // distractor — must be ignored
                let (verb, ans) = if rng.next_f32() < 0.5 {
                    ("ADDS", a + b)
                } else if a >= b {
                    ("DROPS", a - b)
                } else {
                    ("ADDS", a + b)
                };
                Sample {
                    prompt: format!("{n} HAS {a}. {verb} {b}, SEES {d}. NOW?A:"),
                    answer: format!("{ans}."),
                }
            }
            Task::SynBoolq => {
                let a = rng.range(0, 99);
                let b = rng.range(0, 99);
                Sample {
                    prompt: format!("IS {a} OVER {b}?A:"),
                    answer: format!("{}.", if a > b { "Y" } else { "N" }),
                }
            }
            Task::SynPiqa => {
                let item = rng.range(1, 99);
                let cap = rng.range(1, 99);
                Sample {
                    prompt: format!("FIT {item} IN BOX {cap}?A:"),
                    answer: format!("{}.", if item <= cap { "Y" } else { "N" }),
                }
            }
            Task::SynHellas => {
                let start = rng.range(0, 4);
                let step = rng.range(1, 3);
                let (a, b, c) = (start, start + step, start + 2 * step);
                Sample {
                    prompt: format!("NEXT {a},{b},{c}?A:"),
                    answer: format!("{}.", (start + 3 * step) % 10),
                }
            }
            Task::SynWinog => {
                let p = (b'B' + rng.below(12) as u8) as char;
                let mut q = (b'B' + rng.below(12) as u8) as char;
                if q == p {
                    q = if p == 'M' { 'B' } else { ((p as u8) + 1) as char };
                }
                let wins_first = rng.next_f32() < 0.5;
                let verb = if wins_first { "BEATS" } else { "LOSES TO" };
                let ans = if wins_first { p } else { q };
                Sample {
                    prompt: format!("{p} {verb} {q}. WINNER?A:"),
                    answer: format!("{ans}."),
                }
            }
            Task::SynArcE => {
                let a = rng.range(0, 9);
                let b = rng.range(0, 9);
                let c = rng.range(0, 9);
                Sample {
                    prompt: format!("MAX {a},{b},{c}?A:"),
                    answer: format!("{}.", a.max(b).max(c)),
                }
            }
            Task::SynArcC => {
                let a = rng.range(0, 9);
                let b = rng.range(0, 9);
                let c = rng.range(2, 9);
                Sample {
                    prompt: format!("{a}+{b} THEN *{c}, LAST DIGIT?A:"),
                    answer: format!("{}.", ((a + b) * c) % 10),
                }
            }
            Task::SynObqa => {
                let mut set: Vec<char> = Vec::new();
                while set.len() < 3 {
                    let c = (b'A' + rng.below(16) as u8) as char;
                    if !set.contains(&c) {
                        set.push(c);
                    }
                }
                let probe = (b'A' + rng.below(16) as u8) as char;
                let inside = set.contains(&probe);
                let s: String = set.iter().collect();
                Sample {
                    prompt: format!("IS {probe} IN {s}?A:"),
                    answer: format!("{}.", if inside { "Y" } else { "N" }),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_roundtrip() {
        for t in Task::all() {
            assert_eq!(Task::from_name(t.name()), Some(t));
        }
        assert_eq!(Task::from_name("nope"), None);
    }

    #[test]
    fn answers_are_correct_gsm() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let s = Task::SynGsm.gen_sample(&mut rng);
            // parse "Q:a+b*c=?A:"
            let body = s.prompt.strip_prefix("Q:").unwrap().strip_suffix("=?A:").unwrap();
            let (a, rest) = body.split_once('+').unwrap();
            let (b, c) = rest.split_once('*').unwrap();
            let want = a.parse::<i64>().unwrap()
                + b.parse::<i64>().unwrap() * c.parse::<i64>().unwrap();
            assert_eq!(s.answer, format!("{want}."));
        }
    }

    #[test]
    fn mc_answers_are_single_char() {
        let mut rng = Rng::new(2);
        for t in Task::commonsense() {
            for _ in 0..50 {
                let s = t.gen_sample(&mut rng);
                assert_eq!(s.answer.len(), 2, "{t:?}: {}", s.answer); // "X."
                assert!(s.answer.ends_with('.'));
            }
        }
    }

    #[test]
    fn prompts_fit_small_seq() {
        let mut rng = Rng::new(3);
        for t in Task::all() {
            for _ in 0..300 {
                let s = t.gen_sample(&mut rng);
                assert!(s.prompt.len() + s.answer.len() + 1 <= 48,
                    "{t:?} too long: {}{}", s.prompt, s.answer);
            }
        }
    }

    #[test]
    fn winog_entities_distinct() {
        let mut rng = Rng::new(4);
        for _ in 0..200 {
            let s = Task::SynWinog.gen_sample(&mut rng);
            let p = s.prompt.chars().next().unwrap();
            let q = s.prompt.split_whitespace().rev().nth(1).unwrap()
                .trim_end_matches('.').chars().next().unwrap();
            assert_ne!(p, q, "{}", s.prompt);
        }
    }

    #[test]
    fn validation_split_rule_matches_paper() {
        let with_val: Vec<_> =
            Task::all().into_iter().filter(|t| t.has_validation()).collect();
        assert_eq!(with_val.len(), 3); // Arc-e, Arc-c, OBQA only (paper §3.3)
    }
}
