//! Byte-level tokenizer over a fixed 64-symbol alphabet (= model vocab).
//!
//! The alphabet covers everything the synthetic task generators emit.
//! Index 0 is PAD (also the ignore target), index 63 is BOS.

use anyhow::{bail, Result};

pub struct Tokenizer {
    to_id: [i32; 256],
    to_char: Vec<char>,
}

/// digits, operators, punctuation, upper-case letters, a few lower-case.
const ALPHABET: &str = "\u{0}0123456789+-*/=?:. ,ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnop";

impl Tokenizer {
    pub const PAD: i32 = 0;
    pub const BOS: i32 = 63;

    pub fn new() -> Tokenizer {
        let chars: Vec<char> = ALPHABET.chars().collect();
        assert_eq!(chars.len(), 63, "alphabet must be 63 chars + BOS = 64");
        let mut to_id = [-1i32; 256];
        for (i, &c) in chars.iter().enumerate() {
            to_id[c as usize] = i as i32;
        }
        let mut to_char = chars;
        to_char.push('#'); // BOS renders as '#'
        Tokenizer { to_id, to_char }
    }

    pub fn vocab(&self) -> usize {
        64
    }

    pub fn encode(&self, s: &str) -> Result<Vec<i32>> {
        let mut out = Vec::with_capacity(s.len());
        for c in s.chars() {
            let id = if (c as usize) < 256 { self.to_id[c as usize] } else { -1 };
            if id < 0 {
                bail!("character {c:?} not in alphabet");
            }
            out.push(id);
        }
        Ok(out)
    }

    pub fn decode_one(&self, id: i32) -> Result<char> {
        if id < 0 || id as usize >= self.to_char.len() {
            bail!("token id {id} out of range");
        }
        Ok(self.to_char[id as usize])
    }

    pub fn decode(&self, ids: &[i32]) -> Result<String> {
        ids.iter().map(|&i| self.decode_one(i)).collect()
    }
}

impl Default for Tokenizer {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = Tokenizer::new();
        let s = "Q:12+34*5=?A:182. YES and no";
        let ids = t.encode(s).unwrap();
        assert_eq!(t.decode(&ids).unwrap(), s);
    }

    #[test]
    fn vocab_is_64() {
        let t = Tokenizer::new();
        assert_eq!(t.vocab(), 64);
        // ids stay within vocab
        let ids = t.encode("ABCxyz? no wait").unwrap_err();
        let _ = ids; // 'x','y','z' beyond 'p' are rejected
    }

    #[test]
    fn rejects_unknown() {
        let t = Tokenizer::new();
        assert!(t.encode("hello!").is_err()); // '!' not in alphabet
        assert!(t.encode("émoji").is_err());
    }

    #[test]
    fn pad_and_bos_distinct() {
        let t = Tokenizer::new();
        assert_eq!(Tokenizer::PAD, 0);
        assert_eq!(Tokenizer::BOS, 63);
        assert_eq!(t.decode_one(Tokenizer::BOS).unwrap(), '#');
    }
}
