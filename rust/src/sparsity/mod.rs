//! Sparsification: activation calibration + Wanda scoring + mask management.
//!
//! Wanda (Sun et al. 2023), the paper's default Ψ: score(w_ij) =
//! |w_ij| · ‖X_j‖₂ with per-output-row comparison groups; the least
//! important (1−s) fraction per row is zeroed.  Calibration statistics come
//! from the `calib` artifact, which captures the activations entering each
//! linear site; the Wanda scores themselves run through the L1
//! `wanda_{m}x{n}` kernels, and the top-k threshold is host-side.
//! An N:M structured variant is included (paper mentions Wanda supports it).

use crate::data::{Batch, Batcher, Sample, Tokenizer};
use crate::model::{linear_keys, ParamSet};
use crate::runtime::{args::build_args, DeviceStore, ModelHyper, Runtime};
use crate::tensor::{Rng, Tensor};
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Per-site calibration statistics, keyed by base weight name + layer:
/// column L2 norms of the inputs to that linear layer, and (optionally)
/// the Gram matrix X^T X for GPTQ.
#[derive(Debug, Default)]
pub struct CalibStats {
    /// "wq/0" -> (in,) column norms
    pub norms: BTreeMap<String, Tensor>,
    /// "wq/0" -> (in, in) Gram (only when `with_gram`)
    pub grams: BTreeMap<String, Tensor>,
    pub tokens_seen: usize,
}

impl CalibStats {
    pub fn norm(&self, wkey: &str, layer: usize) -> Result<&Tensor> {
        self.norms
            .get(&format!("{wkey}/{layer}"))
            .ok_or_else(|| anyhow::anyhow!("no calib norms for {wkey}/{layer}"))
    }

    pub fn gram(&self, wkey: &str, layer: usize) -> Result<&Tensor> {
        self.grams
            .get(&format!("{wkey}/{layer}"))
            .ok_or_else(|| anyhow::anyhow!("no calib gram for {wkey}/{layer}"))
    }
}

/// Which activation-capture site feeds each linear weight.
fn site_of(wkey: &str) -> (&'static str, usize) {
    // (calib output name, output index in the calib artifact)
    match wkey {
        "wq" | "wk" | "wv" => ("xqkv", 1),
        "wo" => ("xo", 2),
        "wgate" | "wup" => ("xmlp", 3),
        "wdown" => ("xdown", 4),
        _ => panic!("not a linear key: {wkey}"),
    }
}

/// Run the calib artifact over `n_batches` random batches and accumulate
/// per-site column-square-sums (and Grams when `with_gram`).
#[allow(clippy::too_many_arguments)]
pub fn calibrate(
    rt: &Runtime,
    config: &str,
    device: &DeviceStore,
    adapters: &ParamSet,
    samples: &[Sample],
    tok: &Tokenizer,
    n_batches: usize,
    with_gram: bool,
    rng: &mut Rng,
) -> Result<CalibStats> {
    let hyper = rt.model(config)?.clone();
    let exe = rt.executable(config, "calib")?;
    let batcher = Batcher::new(samples, tok, hyper.seq_len, hyper.batch);
    let mut stats = CalibStats::default();
    // square-sum accumulators per site/layer
    let mut sq: BTreeMap<String, Vec<f64>> = BTreeMap::new();
    for _ in 0..n_batches {
        let batch: Batch = batcher.random_batch(rng)?;
        let args = build_args(&exe.spec, &[device], &[adapters], Some(&batch), &[])?;
        let outs = exe.run_mixed(&rt.client, &args)?;
        stats.tokens_seen += batch.batch * batch.seq;
        for site_idx in 1..=4 {
            let acts = &outs[site_idx]; // (L, T, dim)
            let (l_n, t_n, dim) =
                (acts.shape()[0], acts.shape()[1], acts.shape()[2]);
            let site_name = ["", "xqkv", "xo", "xmlp", "xdown"][site_idx];
            for l in 0..l_n {
                let key = format!("{site_name}/{l}");
                let acc = sq.entry(key.clone()).or_insert_with(|| vec![0.0; dim]);
                let base_off = l * t_n * dim;
                for t in 0..t_n {
                    let row = &acts.data()[base_off + t * dim..base_off + (t + 1) * dim];
                    for j in 0..dim {
                        acc[j] += (row[j] as f64) * (row[j] as f64);
                    }
                }
                if with_gram {
                    let gram = stats
                        .grams
                        .entry(key.clone())
                        .or_insert_with(|| Tensor::zeros(&[dim, dim]));
                    let layer_acts = Tensor::new(
                        &[t_n, dim],
                        acts.data()[base_off..base_off + t_n * dim].to_vec(),
                    )?;
                    layer_acts.accumulate_gram(gram);
                }
            }
        }
    }
    // convert square sums to norms, fan the site stats out to weight keys
    for wkey in linear_keys() {
        let (site, _) = site_of(wkey);
        for l in 0..hyper.n_layers {
            let skey = format!("{site}/{l}");
            let acc = &sq[&skey];
            let norms = Tensor::new(
                &[acc.len()],
                acc.iter().map(|&s| (s.sqrt()) as f32).collect(),
            )?;
            stats.norms.insert(format!("{wkey}/{l}"), norms);
            if with_gram {
                let g = stats.grams[&skey].clone();
                stats.grams.insert(format!("{wkey}/{l}"), g);
            }
        }
    }
    Ok(stats)
}

/// Per-row unstructured top-k mask from a score matrix: keep the
/// highest-scoring (1−s) fraction of each output row (Wanda's comparison
/// group = output row).
pub fn topk_row_mask(scores: &Tensor, sparsity: f64) -> Tensor {
    let (m, n) = (scores.rows(), scores.cols());
    let drop = ((sparsity * n as f64).round() as usize).min(n);
    let keep = n - drop;
    let mut mask = Tensor::zeros(&[m, n]);
    let mut idx: Vec<usize> = Vec::with_capacity(n);
    for i in 0..m {
        idx.clear();
        idx.extend(0..n);
        let row = scores.row(i);
        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap());
        for &j in idx.iter().take(keep) {
            mask.set2(i, j, 1.0);
        }
    }
    mask
}

/// N:M structured mask: in every group of `m_group` consecutive inputs keep
/// the `n_keep` highest-scoring (e.g. 2:4).
pub fn nm_mask(scores: &Tensor, n_keep: usize, m_group: usize) -> Result<Tensor> {
    let (rows, cols) = (scores.rows(), scores.cols());
    if cols % m_group != 0 {
        bail!("N:M mask: {cols} cols not divisible by group {m_group}");
    }
    let mut mask = Tensor::zeros(&[rows, cols]);
    let mut idx: Vec<usize> = Vec::with_capacity(m_group);
    for i in 0..rows {
        let row = scores.row(i);
        for g in (0..cols).step_by(m_group) {
            idx.clear();
            idx.extend(0..m_group);
            idx.sort_by(|&a, &b| row[g + b].partial_cmp(&row[g + a]).unwrap());
            for &j in idx.iter().take(n_keep) {
                mask.set2(i, g + j, 1.0);
            }
        }
    }
    Ok(mask)
}

/// Compute Wanda masks for every linear weight (stacked (L, out, in)),
/// scoring through the L1 wanda kernels.  Returns a ParamSet with keys
/// "mask_wq", ..., "mask_wdown".
pub fn wanda_masks(
    rt: &Runtime,
    base: &ParamSet,
    stats: &CalibStats,
    sparsity: f64,
    hyper: &ModelHyper,
) -> Result<ParamSet> {
    let mut masks = ParamSet::new();
    for wkey in linear_keys() {
        let w_stack = base.get(wkey)?;
        let (out, inp) = (w_stack.shape()[1], w_stack.shape()[2]);
        let exe = rt.shape_executable(&format!("wanda_{out}x{inp}"))?;
        let mut layers = Vec::new();
        for l in 0..hyper.n_layers {
            let w = w_stack.index0(l);
            let norms = stats.norm(wkey, l)?.clone();
            let outs = exe.run(&rt.client, &[w.into(), norms.into()])?;
            layers.push(topk_row_mask(&outs[0], sparsity));
        }
        masks.insert(&format!("mask_{wkey}"), Tensor::stack(&layers)?);
    }
    Ok(masks)
}

/// Host-only Wanda mask for one matrix (tests + fallback path).
pub fn wanda_mask_host(w: &Tensor, norms: &Tensor, sparsity: f64) -> Tensor {
    let (m, n) = (w.rows(), w.cols());
    let mut scores = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            scores.set2(i, j, w.at2(i, j).abs() * norms.data()[j]);
        }
    }
    topk_row_mask(&scores, sparsity)
}

/// Zero out the masked entries of every linear weight (in place).
pub fn apply_masks(base: &mut ParamSet, masks: &ParamSet) -> Result<()> {
    for wkey in linear_keys() {
        let masked = base.get(wkey)?.mul(masks.get(&format!("mask_{wkey}"))?)?;
        base.insert(wkey, masked);
    }
    Ok(())
}

/// Copy the base-weight masks of the *adapted* modules into adapter-mask
/// keys ("mask_q" etc.) for SparsePEFT runs.
pub fn adapter_masks_from(masks: &ParamSet, hyper: &ModelHyper) -> Result<ParamSet> {
    let mut out = ParamSet::new();
    for m in &hyper.mods {
        let wkey = ModelHyper::weight_key(m);
        out.insert(&format!("mask_{m}"), masks.get(&format!("mask_{wkey}"))?.clone());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_row_mask_exact_fraction() {
        let mut rng = Rng::new(1);
        let scores = Tensor::rand_uniform(&mut rng, &[8, 32], 0.0, 1.0);
        let mask = topk_row_mask(&scores, 0.5);
        for i in 0..8 {
            let kept: f32 = mask.row(i).iter().sum();
            assert_eq!(kept, 16.0);
        }
        // kept entries are the highest-scoring ones
        for i in 0..8 {
            let row_scores = scores.row(i);
            let min_kept = (0..32)
                .filter(|&j| mask.at2(i, j) == 1.0)
                .map(|j| row_scores[j])
                .fold(f32::INFINITY, f32::min);
            let max_dropped = (0..32)
                .filter(|&j| mask.at2(i, j) == 0.0)
                .map(|j| row_scores[j])
                .fold(f32::NEG_INFINITY, f32::max);
            assert!(min_kept >= max_dropped);
        }
    }

    #[test]
    fn topk_extremes() {
        let mut rng = Rng::new(2);
        let scores = Tensor::rand_uniform(&mut rng, &[2, 10], 0.0, 1.0);
        assert_eq!(topk_row_mask(&scores, 0.0).sparsity(), 0.0);
        assert_eq!(topk_row_mask(&scores, 1.0).sparsity(), 1.0);
    }

    #[test]
    fn nm_mask_2_of_4() {
        let mut rng = Rng::new(3);
        let scores = Tensor::rand_uniform(&mut rng, &[4, 16], 0.0, 1.0);
        let mask = nm_mask(&scores, 2, 4).unwrap();
        assert_eq!(mask.sparsity(), 0.5);
        for i in 0..4 {
            for g in (0..16).step_by(4) {
                let kept: f32 = (0..4).map(|j| mask.at2(i, g + j)).sum();
                assert_eq!(kept, 2.0);
            }
        }
        assert!(nm_mask(&scores, 2, 5).is_err());
    }

    #[test]
    fn wanda_host_prefers_high_norm_columns() {
        // |w| equal everywhere: mask decided purely by column norms
        let w = Tensor::ones(&[2, 4]);
        let norms = Tensor::new(&[4], vec![0.1, 5.0, 3.0, 0.2]).unwrap();
        let mask = wanda_mask_host(&w, &norms, 0.5);
        for i in 0..2 {
            assert_eq!(mask.at2(i, 1), 1.0);
            assert_eq!(mask.at2(i, 2), 1.0);
            assert_eq!(mask.at2(i, 0), 0.0);
            assert_eq!(mask.at2(i, 3), 0.0);
        }
    }
}
