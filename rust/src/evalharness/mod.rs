//! Evaluation harness — the lm-eval-harness analogue.
//!
//! Generative tasks: exact match over the masked answer positions under
//! teacher forcing (every answer token's argmax must be correct).
//! Multiple-choice tasks degenerate to the same rule with a single masked
//! position.  Batched through the `eval`/`eval_qa` artifacts; eval state
//! (adapters, rank config) is passed per call so NLS search can sweep
//! configurations against one device-resident base.

use crate::data::{Batcher, Sample, Task, Tokenizer};
use crate::model::ParamSet;
use crate::nls::{Config, SearchSpace};
use crate::runtime::{args::build_args, DeviceStore, Runtime};
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct EvalResult {
    pub correct: usize,
    pub total: usize,
    pub mean_loss: f64,
}

impl EvalResult {
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 { 0.0 } else { self.correct as f64 / self.total as f64 }
    }
}

/// Evaluate one adapter/rank state on a sample set.
///
/// `eval_kind` is "eval" or "eval_qa"; `device` holds base weights (+ QA
/// params when eval_qa); `host_sets` supply adapters/masks/rank params.
#[allow(clippy::too_many_arguments)]
pub fn evaluate(
    rt: &Runtime,
    config: &str,
    eval_kind: &str,
    device: &DeviceStore,
    host_sets: &[&ParamSet],
    samples: &[Sample],
    tok: &Tokenizer,
) -> Result<EvalResult> {
    let hyper = rt.model(config)?.clone();
    let exe = rt.executable(config, eval_kind)?;
    let mut batcher = Batcher::new(samples, tok, hyper.seq_len, hyper.batch);
    let (mut correct, mut total) = (0usize, 0usize);
    let mut loss_sum = 0.0f64;
    let mut loss_n = 0usize;
    while let Some(batch) = batcher.next_batch()? {
        let args = build_args(&exe.spec, &[device], host_sets, Some(&batch), &[])?;
        let outs = exe.run_mixed(&rt.client, &args)?;
        let logits = &outs[0]; // (B, S, V)
        let (b_n, s_n, v_n) = (batch.batch, batch.seq, hyper.vocab);
        for bi in 0..batch.real {
            let mut all_ok = true;
            let mut any = false;
            for si in 0..s_n {
                if batch.loss_mask[bi * s_n + si] == 0.0 {
                    continue;
                }
                any = true;
                let target = batch.targets[bi * s_n + si];
                let row = &logits.data()
                    [bi * s_n * v_n + si * v_n..bi * s_n * v_n + (si + 1) * v_n];
                // argmax
                let mut best = 0usize;
                for v in 1..v_n {
                    if row[v] > row[best] {
                        best = v;
                    }
                }
                // masked NLL for the loss metric
                let maxv = row[best];
                let logsum: f32 =
                    row.iter().map(|&x| (x - maxv).exp()).sum::<f32>().ln() + maxv;
                loss_sum += (logsum - row[target as usize]) as f64;
                loss_n += 1;
                if best != target as usize {
                    all_ok = false;
                }
            }
            if any {
                total += 1;
                if all_ok {
                    correct += 1;
                }
            }
        }
    }
    Ok(EvalResult {
        correct,
        total,
        mean_loss: if loss_n == 0 { 0.0 } else { loss_sum / loss_n as f64 },
    })
}

/// Evaluate one NLS configuration: realize rank masks, then `evaluate`.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_config(
    rt: &Runtime,
    config: &str,
    eval_kind: &str,
    device: &DeviceStore,
    adapters: &ParamSet,
    space: &SearchSpace,
    nls_cfg: &Config,
    samples: &[Sample],
    tok: &Tokenizer,
) -> Result<EvalResult> {
    let rank_params = space.realize(nls_cfg)?;
    evaluate(rt, config, eval_kind, device, &[adapters, &rank_params], samples, tok)
}

/// Macro-average accuracy over multiple task test sets (Tables 2-3 style).
pub struct MultiTaskResult {
    pub per_task: Vec<(Task, EvalResult)>,
}

impl MultiTaskResult {
    pub fn average(&self) -> f64 {
        if self.per_task.is_empty() {
            return 0.0;
        }
        self.per_task.iter().map(|(_, r)| r.accuracy()).sum::<f64>()
            / self.per_task.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_result_accuracy() {
        let r = EvalResult { correct: 3, total: 4, mean_loss: 0.5 };
        assert_eq!(r.accuracy(), 0.75);
        let z = EvalResult { correct: 0, total: 0, mean_loss: 0.0 };
        assert_eq!(z.accuracy(), 0.0);
    }
}
