//! Host tensor substrate: row-major f32 tensors with the operations the
//! coordinator needs (weight manipulation, Wanda scoring, GPTQ linear
//! algebra, metric reductions).  The *model math* never runs here — that is
//! the AOT-compiled XLA artifacts' job — but sparsification, quantization
//! and merging are coordinator-side transformations of host weights, so they
//! need a small, well-tested tensor library.

pub mod linalg;
pub mod rng;

pub use rng::Rng;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    // ------------------------------------------------------------------
    // constructors
    // ------------------------------------------------------------------

    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn ones(shape: &[usize]) -> Tensor {
        Tensor::full(shape, 1.0)
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![1], data: vec![v] }
    }

    /// N(0, std^2) init.
    pub fn randn(rng: &mut Rng, shape: &[usize], std: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: (0..n).map(|_| rng.normal() * std).collect() }
    }

    /// Uniform in [lo, hi).
    pub fn rand_uniform(rng: &mut Rng, shape: &[usize], lo: f32, hi: f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|_| lo + rng.next_f32() * (hi - lo)).collect(),
        }
    }

    // ------------------------------------------------------------------
    // accessors
    // ------------------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// 2-d element access (rows x cols).
    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 2);
        let c = self.shape[1];
        self.data[i * c + j] = v;
    }

    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        debug_assert_eq!(self.shape.len(), 2);
        self.shape[1]
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    // ------------------------------------------------------------------
    // shape ops
    // ------------------------------------------------------------------

    pub fn reshape(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Slice index `i` off the leading axis (copy) — e.g. layer `l` of a
    /// stacked (L, m, n) parameter.
    pub fn index0(&self, i: usize) -> Tensor {
        let inner: usize = self.shape[1..].iter().product();
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[i * inner..(i + 1) * inner].to_vec(),
        }
    }

    /// Write `t` into slot `i` of the leading axis.
    pub fn set_index0(&mut self, i: usize, t: &Tensor) {
        let inner: usize = self.shape[1..].iter().product();
        assert_eq!(inner, t.len(), "set_index0 shape mismatch");
        self.data[i * inner..(i + 1) * inner].copy_from_slice(&t.data);
    }

    /// Stack equal-shape tensors along a new leading axis.
    pub fn stack(parts: &[Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("stack of zero tensors");
        }
        let inner = parts[0].shape.clone();
        let mut data = Vec::with_capacity(parts.len() * parts[0].len());
        for p in parts {
            if p.shape != inner {
                bail!("stack shape mismatch: {:?} vs {:?}", p.shape, inner);
            }
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(&inner);
        Ok(Tensor { shape, data })
    }

    pub fn transpose2(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..m {
            for j in 0..n {
                out.data[j * m + i] = self.data[i * n + j];
            }
        }
        out
    }

    // ------------------------------------------------------------------
    // elementwise / reductions
    // ------------------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Result<Tensor> {
        if self.shape != other.shape {
            bail!("zip shape mismatch {:?} vs {:?}", self.shape, other.shape);
        }
        Ok(Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        })
    }

    pub fn add(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Result<Tensor> {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn sum(&self) -> f64 {
        self.data.iter().map(|&x| x as f64).sum()
    }

    pub fn mean(&self) -> f64 {
        if self.data.is_empty() { 0.0 } else { self.sum() / self.data.len() as f64 }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    /// Fraction of exactly-zero entries — the sparsity metric used all over
    /// the experiment harness.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0.0).count() as f64 / self.data.len() as f64
    }

    /// Column-wise L2 norms of a (rows, cols) matrix (Wanda's ||X||_2).
    pub fn col_norms(&self) -> Tensor {
        let (m, n) = (self.rows(), self.cols());
        let mut sums = vec![0.0f64; n];
        for i in 0..m {
            let row = self.row(i);
            for j in 0..n {
                sums[j] += (row[j] as f64) * (row[j] as f64);
            }
        }
        Tensor { shape: vec![n], data: sums.into_iter().map(|s| s.sqrt() as f32).collect() }
    }

    /// Accumulate X^T X (Gram/Hessian) of a (rows, cols) activation matrix
    /// into `h` ((cols, cols)) — the GPTQ calibration statistic.
    pub fn accumulate_gram(&self, h: &mut Tensor) {
        let (m, n) = (self.rows(), self.cols());
        assert_eq!(h.shape(), &[n, n]);
        for t in 0..m {
            let row = self.row(t).to_vec();
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                let hrow = &mut h.data[i * n..(i + 1) * n];
                for j in 0..n {
                    hrow[j] += ri * row[j];
                }
            }
        }
    }

    /// Relative Frobenius distance ||a-b|| / (||b|| + eps).
    pub fn rel_err(&self, other: &Tensor) -> f64 {
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (&a, &b) in self.data.iter().zip(&other.data) {
            num += ((a - b) as f64).powi(2);
            den += (b as f64).powi(2);
        }
        (num.sqrt()) / (den.sqrt() + 1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_reshape() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.at2(1, 2), 6.0);
        let r = t.clone().reshape(&[3, 2]).unwrap();
        assert_eq!(r.at2(2, 1), 6.0);
        assert!(t.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn stack_index_roundtrip() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(&[2, 2], vec![5., 6., 7., 8.]).unwrap();
        let s = Tensor::stack(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(s.shape(), &[2, 2, 2]);
        assert_eq!(s.index0(0), a);
        assert_eq!(s.index0(1), b);
        let mut s2 = s.clone();
        s2.set_index0(0, &b);
        assert_eq!(s2.index0(0), b);
    }

    #[test]
    fn transpose() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let tt = t.transpose2();
        assert_eq!(tt.shape(), &[3, 2]);
        assert_eq!(tt.at2(2, 1), 6.0);
    }

    #[test]
    fn sparsity_metric() {
        let t = Tensor::new(&[4], vec![0., 1., 0., 2.]).unwrap();
        assert_eq!(t.sparsity(), 0.5);
    }

    #[test]
    fn col_norms_match_manual() {
        let t = Tensor::new(&[2, 2], vec![3., 0., 4., 1.]).unwrap();
        let n = t.col_norms();
        assert!((n.data()[0] - 5.0).abs() < 1e-6);
        assert!((n.data()[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn gram_accumulation() {
        let x = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let mut h = Tensor::zeros(&[2, 2]);
        x.accumulate_gram(&mut h);
        // X^T X = [[10, 14], [14, 20]]
        assert_eq!(h.data(), &[10., 14., 14., 20.]);
    }

    #[test]
    fn elementwise_errors_on_mismatch() {
        let a = Tensor::zeros(&[2, 2]);
        let b = Tensor::zeros(&[4]);
        assert!(a.add(&b).is_err());
    }
}
