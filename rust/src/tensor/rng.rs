//! Deterministic RNG for reproducible experiments (no external deps).
//!
//! SplitMix64 for the integer stream + Box–Muller for normals.  Every
//! experiment in EXPERIMENTS.md records its seed; identical seeds reproduce
//! identical weights, masks, datasets and NLS sampling traces.

#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// cached second Box–Muller output
    spare: Option<f32>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    /// Derive an independent stream (e.g. per-layer init, per-task data).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58476D1CE4E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(hi >= lo);
        lo + (self.next_u64() % (hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let u1 = self.next_f32().max(1e-12);
        let u2 = self.next_f32();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f32::consts::PI * u2).sin_cos();
        self.spare = Some(r * s);
        r * c
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
            let y = r.range(-3, 3);
            assert!((-3..=3).contains(&y));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(42);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn forks_are_independent() {
        let mut r = Rng::new(9);
        let mut f1 = r.fork(1);
        let mut f2 = r.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
