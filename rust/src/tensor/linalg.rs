//! Dense linear algebra for the coordinator-side algorithms.
//!
//! GPTQ needs a damped Cholesky factorization + triangular inverse of the
//! calibration Hessian (Frantar et al. 2022); the eval harness and tests
//! need plain matmuls.  Hot loops are written cache-blocked over rows —
//! good enough for the (<= 2560)^2 matrices that occur here; the model math
//! itself always runs through XLA.

use super::Tensor;
use anyhow::{bail, Result};

/// C = A @ B for 2-d tensors (m,k) x (k,n).
///
/// Tiled like [`matmul_bt`]: blocked over (rows of A) x (rows of B) so a
/// block of B rows stays cache-resident while several A rows stream
/// against it.  Within a tile each A row first gathers its *nonzero*
/// coefficients (the zero-skip fast path — adapter/rank-masked and
/// pruned matrices are the common inputs here), then applies them four B
/// rows per pass, so the output row is traversed once per four rank-1
/// updates instead of once each.  Grouping changes FP summation order,
/// which is fine at the tolerances the callers use.
pub fn matmul(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape().len() != 2 || b.shape().len() != 2 || a.cols() != b.rows() {
        bail!("matmul shape mismatch {:?} x {:?}", a.shape(), b.shape());
    }
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut out = Tensor::zeros(&[m, n]);
    const BI: usize = 8; // A rows per tile
    const BP: usize = 64; // B rows per tile (~BP*n floats hot)
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + BI).min(m);
        let mut p0 = 0;
        while p0 < k {
            let p1 = (p0 + BP).min(k);
            for i in i0..i1 {
                let arow = a.row(i);
                // zero-skip: collect the tile's contributing terms once
                let mut nz = [0usize; BP];
                let mut cnt = 0;
                for p in p0..p1 {
                    if arow[p] != 0.0 {
                        nz[cnt] = p;
                        cnt += 1;
                    }
                }
                let orow = out.row_mut(i);
                let mut t = 0;
                while t + 4 <= cnt {
                    let (pa, pb, pc, pd) = (nz[t], nz[t + 1], nz[t + 2], nz[t + 3]);
                    let (a0, a1, a2, a3) = (arow[pa], arow[pb], arow[pc], arow[pd]);
                    let b0 = &b.data()[pa * n..(pa + 1) * n];
                    let b1 = &b.data()[pb * n..(pb + 1) * n];
                    let b2 = &b.data()[pc * n..(pc + 1) * n];
                    let b3 = &b.data()[pd * n..(pd + 1) * n];
                    for j in 0..n {
                        orow[j] +=
                            (a0 * b0[j] + a1 * b1[j]) + (a2 * b2[j] + a3 * b3[j]);
                    }
                    t += 4;
                }
                while t < cnt {
                    let p = nz[t];
                    let av = arow[p];
                    let brow = &b.data()[p * n..(p + 1) * n];
                    for j in 0..n {
                        orow[j] += av * brow[j];
                    }
                    t += 1;
                }
            }
            p0 = p1;
        }
        i0 = i1;
    }
    Ok(out)
}

/// C = A @ B^T for 2-d tensors (m,k) x (n,k) — the linear-layer convention.
///
/// Tiled over (rows of A) x (rows of B) so a block of B rows stays cache-
/// resident while several A rows stream against it, with a 4-accumulator
/// unrolled dot product (breaks the serial FP dependence chain; changes
/// summation order, which is fine at the tolerances the callers use).
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape().len() != 2 || b.shape().len() != 2 || a.cols() != b.cols() {
        bail!("matmul_bt shape mismatch {:?} x {:?}", a.shape(), b.shape());
    }
    let (m, k, n) = (a.rows(), a.cols(), b.rows());
    let mut out = Tensor::zeros(&[m, n]);
    const BI: usize = 8; // A rows per tile
    const BJ: usize = 64; // B rows per tile (~BJ*k floats hot)
    let mut i0 = 0;
    while i0 < m {
        let i1 = (i0 + BI).min(m);
        let mut j0 = 0;
        while j0 < n {
            let j1 = (j0 + BJ).min(n);
            for i in i0..i1 {
                let arow = a.row(i);
                let orow = out.row_mut(i);
                for j in j0..j1 {
                    let brow = b.row(j);
                    let mut acc = [0.0f32; 4];
                    let k4 = k - k % 4;
                    let mut p = 0;
                    while p < k4 {
                        acc[0] += arow[p] * brow[p];
                        acc[1] += arow[p + 1] * brow[p + 1];
                        acc[2] += arow[p + 2] * brow[p + 2];
                        acc[3] += arow[p + 3] * brow[p + 3];
                        p += 4;
                    }
                    let mut sum = (acc[0] + acc[1]) + (acc[2] + acc[3]);
                    for p in k4..k {
                        sum += arow[p] * brow[p];
                    }
                    orow[j] = sum;
                }
            }
            j0 = j1;
        }
        i0 = i1;
    }
    Ok(out)
}

/// In-place damped Cholesky decomposition H = L L^T (lower triangular
/// returned).  `damp` is added to the diagonal (GPTQ's percdamp * mean diag).
pub fn cholesky(h: &Tensor, damp: f32) -> Result<Tensor> {
    if h.shape().len() != 2 || h.rows() != h.cols() {
        bail!("cholesky wants square matrix, got {:?}", h.shape());
    }
    let n = h.rows();
    let mut l = Tensor::zeros(&[n, n]);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = h.at2(i, j) as f64 + if i == j { damp as f64 } else { 0.0 };
            for p in 0..j {
                sum -= l.at2(i, p) as f64 * l.at2(j, p) as f64;
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("cholesky failed at {i}: non-PD matrix (sum={sum}); raise damping");
                }
                l.set2(i, j, sum.sqrt() as f32);
            } else {
                l.set2(i, j, (sum / l.at2(j, j) as f64) as f32);
            }
        }
    }
    Ok(l)
}

/// Invert a lower-triangular matrix by forward substitution.
pub fn tri_inverse_lower(l: &Tensor) -> Result<Tensor> {
    let n = l.rows();
    let mut inv = Tensor::zeros(&[n, n]);
    for i in 0..n {
        if l.at2(i, i) == 0.0 {
            bail!("singular triangular matrix at {i}");
        }
        inv.set2(i, i, 1.0 / l.at2(i, i));
        for j in 0..i {
            let mut sum = 0.0f64;
            for p in j..i {
                sum += l.at2(i, p) as f64 * inv.at2(p, j) as f64;
            }
            inv.set2(i, j, (-sum / l.at2(i, i) as f64) as f32);
        }
    }
    Ok(inv)
}

/// GPTQ's inverse-Hessian Cholesky: given H (n,n), compute
/// `Hinv_chol = Cholesky(H^{-1})^T` (upper triangular), via
/// H = L L^T  =>  H^{-1} = L^{-T} L^{-1}  =>  chol(H^{-1}) = L^{-T}.
/// Returns the *upper* triangular factor U with H^{-1} = U^T U ... more
/// precisely the GPTQ recursion needs U = chol(H^{-1}, upper=True), i.e.
/// U upper-triangular with H^{-1} = U^T U?  The standard implementation uses
/// H^{-1} = U U^T with U = L^{-T}; row `i`'s diagonal entry U[i,i] and the
/// trailing row segment U[i, i:] drive the error feedback.
pub fn gptq_hinv_factor(h: &Tensor, percdamp: f32) -> Result<Tensor> {
    let n = h.rows();
    let mut mean_diag = 0.0f64;
    for i in 0..n {
        mean_diag += h.at2(i, i) as f64;
    }
    let damp = (percdamp as f64 * mean_diag / n as f64).max(1e-8) as f32;
    let l = cholesky(h, damp)?;
    let linv = tri_inverse_lower(&l)?;
    // U = L^{-T}: upper triangular, H^{-1} = U U^T? check: H^{-1} =
    // (L L^T)^{-1} = L^{-T} L^{-1} = U (U^T)?  with U = L^{-T}:
    // U U^T = L^{-T} L^{-1} = H^{-1}.  Cholesky-of-inverse in "upper" form.
    Ok(linv.transpose2())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn matmul_small() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let b = Tensor::new(&[3, 2], vec![7., 8., 9., 10., 11., 12.]).unwrap();
        let c = matmul(&a, &b).unwrap();
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_matches_naive_on_odd_shapes_and_sparse_inputs() {
        // shapes straddle the BI/BP tile edges and the 4-term remainder;
        // half the A entries are zeroed so the gather fast path is hit
        let naive = |a: &Tensor, b: &Tensor| {
            let (m, k, n) = (a.rows(), a.cols(), b.cols());
            let mut out = Tensor::zeros(&[m, n]);
            for i in 0..m {
                for p in 0..k {
                    for j in 0..n {
                        let v = out.at2(i, j) + a.at2(i, p) * b.at2(p, j);
                        out.set2(i, j, v);
                    }
                }
            }
            out
        };
        let mut rng = Rng::new(13);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (9, 67, 7), (17, 130, 3)] {
            let mut a = Tensor::randn(&mut rng, &[m, k], 1.0);
            for (i, x) in a.data_mut().iter_mut().enumerate() {
                if i % 2 == 0 {
                    *x = 0.0;
                }
            }
            let b = Tensor::randn(&mut rng, &[k, n], 1.0);
            let got = matmul(&a, &b).unwrap();
            let want = naive(&a, &b);
            for (x, y) in got.data().iter().zip(want.data()) {
                assert!((x - y).abs() < 1e-3, "({m},{k},{n}): {x} vs {y}");
            }
        }
    }

    #[test]
    fn matmul_bt_matches_matmul() {
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&mut rng, &[4, 6], 1.0);
        let b = Tensor::randn(&mut rng, &[5, 6], 1.0);
        let c1 = matmul_bt(&a, &b).unwrap();
        let c2 = matmul(&a, &b.transpose2()).unwrap();
        for (x, y) in c1.data().iter().zip(c2.data()) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::new(7);
        let n = 8;
        let x = Tensor::randn(&mut rng, &[16, n], 1.0);
        let mut h = Tensor::zeros(&[n, n]);
        x.accumulate_gram(&mut h);
        let l = cholesky(&h, 0.01).unwrap();
        let rec = matmul_bt(&l, &l).unwrap(); // L L^T
        for i in 0..n {
            for j in 0..n {
                let want = h.at2(i, j) + if i == j { 0.01 } else { 0.0 };
                assert!((rec.at2(i, j) - want).abs() < 1e-2,
                    "({i},{j}): {} vs {want}", rec.at2(i, j));
            }
        }
    }

    #[test]
    fn tri_inverse_is_inverse() {
        let mut rng = Rng::new(9);
        let n = 6;
        let x = Tensor::randn(&mut rng, &[12, n], 1.0);
        let mut h = Tensor::zeros(&[n, n]);
        x.accumulate_gram(&mut h);
        let l = cholesky(&h, 0.05).unwrap();
        let linv = tri_inverse_lower(&l).unwrap();
        let id = matmul(&linv, &l).unwrap();
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((id.at2(i, j) - want).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn hinv_factor_is_upper_and_reconstructs_inverse() {
        let mut rng = Rng::new(11);
        let n = 5;
        let x = Tensor::randn(&mut rng, &[20, n], 1.0);
        let mut h = Tensor::zeros(&[n, n]);
        x.accumulate_gram(&mut h);
        let u = gptq_hinv_factor(&h, 0.01).unwrap();
        // upper triangular
        for i in 0..n {
            for j in 0..i {
                assert_eq!(u.at2(i, j), 0.0);
            }
        }
        // U U^T ~= H^{-1}  =>  H (U U^T) ~= I  (with damping slack)
        let uut = matmul_bt(&u, &u).unwrap();
        let hu = matmul(&h, &uut).unwrap();
        for i in 0..n {
            assert!((hu.at2(i, i) - 1.0).abs() < 0.05, "diag {}", hu.at2(i, i));
        }
    }

    #[test]
    fn cholesky_rejects_non_pd() {
        let h = Tensor::new(&[2, 2], vec![1., 2., 2., 1.]).unwrap(); // indefinite
        assert!(cholesky(&h, 0.0).is_err());
    }
}
