//! Pipeline orchestration — the four Figure-2 configurations end to end.
//!
//!   base ckpt → [Wanda sparsify] → [GPTQ quantize] → NLS/LoRA fine-tune
//!            → [merge (SparsePEFT Eq. 2 / QA-SparsePEFT Eq. 3)] → eval
//!
//! `prepare` produces the frozen model state a Method trains against;
//! `finetune` runs the adapter loop; `merged_state` folds the adapters back
//! and verifies the paper's mergeability criteria (sparsity preserved,
//! precision preserved); `evaluate_state` scores any of these states.

use crate::data::{Sample, Task, Tokenizer};
use crate::evalharness::{evaluate, EvalResult};
use crate::model::checkpoint::PackedTensor;
use crate::model::{checkpoint, init_adapters, linear_keys, ParamSet};
use crate::nls::{Config, SearchSpace};
use crate::peft::{merge_qa, merge_sparsepeft, Method};
use crate::quant::pack::{pack_int4_stack, unpack_int4_stack};
use crate::quant::{quantize_model, qmax, BITS};
use crate::runtime::{DeviceStore, ModelHyper, Runtime};
use crate::serve::AdapterEntry;
use crate::sparsity::{adapter_masks_from, apply_masks, calibrate, wanda_masks, CalibStats};
use crate::tensor::{Rng, Tensor};
use crate::train::{upload, LossCurve, TrainOpts, Trainer};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Frozen model state one Method fine-tunes against.
pub struct Prepared {
    pub hyper: ModelHyper,
    pub method: Method,
    /// base weights with sparsity/fake-quant applied (artifact values)
    pub base: ParamSet,
    /// mask_w* stacks for every linear weight (all-ones if dense)
    pub weight_masks: ParamSet,
    /// mask_q.. adapter masks (ones unless sparsity-aware)
    pub adapter_masks: ParamSet,
    /// qscales_/qzeros_/qmax (methods with quantized base)
    pub qa: Option<ParamSet>,
    /// INT4 codes per linear weight (storage metrics)
    pub codes: Option<ParamSet>,
    pub stats: Option<CalibStats>,
    pub sparsity: f64,
}

impl Prepared {
    /// Everything uploaded to the device for train/eval.
    pub fn frozen_set(&self) -> Result<ParamSet> {
        let mut f = ParamSet::new();
        for (n, t) in self.base.iter() {
            f.insert(n, t.clone());
        }
        for (n, t) in self.adapter_masks.iter() {
            f.insert(n, t.clone());
        }
        if let Some(qa) = &self.qa {
            for m in &self.hyper.mods {
                f.insert(&format!("qscales_{m}"), qa.get(&format!("qscales_{m}"))?.clone());
                f.insert(&format!("qzeros_{m}"), qa.get(&format!("qzeros_{m}"))?.clone());
            }
            f.insert("qmax", qa.get("qmax")?.clone());
        }
        Ok(f)
    }

    /// Measured sparsity of the adapted base weights.
    pub fn measured_sparsity(&self) -> f64 {
        self.base.sparsity_of(&linear_keys())
    }
}

/// All-ones adapter masks for dense methods.
pub fn dense_adapter_masks(hyper: &ModelHyper) -> ParamSet {
    let mut p = ParamSet::new();
    for m in &hyper.mods {
        let (out, inp) = hyper.mod_dims(m);
        p.insert(&format!("mask_{m}"), Tensor::ones(&[hyper.n_layers, out, inp]));
    }
    p
}

/// Build the frozen state for `method` from a pretrained base.
#[allow(clippy::too_many_arguments)]
pub fn prepare(
    rt: &Runtime,
    config: &str,
    pretrained: &ParamSet,
    method: Method,
    sparsity: f64,
    calib_samples: &[Sample],
    tok: &Tokenizer,
    calib_batches: usize,
    rng: &mut Rng,
) -> Result<Prepared> {
    let hyper = rt.model(config)?.clone();
    let mut base = pretrained.clone();

    // calibration runs on the *dense* pretrained model (Wanda convention)
    let needs_calib = sparsity > 0.0 || method.quantized_base();
    let stats = if needs_calib {
        let mut dev = DeviceStore::new();
        upload(rt, &mut dev, &base)?;
        // calib artifact wants adapter inputs: pass a no-op adapter
        // (zero A, full-rank masks realized explicitly)
        let mut noop = init_adapters(&hyper, rng, 2.0 * hyper.r_max as f32);
        for m in &hyper.mods {
            let a = noop.get(&format!("a_{m}"))?.clone();
            noop.insert(&format!("a_{m}"), Tensor::zeros(a.shape()));
        }
        let space = SearchSpace::default_for(&hyper, 1.0);
        for (n, t) in space.realize(&space.max_config())?.iter() {
            noop.insert(n, t.clone());
        }
        Some(calibrate(rt, config, &dev, &noop, calib_samples, tok,
                       calib_batches, method.quantized_base(), rng)?)
    } else {
        None
    };

    // 1. Wanda sparsification
    let weight_masks = if sparsity > 0.0 {
        let masks = wanda_masks(rt, &base, stats.as_ref().unwrap(), sparsity, &hyper)?;
        apply_masks(&mut base, &masks)?;
        masks
    } else {
        let mut p = ParamSet::new();
        for wkey in linear_keys() {
            p.insert(&format!("mask_{wkey}"), Tensor::ones(base.get(wkey)?.shape()));
        }
        p
    };

    // 2. GPTQ quantization (sparsity-preserving)
    let (qa, codes) = if method.quantized_base() {
        let stats_ref = stats.as_ref().unwrap();
        let masks_opt = if sparsity > 0.0 { Some(&weight_masks) } else { None };
        let (qa, codes) = quantize_model(
            &mut base,
            |wkey, l| Ok(stats_ref.gram(wkey, l)?.clone()),
            masks_opt,
            &hyper,
            true,
        )?;
        (Some(qa), Some(codes))
    } else {
        (None, None)
    };

    // 3. adapter masks (Eq. 1) only for sparsity-aware methods
    let adapter_masks = if method.sparsity_aware() {
        adapter_masks_from(&weight_masks, &hyper)?
    } else {
        dense_adapter_masks(&hyper)
    };

    Ok(Prepared {
        hyper,
        method,
        base,
        weight_masks,
        adapter_masks,
        qa,
        codes,
        stats,
        sparsity,
    })
}

/// Run the fine-tuning loop; returns the trainer (holding tuned adapters)
/// and the loss curve.
pub fn finetune<'a>(
    rt: &'a Runtime,
    config: &str,
    prepared: &Prepared,
    space: SearchSpace,
    samples: &[Sample],
    tok: &Tokenizer,
    opts: &TrainOpts,
) -> Result<(Trainer<'a>, LossCurve)> {
    let hyper = prepared.hyper.clone();
    let mut rng = Rng::new(opts.seed ^ 0xF1D0);
    let adapters = init_adapters(&hyper, &mut rng, space.alpha);
    let frozen = prepared.frozen_set()?;
    let mut trainer = Trainer::new(rt, config, prepared.method, &frozen,
                                   adapters, space, opts.seed)?;
    trainer.fixed_rank = opts.fixed_rank;
    let curve = trainer.train(samples, tok, opts)?;
    Ok((trainer, curve))
}

/// The tuned adapter state one tenant serves with: just `a_`/`b_`.  The
/// adapter masks are a property of the shared frozen base (frozen_set
/// uploads them device-resident, and build_args resolves device buffers
/// first), so shipping them per tenant would be dead weight — the whole
/// point of base+adapter serving is that the per-tenant payload is small.
fn servable_adapters(trainer: &Trainer) -> ParamSet {
    let mut adapters = ParamSet::new();
    for (n, t) in trainer.adapters.iter() {
        if n.starts_with("a_") || n.starts_with("b_") {
            adapters.insert(n, t.clone());
        }
    }
    adapters
}

/// Export a tuned adapter (+ NLS rank configuration at `cfg`) as a
/// servable checkpoint for the multi-tenant registry (`sqft serve
/// --adapters DIR`).
pub fn export_adapter(
    prepared: &Prepared,
    trainer: &Trainer,
    cfg: &Config,
    config_name: &str,
    adapter_id: &str,
    path: &Path,
) -> Result<()> {
    let rank_params = trainer.space.realize(cfg)?;
    checkpoint::save_adapter(
        path,
        &servable_adapters(trainer),
        &rank_params,
        config_name,
        prepared.method.eval_kind(),
        adapter_id,
        prepared.method.cli_name(),
        prepared.sparsity,
    )
}

/// Fine-tune `n` tenant adapters over one prepared base (distinct seeds,
/// so each tenant converges to different adapter weights) and return
/// registry entries ready to serve.  Deployed rank config follows the
/// paper's convention: heuristic for NLS methods, max for LoRA.
#[allow(clippy::too_many_arguments)]
pub fn tenant_adapters(
    rt: &Runtime,
    config: &str,
    prepared: &Prepared,
    n: usize,
    samples: &[Sample],
    tok: &Tokenizer,
    steps: usize,
    base_seed: u64,
) -> Result<Vec<AdapterEntry>> {
    let mut out = Vec::new();
    for i in 0..n {
        let (choices, alpha) = default_space_for(&prepared.hyper);
        let space = SearchSpace::new(&prepared.hyper, choices, alpha)?;
        let opts = TrainOpts {
            steps,
            lr: 1e-3,
            log_every: steps.max(1),
            seed: base_seed.wrapping_add(i as u64),
            fixed_rank: false,
        };
        let (trainer, _) = finetune(rt, config, prepared, space, samples, tok, &opts)?;
        let cfg = if prepared.method.uses_nls() {
            trainer.space.heuristic_config()
        } else {
            trainer.space.max_config()
        };
        out.push(AdapterEntry {
            id: format!("tenant{i}"),
            eval_kind: prepared.method.eval_kind().to_string(),
            host_sets: vec![servable_adapters(&trainer), trainer.space.realize(&cfg)?],
        });
    }
    Ok(out)
}

/// Evaluate (base + adapters at `cfg`) — the *unmerged* accuracy.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_unmerged(
    rt: &Runtime,
    config: &str,
    prepared: &Prepared,
    trainer: &Trainer,
    cfg: &Config,
    samples: &[Sample],
    tok: &Tokenizer,
) -> Result<EvalResult> {
    let rank_params = trainer.space.realize(cfg)?;
    evaluate(rt, config, prepared.method.eval_kind(), &trainer.device,
             &[&trainer.adapters, &rank_params], samples, tok)
}

/// Evaluate the base model with no-op adapters ("w/o tune" rows).
pub fn evaluate_base(
    rt: &Runtime,
    config: &str,
    prepared: &Prepared,
    samples: &[Sample],
    tok: &Tokenizer,
) -> Result<EvalResult> {
    let hyper = prepared.hyper.clone();
    let mut rng = Rng::new(1);
    let adapters = init_adapters(&hyper, &mut rng, 1.0); // B=0 ⇒ no-op
    let space = SearchSpace::default_for(&hyper, 1.0);
    let rank_params = space.realize(&space.max_config())?;
    let mut dev = DeviceStore::new();
    upload(rt, &mut dev, &prepared.frozen_set()?)?;
    // base eval always goes through the plain eval artifact: the base
    // weights already carry fake-quant values when quantized
    evaluate(rt, config, "eval", &dev, &[&adapters, &rank_params], samples, tok)
}

/// The merged model state (paper Eq. 2 / Eq. 3) + mergeability checks.
pub struct MergedState {
    pub base: ParamSet,
    pub codes: Option<ParamSet>,
    /// sparsity of the adapted weights before/after merging
    pub sparsity_before: f64,
    pub sparsity_after: f64,
}

/// Fold the tuned adapters (at `cfg`) into the base weights.
pub fn merged_state(
    prepared: &Prepared,
    trainer: &Trainer,
    cfg: &Config,
) -> Result<MergedState> {
    if !prepared.method.mergeable() {
        bail!("{} is not mergeable without losing sparsity or precision \
               (paper Fig. 1); refusing", prepared.method.name());
    }
    let hyper = prepared.hyper.clone();
    let mut base = prepared.base.clone();
    let sparsity_before = base.sparsity_of(&linear_keys());
    // adapters at the deployed rank configuration
    let rank_params = trainer.space.realize(cfg)?;
    let mut adapters = trainer.adapters.clone();
    for (n, t) in prepared.adapter_masks.iter() {
        adapters.insert(n, t.clone());
    }
    for (n, t) in rank_params.iter() {
        adapters.insert(n, t.clone());
    }
    let codes = match prepared.method {
        Method::SparsePeft => {
            merge_sparsepeft(&mut base, &adapters, &hyper)?;
            None
        }
        Method::QaSparsePeft => {
            let qa = prepared.qa.as_ref().expect("QA method has quant params");
            Some(merge_qa(&mut base, &adapters, qa, &hyper, qmax(BITS))?)
        }
        _ => unreachable!(),
    };
    let sparsity_after = base.sparsity_of(&linear_keys());
    Ok(MergedState { base, codes, sparsity_before, sparsity_after })
}

/// A merged quantized-base model in its *final* numerical format: packed
/// INT4 codes + shared group params for every linear weight, f32 only for
/// embed/norms.  This is what `pipeline --out` persists for QA-SparsePEFT
/// (true 4-bit on disk, not dequantized f32) and what the INT4-resident
/// serving engine uploads — the paper's "INT4 Final Precision" column made
/// real end to end.
pub struct Int4Model {
    /// model config the codes were produced against
    pub config: String,
    /// embed/final_ln/ln1/ln2 plus qscales_<wkey>/qzeros_<wkey> stacks
    pub params: ParamSet,
    /// packed_<wkey> → two-nibble codes for every linear weight stack
    pub packed: BTreeMap<String, PackedTensor>,
}

impl Int4Model {
    /// Total bytes this model keeps resident when serving: packed codes as
    /// u8 plus everything in `params` as f32.  The exact byte count the
    /// INT4 engine uploads (`BENCH_int4_serving.json` reads it).
    pub fn resident_bytes(&self) -> usize {
        self.params.total_bytes() + self.packed.values().map(|p| p.data.len()).sum::<usize>()
    }

    /// Reconstruct the dense f32 base (the fake-quant serving values) by
    /// unpacking and dequantizing every linear stack.  `(q - z) * s` here
    /// is the same f32 arithmetic `fake_quant_host` ran at merge time, so
    /// the result is bit-identical to the merged base the codes came from
    /// (asserted in tests) — the fallback path for runtimes without the
    /// eval_int4 artifact, and the equivalence oracle.
    pub fn dequant_base(&self) -> Result<ParamSet> {
        let mut base = ParamSet::new();
        for (n, t) in self.params.iter() {
            if !n.starts_with("qscales_") && !n.starts_with("qzeros_") {
                base.insert(n, t.clone());
            }
        }
        for wkey in linear_keys() {
            let p = self
                .packed
                .get(&format!("packed_{wkey}"))
                .with_context(|| format!("int4 model missing 'packed_{wkey}'"))?;
            let codes = unpack_int4_stack(&p.data, &p.shape)?;
            let scales = self.params.get(&format!("qscales_{wkey}"))?;
            let zeros = self.params.get(&format!("qzeros_{wkey}"))?;
            let (l, out, inp) = (p.shape[0], p.shape[1], p.shape[2]);
            let g = inp / p.group_size;
            if scales.shape() != [l, out, g] || zeros.shape() != [l, out, g] {
                bail!(
                    "int4 model '{wkey}': group params {:?}/{:?} mismatch codes {:?} (gs {})",
                    scales.shape(), zeros.shape(), p.shape, p.group_size
                );
            }
            let mut w = Tensor::zeros(&p.shape);
            let (cd, sd, zd) = (codes.data(), scales.data(), zeros.data());
            let wd = w.data_mut();
            for li in 0..l {
                for i in 0..out {
                    let row = (li * out + i) * inp;
                    let grow = (li * out + i) * g;
                    for j in 0..inp {
                        let q = cd[row + j];
                        let s = sd[grow + j / p.group_size];
                        let z = zd[grow + j / p.group_size];
                        wd[row + j] = (q - z) * s;
                    }
                }
            }
            base.insert(wkey, w);
        }
        Ok(base)
    }
}

/// Assemble the true-INT4 model from a prepared + merged quantized-base
/// run: adapted modules take their *re-quantized* merge codes (Eq. 3 on
/// `W + L`), non-adapted linears (wo, wgate) keep their GPTQ codes from
/// `prepare`, and every stack shares the base model's group params.
pub fn int4_model(prepared: &Prepared, merged: &MergedState) -> Result<Int4Model> {
    if !prepared.method.quantized_base() {
        bail!("{} has no INT4 base; nothing to pack", prepared.method.name());
    }
    let merged_codes = merged
        .codes
        .as_ref()
        .context("merged state carries no INT4 codes (not a QA merge?)")?;
    let prep_codes = prepared.codes.as_ref().context("prepare produced no INT4 codes")?;
    let qa = prepared.qa.as_ref().context("prepare produced no quant params")?;
    let hyper = &prepared.hyper;
    // mod → weight key ("q" → "wq"): adapted stacks use the merge codes
    let adapted: BTreeMap<&str, &str> = hyper
        .mods
        .iter()
        .map(|m| (ModelHyper::weight_key(m), m.as_str()))
        .collect();
    let mut params = ParamSet::new();
    for n in ["embed", "final_ln", "ln1", "ln2"] {
        params.insert(n, merged.base.get(n)?.clone());
    }
    let mut packed = BTreeMap::new();
    for wkey in linear_keys() {
        let codes = match adapted.get(wkey) {
            Some(m) => merged_codes.get(&format!("codes_{m}"))?,
            None => prep_codes.get(&format!("codes_{wkey}"))?,
        };
        let p = PackedTensor {
            shape: codes.shape().to_vec(),
            group_size: hyper.group_size,
            data: pack_int4_stack(codes)?,
        };
        p.validate(wkey)?;
        packed.insert(format!("packed_{wkey}"), p);
        params.insert(&format!("qscales_{wkey}"), qa.get(&format!("qscales_{wkey}"))?.clone());
        params.insert(&format!("qzeros_{wkey}"), qa.get(&format!("qzeros_{wkey}"))?.clone());
    }
    Ok(Int4Model { config: hyper.name.clone(), params, packed })
}

/// Persist an INT4 model: packed codes in the checkpoint's packed section
/// (true 4-bit on disk), group params + embed/norms as f32.
pub fn save_int4_model(
    model: &Int4Model,
    path: &Path,
    mut extra_meta: Vec<(&str, Json)>,
) -> Result<()> {
    let mut meta = vec![
        ("kind", Json::Str("int4-model".into())),
        ("config", Json::Str(model.config.clone())),
    ];
    meta.append(&mut extra_meta);
    checkpoint::save_packed(&model.params, &model.packed, path, Json::obj(meta))
}

/// Load an INT4 model checkpoint written by [`save_int4_model`].
pub fn load_int4_model(path: &Path) -> Result<Int4Model> {
    let (params, packed, meta) = checkpoint::load_packed(path)?;
    let kind = meta.get("kind").and_then(|k| k.as_str().ok()).unwrap_or("");
    if kind != "int4-model" {
        bail!("{path:?} is not an INT4 model checkpoint (kind '{kind}')");
    }
    let config = meta.req("config")?.as_str()?.to_string();
    let model = Int4Model { config, params, packed };
    // every linear stack must be present and consistent with its params
    for wkey in linear_keys() {
        let p = model
            .packed
            .get(&format!("packed_{wkey}"))
            .with_context(|| format!("{path:?}: missing packed stack for '{wkey}'"))?;
        p.validate(wkey)?;
        if p.shape.len() != 3 {
            bail!("{path:?}: packed '{wkey}' is not a (L, out, in) stack");
        }
        let g = p.shape[2] / p.group_size;
        let want = [p.shape[0], p.shape[1], g];
        let sc = model.params.get(&format!("qscales_{wkey}"))?;
        let ze = model.params.get(&format!("qzeros_{wkey}"))?;
        if sc.shape() != want || ze.shape() != want {
            bail!("{path:?}: group params for '{wkey}' mismatch the packed shape");
        }
    }
    Ok(model)
}

/// Evaluate a merged state (zero adapters on the merged weights).
pub fn evaluate_merged(
    rt: &Runtime,
    config: &str,
    prepared: &Prepared,
    merged: &MergedState,
    samples: &[Sample],
    tok: &Tokenizer,
) -> Result<EvalResult> {
    let hyper = prepared.hyper.clone();
    let mut rng = Rng::new(1);
    let adapters = init_adapters(&hyper, &mut rng, 1.0); // B=0 ⇒ no-op
    let space = SearchSpace::default_for(&hyper, 1.0);
    let rank_params = space.realize(&space.max_config())?;
    let mut frozen = ParamSet::new();
    for (n, t) in merged.base.iter() {
        frozen.insert(n, t.clone());
    }
    for (n, t) in dense_adapter_masks(&hyper).iter() {
        frozen.insert(n, t.clone());
    }
    let mut dev = DeviceStore::new();
    upload(rt, &mut dev, &frozen)?;
    evaluate(rt, config, "eval", &dev, &[&adapters, &rank_params], samples, tok)
}

/// Convenience bundle for the table harness: run one (method, sparsity)
/// cell end to end and report everything the paper's tables need.
pub struct CellResult {
    pub method: Method,
    pub sparsity: f64,
    pub accuracy: f64,
    pub merged_accuracy: Option<f64>,
    pub sparsity_preserved: Option<bool>,
    pub loss_curve: LossCurve,
}

#[allow(clippy::too_many_arguments)]
pub fn run_cell(
    rt: &Runtime,
    config: &str,
    pretrained: &ParamSet,
    method: Method,
    sparsity: f64,
    train_samples: &[Sample],
    test_samples: &[Sample],
    tok: &Tokenizer,
    space_choices: Vec<usize>,
    alpha: f32,
    opts: &TrainOpts,
) -> Result<CellResult> {
    let mut rng = Rng::new(opts.seed);
    let prepared = prepare(rt, config, pretrained, method, sparsity,
                           train_samples, tok, 4, &mut rng)?;
    let hyper = prepared.hyper.clone();
    let space = SearchSpace::new(&hyper, space_choices, alpha)?;
    let (trainer, curve) = finetune(rt, config, &prepared, space, train_samples,
                                    tok, opts)?;
    // deployed config: paper's heuristic (median) for NLS, max for LoRA
    let cfg = if method.uses_nls() {
        trainer.space.heuristic_config()
    } else {
        trainer.space.max_config()
    };
    let acc = evaluate_unmerged(rt, config, &prepared, &trainer, &cfg,
                                test_samples, tok)?;
    let (merged_acc, preserved) = if method.mergeable() {
        let merged = merged_state(&prepared, &trainer, &cfg)?;
        let macc = evaluate_merged(rt, config, &prepared, &merged,
                                   test_samples, tok)?;
        (Some(macc.accuracy()),
         Some(merged.sparsity_after >= merged.sparsity_before - 1e-9))
    } else {
        (None, None)
    };
    Ok(CellResult {
        method,
        sparsity,
        accuracy: acc.accuracy(),
        merged_accuracy: merged_acc,
        sparsity_preserved: preserved,
        loss_curve: curve,
    })
}

/// Shared experiment defaults per task family.
pub fn default_space_for(hyper: &ModelHyper) -> (Vec<usize>, f32) {
    let r = hyper.r_max;
    (vec![r / 2, (3 * r) / 4, r], 2.0 * r as f32)
}

/// Standard dataset sizes for the table harness.
pub fn standard_datasets(task: Task, seed: u64) -> crate::data::Dataset {
    let n_val = if task.has_validation() { 200 } else { 0 };
    crate::data::Dataset::generate(task, 4000, n_val, 400, seed)
}
