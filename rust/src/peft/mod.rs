//! PEFT state + merging — the paper's core contribution, host side.
//!
//! Six fine-tuning methods are reproduced (paper Tables 1-3):
//!   LoRA          dense adapter on sparse FP16 base      (not mergeable)
//!   Shears        NLS adapter on sparse FP16 base        (not mergeable)
//!   SparsePEFT    NLS adapter ⊙ mask on sparse base      (mergeable, Eq. 1-2)
//!   GPTQ+LoRA     dense adapter on INT4 base             (not mergeable)
//!   SQFT          NLS adapter on INT4 base               (not mergeable)
//!   QA-SparsePEFT NLS masked adapter, shared scales      (mergeable, Eq. 3-4)
//!
//! "Mergeable" follows the paper's criterion: merging must lose neither
//! accuracy nor sparsity nor numerical precision.  `merge_sparsepeft`
//! realizes Eq. 2 and `merge_qa` Eq. 3-4; property tests assert bit-exact
//! equivalence with the (un-merged) training-time forward.

use crate::model::ParamSet;
use crate::runtime::ModelHyper;
use crate::tensor::linalg::matmul;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Fine-tuning method selector (drives pipeline + table harness).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Lora,
    Shears,
    SparsePeft,
    GptqLora,
    Sqft,
    QaSparsePeft,
}

impl Method {
    pub fn all() -> [Method; 6] {
        [Method::Lora, Method::Shears, Method::SparsePeft,
         Method::GptqLora, Method::Sqft, Method::QaSparsePeft]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Lora => "LoRA",
            Method::Shears => "Shears",
            Method::SparsePeft => "SQFT + SparsePEFT",
            Method::GptqLora => "GPTQ + LoRA",
            Method::Sqft => "SQFT",
            Method::QaSparsePeft => "SQFT + QA-SparsePEFT",
        }
    }

    pub fn from_name(s: &str) -> Option<Method> {
        match s {
            "lora" => Some(Method::Lora),
            "shears" => Some(Method::Shears),
            "sparsepeft" => Some(Method::SparsePeft),
            "gptq-lora" => Some(Method::GptqLora),
            "sqft" => Some(Method::Sqft),
            "qa-sparsepeft" => Some(Method::QaSparsePeft),
            _ => None,
        }
    }

    pub fn cli_name(&self) -> &'static str {
        match self {
            Method::Lora => "lora",
            Method::Shears => "shears",
            Method::SparsePeft => "sparsepeft",
            Method::GptqLora => "gptq-lora",
            Method::Sqft => "sqft",
            Method::QaSparsePeft => "qa-sparsepeft",
        }
    }

    /// Fixed-rank LoRA vs elastic-rank NLS (paper Table 5 ablation axis).
    pub fn uses_nls(&self) -> bool {
        matches!(self, Method::Shears | Method::SparsePeft | Method::Sqft
                       | Method::QaSparsePeft)
    }

    /// Adapter delta is masked by the base sparsity pattern (Eq. 1).
    pub fn sparsity_aware(&self) -> bool {
        matches!(self, Method::SparsePeft | Method::QaSparsePeft)
    }

    /// Base model is GPTQ-quantized INT4.
    pub fn quantized_base(&self) -> bool {
        matches!(self, Method::GptqLora | Method::Sqft | Method::QaSparsePeft)
    }

    /// Trains through the shared-scale fake quantizer (Eq. 3-4).
    pub fn qa(&self) -> bool {
        matches!(self, Method::QaSparsePeft)
    }

    /// Paper's mergeable criterion.
    pub fn mergeable(&self) -> bool {
        matches!(self, Method::SparsePeft | Method::QaSparsePeft)
    }

    /// "Final Precision (Base + Adapter / Base)" column of Tables 1-3.
    pub fn final_precision(&self) -> &'static str {
        match self {
            Method::Lora | Method::Shears => "FP16 + FP16",
            Method::SparsePeft => "FP16",
            Method::GptqLora | Method::Sqft => "INT4 + FP16",
            Method::QaSparsePeft => "INT4",
        }
    }

    /// Which train artifact this method runs through.
    pub fn train_kind(&self) -> &'static str {
        if self.qa() { "train_qa" } else { "train" }
    }

    pub fn eval_kind(&self) -> &'static str {
        if self.qa() { "eval_qa" } else { "eval" }
    }
}

/// Compute the (masked, elastic-rank) adapter delta for one module instance:
/// `scale * (B diag(rm) A) ⊙ M` — host mirror of the L1 kernel semantics.
pub fn adapter_delta(a: &Tensor, b: &Tensor, mask: Option<&Tensor>,
                     rank_mask: &Tensor, scale: f32) -> Result<Tensor> {
    let r = a.shape()[0];
    let out = b.shape()[0];
    // B * diag(rank_mask)
    let mut bm = b.clone();
    for i in 0..out {
        let row = bm.row_mut(i);
        for j in 0..r {
            row[j] *= rank_mask.data()[j];
        }
    }
    let mut delta = matmul(&bm, a)?.scale(scale);
    if let Some(m) = mask {
        delta = delta.mul(m)?;
    }
    Ok(delta)
}

/// SparsePEFT merge (paper Eq. 2): W^p <- W^p + (BA)⊙M, in place on the
/// stacked base tensors.  Returns nothing new — sparsity preservation is
/// structural (the delta carries the same mask).
pub fn merge_sparsepeft(base: &mut ParamSet, adapters: &ParamSet,
                        hyper: &ModelHyper) -> Result<()> {
    for m in &hyper.mods {
        let wkey = ModelHyper::weight_key(m);
        let mut w = base.get(wkey)?.clone();
        let a_s = adapters.get(&format!("a_{m}"))?;
        let b_s = adapters.get(&format!("b_{m}"))?;
        let m_s = adapters.get(&format!("mask_{m}"))?;
        let rm_s = adapters.get(&format!("rankmask_{m}"))?;
        let sc_s = adapters.get(&format!("scale_{m}"))?;
        for l in 0..hyper.n_layers {
            let delta = adapter_delta(
                &a_s.index0(l), &b_s.index0(l), Some(&m_s.index0(l)),
                &rm_s.index0(l), sc_s.data()[l])?;
            let merged = w.index0(l).add(&delta)?;
            w.set_index0(l, &merged);
        }
        base.insert(wkey, w);
    }
    Ok(())
}

/// Host fake quantizer (paper Eq. 3 then Eq. 4), group-wise along in-dim.
///
/// The in-dim must divide evenly into the scales' group count: with a
/// remainder, `gs = inp / g` truncates and `scales.at2(i, j / gs)` reads
/// out of bounds for the trailing columns — rejected here instead.
pub fn fake_quant_host(w: &Tensor, scales: &Tensor, zeros: &Tensor,
                       qmax: f32) -> Result<(Tensor, Tensor)> {
    let (out, inp) = (w.rows(), w.cols());
    let g = scales.cols();
    if g == 0 || inp % g != 0 {
        bail!("fake_quant_host: in-dim {inp} does not divide into {g} groups");
    }
    if zeros.shape() != scales.shape() || scales.rows() != out {
        bail!("fake_quant_host: scales {:?} / zeros {:?} mismatch weight {:?}",
              scales.shape(), zeros.shape(), w.shape());
    }
    let gs = inp / g;
    let mut codes = Tensor::zeros(&[out, inp]);
    let mut dq = Tensor::zeros(&[out, inp]);
    for i in 0..out {
        for j in 0..inp {
            let s = scales.at2(i, j / gs);
            let z = zeros.at2(i, j / gs);
            let q = ((w.at2(i, j) / s).round() + z).clamp(0.0, qmax);
            codes.set2(i, j, q);
            dq.set2(i, j, (q - z) * s);
        }
    }
    Ok((codes, dq))
}

/// QA-SparsePEFT merge (paper Eq. 3): quantize (W^p + L^p) with the *base
/// model's* shared scales/zeros.  Returns per-module INT4 codes stacked
/// (L, out, in) in `codes` plus updates `base` weights to the dequantized
/// merged values (what the serving path computes from the codes).
pub fn merge_qa(base: &mut ParamSet, adapters: &ParamSet, qa: &ParamSet,
                hyper: &ModelHyper, qmax: f32) -> Result<ParamSet> {
    let mut codes_set = ParamSet::new();
    for m in &hyper.mods {
        let wkey = ModelHyper::weight_key(m);
        let mut w = base.get(wkey)?.clone();
        let a_s = adapters.get(&format!("a_{m}"))?;
        let b_s = adapters.get(&format!("b_{m}"))?;
        let m_s = adapters.get(&format!("mask_{m}"))?;
        let rm_s = adapters.get(&format!("rankmask_{m}"))?;
        let sc_s = adapters.get(&format!("scale_{m}"))?;
        let qs_s = qa.get(&format!("qscales_{m}"))?;
        let qz_s = qa.get(&format!("qzeros_{m}"))?;
        let mut code_layers = Vec::new();
        for l in 0..hyper.n_layers {
            let delta = adapter_delta(
                &a_s.index0(l), &b_s.index0(l), Some(&m_s.index0(l)),
                &rm_s.index0(l), sc_s.data()[l])?;
            let merged = w.index0(l).add(&delta)?;
            let (codes, dq) =
                fake_quant_host(&merged, &qs_s.index0(l), &qz_s.index0(l), qmax)?;
            w.set_index0(l, &dq);
            code_layers.push(codes);
        }
        base.insert(wkey, w);
        codes_set.insert(&format!("codes_{m}"), Tensor::stack(&code_layers)?);
    }
    Ok(codes_set)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn method_taxonomy_matches_paper_table6() {
        assert!(!Method::Lora.mergeable() && !Method::Shears.mergeable());
        assert!(Method::SparsePeft.mergeable() && Method::QaSparsePeft.mergeable());
        assert_eq!(Method::QaSparsePeft.final_precision(), "INT4");
        assert_eq!(Method::Sqft.final_precision(), "INT4 + FP16");
        assert!(Method::Shears.uses_nls() && !Method::Lora.uses_nls());
        for m in Method::all() {
            assert_eq!(Method::from_name(m.cli_name()), Some(m));
        }
    }

    #[test]
    fn adapter_delta_respects_masks() {
        let mut rng = Rng::new(1);
        let a = Tensor::randn(&mut rng, &[4, 8], 1.0);
        let b = Tensor::randn(&mut rng, &[6, 4], 1.0);
        let mask = Tensor::new(&[6, 8], (0..48).map(|i| (i % 2) as f32).collect()).unwrap();
        let rm = Tensor::new(&[4], vec![1., 1., 0., 0.]).unwrap();
        let d = adapter_delta(&a, &b, Some(&mask), &rm, 0.5).unwrap();
        // masked positions are exactly zero
        for i in 0..6 {
            for j in 0..8 {
                if mask.at2(i, j) == 0.0 {
                    assert_eq!(d.at2(i, j), 0.0);
                }
            }
        }
        // deactivated rank components don't contribute: recompute with
        // truncated a/b and full rank mask
        let mut a2 = a.clone();
        for r in 2..4 {
            for j in 0..8 {
                a2.set2(r, j, 0.0);
            }
        }
        let d2 = adapter_delta(&a2, &b, Some(&mask), &Tensor::ones(&[4]), 0.5).unwrap();
        for (x, y) in d.data().iter().zip(d2.data()) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn fake_quant_host_is_projection() {
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&mut rng, &[4, 8], 0.5);
        let scales = Tensor::full(&[4, 2], 0.1);
        let zeros = Tensor::full(&[4, 2], 8.0);
        let (codes, dq) = fake_quant_host(&w, &scales, &zeros, 15.0).unwrap();
        assert!(codes.data().iter().all(|&c| (0.0..=15.0).contains(&c)));
        let (_, dq2) = fake_quant_host(&dq, &scales, &zeros, 15.0).unwrap();
        for (x, y) in dq.data().iter().zip(dq2.data()) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn fake_quant_host_rejects_indivisible_groups() {
        // regression: 3 groups over in-dim 8 used to truncate gs to 2 and
        // read scales out of bounds at j >= 6
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&mut rng, &[4, 8], 0.5);
        let scales = Tensor::full(&[4, 3], 0.1);
        let zeros = Tensor::full(&[4, 3], 8.0);
        assert!(fake_quant_host(&w, &scales, &zeros, 15.0).is_err());
        // zeros shaped unlike scales is a mismatch, not UB
        let scales = Tensor::full(&[4, 2], 0.1);
        let zeros = Tensor::full(&[4, 4], 8.0);
        assert!(fake_quant_host(&w, &scales, &zeros, 15.0).is_err());
        // row-count mismatch is rejected too
        let scales = Tensor::full(&[2, 2], 0.1);
        let zeros = Tensor::full(&[2, 2], 8.0);
        assert!(fake_quant_host(&w, &scales, &zeros, 15.0).is_err());
    }
}
