//! Neural Low-rank Adapter Search (NLS) — elastic-rank adapters.
//!
//! Instead of one fixed LoRA rank, every (layer, module) instance picks a
//! rank from an elastic choice list C = [c_1..c_n] (paper §2.2, following
//! Shears/Munoz 2024a).  Training samples a random sub-adapter per step
//! (weight sharing); deployment uses either
//!   - the *heuristic* configuration — the median choice per instance
//!     (Munoz 2024b, paper §3.1 "Reference Configuration"), or
//!   - the hill-climbing search of paper Algorithm 1 over validation
//!     accuracy.
//!
//! A configuration maps to the static-shaped artifacts through per-instance
//! rank-mask vectors (first r entries 1) and scale = alpha / r.

use crate::model::ParamSet;
use crate::runtime::ModelHyper;
use crate::tensor::{Rng, Tensor};
use anyhow::{bail, Result};
use std::collections::BTreeSet;

/// Elastic-rank search space: one choice list shared by every
/// (layer, module) instance, instance order = layer-major over mods.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub choices: Vec<usize>, // ascending, all <= r_max
    pub n_layers: usize,
    pub mods: Vec<String>,
    pub r_max: usize,
    pub alpha: f32,
}

/// One NLS configuration: a choice *index* per instance.
pub type Config = Vec<usize>;

impl SearchSpace {
    pub fn new(hyper: &ModelHyper, choices: Vec<usize>, alpha: f32) -> Result<SearchSpace> {
        if choices.is_empty() || choices.iter().any(|&c| c == 0 || c > hyper.r_max) {
            bail!("invalid rank choices {choices:?} for r_max {}", hyper.r_max);
        }
        let mut sorted = choices.clone();
        sorted.sort_unstable();
        Ok(SearchSpace {
            choices: sorted,
            n_layers: hyper.n_layers,
            mods: hyper.mods.clone(),
            r_max: hyper.r_max,
            alpha,
        })
    }

    /// Default space mirroring the paper's Table 8 style ([r, 3r/4, r/2]).
    pub fn default_for(hyper: &ModelHyper, alpha: f32) -> SearchSpace {
        let r = hyper.r_max;
        let mut choices = vec![r / 2, (3 * r) / 4, r];
        choices.retain(|&c| c > 0);
        choices.dedup();
        SearchSpace::new(hyper, choices, alpha).expect("default space")
    }

    pub fn n_instances(&self) -> usize {
        self.n_layers * self.mods.len()
    }

    pub fn instance(&self, layer: usize, mod_idx: usize) -> usize {
        layer * self.mods.len() + mod_idx
    }

    /// LoRA baseline: every instance at max rank (fixed).
    pub fn max_config(&self) -> Config {
        vec![self.choices.len() - 1; self.n_instances()]
    }

    /// The paper's heuristic reference: median choice per instance.
    pub fn heuristic_config(&self) -> Config {
        vec![self.choices.len() / 2; self.n_instances()]
    }

    /// Random sub-adapter (one per training step under NLS).
    pub fn sample(&self, rng: &mut Rng) -> Config {
        (0..self.n_instances()).map(|_| rng.below(self.choices.len())).collect()
    }

    pub fn rank_of(&self, cfg: &Config, inst: usize) -> usize {
        self.choices[cfg[inst]]
    }

    /// Realize a configuration as rankmask_/scale_ tensors.
    pub fn realize(&self, cfg: &Config) -> Result<ParamSet> {
        if cfg.len() != self.n_instances() {
            bail!("config has {} instances, space wants {}", cfg.len(), self.n_instances());
        }
        let mut p = ParamSet::new();
        for (mi, m) in self.mods.iter().enumerate() {
            let mut rm = Tensor::zeros(&[self.n_layers, self.r_max]);
            let mut sc = Tensor::zeros(&[self.n_layers]);
            for l in 0..self.n_layers {
                let r = self.rank_of(cfg, self.instance(l, mi));
                for j in 0..r {
                    rm.data_mut()[l * self.r_max + j] = 1.0;
                }
                sc.data_mut()[l] = self.alpha / r as f32;
            }
            p.insert(&format!("rankmask_{m}"), rm);
            p.insert(&format!("scale_{m}"), sc);
        }
        Ok(p)
    }

    /// Unvisited neighbors within `step` index-moves of `anchor`
    /// (Algorithm 1's Neighbor-sample).
    pub fn neighbors(&self, anchor: &Config, n: usize, step: usize,
                     visited: &BTreeSet<Config>, rng: &mut Rng) -> Vec<Config> {
        let mut out = Vec::new();
        let mut tries = 0;
        while out.len() < n && tries < n * 20 {
            tries += 1;
            let mut c = anchor.clone();
            // perturb 1..=step instances by one choice-index each
            let k = 1 + rng.below(step);
            for _ in 0..k {
                let i = rng.below(c.len());
                let delta: i64 = if rng.next_f32() < 0.5 { -1 } else { 1 };
                let ni = (c[i] as i64 + delta)
                    .clamp(0, self.choices.len() as i64 - 1) as usize;
                c[i] = ni;
            }
            if c != *anchor && !visited.contains(&c) && !out.contains(&c) {
                out.push(c);
            }
        }
        out
    }

    /// Mean active rank of a configuration (Figure 4 statistic).
    pub fn mean_rank(&self, cfg: &Config) -> f64 {
        let total: usize = (0..self.n_instances()).map(|i| self.rank_of(cfg, i)).sum();
        total as f64 / self.n_instances() as f64
    }

    /// Histogram of ranks per module type (Figure 4).
    pub fn rank_histogram(&self, cfg: &Config) -> Vec<(String, Vec<usize>)> {
        self.mods
            .iter()
            .enumerate()
            .map(|(mi, m)| {
                let ranks: Vec<usize> = (0..self.n_layers)
                    .map(|l| self.rank_of(cfg, self.instance(l, mi)))
                    .collect();
                (m.clone(), ranks)
            })
            .collect()
    }
}

/// Clamp a realized rank configuration (`rankmask_`/`scale_` tensors) to at
/// most `rank` active rows per (layer, module) instance — the serving-side
/// half of rank elasticity.  Each rankmask row keeps the first
/// `min(r_l, rank)` ones of its prefix; the paired scale is rebuilt from the
/// instance's recovered alpha (`scale_l * r_l`, the inverse of
/// [`SearchSpace::realize`]) so the degraded adapter keeps the same
/// alpha-over-rank semantics the training space used.  Rows already at or
/// below `rank` pass through bit-identical, so degrading to `r_max` is the
/// identity.
pub fn degrade_rank_params(rank_params: &ParamSet, rank: usize) -> Result<ParamSet> {
    if rank == 0 {
        bail!("cannot degrade to rank 0");
    }
    let mut out = ParamSet::new();
    for (name, t) in rank_params.iter() {
        if let Some(m) = name.strip_prefix("rankmask_") {
            let shape = t.shape();
            if shape.len() != 2 {
                bail!("rankmask '{name}' is not [n_layers, r_max]: {shape:?}");
            }
            let (n_layers, r_max) = (shape[0], shape[1]);
            let scale_name = format!("scale_{m}");
            let scale = rank_params
                .get(&scale_name)
                .ok_or_else(|| anyhow::anyhow!("'{name}' has no paired '{scale_name}'"))?;
            if scale.shape() != [n_layers] {
                bail!("'{scale_name}' is not [n_layers]: {:?}", scale.shape());
            }
            let mut rm = Tensor::zeros(&[n_layers, r_max]);
            let mut sc = Tensor::zeros(&[n_layers]);
            for l in 0..n_layers {
                let row = &t.data()[l * r_max..(l + 1) * r_max];
                let r_full = row.iter().take_while(|&&x| x == 1.0).count();
                if row[r_full..].iter().any(|&x| x != 0.0) || r_full == 0 {
                    bail!("rankmask '{name}' layer {l} is not a non-empty prefix mask");
                }
                let r_new = r_full.min(rank);
                for j in 0..r_new {
                    rm.data_mut()[l * r_max + j] = 1.0;
                }
                let alpha = scale.data()[l] * r_full as f32;
                sc.data_mut()[l] = alpha / r_new as f32;
            }
            out.insert(name, rm);
            out.insert(&scale_name, sc);
        } else if !name.starts_with("scale_") {
            bail!("'{name}' is not a rank parameter");
        }
    }
    Ok(out)
}

/// Paper Algorithm 1: hill-climbing sub-network search.
/// `eval` scores a configuration on the validation proxy set (higher=better).
pub struct HillClimbResult {
    pub best: Config,
    pub best_score: f64,
    pub evaluated: usize,
    pub trace: Vec<(usize, f64)>, // (turn, anchor score)
}

pub fn hill_climb(
    space: &SearchSpace,
    start: Config,
    turns: usize,
    n_neighbors: usize,
    step: usize,
    mut eval: impl FnMut(&Config) -> Result<f64>,
    rng: &mut Rng,
) -> Result<HillClimbResult> {
    let mut visited: BTreeSet<Config> = BTreeSet::new();
    visited.insert(start.clone());
    let mut anchor = start;
    let mut anchor_score = eval(&anchor)?;
    let mut evaluated = 1;
    let mut trace = vec![(0, anchor_score)];
    for t in 1..=turns {
        let cands = space.neighbors(&anchor, n_neighbors, step, &visited, rng);
        let mut best_cand: Option<(Config, f64)> = None;
        for c in cands {
            visited.insert(c.clone());
            let s = eval(&c)?;
            evaluated += 1;
            if best_cand.as_ref().map(|(_, bs)| s > *bs).unwrap_or(true) {
                best_cand = Some((c, s));
            }
        }
        if let Some((c, s)) = best_cand {
            if s > anchor_score {
                anchor = c;
                anchor_score = s;
            }
        }
        trace.push((t, anchor_score));
    }
    Ok(HillClimbResult { best: anchor, best_score: anchor_score, evaluated, trace })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn hyper() -> ModelHyper {
        let mods: Vec<String> =
            ["q", "k", "v", "up", "down"].iter().map(|s| s.to_string()).collect();
        let mut mod_dims = BTreeMap::new();
        for m in &mods {
            mod_dims.insert(m.clone(), (64usize, 64usize));
        }
        ModelHyper {
            name: "t".into(), vocab: 64, d_model: 64, n_layers: 2, n_heads: 2,
            d_ff: 128, seq_len: 48, batch: 8, r_max: 8, group_size: 32,
            param_count: 0, mods, mod_dims,
        }
    }

    #[test]
    fn heuristic_is_median() {
        let s = SearchSpace::new(&hyper(), vec![4, 6, 8], 16.0).unwrap();
        let h = s.heuristic_config();
        assert!(h.iter().all(|&i| s.choices[i] == 6));
    }

    #[test]
    fn realize_shapes_and_semantics() {
        let s = SearchSpace::new(&hyper(), vec![4, 8], 16.0).unwrap();
        let mut cfg = s.max_config();
        cfg[0] = 0; // layer 0, module q at rank 4
        let p = s.realize(&cfg).unwrap();
        let rm = p.get("rankmask_q").unwrap();
        assert_eq!(rm.shape(), &[2, 8]);
        let row0: f32 = rm.data()[..8].iter().sum();
        assert_eq!(row0, 4.0);
        let row1: f32 = rm.data()[8..].iter().sum();
        assert_eq!(row1, 8.0);
        // prefix property: ones then zeros
        assert_eq!(&rm.data()[..8], &[1., 1., 1., 1., 0., 0., 0., 0.]);
        let sc = p.get("scale_q").unwrap();
        assert_eq!(sc.data()[0], 4.0);
        assert_eq!(sc.data()[1], 2.0);
    }

    #[test]
    fn degrade_clamps_prefix_and_rescales() {
        let s = SearchSpace::new(&hyper(), vec![4, 8], 16.0).unwrap();
        let mut cfg = s.max_config();
        cfg[0] = 0; // layer 0, module q already at rank 4
        let full = s.realize(&cfg).unwrap();
        let d = degrade_rank_params(&full, 2).unwrap();
        let rm = d.get("rankmask_q").unwrap();
        // every row clamps to a 2-one prefix
        assert_eq!(&rm.data()[..8], &[1., 1., 0., 0., 0., 0., 0., 0.]);
        assert_eq!(&rm.data()[8..], &[1., 1., 0., 0., 0., 0., 0., 0.]);
        // scale rebuilt from the recovered alpha: 16/2 = 8 in both layers
        let sc = d.get("scale_q").unwrap();
        assert_eq!(&sc.data()[..], &[8.0, 8.0]);
        // degrading to a rank at/above every row is the identity
        let same = degrade_rank_params(&full, 8).unwrap();
        assert_eq!(same.get("rankmask_q").unwrap(), full.get("rankmask_q").unwrap());
        assert_eq!(same.get("scale_q").unwrap(), full.get("scale_q").unwrap());
        assert_eq!(d.len(), full.len());
        // rank 0 and non-prefix masks are rejected
        assert!(degrade_rank_params(&full, 0).is_err());
        let mut bad = ParamSet::new();
        let mut t = Tensor::zeros(&[1, 4]);
        t.data_mut()[2] = 1.0; // hole in the prefix
        bad.insert("rankmask_q", t);
        bad.insert("scale_q", Tensor::full(&[1], 4.0));
        assert!(degrade_rank_params(&bad, 2).is_err());
    }

    #[test]
    fn sample_is_in_space() {
        let s = SearchSpace::new(&hyper(), vec![4, 6, 8], 16.0).unwrap();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let c = s.sample(&mut rng);
            assert_eq!(c.len(), s.n_instances());
            assert!(c.iter().all(|&i| i < 3));
        }
    }

    #[test]
    fn neighbors_are_fresh_and_close() {
        let s = SearchSpace::new(&hyper(), vec![4, 6, 8], 16.0).unwrap();
        let mut rng = Rng::new(2);
        let anchor = s.heuristic_config();
        let mut visited = BTreeSet::new();
        visited.insert(anchor.clone());
        let ns = s.neighbors(&anchor, 5, 2, &visited, &mut rng);
        assert!(!ns.is_empty());
        for n in &ns {
            assert_ne!(*n, anchor);
            let dist: usize =
                n.iter().zip(&anchor).map(|(a, b)| a.abs_diff(*b)).sum();
            assert!(dist >= 1 && dist <= 2, "dist={dist}");
        }
    }

    #[test]
    fn hill_climb_improves_and_never_regresses() {
        let s = SearchSpace::new(&hyper(), vec![4, 6, 8], 16.0).unwrap();
        let mut rng = Rng::new(3);
        // objective: prefer bigger ranks on module 0, smaller elsewhere
        let space = s.clone();
        let res = hill_climb(
            &s,
            s.heuristic_config(),
            8, 6, 2,
            |c| {
                let mut score = 0.0;
                for l in 0..space.n_layers {
                    for (mi, _) in space.mods.iter().enumerate() {
                        let r = space.rank_of(c, space.instance(l, mi)) as f64;
                        score += if mi == 0 { r } else { -r };
                    }
                }
                Ok(score)
            },
            &mut rng,
        )
        .unwrap();
        let start_score = res.trace[0].1;
        assert!(res.best_score > start_score);
        // anchor score is monotone non-decreasing (Algorithm 1 property)
        for w in res.trace.windows(2) {
            assert!(w[1].1 >= w[0].1);
        }
        // found the optimum direction: module 0 at max rank
        for l in 0..space.n_layers {
            assert_eq!(space.rank_of(&res.best, space.instance(l, 0)), 8);
        }
    }
}
