//! SQFT: Low-cost Model Adaptation in Low-precision Sparse Foundation Models
//! (Muñoz, Yuan, Jain — EMNLP 2024 Findings) — rust+JAX+Pallas reproduction.
//!
//! Layer-3 coordinator crate: everything from sparsification to serving runs
//! here; model math executes through AOT-compiled XLA artifacts (see
//! DESIGN.md for the three-layer architecture).

// The numeric kernels (Wanda scoring, GPTQ recursion, logit scans) index
// several parallel buffers per iteration; explicit indices read better
// than zipped iterator chains there, so the lint is off crate-wide.
#![allow(clippy::needless_range_loop)]

pub mod data;
pub mod faults;
pub mod harness;
pub mod model;
pub mod evalharness;
pub mod nls;
pub mod obs;
pub mod peft;
pub mod pipeline;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sparsity;
pub mod tensor;
pub mod train;
pub mod util;
