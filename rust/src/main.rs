//! SQFT command-line launcher.
//!
//! Subcommands:
//!   info                         — artifact/manifest summary
//!   pretrain                     — full-weight pretraining on a task mixture
//!   pipeline                     — one end-to-end SQFT run (prepare → tune
//!                                  → merge → eval) for a chosen method
//!   search                       — hill-climbing NLS search (Algorithm 1)
//!   serve                        — multi-tenant serving (adapter registry
//!                                  → same-adapter batch scheduler → one
//!                                  device-resident engine) + per-tenant
//!                                  throughput/latency stats
//!
//! Common flags: --artifacts DIR (default ./artifacts), --model NAME
//! (default sqft-tiny), --task NAME, --seed N, --steps N, --lr F.

use anyhow::{bail, Context, Result};
use sqft::data::{Task, Tokenizer};
use sqft::model::{checkpoint, init_base};
use sqft::nls::SearchSpace;
use sqft::obs::expose::MetricsWriter;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::report::{pct, Table};
use sqft::runtime::Runtime;
use sqft::tensor::Rng;
use sqft::train::{Pretrainer, TrainOpts};
use sqft::util::cli::Args;
use sqft::util::json::Json;
use std::path::{Path, PathBuf};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> &'static str {
    "usage: sqft <info|pretrain|pipeline|search|serve> [options]\n\
     \n\
     sqft info      [--artifacts DIR]\n\
     sqft pretrain  --model M --task T --steps N [--lr F] [--out CKPT]\n\
     sqft pipeline  --model M --task T --method lora|shears|sparsepeft|\n\
                    gptq-lora|sqft|qa-sparsepeft --sparsity S [--steps N]\n\
                    [--ckpt CKPT] [--out CKPT]\n\
                    [--export-adapter CKPT [--adapter-id ID]]\n\
     sqft search    --model M --task T --method M --sparsity S [--turns N]\n\
     sqft serve     --model M [--ckpt CKPT] [--requests N] [--workers N]\n\
                    [--adapters DIR | --tenants K [--tenant-steps N]]\n\
                    [--merged-ckpt CKPT] [--max-new-tokens N]\n\
                    [--registry-cap K] [--aging-ms MS] [--merged]\n\
                    [--deadline-ms MS] [--queue-cap N] [--max-retries N]\n\
                    [--host-tier-cap K] [--device-budget-kb N]\n\
                    [--degrade-ranks R1,R2,...]\n\
                    [--metrics-out PATH [--metrics-interval-ms N]]\n\
     \n\
     serve: one engine holds the frozen base device-resident; requests are\n\
     tagged with an adapter id and batched per adapter (registry -> batch\n\
     scheduler -> engine).  --adapters loads per-tenant checkpoints written\n\
     by `pipeline --export-adapter` and prepares the base with the method/\n\
     sparsity recorded in their metadata (pass the same --ckpt/--task/--seed\n\
     as the export run so the bases match); --tenants fine-tunes K synthetic\n\
     tenants in-process; --merged adds no-adapter fast-path traffic.\n\
     --workers N > 1 serves through the worker pool: N per-thread engine\n\
     replicas fed by a sharded work-stealing scheduler (answers stay\n\
     byte-identical to --workers 1; throughput scales with cores).\n\
     --merged-ckpt serves a packed-INT4 merged model (written by\n\
     `pipeline --method qa-sparsepeft --out`) through the eval_int4\n\
     artifact: weights stay device-resident as packed u8 + group params.\n\
     --metrics-out PATH enables live telemetry: a background writer\n\
     rewrites PATH (Prometheus text), PATH.json (snapshot), and\n\
     PATH.trace.jsonl (per-request spans) every --metrics-interval-ms\n\
     (default 500) during the run, plus a final snapshot at the end.\n\
     Failure policy: --deadline-ms sheds requests still queued past the\n\
     deadline (0 = off), --queue-cap bounds each scheduler queue and\n\
     rejects excess pushes as overloaded (0 = unbounded), --max-retries\n\
     (default 2) bounds both in-session decode retries and per-request\n\
     re-admissions after session failures / worker crashes.  Chaos:\n\
     SQFT_FAULTS=\"site=rate[:error|panic|delay<ms>],...\" with\n\
     SQFT_FAULT_SEED=N injects deterministic faults (sites:\n\
     engine.forward, engine.slow_forward, runtime.upload,\n\
     pool.worker_panic, registry.register).\n\
     Tiered residency: --host-tier-cap K keeps up to K validated tenant\n\
     copies host-resident (default --registry-cap) so re-promotion skips\n\
     the disk re-read; --device-budget-kb N bounds device-resident\n\
     adapter bytes per worker (0 = unbounded) and --degrade-ranks\n\
     R1,R2,... is the elastic ladder tried, highest first, when a tenant\n\
     does not fit at full rank — degraded tenants keep serving and are\n\
     restored when pressure drops.  A corrupt adapter checkpoint in\n\
     --adapters quarantines that tenant (typed tenant_unavailable\n\
     replies); siblings serve normally.\n"
}

fn run(argv: &[String]) -> Result<()> {
    if argv.is_empty() {
        println!("{}", usage());
        return Ok(());
    }
    let args = Args::parse(&argv[1..], &["quiet", "merged", "no-merge"])?;
    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    match argv[0].as_str() {
        "info" => info(&artifacts),
        "pretrain" => pretrain(&artifacts, &args),
        "pipeline" => cmd_pipeline(&artifacts, &args),
        "search" => cmd_search(&artifacts, &args),
        "serve" => cmd_serve(&artifacts, &args),
        other => bail!("unknown subcommand '{other}'\n{}", usage()),
    }
}

fn info(artifacts: &Path) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    println!("platform: {}", rt.client.platform_name());
    let mut t = Table::new("Model configs", &["name", "params", "d", "L", "ff", "seq", "r_max"]);
    for (name, entry) in &rt.manifest.configs {
        let m = &entry.model;
        t.row(vec![
            name.clone(),
            format!("{:.1}M", m.param_count as f64 / 1e6),
            m.d_model.to_string(),
            m.n_layers.to_string(),
            m.d_ff.to_string(),
            m.seq_len.to_string(),
            m.r_max.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("artifact kinds per config: {:?}",
        rt.manifest.configs.values().next()
            .map(|e| e.artifacts.keys().collect::<Vec<_>>()).unwrap_or_default());
    println!("shape artifacts: {}", rt.manifest.shape_artifacts.len());
    Ok(())
}

fn parse_task(args: &Args) -> Result<Task> {
    let name = args.get_or("task", "syn-gsm");
    Task::from_name(name).with_context(|| format!("unknown task '{name}'"))
}

fn pretrain(artifacts: &Path, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let config = args.get_or("model", "sqft-tiny").to_string();
    let task = parse_task(args)?;
    let steps = args.get_usize("steps", 300)?;
    let lr = args.get_f64("lr", 1e-3)?;
    let seed = args.get_u64("seed", 7)?;
    let hyper = rt.model(&config)?.clone();
    let tok = Tokenizer::new();
    let ds = pipeline::standard_datasets(task, seed);

    println!("pretraining {config} ({:.1}M params) on {} for {steps} steps",
        hyper.param_count as f64 / 1e6, task.name());
    let mut rng = Rng::new(seed);
    let base = init_base(&hyper, &mut rng);
    let mut pre = Pretrainer::new(&rt, &config, base);
    let opts = TrainOpts { steps, lr, log_every: (steps / 20).max(1), seed, fixed_rank: false };
    let curve = pre.train(&ds.train, &tok, &opts)?;
    println!("{}", curve.render());

    let prepared = pipeline::prepare(&rt, &config, &pre.base, Method::Lora, 0.0,
                                     &ds.train, &tok, 0, &mut rng)?;
    let acc = pipeline::evaluate_base(&rt, &config, &prepared, &ds.test, &tok)?;
    println!("dense test accuracy: {}% ({}/{})",
        pct(acc.accuracy()), acc.correct, acc.total);

    let out = args.get_or("out", "checkpoints/base.ckpt");
    let meta = Json::obj(vec![
        ("config", Json::Str(config.clone())),
        ("task", Json::Str(task.name().into())),
        ("steps", Json::Num(steps as f64)),
        ("seed", Json::Num(seed as f64)),
        ("accuracy", Json::Num(acc.accuracy())),
    ]);
    checkpoint::save(&pre.base, Path::new(out), meta)?;
    println!("saved {out}");
    Ok(())
}

fn load_or_pretrain(rt: &Runtime, config: &str, task: Task, args: &Args,
                    seed: u64) -> Result<sqft::model::ParamSet> {
    if let Some(ckpt) = args.get("ckpt") {
        let (params, meta) = checkpoint::load(Path::new(ckpt))?;
        if let Some(c) = meta.get("config") {
            if c.as_str()? != config {
                bail!("checkpoint {ckpt} was trained for config {:?}, not {config}",
                    c.as_str()?);
            }
        }
        println!("loaded base checkpoint {ckpt}");
        return Ok(params);
    }
    // no checkpoint: quick pretrain
    let hyper = rt.model(config)?.clone();
    let tok = Tokenizer::new();
    let ds = pipeline::standard_datasets(task, seed);
    let steps = args.get_usize("pretrain-steps", 300)?;
    println!("no --ckpt given; pretraining {steps} steps first");
    let mut rng = Rng::new(seed);
    let base = init_base(&hyper, &mut rng);
    let mut pre = Pretrainer::new(rt, config, base);
    pre.train(&ds.train, &tok,
              &TrainOpts { steps, lr: 1e-3, log_every: steps.max(1), seed, fixed_rank: false })?;
    Ok(pre.base)
}

fn cmd_pipeline(artifacts: &Path, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let config = args.get_or("model", "sqft-tiny").to_string();
    let task = parse_task(args)?;
    let method = Method::from_name(args.get_or("method", "sparsepeft"))
        .context("bad --method")?;
    let sparsity = args.get_f64("sparsity", 0.5)?;
    let steps = args.get_usize("steps", 200)?;
    let lr = args.get_f64("lr", 1e-3)?;
    let seed = args.get_u64("seed", 7)?;
    let tok = Tokenizer::new();
    let ds = pipeline::standard_datasets(task, seed);
    let pretrained = load_or_pretrain(&rt, &config, task, args, seed)?;

    println!("== SQFT pipeline: {} | {} | sparsity {:.0}% ==",
        method.name(), task.name(), sparsity * 100.0);
    let mut rng = Rng::new(seed ^ 2);
    let prepared = pipeline::prepare(&rt, &config, &pretrained, method, sparsity,
                                     &ds.train, &tok, 4, &mut rng)?;
    println!("base sparsity after prepare: {:.1}%",
        prepared.measured_sparsity() * 100.0);
    let base_acc = pipeline::evaluate_base(&rt, &config, &prepared, &ds.test, &tok)?;
    println!("compressed, w/o tune: {}%", pct(base_acc.accuracy()));

    let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
    let space = SearchSpace::new(&prepared.hyper, choices, alpha)?;
    let opts = TrainOpts { steps, lr, log_every: (steps / 10).max(1), seed, fixed_rank: false };
    let (trainer, curve) = pipeline::finetune(&rt, &config, &prepared, space,
                                              &ds.train, &tok, &opts)?;
    println!("{}", curve.render());

    let cfg = if method.uses_nls() {
        trainer.space.heuristic_config()
    } else {
        trainer.space.max_config()
    };
    let acc = pipeline::evaluate_unmerged(&rt, &config, &prepared, &trainer,
                                          &cfg, &ds.test, &tok)?;
    println!("fine-tuned ({}): {}%  [final precision {}]",
        if method.uses_nls() { "NLS heuristic" } else { "LoRA" },
        pct(acc.accuracy()), method.final_precision());

    if let Some(out) = args.get("export-adapter") {
        let default_id = format!("{}-{}", method.cli_name(), task.name());
        let adapter_id = args.get_or("adapter-id", &default_id);
        pipeline::export_adapter(&prepared, &trainer, &cfg, &config, adapter_id,
                                 Path::new(out))?;
        println!("exported adapter '{adapter_id}' to {out}");
    }

    if method.mergeable() && !args.has_flag("no-merge") {
        let merged = pipeline::merged_state(&prepared, &trainer, &cfg)?;
        let macc = pipeline::evaluate_merged(&rt, &config, &prepared, &merged,
                                             &ds.test, &tok)?;
        println!("merged: {}%  sparsity {:.1}% -> {:.1}%  (mergeable: yes)",
            pct(macc.accuracy()),
            merged.sparsity_before * 100.0, merged.sparsity_after * 100.0);
        if let Some(out) = args.get("out") {
            if method.quantized_base() {
                // QA merge: persist the model in its final numerical format
                // — packed INT4 codes + group params, never dequantized f32
                let model = pipeline::int4_model(&prepared, &merged)?;
                let disk = model.resident_bytes();
                let dense = merged.base.total_bytes();
                pipeline::save_int4_model(&model, Path::new(out), vec![
                    ("method", Json::Str(method.cli_name().into())),
                    ("task", Json::Str(task.name().into())),
                    ("accuracy", Json::Num(macc.accuracy())),
                ])?;
                println!(
                    "saved packed-INT4 merged model to {out} \
                     ({:.1} KB vs {:.1} KB dense f32, {:.2}x smaller)",
                    disk as f64 / 1e3, dense as f64 / 1e3, dense as f64 / disk as f64
                );
            } else {
                let meta = Json::obj(vec![
                    ("config", Json::Str(config.clone())),
                    ("method", Json::Str(method.cli_name().into())),
                    ("task", Json::Str(task.name().into())),
                    ("accuracy", Json::Num(macc.accuracy())),
                ]);
                checkpoint::save(&merged.base, Path::new(out), meta)?;
                println!("saved merged model to {out}");
            }
        }
    } else if !method.mergeable() {
        println!("mergeable: no ({} keeps a separate FP16 adapter)", method.name());
    }
    Ok(())
}

fn cmd_search(artifacts: &Path, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let config = args.get_or("model", "sqft-tiny").to_string();
    let task = parse_task(args)?;
    let method = Method::from_name(args.get_or("method", "sparsepeft"))
        .context("bad --method")?;
    let sparsity = args.get_f64("sparsity", 0.5)?;
    let steps = args.get_usize("steps", 200)?;
    let turns = args.get_usize("turns", 5)?;
    let seed = args.get_u64("seed", 7)?;
    let tok = Tokenizer::new();
    let ds = pipeline::standard_datasets(task, seed);
    if ds.val.is_empty() {
        bail!("task {} has no validation split (paper uses Arc-e/Arc-c/OBQA)",
            task.name());
    }
    let pretrained = load_or_pretrain(&rt, &config, task, args, seed)?;
    let mut rng = Rng::new(seed ^ 2);
    let prepared = pipeline::prepare(&rt, &config, &pretrained, method, sparsity,
                                     &ds.train, &tok, 4, &mut rng)?;
    let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
    let space = SearchSpace::new(&prepared.hyper, choices, alpha)?;
    let opts = TrainOpts { steps, lr: 1e-3, log_every: steps.max(1), seed, fixed_rank: false };
    let (trainer, _) = pipeline::finetune(&rt, &config, &prepared, space,
                                          &ds.train, &tok, &opts)?;
    let start = trainer.space.heuristic_config();
    println!("hill-climbing from heuristic (Algorithm 1): {turns} turns");
    let mut search_rng = Rng::new(seed ^ 3);
    let space_ref = trainer.space.clone();
    let res = sqft::nls::hill_climb(
        &space_ref, start, turns, 4, 2,
        |cfg| {
            let r = pipeline::evaluate_unmerged(
                &rt, &config, &prepared, &trainer, cfg, &ds.val, &tok)?;
            Ok(r.accuracy())
        },
        &mut search_rng,
    )?;
    println!("evaluated {} configs; best val acc {}%", res.evaluated,
        pct(res.best_score));
    let test_h = pipeline::evaluate_unmerged(
        &rt, &config, &prepared, &trainer,
        &trainer.space.heuristic_config(), &ds.test, &tok)?;
    let test_b = pipeline::evaluate_unmerged(
        &rt, &config, &prepared, &trainer, &res.best, &ds.test, &tok)?;
    let mut t = Table::new(
        "Hill-climbing vs heuristic (paper Table 4)",
        &["Sub-Adapter", "Val Acc(%)", "Test Acc(%)", "Mean rank"]);
    t.row(vec!["Heuristic".into(), pct(res.trace[0].1), pct(test_h.accuracy()),
               format!("{:.1}", trainer.space.mean_rank(&trainer.space.heuristic_config()))]);
    t.row(vec!["Hill-climbing".into(), pct(res.best_score), pct(test_b.accuracy()),
               format!("{:.1}", trainer.space.mean_rank(&res.best))]);
    print!("{}", t.render());
    Ok(())
}

/// Build the serve observability context from --metrics-out /
/// --metrics-interval-ms: with a path, metrics + trace plus a background
/// exposition writer; without, metrics only (end-of-run tables still come
/// from the same registry).
fn serve_obs(args: &Args) -> Result<(sqft::serve::ServeObs, Option<MetricsWriter>)> {
    match args.get("metrics-out") {
        Some(path) => {
            let obs = sqft::serve::ServeObs::with_trace();
            let interval = args.get_u64("metrics-interval-ms", 500)?;
            let writer = MetricsWriter::spawn(
                obs.registry().clone(),
                obs.trace().cloned(),
                PathBuf::from(path),
                std::time::Duration::from_millis(interval.max(1)),
            );
            Ok((obs, Some(writer)))
        }
        None => Ok((sqft::serve::ServeObs::new(), None)),
    }
}

/// Scheduler policy from the serve CLI knobs: --aging-ms, --deadline-ms
/// (0 = no deadline), --queue-cap (0 = unbounded), --max-retries.
fn sched_opts_from_args(args: &Args, max_batch: usize) -> Result<sqft::serve::SchedulerOpts> {
    let deadline_ms = args.get_u64("deadline-ms", 0)?;
    let queue_cap = args.get_usize("queue-cap", 0)?;
    Ok(sqft::serve::SchedulerOpts {
        max_batch,
        aging: std::time::Duration::from_millis(args.get_u64("aging-ms", 50)?),
        deadline: (deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(deadline_ms)),
        queue_cap: (queue_cap > 0).then_some(queue_cap),
        max_retries: args.get_usize("max-retries", 2)?,
    })
}

/// The chaos plan from SQFT_FAULTS / SQFT_FAULT_SEED (disabled when the
/// env carries none); announces an armed plan so a chaos run is visible.
fn fault_injector_from_env() -> Result<sqft::faults::FaultInjector> {
    match sqft::faults::FaultInjector::from_env()? {
        Some(inj) => {
            println!("fault injection armed from SQFT_FAULTS");
            Ok(inj)
        }
        None => Ok(sqft::faults::FaultInjector::disabled()),
    }
}

/// Final exposition write after the run (the writer also wrote
/// periodically while serving).
fn finish_metrics(writer: Option<MetricsWriter>) -> Result<()> {
    if let Some(w) = writer {
        let path = w.finish()?;
        println!("metrics snapshot: {} (+ .json, .trace.jsonl)", path.display());
    }
    Ok(())
}

/// Serve a packed-INT4 merged model (written by `pipeline --method
/// qa-sparsepeft --out`): the base crosses the PJRT boundary once as packed
/// u8 + f32 group params and every request takes the eval_int4 path.
#[allow(clippy::too_many_arguments)]
fn serve_int4_merged(
    rt: &Runtime,
    config: &str,
    task: Task,
    ckpt: &str,
    n_requests: usize,
    max_new_tokens: usize,
    args: &Args,
    seed: u64,
) -> Result<()> {
    if args.get("adapters").is_some() || args.get("tenants").is_some() {
        bail!("--merged-ckpt serves a merged model; it has no adapters \
               (drop --adapters/--tenants or serve them from a separate engine)");
    }
    if args.get_usize("workers", 1)? > 1 {
        bail!("--merged-ckpt currently serves on one worker; drop --workers");
    }
    let model = pipeline::load_int4_model(Path::new(ckpt))?;
    let engine = sqft::serve::Engine::new_int4(rt, config, &model, max_new_tokens)?;
    println!(
        "serving packed-INT4 merged model from {ckpt}: {:.1} KB resident \
         (packed u8 codes + f32 group params)",
        engine.resident_weight_bytes() as f64 / 1e3
    );
    let hyper = rt.model(config)?.clone();
    let mut grng = Rng::new(seed ^ 9);
    let requests: Vec<(Option<String>, String)> = (0..n_requests)
        .map(|_| (None, task.gen_sample(&mut grng).prompt))
        .collect();
    let opts = sched_opts_from_args(args, hyper.batch)?;
    let (obs, writer) = serve_obs(args)?;
    let mut router = sqft::serve::Router::new(engine, sqft::serve::AdapterRegistry::new(1));
    router.set_obs(obs);
    router.set_faults(fault_injector_from_env()?);
    let stats = sqft::serve::benchmark_router(
        &mut router, requests, std::time::Duration::from_millis(2), opts)?;
    print!("{}", stats.render());
    finish_metrics(writer)
}

fn cmd_serve(artifacts: &Path, args: &Args) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let config = args.get_or("model", "sqft-tiny").to_string();
    let task = parse_task(args)?;
    let n_requests = args.get_usize("requests", 64)?;
    let max_new_tokens = args.get_usize("max-new-tokens", 6)?;
    let n_tenants = args.get_usize("tenants", 3)?;
    let tenant_steps = args.get_usize("tenant-steps", 30)?;
    let registry_cap = args.get_usize("registry-cap", 8)?;
    let host_tier_cap = args.get_usize("host-tier-cap", registry_cap)?;
    let device_budget = args.get_usize("device-budget-kb", 0)?.saturating_mul(1024);
    let degrade_ranks: Vec<usize> = match args.get("degrade-ranks") {
        Some(s) => s
            .split(',')
            .map(str::trim)
            .filter(|x| !x.is_empty())
            .map(|x| {
                x.parse::<usize>()
                    .map_err(|e| anyhow::anyhow!("--degrade-ranks: bad rank '{x}': {e}"))
            })
            .collect::<Result<_>>()?,
        None => Vec::new(),
    };
    let seed = args.get_u64("seed", 7)?;
    // a packed-INT4 merged checkpoint serves through its own engine: no
    // base prep, no adapters — the model is already in final form
    if let Some(ckpt) = args.get("merged-ckpt") {
        let ckpt = ckpt.to_string();
        return serve_int4_merged(&rt, &config, task, &ckpt, n_requests,
                                 max_new_tokens, args, seed);
    }
    let tok = Tokenizer::new();
    let pretrained = load_or_pretrain(&rt, &config, task, args, seed)?;
    let ds = pipeline::standard_datasets(task, seed);

    // when serving exported adapters, the base must be prepared exactly
    // like the export run prepared it (method + sparsity from the
    // checkpoint metadata; same --ckpt/--task/--seed as the export)
    // fault-tolerant load: a corrupt/mismatched checkpoint quarantines
    // that one tenant (typed tenant_unavailable replies) while its
    // siblings load and serve normally
    let mut quarantined: Vec<(String, String)> = Vec::new();
    let ckpts = match args.get("adapters") {
        Some(dir) => {
            let (good, bad) =
                sqft::serve::load_adapter_dir_tolerant(Path::new(dir), &config)?;
            for (id, path, reason) in bad {
                eprintln!(
                    "quarantining adapter '{id}' ({}): {reason}",
                    path.display()
                );
                quarantined.push((id, reason));
            }
            good
        }
        None => Vec::new(),
    };
    let (method, sparsity) = match ckpts.first() {
        Some(first) => {
            let m = Method::from_name(&first.method).with_context(|| {
                format!("adapter '{}' carries unknown method '{}'",
                        first.adapter_id, first.method)
            })?;
            for ck in &ckpts {
                if ck.method != first.method || ck.sparsity != first.sparsity {
                    bail!("adapters disagree on base prep ('{}' is {}@{:.0}%, '{}' is {}@{:.0}%); serve them from separate dirs",
                        first.adapter_id, first.method, first.sparsity * 100.0,
                        ck.adapter_id, ck.method, ck.sparsity * 100.0);
                }
            }
            (m, first.sparsity)
        }
        None => (Method::Lora, 0.0),
    };
    let mut rng = Rng::new(seed ^ 2);
    let calib = if sparsity > 0.0 || method.quantized_base() { 4 } else { 0 };
    let prepared = pipeline::prepare(&rt, &config, &pretrained, method, sparsity,
                                     &ds.train, &tok, calib, &mut rng)?;
    let frozen = prepared.frozen_set()?;
    let hyper = prepared.hyper.clone();
    let workers = args.get_usize("workers", 1)?;

    // collect tenant entries: the loaded checkpoints, or synthetic tenants
    // fine-tuned over the shared frozen base
    let mut entries: Vec<sqft::serve::AdapterEntry> = Vec::new();
    if !ckpts.is_empty() {
        for ck in ckpts {
            if ck.eval_kind != method.eval_kind() {
                bail!("adapter '{}' serves through '{}' but method {} uses '{}'",
                    ck.adapter_id, ck.eval_kind, method.name(), method.eval_kind());
            }
            entries.push(sqft::serve::AdapterEntry::from_ckpt(ck, "adapter"));
        }
        println!("loaded {} adapters ({}, sparsity {:.0}%)",
            entries.len(), method.name(), sparsity * 100.0);
    } else if n_tenants > 0 {
        println!("fine-tuning {n_tenants} tenant adapters ({tenant_steps} steps each)...");
        entries = pipeline::tenant_adapters(&rt, &config, &prepared, n_tenants,
                                            &ds.train, &tok, tenant_steps,
                                            seed ^ 21)?;
    }
    let mut tenant_ids: Vec<Option<String>> =
        entries.iter().map(|e| Some(e.id.clone())).collect();
    if tenant_ids.is_empty() || args.has_flag("merged") {
        tenant_ids.push(None); // merged / no-adapter fast path
    }

    let mut grng = Rng::new(seed ^ 9);
    let requests: Vec<(Option<String>, String)> = (0..n_requests)
        .map(|i| (tenant_ids[i % tenant_ids.len()].clone(),
                  task.gen_sample(&mut grng).prompt))
        .collect();
    let opts = sched_opts_from_args(args, hyper.batch)?;
    println!("serving {n_requests} requests over {} tenants with {workers} worker(s) \
(batch {}, aging {:?}, max_new_tokens {max_new_tokens})...",
        tenant_ids.len(), opts.max_batch, opts.aging);
    if workers > 1 {
        // worker pool: per-thread engine replicas; each worker compiles
        // its own executables and replicates the tenants device-resident
        let source = sqft::serve::SharedAdapterSource::new(hyper.clone(), registry_cap);
        source.register_all(entries)
            .context("registering tenants (see --registry-cap / --adapter-id)")?;
        for (id, reason) in &quarantined {
            source.quarantine(id, reason.clone());
        }
        let spec = sqft::serve::EngineSpec {
            artifacts: artifacts.to_path_buf(),
            config: config.clone(),
            frozen,
            eval_kind: "eval".to_string(),
            max_new_tokens,
            registry_capacity: registry_cap.max(host_tier_cap),
            device_budget,
            degrade_ranks: degrade_ranks.clone(),
        };
        let popts = sqft::serve::PoolOpts {
            workers,
            sched: opts,
            faults: fault_injector_from_env()?,
        };
        let (obs, writer) = serve_obs(args)?;
        let stats = sqft::serve::benchmark_pool_obs(
            &spec, &source, requests, std::time::Duration::from_millis(2), popts, obs)?;
        print!("{}", stats.serve.render());
        println!("pool: {} workers, {} stolen batches", stats.workers, stats.steals);
        for w in &stats.per_worker {
            println!("  worker {}: {} served, {} errors, {} sessions ({} stolen), \
{} forwards, setup {:.0}ms{}",
                w.worker, w.served, w.errors, w.sessions, w.stolen_sessions, w.decode_steps,
                w.setup_secs * 1e3,
                w.setup_error.as_deref().map(|e| format!("  [SETUP FAILED: {e}]"))
                    .unwrap_or_default());
        }
        finish_metrics(writer)?;
    } else {
        let engine = sqft::serve::Engine::new(&rt, &config, &frozen, None, "eval",
                                              max_new_tokens)?;
        let mut registry =
            sqft::serve::AdapterRegistry::new(registry_cap.max(host_tier_cap));
        registry.set_device_budget(device_budget);
        registry.set_degrade_ranks(&degrade_ranks);
        registry.register_all_resident(&rt, &hyper, entries)
            .context("registering tenants (see --registry-cap / --adapter-id)")?;
        for (id, reason) in &quarantined {
            registry.quarantine(id, reason.clone());
        }
        let (obs, writer) = serve_obs(args)?;
        let mut router = sqft::serve::Router::new(engine, registry);
        router.set_obs(obs);
        router.set_faults(fault_injector_from_env()?);
        let stats = sqft::serve::benchmark_router(
            &mut router, requests, std::time::Duration::from_millis(2), opts)?;
        print!("{}", stats.render());
        finish_metrics(writer)?;
    }
    Ok(())
}
