//! Multi-worker serving: per-thread engine replicas over a sharded
//! work-stealing scheduler.
//!
//! The single-worker [`Router`](super::Router) decodes one session at a
//! time on one OS thread, so an N-core box serves at 1-core throughput.
//! Per-tenant serving state is small and independent (the LoRA-style
//! multi-adapter pattern), which makes tenant-sharded data parallelism
//! the cheap scaling axis:
//!
//!   - **N workers**, each owning a thread-local `Runtime` + [`Engine`]
//!     replica built from the same artifact dir (executables compile per
//!     worker; `Runtime` is `!Send` by design and never crosses threads)
//!     plus a private [`AdapterRegistry`] whose device-resident tenants
//!     are replayed from a [`SharedAdapterSource`] — the host-side source
//!     of truth that also coordinates eviction across replicas;
//!   - a [`ShardedScheduler`] assigns each tenant a home worker (keeps
//!     one tenant's traffic on one replica — better bank-slot locality)
//!     and lets idle workers steal whole **mixed** batches from
//!     overloaded shards; each shard runs the slot-level mixed policy
//!     its single-worker counterpart uses;
//!   - a **dispatcher** on the calling thread feeds the shards from the
//!     public request channel, so producers see the same API as
//!     [`Router::serve`](super::Router::serve).
//!
//! Replicas run identical artifacts and decode rows independently, so
//! per-request answers are byte-identical to the single-worker reference
//! regardless of worker count, batch composition, or steal schedule —
//! only throughput changes.  Workers go live together (a barrier after
//! setup), so tenants see uniform capacity and the scaling bench's
//! steady-state window is exact.  A worker whose replica fails to build
//! does not strand its shard: it steps aside and healthy siblings absorb
//! its queue through stealing; only when *every* replica fails does the
//! last one drain the queues with errors, so nothing ever hangs and no
//! request is failed while a healthy replica could have served it.

use super::error::ServeError;
use super::registry::{gathered_slots, AdapterRegistry, SharedAdapterSource};
use super::scheduler::{Request, SchedulerOpts, ShardedScheduler};
use super::{
    finish_multi_obs, serve_batch, Engine, MultiServeStats, RecorderCache, ServeObs,
    SessionPolicy, GATHERED_KIND,
};
use crate::faults::FaultInjector;
use crate::model::ParamSet;
use crate::runtime::Runtime;
use crate::util::sync::lock_recover;
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Barrier, Mutex};
use std::time::{Duration, Instant};

/// Everything a worker thread needs to build its own engine replica.
/// Host-side and `Sync`, so one spec (borrowed) serves every worker.
pub struct EngineSpec {
    /// artifact directory each worker compiles its executables from
    pub artifacts: PathBuf,
    pub config: String,
    /// frozen base weights, uploaded per worker at startup
    pub frozen: ParamSet,
    /// eval artifact kind for the merged / no-adapter path
    pub eval_kind: String,
    pub max_new_tokens: usize,
    /// per-worker registry capacity; must be ≥ the shared source's
    /// capacity so replica LRU never fires on its own (eviction stays
    /// coordinated through the source)
    pub registry_capacity: usize,
    /// per-worker device residency budget in logical adapter bytes
    /// (0 = unbounded, the flat legacy behavior); see
    /// [`AdapterRegistry::set_device_budget`]
    pub device_budget: usize,
    /// rank-elastic degradation ladder offered under device pressure
    /// (empty = never degrade); see
    /// [`AdapterRegistry::set_degrade_ranks`]
    pub degrade_ranks: Vec<usize>,
}

/// Worker-pool serving knobs.
#[derive(Clone, Debug)]
pub struct PoolOpts {
    /// engine replicas (and scheduler shards); 1 degenerates to
    /// single-worker behavior over the pool plumbing
    pub workers: usize,
    pub sched: SchedulerOpts,
    /// chaos-harness failpoints, threaded into every worker (disabled by
    /// default: checks cost one branch)
    pub faults: FaultInjector,
}

impl Default for PoolOpts {
    fn default() -> Self {
        PoolOpts {
            workers: 1,
            sched: SchedulerOpts::default(),
            faults: FaultInjector::disabled(),
        }
    }
}

/// One worker's contribution to the run (summed/merged into the
/// aggregate [`MultiServeStats`]).
#[derive(Clone, Debug)]
pub struct WorkerStats {
    pub worker: usize,
    pub served: usize,
    pub errors: usize,
    /// decode sessions this worker ran
    pub sessions: usize,
    /// sessions whose batch was stolen from another worker's shard
    pub stolen_sessions: usize,
    pub decode_steps: usize,
    /// model bytes this worker's replica keeps device-resident
    pub resident_weight_bytes: u64,
    /// replica setup time (runtime load + executable-compile-on-first-use
    /// happens lazily, so this covers runtime/engine build + tenant
    /// replication), measured from pool start
    pub setup_secs: f64,
    /// setup error, if the replica failed to build; the worker then
    /// stepped aside (healthy siblings steal its shard) — or, when every
    /// replica failed, the last one drained all requests with errors
    pub setup_error: Option<String>,
}

/// Aggregate + per-worker serving report for one pool run.
#[derive(Debug)]
pub struct PoolServeStats {
    /// merged per-tenant/total stats; `scheduler` is the cross-shard
    /// aggregate and `occupancy`/`generated_tokens` span all workers
    pub serve: MultiServeStats,
    pub workers: usize,
    /// batches executed by a non-home worker (work stealing)
    pub steals: usize,
    /// total wall minus the slowest healthy replica's setup — the
    /// steady-state window scaling benches should divide tokens by, so
    /// per-worker compile time doesn't masquerade as serving cost
    pub serving_wall_secs: f64,
    pub per_worker: Vec<WorkerStats>,
}

/// What a worker thread hands back at join time.  Serving counts live in
/// the shared [`ServeObs`] registry (one instrument, many views); only
/// setup facts the registry doesn't carry come back through here.
struct WorkerOutcome {
    worker: usize,
    capacity: usize,
    resident_weight_bytes: u64,
    setup_secs: f64,
    setup_error: Option<String>,
}

/// Serve `rx` with `opts.workers` engine replicas until the channel
/// closes and every queue drains.  Tenants come from `source` (replayed
/// into each replica's registry, device-resident).  The calling thread
/// becomes the dispatcher.  `opts.sched.max_batch` is clamped to the
/// artifact batch during worker setup (same rule as `Router::serve`), so
/// a dispatched batch never outsizes the decode slots.
pub fn serve_pool(
    spec: &EngineSpec,
    source: &SharedAdapterSource,
    rx: Receiver<Request>,
    opts: PoolOpts,
) -> Result<PoolServeStats> {
    serve_pool_obs(spec, source, rx, opts, ServeObs::new())
}

/// [`serve_pool`] with a caller-supplied observability context — e.g. one
/// with tracing enabled, or one a `MetricsWriter` is already exposing.
pub fn serve_pool_obs(
    spec: &EngineSpec,
    source: &SharedAdapterSource,
    rx: Receiver<Request>,
    opts: PoolOpts,
    obs: ServeObs,
) -> Result<PoolServeStats> {
    let workers = opts.workers.max(1);
    let policy =
        SessionPolicy { max_retries: opts.sched.max_retries, faults: opts.faults.clone() };
    let mut sched = ShardedScheduler::new(workers, opts.sched.clone());
    sched.bind_obs(obs.registry());
    let start = Instant::now();
    // replicas go live together: every worker (healthy or failed) checks
    // in here after setup, so no request is served while a sibling is
    // still compiling — tenants see uniform capacity from the first
    // token, and the steady-state serving window is exactly
    // `wall - slowest setup` (what the scaling bench divides by)
    let ready = Barrier::new(workers);
    let failed = AtomicUsize::new(0);
    let outcomes: Vec<WorkerOutcome> = std::thread::scope(|scope| {
        let (sched, ready, failed, obs, policy) = (&sched, &ready, &failed, &obs, &policy);
        let handles: Vec<_> = (0..workers)
            .map(|wid| {
                scope.spawn(move || {
                    worker_main(wid, spec, source, sched, start, ready, failed, obs, policy)
                })
            })
            .collect();
        // dispatcher: feed the shards until the producer side closes
        // (refused pushes — overload / expired deadline — replied inline)
        for req in rx.iter() {
            obs.enqueue(&req);
            sched.push(req);
        }
        sched.close();
        handles
            .into_iter()
            .enumerate()
            .map(|(wid, h)| {
                h.join().unwrap_or_else(|_| {
                    // the thread died outside the per-session unwind
                    // boundary (setup-path panic): synthesize an outcome
                    // so the pool report stays complete
                    obs.worker_crash(wid);
                    WorkerOutcome {
                        worker: wid,
                        capacity: 0,
                        resident_weight_bytes: 0,
                        setup_secs: 0.0,
                        setup_error: Some("worker thread panicked".to_string()),
                    }
                })
            })
            .collect()
    });
    let wall = start.elapsed().as_secs_f64();
    let capacity = outcomes.iter().map(|o| o.capacity).max().unwrap_or(0);
    // per-worker serving counts are views over the shared registry, keyed
    // by the worker label the recorders stamped
    let snap = obs.registry().snapshot();
    let served_by = snap.sum_by("serve_requests_total", "worker");
    let errors_by = snap.sum_by("serve_errors_total", "worker");
    let sessions_by = snap.sum_by("serve_sessions_total", "worker");
    let stolen_by = snap.sum_by("serve_stolen_sessions_total", "worker");
    let steps_by = snap.sum_by("serve_decode_steps_total", "worker");
    let mut per_worker = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        let w = o.worker.to_string();
        let count = |m: &BTreeMap<String, f64>| m.get(&w).copied().unwrap_or(0.0) as usize;
        per_worker.push(WorkerStats {
            worker: o.worker,
            served: count(&served_by),
            errors: count(&errors_by),
            sessions: count(&sessions_by),
            stolen_sessions: count(&stolen_by),
            decode_steps: count(&steps_by),
            resident_weight_bytes: o.resident_weight_bytes,
            setup_secs: o.setup_secs,
            setup_error: o.setup_error,
        });
    }
    // the barrier releases serving at the slowest worker's check-in, so
    // this is the exact start of the serving window (failed workers
    // check in too — their time-to-fail gates the barrier the same way)
    let slowest_setup = per_worker.iter().map(|w| w.setup_secs).fold(0.0f64, f64::max);
    let serving_wall = wall - slowest_setup;
    let mut serve = finish_multi_obs(&obs, wall, sched.metrics(), capacity);
    // per-replica figure (replicas are identical); 0 only if every worker
    // failed before building its engine
    serve.total.resident_weight_bytes =
        per_worker.iter().map(|w| w.resident_weight_bytes).max().filter(|&b| b > 0);
    Ok(PoolServeStats {
        serve,
        workers,
        steals: sched.steals(),
        serving_wall_secs: if serving_wall > 0.0 { serving_wall } else { wall },
        per_worker,
    })
}

/// Worker entry point: build the replica, check in at the go-live
/// barrier, then serve.  On setup failure the worker steps aside —
/// healthy siblings absorb its shard through stealing — and only when
/// *every* replica failed does the last one drain the queues with
/// errors, so no request ever hangs and none is failed while a healthy
/// replica could have served it.
#[allow(clippy::too_many_arguments)]
fn worker_main(
    wid: usize,
    spec: &EngineSpec,
    source: &SharedAdapterSource,
    sched: &ShardedScheduler,
    epoch: Instant,
    ready: &Barrier,
    failed: &AtomicUsize,
    obs: &ServeObs,
    policy: &SessionPolicy,
) -> WorkerOutcome {
    let mut out = WorkerOutcome {
        worker: wid,
        capacity: 0,
        resident_weight_bytes: 0,
        setup_secs: 0.0,
        setup_error: None,
    };
    match worker_serve(wid, spec, source, sched, epoch, ready, obs, policy, &mut out) {
        Ok(()) => {}
        Err(e) => {
            let msg = format!("worker {wid} replica setup failed: {e:#}");
            out.setup_error = Some(format!("{e:#}"));
            out.setup_secs = epoch.elapsed().as_secs_f64();
            obs.setup_failure(wid);
            let all_failed = failed.fetch_add(1, Ordering::SeqCst) + 1 == sched.shards();
            ready.wait();
            if !all_failed {
                return out; // a healthy sibling serves (and steals) instead
            }
            while let Some((reqs, stolen)) = sched.next_work(wid, Instant::now()) {
                obs.dispatch(wid, &reqs, stolen);
                let mut recs = RecorderCache::new(obs, wid);
                for req in reqs {
                    recs.get(&req.adapter_id).error(&req, 0, &msg);
                    let _ = req.reply.send(Err(anyhow!(msg.clone())));
                }
            }
        }
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn worker_serve(
    wid: usize,
    spec: &EngineSpec,
    source: &SharedAdapterSource,
    sched: &ShardedScheduler,
    epoch: Instant,
    ready: &Barrier,
    obs: &ServeObs,
    policy: &SessionPolicy,
    out: &mut WorkerOutcome,
) -> Result<()> {
    // route the below-serve-layer failpoints (runtime upload, registry
    // replication) through this worker's thread
    let _fault_guard = crate::faults::install(&policy.faults);
    // the replica: everything below is thread-local, including the PJRT
    // client and every device buffer
    let rt = Runtime::new(&spec.artifacts)
        .with_context(|| format!("worker {wid}: loading artifacts {:?}", spec.artifacts))?;
    let engine = Engine::new(
        &rt,
        &spec.config,
        &spec.frozen,
        None,
        &spec.eval_kind,
        spec.max_new_tokens,
    )
    .with_context(|| format!("worker {wid}: building engine replica"))?;
    out.capacity = engine.artifact_batch()?;
    out.resident_weight_bytes = engine.resident_weight_bytes();
    // dispatched batches must fit the decode slots (idempotent across
    // workers; runs before the barrier, so before any dispatch)
    sched.clamp_max_batch(out.capacity);
    // compile the serving executable now, not on the first request:
    // setup_secs should cover it, and first-token latency shouldn't
    // (tenants on a different eval kind still compile lazily, once)
    rt.executable(&spec.config, &spec.eval_kind)
        .with_context(|| format!("worker {wid}: compiling '{}'", spec.eval_kind))?;
    // the KV-cached split compiles in the same setup window when present
    // (stale artifact dirs skip it and the engine runs full forwards)
    for kind in engine.cache_kinds(&spec.eval_kind).into_iter().flatten() {
        rt.executable(&spec.config, kind)
            .with_context(|| format!("worker {wid}: compiling '{kind}'"))?;
    }
    let mut registry = AdapterRegistry::new(spec.registry_capacity.max(source.capacity()));
    registry.bind_obs(obs.registry(), wid);
    if let Some(t) = obs.trace() {
        registry.bind_trace(t.clone());
    }
    registry.set_device_budget(spec.device_budget);
    registry.set_degrade_ranks(&spec.degrade_ranks);
    // gathered banks, same eligibility rule as `Router::setup_gathered`:
    // enable *before* the first sync so replicated tenants land in bank
    // slots as they register (each resident registration flushes its
    // slices), and compile the gathered executable inside the setup
    // window like the uniform kind above
    if engine.supports_gathered() {
        if let Some(slots) = rt
            .manifest
            .config(&spec.config)
            .ok()
            .and_then(|c| c.artifacts.get(GATHERED_KIND))
            .and_then(gathered_slots)
        {
            if registry.capacity() <= slots.saturating_sub(1)
                && registry.enable_gathered(rt.model(&spec.config)?, slots).is_ok()
            {
                rt.executable(&spec.config, GATHERED_KIND)
                    .with_context(|| format!("worker {wid}: compiling '{GATHERED_KIND}'"))?;
                for kind in engine.cache_kinds(GATHERED_KIND).into_iter().flatten() {
                    rt.executable(&spec.config, kind)
                        .with_context(|| format!("worker {wid}: compiling '{kind}'"))?;
                }
            }
        }
    }
    let mut cursor = 0u64;
    source
        .sync(&mut registry, Some(&rt), &mut cursor)
        .with_context(|| format!("worker {wid}: replicating resident tenants"))?;
    out.setup_secs = epoch.elapsed().as_secs_f64();
    obs.set_worker_gauges(wid, out.capacity, out.resident_weight_bytes);
    ready.wait(); // go live together (see serve_pool)
    while let Some((reqs, stolen)) = sched.next_work(wid, Instant::now()) {
        obs.dispatch(wid, &reqs, stolen);
        // pick up registrations/evictions before resolving tenants; a
        // failed sync fails this batch but keeps the worker serving (the
        // unchanged cursor retries the same changes next session)
        if let Err(e) = source.sync(&mut registry, Some(&rt), &mut cursor) {
            let msg = format!("worker {wid}: syncing tenant changes: {e:#}");
            let mut recs = RecorderCache::new(obs, wid);
            for req in reqs {
                recs.get(&req.adapter_id).error(&req, 0, &msg);
                let _ = req.reply.send(Err(anyhow!(msg.clone())));
            }
            continue;
        }
        obs.session_start(wid, stolen);
        // the pen: the claimed batch lives outside the unwind boundary, so
        // a session that panics before taking it leaves it recoverable.
        // The injected crash point (`SITE_WORKER_PANIC`) fires before the
        // take — modelling the realistic worst case of a worker dying
        // right after claiming work — so chaos runs are deterministic.  A
        // panic *after* the take unwinds the session's slot state, and
        // those clients see their reply channel close (at-most-once).
        let pen = Mutex::new(Some(reqs));
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            let _ = policy.faults.check(crate::faults::SITE_WORKER_PANIC);
            let reqs = lock_recover(&pen).take().expect("pen filled above");
            // mid-session refill: mixed sessions take any shard work
            // (home first, then steal); uniform fallback sessions stay on
            // their tenant so device buffers never switch mid-flight
            let mut refill = |filter: Option<&Option<String>>, free: usize| match filter {
                None => sched.admit(wid, Instant::now(), free),
                Some(gid) => sched.admit_for(gid, Instant::now(), free),
            };
            serve_batch(&engine, &mut registry, wid, reqs, &mut refill, obs, policy)
        }));
        let survivors: Vec<Request> = match outcome {
            Ok(survivors) => survivors,
            Err(_) => {
                // the session crashed; the worker itself lives on.  Charge
                // every recovered request one attempt (at-most-once: a row
                // that might have half-decoded is never silently re-run
                // past its budget)
                obs.worker_crash(wid);
                let recovered = lock_recover(&pen).take().unwrap_or_default();
                let msg = format!("worker {wid} crashed while serving this batch");
                let mut recs = RecorderCache::new(obs, wid);
                let mut live = Vec::new();
                for mut req in recovered {
                    req.attempts += 1;
                    if req.attempts > policy.max_retries {
                        recs.get(&req.adapter_id).error(&req, 0, &msg);
                        let _ =
                            req.reply.send(Err(anyhow::Error::new(ServeError::EngineFailure {
                                attempts: req.attempts,
                                message: msg.clone(),
                            })));
                    } else {
                        live.push(req);
                    }
                }
                live
            }
        };
        if !survivors.is_empty() {
            // back to the queue for a fresh session — possibly on a
            // sibling worker (requeue wakes one); works even after close
            let n = survivors.len();
            for req in survivors {
                sched.requeue(req);
            }
            obs.session_rebuilt(wid, n);
        }
    }
    Ok(())
}

/// Drive a worker pool with a synthetic open-loop workload (the pool
/// analog of [`benchmark_router`](super::benchmark_router)): one producer
/// thread sends `(adapter_id, prompt)` requests at `inter_arrival`
/// spacing, the pool serves them, and the measured stats come back.
pub fn benchmark_pool(
    spec: &EngineSpec,
    source: &SharedAdapterSource,
    requests: Vec<(Option<String>, String)>,
    inter_arrival: Duration,
    opts: PoolOpts,
) -> Result<PoolServeStats> {
    benchmark_pool_obs(spec, source, requests, inter_arrival, opts, ServeObs::new())
}

/// [`benchmark_pool`] with a caller-supplied observability context.
pub fn benchmark_pool_obs(
    spec: &EngineSpec,
    source: &SharedAdapterSource,
    requests: Vec<(Option<String>, String)>,
    inter_arrival: Duration,
    opts: PoolOpts,
    obs: ServeObs,
) -> Result<PoolServeStats> {
    let (tx, rx) = channel::<Request>();
    let producer = std::thread::spawn(move || {
        let mut replies = Vec::new();
        for (adapter_id, prompt) in requests {
            let (rtx, rrx) = channel();
            let _ = tx.send(Request::new(adapter_id, prompt, rtx));
            replies.push(rrx);
            if !inter_arrival.is_zero() {
                std::thread::sleep(inter_arrival);
            }
        }
        drop(tx);
        // drain replies so worker sends don't error
        for r in replies {
            let _ = r.recv();
        }
    });
    let stats = serve_pool_obs(spec, source, rx, opts, obs);
    producer.join().ok();
    stats
}
