//! Typed serving error taxonomy.
//!
//! Every way the serving stack refuses or loses a request maps to one
//! [`ServeError`] variant, delivered through the request's reply channel
//! as an `anyhow::Error` that downcasts back to the enum — so clients,
//! tests, and the chaos bench can branch on the *kind* of failure instead
//! of string-matching messages:
//!
//!   - [`ServeError::Overloaded`]: rejected at enqueue — the scheduler
//!     shard's queue is at its configured cap (`serve --queue-cap`);
//!     backpressure, not failure: retry later or elsewhere;
//!   - [`ServeError::DeadlineExceeded`]: shed — the request's deadline
//!     (`serve --deadline-ms`, or a per-request `Request::deadline`)
//!     expired before a decode slot ran it;
//!   - [`ServeError::Cancelled`]: the client walked away mid-flight (its
//!     [`CancelHandle`](super::scheduler::CancelHandle) dropped), so the
//!     slot was retired early;
//!   - [`ServeError::EngineFailure`]: a decode session failed persistently
//!     (step retries exhausted) or its worker crashed, and this request's
//!     re-admission budget (`serve --max-retries`) is spent;
//!   - [`ServeError::TenantUnavailable`]: the request names a tenant the
//!     registry cannot serve — never registered, or quarantined because
//!     its adapter checkpoint failed integrity/validation checks (the
//!     `reason` says which).  Quarantine is per-tenant: siblings keep
//!     serving, and the quarantined id stays refused until re-registered
//!     from a good checkpoint.
//!
//! Use [`ServeError::of`] to classify a reply error; `None` means an
//! untyped failure (setup errors, prompt validation).

use std::fmt;

/// The serving stack's typed failure modes (see module docs).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Rejected at enqueue: the scheduler queue is at `queue_cap`.
    Overloaded { queue_cap: usize },
    /// Shed: the deadline expired after waiting `waited_ms` in queue.
    DeadlineExceeded { waited_ms: u64 },
    /// The client cancelled (dropped its handle) while in flight.
    Cancelled,
    /// Decode failed persistently; `attempts` re-admissions were spent.
    EngineFailure { attempts: usize, message: String },
    /// The tenant can't serve: unregistered, or quarantined after its
    /// checkpoint failed integrity/validation (`reason` says which).
    TenantUnavailable { tenant: String, reason: String },
}

impl ServeError {
    /// Stable machine-readable kind tag (used in metrics labels and the
    /// chaos bench report).
    pub fn kind(&self) -> &'static str {
        match self {
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::DeadlineExceeded { .. } => "deadline_exceeded",
            ServeError::Cancelled => "cancelled",
            ServeError::EngineFailure { .. } => "engine_failure",
            ServeError::TenantUnavailable { .. } => "tenant_unavailable",
        }
    }

    /// Downcast a reply error back to the taxonomy (`None` = untyped).
    pub fn of(err: &anyhow::Error) -> Option<&ServeError> {
        err.downcast_ref::<ServeError>()
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::Overloaded { queue_cap } => {
                write!(f, "overloaded: scheduler queue at cap {queue_cap}")
            }
            ServeError::DeadlineExceeded { waited_ms } => {
                write!(f, "deadline exceeded after {waited_ms} ms in queue")
            }
            ServeError::Cancelled => write!(f, "cancelled by client"),
            ServeError::EngineFailure { attempts, message } => {
                write!(f, "engine failure after {attempts} attempt(s): {message}")
            }
            ServeError::TenantUnavailable { tenant, reason } => {
                write!(f, "tenant '{tenant}' unavailable: {reason}")
            }
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn downcasts_through_anyhow() {
        let err = anyhow::Error::new(ServeError::Overloaded { queue_cap: 8 });
        match ServeError::of(&err) {
            Some(ServeError::Overloaded { queue_cap }) => assert_eq!(*queue_cap, 8),
            other => panic!("bad downcast: {other:?}"),
        }
        assert_eq!(ServeError::of(&err).unwrap().kind(), "overloaded");
        let untyped = anyhow::anyhow!("plain");
        assert!(ServeError::of(&untyped).is_none());
    }

    #[test]
    fn display_is_informative() {
        let e = ServeError::EngineFailure { attempts: 3, message: "boom".into() };
        let s = e.to_string();
        assert!(s.contains("3") && s.contains("boom"));
        assert_eq!(ServeError::DeadlineExceeded { waited_ms: 12 }.kind(), "deadline_exceeded");
        assert_eq!(ServeError::Cancelled.kind(), "cancelled");
        let t = ServeError::TenantUnavailable {
            tenant: "t3".into(),
            reason: "quarantined: corrupt checkpoint (f32 payload section)".into(),
        };
        assert_eq!(t.kind(), "tenant_unavailable");
        let s = t.to_string();
        assert!(s.contains("t3") && s.contains("quarantined"), "{s}");
    }
}
