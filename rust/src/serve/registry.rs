//! Per-tenant adapter registry for multi-tenant serving.
//!
//! One `Engine` owns the device-resident frozen base; every tenant's tuned
//! adapter (LoRA/NLS tensors + realized rank configuration) is uploaded to
//! the device **once, at registration** (`register_resident`), so the
//! steady-state decode loop ships only the token batch across the PJRT
//! boundary.  The registry validates entries against the model
//! hyperparameters at registration (shape bugs surface at load time, not
//! mid-serve), supports hot registration/eviction, and bounds resident
//! state with an LRU policy: serving an adapter touches it, and
//! registering past capacity evicts the least-recently-used tenant —
//! dropping its device buffers along with the host entry.  The host-only
//! `register` path is kept for callers without a runtime handle; those
//! tenants serve through the per-forward host-upload fallback.
//!
//! # Residency tiers and rank-elastic degradation
//!
//! Beyond the flat LRU, the registry models a **disk → host → device**
//! residency ladder.  Validated host entries survive device demotion, so
//! re-promoting a warm tenant re-uploads from host instead of re-reading
//! and re-validating disk; [`AdapterRegistry::catalog_disk`] records where
//! a cold tenant's checkpoint lives so [`AdapterRegistry::prefetch_host`]
//! can pull it into the host tier when its traffic arrives.  Device
//! residency is bounded by a *logical byte budget*
//! ([`AdapterRegistry::set_device_budget`], modeling HBM on
//! rank-specialized hardware: a tenant served at rank d is charged the
//! bytes of its rank-d adapter slices even though the XLA artifact inputs
//! stay r_max-shaped with a zeroed tail).  Under budget pressure
//! [`AdapterRegistry::ensure_device`] degrades tenants down the elastic
//! rank ladder ([`AdapterRegistry::set_degrade_ranks`], reusing the NLS
//! realize semantics via [`crate::nls::degrade_rank_params`]) instead of
//! refusing them, and restores full rank when pressure drops; every
//! transition is counted (`registry_degraded_total` /
//! `registry_restored_total`) and traced.  A checkpoint that fails
//! integrity or validation **quarantines only that tenant**
//! ([`AdapterRegistry::quarantine`]): its id serves typed
//! `TenantUnavailable` refusals while siblings keep serving.

use crate::model::checkpoint::{self, AdapterCkpt};
use crate::model::ParamSet;
use crate::obs::{Counter, Gauge, Registry, Series, TraceLog};
use crate::runtime::{DeviceStore, ModelHyper, Runtime};
use crate::serve::error::ServeError;
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::sync::lock_recover;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One registered tenant: id, eval artifact kind, and the host-side
/// per-forward input sets (`[adapters (a_/b_), rank params]`, resolved in
/// order by `build_args` — same contract as `evaluate_unmerged`; the
/// adapter masks stay device-resident with the shared frozen base).
#[derive(Clone, Debug)]
pub struct AdapterEntry {
    pub id: String,
    /// "eval" (FP16 base) or "eval_qa" (shared-scale fake-quant base)
    pub eval_kind: String,
    pub host_sets: Vec<ParamSet>,
}

impl AdapterEntry {
    /// Build a registry entry from a loaded adapter checkpoint (the id
    /// falls back to `fallback_id` when the metadata carries none).
    pub fn from_ckpt(ck: AdapterCkpt, fallback_id: &str) -> AdapterEntry {
        let id = if ck.adapter_id.is_empty() { fallback_id.to_string() } else { ck.adapter_id };
        AdapterEntry {
            id,
            eval_kind: ck.eval_kind,
            host_sets: vec![ck.adapters, ck.rank_params],
        }
    }
}

/// Load every `*.ckpt` adapter checkpoint in `dir` (sorted by file name)
/// without registering anything, so the caller can inspect the metadata
/// (method, sparsity) and prepare a matching base first.  Checkpoints
/// tuned for a different model config are an error.
pub fn load_adapter_dir(dir: &Path, config: &str) -> Result<Vec<AdapterCkpt>> {
    let mut files: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading adapter dir {dir:?}"))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "ckpt").unwrap_or(false))
        .collect();
    files.sort();
    if files.is_empty() {
        bail!("no *.ckpt adapter checkpoints in {dir:?}");
    }
    let mut out = Vec::new();
    for path in files {
        let mut ck = checkpoint::load_adapter(&path)
            .with_context(|| format!("loading adapter {path:?}"))?;
        if ck.config != config {
            bail!(
                "adapter {path:?} was tuned for config '{}', engine runs '{config}'",
                ck.config
            );
        }
        if ck.adapter_id.is_empty() {
            ck.adapter_id = path
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("adapter")
                .to_string();
        }
        out.push(ck);
    }
    Ok(out)
}

/// Fault-tolerant variant of [`load_adapter_dir`]: a checkpoint that fails
/// to load (corrupt container, wrong kind, config mismatch) is returned as
/// a `(tenant_id, path, reason)` casualty instead of failing the whole
/// directory, so one torn file quarantines one tenant while siblings keep
/// serving.  The tenant id of a casualty is the file stem (the metadata is
/// unreadable by definition).  An empty directory is still an error — a
/// serve fleet with zero loadable adapters is a misconfiguration, not a
/// degraded state.
pub fn load_adapter_dir_tolerant(
    dir: &Path,
    config: &str,
) -> Result<(Vec<AdapterCkpt>, Vec<(String, PathBuf, String)>)> {
    let mut files: Vec<_> = std::fs::read_dir(dir)
        .with_context(|| format!("reading adapter dir {dir:?}"))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "ckpt").unwrap_or(false))
        .collect();
    files.sort();
    if files.is_empty() {
        bail!("no *.ckpt adapter checkpoints in {dir:?}");
    }
    let mut good = Vec::new();
    let mut bad = Vec::new();
    for path in files {
        let stem =
            path.file_stem().and_then(|s| s.to_str()).unwrap_or("adapter").to_string();
        match checkpoint::load_adapter(&path) {
            Ok(mut ck) => {
                if ck.config != config {
                    bad.push((
                        stem,
                        path.clone(),
                        format!("tuned for config '{}', engine runs '{config}'", ck.config),
                    ));
                    continue;
                }
                if ck.adapter_id.is_empty() {
                    ck.adapter_id = stem;
                }
                good.push(ck);
            }
            Err(e) => bad.push((stem, path.clone(), format!("{e:#}"))),
        }
    }
    if good.is_empty() {
        bail!(
            "no loadable adapter checkpoints in {dir:?} ({} corrupt/mismatched)",
            bad.len()
        );
    }
    Ok((good, bad))
}

/// Slot count of the `eval_gathered` artifact's adapter banks, read back
/// from the manifest input specs (never from the Python-side constant):
/// the leading dimension of any `a_bank_*` input.
pub fn gathered_slots(spec: &crate::runtime::ArtifactSpec) -> Option<usize> {
    spec.inputs
        .iter()
        .find(|i| i.name.starts_with("a_bank_"))
        .map(|i| i.shape[0])
}

/// Gathered adapter banks for mixed-tenant decode (S-LoRA/punica style):
/// every tenant's LoRA/NLS tensors stacked along a leading slot axis `T`
/// (`a_bank_<mod>: (T, L, r, in)` etc., matching the `eval_gathered`
/// artifact inputs), so one forward serves a *mixed* batch by picking
/// per-row slices with an i32 index vector instead of switching device
/// buffer sets between sessions.
///
/// Slot 0 is reserved for the identity adapter (`B = 0`): rows with no
/// tenant — the merged / `adapter_id: None` path — batch together with
/// adapted rows and still compute the plain base projection.  Tenants
/// occupy slots `1..T`, lowest free slot first.
///
/// Registration overwrites the tenant's contiguous host-side slice and
/// marks the bank tensor dirty; `flush` re-uploads dirty tensors (PJRT
/// buffers are immutable, so a slice write costs one whole-bank upload
/// at registration time — never on the decode hot path, which ships only
/// tokens + indices).  Eviction just recycles the slot: no live row
/// indexes a freed slot, and re-registration overwrites the full slice
/// before the slot is handed out again.  The Wanda masks are *not*
/// banked — they belong to the shared sparsified base and stay resident
/// with it.
pub struct GatheredBank {
    slots: usize,
    host: ParamSet,
    device: DeviceStore,
    assign: BTreeMap<String, usize>,
    /// recycled tenant slots, descending so `pop()` hands out the lowest
    free: Vec<usize>,
    /// bank tensor names written on the host but not yet re-uploaded
    dirty: std::collections::BTreeSet<String>,
}

fn bank_specs(hyper: &ModelHyper, slots: usize) -> Vec<(String, Vec<usize>)> {
    let (l, r, t) = (hyper.n_layers, hyper.r_max, slots);
    let mut specs = Vec::new();
    for m in &hyper.mods {
        let (out, inp) = hyper.mod_dims(m);
        specs.push((format!("a_bank_{m}"), vec![t, l, r, inp]));
        specs.push((format!("b_bank_{m}"), vec![t, l, out, r]));
        specs.push((format!("rankmask_bank_{m}"), vec![t, l, r]));
        specs.push((format!("scale_bank_{m}"), vec![t, l]));
    }
    specs
}

impl GatheredBank {
    /// Zero-initialized banks: slot 0 (identity, `B = 0`) is correct by
    /// construction, and unassigned slots behave as identity too.
    pub fn new(hyper: &ModelHyper, slots: usize) -> Result<GatheredBank> {
        if slots < 2 {
            bail!("gathered bank needs >= 2 slots (slot 0 is the identity adapter), got {slots}");
        }
        let mut host = ParamSet::new();
        let mut dirty = std::collections::BTreeSet::new();
        for (name, shape) in bank_specs(hyper, slots) {
            host.insert(&name, Tensor::zeros(&shape));
            dirty.insert(name);
        }
        Ok(GatheredBank {
            slots,
            host,
            device: DeviceStore::new(),
            assign: BTreeMap::new(),
            free: (1..slots).rev().collect(),
            dirty,
        })
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Tenants the bank can hold (slot 0 is never assigned).
    pub fn tenant_capacity(&self) -> usize {
        self.slots - 1
    }

    pub fn assigned(&self) -> usize {
        self.assign.len()
    }

    /// The tenant's bank slot, if registered.
    pub fn slot(&self, id: &str) -> Option<usize> {
        self.assign.get(id).copied()
    }

    /// Host-side bank tensors (tests and host-only callers).
    pub fn host(&self) -> &ParamSet {
        &self.host
    }

    /// Device-resident bank buffers (populated by `flush`).
    pub fn device(&self) -> &DeviceStore {
        &self.device
    }

    /// Write a validated entry into its slot (existing tenants keep their
    /// slot — a replace overwrites the same slice) and return the slot.
    pub fn register(&mut self, entry: &AdapterEntry) -> Result<usize> {
        let slot = match self.assign.get(&entry.id) {
            Some(&s) => s,
            None => match self.free.pop() {
                Some(s) => {
                    self.assign.insert(entry.id.clone(), s);
                    s
                }
                None => bail!(
                    "no free adapter-bank slot for '{}' ({} tenant slots; \
                     evict a tenant or lower the registry capacity)",
                    entry.id,
                    self.slots - 1
                ),
            },
        };
        let names: Vec<String> = self.host.names().cloned().collect();
        for bank_name in names {
            let src_name = bank_name.replace("_bank_", "_");
            let src = find(&entry.host_sets, &src_name).with_context(|| {
                format!("adapter '{}': missing tensor '{src_name}' for bank write", entry.id)
            })?;
            let dst = self.host.get_mut(&bank_name)?;
            let n = src.data().len();
            dst.data_mut()[slot * n..(slot + 1) * n].copy_from_slice(src.data());
            self.dirty.insert(bank_name);
        }
        Ok(slot)
    }

    /// Recycle the tenant's slot (device untouched — see type docs).
    /// True if the tenant was banked.
    pub fn evict(&mut self, id: &str) -> bool {
        match self.assign.remove(id) {
            Some(slot) => {
                self.free.push(slot);
                self.free.sort_unstable_by(|a, b| b.cmp(a));
                true
            }
            None => false,
        }
    }

    /// Upload every dirty bank tensor; returns how many were uploaded.
    pub fn flush(&mut self, rt: &Runtime) -> Result<usize> {
        let names = std::mem::take(&mut self.dirty);
        let n = names.len();
        for name in names {
            let t = self.host.get(&name)?;
            self.device
                .put_tensor(&rt.client, &name, t)
                .with_context(|| format!("uploading bank tensor '{name}'"))?;
        }
        Ok(n)
    }
}

/// LRU-bounded map from adapter id to validated host state, plus (for
/// tenants registered through `register_resident`) the device-resident
/// copy of that state keyed by the same id.  Dropping a `DeviceStore`
/// drops its `PjRtBuffer`s, so eviction releases device memory.
///
/// With [`AdapterRegistry::enable_gathered`] the registry additionally
/// maintains a [`GatheredBank`]: every registration writes the tenant's
/// slice and every eviction/replacement recycles it, so the bank always
/// mirrors the resident set.
pub struct AdapterRegistry {
    capacity: usize,
    clock: u64,
    entries: BTreeMap<String, (u64, AdapterEntry)>,
    device_sets: BTreeMap<String, DeviceStore>,
    evictions: Vec<String>,
    obs: Option<RegistryObs>,
    bank: Option<GatheredBank>,
    /// logical device-byte budget; 0 = unbounded (the legacy flat path)
    device_budget: usize,
    /// elastic degradation ladder, descending ranks (empty = never degrade)
    degrade_ladder: Vec<usize>,
    /// logical bytes charged per device-resident tenant (at serving rank)
    device_bytes: BTreeMap<String, usize>,
    /// id → reduced serving rank for currently-degraded tenants
    degraded: BTreeMap<String, usize>,
    /// disk catalog for the cold tier: id → checkpoint path
    disk: BTreeMap<String, PathBuf>,
    /// id → reason for tenants refused after a corrupt/invalid checkpoint
    quarantined: BTreeMap<String, String>,
    trace: Option<Arc<TraceLog>>,
}

/// Registry instruments (bound per worker replica): registration and
/// eviction event counters plus resident-state level gauges, and — for
/// the tiered-residency path — quarantine/degrade/restore transition
/// counters, per-tier resident gauges, and cold-start latency series
/// keyed by the tier the promotion started from.
struct RegistryObs {
    registrations: Arc<Counter>,
    evictions: Arc<Counter>,
    resident: Arc<Gauge>,
    resident_bytes: Arc<Gauge>,
    quarantined: Arc<Counter>,
    degraded: Arc<Counter>,
    restored: Arc<Counter>,
    tier_disk: Arc<Gauge>,
    tier_host: Arc<Gauge>,
    tier_device: Arc<Gauge>,
    cold_start_disk: Arc<Series>,
    cold_start_host: Arc<Series>,
}

fn find<'s>(sets: &'s [ParamSet], name: &str) -> Option<&'s Tensor> {
    sets.iter().find_map(|s| if s.contains(name) { s.get(name).ok() } else { None })
}

fn expect_shape(id: &str, name: &str, t: &Tensor, want: &[usize]) -> Result<()> {
    if t.shape() != want {
        bail!("adapter '{id}': tensor '{name}' has shape {:?}, want {want:?}", t.shape());
    }
    Ok(())
}

impl AdapterRegistry {
    /// `capacity` is the maximum number of resident tenants (min 1).
    pub fn new(capacity: usize) -> AdapterRegistry {
        AdapterRegistry {
            capacity: capacity.max(1),
            clock: 0,
            entries: BTreeMap::new(),
            device_sets: BTreeMap::new(),
            evictions: Vec::new(),
            obs: None,
            bank: None,
            device_budget: 0,
            degrade_ladder: Vec::new(),
            device_bytes: BTreeMap::new(),
            degraded: BTreeMap::new(),
            disk: BTreeMap::new(),
            quarantined: BTreeMap::new(),
            trace: None,
        }
    }

    /// Attach a [`GatheredBank`] with `slots` slots (read from the
    /// `eval_gathered` manifest specs via [`gathered_slots`]).  Tenants
    /// already resident are backfilled in id order; from here on every
    /// registration/eviction keeps the bank in lockstep.  The bank must
    /// hold at least `capacity` tenants so bank exhaustion can never
    /// strand a registration the LRU bound admitted.
    pub fn enable_gathered(&mut self, hyper: &ModelHyper, slots: usize) -> Result<()> {
        let mut bank = GatheredBank::new(hyper, slots)?;
        if self.capacity > bank.tenant_capacity() {
            bail!(
                "registry capacity {} exceeds the {} tenant slots of the gathered bank; \
                 lower the capacity or regenerate artifacts with more slots",
                self.capacity,
                bank.tenant_capacity()
            );
        }
        for (_, entry) in self.entries.values() {
            bank.register(entry)?;
        }
        self.bank = Some(bank);
        Ok(())
    }

    /// The gathered bank, if enabled.
    pub fn bank(&self) -> Option<&GatheredBank> {
        self.bank.as_ref()
    }

    /// The tenant's bank slot, if the bank is enabled and the tenant is
    /// registered.
    pub fn bank_slot(&self, id: &str) -> Option<usize> {
        self.bank.as_ref().and_then(|b| b.slot(id))
    }

    /// Upload dirty bank tensors (no-op without a bank); returns how many
    /// tensors went up.
    pub fn flush_bank(&mut self, rt: &Runtime) -> Result<usize> {
        match self.bank.as_mut() {
            Some(b) => b.flush(rt),
            None => Ok(0),
        }
    }

    /// Mirror a just-inserted entry into the bank (no-op without one).
    fn bank_write(&mut self, id: &str) -> Result<()> {
        let Some(bank) = self.bank.as_mut() else { return Ok(()) };
        let Some((_, entry)) = self.entries.get(id) else { return Ok(()) };
        bank.register(entry)?;
        Ok(())
    }

    /// Export this registry's state into a metrics registry (labelled by
    /// `worker`, since pool replicas each carry one): registration and
    /// eviction counters count events from now on; the resident-tenant /
    /// resident-byte gauges reflect current contents immediately.
    pub fn bind_obs(&mut self, reg: &Registry, worker: usize) {
        let w = worker.to_string();
        let l = [("worker", w.as_str())];
        self.obs = Some(RegistryObs {
            registrations: reg.counter("registry_registrations_total", &l),
            evictions: reg.counter("registry_evictions_total", &l),
            resident: reg.gauge("registry_resident_adapters", &l),
            resident_bytes: reg.gauge("registry_resident_adapter_bytes", &l),
            quarantined: reg.counter("registry_quarantined_total", &l),
            degraded: reg.counter("registry_degraded_total", &l),
            restored: reg.counter("registry_restored_total", &l),
            tier_disk: reg.gauge("registry_tier_residents", &[("tier", "disk"), ("worker", w.as_str())]),
            tier_host: reg.gauge("registry_tier_residents", &[("tier", "host"), ("worker", w.as_str())]),
            tier_device: reg
                .gauge("registry_tier_residents", &[("tier", "device"), ("worker", w.as_str())]),
            cold_start_disk: reg
                .series("registry_cold_start_ms", &[("tier", "disk"), ("worker", w.as_str())]),
            cold_start_host: reg
                .series("registry_cold_start_ms", &[("tier", "host"), ("worker", w.as_str())]),
        });
        self.refresh_obs();
    }

    /// Attach a trace log so tier transitions (quarantine, degrade,
    /// restore) land in the per-request trace stream.
    pub fn bind_trace(&mut self, trace: Arc<TraceLog>) {
        self.trace = Some(trace);
    }

    /// Re-level the resident gauges after any mutation: tenant count and
    /// total host-state bytes of the registered entries (the same tensors
    /// `register_resident` keeps device-resident), plus the per-tier
    /// occupancy ladder — `device` counts tenants with resident device
    /// buffers, `host` counts validated entries *not* on device, and
    /// `disk` counts cataloged checkpoints not yet loaded (quarantined
    /// ids count in no tier).
    fn refresh_obs(&self) {
        if let Some(o) = &self.obs {
            o.resident.set(self.entries.len() as f64);
            let bytes: usize = self
                .entries
                .values()
                .map(|(_, e)| e.host_sets.iter().map(|s| s.total_bytes()).sum::<usize>())
                .sum();
            o.resident_bytes.set(bytes as f64);
            let device = self.device_sets.len();
            let host = self.entries.keys().filter(|id| !self.device_sets.contains_key(*id)).count();
            let disk = self
                .disk
                .keys()
                .filter(|id| {
                    !self.entries.contains_key(*id) && !self.quarantined.contains_key(*id)
                })
                .count();
            o.tier_device.set(device as f64);
            o.tier_host.set(host as f64);
            o.tier_disk.set(disk as f64);
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn contains(&self, id: &str) -> bool {
        self.entries.contains_key(id)
    }

    pub fn ids(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    /// Tenants evicted by the LRU bound, oldest first.
    pub fn evictions(&self) -> &[String] {
        &self.evictions
    }

    /// Check an entry against the model: every adapted module needs
    /// `a_`/`b_` at the artifact shapes plus a realized rank configuration
    /// (prefix 0/1 `rankmask_` rows and per-layer `scale_`).
    pub fn validate(hyper: &ModelHyper, entry: &AdapterEntry) -> Result<()> {
        if entry.id.is_empty() {
            bail!("adapter id must be non-empty");
        }
        if entry.eval_kind != "eval" && entry.eval_kind != "eval_qa" {
            bail!("adapter '{}': unknown eval kind '{}'", entry.id, entry.eval_kind);
        }
        let (l, r) = (hyper.n_layers, hyper.r_max);
        for m in &hyper.mods {
            let (out, inp) = hyper.mod_dims(m);
            let a = find(&entry.host_sets, &format!("a_{m}"))
                .with_context(|| format!("adapter '{}': missing tensor 'a_{m}'", entry.id))?;
            expect_shape(&entry.id, &format!("a_{m}"), a, &[l, r, inp])?;
            let b = find(&entry.host_sets, &format!("b_{m}"))
                .with_context(|| format!("adapter '{}': missing tensor 'b_{m}'", entry.id))?;
            expect_shape(&entry.id, &format!("b_{m}"), b, &[l, out, r])?;
            if let Some(mask) = find(&entry.host_sets, &format!("mask_{m}")) {
                expect_shape(&entry.id, &format!("mask_{m}"), mask, &[l, out, inp])?;
            }
            let rm = find(&entry.host_sets, &format!("rankmask_{m}")).with_context(|| {
                format!("adapter '{}': missing rank configuration 'rankmask_{m}'", entry.id)
            })?;
            expect_shape(&entry.id, &format!("rankmask_{m}"), rm, &[l, r])?;
            for layer in 0..l {
                let row = &rm.data()[layer * r..(layer + 1) * r];
                let mut seen_zero = false;
                for &x in row {
                    if x != 0.0 && x != 1.0 {
                        bail!("adapter '{}': rankmask_{m} has non-binary value {x}", entry.id);
                    }
                    if x == 0.0 {
                        seen_zero = true;
                    } else if seen_zero {
                        bail!(
                            "adapter '{}': rankmask_{m} layer {layer} is not a prefix mask",
                            entry.id
                        );
                    }
                }
            }
            let sc = find(&entry.host_sets, &format!("scale_{m}"))
                .with_context(|| format!("adapter '{}': missing 'scale_{m}'", entry.id))?;
            expect_shape(&entry.id, &format!("scale_{m}"), sc, &[l])?;
        }
        Ok(())
    }

    /// Validate + insert host-side only (replacing any same-id entry);
    /// returns the id evicted by the LRU bound, if any.  A replaced or
    /// evicted tenant's device buffers are dropped — a stale device set
    /// must never shadow freshly registered weights.
    pub fn register(&mut self, hyper: &ModelHyper, entry: AdapterEntry) -> Result<Option<String>> {
        Self::validate(hyper, &entry)?;
        let id = entry.id.clone();
        let evicted = self.insert_validated(entry);
        if let Err(e) = self.bank_write(&id) {
            // bank exhaustion (capacity misconfiguration): roll the insert
            // back so registry and bank never disagree on the resident set
            self.entries.remove(&id);
            self.device_sets.remove(&id);
            self.refresh_obs();
            return Err(e);
        }
        Ok(evicted)
    }

    /// Insert an already-validated entry: bump the clock, drop any stale
    /// same-id device set, apply the LRU bound.  Every registration path
    /// funnels through here so validation runs exactly once per entry.
    fn insert_validated(&mut self, entry: AdapterEntry) -> Option<String> {
        self.clock += 1;
        let id = entry.id.clone();
        self.device_sets.remove(&id);
        self.device_bytes.remove(&id);
        self.degraded.remove(&id);
        // a fresh registration is the cure for quarantine: the new entry
        // passed validation, so the tenant serves again
        self.quarantined.remove(&id);
        self.entries.insert(id.clone(), (self.clock, entry));
        if let Some(o) = &self.obs {
            o.registrations.inc();
        }
        if self.entries.len() <= self.capacity {
            self.refresh_obs();
            return None;
        }
        let victim = self
            .entries
            .iter()
            .filter(|(k, _)| **k != id)
            .min_by_key(|(_, (used, _))| *used)
            .map(|(k, _)| k.clone());
        if let Some(v) = victim {
            self.entries.remove(&v);
            self.device_sets.remove(&v);
            self.device_bytes.remove(&v);
            self.degraded.remove(&v);
            if let Some(b) = self.bank.as_mut() {
                b.evict(&v);
            }
            self.evictions.push(v.clone());
            if let Some(o) = &self.obs {
                o.evictions.inc();
            }
            self.refresh_obs();
            return Some(v);
        }
        self.refresh_obs();
        None
    }

    /// Upload a validated entry's host sets as one device buffer set
    /// (earlier sets win on duplicate names, matching `build_args` host
    /// precedence).
    fn upload_entry(rt: &Runtime, entry: &AdapterEntry) -> Result<DeviceStore> {
        let mut dev = DeviceStore::new();
        for set in &entry.host_sets {
            for (n, t) in set.iter() {
                if !dev.contains(n) {
                    dev.put_tensor(&rt.client, n, t)
                        .with_context(|| format!("uploading '{}' for '{}'", n, entry.id))?;
                }
            }
        }
        Ok(dev)
    }

    /// Validate + upload to the device + insert.  Serving this tenant then
    /// passes borrowed device handles per forward instead of re-uploading
    /// the adapter host set every decode step (the Table 7 hot path).
    pub fn register_resident(
        &mut self,
        rt: &Runtime,
        hyper: &ModelHyper,
        entry: AdapterEntry,
    ) -> Result<Option<String>> {
        Self::validate(hyper, &entry)?;
        let dev = Self::upload_entry(rt, &entry)?;
        let id = entry.id.clone();
        let bytes = Self::entry_logical_bytes(&entry, None);
        let evicted = self.insert_validated(entry);
        self.device_sets.insert(id.clone(), dev);
        self.device_bytes.insert(id.clone(), bytes);
        if let Err(e) = self.bank_write(&id) {
            self.entries.remove(&id);
            self.device_sets.remove(&id);
            self.device_bytes.remove(&id);
            self.refresh_obs();
            return Err(e);
        }
        self.flush_bank(rt)?;
        Ok(evicted)
    }

    /// The tenant's device-resident buffer set, if registered through
    /// `register_resident` and not since evicted/replaced.
    pub fn device_set(&self, id: &str) -> Option<&DeviceStore> {
        self.device_sets.get(id)
    }

    /// Shared-borrow lookup that does *not* touch the LRU stamp.  For
    /// eligibility checks inside a running gathered session, where the
    /// bank's device buffers are already borrowed; the dispatcher
    /// touches each batch's tenants via [`AdapterRegistry::get`] up
    /// front so serving still counts as LRU use.
    pub fn peek(&self, id: &str) -> Option<&AdapterEntry> {
        self.entries.get(id).map(|(_, entry)| entry)
    }

    /// Look up an adapter for serving; touches its LRU stamp.
    pub fn get(&mut self, id: &str) -> Option<&AdapterEntry> {
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(id) {
            Some((used, entry)) => {
                *used = clock;
                Some(entry)
            }
            None => None,
        }
    }

    /// Serving lookup: entry + (when resident) its device buffer set in
    /// one call, touching the LRU stamp once.
    pub fn get_for_serving(&mut self, id: &str) -> Option<(&AdapterEntry, Option<&DeviceStore>)> {
        self.clock += 1;
        let clock = self.clock;
        let entry = match self.entries.get_mut(id) {
            Some((used, entry)) => {
                *used = clock;
                &*entry
            }
            None => return None,
        };
        Some((entry, self.device_sets.get(id)))
    }

    /// Drop a tenant explicitly (host entry + any device buffers); true if
    /// it was resident.
    pub fn evict(&mut self, id: &str) -> bool {
        self.device_sets.remove(id);
        self.device_bytes.remove(id);
        self.degraded.remove(id);
        if let Some(b) = self.bank.as_mut() {
            b.evict(id);
        }
        let evicted = self.entries.remove(id).is_some();
        if evicted {
            if let Some(o) = &self.obs {
                o.evictions.inc();
            }
            self.refresh_obs();
        }
        evicted
    }

    /// Register a batch of tenants the caller is about to route traffic
    /// to.  All-or-nothing: duplicate ids (a silent replace would serve
    /// one tenant's traffic with another tenant's weights), validation
    /// failures, and capacity overflow are checked *before* anything is
    /// inserted, so a failed batch leaves resident tenants untouched and
    /// never LRU-evicts one.  Returns the registered ids in order.
    pub fn register_all(
        &mut self,
        hyper: &ModelHyper,
        entries: Vec<AdapterEntry>,
    ) -> Result<Vec<String>> {
        let ids = self.precheck_batch(hyper, &entries)?;
        for entry in entries {
            // pre-validated and within capacity: no eviction possible,
            // and the bank (capped at >= capacity slots) cannot fill up
            let id = entry.id.clone();
            self.insert_validated(entry);
            self.bank_write(&id)?;
        }
        Ok(ids)
    }

    /// `register_all` with device-resident uploads.  Same all-or-nothing
    /// contract: validation/duplicate/capacity failures happen before any
    /// insert, and if an *upload* fails partway (device OOM, client error)
    /// the already-registered prefix is rolled back — entries removed and
    /// their device buffers freed — so a failed batch leaves the registry
    /// exactly as it was.
    pub fn register_all_resident(
        &mut self,
        rt: &Runtime,
        hyper: &ModelHyper,
        entries: Vec<AdapterEntry>,
    ) -> Result<Vec<String>> {
        let ids = self.precheck_batch(hyper, &entries)?;
        let mut inserted: Vec<String> = Vec::new();
        for entry in entries {
            // pre-validated; only the device upload can still fail
            match Self::upload_entry(rt, &entry) {
                Ok(dev) => {
                    let id = entry.id.clone();
                    let bytes = Self::entry_logical_bytes(&entry, None);
                    self.insert_validated(entry);
                    self.device_sets.insert(id.clone(), dev);
                    self.device_bytes.insert(id.clone(), bytes);
                    self.bank_write(&id)?;
                    inserted.push(id);
                }
                Err(e) => {
                    for done in &inserted {
                        self.entries.remove(done);
                        self.device_sets.remove(done);
                        self.device_bytes.remove(done);
                        if let Some(b) = self.bank.as_mut() {
                            b.evict(done);
                        }
                    }
                    // rollback removals are not evictions, but the
                    // resident gauges must re-level
                    self.refresh_obs();
                    return Err(e.context(
                        "register_all rollback: no tenants from this batch remain resident",
                    ));
                }
            }
        }
        self.flush_bank(rt)?;
        Ok(ids)
    }

    /// Shared all-or-nothing pre-checks for batch registration: duplicate
    /// ids (in the batch or already resident), per-entry validation, and
    /// the capacity bound.  Nothing is mutated.
    fn precheck_batch(
        &self,
        hyper: &ModelHyper,
        entries: &[AdapterEntry],
    ) -> Result<Vec<String>> {
        let mut ids: Vec<String> = Vec::new();
        for entry in entries {
            if self.contains(&entry.id) || ids.iter().any(|i| i == &entry.id) {
                bail!(
                    "duplicate adapter id '{}'; export with distinct --adapter-id values",
                    entry.id
                );
            }
            Self::validate(hyper, entry)?;
            ids.push(entry.id.clone());
        }
        if self.entries.len() + entries.len() > self.capacity {
            bail!(
                "batch of {} adapters exceeds registry capacity {} ({} already resident); raise the capacity",
                entries.len(),
                self.capacity,
                self.entries.len()
            );
        }
        Ok(ids)
    }

    // ------------------------------------------------------------------
    // Tiered residency: disk → host → device, rank-elastic degradation
    // ------------------------------------------------------------------

    /// Bound device residency to `bytes` logical adapter bytes (0 =
    /// unbounded, the legacy flat behavior).
    pub fn set_device_budget(&mut self, bytes: usize) {
        self.device_budget = bytes;
    }

    pub fn device_budget(&self) -> usize {
        self.device_budget
    }

    /// Elastic degradation ladder: ranks to offer a tenant whose
    /// full-rank view does not fit the device budget.  Stored descending
    /// (the least-degraded fitting rank wins); zero ranks are dropped.
    pub fn set_degrade_ranks(&mut self, ranks: &[usize]) {
        let mut l: Vec<usize> = ranks.iter().copied().filter(|&r| r > 0).collect();
        l.sort_unstable_by(|a, b| b.cmp(a));
        l.dedup();
        self.degrade_ladder = l;
    }

    pub fn degrade_ranks(&self) -> &[usize] {
        &self.degrade_ladder
    }

    /// Whether any tiering feature is configured.  When false the serve
    /// path must behave exactly like the flat legacy registry (no
    /// auto-promotion, no budgets), so full-rank serving stays
    /// byte-identical to the pre-tiering stack.
    pub fn tiering_enabled(&self) -> bool {
        self.device_budget > 0 || !self.degrade_ladder.is_empty() || !self.disk.is_empty()
    }

    /// Record where a cold tenant's checkpoint lives (the disk tier of
    /// the residency ladder); [`AdapterRegistry::prefetch_host`] loads it
    /// on demand.
    pub fn catalog_disk(&mut self, id: &str, path: PathBuf) {
        self.disk.insert(id.to_string(), path);
        self.refresh_obs();
    }

    /// Ids cataloged on disk but neither loaded nor quarantined — the
    /// cold tenants a queue-arrival prefetch should warm.
    pub fn cold_ids(&self) -> Vec<String> {
        self.disk
            .keys()
            .filter(|id| !self.entries.contains_key(*id) && !self.quarantined.contains_key(*id))
            .cloned()
            .collect()
    }

    /// Refuse a tenant: drop every copy of its state (host entry, device
    /// buffers, byte charge, bank slot) and remember why.  Until
    /// re-registered from a good checkpoint its requests get typed
    /// `TenantUnavailable` replies; siblings are untouched.
    pub fn quarantine(&mut self, id: &str, reason: impl Into<String>) {
        let reason = reason.into();
        self.device_sets.remove(id);
        self.device_bytes.remove(id);
        self.degraded.remove(id);
        if let Some(b) = self.bank.as_mut() {
            b.evict(id);
        }
        self.entries.remove(id);
        self.quarantined.insert(id.to_string(), reason.clone());
        if let Some(o) = &self.obs {
            o.quarantined.inc();
        }
        if let Some(t) = &self.trace {
            t.event(
                "tenant_quarantine",
                vec![("tenant", Json::Str(id.to_string())), ("reason", Json::Str(reason))],
            );
        }
        self.refresh_obs();
    }

    pub fn is_quarantined(&self, id: &str) -> bool {
        self.quarantined.contains_key(id)
    }

    /// Idempotently mirror a quarantine decision replicated from a
    /// [`SharedAdapterSource`] (counts and traces only the first time).
    pub fn note_quarantined(&mut self, id: &str, reason: &str) {
        if self.quarantined.contains_key(id) {
            return;
        }
        self.quarantine(id, reason);
    }

    pub fn quarantine_reason(&self, id: &str) -> Option<&str> {
        self.quarantined.get(id).map(|s| s.as_str())
    }

    /// The typed refusal for an id this registry cannot serve.
    pub fn unavailable_error(&self, id: &str) -> ServeError {
        match self.quarantined.get(id) {
            Some(reason) => ServeError::TenantUnavailable {
                tenant: id.to_string(),
                reason: format!("quarantined: {reason}"),
            },
            None => ServeError::TenantUnavailable {
                tenant: id.to_string(),
                reason: "not registered".to_string(),
            },
        }
    }

    /// The tenant's reduced serving rank, if currently degraded.
    pub fn degraded_rank(&self, id: &str) -> Option<usize> {
        self.degraded.get(id).copied()
    }

    /// Logical adapter bytes of `entry` served at `rank` (None = full):
    /// `a_` `[l, r, in]` / `b_` `[l, out, r]` / `rankmask_` `[l, r]`
    /// slices are charged at the serving rank; `scale_` and the sparsity
    /// masks are rank-independent.  This is the unit
    /// [`AdapterRegistry::set_device_budget`] is denominated in — the XLA
    /// artifact inputs stay r_max-shaped (zero tail), so the budget
    /// models HBM on rank-specialized hardware, not PJRT buffer sizes.
    pub fn entry_logical_bytes(entry: &AdapterEntry, rank: Option<usize>) -> usize {
        let mut elems = 0usize;
        for set in &entry.host_sets {
            for (name, t) in set.iter() {
                let s = t.shape();
                let n = match rank {
                    Some(d) if name.starts_with("a_") && s.len() == 3 => s[0] * d.min(s[1]) * s[2],
                    Some(d) if name.starts_with("b_") && s.len() == 3 => s[0] * s[1] * d.min(s[2]),
                    Some(d) if name.starts_with("rankmask_") && s.len() == 2 => s[0] * d.min(s[1]),
                    _ => t.len(),
                };
                elems += n;
            }
        }
        elems * 4
    }

    /// Rank-sliced copy of an entry: `a_` rows and `b_` columns beyond
    /// `rank` zeroed, and the rank configuration clamped through
    /// [`crate::nls::degrade_rank_params`] (prefix masks shortened, scale
    /// rebuilt from the recovered alpha).  The artifact input shapes stay
    /// at r_max, so the view uploads through the same executables and the
    /// clamped rankmask guarantees the zeroed tail never contributes.
    pub fn degraded_view(entry: &AdapterEntry, rank: usize) -> Result<AdapterEntry> {
        let mut sets = Vec::with_capacity(entry.host_sets.len());
        for set in &entry.host_sets {
            let mut rank_part = ParamSet::new();
            let mut out = ParamSet::new();
            for (name, t) in set.iter() {
                if name.starts_with("rankmask_") || name.starts_with("scale_") {
                    rank_part.insert(name, t.clone());
                } else if name.starts_with("a_") && t.shape().len() == 3 {
                    let mut t2 = t.clone();
                    let s = t2.shape().to_vec();
                    let (r_n, in_n) = (s[1], s[2]);
                    for l in 0..s[0] {
                        for j in rank.min(r_n)..r_n {
                            let off = (l * r_n + j) * in_n;
                            t2.data_mut()[off..off + in_n].fill(0.0);
                        }
                    }
                    out.insert(name, t2);
                } else if name.starts_with("b_") && t.shape().len() == 3 {
                    let mut t2 = t.clone();
                    let s = t2.shape().to_vec();
                    let r_n = s[2];
                    for row in 0..s[0] * s[1] {
                        for j in rank.min(r_n)..r_n {
                            t2.data_mut()[row * r_n + j] = 0.0;
                        }
                    }
                    out.insert(name, t2);
                } else {
                    out.insert(name, t.clone());
                }
            }
            if !rank_part.is_empty() {
                let clamped = crate::nls::degrade_rank_params(&rank_part, rank)?;
                for (n, t) in clamped.iter() {
                    out.insert(n, t.clone());
                }
            }
            sets.push(out);
        }
        Ok(AdapterEntry {
            id: entry.id.clone(),
            eval_kind: entry.eval_kind.clone(),
            host_sets: sets,
        })
    }

    /// Drop a tenant's device residency back to the host tier (validated
    /// entry kept, buffers and byte charge dropped); true if it was
    /// device-resident.  The *whole point* of the host tier: a later
    /// re-promotion re-uploads from here instead of re-reading disk.
    pub fn demote_device(&mut self, id: &str) -> bool {
        let was = self.device_sets.remove(id).is_some();
        self.device_bytes.remove(id);
        self.degraded.remove(id);
        if was {
            if let Some(t) = &self.trace {
                t.event("tenant_demote", vec![("tenant", Json::Str(id.to_string()))]);
            }
            self.refresh_obs();
        }
        was
    }

    /// Pull a cold tenant's checkpoint from the disk catalog into the
    /// validated host tier (no device work).  `Ok(true)` if a load
    /// happened; `Ok(false)` if the tenant is already resident, unknown
    /// to the catalog, or quarantined.  A corrupt or invalid checkpoint
    /// quarantines the tenant and returns its typed refusal.
    pub fn prefetch_host(&mut self, hyper: &ModelHyper, id: &str) -> Result<bool> {
        if self.entries.contains_key(id) || self.quarantined.contains_key(id) {
            return Ok(false);
        }
        let Some(path) = self.disk.get(id).cloned() else { return Ok(false) };
        let t0 = Instant::now();
        let loaded = checkpoint::load_adapter(&path)
            .map(|ck| AdapterEntry::from_ckpt(ck, id))
            .and_then(|entry| {
                if entry.id != id {
                    bail!(
                        "checkpoint {path:?} carries adapter id '{}', cataloged as '{id}'",
                        entry.id
                    );
                }
                Self::validate(hyper, &entry)?;
                Ok(entry)
            });
        let entry = match loaded {
            Ok(e) => e,
            Err(e) => {
                self.quarantine(id, format!("{e:#}"));
                return Err(anyhow::Error::new(self.unavailable_error(id)));
            }
        };
        self.insert_validated(entry);
        if let Err(e) = self.bank_write(id) {
            self.entries.remove(id);
            self.refresh_obs();
            return Err(e);
        }
        if let Some(o) = &self.obs {
            o.cold_start_disk.record(t0.elapsed().as_secs_f64() * 1e3);
        }
        self.refresh_obs();
        Ok(true)
    }

    /// Make the tenant serveable from the device within the byte budget:
    /// full rank when it fits, else the highest degrade-ladder rank that
    /// fits; under pressure the biggest shrinkable sibling is degraded
    /// one ladder step at a time to make room (so the fleet converges on
    /// everyone-resident-at-reduced-rank instead of thrashing whole
    /// tenants in and out), then least-recently-used siblings are demoted
    /// to host, and as a last resort the tenant itself stays
    /// host-resident — serving falls back to per-forward host uploads,
    /// so **no request is ever refused for residency alone**.  Restores
    /// (full rank or a higher ladder rank) happen the same way when
    /// pressure drops.  No-op for unknown or quarantined ids.
    pub fn ensure_device(&mut self, rt: &Runtime, id: &str) -> Result<()> {
        if !self.entries.contains_key(id) || self.quarantined.contains_key(id) {
            return Ok(());
        }
        loop {
            if self.try_place(rt, id)? {
                return Ok(());
            }
            if let Some((v, r, bytes)) = self.shrink_candidate(id) {
                let entry = match self.entries.get(&v) {
                    Some((_, e)) => e.clone(),
                    None => continue,
                };
                self.place(rt, &v, &entry, Some(r), bytes)?;
                continue;
            }
            let victim = self
                .entries
                .iter()
                .filter(|(k, _)| k.as_str() != id && self.device_sets.contains_key(k.as_str()))
                .min_by_key(|(_, (used, _))| *used)
                .map(|(k, _)| k.clone());
            match victim {
                Some(v) => {
                    self.demote_device(&v);
                }
                None => return Ok(()),
            }
        }
    }

    /// Place the tenant at the best rank that fits the available budget
    /// without touching siblings; false if nothing fits.
    fn try_place(&mut self, rt: &Runtime, id: &str) -> Result<bool> {
        let entry = match self.entries.get(id) {
            Some((_, e)) => e.clone(),
            None => return Ok(true),
        };
        let full = Self::entry_logical_bytes(&entry, None);
        let mine = self.device_bytes.get(id).copied().unwrap_or(0);
        let charged: usize = self.device_bytes.values().sum();
        let avail = if self.device_budget == 0 {
            usize::MAX
        } else {
            self.device_budget.saturating_sub(charged - mine)
        };
        if full <= avail {
            self.place(rt, id, &entry, None, full)?;
            return Ok(true);
        }
        for r in self.degrade_ladder.clone() {
            let bytes = Self::entry_logical_bytes(&entry, Some(r));
            if bytes <= avail {
                self.place(rt, id, &entry, Some(r), bytes)?;
                return Ok(true);
            }
        }
        Ok(false)
    }

    /// The device-resident sibling with the largest byte charge that can
    /// still shrink one ladder step *and actually free bytes by doing so*
    /// (deterministic tie-break by id).  Returns (id, next rank, bytes at
    /// that rank).
    fn shrink_candidate(&self, id: &str) -> Option<(String, usize, usize)> {
        let mut best: Option<(String, usize, usize, usize)> = None;
        for (k, &old) in &self.device_bytes {
            if k == id {
                continue;
            }
            let Some((_, entry)) = self.entries.get(k) else { continue };
            let cur = self.degraded.get(k).copied();
            let next = self
                .degrade_ladder
                .iter()
                .copied()
                .find(|&r| cur.map(|c| r < c).unwrap_or(true));
            let Some(r) = next else { continue };
            let nb = Self::entry_logical_bytes(entry, Some(r));
            if nb >= old {
                continue;
            }
            let better = match &best {
                Some((bk, _, _, bo)) => old > *bo || (old == *bo && k < bk),
                None => true,
            };
            if better {
                best = Some((k.clone(), r, nb, old));
            }
        }
        best.map(|(k, r, nb, _)| (k, r, nb))
    }

    /// Upload (or keep) the tenant's device view at `rank` (None = full),
    /// maintaining the byte ledger, degrade/restore accounting, the
    /// cold-start series, and the gathered-bank slice.
    fn place(
        &mut self,
        rt: &Runtime,
        id: &str,
        entry: &AdapterEntry,
        rank: Option<usize>,
        bytes: usize,
    ) -> Result<()> {
        let current = self.degraded.get(id).copied();
        let resident = self.device_sets.contains_key(id);
        if resident && current == rank {
            return Ok(());
        }
        let view = match rank {
            Some(r) => Self::degraded_view(entry, r)?,
            None => entry.clone(),
        };
        let t0 = Instant::now();
        let dev = Self::upload_entry(rt, &view)?;
        let promote_ms = t0.elapsed().as_secs_f64() * 1e3;
        self.device_sets.insert(id.to_string(), dev);
        self.device_bytes.insert(id.to_string(), bytes);
        match rank {
            Some(r) => {
                self.degraded.insert(id.to_string(), r);
            }
            None => {
                self.degraded.remove(id);
            }
        }
        // a banked tenant's slot must serve the same view as its uniform
        // sessions: rewrite the slice and re-upload before the slot is
        // used again (in-flight sessions hold the *previous* bank buffers
        // borrowed, so they finish with the weights they started with)
        let banked = matches!(self.bank.as_ref(), Some(b) if b.slot(id).is_some());
        if banked {
            if let Some(b) = self.bank.as_mut() {
                b.register(&view)?;
            }
            self.flush_bank(rt)?;
        }
        if !resident {
            // host → device promotion: the warm-tier cold start
            if let Some(o) = &self.obs {
                o.cold_start_host.record(promote_ms);
            }
        }
        let degrade_to = match (current, rank) {
            (None, Some(r)) => Some(r),
            (Some(from), Some(r)) if r < from => Some(r),
            _ => None,
        };
        let restore_to = match (current, rank) {
            (Some(_), None) => Some(None),
            (Some(from), Some(r)) if r > from => Some(Some(r)),
            _ => None,
        };
        if let Some(r) = degrade_to {
            if let Some(o) = &self.obs {
                o.degraded.inc();
            }
            if let Some(t) = &self.trace {
                t.event(
                    "tenant_degrade",
                    vec![("tenant", Json::Str(id.to_string())), ("rank", Json::Num(r as f64))],
                );
            }
        } else if let Some(r) = restore_to {
            if let Some(o) = &self.obs {
                o.restored.inc();
            }
            if let Some(t) = &self.trace {
                t.event(
                    "tenant_restore",
                    vec![
                        ("tenant", Json::Str(id.to_string())),
                        ("rank", Json::Num(r.map(|x| x as f64).unwrap_or(-1.0))),
                    ],
                );
            }
        }
        self.refresh_obs();
        Ok(())
    }
}

/// Host-side source of truth for multi-worker serving: validated tenant
/// entries plus a monotonically versioned change log, shared (behind a
/// mutex) by every worker thread.  Each worker keeps a private
/// [`AdapterRegistry`] replica — device buffers belong to that worker's
/// PJRT client and cannot be shared — and calls [`SharedAdapterSource::sync`]
/// to replay registrations and evictions it hasn't seen yet, in version
/// order, so all replicas converge on the same resident set.
///
/// Coordinated eviction: the source enforces the capacity bound itself
/// (registration past capacity is an error, never a silent LRU kick), so
/// the only way a tenant leaves is an explicit [`SharedAdapterSource::evict`]
/// — which every worker applies at its next sync, freeing that worker's
/// device buffers.  Worker registries must be created with at least this
/// capacity so their local LRU never fires on its own.
///
/// Memory is bounded: entries are stored once (latest version wins on
/// same-id re-registration, count capped by `capacity`), and the
/// eviction log is compacted once it exceeds [`EVICTION_LOG_CAP`] — a
/// worker whose cursor predates the compaction `floor` takes a snapshot
/// resync instead of a log replay (drop every replica id the source no
/// longer has, then apply registrations as usual), so long-lived
/// serving with tenant churn never accumulates dead history.
pub struct SharedAdapterSource {
    inner: Mutex<SourceInner>,
}

/// Evictions retained for incremental replay; beyond this the oldest
/// half is compacted away and stale workers snapshot-resync.
const EVICTION_LOG_CAP: usize = 64;

struct SourceInner {
    hyper: ModelHyper,
    capacity: usize,
    version: u64,
    /// id → (version registered, entry); same-id re-registration replaces
    entries: BTreeMap<String, (u64, AdapterEntry)>,
    /// (version, id) of retained evictions, in order (compacted — see
    /// `floor`)
    evictions: Vec<(u64, String)>,
    /// evictions at or below this version have been compacted away;
    /// cursors below it cannot replay the log and snapshot-resync instead
    floor: u64,
    /// id → reason for tenants pulled for bad checkpoints; replicated
    /// into every worker registry at sync so the whole fleet refuses the
    /// tenant with the same typed error
    quarantined: BTreeMap<String, String>,
}

impl SharedAdapterSource {
    pub fn new(hyper: ModelHyper, capacity: usize) -> SharedAdapterSource {
        SharedAdapterSource {
            inner: Mutex::new(SourceInner {
                hyper,
                capacity: capacity.max(1),
                version: 0,
                entries: BTreeMap::new(),
                evictions: Vec::new(),
                floor: 0,
                quarantined: BTreeMap::new(),
            }),
        }
    }

    pub fn capacity(&self) -> usize {
        lock_recover(&self.inner).capacity
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.inner).entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Monotonic change counter; bumps on every register/evict.
    pub fn version(&self) -> u64 {
        lock_recover(&self.inner).version
    }

    pub fn ids(&self) -> Vec<String> {
        lock_recover(&self.inner).entries.keys().cloned().collect()
    }

    /// Validate + record one tenant.  Same-id registration replaces the
    /// previous weights (workers pick the new ones up at next sync); a
    /// *new* id past capacity is an error — eviction is always explicit.
    pub fn register(&self, entry: AdapterEntry) -> Result<()> {
        let mut inner = lock_recover(&self.inner);
        AdapterRegistry::validate(&inner.hyper, &entry)?;
        if !inner.entries.contains_key(&entry.id) && inner.entries.len() >= inner.capacity {
            bail!(
                "adapter '{}' would exceed shared-source capacity {}; evict a tenant first",
                entry.id,
                inner.capacity
            );
        }
        inner.version += 1;
        let v = inner.version;
        // a fresh validated registration cures quarantine fleet-wide
        inner.quarantined.remove(&entry.id);
        inner.entries.insert(entry.id.clone(), (v, entry));
        Ok(())
    }

    /// All-or-nothing batch registration (mirrors
    /// [`AdapterRegistry::register_all`]): duplicate ids, validation
    /// failures, and capacity overflow are checked before anything is
    /// recorded.  Returns the registered ids in order.
    pub fn register_all(&self, entries: Vec<AdapterEntry>) -> Result<Vec<String>> {
        let mut inner = lock_recover(&self.inner);
        let mut ids: Vec<String> = Vec::new();
        for entry in &entries {
            if inner.entries.contains_key(&entry.id) || ids.iter().any(|i| i == &entry.id) {
                bail!(
                    "duplicate adapter id '{}'; export with distinct --adapter-id values",
                    entry.id
                );
            }
            AdapterRegistry::validate(&inner.hyper, entry)?;
            ids.push(entry.id.clone());
        }
        if inner.entries.len() + entries.len() > inner.capacity {
            bail!(
                "batch of {} adapters exceeds shared-source capacity {} ({} already registered)",
                entries.len(),
                inner.capacity,
                inner.entries.len()
            );
        }
        for entry in entries {
            inner.version += 1;
            let v = inner.version;
            inner.quarantined.remove(&entry.id);
            inner.entries.insert(entry.id.clone(), (v, entry));
        }
        Ok(ids)
    }

    /// Pull a tenant fleet-wide for a bad checkpoint: removed from the
    /// source of truth like [`SharedAdapterSource::evict`], but every
    /// worker also records the reason at its next sync, so the tenant's
    /// requests draw typed `TenantUnavailable` refusals on every shard
    /// until it is re-registered from a good checkpoint.  True if the
    /// tenant was registered or newly quarantined.
    pub fn quarantine(&self, id: &str, reason: impl Into<String>) -> bool {
        let mut inner = lock_recover(&self.inner);
        let fresh = inner.quarantined.insert(id.to_string(), reason.into()).is_none();
        if inner.entries.remove(id).is_none() {
            if fresh {
                // reason replication still needs a version bump so synced
                // workers wake up and record it
                inner.version += 1;
            }
            return fresh;
        }
        inner.version += 1;
        let v = inner.version;
        inner.evictions.push((v, id.to_string()));
        if inner.evictions.len() > EVICTION_LOG_CAP {
            let drop_n = inner.evictions.len() / 2;
            inner.floor = inner.evictions[drop_n - 1].0;
            inner.evictions.drain(..drop_n);
        }
        true
    }

    /// The fleet-wide quarantine reason for `id`, if any.
    pub fn quarantine_reason(&self, id: &str) -> Option<String> {
        lock_recover(&self.inner).quarantined.get(id).cloned()
    }

    /// Remove a tenant from the source of truth; every worker drops its
    /// replica (host entry + device buffers) at its next sync.  True if
    /// the tenant was registered.
    pub fn evict(&self, id: &str) -> bool {
        let mut inner = lock_recover(&self.inner);
        if inner.entries.remove(id).is_none() {
            return false;
        }
        inner.version += 1;
        let v = inner.version;
        inner.evictions.push((v, id.to_string()));
        if inner.evictions.len() > EVICTION_LOG_CAP {
            // compact the oldest half; workers behind the new floor take
            // the snapshot-resync path in `sync`
            let drop_n = inner.evictions.len() / 2;
            inner.floor = inner.evictions[drop_n - 1].0;
            inner.evictions.drain(..drop_n);
        }
        true
    }

    /// Replay every change after `cursor` into a worker's registry
    /// replica, in version order, and advance the cursor.  With `rt` the
    /// registrations go device-resident (the serving path); without it
    /// they stay host-only (tests, dry runs).  Entry payloads are cloned
    /// and uploads run *outside* the source lock, so a slow worker sync
    /// never blocks registration or its siblings.  Returns the number of
    /// changes applied.
    pub fn sync(
        &self,
        registry: &mut AdapterRegistry,
        rt: Option<&Runtime>,
        cursor: &mut u64,
    ) -> Result<usize> {
        enum Change {
            Register(AdapterEntry),
            Evict(String),
        }
        let (hyper, mut changes, head, quarantined) = {
            let inner = lock_recover(&self.inner);
            // steady-state fast path: one u64 compare under the lock —
            // per-session worker syncs must not pay a full log scan
            if inner.version == *cursor {
                return Ok(0);
            }
            let mut changes: Vec<(u64, Change)> = Vec::new();
            if *cursor < inner.floor {
                // the eviction log was compacted past this cursor:
                // snapshot resync — drop every replica id the source no
                // longer has (version 0 sorts these before all
                // registrations), then apply registrations as usual
                for id in registry.ids() {
                    if !inner.entries.contains_key(id) {
                        changes.push((0, Change::Evict(id.to_string())));
                    }
                }
            } else {
                for (v, id) in inner.evictions.iter().filter(|(v, _)| *v > *cursor) {
                    changes.push((*v, Change::Evict(id.clone())));
                }
            }
            for (v, entry) in inner.entries.values().filter(|(v, _)| *v > *cursor) {
                changes.push((*v, Change::Register(entry.clone())));
            }
            let quarantined: Vec<(String, String)> =
                inner.quarantined.iter().map(|(k, r)| (k.clone(), r.clone())).collect();
            (inner.hyper.clone(), changes, inner.version, quarantined)
        };
        changes.sort_by_key(|(v, _)| *v);
        let applied = changes.len();
        for (_, change) in changes.drain(..) {
            match change {
                Change::Register(entry) => {
                    // chaos-harness failpoint: a replication failure here
                    // leaves the cursor unadvanced, so the worker retries
                    // the same changes at its next per-session sync
                    crate::faults::check_thread(crate::faults::SITE_REGISTER)?;
                    match rt {
                        Some(rt) => registry.register_resident(rt, &hyper, entry)?,
                        None => registry.register(&hyper, entry)?,
                    };
                }
                Change::Evict(id) => {
                    registry.evict(&id);
                }
            }
        }
        // replicate quarantine reasons so this worker's refusals carry
        // the same typed detail as the shard that found the corruption
        // (idempotent: already-noted ids are skipped)
        for (id, reason) in quarantined {
            registry.note_quarantined(&id, &reason);
        }
        *cursor = head;
        Ok(applied)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init_adapters;
    use crate::nls::SearchSpace;
    use crate::tensor::Rng;

    fn hyper() -> ModelHyper {
        let mods: Vec<String> =
            ["q", "k", "v", "up", "down"].iter().map(|s| s.to_string()).collect();
        let mut mod_dims = BTreeMap::new();
        mod_dims.insert("q".into(), (64, 64));
        mod_dims.insert("k".into(), (64, 64));
        mod_dims.insert("v".into(), (64, 64));
        mod_dims.insert("up".into(), (128, 64));
        mod_dims.insert("down".into(), (64, 128));
        ModelHyper {
            name: "test".into(),
            vocab: 64,
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            d_ff: 128,
            seq_len: 48,
            batch: 8,
            r_max: 8,
            group_size: 32,
            param_count: 0,
            mods,
            mod_dims,
        }
    }

    fn entry(h: &ModelHyper, id: &str, seed: u64) -> AdapterEntry {
        let mut rng = Rng::new(seed);
        let adapters = init_adapters(h, &mut rng, 16.0);
        let space = SearchSpace::default_for(h, 16.0);
        let rank = space.realize(&space.heuristic_config()).unwrap();
        AdapterEntry {
            id: id.to_string(),
            eval_kind: "eval".to_string(),
            host_sets: vec![adapters, rank],
        }
    }

    #[test]
    fn register_get_and_explicit_evict() {
        let h = hyper();
        let mut reg = AdapterRegistry::new(4);
        assert!(reg.register(&h, entry(&h, "t0", 1)).unwrap().is_none());
        assert!(reg.contains("t0"));
        assert_eq!(reg.get("t0").unwrap().eval_kind, "eval");
        assert!(reg.get("missing").is_none());
        assert!(reg.evict("t0"));
        assert!(!reg.evict("t0"));
        assert!(reg.is_empty());
    }

    #[test]
    fn lru_bound_evicts_least_recently_used() {
        let h = hyper();
        let mut reg = AdapterRegistry::new(2);
        reg.register(&h, entry(&h, "a", 1)).unwrap();
        reg.register(&h, entry(&h, "b", 2)).unwrap();
        // touch a, so b is the LRU victim
        assert!(reg.get("a").is_some());
        let evicted = reg.register(&h, entry(&h, "c", 3)).unwrap();
        assert_eq!(evicted.as_deref(), Some("b"));
        assert!(reg.contains("a") && reg.contains("c") && !reg.contains("b"));
        assert_eq!(reg.evictions(), &["b".to_string()]);
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn validation_rejects_malformed_entries() {
        let h = hyper();
        // wrong a_ shape
        let mut e = entry(&h, "bad", 1);
        e.host_sets[0].insert("a_q", Tensor::zeros(&[2, 8, 32]));
        assert!(AdapterRegistry::validate(&h, &e).is_err());
        // unknown eval kind
        let mut e = entry(&h, "bad", 1);
        e.eval_kind = "train".into();
        assert!(AdapterRegistry::validate(&h, &e).is_err());
        // missing rank configuration
        let mut e = entry(&h, "bad", 1);
        e.host_sets.truncate(1);
        assert!(AdapterRegistry::validate(&h, &e).is_err());
        // non-prefix rank mask
        let mut e = entry(&h, "bad", 1);
        let mut rm = Tensor::zeros(&[2, 8]);
        rm.data_mut()[1] = 1.0; // 0 then 1: not a prefix
        e.host_sets[1].insert("rankmask_q", rm);
        assert!(AdapterRegistry::validate(&h, &e).is_err());
        // empty id
        let mut e = entry(&h, "x", 1);
        e.id.clear();
        let mut reg = AdapterRegistry::new(2);
        assert!(reg.register(&h, e).is_err());
        assert!(reg.is_empty());
    }

    #[test]
    fn register_all_rejects_duplicate_ids_and_overflow() {
        let h = hyper();
        // duplicate ids in one batch: second would silently shadow the
        // first tenant's weights, so the batch is rejected
        let mut reg = AdapterRegistry::new(4);
        let e = reg
            .register_all(&h, vec![entry(&h, "dup", 1), entry(&h, "dup", 2)])
            .unwrap_err();
        assert!(format!("{e:#}").contains("duplicate"), "{e:#}");
        assert!(reg.is_empty(), "failed batch must not partially register");
        // a batch larger than the capacity is rejected, not LRU-evicted,
        // and resident tenants survive the failed call untouched
        let mut reg = AdapterRegistry::new(2);
        reg.register(&h, entry(&h, "resident", 9)).unwrap();
        let batch = vec![entry(&h, "a", 1), entry(&h, "b", 2)];
        let e = reg.register_all(&h, batch).unwrap_err();
        assert!(format!("{e:#}").contains("capacity"), "{e:#}");
        assert!(reg.contains("resident") && reg.len() == 1);
        // a batch that fits registers everything in order
        let mut reg = AdapterRegistry::new(2);
        let ids = reg
            .register_all(&h, vec![entry(&h, "a", 1), entry(&h, "b", 2)])
            .unwrap();
        assert_eq!(ids, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn shared_source_replicates_into_worker_registries() {
        let h = hyper();
        let source = SharedAdapterSource::new(h.clone(), 4);
        source.register_all(vec![entry(&h, "a", 1), entry(&h, "b", 2)]).unwrap();
        // two workers replicate independently (host-only sync: no runtime)
        let mut reg0 = AdapterRegistry::new(4);
        let mut reg1 = AdapterRegistry::new(4);
        let (mut c0, mut c1) = (0u64, 0u64);
        assert_eq!(source.sync(&mut reg0, None, &mut c0).unwrap(), 2);
        assert_eq!(source.sync(&mut reg1, None, &mut c1).unwrap(), 2);
        assert!(reg0.contains("a") && reg0.contains("b"));
        assert!(reg1.contains("a") && reg1.contains("b"));
        // a second sync with nothing new is a no-op
        assert_eq!(source.sync(&mut reg0, None, &mut c0).unwrap(), 0);
        // coordinated eviction: both replicas drop the tenant at next sync
        assert!(source.evict("a"));
        assert!(!source.evict("a"), "double evict must report absence");
        assert_eq!(source.sync(&mut reg0, None, &mut c0).unwrap(), 1);
        assert_eq!(source.sync(&mut reg1, None, &mut c1).unwrap(), 1);
        assert!(!reg0.contains("a") && !reg1.contains("a"));
        assert!(reg0.contains("b") && reg1.contains("b"));
        // a late-joining worker replays history to the same end state
        let mut late = AdapterRegistry::new(4);
        let mut cl = 0u64;
        source.sync(&mut late, None, &mut cl).unwrap();
        assert!(!late.contains("a") && late.contains("b"));
        assert_eq!(late.len(), 1);
    }

    #[test]
    fn shared_source_enforces_capacity_and_rejects_duplicates() {
        let h = hyper();
        let source = SharedAdapterSource::new(h.clone(), 2);
        source.register(entry(&h, "a", 1)).unwrap();
        source.register(entry(&h, "b", 2)).unwrap();
        // eviction is explicit: a new id past capacity errors, never LRUs
        let e = source.register(entry(&h, "c", 3)).unwrap_err();
        assert!(format!("{e:#}").contains("capacity"), "{e:#}");
        assert_eq!(source.len(), 2);
        // same-id re-registration replaces (no capacity change) and
        // reaches an already-synced worker as one more change
        let mut reg = AdapterRegistry::new(2);
        let mut cursor = 0u64;
        source.sync(&mut reg, None, &mut cursor).unwrap();
        source.register(entry(&h, "a", 9)).unwrap();
        assert_eq!(source.sync(&mut reg, None, &mut cursor).unwrap(), 1);
        assert_eq!(source.len(), 2);
        // batch with a duplicate of a registered id is rejected whole
        let e = source.register_all(vec![entry(&h, "b", 4)]).unwrap_err();
        assert!(format!("{e:#}").contains("duplicate"), "{e:#}");
        // validation failures are caught at the source
        let mut bad = entry(&h, "bad", 5);
        bad.host_sets.truncate(1);
        assert!(source.register(bad).is_err());
    }

    #[test]
    fn shared_source_compacts_eviction_log_and_stale_workers_snapshot_resync() {
        let h = hyper();
        let source = SharedAdapterSource::new(h.clone(), 4);
        // a worker syncs early, then goes quiet while tenants churn
        source.register(entry(&h, "keep", 1)).unwrap();
        source.register(entry(&h, "stale", 2)).unwrap();
        let mut quiet = AdapterRegistry::new(8);
        let mut qc = 0u64;
        source.sync(&mut quiet, None, &mut qc).unwrap();
        assert!(quiet.contains("keep") && quiet.contains("stale"));
        // churn far past the log cap: register+evict cycles
        source.evict("stale");
        for i in 0..(2 * EVICTION_LOG_CAP) {
            let id = format!("churn{i}");
            source.register(entry(&h, &id, 100 + i as u64)).unwrap();
            assert!(source.evict(&id));
        }
        // one survivor registered after the churn
        source.register(entry(&h, "late", 9)).unwrap();
        // the quiet worker's cursor predates the compaction floor; its
        // snapshot resync must drop 'stale' (and no churn ghosts), keep
        // 'keep', and pick up 'late'
        let n = source.sync(&mut quiet, None, &mut qc).unwrap();
        assert!(n >= 2, "resync must evict 'stale' and register 'late', got {n}");
        assert!(quiet.contains("keep"), "unchanged tenant must survive resync");
        assert!(!quiet.contains("stale"), "compacted eviction must still apply");
        assert!(quiet.contains("late"));
        assert_eq!(quiet.len(), 2);
        // and the worker is now current: next sync is a no-op
        assert_eq!(source.sync(&mut quiet, None, &mut qc).unwrap(), 0);
        // a brand-new worker converges to the same set
        let mut fresh = AdapterRegistry::new(8);
        let mut fc = 0u64;
        source.sync(&mut fresh, None, &mut fc).unwrap();
        assert!(fresh.contains("keep") && fresh.contains("late"));
        assert_eq!(fresh.len(), 2);
    }

    /// The tenant's `a_q` slice inside the bank's `a_bank_q` tensor.
    fn bank_slice<'r>(reg: &'r AdapterRegistry, slot: usize, h: &ModelHyper) -> &'r [f32] {
        let (_, inp) = h.mod_dims("q");
        let n = h.n_layers * h.r_max * inp;
        let t = reg.bank().unwrap().host().get("a_bank_q").unwrap();
        &t.data()[slot * n..(slot + 1) * n]
    }

    #[test]
    fn gathered_bank_recycles_slots_on_evict_and_replace() {
        let h = hyper();
        let mut reg = AdapterRegistry::new(3);
        reg.enable_gathered(&h, 4).unwrap(); // 3 tenant slots + identity
        reg.register(&h, entry(&h, "a", 1)).unwrap();
        reg.register(&h, entry(&h, "b", 2)).unwrap();
        // lowest free slot first; slot 0 is never assigned
        assert_eq!(reg.bank_slot("a"), Some(1));
        assert_eq!(reg.bank_slot("b"), Some(2));
        // the slice holds the tenant's weights; the identity slot stays 0
        let want_a = entry(&h, "a", 1);
        let src = find(&want_a.host_sets, "a_q").unwrap();
        assert_eq!(bank_slice(&reg, 1, &h), src.data());
        assert!(bank_slice(&reg, 0, &h).iter().all(|&x| x == 0.0));
        // eviction recycles the slot for the next registration
        assert!(reg.evict("a"));
        assert_eq!(reg.bank_slot("a"), None);
        reg.register(&h, entry(&h, "c", 3)).unwrap();
        assert_eq!(reg.bank_slot("c"), Some(1));
        let want_c = entry(&h, "c", 3);
        let src = find(&want_c.host_sets, "a_q").unwrap();
        assert_eq!(bank_slice(&reg, 1, &h), src.data(), "new tenant overwrites the slice");
        // same-id re-registration keeps the slot, new weights land in it
        reg.register(&h, entry(&h, "b", 9)).unwrap();
        assert_eq!(reg.bank_slot("b"), Some(2));
        let want_b = entry(&h, "b", 9);
        let src = find(&want_b.host_sets, "a_q").unwrap();
        assert_eq!(bank_slice(&reg, 2, &h), src.data());
        assert_eq!(reg.bank().unwrap().assigned(), 2);
    }

    #[test]
    fn gathered_bank_follows_lru_eviction() {
        let h = hyper();
        let mut reg = AdapterRegistry::new(2);
        reg.enable_gathered(&h, 4).unwrap();
        reg.register(&h, entry(&h, "a", 1)).unwrap();
        reg.register(&h, entry(&h, "b", 2)).unwrap();
        assert!(reg.get("a").is_some()); // touch a → b is the LRU victim
        let evicted = reg.register(&h, entry(&h, "c", 3)).unwrap();
        assert_eq!(evicted.as_deref(), Some("b"));
        assert_eq!(reg.bank_slot("b"), None, "LRU victim's slot must be freed");
        assert_eq!(reg.bank_slot("c"), Some(2), "victim's slot is recycled");
        assert_eq!(reg.bank_slot("a"), Some(1));
    }

    #[test]
    fn enable_gathered_backfills_and_bounds_capacity() {
        let h = hyper();
        // capacity above the bank's tenant slots is a config error: the
        // LRU bound could admit a tenant the bank cannot hold
        let mut reg = AdapterRegistry::new(8);
        let e = reg.enable_gathered(&h, 4).unwrap_err();
        assert!(format!("{e:#}").contains("tenant slots"), "{e:#}");
        // tenants registered before the bank exists get backfilled
        let mut reg = AdapterRegistry::new(3);
        reg.register(&h, entry(&h, "x", 1)).unwrap();
        reg.register(&h, entry(&h, "y", 2)).unwrap();
        reg.enable_gathered(&h, 4).unwrap();
        assert_eq!(reg.bank_slot("x"), Some(1));
        assert_eq!(reg.bank_slot("y"), Some(2));
        let want = entry(&h, "y", 2);
        let src = find(&want.host_sets, "a_q").unwrap();
        assert_eq!(bank_slice(&reg, 2, &h), src.data());
        // a bank without an identity slot is rejected outright
        assert!(GatheredBank::new(&h, 1).is_err());
    }

    #[test]
    fn gathered_bank_exhaustion_is_a_hard_error() {
        let h = hyper();
        let mut bank = GatheredBank::new(&h, 3).unwrap(); // 2 tenant slots
        bank.register(&entry(&h, "a", 1)).unwrap();
        bank.register(&entry(&h, "b", 2)).unwrap();
        let e = bank.register(&entry(&h, "c", 3)).unwrap_err();
        assert!(format!("{e:#}").contains("no free adapter-bank slot"), "{e:#}");
        // replace of a banked tenant still works at full occupancy
        assert_eq!(bank.register(&entry(&h, "a", 9)).unwrap(), 1);
    }

    #[test]
    fn shared_source_sync_fills_replica_banks_identically() {
        let h = hyper();
        let source = SharedAdapterSource::new(h.clone(), 3);
        source.register_all(vec![entry(&h, "a", 1), entry(&h, "b", 2)]).unwrap();
        // two replicas enable the bank before their first sync (the pool
        // worker startup order) and must converge on identical slots
        let mk = || {
            let mut r = AdapterRegistry::new(3);
            r.enable_gathered(&h, 4).unwrap();
            r
        };
        let (mut r0, mut r1) = (mk(), mk());
        let (mut c0, mut c1) = (0u64, 0u64);
        source.sync(&mut r0, None, &mut c0).unwrap();
        source.sync(&mut r1, None, &mut c1).unwrap();
        for id in ["a", "b"] {
            assert_eq!(r0.bank_slot(id), r1.bank_slot(id), "replicas diverged on '{id}'");
            assert!(r0.bank_slot(id).is_some());
        }
        // churn: evict + register reaches both replicas with the same slot
        source.evict("a");
        source.register(entry(&h, "c", 3)).unwrap();
        source.sync(&mut r0, None, &mut c0).unwrap();
        source.sync(&mut r1, None, &mut c1).unwrap();
        assert_eq!(r0.bank_slot("a"), None);
        assert_eq!(r0.bank_slot("c"), r1.bank_slot("c"));
        assert_eq!(r0.bank_slot("c"), Some(1), "recycled slot must be deterministic");
    }

    #[test]
    fn adapter_dir_roundtrips_into_registry() {
        let h = hyper();
        let dir = std::env::temp_dir().join("sqft_registry_test");
        std::fs::remove_dir_all(&dir).ok();
        for (i, id) in ["alpha", "beta"].iter().enumerate() {
            let e = entry(&h, id, i as u64 + 1);
            checkpoint::save_adapter(
                &dir.join(format!("{id}.ckpt")),
                &e.host_sets[0],
                &e.host_sets[1],
                "test",
                &e.eval_kind,
                id,
                "lora",
                0.0,
            )
            .unwrap();
        }
        // metadata is inspectable before any registration (cmd_serve
        // derives base prep from it)
        let cks = load_adapter_dir(&dir, "test").unwrap();
        assert_eq!(cks.len(), 2);
        assert!(cks.iter().all(|c| c.method == "lora" && c.sparsity == 0.0));
        // the production path: from_ckpt + register_all
        let entries: Vec<AdapterEntry> = load_adapter_dir(&dir, "test")
            .unwrap()
            .into_iter()
            .map(|c| AdapterEntry::from_ckpt(c, "adapter"))
            .collect();
        let mut reg = AdapterRegistry::new(4);
        let loaded = reg.register_all(&h, entries).unwrap();
        assert_eq!(loaded, vec!["alpha".to_string(), "beta".to_string()]);
        assert!(reg.contains("alpha") && reg.contains("beta"));
        let a = reg.get("alpha").unwrap();
        assert_eq!(a.host_sets.len(), 2);
        assert!(a.host_sets[0].contains("a_q") && a.host_sets[1].contains("scale_q"));
        // config mismatch is an error at load time
        assert!(load_adapter_dir(&dir, "other-config").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
\n
    #[test]
    fn degrade_ladder_config_and_tiering_gate() {
        let mut reg = AdapterRegistry::new(4);
        assert!(!reg.tiering_enabled());
        reg.set_degrade_ranks(&[2, 8, 0, 4, 8]);
        assert_eq!(reg.degrade_ranks(), &[8, 4, 2]);
        assert!(reg.tiering_enabled());
        reg.set_degrade_ranks(&[]);
        assert!(!reg.tiering_enabled());
        reg.set_device_budget(1024);
        assert!(reg.tiering_enabled());
        reg.set_device_budget(0);
        reg.catalog_disk("cold", std::env::temp_dir().join("cold.ckpt"));
        assert!(reg.tiering_enabled());
        assert_eq!(reg.cold_ids(), vec!["cold".to_string()]);
    }

    #[test]
    fn logical_bytes_shrink_with_rank() {
        let h = hyper();
        let e = entry(&h, "t", 1);
        let full = AdapterRegistry::entry_logical_bytes(&e, None);
        let half = AdapterRegistry::entry_logical_bytes(&e, Some(4));
        let quarter = AdapterRegistry::entry_logical_bytes(&e, Some(2));
        assert!(full > half && half > quarter, "{full} {half} {quarter}");
        // rank >= r_max clamps to full
        assert_eq!(AdapterRegistry::entry_logical_bytes(&e, Some(64)), full);
        // exact delta going 8 -> 4: per mod, a_ loses l*(8-4)*in elems,
        // b_ loses l*out*(8-4), rankmask_ loses l*(8-4); scale_ and the
        // sparsity masks are rank-independent (4 bytes/elem)
        let delta_elems: usize = [(64, 64), (64, 64), (64, 64), (128, 64), (64, 128)]
            .iter()
            .map(|&(out, inp): &(usize, usize)| 2 * 4 * inp + 2 * out * 4 + 2 * 4)
            .sum();
        assert_eq!(full - half, delta_elems * 4);
    }

    #[test]
    fn degraded_view_zeroes_tail_and_still_validates() {
        let h = hyper();
        let e = entry(&h, "t", 3);
        let view = AdapterRegistry::degraded_view(&e, 2).unwrap();
        AdapterRegistry::validate(&h, &view).unwrap();
        // a_q rows >= 2 are zeroed per layer, b_q cols >= 2 likewise
        let a = view.host_sets[0].get("a_q").unwrap();
        let (l_n, r_n, in_n) = (a.shape()[0], a.shape()[1], a.shape()[2]);
        for l in 0..l_n {
            for j in 2..r_n {
                let off = (l * r_n + j) * in_n;
                assert!(a.data()[off..off + in_n].iter().all(|&x| x == 0.0));
            }
            // the kept rows carry the original weights
            let off = l * r_n * in_n;
            let orig = e.host_sets[0].get("a_q").unwrap();
            assert_eq!(&a.data()[off..off + 2 * in_n], &orig.data()[off..off + 2 * in_n]);
        }
        let b = view.host_sets[0].get("b_q").unwrap();
        let rb = b.shape()[2];
        for row in 0..b.shape()[0] * b.shape()[1] {
            for j in 2..rb {
                assert_eq!(b.data()[row * rb + j], 0.0);
            }
        }
        // rank params clamp to a 2-prefix and rescale to the same alpha
        let mask = view.host_sets[1].get("rankmask_q").unwrap();
        for l in 0..l_n {
            let row = &mask.data()[l * r_n..(l + 1) * r_n];
            assert_eq!(row.iter().sum::<f32>(), 2.0, "layer {l}: {row:?}");
        }
        let sc_old = e.host_sets[1].get("scale_q").unwrap();
        let mask_old = e.host_sets[1].get("rankmask_q").unwrap();
        let sc_new = view.host_sets[1].get("scale_q").unwrap();
        for l in 0..l_n {
            let r_full: f32 = mask_old.data()[l * r_n..(l + 1) * r_n].iter().sum();
            let alpha = sc_old.data()[l] * r_full;
            assert!((sc_new.data()[l] - alpha / 2.0).abs() < 1e-5);
        }
    }

    #[test]
    fn quarantine_isolates_one_tenant_and_reregistration_cures() {
        let h = hyper();
        let mut reg = AdapterRegistry::new(4);
        reg.register(&h, entry(&h, "good", 1)).unwrap();
        reg.register(&h, entry(&h, "bad", 2)).unwrap();
        reg.quarantine("bad", "corrupt checkpoint (f32 payload section)");
        assert!(!reg.contains("bad") && reg.contains("good"));
        assert!(reg.is_quarantined("bad"));
        assert_eq!(
            reg.quarantine_reason("bad"),
            Some("corrupt checkpoint (f32 payload section)")
        );
        let err = reg.unavailable_error("bad");
        assert_eq!(err.kind(), "tenant_unavailable");
        assert!(err.to_string().contains("quarantined"));
        // unknown ids refuse with the plain reason
        assert!(reg.unavailable_error("nobody").to_string().contains("not registered"));
        // a fresh validated registration cures the quarantine
        reg.register(&h, entry(&h, "bad", 5)).unwrap();
        assert!(reg.contains("bad") && !reg.is_quarantined("bad"));
    }

    #[test]
    fn prefetch_host_loads_cataloged_tenants_and_quarantines_corruption() {
        let h = hyper();
        let dir = std::env::temp_dir().join("sqft_registry_prefetch");
        std::fs::remove_dir_all(&dir).ok();
        let e = entry(&h, "warm", 1);
        let good = dir.join("warm.ckpt");
        checkpoint::save_adapter(
            &good,
            &e.host_sets[0],
            &e.host_sets[1],
            "test",
            &e.eval_kind,
            "warm",
            "lora",
            0.0,
        )
        .unwrap();
        // corrupt sibling: flip one payload byte of a valid checkpoint
        let torn = dir.join("torn.ckpt");
        let e2 = entry(&h, "torn", 2);
        checkpoint::save_adapter(
            &torn,
            &e2.host_sets[0],
            &e2.host_sets[1],
            "test",
            &e2.eval_kind,
            "torn",
            "lora",
            0.0,
        )
        .unwrap();
        let mut bytes = std::fs::read(&torn).unwrap();
        let n = bytes.len();
        bytes[n - 200] ^= 0x40;
        std::fs::write(&torn, bytes).unwrap();

        let mut reg = AdapterRegistry::new(4);
        reg.catalog_disk("warm", good);
        reg.catalog_disk("torn", torn);
        assert_eq!(reg.cold_ids().len(), 2);
        // cold -> host: loads, validates, becomes serveable (host tier)
        assert!(reg.prefetch_host(&h, "warm").unwrap());
        assert!(reg.contains("warm"));
        assert!(!reg.prefetch_host(&h, "warm").unwrap(), "already resident");
        assert!(!reg.prefetch_host(&h, "unknown").unwrap(), "not cataloged");
        // corruption quarantines exactly that tenant with a typed refusal
        let err = reg.prefetch_host(&h, "torn").unwrap_err();
        let serr = ServeError::of(&err).expect("typed TenantUnavailable");
        assert_eq!(serr.kind(), "tenant_unavailable");
        assert!(reg.is_quarantined("torn") && reg.contains("warm"));
        assert!(reg.quarantine_reason("torn").unwrap().contains("checksum"));
        // quarantined ids are not re-prefetched
        assert!(!reg.prefetch_host(&h, "torn").unwrap());
        assert!(reg.cold_ids().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_source_replicates_quarantine_and_cure() {
        let h = hyper();
        let source = SharedAdapterSource::new(h.clone(), 4);
        source.register(entry(&h, "t0", 1)).unwrap();
        source.register(entry(&h, "t1", 2)).unwrap();
        let mut reg = AdapterRegistry::new(4);
        let mut cursor = 0u64;
        source.sync(&mut reg, None, &mut cursor).unwrap();
        assert!(reg.contains("t0") && reg.contains("t1"));
        // quarantine replicates: the replica drops the tenant and records
        // the reason, siblings untouched
        assert!(source.quarantine("t0", "corrupt checkpoint"));
        source.sync(&mut reg, None, &mut cursor).unwrap();
        assert!(!reg.contains("t0") && reg.contains("t1"));
        assert!(reg.is_quarantined("t0"));
        assert_eq!(source.quarantine_reason("t0").as_deref(), Some("corrupt checkpoint"));
        // re-registration cures fleet-wide
        source.register(entry(&h, "t0", 9)).unwrap();
        source.sync(&mut reg, None, &mut cursor).unwrap();
        assert!(reg.contains("t0") && !reg.is_quarantined("t0"));
        assert!(source.quarantine_reason("t0").is_none());
    }
}
