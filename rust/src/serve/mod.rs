//! Multi-tenant serving: registry → scheduler → engine.
//!
//! The paper's §2.5 motivation is serving economics: merged models
//! (SparsePEFT/QA-SparsePEFT) serve faster and smaller than base+adapter
//! pairs, while unmerged pairs keep precision flexibility.  This module
//! serves *many* fine-tuned tenants over one device-resident frozen base —
//! the deployment pattern LoRA-style adapters were designed for:
//!
//!   - [`registry::AdapterRegistry`] holds validated per-tenant adapter
//!     state (hot registration/eviction, LRU-bounded); `register_resident`
//!     uploads a tenant's adapters to the device once, so steady-state
//!     decoding ships only the token batch across the PJRT boundary;
//!   - [`scheduler::Scheduler`] groups pending requests into same-adapter
//!     batches (one forward serves one adapter, cached or host-side, so a
//!     batch must share one adapter) with an aging policy so low-traffic
//!     tenants don't starve;
//!   - [`Engine`] owns the Runtime handles (PJRT is not Sync) and executes
//!     batches for any registered adapter — or the merged no-adapter fast
//!     path; [`Router`] ties the three together on one serving thread,
//!     with request producers talking to it over channels.
//!
//! Greedy decoding is teacher-forcing-free: each generated token re-runs
//! the batched forward with the answer-so-far appended (no KV cache in the
//! artifact — acceptable at seq<=128, and identical work for merged vs
//! unmerged, which is what the Table 7 comparison needs).

pub mod registry;
pub mod scheduler;

pub use registry::{load_adapter_dir, AdapterEntry, AdapterRegistry};
pub use scheduler::{Request, Scheduler, SchedulerMetrics, SchedulerOpts};

use crate::data::Tokenizer;
use crate::model::ParamSet;
use crate::nls::{Config, SearchSpace};
use crate::report::Table;
use crate::runtime::{args::build_args, DeviceStore, Runtime};
use crate::util::{summarize, Summary};
use anyhow::{anyhow, bail, Result};
use std::cell::Cell;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::time::{Duration, Instant};

/// Stats label for the merged / no-adapter fast path.
pub const MERGED_ID: &str = "merged";

/// Engine state: device-resident frozen weights + default host inputs for
/// the merged / single-adapter compatibility path.
pub struct Engine<'a> {
    rt: &'a Runtime,
    config: String,
    device: DeviceStore,
    /// host-side eval inputs used when a request names no adapter
    /// (no-op adapters = the merged fast path)
    default_sets: Vec<ParamSet>,
    default_kind: String,
    tok: Tokenizer,
    max_new_tokens: usize,
    /// forwards executed by the most recent generate call (benches/tests
    /// divide upload-byte deltas by this to get per-step cost)
    last_decode_steps: Cell<usize>,
}

impl<'a> Engine<'a> {
    /// Build an engine from frozen (device) params.  `adapters` optionally
    /// installs a default adapter for the no-id path; `None` means the
    /// merged fast path (no-op adapters, B = 0).  `max_new_tokens` bounds
    /// greedy decoding per request and must fit the artifact sequence.
    pub fn new(
        rt: &'a Runtime,
        config: &str,
        frozen: &ParamSet,
        adapters: Option<(&ParamSet, &SearchSpace, &Config)>,
        eval_kind: &str,
        max_new_tokens: usize,
    ) -> Result<Engine<'a>> {
        let hyper = rt.model(config)?.clone();
        if max_new_tokens == 0 || max_new_tokens > hyper.seq_len.saturating_sub(2) {
            bail!(
                "max_new_tokens {max_new_tokens} does not fit seq_len {} (need 1..={})",
                hyper.seq_len,
                hyper.seq_len.saturating_sub(2)
            );
        }
        let mut device = DeviceStore::new();
        for (n, t) in frozen.iter() {
            device.put_tensor(&rt.client, n, t)?;
        }
        let mut default_sets = Vec::new();
        match adapters {
            Some((ad, space, cfg)) => {
                default_sets.push(ad.clone());
                default_sets.push(space.realize(cfg)?);
            }
            None => {
                // merged model: no-op adapters (B = 0)
                let mut rng = crate::tensor::Rng::new(1);
                default_sets.push(crate::model::init_adapters(&hyper, &mut rng, 1.0));
                let space = SearchSpace::default_for(&hyper, 1.0);
                default_sets.push(space.realize(&space.max_config())?);
            }
        }
        Ok(Engine {
            rt,
            config: config.to_string(),
            device,
            default_sets,
            default_kind: eval_kind.to_string(),
            tok: Tokenizer::new(),
            max_new_tokens,
            last_decode_steps: Cell::new(0),
        })
    }

    pub fn max_new_tokens(&self) -> usize {
        self.max_new_tokens
    }

    /// The artifact's fixed batch dimension (upper bound on batch size).
    pub fn artifact_batch(&self) -> Result<usize> {
        Ok(self.rt.model(&self.config)?.batch)
    }

    /// Forwards executed by the most recent generate call on this engine.
    pub fn last_decode_steps(&self) -> usize {
        self.last_decode_steps.get()
    }

    /// Greedy-decode a batch of prompts with the engine's default adapter
    /// state (merged fast path when built with `adapters: None`).
    pub fn generate_batch<S: AsRef<str>>(&self, prompts: &[S]) -> Result<Vec<String>> {
        let sets: Vec<&ParamSet> = self.default_sets.iter().collect();
        self.generate_batch_cached(None, &sets, &self.default_kind, prompts)
    }

    /// Greedy-decode a batch of prompts against explicit per-forward host
    /// inputs (one tenant's adapter + rank params) — the fallback for
    /// unregistered one-off calls: the adapter host set is re-uploaded
    /// every decode step.  All prompts in the batch share `host_sets`.
    pub fn generate_batch_for<S: AsRef<str>>(
        &self,
        host_sets: &[&ParamSet],
        eval_kind: &str,
        prompts: &[S],
    ) -> Result<Vec<String>> {
        self.generate_batch_cached(None, host_sets, eval_kind, prompts)
    }

    /// The multi-tenant hot path.  With `tenant_device` (a registered
    /// tenant's cached buffer set) every adapter input resolves to a
    /// borrowed device handle and a steady-state decode step uploads
    /// *only* the token batch; `host_sets` then only backfill names the
    /// device sets don't carry.  Without it, this is the host-upload
    /// fallback path.
    ///
    /// Decode-loop mechanics: one flattened `(batch, seq)` token buffer is
    /// reused across steps (no per-token re-flatten) and re-uploaded once
    /// per forward, guarded by a dirty flag so an unchanged buffer is
    /// never re-shipped (today every executed forward appends at least one
    /// token, so the guard is a structural invariant rather than a
    /// measured saving); the loop stops paying forwards the moment every
    /// real row is done.
    pub fn generate_batch_cached<S: AsRef<str>>(
        &self,
        tenant_device: Option<&DeviceStore>,
        host_sets: &[&ParamSet],
        eval_kind: &str,
        prompts: &[S],
    ) -> Result<Vec<String>> {
        let hyper = self.rt.model(&self.config)?.clone();
        if prompts.is_empty() || prompts.len() > hyper.batch {
            bail!("batch of {} prompts (max {})", prompts.len(), hyper.batch);
        }
        let exe = self.rt.executable(&self.config, eval_kind)?;
        let (b, seq, v) = (hyper.batch, hyper.seq_len, hyper.vocab);
        // one flattened token buffer + current row lengths
        let mut flat = vec![0i32; b * seq];
        let mut lens: Vec<usize> = Vec::with_capacity(b);
        for (bi, p) in prompts.iter().enumerate() {
            let ids = self.tok.encode(p.as_ref())?;
            if ids.len() + 1 + self.max_new_tokens > seq {
                bail!("prompt too long for seq {seq}");
            }
            let row = &mut flat[bi * seq..(bi + 1) * seq];
            row[0] = Tokenizer::BOS;
            for (i, &id) in ids.iter().enumerate() {
                row[i + 1] = id;
            }
            lens.push(ids.len() + 1);
        }
        for bi in prompts.len()..b {
            flat.copy_within(0..seq, bi * seq);
            lens.push(0); // padding row: never decoded
        }
        let mut done = vec![false; prompts.len()];
        let mut answers: Vec<String> = vec![String::new(); prompts.len()];
        let mut active = prompts.len();
        let mut steps = 0usize;
        // the token batch rides in a device store behind a dirty flag: an
        // unchanged buffer is never re-shipped (every forward currently
        // dirties it — at least one active row appends a token — so this
        // is one upload per forward, kept explicit rather than incidental)
        let mut step_store = DeviceStore::new();
        let mut dirty = true;
        for _ in 0..self.max_new_tokens {
            if active == 0 {
                break; // fully-done batch: stop paying forwards
            }
            if dirty {
                step_store.put_i32(&self.rt.client, "tokens", &[b, seq], &flat)?;
                dirty = false;
            }
            // precedence mirrors the host-upload path exactly (frozen
            // device store beats per-tenant state), so cached and host
            // answers are byte-identical by construction
            let mut devices: Vec<&DeviceStore> = Vec::with_capacity(3);
            devices.push(&step_store);
            devices.push(&self.device);
            if let Some(d) = tenant_device {
                devices.push(d);
            }
            let args = build_args(&exe.spec, &devices, host_sets, None, &[])?;
            let outs = exe.run_mixed(&self.rt.client, &args)?;
            steps += 1;
            let logits = &outs[0];
            for (bi, len) in lens.iter_mut().enumerate().take(prompts.len()) {
                if done[bi] || *len == 0 {
                    continue;
                }
                let pos = *len - 1; // logits at last filled position
                let row = &logits.data()[bi * seq * v + pos * v..bi * seq * v + (pos + 1) * v];
                let mut best = 0usize;
                for t in 1..v {
                    if row[t] > row[best] {
                        best = t;
                    }
                }
                let ch = self.tok.decode_one(best as i32)?;
                if ch == '.' || *len >= seq - 1 {
                    done[bi] = true;
                    active -= 1;
                }
                if ch != '.' {
                    answers[bi].push(ch);
                }
                flat[bi * seq + *len] = best as i32;
                *len += 1;
                dirty = true;
            }
        }
        self.last_decode_steps.set(steps);
        Ok(answers)
    }
}

/// Serving outcome for one tenant (or the whole run).
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub served: usize,
    pub errors: usize,
    pub wall_secs: f64,
    pub throughput: f64,
    pub latency_ms: Option<Summary>,
}

/// Per-run serving report: totals, per-tenant breakdown, and the
/// scheduler's queue-depth / batch-fill counters.
#[derive(Debug)]
pub struct MultiServeStats {
    pub total: ServeStats,
    /// keyed by adapter id (the merged path reports as [`MERGED_ID`])
    pub per_tenant: Vec<(String, ServeStats)>,
    pub scheduler: SchedulerMetrics,
}

impl MultiServeStats {
    pub fn tenant(&self, id: &str) -> Option<&ServeStats> {
        self.per_tenant.iter().find(|(k, _)| k == id).map(|(_, s)| s)
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Multi-tenant serving",
            &["tenant", "served", "errors", "req/s", "mean ms", "p50 ms", "p95 ms"],
        );
        let lat = |s: &ServeStats, f: fn(&Summary) -> f64| match &s.latency_ms {
            Some(l) => format!("{:.1}", f(l)),
            None => "-".to_string(),
        };
        let row = |name: &str, s: &ServeStats| {
            vec![
                name.to_string(),
                s.served.to_string(),
                s.errors.to_string(),
                format!("{:.1}", s.throughput),
                lat(s, |l| l.mean),
                lat(s, |l| l.p50),
                lat(s, |l| l.p95),
            ]
        };
        for (id, s) in &self.per_tenant {
            t.row(row(id.as_str(), s));
        }
        t.row(row("TOTAL", &self.total));
        let mut out = t.render();
        let _ = writeln!(
            out,
            "scheduler: {} batches, avg fill {:.2}, {} aged, max queue depth {}",
            self.scheduler.batches,
            self.scheduler.avg_fill(),
            self.scheduler.aged_batches,
            self.scheduler.max_queue_depth
        );
        out
    }
}

#[derive(Default)]
struct Tally {
    served: usize,
    errors: usize,
    latencies: Vec<f64>,
}

impl Tally {
    fn finish(self, wall: f64) -> ServeStats {
        ServeStats {
            served: self.served,
            errors: self.errors,
            wall_secs: wall,
            throughput: self.served as f64 / wall.max(1e-9),
            latency_ms: if self.latencies.is_empty() {
                None
            } else {
                Some(summarize(self.latencies))
            },
        }
    }
}

/// One engine + one registry = a multi-tenant serving endpoint.
pub struct Router<'a> {
    engine: Engine<'a>,
    registry: AdapterRegistry,
}

impl<'a> Router<'a> {
    pub fn new(engine: Engine<'a>, registry: AdapterRegistry) -> Router<'a> {
        Router { engine, registry }
    }

    pub fn engine(&self) -> &Engine<'a> {
        &self.engine
    }

    pub fn registry_mut(&mut self) -> &mut AdapterRegistry {
        &mut self.registry
    }

    /// Serve requests from a channel until it closes and all queues drain.
    /// Replaces the old FIFO coalescing loop: pending requests are grouped
    /// into same-adapter batches by the [`Scheduler`]'s fill+aging policy.
    pub fn serve(&mut self, rx: Receiver<Request>, opts: SchedulerOpts) -> Result<MultiServeStats> {
        let cap = self.engine.artifact_batch()?;
        let opts = SchedulerOpts { max_batch: opts.max_batch.min(cap).max(1), ..opts };
        let mut sched = Scheduler::new(opts);
        let mut tallies: BTreeMap<String, Tally> = BTreeMap::new();
        let start = Instant::now();
        let mut open = true;
        while open || !sched.is_empty() {
            if sched.is_empty() {
                // block for the first pending request
                match rx.recv() {
                    Ok(r) => sched.push(r),
                    Err(_) => {
                        open = false;
                        continue;
                    }
                }
            }
            // drain whatever else is already queued
            loop {
                match rx.try_recv() {
                    Ok(r) => sched.push(r),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            let Some((id, reqs)) = sched.next_batch(Instant::now()) else {
                continue;
            };
            self.dispatch(id, reqs, &mut tallies);
        }
        let wall = start.elapsed().as_secs_f64();
        let mut total = Tally::default();
        let mut per_tenant = Vec::new();
        for (id, tally) in tallies {
            total.served += tally.served;
            total.errors += tally.errors;
            total.latencies.extend_from_slice(&tally.latencies);
            per_tenant.push((id, tally.finish(wall)));
        }
        Ok(MultiServeStats {
            total: total.finish(wall),
            per_tenant,
            scheduler: sched.metrics().clone(),
        })
    }

    /// Execute one same-adapter batch and reply to every request in it.
    /// Registered-resident tenants take the device-cached path (adapter
    /// buffers already on device); host-only registrations fall back to
    /// per-forward upload.  Prompts are borrowed, not cloned.
    fn dispatch(
        &mut self,
        id: Option<String>,
        reqs: Vec<Request>,
        tallies: &mut BTreeMap<String, Tally>,
    ) {
        let prompts: Vec<&str> = reqs.iter().map(|r| r.prompt.as_str()).collect();
        let result = match &id {
            None => self.engine.generate_batch(&prompts),
            Some(tid) => match self.registry.get_for_serving(tid) {
                Some((entry, dev)) => {
                    let sets: Vec<&ParamSet> = entry.host_sets.iter().collect();
                    self.engine.generate_batch_cached(dev, &sets, &entry.eval_kind, &prompts)
                }
                None => Err(anyhow!("adapter '{tid}' is not registered")),
            },
        };
        let key = id.as_deref().unwrap_or(MERGED_ID).to_string();
        let tally = tallies.entry(key).or_default();
        match result {
            Ok(answers) => {
                for (req, ans) in reqs.into_iter().zip(answers) {
                    tally.latencies.push(req.enqueued.elapsed().as_secs_f64() * 1e3);
                    tally.served += 1;
                    let _ = req.reply.send(Ok(ans));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for req in reqs {
                    tally.errors += 1;
                    let _ = req.reply.send(Err(anyhow!(msg.clone())));
                }
            }
        }
    }
}

/// Drive a router with a synthetic open-loop workload: one producer thread
/// sends `(adapter_id, prompt)` requests at `inter_arrival` spacing, the
/// router serves on the calling thread; returns the measured stats.
pub fn benchmark_router(
    router: &mut Router,
    requests: Vec<(Option<String>, String)>,
    inter_arrival: Duration,
    opts: SchedulerOpts,
) -> Result<MultiServeStats> {
    let (tx, rx) = channel::<Request>();
    let producer = std::thread::spawn(move || {
        let mut replies = Vec::new();
        for (adapter_id, prompt) in requests {
            let (rtx, rrx) = channel();
            let _ = tx.send(Request { adapter_id, prompt, reply: rtx, enqueued: Instant::now() });
            replies.push(rrx);
            if !inter_arrival.is_zero() {
                std::thread::sleep(inter_arrival);
            }
        }
        drop(tx);
        // drain replies so the router's sends don't error
        for r in replies {
            let _ = r.recv();
        }
    });
    let stats = router.serve(rx, opts)?;
    producer.join().ok();
    Ok(stats)
}
