//! Multi-tenant serving: registry → scheduler → engine.
//!
//! The paper's §2.5 motivation is serving economics: merged models
//! (SparsePEFT/QA-SparsePEFT) serve faster and smaller than base+adapter
//! pairs, while unmerged pairs keep precision flexibility.  This module
//! serves *many* fine-tuned tenants over one device-resident frozen base —
//! the deployment pattern LoRA-style adapters were designed for:
//!
//!   - [`registry::AdapterRegistry`] holds validated per-tenant adapter
//!     state (hot registration/eviction, LRU-bounded); `register_resident`
//!     uploads a tenant's adapters to the device once, and with the
//!     gathered bank enabled ([`registry::GatheredBank`]) also writes them
//!     into stacked `(T, …)` bank tensors, so steady-state decoding ships
//!     only the token batch and a per-row i32 slot vector across the PJRT
//!     boundary;
//!   - [`scheduler::Scheduler`] pops **mixed** batches: one slot-level
//!     policy over every tenant's queue (fullest queue first, an aged
//!     head anywhere wins outright), since the `eval_gathered` artifact
//!     applies each row's own adapter — a batch no longer needs to share
//!     one.  Aging is a fairness tie-break inside the pop, not an
//!     admission hold;
//!   - [`Engine`] owns the Runtime handles (PJRT is not Sync) and executes
//!     batches for any mix of registered adapters — or the merged
//!     no-adapter fast path via the bank's reserved identity slot 0;
//!     [`Router`] ties the three together on one serving thread, with
//!     request producers talking to it over channels.
//!
//! Engines that can't run the gathered artifact (packed-INT4 bases, whose
//! artifact has no f32 weight inputs) and tenants it can't express
//! (QA-kind adapters, which merge through the fake-quant path) fall back
//! to per-tenant *uniform* sessions: the dispatcher splits a mixed batch
//! by tenant and serves the groups sequentially, refilling each from its
//! own queue only ([`Scheduler::admit_for`], which pauses when another
//! tenant's head ages — the pre-gathered starvation bound).  Either way
//! each request's answer is byte-identical: the gathered kernel computes
//! the same masked adapter projection per row that the uniform artifact
//! computes per batch.
//!
//! Greedy decoding is teacher-forcing-free: each generated token re-runs
//! the batched forward with the answer-so-far appended (no KV cache in the
//! artifact — acceptable at seq<=128, and identical work for merged vs
//! unmerged, which is what the Table 7 comparison needs).
//!
//! Decoding is **continuous-batched** (slot-based): the engine owns a
//! persistent [`DecodeSession`] sized `(artifact batch) × seq` whose slots
//! hold independent in-flight requests.  A slot is retired the forward its
//! row emits the stop token (or hits its per-request cap) and can be
//! re-filled with *any* waiting request *between forwards* — the session
//! tracks a per-slot bank index, so a freed slot takes the next request
//! regardless of tenant.  Short requests no longer pay for the longest
//! row in their batch, and the device stays busy as long as any queue is
//! non-empty.  The old run-to-completion path
//! ([`Engine::generate_batch_cached`]) is a thin wrapper over the same
//! session (admit everything up front, never re-fill), so the two paths
//! are byte-identical per request by construction.
//!
//! Serving scales past one core with the **worker pool** ([`pool`]): N
//! worker threads, each owning a full engine replica (its own `Runtime`,
//! compiled executables, device stores, and registry replica synced from
//! a [`SharedAdapterSource`]), fed by a [`ShardedScheduler`] that keeps
//! each tenant's traffic on a home worker and lets idle workers steal
//! waiting batches.  Replicas run identical artifacts and rows decode
//! independently, so per-request answers are byte-identical to the
//! single-worker [`Router`] reference regardless of worker count or
//! batch composition.

pub mod error;
pub mod pool;
pub mod registry;
pub mod scheduler;

pub use error::ServeError;
pub use pool::{
    benchmark_pool, benchmark_pool_obs, serve_pool, serve_pool_obs, EngineSpec, PoolOpts,
    PoolServeStats, WorkerStats,
};
pub use registry::{
    gathered_slots, load_adapter_dir, load_adapter_dir_tolerant, AdapterEntry, AdapterRegistry,
    GatheredBank, SharedAdapterSource,
};
pub use scheduler::{
    CancelHandle, Request, Scheduler, SchedulerMetrics, SchedulerOpts, ShardedScheduler,
};

use crate::data::Tokenizer;
use crate::model::ParamSet;
use crate::nls::{Config, SearchSpace};
use crate::obs::{Counter, Gauge, Histogram, Registry, Series, TraceLog};
use crate::report::Table;
use crate::runtime::{args::build_args, DeviceStore, Runtime};
use crate::util::json::Json;
use crate::util::{summarize, Summary};
use anyhow::{anyhow, bail, Context, Result};
use std::cell::Cell;
use std::collections::{BTreeMap, VecDeque};
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Stats label for the merged / no-adapter fast path.
pub const MERGED_ID: &str = "merged";

/// Artifact kind of the gathered mixed-tenant eval (stacked adapter banks
/// plus a per-row i32 `adapter_idx` input).
pub const GATHERED_KIND: &str = "eval_gathered";

/// Engine state: device-resident frozen weights + default host inputs for
/// the merged / single-adapter compatibility path.
pub struct Engine<'a> {
    rt: &'a Runtime,
    config: String,
    device: DeviceStore,
    /// host-side eval inputs used when a request names no adapter
    /// (no-op adapters = the merged fast path)
    default_sets: Vec<ParamSet>,
    default_kind: String,
    tok: Tokenizer,
    max_new_tokens: usize,
    /// token id that terminates an answer ('.')
    stop_id: i32,
    /// forwards executed by the most recent generate call (benches/tests
    /// divide upload-byte deltas by this to get per-step cost)
    last_decode_steps: Cell<usize>,
    /// token-batch uploads performed by the most recent generate call;
    /// `uploads <= steps` always, and a forward is only preceded by an
    /// upload when a live slot actually changed since the previous one
    last_decode_uploads: Cell<usize>,
    /// bytes of model state this engine keeps device-resident (frozen f32
    /// uploads, or packed u8 + f32 group params on the INT4 path) — the
    /// Table 7 inference-memory figure, reported through `ServeStats`
    resident_bytes: u64,
    /// true when the no-adapter path is the merged model (no-op adapters,
    /// B = 0) — exactly the case the gathered bank's identity slot 0
    /// reproduces, so `adapter_id: None` requests may ride mixed batches
    merged_default: bool,
    /// force the legacy full-forward decode path even when cache
    /// artifacts exist — the reference leg for equivalence tests and the
    /// `full_forward` bench comparison
    full_forward: Cell<bool>,
    /// latched when a cache-artifact probe fails (missing file, tuple
    /// root, wrong state shape): the engine permanently falls back to
    /// full forwards — correctness over speed, never mid-session mixing
    cache_broken: Cell<bool>,
    /// prefill forwards executed by the most recent generate call
    last_decode_prefills: Cell<usize>,
}

/// Artifact kinds for one eval kind's KV-cached decode split, resolved
/// once per forward by [`Engine::cache_plan`].
struct CachePlan {
    prefill: &'static str,
    decode: &'static str,
}

/// Packed per-slot KV-state row length in f32 elements: per-layer K and V
/// `(seq, d_model)` panes plus the row's frontier logits.  Must match
/// `kv_state_elems` in `python/compile/model.py` — the probe in
/// `cached_forward` enforces it at runtime.
fn kv_state_elems(h: &crate::runtime::ModelHyper) -> usize {
    2 * h.n_layers * h.seq_len * h.d_model + h.vocab
}

impl<'a> Engine<'a> {
    /// Build an engine from frozen (device) params.  `adapters` optionally
    /// installs a default adapter for the no-id path; `None` means the
    /// merged fast path (no-op adapters, B = 0).  `max_new_tokens` bounds
    /// greedy decoding per request and must fit the artifact sequence.
    pub fn new(
        rt: &'a Runtime,
        config: &str,
        frozen: &ParamSet,
        adapters: Option<(&ParamSet, &SearchSpace, &Config)>,
        eval_kind: &str,
        max_new_tokens: usize,
    ) -> Result<Engine<'a>> {
        let hyper = rt.model(config)?.clone();
        if max_new_tokens == 0 || max_new_tokens > hyper.seq_len.saturating_sub(2) {
            bail!(
                "max_new_tokens {max_new_tokens} does not fit seq_len {} (need 1..={})",
                hyper.seq_len,
                hyper.seq_len.saturating_sub(2)
            );
        }
        let mut device = DeviceStore::new();
        for (n, t) in frozen.iter() {
            device.put_tensor(&rt.client, n, t)?;
        }
        let mut default_sets = Vec::new();
        match adapters {
            Some((ad, space, cfg)) => {
                default_sets.push(ad.clone());
                default_sets.push(space.realize(cfg)?);
            }
            None => {
                // merged model: no-op adapters (B = 0)
                let mut rng = crate::tensor::Rng::new(1);
                default_sets.push(crate::model::init_adapters(&hyper, &mut rng, 1.0));
                let space = SearchSpace::default_for(&hyper, 1.0);
                default_sets.push(space.realize(&space.max_config())?);
            }
        }
        let merged_default = adapters.is_none();
        let tok = Tokenizer::new();
        let stop_id = tok.encode(".")?[0];
        Ok(Engine {
            rt,
            config: config.to_string(),
            device,
            default_sets,
            default_kind: eval_kind.to_string(),
            tok,
            max_new_tokens,
            stop_id,
            last_decode_steps: Cell::new(0),
            last_decode_uploads: Cell::new(0),
            resident_bytes: frozen.total_bytes() as u64,
            merged_default,
            full_forward: Cell::new(false),
            cache_broken: Cell::new(false),
            last_decode_prefills: Cell::new(0),
        })
    }

    /// Build an engine whose base stays in its final numerical format: the
    /// packed INT4 codes cross the PJRT boundary once as u8 buffers plus
    /// f32 group params, and every decode forward runs the `eval_int4`
    /// artifact — no dense f32 weight copy ever exists on the device.
    /// Serves merged-model (no-adapter) traffic only: the artifact has no
    /// adapter inputs because a merged model has no adapters.
    pub fn new_int4(
        rt: &'a Runtime,
        config: &str,
        model: &crate::pipeline::Int4Model,
        max_new_tokens: usize,
    ) -> Result<Engine<'a>> {
        if model.config != config {
            bail!(
                "INT4 model was packed for config '{}', engine runs '{config}'",
                model.config
            );
        }
        let hyper = rt.model(config)?.clone();
        if max_new_tokens == 0 || max_new_tokens > hyper.seq_len.saturating_sub(2) {
            bail!(
                "max_new_tokens {max_new_tokens} does not fit seq_len {} (need 1..={})",
                hyper.seq_len,
                hyper.seq_len.saturating_sub(2)
            );
        }
        let spec = rt
            .manifest
            .config(config)?
            .artifacts
            .get("eval_int4")
            .with_context(|| format!(
                "config '{config}' has no eval_int4 artifact; re-run `make artifacts` \
                 (the packed-INT4 serving path needs regenerated artifacts)"
            ))?
            .clone();
        // upload exactly the artifact's weight inputs, validating shapes
        // against the manifest so a stale checkpoint fails here, not
        // mid-serve
        let mut device = DeviceStore::new();
        for input in &spec.inputs {
            let name = input.name.as_str();
            if name == "tokens" {
                continue;
            }
            if let Some(p) = model.packed.get(name) {
                if input.dtype != crate::runtime::DType::U8 {
                    bail!("artifact input '{name}' is not u8; manifest/checkpoint mismatch");
                }
                let mut packed_shape = p.shape.clone();
                let last = packed_shape.len() - 1;
                packed_shape[last] /= 2;
                if packed_shape != input.shape {
                    bail!(
                        "packed '{name}': checkpoint shape {:?} packs to {:?}, artifact wants {:?}",
                        p.shape, packed_shape, input.shape
                    );
                }
                device.put_u8(&rt.client, name, &packed_shape, &p.data)?;
            } else {
                let t = model
                    .params
                    .get(name)
                    .with_context(|| format!("INT4 model missing artifact input '{name}'"))?;
                if t.shape() != input.shape.as_slice() {
                    bail!(
                        "'{name}': checkpoint shape {:?} != artifact spec {:?}",
                        t.shape(), input.shape
                    );
                }
                device.put_tensor(&rt.client, name, t)?;
            }
        }
        let tok = Tokenizer::new();
        let stop_id = tok.encode(".")?[0];
        Ok(Engine {
            rt,
            config: config.to_string(),
            device,
            default_sets: Vec::new(),
            default_kind: "eval_int4".to_string(),
            tok,
            max_new_tokens,
            stop_id,
            last_decode_steps: Cell::new(0),
            last_decode_uploads: Cell::new(0),
            resident_bytes: model.resident_bytes() as u64,
            merged_default: true,
            full_forward: Cell::new(false),
            cache_broken: Cell::new(false),
            last_decode_prefills: Cell::new(0),
        })
    }

    /// Device-resident model bytes (weights + group params + norms/embed).
    pub fn resident_weight_bytes(&self) -> u64 {
        self.resident_bytes
    }

    /// True when the merged/no-adapter path serves from packed INT4.
    pub fn is_int4(&self) -> bool {
        self.default_kind == "eval_int4"
    }

    /// True when this engine can run the gathered mixed-tenant artifact:
    /// the frozen f32 base is device-resident (the INT4 path's artifact
    /// has no dense weight inputs) and the manifest was generated with
    /// `eval_gathered`.  Stale artifact directories simply fall back to
    /// uniform sessions.
    pub fn supports_gathered(&self) -> bool {
        !self.is_int4()
            && self
                .rt
                .manifest
                .config(&self.config)
                .map(|c| c.artifacts.contains_key(GATHERED_KIND))
                .unwrap_or(false)
    }

    pub fn max_new_tokens(&self) -> usize {
        self.max_new_tokens
    }

    /// The artifact's fixed batch dimension (upper bound on batch size).
    pub fn artifact_batch(&self) -> Result<usize> {
        Ok(self.rt.model(&self.config)?.batch)
    }

    /// Forwards executed by the most recent generate call on this engine.
    pub fn last_decode_steps(&self) -> usize {
        self.last_decode_steps.get()
    }

    /// Token-batch uploads performed by the most recent generate call.
    /// On the KV-cached path tokens upload only at prefills, so this
    /// equals [`Engine::last_decode_prefills`] there; on the full-forward
    /// path it counts steps where a live slot changed.
    pub fn last_decode_uploads(&self) -> usize {
        self.last_decode_uploads.get()
    }

    /// Prefill forwards executed by the most recent generate call (0 when
    /// the legacy full-forward path ran).
    pub fn last_decode_prefills(&self) -> usize {
        self.last_decode_prefills.get()
    }

    /// Force (`true`) or re-allow (`false`) the legacy full-forward decode
    /// path.  With cache artifacts present the engine defaults to the
    /// prefill/decode split; tests and benches flip this to pin the
    /// reference leg.
    pub fn set_full_forward(&self, on: bool) {
        self.full_forward.set(on);
    }

    /// True when the next decode session for `eval_kind` will run the
    /// KV-cached prefill/decode split (artifacts present, not forced or
    /// broken back to full forwards).
    pub fn kv_cache_active(&self, eval_kind: &str) -> bool {
        self.cache_plan(eval_kind).is_some()
    }

    /// The artifact kinds the KV-cached split for `eval_kind` executes
    /// (prefill, decode, readout), or `None` when it runs full forwards —
    /// what pool workers pre-compile inside the setup window.
    pub fn cache_kinds(&self, eval_kind: &str) -> Option<[&'static str; 3]> {
        self.cache_plan(eval_kind).map(|p| [p.prefill, p.decode, "decode_out"])
    }

    /// Resolve the KV-cached artifact pair for `eval_kind`, or `None` when
    /// the session must run legacy full forwards: the knob forces it, a
    /// probe latched `cache_broken`, the kind has no cached split
    /// (`eval_qa` merges through fake-quant and stays legacy), or the
    /// artifact directory predates the split.
    fn cache_plan(&self, eval_kind: &str) -> Option<CachePlan> {
        if self.full_forward.get() || self.cache_broken.get() {
            return None;
        }
        let (prefill, decode) = match eval_kind {
            "eval" => ("prefill", "decode"),
            GATHERED_KIND => ("prefill_gathered", "decode_gathered"),
            "eval_int4" => ("prefill_int4", "decode_int4"),
            _ => return None,
        };
        let arts = &self.rt.manifest.config(&self.config).ok()?.artifacts;
        [prefill, decode, "decode_out"]
            .iter()
            .all(|k| arts.contains_key(*k))
            .then_some(CachePlan { prefill, decode })
    }

    /// Greedy-decode a batch of prompts with the engine's default adapter
    /// state (merged fast path when built with `adapters: None`).
    pub fn generate_batch<S: AsRef<str>>(&self, prompts: &[S]) -> Result<Vec<String>> {
        let sets: Vec<&ParamSet> = self.default_sets.iter().collect();
        self.generate_batch_cached(None, &sets, &self.default_kind, prompts)
    }

    /// Greedy-decode a batch of prompts against explicit per-forward host
    /// inputs (one tenant's adapter + rank params) — the fallback for
    /// unregistered one-off calls: the adapter host set is re-uploaded
    /// every decode step.  All prompts in the batch share `host_sets`.
    pub fn generate_batch_for<S: AsRef<str>>(
        &self,
        host_sets: &[&ParamSet],
        eval_kind: &str,
        prompts: &[S],
    ) -> Result<Vec<String>> {
        self.generate_batch_cached(None, host_sets, eval_kind, prompts)
    }

    /// Allocate a fresh decode session sized to the artifact batch.  All
    /// slots start free; admit prompts with [`Engine::admit`] and run
    /// forwards with [`Engine::decode_step`].
    pub fn begin_decode(&self) -> Result<DecodeSession> {
        let hyper = self.rt.model(&self.config)?;
        let (b, seq, v) = (hyper.batch, hyper.seq_len, hyper.vocab);
        Ok(DecodeSession {
            capacity: b,
            seq,
            vocab: v,
            flat: vec![0i32; b * seq],
            len: vec![0; b],
            limit: vec![0; b],
            min_len: vec![0; b],
            occupied: vec![false; b],
            answer: vec![String::new(); b],
            step_store: DeviceStore::new(),
            dirty: false,
            // all-zero = every row on the identity slot; starts dirty so a
            // gathered session's first forward has the vector resident
            slot_idx: vec![0i32; b],
            idx_dirty: true,
            cache: DeviceStore::new(),
            pending: vec![false; b],
            primed: false,
            kv_elems: kv_state_elems(hyper),
            steps: 0,
            uploads: 0,
            idx_uploads: 0,
            prefills: 0,
            slot_steps: 0,
        })
    }

    /// Admit one prompt into the first free slot of `s`; returns the slot
    /// index.  `max_new` caps this request's generated tokens (clamped to
    /// the engine bound, `None` = engine default); `min_new` masks the
    /// stop token out of the argmax until that many tokens exist.  The
    /// slot's row is rewritten from scratch (BOS + prompt, zero tail), so
    /// a retired occupant leaves no residue.
    pub fn admit(
        &self,
        s: &mut DecodeSession,
        prompt: &str,
        max_new: Option<usize>,
        min_new: usize,
    ) -> Result<usize> {
        let cap = max_new.unwrap_or(self.max_new_tokens).min(self.max_new_tokens);
        if cap == 0 {
            bail!("per-request max_new_tokens must be >= 1");
        }
        let slot = s
            .occupied
            .iter()
            .position(|&o| !o)
            .ok_or_else(|| anyhow!("no free decode slot (capacity {})", s.capacity))?;
        let ids = self.tok.encode(prompt)?;
        if ids.len() + 1 + cap > s.seq {
            bail!("prompt too long for seq {}", s.seq);
        }
        let row = &mut s.flat[slot * s.seq..(slot + 1) * s.seq];
        row.fill(0);
        row[0] = Tokenizer::BOS;
        for (i, &id) in ids.iter().enumerate() {
            row[i + 1] = id;
        }
        let start = ids.len() + 1;
        s.len[slot] = start;
        s.limit[slot] = start + cap;
        s.min_len[slot] = start + min_new.min(cap);
        s.answer[slot].clear();
        s.occupied[slot] = true;
        s.dirty = true;
        // the slot's cache page (if any) describes the retired occupant;
        // the next forward must be a prefill to rebuild it from the row
        s.pending[slot] = true;
        // a recycled slot may still carry a previous tenant's bank index;
        // plain admission means "the session's shared adapter state" =
        // identity slot 0 on the gathered path
        if s.slot_idx[slot] != 0 {
            s.slot_idx[slot] = 0;
            s.idx_dirty = true;
        }
        Ok(slot)
    }

    /// [`Engine::admit`] plus a gathered-bank slot index: the row's
    /// forward gathers bank slice `bank_slot` (0 = identity adapter, the
    /// merged path).  Only uploads the index vector when the slot's index
    /// actually changed — same-tenant reuse of a slot costs nothing.
    pub fn admit_indexed(
        &self,
        s: &mut DecodeSession,
        prompt: &str,
        max_new: Option<usize>,
        min_new: usize,
        bank_slot: i32,
    ) -> Result<usize> {
        let slot = self.admit(s, prompt, max_new, min_new)?;
        if s.slot_idx[slot] != bank_slot {
            s.slot_idx[slot] = bank_slot;
            s.idx_dirty = true;
        }
        Ok(slot)
    }

    /// One batched forward over every occupied slot, then append one
    /// greedy token per live row and **retire** each slot whose row
    /// emitted the stop token or hit its cap — returning `(slot, answer)`
    /// for every retirement so the caller can reply and re-fill the slot
    /// before the next forward.
    ///
    /// With cache artifacts present ([`Engine::cache_plan`]) the forward
    /// is the KV-cached split: a *prefill* (full causal forward rebuilding
    /// every row's resident cache page) whenever any slot was admitted
    /// since the last one, else a *decode* that ships only the one-token
    /// frontier and runs single-position attention against the resident
    /// cache — O(1) host traffic and O(1) fresh compute per token
    /// regardless of row length.  Otherwise the legacy full forward runs:
    /// token batch uploaded iff a live slot changed, logits read at each
    /// row's last filled position.  Both paths compute the identical
    /// masked softmax-free argmax, so answers are byte-identical by
    /// construction (asserted in `tests/serve_kv_cache.rs`).
    ///
    /// A retiring row's stop token is *not* written back into the token
    /// buffer and does not mark it dirty: retired rows never feed another
    /// forward, so writing them would only force spurious token-batch
    /// re-uploads on steps where nothing live changed.
    ///
    /// With `tenant_device` (a registered tenant's cached buffer set)
    /// every adapter input resolves to a borrowed device handle; without
    /// it, `host_sets` are re-uploaded per forward (the fallback path).
    /// Device-store precedence mirrors the host path exactly, so cached
    /// and host answers are byte-identical by construction.
    ///
    /// Failure contract: a failed *prefill* surfaces as [`PrefillError`]
    /// after releasing exactly the rows it was admitting (in-flight rows
    /// keep their resident pages — the functional cache update never
    /// happened); any other failure is a plain error and the step is
    /// retry-safe — uploads re-run off their dirty flags, a cached decode
    /// rewrites the same K/V it wrote last time, and rows only advance on
    /// success.
    pub fn decode_step(
        &self,
        s: &mut DecodeSession,
        tenant_device: Option<&DeviceStore>,
        host_sets: &[&ParamSet],
        eval_kind: &str,
    ) -> Result<Vec<(usize, String)>> {
        let active = s.active_slots();
        if active == 0 {
            bail!("decode_step on a session with no occupied slots");
        }
        let cached = match self.cache_plan(eval_kind) {
            Some(plan) => self.cached_forward(s, tenant_device, host_sets, &plan)?,
            None => None,
        };
        let logits = match cached {
            Some(t) => StepLogits::Frontier(t),
            // no plan, or a probe just latched `cache_broken`: the legacy
            // forward is always correct here — the dirty flags guarantee
            // the token buffer re-uploads whatever the cache path skipped
            None => StepLogits::Full(self.full_forward(s, tenant_device, host_sets, eval_kind)?),
        };
        s.steps += 1;
        s.slot_steps += active;
        let (seq, v) = (s.seq, s.vocab);
        let stop = self.stop_id as usize;
        let mut retired = Vec::new();
        for slot in 0..s.capacity {
            if !s.occupied[slot] {
                continue;
            }
            let pos = s.len[slot] - 1; // logits at last filled position
            let row = logits.row(slot, pos, seq, v);
            // greedy argmax; the stop token is masked out while the slot
            // is under its min_new floor
            let mask_stop = s.len[slot] < s.min_len[slot];
            let mut best = if mask_stop && stop == 0 { 1 } else { 0 };
            for t in (best + 1)..v {
                if mask_stop && t == stop {
                    continue;
                }
                if row[t] > row[best] {
                    best = t;
                }
            }
            let hit_stop = best == stop;
            if !hit_stop {
                s.answer[slot].push(self.tok.decode_one(best as i32)?);
            }
            if hit_stop || s.len[slot] + 1 >= s.limit[slot] || s.len[slot] >= seq - 1 {
                // retire: free the slot, don't touch flat / dirty.  The
                // slot's cache page is implicitly invalidated: re-filling
                // sets `pending`, and the prefill that follows rebuilds it
                // from the new occupant's row
                s.occupied[slot] = false;
                s.len[slot] = 0;
                retired.push((slot, std::mem::take(&mut s.answer[slot])));
            } else {
                s.flat[slot * seq + s.len[slot]] = best as i32;
                s.len[slot] += 1;
                s.dirty = true;
            }
        }
        Ok(retired)
    }

    /// The legacy one-shot forward: whole `(capacity, seq)` token buffer
    /// through the eval artifact, full `(capacity, seq, vocab)` logits
    /// back.  Pre-split reference path, still the only path for artifact
    /// kinds without a cached pair.
    fn full_forward(
        &self,
        s: &mut DecodeSession,
        tenant_device: Option<&DeviceStore>,
        host_sets: &[&ParamSet],
        eval_kind: &str,
    ) -> Result<crate::tensor::Tensor> {
        let exe = self.rt.executable(&self.config, eval_kind)?;
        if s.dirty {
            s.step_store
                .put_i32(&self.rt.client, "tokens", &[s.capacity, s.seq], &s.flat)?;
            s.dirty = false;
            s.uploads += 1;
        }
        // the gathered artifact also takes the per-row bank-slot vector;
        // like the token batch it is re-uploaded only when an admission
        // changed it (steady-state same-slot refills ship nothing extra)
        if s.idx_dirty && exe.spec.inputs.iter().any(|i| i.name == "adapter_idx") {
            s.step_store.put_i32(&self.rt.client, "adapter_idx", &[s.capacity], &s.slot_idx)?;
            s.idx_dirty = false;
            s.idx_uploads += 1;
        }
        let mut devices: Vec<&DeviceStore> = Vec::with_capacity(3);
        devices.push(&s.step_store);
        devices.push(&self.device);
        if let Some(d) = tenant_device {
            devices.push(d);
        }
        let args = build_args(&exe.spec, &devices, host_sets, None, &[])?;
        let mut outs = exe.run_mixed(&self.rt.client, &args)?;
        Ok(outs.swap_remove(0))
    }

    /// One KV-cached forward: run `plan.prefill` when any slot was
    /// admitted since the last prefill (rebuilding every occupied row's
    /// cache page from the token buffer), else `plan.decode` (frontier
    /// token + position vectors only, single-position attention against
    /// the resident packed state).  Either way the artifact's array-root
    /// output buffer goes straight back into the session's cache store —
    /// it never touches the host — and `decode_out` reads just the
    /// `(capacity, vocab)` frontier logits pane out of it.
    ///
    /// Returns `Ok(None)` after latching `cache_broken` when a probe
    /// fails (artifact missing/uncompilable, tuple-shaped root, state
    /// shape mismatch): the caller falls back to the legacy forward *in
    /// the same step*, so a stale artifact directory degrades to the
    /// pre-split behaviour instead of failing requests.
    fn cached_forward(
        &self,
        s: &mut DecodeSession,
        tenant_device: Option<&DeviceStore>,
        host_sets: &[&ParamSet],
        plan: &CachePlan,
    ) -> Result<Option<crate::tensor::Tensor>> {
        let needs_prefill = !s.primed || s.pending.iter().any(|&p| p);
        let kind = if needs_prefill { plan.prefill } else { plan.decode };
        let (Ok(exe), Ok(exe_out)) = (
            self.rt.executable(&self.config, kind),
            self.rt.executable(&self.config, "decode_out"),
        ) else {
            self.cache_broken.set(true);
            return Ok(None);
        };
        if needs_prefill {
            if let Err(e) = self.run_prefill(s, tenant_device, host_sets, &exe) {
                if self.cache_broken.get() {
                    return Ok(None); // probe failed: fall back, fail nothing
                }
                // release exactly the rows this prefill was admitting;
                // in-flight rows keep decoding off their resident pages
                let mut failed = Vec::new();
                for slot in 0..s.capacity {
                    if s.pending[slot] && s.occupied[slot] {
                        s.release(slot);
                        failed.push(slot);
                    }
                }
                return Err(anyhow::Error::new(PrefillError {
                    slots: failed,
                    message: format!("{e:#}"),
                }));
            }
        } else if let Err(e) = self.run_cached_decode(s, tenant_device, host_sets, &exe) {
            if self.cache_broken.get() {
                return Ok(None);
            }
            return Err(e);
        }
        // frontier logits live in the packed state; decode_out slices them
        let args = build_args(&exe_out.spec, &[&s.cache], &[], None, &[])?;
        let outs = exe_out.run_device(&self.rt.client, &args)?;
        let buf = outs.first().context("decode_out produced no output buffer")?;
        match crate::runtime::buffer_array_dims(buf) {
            Ok(dims) if dims == [s.capacity, s.vocab] => {}
            // a mis-shaped readout means stale decode_out artifacts: latch
            // broken and recompute this step's logits the legacy way (the
            // token buffer, not the cache, is the source of truth)
            _ => {
                self.cache_broken.set(true);
                return Ok(None);
            }
        }
        let logits = crate::runtime::buffer_to_tensor(buf, &[s.capacity, s.vocab])?;
        Ok(Some(logits))
    }

    /// The prefill leg of [`Engine::cached_forward`]: upload the token
    /// buffer (iff dirty — an admission always dirtied it) and per-row
    /// lengths, run the full causal forward, and install the fresh packed
    /// state as the session's cache page set.  Latches `cache_broken`
    /// (and errors) when the output shape probe fails.
    fn run_prefill(
        &self,
        s: &mut DecodeSession,
        tenant_device: Option<&DeviceStore>,
        host_sets: &[&ParamSet],
        exe: &crate::runtime::Executable,
    ) -> Result<()> {
        crate::faults::check_thread(crate::faults::SITE_PREFILL)?;
        if s.dirty {
            s.step_store
                .put_i32(&self.rt.client, "tokens", &[s.capacity, s.seq], &s.flat)?;
            s.dirty = false;
            s.uploads += 1;
        }
        // free rows carry len 0; the artifact clamps their frontier gather
        // and their pages are never read (every admission re-prefills)
        let lens: Vec<i32> = s.len.iter().map(|&l| l as i32).collect();
        s.step_store.put_i32(&self.rt.client, "seq_lens", &[s.capacity], &lens)?;
        if s.idx_dirty && exe.spec.inputs.iter().any(|i| i.name == "adapter_idx") {
            s.step_store.put_i32(&self.rt.client, "adapter_idx", &[s.capacity], &s.slot_idx)?;
            s.idx_dirty = false;
            s.idx_uploads += 1;
        }
        let buf = {
            let mut devices: Vec<&DeviceStore> = vec![&s.step_store, &self.device];
            if let Some(d) = tenant_device {
                devices.push(d);
            }
            let args = build_args(&exe.spec, &devices, host_sets, None, &[])?;
            let mut outs = exe.run_device(&self.rt.client, &args)?;
            if outs.is_empty() {
                bail!("prefill produced no output buffer");
            }
            outs.swap_remove(0)
        };
        self.probe_state(s, &buf)?;
        s.cache.put("kv_state", buf);
        s.pending.iter_mut().for_each(|p| *p = false);
        s.primed = true;
        s.prefills += 1;
        Ok(())
    }

    /// The steady-state leg: ship the `(capacity,)` frontier-token and
    /// position vectors (8 bytes/slot — the *only* host→device traffic),
    /// run single-position attention against the resident state, and swap
    /// the functionally-updated state back in.  Retry-safe: re-running
    /// rewrites the same K/V at the same positions and reproduces the
    /// same frontier logits.
    fn run_cached_decode(
        &self,
        s: &mut DecodeSession,
        tenant_device: Option<&DeviceStore>,
        host_sets: &[&ParamSet],
        exe: &crate::runtime::Executable,
    ) -> Result<()> {
        crate::faults::check_thread(crate::faults::SITE_CACHE_UPLOAD)?;
        let mut frontier = vec![0i32; s.capacity];
        let mut positions = vec![0i32; s.capacity];
        for slot in 0..s.capacity {
            // free rows pin position 0 / token 0: rows are computed
            // independently, so their garbage output is never read
            if s.occupied[slot] {
                frontier[slot] = s.flat[slot * s.seq + s.len[slot] - 1];
                positions[slot] = (s.len[slot] - 1) as i32;
            }
        }
        s.step_store.put_i32(&self.rt.client, "frontier", &[s.capacity], &frontier)?;
        s.step_store.put_i32(&self.rt.client, "positions", &[s.capacity], &positions)?;
        if s.idx_dirty && exe.spec.inputs.iter().any(|i| i.name == "adapter_idx") {
            s.step_store.put_i32(&self.rt.client, "adapter_idx", &[s.capacity], &s.slot_idx)?;
            s.idx_dirty = false;
            s.idx_uploads += 1;
        }
        let buf = {
            let mut devices: Vec<&DeviceStore> = vec![&s.cache, &s.step_store, &self.device];
            if let Some(d) = tenant_device {
                devices.push(d);
            }
            let args = build_args(&exe.spec, &devices, host_sets, None, &[])?;
            let mut outs = exe.run_device(&self.rt.client, &args)?;
            if outs.is_empty() {
                bail!("decode produced no output buffer");
            }
            outs.swap_remove(0)
        };
        self.probe_state(s, &buf)?;
        s.cache.put("kv_state", buf);
        Ok(())
    }

    /// Validate a cache-artifact output against the packed-state contract
    /// (`(capacity, kv_elems)` f32 array root); on mismatch latch
    /// `cache_broken` so the session — and every later one — falls back
    /// to full forwards instead of decoding against garbage.
    fn probe_state(&self, s: &DecodeSession, buf: &xla::PjRtBuffer) -> Result<()> {
        let dims = match crate::runtime::buffer_array_dims(buf) {
            Ok(d) => d,
            Err(e) => {
                self.cache_broken.set(true);
                return Err(e);
            }
        };
        if dims != [s.capacity, s.kv_elems] {
            self.cache_broken.set(true);
            bail!(
                "kv_state shape {:?} != expected [{}, {}] (stale artifacts?)",
                dims, s.capacity, s.kv_elems
            );
        }
        Ok(())
    }

    /// Run-to-completion decode of one batch: admit every prompt up front,
    /// never re-fill, stop when the last row retires.  A thin wrapper over
    /// the slot-based session, kept as the reference path (and for callers
    /// without a request queue).
    pub fn generate_batch_cached<S: AsRef<str>>(
        &self,
        tenant_device: Option<&DeviceStore>,
        host_sets: &[&ParamSet],
        eval_kind: &str,
        prompts: &[S],
    ) -> Result<Vec<String>> {
        let mut s = self.begin_decode()?;
        if prompts.is_empty() || prompts.len() > s.capacity() {
            bail!("batch of {} prompts (max {})", prompts.len(), s.capacity());
        }
        let mut answers: Vec<String> = vec![String::new(); prompts.len()];
        for p in prompts {
            // slots fill in admission order, so slot index == prompt index
            self.admit(&mut s, p.as_ref(), None, 0)?;
        }
        while s.active_slots() > 0 {
            for (slot, ans) in self.decode_step(&mut s, tenant_device, host_sets, eval_kind)? {
                answers[slot] = ans;
            }
        }
        self.last_decode_steps.set(s.steps());
        self.last_decode_uploads.set(s.uploads());
        self.last_decode_prefills.set(s.prefills());
        Ok(answers)
    }
}

/// Logits produced by one decode step, abstracting over the two forward
/// paths' output layouts so the argmax/retire loop is shared verbatim.
enum StepLogits {
    /// `(capacity, seq, vocab)` from the legacy full forward
    Full(crate::tensor::Tensor),
    /// `(capacity, vocab)` frontier pane from the KV-cached `decode_out`
    Frontier(crate::tensor::Tensor),
}

impl StepLogits {
    /// `slot`'s logits at its last filled position (`pos` = len-1; the
    /// cached pane already *is* that position, by construction).
    fn row(&self, slot: usize, pos: usize, seq: usize, v: usize) -> &[f32] {
        match self {
            StepLogits::Full(t) => &t.data()[slot * seq * v + pos * v..][..v],
            StepLogits::Frontier(t) => &t.data()[slot * v..][..v],
        }
    }
}

/// Marker error for a failed prefill forward: the engine already released
/// exactly the rows that prefill was admitting (their requests must be
/// requeued or failed by the driver), while every in-flight row's
/// resident cache page is untouched — the functional state update never
/// happened — so the session keeps decoding.  Surfaced to clients (only
/// once a request exhausts its re-admission budget) as
/// [`ServeError::EngineFailure`]; this type never crosses the serve API.
#[derive(Debug)]
pub(crate) struct PrefillError {
    /// already-released session slots whose admissions the prefill was
    /// absorbing
    pub(crate) slots: Vec<usize>,
    pub(crate) message: String,
}

impl std::fmt::Display for PrefillError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "prefill failed for {} admitted row(s): {}",
            self.slots.len(),
            self.message
        )
    }
}

impl std::error::Error for PrefillError {}

/// Persistent slot-based decode state for one same-tenant continuous
/// batch: a flattened `(batch, seq)` token buffer plus per-slot
/// `len`/`limit`/`answer` bookkeeping, the device-side token buffer behind
/// a dirty flag, and occupancy counters.  Created by
/// [`Engine::begin_decode`]; slots cycle admit → decode → retire →
/// re-fill without ever restarting the batch.
pub struct DecodeSession {
    capacity: usize,
    seq: usize,
    vocab: usize,
    /// flattened `(capacity, seq)` token rows, mutated in place
    flat: Vec<i32>,
    /// per-slot filled row length (prompt + generated); 0 while free
    len: Vec<usize>,
    /// per-slot row length at which the slot is force-retired
    limit: Vec<usize>,
    /// per-slot row length below which the stop token is masked out
    min_len: Vec<usize>,
    occupied: Vec<bool>,
    answer: Vec<String>,
    step_store: DeviceStore,
    dirty: bool,
    /// per-slot gathered-bank index (`(capacity,)` i32; 0 = identity);
    /// ignored by uniform artifacts, gathered forwards upload it behind
    /// its own dirty flag
    slot_idx: Vec<i32>,
    idx_dirty: bool,
    /// device-resident packed K/V + frontier-logits state (`kv_state`,
    /// `(capacity, kv_elems)` f32), owned by the session: dropping the
    /// session frees every cache page at once
    cache: DeviceStore,
    /// per-slot "admitted since the last successful prefill" flag — any
    /// set bit makes the next forward a prefill, which rebuilds every
    /// occupied row's page from the token buffer (page invalidation on
    /// retire/re-fill is exactly this bit)
    pending: Vec<bool>,
    /// true once a prefill has populated `cache` this session
    primed: bool,
    /// packed-state row length in f32 elements (from the hyperparams; the
    /// engine probes artifact outputs against it)
    kv_elems: usize,
    steps: usize,
    uploads: usize,
    /// `adapter_idx` uploads so far (gathered sessions only; `<= steps`)
    idx_uploads: usize,
    /// prefill forwards so far (`<= steps`; 0 on the full-forward path).
    /// Token uploads only happen at prefills on the cached path, so
    /// `uploads == prefills` there
    prefills: usize,
    /// sum over forwards of occupied slots — the occupancy numerator (and
    /// exactly the number of generated tokens: one per live slot per step)
    slot_steps: usize,
}

impl DecodeSession {
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn active_slots(&self) -> usize {
        self.occupied.iter().filter(|&&o| o).count()
    }

    pub fn free_slots(&self) -> usize {
        self.capacity - self.active_slots()
    }

    /// Forwards executed so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Token-batch uploads so far (`<= steps`).
    pub fn uploads(&self) -> usize {
        self.uploads
    }

    /// `adapter_idx` vector uploads so far (0 on uniform sessions).
    pub fn idx_uploads(&self) -> usize {
        self.idx_uploads
    }

    /// Prefill forwards so far (0 on the full-forward path); cached
    /// decode steps are `steps() - prefills()`.
    pub fn prefills(&self) -> usize {
        self.prefills
    }

    /// Bytes of packed K/V + frontier state resident on the device for
    /// this session (0 until the first prefill, then the full page set —
    /// pages are slot-indexed panes of one `(capacity, kv_elems)` f32
    /// buffer, so residency is all-or-nothing by construction).
    pub fn cache_resident_bytes(&self) -> u64 {
        if self.primed {
            (self.capacity * self.kv_elems * 4) as u64
        } else {
            0
        }
    }

    /// Occupied-slot-forwards so far == generated tokens so far.
    pub fn slot_steps(&self) -> usize {
        self.slot_steps
    }

    /// Free `slot` without retiring it through a forward — the
    /// cancellation path (client went away mid-decode).  Like a retire,
    /// the token row is left in place and the dirty flag untouched:
    /// released rows never feed another forward.
    pub fn release(&mut self, slot: usize) {
        self.occupied[slot] = false;
        self.len[slot] = 0;
        self.answer[slot].clear();
        // a released row must not force (or survive into) a prefill
        self.pending[slot] = false;
    }

    /// Mean fraction of slots doing useful work per forward.
    pub fn occupancy(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.slot_steps as f64 / (self.steps * self.capacity) as f64
        }
    }
}

/// Serving outcome for one tenant (or the whole run).
#[derive(Clone, Debug)]
pub struct ServeStats {
    pub served: usize,
    pub errors: usize,
    pub wall_secs: f64,
    pub throughput: f64,
    /// end-to-end latency (enqueue → full answer)
    pub latency_ms: Option<Summary>,
    /// time to first token (enqueue → first forward that computed this
    /// request's row)
    pub ttft_ms: Option<Summary>,
    /// queue wait (enqueue → admission into a decode slot)
    pub queue_ms: Option<Summary>,
    /// bytes of model state the serving engine keeps device-resident
    /// (packed u8 + group params on the INT4 path, dense f32 otherwise);
    /// set on the run-level `total` stats, `None` on per-tenant rows
    pub resident_weight_bytes: Option<u64>,
}

/// Per-run serving report: totals, per-tenant breakdown, the scheduler's
/// queue-depth / batch-fill / admission counters, and decode-loop slot
/// occupancy.
#[derive(Debug)]
pub struct MultiServeStats {
    pub total: ServeStats,
    /// keyed by adapter id (the merged path reports as [`MERGED_ID`])
    pub per_tenant: Vec<(String, ServeStats)>,
    pub scheduler: SchedulerMetrics,
    /// decode forwards executed across all sessions (all workers)
    pub decode_steps: usize,
    /// mean fraction of decode slots doing useful work per forward
    pub occupancy: f64,
    /// tokens generated across all sessions (== occupied-slot-forwards);
    /// divide by `total.wall_secs` for aggregate tokens/s
    pub generated_tokens: usize,
}

impl MultiServeStats {
    pub fn tenant(&self, id: &str) -> Option<&ServeStats> {
        self.per_tenant.iter().find(|(k, _)| k == id).map(|(_, s)| s)
    }

    pub fn render(&self) -> String {
        let mut t = Table::new(
            "Multi-tenant serving",
            &[
                "tenant", "served", "errors", "req/s", "mean ms", "p50 ms", "p95 ms", "p99 ms",
                "ttft ms", "queue ms",
            ],
        );
        let summ = |o: &Option<Summary>, f: fn(&Summary) -> f64| match o {
            Some(l) => format!("{:.1}", f(l)),
            None => "-".to_string(),
        };
        let row = |name: &str, s: &ServeStats| {
            vec![
                name.to_string(),
                s.served.to_string(),
                s.errors.to_string(),
                format!("{:.1}", s.throughput),
                summ(&s.latency_ms, |l| l.mean),
                summ(&s.latency_ms, |l| l.p50),
                summ(&s.latency_ms, |l| l.p95),
                summ(&s.latency_ms, |l| l.p99),
                summ(&s.ttft_ms, |l| l.mean),
                summ(&s.queue_ms, |l| l.mean),
            ]
        };
        for (id, s) in &self.per_tenant {
            t.row(row(id.as_str(), s));
        }
        t.row(row("TOTAL", &self.total));
        let mut out = t.render();
        let _ = writeln!(
            out,
            "scheduler: {} batches ({} mixed), avg fill {:.2}, {} admitted mid-batch, \
{} aged, max queue depth {}",
            self.scheduler.batches,
            self.scheduler.mixed_batches,
            self.scheduler.avg_fill(),
            self.scheduler.admitted,
            self.scheduler.aged_batches,
            self.scheduler.max_queue_depth
        );
        let _ = writeln!(
            out,
            "decode: {} forwards, slot occupancy {:.2}, {} tokens ({:.1} tok/s)",
            self.decode_steps,
            self.occupancy,
            self.generated_tokens,
            self.generated_tokens as f64 / self.total.wall_secs.max(1e-9)
        );
        if let Some(b) = self.total.resident_weight_bytes {
            let _ = writeln!(
                out,
                "resident model weights: {:.1} KB per engine replica",
                b as f64 / 1e3
            );
        }
        out
    }
}

/// Decode-step latency buckets (ms) for `serve_decode_step_ms`.
const DECODE_STEP_MS_BOUNDS: &[f64] =
    &[0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0, 500.0, 1000.0];

/// Per-step upload-bytes buckets for `runtime_upload_step_bytes` (0 = the
/// device-resident steady state where nothing but tokens moves).
const UPLOAD_STEP_BYTES_BOUNDS: &[f64] =
    &[0.0, 4096.0, 65536.0, 1048576.0, 16777216.0, 268435456.0];

/// One serve run's observability context: a fresh metrics [`Registry`]
/// plus (optionally) a [`TraceLog`] of per-request slot-lifecycle spans.
///
/// Cloned into the dispatcher and every worker; all clones share the same
/// registry, so the end-of-run stats ([`finish_multi_obs`]), the live
/// exposition writer, and `metrics()`-style accessors read the *same*
/// instruments.  A `disabled()` context still hands out recorders, but
/// every record call early-returns — the uninstrumented baseline for the
/// overhead bench.
#[derive(Clone)]
pub struct ServeObs {
    registry: Arc<Registry>,
    trace: Option<Arc<TraceLog>>,
    enabled: bool,
    /// monotonically numbers dispatched batches across workers so trace
    /// spans can attribute requests to (worker, batch) pairs
    batch_seq: Arc<AtomicU64>,
}

impl Default for ServeObs {
    fn default() -> Self {
        ServeObs::new()
    }
}

impl ServeObs {
    /// Metrics only — counters/gauges/histograms, no per-request trace.
    pub fn new() -> ServeObs {
        ServeObs {
            registry: Arc::new(Registry::new()),
            trace: None,
            enabled: true,
            batch_seq: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Metrics plus a JSONL trace of request lifecycle events.
    pub fn with_trace() -> ServeObs {
        ServeObs { trace: Some(Arc::new(TraceLog::new())), ..ServeObs::new() }
    }

    /// No-op context: every record call early-returns.  The registry is a
    /// throwaway so the stats assembly still works (and reports zeros).
    pub fn disabled() -> ServeObs {
        ServeObs { enabled: false, ..ServeObs::new() }
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    pub fn trace(&self) -> Option<&Arc<TraceLog>> {
        self.trace.as_ref()
    }

    fn tenant_key(id: &Option<String>) -> &str {
        id.as_deref().unwrap_or(MERGED_ID)
    }

    /// A request entered the serving endpoint (dispatcher side).
    pub(crate) fn enqueue(&self, req: &Request) {
        if !self.enabled {
            return;
        }
        if let Some(t) = &self.trace {
            t.event(
                "enqueue",
                vec![
                    ("req", Json::Num(req.id as f64)),
                    ("tenant", Json::Str(Self::tenant_key(&req.adapter_id).to_string())),
                ],
            );
        }
    }

    /// A scheduler batch was handed to `worker` (stolen = pulled from
    /// another shard's queue).  One batch id covers all its requests;
    /// each span carries its own request's tenant, since mixed batches
    /// routinely span tenants.
    pub(crate) fn dispatch(&self, worker: usize, reqs: &[Request], stolen: bool) {
        if !self.enabled {
            return;
        }
        let batch = self.batch_seq.fetch_add(1, Ordering::Relaxed);
        if let Some(t) = &self.trace {
            for req in reqs {
                t.event(
                    "dispatch",
                    vec![
                        ("req", Json::Num(req.id as f64)),
                        ("tenant", Json::Str(Self::tenant_key(&req.adapter_id).to_string())),
                        ("worker", Json::Num(worker as f64)),
                        ("batch", Json::Num(batch as f64)),
                        ("stolen", Json::Bool(stolen)),
                    ],
                );
            }
        }
    }

    /// Per-(tenant, worker) instrument bundle for one decode session.
    pub(crate) fn recorder(&self, id: &Option<String>, worker: usize) -> SessionRecorder {
        let tenant = Self::tenant_key(id).to_string();
        let w = worker.to_string();
        let tw = [("tenant", tenant.as_str()), ("worker", w.as_str())];
        let tl = [("tenant", tenant.as_str())];
        let wl = [("worker", w.as_str())];
        let reg = &self.registry;
        SessionRecorder {
            enabled: self.enabled,
            trace: self.trace.clone(),
            worker,
            requests: reg.counter("serve_requests_total", &tw),
            errors: reg.counter("serve_errors_total", &tw),
            tokens: reg.counter("serve_tokens_total", &tw),
            latency: reg.series("serve_latency_ms", &tl),
            ttft: reg.series("serve_ttft_ms", &tl),
            queue: reg.series("serve_queue_ms", &tl),
            decode_steps: reg.counter("serve_decode_steps_total", &wl),
            decode_step_ms: reg.histogram("serve_decode_step_ms", &wl, DECODE_STEP_MS_BOUNDS),
            prefills: reg.counter("serve_prefills_total", &wl),
            prefill_ms: reg.histogram("serve_prefill_ms", &wl, DECODE_STEP_MS_BOUNDS),
            cache_bytes: reg.gauge("serve_cache_resident_bytes", &wl),
            uploads: reg.counter("runtime_uploads_total", &wl),
            upload_bytes: reg.counter("runtime_upload_bytes_total", &wl),
            upload_step_bytes: reg.histogram(
                "runtime_upload_step_bytes",
                &wl,
                UPLOAD_STEP_BYTES_BOUNDS,
            ),
            occupied: reg.gauge("serve_slots_occupied", &wl),
            retries: reg.counter("serve_retries_total", &wl),
            cancelled: reg.counter("serve_cancelled_total", &tw),
            tenant: tenant.clone(),
        }
    }

    /// A decode session on `worker` panicked (caught at the session
    /// boundary; the worker itself keeps serving).
    pub(crate) fn worker_crash(&self, worker: usize) {
        if !self.enabled {
            return;
        }
        let w = worker.to_string();
        self.registry.counter("serve_worker_crashes_total", &[("worker", w.as_str())]).inc();
        if let Some(t) = &self.trace {
            t.event("worker_crash", vec![("worker", Json::Num(worker as f64))]);
        }
    }

    /// `survivors` requests from a failed / crashed session were
    /// re-admitted to the queue for a fresh session.
    pub(crate) fn session_rebuilt(&self, worker: usize, survivors: usize) {
        if !self.enabled {
            return;
        }
        let w = worker.to_string();
        self.registry.counter("serve_sessions_rebuilt_total", &[("worker", w.as_str())]).inc();
        if let Some(t) = &self.trace {
            t.event(
                "session_rebuilt",
                vec![
                    ("worker", Json::Num(worker as f64)),
                    ("survivors", Json::Num(survivors as f64)),
                ],
            );
        }
    }

    /// Static per-worker levels, set once after engine setup.
    pub(crate) fn set_worker_gauges(&self, worker: usize, capacity: usize, resident_bytes: u64) {
        if !self.enabled {
            return;
        }
        let w = worker.to_string();
        let wl = [("worker", w.as_str())];
        self.registry.gauge("serve_slots_capacity", &wl).set(capacity as f64);
        self.registry.gauge("serve_resident_weight_bytes", &wl).set(resident_bytes as f64);
    }

    /// A pool worker's engine replica failed to set up.
    pub(crate) fn setup_failure(&self, worker: usize) {
        if !self.enabled {
            return;
        }
        let w = worker.to_string();
        self.registry.counter("pool_setup_failures_total", &[("worker", w.as_str())]).inc();
    }

    /// A worker started a decode session (stolen = batch came from
    /// another shard's queue).
    pub(crate) fn session_start(&self, worker: usize, stolen: bool) {
        if !self.enabled {
            return;
        }
        let w = worker.to_string();
        let wl = [("worker", w.as_str())];
        self.registry.counter("serve_sessions_total", &wl).inc();
        if stolen {
            self.registry.counter("serve_stolen_sessions_total", &wl).inc();
        }
    }
}

/// The decode loop's hot-path handle: pre-resolved `Arc`s to every
/// instrument one (tenant, worker) session touches, so recording is a few
/// relaxed atomic ops with no registry lookups per forward.
pub(crate) struct SessionRecorder {
    enabled: bool,
    trace: Option<Arc<TraceLog>>,
    tenant: String,
    worker: usize,
    requests: Arc<Counter>,
    errors: Arc<Counter>,
    tokens: Arc<Counter>,
    latency: Arc<Series>,
    ttft: Arc<Series>,
    queue: Arc<Series>,
    decode_steps: Arc<Counter>,
    decode_step_ms: Arc<Histogram>,
    /// prefill forwards (cache-page rebuilds); a strict subset of
    /// `decode_steps`, with their latency broken out in `prefill_ms`
    prefills: Arc<Counter>,
    prefill_ms: Arc<Histogram>,
    /// packed K/V + frontier state resident on this worker's device
    cache_bytes: Arc<Gauge>,
    uploads: Arc<Counter>,
    upload_bytes: Arc<Counter>,
    upload_step_bytes: Arc<Histogram>,
    occupied: Arc<Gauge>,
    /// transient decode-step retries absorbed inside this session
    retries: Arc<Counter>,
    /// requests retired early because their client went away
    cancelled: Arc<Counter>,
}

impl SessionRecorder {
    pub(crate) fn enabled(&self) -> bool {
        self.enabled
    }

    /// Request admitted into a decode slot.
    pub(crate) fn admit(&self, req: &Request, slot: usize, queue_ms: f64) {
        if !self.enabled {
            return;
        }
        self.queue.record(queue_ms);
        if let Some(t) = &self.trace {
            t.event(
                "admit",
                vec![
                    ("req", Json::Num(req.id as f64)),
                    ("tenant", Json::Str(self.tenant.clone())),
                    ("worker", Json::Num(self.worker as f64)),
                    ("slot", Json::Num(slot as f64)),
                    ("queue_ms", Json::Num(queue_ms)),
                ],
            );
        }
    }

    /// Request's slot went through its first forward.
    pub(crate) fn first_token(&self, req: &Request, ttft_ms: f64) {
        if !self.enabled {
            return;
        }
        self.ttft.record(ttft_ms);
        if let Some(t) = &self.trace {
            t.event(
                "first_token",
                vec![("req", Json::Num(req.id as f64)), ("ttft_ms", Json::Num(ttft_ms))],
            );
        }
    }

    /// Request completed; `tokens` = forwards its slot went through.
    pub(crate) fn retire(&self, req: &Request, slot: usize, tokens: usize, latency_ms: f64) {
        if !self.enabled {
            return;
        }
        self.requests.inc();
        self.tokens.add(tokens as u64);
        self.latency.record(latency_ms);
        if let Some(t) = &self.trace {
            t.event(
                "retire",
                vec![
                    ("req", Json::Num(req.id as f64)),
                    ("tenant", Json::Str(self.tenant.clone())),
                    ("worker", Json::Num(self.worker as f64)),
                    ("slot", Json::Num(slot as f64)),
                    ("tokens", Json::Num(tokens as f64)),
                    ("latency_ms", Json::Num(latency_ms)),
                ],
            );
        }
    }

    /// Request failed.  `tokens` counts forwards an in-flight slot already
    /// completed before the failure, so `serve_tokens_total` stays equal
    /// to occupied-slot-forwards even on a poisoned session.
    pub(crate) fn error(&self, req: &Request, tokens: usize, error: &str) {
        if !self.enabled {
            return;
        }
        self.errors.inc();
        if tokens > 0 {
            self.tokens.add(tokens as u64);
        }
        if let Some(t) = &self.trace {
            t.event(
                "error",
                vec![
                    ("req", Json::Num(req.id as f64)),
                    ("tenant", Json::Str(self.tenant.clone())),
                    ("error", Json::Str(error.to_string())),
                    ("tokens", Json::Num(tokens as f64)),
                ],
            );
        }
    }

    /// A decode forward failed transiently and is being retried
    /// (`attempt` = retries consumed so far this session, 1-based).
    pub(crate) fn retry(&self, attempt: usize, error: &str) {
        if !self.enabled {
            return;
        }
        self.retries.inc();
        if let Some(t) = &self.trace {
            t.event(
                "retry",
                vec![
                    ("tenant", Json::Str(self.tenant.clone())),
                    ("worker", Json::Num(self.worker as f64)),
                    ("attempt", Json::Num(attempt as f64)),
                    ("error", Json::Str(error.to_string())),
                ],
            );
        }
    }

    /// Request cancelled (client dropped its handle, or its reply channel
    /// was found closed).  `slot` is the decode slot released, `None` when
    /// the request was still waiting; `tokens` counts forwards the slot
    /// completed before the cancel, so `serve_tokens_total` keeps matching
    /// occupied-slot-forwards.
    pub(crate) fn cancel(&self, req: &Request, slot: Option<usize>, tokens: usize) {
        if !self.enabled {
            return;
        }
        self.cancelled.inc();
        if tokens > 0 {
            self.tokens.add(tokens as u64);
        }
        if let Some(t) = &self.trace {
            t.event(
                "cancel",
                vec![
                    ("req", Json::Num(req.id as f64)),
                    ("tenant", Json::Str(self.tenant.clone())),
                    ("worker", Json::Num(self.worker as f64)),
                    ("slot", slot.map(|s| Json::Num(s as f64)).unwrap_or(Json::Null)),
                    ("tokens", Json::Num(tokens as f64)),
                ],
            );
        }
    }

    /// One decode forward: latency, occupancy level, what the step moved
    /// host→device (token-batch upload flag + byte delta), and the
    /// session's device-resident cache footprint after the step.
    pub(crate) fn step(
        &self,
        step_ms: f64,
        active: usize,
        uploaded: bool,
        upload_bytes: u64,
        cache_bytes: u64,
    ) {
        if !self.enabled {
            return;
        }
        self.decode_steps.inc();
        self.decode_step_ms.observe(step_ms);
        self.occupied.set(active as f64);
        self.cache_bytes.set(cache_bytes as f64);
        self.upload_step_bytes.observe(upload_bytes as f64);
        if uploaded {
            self.uploads.inc();
        }
        if upload_bytes > 0 {
            self.upload_bytes.add(upload_bytes);
        }
    }

    /// The forward just recorded by [`SessionRecorder::step`] was a
    /// prefill: count it and break its latency out of the step histogram.
    pub(crate) fn prefill(&self, step_ms: f64) {
        if !self.enabled {
            return;
        }
        self.prefills.inc();
        self.prefill_ms.observe(step_ms);
    }

    /// `req`'s prompt was built into a cache page by the prefill that
    /// just ran — the trace span between its `admit` and `first_token`.
    pub(crate) fn prefill_span(&self, req: &Request) {
        if !self.enabled {
            return;
        }
        if let Some(t) = &self.trace {
            t.event(
                "prefill",
                vec![
                    ("req", Json::Num(req.id as f64)),
                    ("tenant", Json::Str(self.tenant.clone())),
                    ("worker", Json::Num(self.worker as f64)),
                ],
            );
        }
    }
}

/// Assemble the per-run report from a registry snapshot (shared by the
/// single-worker router and the worker pool).  `ServeStats` rows are pure
/// *views* over the same instruments the live exposition reads — there is
/// no second bookkeeping path to drift from it.
pub(crate) fn finish_multi_obs(
    obs: &ServeObs,
    wall: f64,
    scheduler: SchedulerMetrics,
    capacity: usize,
) -> MultiServeStats {
    let snap = obs.registry().snapshot();
    let served = snap.sum_by("serve_requests_total", "tenant");
    let errors = snap.sum_by("serve_errors_total", "tenant");
    let mut lat = snap.series_by("serve_latency_ms", "tenant");
    let mut ttft = snap.series_by("serve_ttft_ms", "tenant");
    let mut queue = snap.series_by("serve_queue_ms", "tenant");
    let mut tenants: Vec<String> = served.keys().chain(errors.keys()).cloned().collect();
    tenants.sort();
    tenants.dedup();
    let summ = |xs: Vec<f64>| if xs.is_empty() { None } else { Some(summarize(xs)) };
    let mut per_tenant = Vec::new();
    let (mut tot_served, mut tot_errors) = (0usize, 0usize);
    let (mut tot_lat, mut tot_ttft, mut tot_queue) = (Vec::new(), Vec::new(), Vec::new());
    for id in tenants {
        let s = served.get(&id).copied().unwrap_or(0.0) as usize;
        let e = errors.get(&id).copied().unwrap_or(0.0) as usize;
        let l = lat.remove(&id).unwrap_or_default();
        let t = ttft.remove(&id).unwrap_or_default();
        let q = queue.remove(&id).unwrap_or_default();
        tot_served += s;
        tot_errors += e;
        tot_lat.extend_from_slice(&l);
        tot_ttft.extend_from_slice(&t);
        tot_queue.extend_from_slice(&q);
        per_tenant.push((
            id,
            ServeStats {
                served: s,
                errors: e,
                wall_secs: wall,
                throughput: s as f64 / wall.max(1e-9),
                latency_ms: summ(l),
                ttft_ms: summ(t),
                queue_ms: summ(q),
                resident_weight_bytes: None,
            },
        ));
    }
    let decode_steps = snap.sum("serve_decode_steps_total") as usize;
    let generated_tokens = snap.sum("serve_tokens_total") as usize;
    MultiServeStats {
        total: ServeStats {
            served: tot_served,
            errors: tot_errors,
            wall_secs: wall,
            throughput: tot_served as f64 / wall.max(1e-9),
            latency_ms: summ(tot_lat),
            ttft_ms: summ(tot_ttft),
            queue_ms: summ(tot_queue),
            resident_weight_bytes: None,
        },
        per_tenant,
        scheduler,
        decode_steps,
        occupancy: if decode_steps == 0 {
            0.0
        } else {
            generated_tokens as f64 / (decode_steps * capacity.max(1)) as f64
        },
        generated_tokens,
    }
}

/// Fault-handling policy for a decode session, shared by the router and
/// every pool worker: the transient-retry / re-admission budget plus the
/// (normally disabled) fault injector the chaos harness threads through.
#[derive(Clone, Default)]
pub(crate) struct SessionPolicy {
    /// Bounds both the in-session decode-step retries and each request's
    /// re-admission count after persistent failures (one knob:
    /// `serve --max-retries`, [`SchedulerOpts::max_retries`]).
    pub(crate) max_retries: usize,
    pub(crate) faults: crate::faults::FaultInjector,
}

/// Cap on the exponential retry backoff (base 1ms, doubled per retry).
const RETRY_BACKOFF_CAP: Duration = Duration::from_millis(50);

/// How one decode session resolves its adapter inputs.
pub(crate) enum SessionMode<'s> {
    /// Legacy single-tenant session: one tenant's host/device state serves
    /// every row; requests for any other tenant are deferred back to the
    /// queue.  The fallback for engines/tenants outside the gathered
    /// artifact's reach (INT4 bases, QA-kind adapters).
    Uniform {
        id: Option<String>,
        dev: Option<&'s DeviceStore>,
        host_sets: Vec<&'s ParamSet>,
        eval_kind: &'s str,
    },
    /// Mixed-tenant session over the gathered banks: every row carries a
    /// bank-slot index, resolved per request by `slot_of` (0 = identity /
    /// merged path; `None` = ineligible, deferred back to the queue).
    Gathered {
        bank: &'s DeviceStore,
        slot_of: &'s dyn Fn(&Option<String>) -> Option<i32>,
    },
}

/// Lazily-built per-tenant [`SessionRecorder`]s for one dispatched batch:
/// mixed sessions touch several tenants' instruments, and resolving a
/// recorder per *event* would re-do registry lookups on the hot path.
pub(crate) struct RecorderCache<'o> {
    obs: &'o ServeObs,
    worker: usize,
    map: BTreeMap<Option<String>, Arc<SessionRecorder>>,
}

impl<'o> RecorderCache<'o> {
    pub(crate) fn new(obs: &'o ServeObs, worker: usize) -> RecorderCache<'o> {
        RecorderCache { obs, worker, map: BTreeMap::new() }
    }

    pub(crate) fn get(&mut self, id: &Option<String>) -> Arc<SessionRecorder> {
        if let Some(rec) = self.map.get(id) {
            return Arc::clone(rec);
        }
        let rec = Arc::new(self.obs.recorder(id, self.worker));
        self.map.insert(id.clone(), Arc::clone(&rec));
        rec
    }
}

/// Drive one continuous decode session: admit the handed-over batch, then
/// loop forward → retire/reply → re-fill, until the slots drain and
/// nothing admissible is waiting.  `refill` is called between forwards
/// whenever the hand-over queue is dry, with the current free-slot count —
/// the single-worker router drains its request channel and asks its
/// scheduler there; pool workers ask the sharded scheduler.  Gathered
/// sessions re-fill with *any* tenant's request (its adapter rides its own
/// bank slot); uniform sessions re-fill same-tenant only.
///
/// A request the session can't serve — wrong tenant for a uniform session,
/// no bank slot for a gathered one — is **deferred**: returned with the
/// survivors, uncharged, for the caller to requeue (the next dispatch
/// routes it through the fallback path).
///
/// Failure isolation: a failed forward is retried in place with capped
/// exponential backoff (transient faults never surface to clients); once
/// `policy.max_retries` retries are spent the session fails — but only
/// *this session*.  Each resident request is charged one attempt: those
/// over their re-admission budget fail with [`ServeError::EngineFailure`],
/// the rest — plus all still-waiting requests, uncharged — are **returned
/// as survivors** for the caller to re-admit into a fresh session.
///
/// Cancellation: a request whose [`CancelHandle`] fired is skipped at
/// admission or released mid-decode, counting `serve_cancelled_total`; a
/// completed request whose reply channel is gone counts there too.
///
/// All accounting flows through `recs`, each request through its own
/// tenant's recorder — a request's token count is the number of forwards
/// between its admission and retirement, so summed retire / cancel /
/// error tokens equal the session's occupied-slot-forwards, *minus*
/// forwards spent on survivor rows (their partial progress is discarded
/// with the session and recounted in the session that actually completes
/// them).
pub(crate) fn run_decode_session(
    engine: &Engine,
    mode: &SessionMode,
    reqs: Vec<Request>,
    refill: &mut dyn FnMut(usize) -> Vec<Request>,
    recs: &mut RecorderCache,
    policy: &SessionPolicy,
) -> Vec<Request> {
    if reqs.is_empty() {
        return Vec::new();
    }
    // worker-scoped instruments (decode steps, uploads, retries) dedupe in
    // the registry by label, so any tenant's recorder records them
    // identically; the first request's tenant labels the trace spans
    let step_rec = recs.get(&reqs[0].adapter_id);
    let (dev, host_sets, eval_kind): (Option<&DeviceStore>, &[&ParamSet], &str) = match mode {
        SessionMode::Uniform { dev, host_sets, eval_kind, .. } => {
            (*dev, host_sets.as_slice(), eval_kind)
        }
        SessionMode::Gathered { bank, .. } => (Some(*bank), &[], GATHERED_KIND),
    };
    let mut session = match engine.begin_decode() {
        Ok(s) => s,
        Err(e) => {
            let msg = format!("{e:#}");
            for req in reqs {
                recs.get(&req.adapter_id).error(&req, 0, &msg);
                let _ = req.reply.send(Err(anyhow!(msg.clone())));
            }
            return Vec::new();
        }
    };
    // in-flight request per slot: (request, first-forward pending, session
    // step count at admission — its token count at retire is the forwards
    // since then)
    let mut slots: Vec<Option<(Request, bool, usize)>> =
        (0..session.capacity()).map(|_| None).collect();
    let mut waiting: VecDeque<Request> = reqs.into();
    let mut deferred: Vec<Request> = Vec::new();
    let mut failure: Option<String> = None;
    let mut retries = 0usize;
    let mut backoff = Duration::from_millis(1);
    loop {
        // fill free slots from the hand-off / refill queue
        while session.free_slots() > 0 {
            let Some(req) = waiting.pop_front() else { break };
            if req.is_cancelled() {
                recs.get(&req.adapter_id).cancel(&req, None, 0);
                let _ = req.reply.send(Err(anyhow::Error::new(ServeError::Cancelled)));
                continue;
            }
            // resolve how this row's adapter reaches the forward
            let bank_slot = match mode {
                SessionMode::Uniform { id, .. } => {
                    if req.adapter_id != *id {
                        deferred.push(req);
                        continue;
                    }
                    None
                }
                SessionMode::Gathered { slot_of, .. } => match slot_of(&req.adapter_id) {
                    Some(idx) => Some(idx),
                    None => {
                        deferred.push(req);
                        continue;
                    }
                },
            };
            let rec = recs.get(&req.adapter_id);
            let admitted = match bank_slot {
                Some(idx) => engine.admit_indexed(
                    &mut session,
                    &req.prompt,
                    req.max_new_tokens,
                    req.min_new_tokens,
                    idx,
                ),
                None => {
                    engine.admit(&mut session, &req.prompt, req.max_new_tokens, req.min_new_tokens)
                }
            };
            match admitted {
                Ok(slot) => {
                    rec.admit(&req, slot, req.enqueued.elapsed().as_secs_f64() * 1e3);
                    slots[slot] = Some((req, true, session.steps()));
                }
                Err(e) => {
                    rec.error(&req, 0, &format!("{e:#}"));
                    let _ = req.reply.send(Err(e));
                }
            }
        }
        let active = session.active_slots();
        if active == 0 {
            break; // nothing admitted and nothing admissible waiting
        }
        // pre-step state for the step record, captured only when recording
        let pre = step_rec
            .enabled()
            .then(|| (Instant::now(), session.uploads(), crate::runtime::thread_upload_bytes()));
        let prefills_before = session.prefills();
        // the forward, behind the chaos harness's failpoints (no-ops when
        // injection is disabled); `decode_step` is retry-safe — the token
        // upload re-runs off its dirty flag and rows only advance on
        // success, so a failed call leaves the session exactly as it was
        let retired = match policy
            .faults
            .check(crate::faults::SITE_SLOW_FORWARD)
            .and_then(|_| policy.faults.check(crate::faults::SITE_FORWARD))
            .and_then(|_| engine.decode_step(&mut session, dev, host_sets, eval_kind))
        {
            Ok(r) => r,
            Err(e) => {
                if let Some(pe) = e.downcast_ref::<PrefillError>() {
                    // a failed prefill fails only the rows it was
                    // admitting — the engine already released them and
                    // in-flight rows keep their resident pages.  Charge
                    // each affected request one attempt: over budget
                    // fails typed, the rest requeue for re-admission
                    // (and a fresh prefill) next iteration.
                    for &slot in &pe.slots {
                        let Some((mut req, _, _)) = slots[slot].take() else { continue };
                        req.attempts += 1;
                        if req.attempts > policy.max_retries {
                            let rec = recs.get(&req.adapter_id);
                            rec.error(&req, 0, &pe.message);
                            let _ = req.reply.send(Err(anyhow::Error::new(
                                ServeError::EngineFailure {
                                    attempts: req.attempts,
                                    message: pe.message.clone(),
                                },
                            )));
                        } else {
                            waiting.push_back(req);
                        }
                    }
                    continue;
                }
                if retries >= policy.max_retries {
                    failure = Some(format!("{e:#}"));
                    break;
                }
                retries += 1;
                step_rec.retry(retries, &format!("{e:#}"));
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(RETRY_BACKOFF_CAP);
                continue;
            }
        };
        let was_prefill = session.prefills() > prefills_before;
        if let Some((t0, uploads_before, bytes_before)) = pre {
            let step_ms = t0.elapsed().as_secs_f64() * 1e3;
            step_rec.step(
                step_ms,
                active,
                session.uploads() > uploads_before,
                crate::runtime::thread_upload_bytes().saturating_sub(bytes_before),
                session.cache_resident_bytes(),
            );
            if was_prefill {
                step_rec.prefill(step_ms);
            }
        }
        // every occupied row went through that forward: first tokens (and
        // the prefill span that built the row's cache page — a request's
        // first forward is a prefill whenever the cached path is active)
        let now = Instant::now();
        for entry in slots.iter_mut().flatten() {
            if entry.1 {
                entry.1 = false;
                let rec = recs.get(&entry.0.adapter_id);
                if was_prefill {
                    rec.prefill_span(&entry.0);
                }
                let waited = now.saturating_duration_since(entry.0.enqueued);
                rec.first_token(&entry.0, waited.as_secs_f64() * 1e3);
            }
        }
        for (slot, answer) in retired {
            if let Some((req, _, admit_steps)) = slots[slot].take() {
                let tokens = session.steps() - admit_steps;
                let rec = recs.get(&req.adapter_id);
                if req.reply.send(Ok(answer)).is_ok() {
                    rec.retire(&req, slot, tokens, req.enqueued.elapsed().as_secs_f64() * 1e3);
                } else {
                    // nobody is listening: the client went away without a
                    // cancel handle — count the dropped-client retirement
                    rec.cancel(&req, Some(slot), tokens);
                }
            }
        }
        // release slots whose client cancelled mid-decode: no more
        // forwards are spent on them
        for (slot, entry) in slots.iter_mut().enumerate() {
            if entry.as_ref().map(|(r, _, _)| r.is_cancelled()).unwrap_or(false) {
                let (req, _, admit_steps) = entry.take().expect("checked occupied");
                session.release(slot);
                recs.get(&req.adapter_id).cancel(&req, Some(slot), session.steps() - admit_steps);
                let _ = req.reply.send(Err(anyhow::Error::new(ServeError::Cancelled)));
            }
        }
        // top the freed slots up between forwards
        let free = session.free_slots();
        if free > 0 && waiting.is_empty() {
            waiting.extend(refill(free));
        }
        if session.active_slots() == 0 && waiting.is_empty() {
            break;
        }
    }
    let mut survivors = Vec::new();
    if let Some(msg) = failure {
        // persistent failure: charge each resident one attempt;
        // over-budget residents fail typed, the rest survive for a fresh
        // session.  Waiting requests never entered the failed session —
        // survivors, uncharged.
        for entry in slots.iter_mut() {
            if let Some((mut req, _, admit_steps)) = entry.take() {
                req.attempts += 1;
                if req.attempts > policy.max_retries {
                    // forwards the failed slot did complete still count as
                    // generated tokens, so token totals stay exact
                    recs.get(&req.adapter_id).error(&req, session.steps() - admit_steps, &msg);
                    let _ = req.reply.send(Err(anyhow::Error::new(ServeError::EngineFailure {
                        attempts: req.attempts,
                        message: msg.clone(),
                    })));
                } else {
                    survivors.push(req);
                }
            }
        }
        survivors.extend(waiting);
    }
    // deferred requests ride back with the survivors (uncharged) so the
    // caller requeues them for the fallback path
    survivors.extend(deferred);
    survivors
}

/// Serve one dispatched batch end-to-end — the driver shared by the
/// single-worker [`Router`] and every pool worker.  When the engine and
/// every request are gathered-eligible, the whole batch (mixed tenants
/// and all) runs as **one** session over the bank; otherwise the batch is
/// split by tenant, first-appearance order, into sequential uniform
/// sessions.  `refill(None, free)` asks for mixed re-fill, `refill(
/// Some(&tenant), free)` for same-tenant re-fill.  Returns the combined
/// survivors for the caller to requeue.
pub(crate) fn serve_batch(
    engine: &Engine,
    registry: &mut AdapterRegistry,
    worker: usize,
    reqs: Vec<Request>,
    refill: &mut dyn FnMut(Option<&Option<String>>, usize) -> Vec<Request>,
    obs: &ServeObs,
    policy: &SessionPolicy,
) -> Vec<Request> {
    let mut recs = RecorderCache::new(obs, worker);
    // tiered residency (opt-in): pull cold cataloged tenants up the
    // ladder before dispatch — disk → host if needed, then host → device
    // within the byte budget (degrading ranks under pressure).  Failures
    // are not fatal here: a quarantined tenant gets its typed refusal in
    // the per-group branch below, and a tenant that can't be placed on
    // the device still serves host-resident via per-forward uploads.
    if registry.tiering_enabled() {
        let mut tenants: Vec<String> = Vec::new();
        for req in &reqs {
            if let Some(tid) = &req.adapter_id {
                if !tenants.iter().any(|t| t == tid) {
                    tenants.push(tid.clone());
                }
            }
        }
        if let Ok(hyper) = engine.rt.model(&engine.config) {
            let hyper = hyper.clone();
            for tid in &tenants {
                let _ = registry.prefetch_host(&hyper, tid);
                let _ = registry.ensure_device(engine.rt, tid);
            }
        }
    }
    let gathered_ready = engine.supports_gathered() && registry.bank().is_some();
    let mut eligible = gathered_ready;
    if gathered_ready {
        for req in &reqs {
            if let Some(tid) = &req.adapter_id {
                // serving counts as LRU use even though the gathered path
                // reads through shared `peek`s from here on
                let _ = registry.get(tid);
            }
            if bank_slot_for(engine, registry, &req.adapter_id).is_none() {
                eligible = false;
            }
        }
    }
    if eligible {
        let registry = &*registry;
        let bank = registry.bank().expect("eligibility implies a bank").device();
        let slot_of = |id: &Option<String>| bank_slot_for(engine, registry, id);
        let mode = SessionMode::Gathered { bank, slot_of: &slot_of };
        let mut mixed_refill = |free: usize| refill(None, free);
        return run_decode_session(engine, &mode, reqs, &mut mixed_refill, &mut recs, policy);
    }
    // fallback: split by tenant (first-appearance order, preserving each
    // tenant's FIFO) and run sequential uniform sessions
    let mut groups: Vec<(Option<String>, Vec<Request>)> = Vec::new();
    for req in reqs {
        match groups.iter_mut().find(|(gid, _)| *gid == req.adapter_id) {
            Some((_, group)) => group.push(req),
            None => groups.push((req.adapter_id.clone(), vec![req])),
        }
    }
    let mut survivors = Vec::new();
    for (gid, group) in groups {
        let (host_sets, eval_kind, dev): (Vec<&ParamSet>, &str, Option<&DeviceStore>) = match &gid
        {
            None => {
                (engine.default_sets.iter().collect(), engine.default_kind.as_str(), None)
            }
            Some(tid) => match registry.get_for_serving(tid) {
                Some((entry, dev)) => {
                    (entry.host_sets.iter().collect(), entry.eval_kind.as_str(), dev)
                }
                None => {
                    // typed refusal: quarantined carries the corruption
                    // reason, otherwise plain not-registered — siblings in
                    // this same dispatch keep serving either way
                    let err = registry.unavailable_error(tid);
                    let msg = err.to_string();
                    for req in group {
                        recs.get(&req.adapter_id).error(&req, 0, &msg);
                        let _ = req.reply.send(Err(anyhow::Error::new(err.clone())));
                    }
                    continue;
                }
            },
        };
        let mode = SessionMode::Uniform { id: gid.clone(), dev, host_sets, eval_kind };
        let mut uniform_refill = |free: usize| refill(Some(&gid), free);
        survivors.extend(run_decode_session(
            engine,
            &mode,
            group,
            &mut uniform_refill,
            &mut recs,
            policy,
        ));
    }
    survivors
}

/// The bank slot a request rides on in a gathered session: the reserved
/// identity slot 0 for no-adapter requests when the engine's default path
/// is the merged one, the tenant's slice for plain-eval registered
/// tenants.  `None` marks the request gathered-ineligible — unknown
/// tenant, QA-kind adapter (merges through fake-quant, which the gathered
/// kernel doesn't model), or a bank without its slice — and routes it to
/// a uniform fallback session.
pub(crate) fn bank_slot_for(
    engine: &Engine,
    registry: &AdapterRegistry,
    id: &Option<String>,
) -> Option<i32> {
    match id {
        None => engine.merged_default.then_some(0),
        Some(tid) => {
            let entry = registry.peek(tid)?;
            if entry.eval_kind != "eval" {
                return None;
            }
            registry.bank_slot(tid).map(|slot| slot as i32)
        }
    }
}

/// One engine + one registry = a multi-tenant serving endpoint.
pub struct Router<'a> {
    engine: Engine<'a>,
    registry: AdapterRegistry,
    obs: Option<ServeObs>,
    faults: crate::faults::FaultInjector,
}

impl<'a> Router<'a> {
    pub fn new(engine: Engine<'a>, registry: AdapterRegistry) -> Router<'a> {
        Router { engine, registry, obs: None, faults: crate::faults::FaultInjector::disabled() }
    }

    /// Arm the chaos harness for this router's serve runs (tests and the
    /// degradation bench; serving is fault-free by default).
    pub fn set_faults(&mut self, faults: crate::faults::FaultInjector) {
        self.faults = faults;
    }

    pub fn engine(&self) -> &Engine<'a> {
        &self.engine
    }

    pub fn registry_mut(&mut self) -> &mut AdapterRegistry {
        &mut self.registry
    }

    /// Install a shared observability context (metrics and optional trace)
    /// before serving — e.g. one a [`crate::obs::expose::MetricsWriter`]
    /// is already watching.  Without this, `serve` creates a private
    /// metrics-only context per run.  Binds the adapter registry's
    /// instruments immediately so registrations from now on are counted.
    pub fn set_obs(&mut self, obs: ServeObs) {
        self.registry.bind_obs(obs.registry(), 0);
        if let Some(t) = obs.trace() {
            self.registry.bind_trace(t.clone());
        }
        self.obs = Some(obs);
    }

    /// Enable the registry's gathered bank when the engine/artifacts
    /// support it, and upload any backfilled tenant slices.  Quietly
    /// leaves the uniform fallback in place when the artifact is absent,
    /// the engine serves packed INT4, the registry's LRU bound outsizes
    /// the bank, or a resident entry can't be banked.
    fn setup_gathered(&mut self) -> Result<()> {
        if !self.engine.supports_gathered() {
            return Ok(());
        }
        if self.registry.bank().is_none() {
            let Some(slots) = self
                .engine
                .rt
                .manifest
                .config(&self.engine.config)
                .ok()
                .and_then(|c| c.artifacts.get(GATHERED_KIND))
                .and_then(gathered_slots)
            else {
                return Ok(());
            };
            if self.registry.capacity() > slots.saturating_sub(1) {
                return Ok(());
            }
            let hyper = self.engine.rt.model(&self.engine.config)?.clone();
            if self.registry.enable_gathered(&hyper, slots).is_err() {
                return Ok(());
            }
        }
        self.registry.flush_bank(self.engine.rt)?;
        Ok(())
    }

    /// Serve requests from a channel until it closes and all queues drain.
    ///
    /// Continuous-batching loop: the [`Scheduler`] pops slot-level
    /// **mixed** batches — one policy across every tenant's queue — and
    /// each batch runs as a single gathered session whenever the engine
    /// and its requests allow ([`serve_batch`]); while a session runs,
    /// freed slots re-fill with *any* waiting request between forwards
    /// ([`Scheduler::admit`]).  Engines or tenants outside the gathered
    /// artifact's reach fall back to sequential per-tenant uniform
    /// sessions refilled same-tenant only ([`Scheduler::admit_for`]).
    pub fn serve(&mut self, rx: Receiver<Request>, opts: SchedulerOpts) -> Result<MultiServeStats> {
        let cap = self.engine.artifact_batch()?;
        let opts = SchedulerOpts { max_batch: opts.max_batch.min(cap).max(1), ..opts };
        let obs = match &self.obs {
            Some(o) => o.clone(),
            None => {
                let o = ServeObs::new();
                self.registry.bind_obs(o.registry(), 0);
                o
            }
        };
        let policy =
            SessionPolicy { max_retries: opts.max_retries, faults: self.faults.clone() };
        // route the runtime/registry failpoints through this thread too
        let _fault_guard = crate::faults::install(&policy.faults);
        self.setup_gathered()?;
        let mut sched = Scheduler::new(opts);
        sched.bind_obs(obs.registry(), 0);
        obs.set_worker_gauges(0, cap, self.engine.resident_weight_bytes());
        let start = Instant::now();
        let mut open = true;
        let engine = &self.engine;
        let registry = &mut self.registry;
        while open || !sched.is_empty() {
            if sched.is_empty() {
                // block for the first pending request
                match rx.recv() {
                    Ok(r) => {
                        obs.enqueue(&r);
                        sched.push(r);
                    }
                    Err(_) => {
                        open = false;
                        continue;
                    }
                }
            }
            drain_channel(&rx, &mut sched, &mut open, &obs);
            // queue arrival warms the disk tier: cold cataloged tenants
            // get validated host copies while they wait, so their first
            // dispatch pays a host → device upload instead of a disk read
            if registry.tiering_enabled() {
                if let Ok(hyper) = engine.rt.model(&engine.config) {
                    let hyper = hyper.clone();
                    for tid in sched.pending_tenants() {
                        let _ = registry.prefetch_host(&hyper, &tid);
                    }
                }
            }
            let Some(reqs) = sched.next_batch(Instant::now()) else {
                continue;
            };
            obs.dispatch(0, &reqs, false);
            obs.session_start(0, false);
            // between forwards: pick up new channel arrivals, then top
            // freed slots up — mixed from every queue, uniform from the
            // session tenant's own
            let mut refill = |filter: Option<&Option<String>>, free: usize| {
                drain_channel(&rx, &mut sched, &mut open, &obs);
                match filter {
                    None => sched.admit(Instant::now(), free),
                    Some(id) => sched.admit_for(id, Instant::now(), free),
                }
            };
            let survivors = serve_batch(engine, registry, 0, reqs, &mut refill, &obs, &policy);
            if !survivors.is_empty() {
                let n = survivors.len();
                for req in survivors {
                    // front of the tenant's FIFO; an expired deadline
                    // replies DeadlineExceeded inside requeue
                    sched.requeue(req);
                }
                obs.session_rebuilt(0, n);
            }
        }
        let wall = start.elapsed().as_secs_f64();
        let mut stats = finish_multi_obs(&obs, wall, sched.metrics(), cap);
        stats.total.resident_weight_bytes = Some(self.engine.resident_weight_bytes());
        Ok(stats)
    }
}

/// Pull everything currently buffered on the request channel into the
/// scheduler without blocking; flips `open` off when the channel closes.
fn drain_channel(rx: &Receiver<Request>, sched: &mut Scheduler, open: &mut bool, obs: &ServeObs) {
    loop {
        match rx.try_recv() {
            Ok(r) => {
                obs.enqueue(&r);
                sched.push(r);
            }
            Err(TryRecvError::Empty) => break,
            Err(TryRecvError::Disconnected) => {
                *open = false;
                break;
            }
        }
    }
}

/// Drive a router with a synthetic open-loop workload: one producer thread
/// sends `(adapter_id, prompt)` requests at `inter_arrival` spacing, the
/// router serves on the calling thread; returns the measured stats.
pub fn benchmark_router(
    router: &mut Router,
    requests: Vec<(Option<String>, String)>,
    inter_arrival: Duration,
    opts: SchedulerOpts,
) -> Result<MultiServeStats> {
    let (tx, rx) = channel::<Request>();
    let producer = std::thread::spawn(move || {
        let mut replies = Vec::new();
        for (adapter_id, prompt) in requests {
            let (rtx, rrx) = channel();
            let _ = tx.send(Request::new(adapter_id, prompt, rtx));
            replies.push(rrx);
            if !inter_arrival.is_zero() {
                std::thread::sleep(inter_arrival);
            }
        }
        drop(tx);
        // drain replies so the router's sends don't error
        for r in replies {
            let _ = r.recv();
        }
    });
    let stats = router.serve(rx, opts)?;
    producer.join().ok();
    Ok(stats)
}
