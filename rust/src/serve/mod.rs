//! Serving loop: dynamic batching + greedy decoding over the eval artifact.
//!
//! The paper's §2.5 motivation: merged models (SparsePEFT/QA-SparsePEFT)
//! serve faster and smaller than base+adapter pairs.  This module measures
//! that on this testbed (Table 7 inference columns): a single-threaded
//! engine owns the Runtime (PJRT handles are not Sync); request producers
//! run on OS threads and talk to it over channels; the engine coalesces up
//! to `batch` pending requests per forward pass.
//!
//! Greedy decoding is teacher-forcing-free: each generated token re-runs
//! the batched forward with the answer-so-far appended (no KV cache in the
//! artifact — acceptable at seq<=128, and identical work for merged vs
//! unmerged, which is what the comparison needs).

use crate::data::Tokenizer;
use crate::model::ParamSet;
use crate::nls::{Config, SearchSpace};
use crate::runtime::{args::build_args, DeviceStore, HostValue, Runtime};
use crate::util::{summarize, Summary};
use anyhow::{bail, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

/// One inference request: a prompt; the reply is the decoded answer string.
pub struct Request {
    pub prompt: String,
    pub reply: Sender<Result<String>>,
    pub enqueued: Instant,
}

/// Engine state: device-resident weights + (optional) adapter host state.
pub struct Engine<'a> {
    rt: &'a Runtime,
    config: String,
    device: DeviceStore,
    /// host-side eval inputs: adapters + rank params (empty set = merged)
    host_sets: Vec<ParamSet>,
    eval_kind: String,
    tok: Tokenizer,
    max_new_tokens: usize,
}

impl<'a> Engine<'a> {
    /// Build an engine from frozen (device) params + host adapter state.
    pub fn new(
        rt: &'a Runtime,
        config: &str,
        frozen: &ParamSet,
        adapters: Option<(&ParamSet, &SearchSpace, &Config)>,
        eval_kind: &str,
    ) -> Result<Engine<'a>> {
        let hyper = rt.model(config)?.clone();
        let mut device = DeviceStore::new();
        for (n, t) in frozen.iter() {
            device.put_host(&rt.client, n, &HostValue::F32(t.clone()))?;
        }
        let mut host_sets = Vec::new();
        match adapters {
            Some((ad, space, cfg)) => {
                host_sets.push(ad.clone());
                host_sets.push(space.realize(cfg)?);
            }
            None => {
                // merged model: no-op adapters (B = 0)
                let mut rng = crate::tensor::Rng::new(1);
                host_sets.push(crate::model::init_adapters(&hyper, &mut rng, 1.0));
                let space = SearchSpace::default_for(&hyper, 1.0);
                host_sets.push(space.realize(&space.max_config())?);
            }
        }
        Ok(Engine {
            rt,
            config: config.to_string(),
            device,
            host_sets,
            eval_kind: eval_kind.to_string(),
            tok: Tokenizer::new(),
            max_new_tokens: 6,
        })
    }

    /// Greedy-decode a batch of prompts (padded to the artifact batch).
    pub fn generate_batch(&self, prompts: &[String]) -> Result<Vec<String>> {
        let hyper = self.rt.model(&self.config)?.clone();
        if prompts.is_empty() || prompts.len() > hyper.batch {
            bail!("batch of {} prompts (max {})", prompts.len(), hyper.batch);
        }
        let exe = self.rt.executable(&self.config, &self.eval_kind)?;
        let seq = hyper.seq_len;
        // token rows + current lengths
        let mut rows: Vec<Vec<i32>> = Vec::new();
        let mut lens: Vec<usize> = Vec::new();
        for p in prompts {
            let ids = self.tok.encode(p)?;
            if ids.len() + 1 + self.max_new_tokens > seq {
                bail!("prompt too long for seq {seq}");
            }
            let mut row = vec![0i32; seq];
            row[0] = Tokenizer::BOS;
            for (i, &id) in ids.iter().enumerate() {
                row[i + 1] = id;
            }
            lens.push(ids.len() + 1);
            rows.push(row);
        }
        while rows.len() < hyper.batch {
            rows.push(rows[0].clone());
            lens.push(0); // padding row: never decoded
        }
        let mut done = vec![false; prompts.len()];
        let mut answers: Vec<String> = vec![String::new(); prompts.len()];
        for _ in 0..self.max_new_tokens {
            if done.iter().all(|&d| d) {
                break;
            }
            let tokens: Vec<i32> = rows.iter().flatten().copied().collect();
            let batch = crate::data::Batch {
                tokens,
                targets: vec![0; hyper.batch * seq],
                loss_mask: vec![0.0; hyper.batch * seq],
                batch: hyper.batch,
                seq,
                real: prompts.len(),
            };
            let args = build_args(
                &exe.spec,
                Some(&self.device),
                &self.host_sets.iter().collect::<Vec<_>>(),
                Some(&batch),
                &[],
            )?;
            let outs = exe.run_mixed(&self.rt.client, &args)?;
            let logits = &outs[0];
            let v = hyper.vocab;
            for (bi, len) in lens.iter_mut().enumerate().take(prompts.len()) {
                if done[bi] || *len == 0 {
                    continue;
                }
                let pos = *len - 1; // logits at last filled position
                let row = &logits.data()[bi * seq * v + pos * v..bi * seq * v + (pos + 1) * v];
                let mut best = 0usize;
                for t in 1..v {
                    if row[t] > row[best] {
                        best = t;
                    }
                }
                let ch = self.tok.decode_one(best as i32)?;
                if ch == '.' || *len >= seq - 1 {
                    done[bi] = true;
                }
                if ch != '.' {
                    answers[bi].push(ch);
                }
                rows[bi][*len] = best as i32;
                *len += 1;
            }
        }
        Ok(answers)
    }

    /// Serve requests from a channel until it closes; coalesces up to
    /// `batch` pending requests per forward pass (dynamic batching).
    pub fn serve(&self, rx: Receiver<Request>) -> Result<ServeStats> {
        let hyper = self.rt.model(&self.config)?.clone();
        let mut latencies = Vec::new();
        let mut served = 0usize;
        let start = Instant::now();
        loop {
            // block for the first request
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => break,
            };
            let mut pending = vec![first];
            // coalesce whatever else is already queued (up to batch)
            while pending.len() < hyper.batch {
                match rx.try_recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break,
                }
            }
            let prompts: Vec<String> =
                pending.iter().map(|r| r.prompt.clone()).collect();
            match self.generate_batch(&prompts) {
                Ok(answers) => {
                    for (req, ans) in pending.into_iter().zip(answers) {
                        latencies.push(req.enqueued.elapsed().as_secs_f64() * 1e3);
                        served += 1;
                        let _ = req.reply.send(Ok(ans));
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for req in pending {
                        let _ = req.reply.send(Err(anyhow::anyhow!(msg.clone())));
                    }
                }
            }
        }
        let wall = start.elapsed().as_secs_f64();
        Ok(ServeStats {
            served,
            wall_secs: wall,
            throughput: served as f64 / wall.max(1e-9),
            latency_ms: if latencies.is_empty() {
                None
            } else {
                Some(summarize(latencies))
            },
        })
    }
}

#[derive(Debug)]
pub struct ServeStats {
    pub served: usize,
    pub wall_secs: f64,
    pub throughput: f64,
    pub latency_ms: Option<Summary>,
}

/// Drive an engine with a synthetic open-loop workload from `n_clients`
/// producer threads, `n_requests` total; returns the measured stats.
pub fn benchmark_engine(engine: &Engine, prompts: Vec<String>,
                        inter_arrival: Duration) -> Result<ServeStats> {
    let (tx, rx) = channel::<Request>();
    let producer = std::thread::spawn(move || {
        let mut replies = Vec::new();
        for p in prompts {
            let (rtx, rrx) = channel();
            let _ = tx.send(Request { prompt: p, reply: rtx, enqueued: Instant::now() });
            replies.push(rrx);
            std::thread::sleep(inter_arrival);
        }
        drop(tx);
        // drain replies so the engine's sends don't error
        for r in replies {
            let _ = r.recv();
        }
    });
    let stats = engine.serve(rx)?;
    producer.join().ok();
    Ok(stats)
}
