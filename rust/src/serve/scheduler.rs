//! Slot-level mixed-tenant batch scheduler.
//!
//! The `eval_gathered` artifact applies each batch row's *own* adapter via
//! device-resident banks and a per-row index vector, so one forward pass
//! serves requests from **any** mix of tenants (the merged / no-adapter
//! path rides along on the reserved identity bank slot 0).  Scheduling is
//! therefore slot-level: a free decode slot takes the best waiting request
//! regardless of tenant, and batches are routinely mixed.
//!
//! The scheduler still keeps a FIFO queue per adapter id — per-tenant
//! FIFO order is a client-visible property, and queue shape drives the
//! admission policy — but both dispatch granularities pull across all
//! queues with one age-ordered policy (`pop_mixed`):
//!
//!   - a queue whose oldest request has waited past the `aging` bound is
//!     served first, oldest head first — the same starvation bound as
//!     same-tenant scheduling, now a fairness tie-break rather than a
//!     batch-switch trigger;
//!   - otherwise the fullest queue wins (keeps a hot tenant's rows
//!     together for upload locality), with the older head breaking ties.
//!
//! [`Scheduler::next_batch`] starts a batch (up to `max_batch` requests);
//! [`Scheduler::admit`] runs *between decode forwards* and tops freed
//! slots up with waiting requests from any tenant — there is no
//! admission hold anymore, because the device never needs to "switch
//! tenants": an aged request is simply admitted into the running batch.
//! Backpressure (`queue_cap` → `Overloaded`), deadlines (queued requests
//! are shed with `DeadlineExceeded` before any slot is spent on them),
//! and the re-admission retry budget carry over unchanged.
//!
//! The scheduler is pure bookkeeping (no runtime handles), so the policy is
//! unit-testable without artifacts; `now` is passed in rather than sampled.

use super::error::ServeError;
use crate::obs::{Counter, FloatCounter, Gauge, Histogram, Registry};
use crate::util::sync::{get_mut_recover, lock_recover, wait_timeout_recover};
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

/// One inference request: a prompt routed to a registered adapter
/// (`adapter_id: None` selects the merged / no-adapter fast path).
pub struct Request {
    /// Process-unique id stamped at construction; keys the trace spans
    /// (enqueue → dispatch → admit → first token → retire) in the JSONL
    /// event log so per-request phases can be joined across threads.
    pub id: u64,
    pub adapter_id: Option<String>,
    pub prompt: String,
    pub reply: Sender<Result<String>>,
    pub enqueued: Instant,
    /// Per-request cap on generated tokens (`None` = the engine default).
    /// Clamped to the engine's `max_new_tokens` at admission.
    pub max_new_tokens: Option<usize>,
    /// Per-request floor on generated tokens: the stop token is masked out
    /// of the argmax until this many tokens exist (0 = stop immediately
    /// allowed — the default).  Length control for benchmarking and for
    /// clients that want a minimum completion length.
    pub min_new_tokens: usize,
    /// Absolute deadline: the request is shed with
    /// [`ServeError::DeadlineExceeded`] if it is still queued past this
    /// instant (`None` = no deadline; the scheduler stamps its configured
    /// default at enqueue, see [`SchedulerOpts::deadline`]).
    pub deadline: Option<Instant>,
    /// Re-admissions consumed so far (session failures / worker crashes
    /// re-admit a request until this exceeds the scheduler's
    /// `max_retries`, after which it fails with
    /// [`ServeError::EngineFailure`]).
    pub attempts: usize,
    /// client-side cancellation flag, shared with a [`CancelHandle`]
    cancelled: Option<Arc<AtomicBool>>,
}

impl Request {
    /// A request with default decode limits (engine cap, no floor).
    pub fn new(
        adapter_id: Option<String>,
        prompt: String,
        reply: Sender<Result<String>>,
    ) -> Request {
        Request {
            id: NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed),
            adapter_id,
            prompt,
            reply,
            enqueued: Instant::now(),
            max_new_tokens: None,
            min_new_tokens: 0,
            deadline: None,
            attempts: 0,
            cancelled: None,
        }
    }

    /// Attach a cancellation handle: if the handle drops (or its
    /// [`CancelHandle::cancel`] is called) while the request is in a
    /// decode slot, the slot is retired early and the request counts as
    /// `serve_cancelled_total` — the dropped-client path.  Requests
    /// without a handle are only detected as cancelled when the final
    /// reply send finds the channel closed.
    pub fn cancel_handle(&mut self) -> CancelHandle {
        let flag = Arc::new(AtomicBool::new(false));
        self.cancelled = Some(flag.clone());
        CancelHandle { flag: Some(flag) }
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.as_ref().map(|f| f.load(Ordering::Relaxed)).unwrap_or(false)
    }

    /// True iff the request carries a deadline that has passed.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map(|d| now >= d).unwrap_or(false)
    }
}

/// Client-held cancellation token for one [`Request`].  Dropping it —
/// which is what happens when a client goes away — marks the request
/// cancelled, so the serving loop can retire its slot early instead of
/// decoding for nobody.  Call [`CancelHandle::disarm`] on clean
/// completion to drop the handle *without* cancelling.
pub struct CancelHandle {
    flag: Option<Arc<AtomicBool>>,
}

impl CancelHandle {
    /// Cancel explicitly (same effect as dropping the handle).
    pub fn cancel(mut self) {
        if let Some(f) = self.flag.take() {
            f.store(true, Ordering::Relaxed);
        }
    }

    /// Consume the handle without cancelling (the request completed).
    pub fn disarm(mut self) {
        self.flag = None;
    }
}

impl Drop for CancelHandle {
    fn drop(&mut self) {
        if let Some(f) = self.flag.take() {
            f.store(true, Ordering::Relaxed);
        }
    }
}

/// Scheduling policy knobs.
#[derive(Clone, Debug)]
pub struct SchedulerOpts {
    /// Upper bound on requests per dispatched batch (clamped to the
    /// artifact batch by the router).
    pub max_batch: usize,
    /// A request that has waited this long is admitted ahead of fuller
    /// queues (the fairness tie-break in the mixed admission policy).
    pub aging: Duration,
    /// Pending-request bound per scheduler (per *shard* in the pool):
    /// pushes beyond it are rejected with [`ServeError::Overloaded`]
    /// instead of growing the queue without limit (`None` = unbounded).
    pub queue_cap: Option<usize>,
    /// Default deadline stamped at enqueue onto requests that carry none,
    /// measured from the request's `enqueued` instant (`None` = no
    /// deadline).  Expired requests are shed with
    /// [`ServeError::DeadlineExceeded`] rather than dispatched.
    pub deadline: Option<Duration>,
    /// Per-request re-admission budget: how many times a request may be
    /// put back on the queue after a persistent session failure or a
    /// worker crash before it fails with [`ServeError::EngineFailure`].
    /// Also bounds the in-session decode-step retries.
    pub max_retries: usize,
}

impl Default for SchedulerOpts {
    fn default() -> Self {
        SchedulerOpts {
            max_batch: 8,
            aging: Duration::from_millis(50),
            queue_cap: None,
            deadline: None,
            max_retries: 2,
        }
    }
}

/// Queue-depth and batch-fill counters (reported with `ServeStats`).
#[derive(Clone, Debug, Default)]
pub struct SchedulerMetrics {
    /// batches dispatched
    pub batches: usize,
    /// requests dispatched across all batches
    pub scheduled: usize,
    /// sum of per-batch fill ratios (len / max_batch)
    pub fill_sum: f64,
    /// highest total pending count observed across all queues
    pub max_queue_depth: usize,
    /// batches where the aging bound promoted a request past fuller queues
    pub aged_batches: usize,
    /// requests admitted into an already-running batch (freed slots
    /// re-filled between forwards, the continuous-batching win)
    pub admitted: usize,
    /// dispatched batches containing more than one distinct adapter id
    /// (the gathered mixed-tenant path; same-tenant batches don't count)
    pub mixed_batches: usize,
    /// requests refused or dropped before dispatch: overload rejections
    /// plus deadline sheds (`shed == overloaded + deadline_expired`)
    pub shed: usize,
    /// the deadline-shed subset of `shed`
    pub deadline_expired: usize,
}

impl SchedulerMetrics {
    pub fn avg_fill(&self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.fill_sum / self.batches as f64 }
    }

    /// Build the metrics view from the scheduler's live instruments.
    /// `SchedulerMetrics` is a *snapshot*, not the source of truth — the
    /// counters live in [`SchedInstruments`] (shared with the obs
    /// registry when bound), so the end-of-run table and `--metrics-out`
    /// exposition read the same atomics.
    fn from_instruments(obs: &SchedInstruments) -> SchedulerMetrics {
        SchedulerMetrics {
            batches: obs.batches.get() as usize,
            scheduled: obs.scheduled.get() as usize,
            fill_sum: obs.fill_sum.get(),
            max_queue_depth: obs.queue_depth.peak() as usize,
            aged_batches: obs.aged_batches.get() as usize,
            admitted: obs.admitted.get() as usize,
            mixed_batches: obs.mixed_batches.get() as usize,
            shed: (obs.shed_overload.get() + obs.shed_deadline.get()) as usize,
            deadline_expired: obs.deadline_exceeded.get() as usize,
        }
    }

    /// Fold another scheduler's counters into this one (used to aggregate
    /// per-shard metrics into the pool-wide report).  Counters sum;
    /// `max_queue_depth` takes the max — i.e. the deepest any single
    /// shard got, a lower bound on the instantaneous global peak.
    pub fn merge(&mut self, other: &SchedulerMetrics) {
        self.batches += other.batches;
        self.scheduled += other.scheduled;
        self.fill_sum += other.fill_sum;
        self.max_queue_depth = self.max_queue_depth.max(other.max_queue_depth);
        self.aged_batches += other.aged_batches;
        self.admitted += other.admitted;
        self.mixed_batches += other.mixed_batches;
        self.shed += other.shed;
        self.deadline_expired += other.deadline_expired;
    }
}

/// The scheduler's counters as shared atomic instruments.  Standalone
/// `Arc`s by default (unit tests, no registry); [`Scheduler::bind_obs`]
/// swaps in registry-owned instruments under the `sched_*` metric names,
/// after which the registry snapshot and [`Scheduler::metrics`] read the
/// same storage — one instrument, many views.
struct SchedInstruments {
    batches: Arc<Counter>,
    scheduled: Arc<Counter>,
    fill_sum: Arc<FloatCounter>,
    /// live queue depth; its peak watermark is `max_queue_depth`
    queue_depth: Arc<Gauge>,
    aged_batches: Arc<Counter>,
    admitted: Arc<Counter>,
    /// dispatched batches spanning more than one adapter id
    /// (`sched_mixed_batches_total`)
    mixed_batches: Arc<Counter>,
    /// distinct adapter ids per dispatched batch
    /// (`sched_batch_distinct_tenants`; observed once per batch, so its
    /// count reconciles exactly with `sched_batches_total`)
    distinct_tenants: Arc<Histogram>,
    /// overload rejections at push (`serve_shed_total{reason=overload}`)
    shed_overload: Arc<Counter>,
    /// deadline sheds (`serve_shed_total{reason=deadline}`)
    shed_deadline: Arc<Counter>,
    /// same increments as `shed_deadline`, under the metric name the
    /// aging/deadline dashboards key on (`serve_deadline_exceeded_total`)
    deadline_exceeded: Arc<Counter>,
}

/// Buckets for `sched_batch_distinct_tenants` (a batch has at least one
/// tenant, so bucket 1 is the same-tenant / singleton case).
const DISTINCT_TENANTS_BOUNDS: &[f64] = &[1.0, 2.0, 4.0, 8.0, 16.0];

impl SchedInstruments {
    fn standalone() -> SchedInstruments {
        SchedInstruments {
            batches: Arc::new(Counter::new()),
            scheduled: Arc::new(Counter::new()),
            fill_sum: Arc::new(FloatCounter::new()),
            queue_depth: Arc::new(Gauge::new()),
            aged_batches: Arc::new(Counter::new()),
            admitted: Arc::new(Counter::new()),
            mixed_batches: Arc::new(Counter::new()),
            distinct_tenants: Arc::new(Histogram::new(DISTINCT_TENANTS_BOUNDS)),
            shed_overload: Arc::new(Counter::new()),
            shed_deadline: Arc::new(Counter::new()),
            deadline_exceeded: Arc::new(Counter::new()),
        }
    }

    fn registered(reg: &Registry, shard: usize) -> SchedInstruments {
        let shard = shard.to_string();
        let labels = [("shard", shard.as_str())];
        SchedInstruments {
            batches: reg.counter("sched_batches_total", &labels),
            scheduled: reg.counter("sched_scheduled_total", &labels),
            fill_sum: reg.float_counter("sched_fill_sum", &labels),
            queue_depth: reg.gauge("sched_queue_depth", &labels),
            aged_batches: reg.counter("sched_aged_batches_total", &labels),
            admitted: reg.counter("sched_admitted_total", &labels),
            mixed_batches: reg.counter("sched_mixed_batches_total", &labels),
            distinct_tenants: reg.histogram(
                "sched_batch_distinct_tenants",
                &labels,
                DISTINCT_TENANTS_BOUNDS,
            ),
            shed_overload: reg.counter(
                "serve_shed_total",
                &[("reason", "overload"), ("shard", shard.as_str())],
            ),
            shed_deadline: reg.counter(
                "serve_shed_total",
                &[("reason", "deadline"), ("shard", shard.as_str())],
            ),
            deadline_exceeded: reg.counter("serve_deadline_exceeded_total", &labels),
        }
    }
}

/// Per-adapter FIFO queues + the mixed slot-level dispatch policy.
pub struct Scheduler {
    opts: SchedulerOpts,
    queues: BTreeMap<Option<String>, VecDeque<Request>>,
    pending: usize,
    obs: SchedInstruments,
    /// queued requests carrying a deadline — the expired-sweep runs only
    /// while this is nonzero, so deadline-free workloads pay nothing
    deadlined: usize,
    /// requests shed (removed from the queues) since the last
    /// [`Scheduler::take_shed`] — the sharded front-end reads this to keep
    /// its cross-shard pending atomic in step
    recent_shed: usize,
}

impl Scheduler {
    pub fn new(opts: SchedulerOpts) -> Scheduler {
        let opts = SchedulerOpts { max_batch: opts.max_batch.max(1), ..opts };
        Scheduler {
            opts,
            queues: BTreeMap::new(),
            pending: 0,
            obs: SchedInstruments::standalone(),
            deadlined: 0,
            recent_shed: 0,
        }
    }

    /// Re-home the counters into `reg` (labelled `shard=<shard>`).  Call
    /// before any traffic: binding replaces the instruments, so counts
    /// recorded earlier stay behind in the standalone atomics.
    pub fn bind_obs(&mut self, reg: &Registry, shard: usize) {
        self.obs = SchedInstruments::registered(reg, shard);
    }

    /// Enqueue one request, stamping the configured default deadline onto
    /// requests that carry none.  Returns false — with the reply already
    /// sent — when the request is refused instead: immediately shed with
    /// [`ServeError::DeadlineExceeded`] if its deadline has already
    /// passed, or rejected with [`ServeError::Overloaded`] when the queue
    /// is at `queue_cap` (backpressure instead of unbounded growth).
    pub fn push(&mut self, mut req: Request) -> bool {
        if req.deadline.is_none() {
            if let Some(d) = self.opts.deadline {
                req.deadline = Some(req.enqueued + d);
            }
        }
        let now = Instant::now();
        if req.expired(now) {
            self.reply_deadline(req, now);
            return false;
        }
        if let Some(cap) = self.opts.queue_cap {
            if self.pending >= cap {
                self.obs.shed_overload.inc();
                let _ = req
                    .reply
                    .send(Err(anyhow::Error::new(ServeError::Overloaded { queue_cap: cap })));
                return false;
            }
        }
        self.enqueue(req, false);
        true
    }

    /// Put a request back on the queue after a session failure or worker
    /// crash: front of its tenant's FIFO (it has already waited its
    /// turn), bypassing the queue cap (it was admitted once — rejecting
    /// the re-admission would turn one engine fault into client-visible
    /// overload).  Its deadline still applies.  Returns false (reply
    /// sent) iff the deadline has passed.
    pub fn requeue(&mut self, req: Request) -> bool {
        let now = Instant::now();
        if req.expired(now) {
            self.reply_deadline(req, now);
            return false;
        }
        self.enqueue(req, true);
        true
    }

    fn enqueue(&mut self, req: Request, front: bool) {
        self.pending += 1;
        if req.deadline.is_some() {
            self.deadlined += 1;
        }
        self.obs.queue_depth.set(self.pending as f64);
        let q = self.queues.entry(req.adapter_id.clone()).or_default();
        if front {
            q.push_front(req);
        } else {
            q.push_back(req);
        }
    }

    /// Shed one request with `DeadlineExceeded` (reply + counters).  The
    /// caller has already removed it from the queues / kept it out.
    fn reply_deadline(&self, req: Request, now: Instant) {
        self.obs.shed_deadline.inc();
        self.obs.deadline_exceeded.inc();
        let waited = now.saturating_duration_since(req.enqueued).as_millis() as u64;
        let _ = req
            .reply
            .send(Err(anyhow::Error::new(ServeError::DeadlineExceeded { waited_ms: waited })));
    }

    /// Drop every queued request whose deadline has passed (honoring
    /// deadlines at queue time, before any decode slot is spent on them)
    /// and reply `DeadlineExceeded` to each.  Runs at the head of every
    /// dispatch decision, so expired work also stops distorting the
    /// fill+aging scores it would otherwise inflate.  No-op unless some
    /// queued request actually carries a deadline.
    fn shed_expired(&mut self, now: Instant) {
        if self.deadlined == 0 {
            return;
        }
        let mut shed: Vec<Request> = Vec::new();
        let mut emptied: Vec<Option<String>> = Vec::new();
        for (id, q) in self.queues.iter_mut() {
            if !q.iter().any(|r| r.expired(now)) {
                continue;
            }
            let mut kept = VecDeque::with_capacity(q.len());
            for req in q.drain(..) {
                if req.expired(now) {
                    shed.push(req);
                } else {
                    kept.push_back(req);
                }
            }
            *q = kept;
            if q.is_empty() {
                emptied.push(id.clone());
            }
        }
        if shed.is_empty() {
            return;
        }
        for id in emptied {
            self.queues.remove(&id);
        }
        self.pending -= shed.len();
        self.deadlined -= shed.len();
        self.recent_shed += shed.len();
        self.obs.queue_depth.set(self.pending as f64);
        for req in shed {
            self.reply_deadline(req, now);
        }
    }

    /// Requests shed out of the queues since the last call (consumed; the
    /// sharded front-end folds this into its cross-shard pending count).
    pub(crate) fn take_shed(&mut self) -> usize {
        std::mem::take(&mut self.recent_shed)
    }

    fn note_removed(&mut self, reqs: &[Request]) {
        self.deadlined -= reqs.iter().filter(|r| r.deadline.is_some()).count();
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    pub fn is_empty(&self) -> bool {
        self.pending == 0
    }

    /// Distinct tenant ids with requests waiting in queue.  The tiered
    /// registry prefetches these into its validated host tier while they
    /// wait, so a cold tenant's dispatch doesn't pay the disk read.
    pub fn pending_tenants(&self) -> Vec<String> {
        self.queues.keys().filter_map(|id| id.clone()).collect()
    }

    /// Snapshot of the scheduler counters (see
    /// [`SchedulerMetrics::from_instruments`]).
    pub fn metrics(&self) -> SchedulerMetrics {
        SchedulerMetrics::from_instruments(&self.obs)
    }

    /// Tighten `max_batch` to `cap` (idempotent; never below 1).  The
    /// worker pool calls this once the artifact batch is known, so a
    /// dispatched batch can never exceed the decode slots — oversized
    /// hand-offs would sit out the aging policy in a session's private
    /// queue (the single-worker router clamps the same way up front).
    pub fn clamp_max_batch(&mut self, cap: usize) {
        self.opts.max_batch = self.opts.max_batch.min(cap).max(1);
    }

    /// Pop up to `limit` requests across all queues under the mixed
    /// slot-level policy, one head at a time:
    ///
    ///   - if any queue's oldest request has waited past the `aging`
    ///     bound, the oldest such head goes next (fairness first);
    ///   - otherwise the fullest queue's head goes next (keeps a hot
    ///     tenant's rows together), the older head breaking ties.
    ///
    /// Per-tenant FIFO order is preserved by construction (only heads are
    /// popped).  Returns the requests plus whether the aging bound ever
    /// promoted a head past a fuller queue.  Bookkeeping (pending,
    /// deadlined, queue-depth gauge, counters) is the *caller's* job.
    fn pop_mixed(&mut self, now: Instant, limit: usize) -> (Vec<Request>, bool) {
        let aging = self.opts.aging;
        let mut out = Vec::with_capacity(limit.min(self.pending));
        let mut aged_hit = false;
        while out.len() < limit && !self.queues.is_empty() {
            // head wait per queue; aged pick = oldest aged head, full
            // pick = fullest queue (tie-break: older head)
            let mut aged_pick: Option<(Option<String>, Duration)> = None;
            let mut full_pick: Option<(Option<String>, usize, Duration)> = None;
            let mut max_len = 0usize;
            for (id, q) in &self.queues {
                let wait = q
                    .front()
                    .map(|r| now.saturating_duration_since(r.enqueued))
                    .unwrap_or(Duration::ZERO);
                if wait >= aging
                    && aged_pick.as_ref().map(|(_, w)| wait > *w).unwrap_or(true)
                {
                    aged_pick = Some((id.clone(), wait));
                }
                if full_pick
                    .as_ref()
                    .map(|(_, n, w)| q.len() > *n || (q.len() == *n && wait > *w))
                    .unwrap_or(true)
                {
                    full_pick = Some((id.clone(), q.len(), wait));
                }
                max_len = max_len.max(q.len());
            }
            let id = match (aged_pick, full_pick) {
                (Some((id, _)), _) => {
                    // only count a *promotion*: the aged head jumped a
                    // strictly fuller queue (an aged head that would have
                    // won on fill anyway is not a fairness event)
                    if self.queues.get(&id).map(|q| q.len()).unwrap_or(0) < max_len {
                        aged_hit = true;
                    }
                    id
                }
                (None, Some((id, _, _))) => id,
                (None, None) => break,
            };
            let q = self.queues.get_mut(&id).expect("picked from live queues");
            out.push(q.pop_front().expect("queues are never left empty"));
            if q.is_empty() {
                self.queues.remove(&id);
            }
        }
        (out, aged_hit)
    }

    /// Pop the next batch (up to `max_batch` requests) under the mixed
    /// policy — routinely spanning tenants; the gathered artifact applies
    /// each row's own adapter.  None iff nothing is pending.
    pub fn next_batch(&mut self, now: Instant) -> Option<Vec<Request>> {
        self.shed_expired(now);
        if self.queues.is_empty() {
            return None;
        }
        let limit = self.opts.max_batch;
        let (reqs, aged) = self.pop_mixed(now, limit);
        if reqs.is_empty() {
            return None;
        }
        if aged {
            self.obs.aged_batches.inc();
        }
        self.pending -= reqs.len();
        self.note_removed(&reqs);
        self.obs.queue_depth.set(self.pending as f64);
        self.obs.batches.inc();
        self.obs.scheduled.add(reqs.len() as u64);
        self.obs.fill_sum.add(reqs.len() as f64 / limit as f64);
        let distinct = reqs
            .iter()
            .map(|r| &r.adapter_id)
            .collect::<std::collections::BTreeSet<_>>()
            .len();
        self.obs.distinct_tenants.observe(distinct as f64);
        if distinct > 1 {
            self.obs.mixed_batches.inc();
        }
        Some(reqs)
    }

    /// Step-level admission for a *running* batch: pop up to `free_slots`
    /// requests from **any** queue under the same mixed policy, so freed
    /// decode slots re-fill between forwards instead of idling until the
    /// batch drains.  There is no aging hold: an aged request is admitted
    /// straight into the running batch (its adapter rides on its own bank
    /// slot, so the device never switches tenants).
    pub fn admit(&mut self, now: Instant, free_slots: usize) -> Vec<Request> {
        if free_slots == 0 {
            return Vec::new();
        }
        self.shed_expired(now);
        let (reqs, _) = self.pop_mixed(now, free_slots);
        if reqs.is_empty() {
            return reqs;
        }
        self.pending -= reqs.len();
        self.note_removed(&reqs);
        self.obs.queue_depth.set(self.pending as f64);
        self.obs.admitted.add(reqs.len() as u64);
        self.obs.scheduled.add(reqs.len() as u64);
        reqs
    }

    /// Step-level admission for a *uniform* session — the fallback path
    /// for engines/tenants the gathered artifact can't serve (INT4
    /// bases, QA-kind tenants): FIFO from `current`'s own queue only,
    /// since the running session is compiled against one tenant's
    /// adapter.  Admission pauses — returns empty — once another
    /// tenant's head has waited past the aging bound, so the session
    /// drains at its natural length and the aged tenant gets the next
    /// dispatch.  That re-creates the pre-gathered starvation bound for
    /// uniform sessions; mixed sessions never need it.
    pub fn admit_for(
        &mut self,
        current: &Option<String>,
        now: Instant,
        free_slots: usize,
    ) -> Vec<Request> {
        if free_slots == 0 {
            return Vec::new();
        }
        self.shed_expired(now);
        if !self.queues.contains_key(current) {
            return Vec::new();
        }
        let aging = self.opts.aging;
        let aged_elsewhere = self.queues.iter().any(|(id, q)| {
            id != current
                && q.front()
                    .map(|r| now.saturating_duration_since(r.enqueued) >= aging)
                    .unwrap_or(false)
        });
        if aged_elsewhere {
            return Vec::new();
        }
        let q = self.queues.get_mut(current).expect("checked above");
        let n = q.len().min(free_slots);
        let reqs: Vec<Request> = q.drain(..n).collect();
        if q.is_empty() {
            self.queues.remove(current);
        }
        self.pending -= reqs.len();
        self.note_removed(&reqs);
        self.obs.queue_depth.set(self.pending as f64);
        self.obs.admitted.add(reqs.len() as u64);
        self.obs.scheduled.add(reqs.len() as u64);
        reqs
    }
}

/// Stable tenant → shard assignment (FNV-1a over the adapter id; the
/// merged / no-adapter queue hashes like the empty string).  Every thread
/// must agree on this mapping, so it is a pure function of the id.
fn shard_of(id: &Option<String>, shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    if let Some(s) = id {
        for &b in s.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    (h % shards as u64) as usize
}

/// Thread-safe front-end for the worker pool: one [`Scheduler`] shard per
/// worker, tenants assigned to shards by stable hash, so each worker has
/// a *home* set of tenants (keeps one tenant's traffic on one worker —
/// better bank-slot locality — instead of splitting it across replicas).
///
/// Batches are mixed *within* a shard: each shard runs the slot-level
/// policy over its own tenants.  A worker whose home shard is dry scans
/// the other shards, home-first order, and takes a whole mixed batch
/// from the first non-empty one (`steals` counts those).  Stealing is
/// what bounds cross-shard starvation: a shard's aging bound only sees
/// its own tenants, so an aged tenant on a busy worker's shard is picked
/// up by whichever worker idles first.
///
/// Step-level admission ([`ShardedScheduler::admit`]) tops freed slots
/// up from the calling worker's home shard first, then its siblings —
/// any tenant, any shard; the gathered artifact decodes them in one
/// batch regardless of origin.
pub struct ShardedScheduler {
    shards: Vec<Mutex<Scheduler>>,
    /// queued requests across all shards (fast idle check without locks)
    pending: AtomicUsize,
    /// batches handed to a worker whose home shard didn't own them, one
    /// counter per worker (the thief) so steal *attribution* is visible;
    /// [`ShardedScheduler::steals`] sums them
    steal_obs: Vec<Arc<Counter>>,
    /// open flag guarded for the condvar; false once the producer closes
    gate: Mutex<bool>,
    work_ready: Condvar,
}

impl ShardedScheduler {
    pub fn new(shards: usize, opts: SchedulerOpts) -> ShardedScheduler {
        let shards = shards.max(1);
        ShardedScheduler {
            shards: (0..shards).map(|_| Mutex::new(Scheduler::new(opts.clone()))).collect(),
            pending: AtomicUsize::new(0),
            steal_obs: (0..shards).map(|_| Arc::new(Counter::new())).collect(),
            gate: Mutex::new(true),
            work_ready: Condvar::new(),
        }
    }

    /// Re-home every shard's counters plus the per-worker steal counters
    /// into `reg` (`sched_*{shard=..}`, `sched_steals_total{worker=..}`).
    /// Call before serving starts, like [`Scheduler::bind_obs`].
    pub fn bind_obs(&mut self, reg: &Registry) {
        for (i, shard) in self.shards.iter_mut().enumerate() {
            get_mut_recover(shard).bind_obs(reg, i);
        }
        self.steal_obs = (0..self.shards.len())
            .map(|w| {
                let w = w.to_string();
                reg.counter("sched_steals_total", &[("worker", w.as_str())])
            })
            .collect();
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard that owns `id`'s queue (exposed for tests and metrics).
    pub fn shard_of(&self, id: &Option<String>) -> usize {
        shard_of(id, self.shards.len())
    }

    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }

    /// Distinct tenant ids waiting on `home`'s shard (see
    /// [`Scheduler::pending_tenants`]); workers use it to warm their
    /// registry replica's host tier between batches.
    pub fn pending_tenants(&self, home: usize) -> Vec<String> {
        let home = home % self.shards.len();
        lock_recover(&self.shards[home]).pending_tenants()
    }

    /// Batches taken by non-home workers so far (all workers summed).
    pub fn steals(&self) -> usize {
        self.steal_obs.iter().map(|c| c.get() as usize).sum()
    }

    /// Enqueue a request on its tenant's home shard and wake a worker.
    /// False (reply already sent) when the shard refused it — overloaded
    /// past its queue cap, or its deadline already expired.
    pub fn push(&self, req: Request) -> bool {
        let shard = shard_of(&req.adapter_id, self.shards.len());
        let queued = lock_recover(&self.shards[shard]).push(req);
        if queued {
            self.pending.fetch_add(1, Ordering::SeqCst);
            self.work_ready.notify_one();
        }
        queued
    }

    /// Re-admit a request after a session failure / worker crash (see
    /// [`Scheduler::requeue`]: front of its tenant's FIFO, cap bypassed,
    /// deadline still honored) and wake a worker.  Works after `close` —
    /// workers drain requeued work before exiting.
    pub fn requeue(&self, req: Request) -> bool {
        let shard = shard_of(&req.adapter_id, self.shards.len());
        let queued = lock_recover(&self.shards[shard]).requeue(req);
        if queued {
            self.pending.fetch_add(1, Ordering::SeqCst);
            self.work_ready.notify_one();
        }
        queued
    }

    /// Producer side is done: once the queues drain, `next_work` returns
    /// `None` and workers exit.
    pub fn close(&self) {
        *lock_recover(&self.gate) = false;
        self.work_ready.notify_all();
    }

    /// Blocking dispatch for worker `home`: pop the next mixed batch
    /// under each shard's slot-level policy, scanning the home shard
    /// first, then stealing from siblings.  Blocks while every queue is
    /// empty but the producer is still open; `None` means shutdown (closed
    /// and drained).  `stolen` in the return is true when the batch came
    /// from a non-home shard.
    pub fn next_work(&self, home: usize, now: Instant) -> Option<(Vec<Request>, bool)> {
        let n = self.shards.len();
        let home = home % n;
        // `now` seeds the first scan (testability); it is resampled after
        // every blocking wait so aging scores never use a stale clock
        let mut now = now;
        loop {
            if self.pending.load(Ordering::SeqCst) > 0 {
                for k in 0..n {
                    let s = (home + k) % n;
                    let mut shard = lock_recover(&self.shards[s]);
                    let batch = shard.next_batch(now);
                    // deadline sheds inside the shard replied directly;
                    // fold them out of the cross-shard pending count so
                    // workers don't spin on work that no longer exists
                    let shed = shard.take_shed();
                    drop(shard);
                    if shed > 0 {
                        self.pending.fetch_sub(shed, Ordering::SeqCst);
                    }
                    if let Some(reqs) = batch {
                        self.pending.fetch_sub(reqs.len(), Ordering::SeqCst);
                        if k > 0 {
                            self.steal_obs[home].inc();
                        }
                        return Some((reqs, k > 0));
                    }
                }
                // raced with another worker's pop; rescan
                continue;
            }
            let open = lock_recover(&self.gate);
            if self.pending.load(Ordering::SeqCst) > 0 {
                continue; // a push landed between the check and the lock
            }
            if !*open {
                return None;
            }
            // the timeout is a safety net against lost wakeups; pushes
            // notify under normal operation
            let (_guard, _timed_out) =
                wait_timeout_recover(&self.work_ready, open, Duration::from_millis(20));
            now = Instant::now();
        }
    }

    /// Step-level admission for worker `home`'s running session: top up
    /// `free_slots` with waiting requests from any tenant, scanning the
    /// home shard first, then its siblings (see [`Scheduler::admit`] —
    /// the per-shard policy is the same mixed one `next_batch` uses).
    /// Home-first keeps a worker mostly on its own tenants; the sibling
    /// sweep keeps freed slots from idling while other shards queue.
    pub fn admit(&self, home: usize, now: Instant, free_slots: usize) -> Vec<Request> {
        let n = self.shards.len();
        let home = home % n;
        let mut out = Vec::new();
        if free_slots == 0 || self.pending.load(Ordering::SeqCst) == 0 {
            return out;
        }
        for k in 0..n {
            if out.len() >= free_slots {
                break;
            }
            let mut shard = lock_recover(&self.shards[(home + k) % n]);
            let got = shard.admit(now, free_slots - out.len());
            let shed = shard.take_shed();
            drop(shard);
            if shed > 0 {
                self.pending.fetch_sub(shed, Ordering::SeqCst);
            }
            if !got.is_empty() {
                self.pending.fetch_sub(got.len(), Ordering::SeqCst);
                out.extend(got);
            }
        }
        out
    }

    /// Same-tenant step-level admission for a fallback *uniform*
    /// session (see [`Scheduler::admit_for`]).  Only the tenant's home
    /// shard is consulted: its queue is the only place `current`'s
    /// requests live, and the aged-elsewhere pause deliberately scopes
    /// to that shard's tenants (siblings are drained by their own
    /// workers / the steal path).
    pub fn admit_for(
        &self,
        current: &Option<String>,
        now: Instant,
        free_slots: usize,
    ) -> Vec<Request> {
        if free_slots == 0 || self.pending.load(Ordering::SeqCst) == 0 {
            return Vec::new();
        }
        let shard_idx = shard_of(current, self.shards.len());
        let mut shard = lock_recover(&self.shards[shard_idx]);
        let got = shard.admit_for(current, now, free_slots);
        let shed = shard.take_shed();
        drop(shard);
        if shed > 0 {
            self.pending.fetch_sub(shed, Ordering::SeqCst);
        }
        if !got.is_empty() {
            self.pending.fetch_sub(got.len(), Ordering::SeqCst);
        }
        got
    }

    /// Tighten every shard's `max_batch` to the artifact batch (see
    /// [`Scheduler::clamp_max_batch`]).  Workers call this during setup,
    /// before the go-live barrier, so no dispatch ever sees the
    /// unclamped value.
    pub fn clamp_max_batch(&self, cap: usize) {
        for shard in &self.shards {
            lock_recover(shard).clamp_max_batch(cap);
        }
    }

    /// Aggregate scheduler counters across shards (see
    /// [`SchedulerMetrics::merge`]).
    pub fn metrics(&self) -> SchedulerMetrics {
        let mut out = SchedulerMetrics::default();
        for shard in &self.shards {
            out.merge(&lock_recover(shard).metrics());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(
        id: Option<&str>,
        prompt: &str,
        age: Duration,
    ) -> (Request, std::sync::mpsc::Receiver<Result<String>>) {
        let (tx, rx) = channel();
        let mut r = Request::new(id.map(|s| s.to_string()), prompt.to_string(), tx);
        r.enqueued = Instant::now().checked_sub(age).unwrap_or_else(Instant::now);
        (r, rx)
    }

    fn opts(max_batch: usize, aging_ms: u64) -> SchedulerOpts {
        SchedulerOpts {
            max_batch,
            aging: Duration::from_millis(aging_ms),
            ..Default::default()
        }
    }

    #[test]
    fn mixed_batch_interleaves_tenants_and_keeps_fifo_order() {
        let mut s = Scheduler::new(opts(8, 50));
        let mut keep = Vec::new();
        for (id, p) in [("a", "a0"), ("b", "b0"), ("a", "a1"), ("b", "b1"), ("a", "a2")] {
            let (r, rx) = req(Some(id), p, Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        assert_eq!(s.pending(), 5);
        // one mixed batch takes everything: fullest queue first, ties
        // broken by the older head, FIFO within each tenant
        let batch = s.next_batch(Instant::now()).unwrap();
        let prompts: Vec<&str> = batch.iter().map(|r| r.prompt.as_str()).collect();
        assert_eq!(prompts, vec!["a0", "b0", "a1", "b1", "a2"]);
        assert!(s.next_batch(Instant::now()).is_none());
        assert!(s.is_empty());
        let m = s.metrics();
        assert_eq!(m.batches, 1);
        assert_eq!(m.mixed_batches, 1, "two tenants in one batch is mixed");
        assert_eq!(s.obs.distinct_tenants.count(), 1, "one observation per batch");
        assert!((s.obs.distinct_tenants.sum() - 2.0).abs() < 1e-9, "two distinct tenants");
    }

    #[test]
    fn respects_max_batch() {
        let mut s = Scheduler::new(opts(2, 50));
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, rx) = req(Some("a"), &format!("p{i}"), Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        let sizes: Vec<usize> = std::iter::from_fn(|| s.next_batch(Instant::now()))
            .map(|b| b.len())
            .collect();
        assert_eq!(sizes, vec![2, 2, 1]);
        let m = s.metrics();
        assert_eq!(m.batches, 3);
        assert_eq!(m.scheduled, 5);
        assert_eq!(m.max_queue_depth, 5);
        assert!((m.avg_fill() - (1.0 + 1.0 + 0.5) / 3.0).abs() < 1e-9);
        assert_eq!(m.mixed_batches, 0, "single-tenant batches are not mixed");
    }

    #[test]
    fn aged_request_is_admitted_first_not_starved() {
        let mut s = Scheduler::new(opts(8, 50));
        let mut keep = Vec::new();
        // hot tenant: a full, fresh batch's worth plus one
        for i in 0..9 {
            let (r, rx) = req(Some("hot"), &format!("h{i}"), Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        // cold tenant: one request that has waited 10x the aging window
        let (r, rx) = req(Some("cold"), "c0", Duration::from_millis(500));
        s.push(r);
        keep.push(rx);
        let batch = s.next_batch(Instant::now()).unwrap();
        // the aged request leads the batch and the hot tenant fills the
        // remaining slots — no batch-switch, no hold, no starvation
        assert_eq!(batch[0].prompt, "c0", "aged request must go first");
        assert_eq!(batch.len(), 8);
        assert!(batch[1..].iter().all(|r| r.adapter_id.as_deref() == Some("hot")));
        let m = s.metrics();
        assert_eq!(m.aged_batches, 1, "aging promoted past a fuller queue");
        assert_eq!(m.mixed_batches, 1);
        let batch2 = s.next_batch(Instant::now()).unwrap();
        assert_eq!(batch2.len(), 2, "leftover hot requests drain next");
    }

    #[test]
    fn prefers_fuller_queue_at_equal_age() {
        let mut s = Scheduler::new(opts(8, 50));
        let mut keep = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(Some("big"), &format!("b{i}"), Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        let (r, rx) = req(Some("small"), "s0", Duration::ZERO);
        s.push(r);
        keep.push(rx);
        let batch = s.next_batch(Instant::now()).unwrap();
        // the fuller queue leads; the straggler still rides along in the
        // same mixed batch (slots are free) — but never ahead of "big"
        let prompts: Vec<&str> = batch.iter().map(|r| r.prompt.as_str()).collect();
        assert_eq!(prompts, vec!["b0", "b1", "b2", "b3", "s0"]);
        assert_eq!(s.metrics().aged_batches, 0);
    }

    #[test]
    fn admit_refills_fifo_and_counts_separately() {
        let mut s = Scheduler::new(opts(8, 50));
        let mut keep = Vec::new();
        for p in ["a0", "a1", "a2"] {
            let (r, rx) = req(Some("a"), p, Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        // zero free slots admits nothing
        assert!(s.admit(Instant::now(), 0).is_empty());
        let got = s.admit(Instant::now(), 2);
        let prompts: Vec<&str> = got.iter().map(|r| r.prompt.as_str()).collect();
        assert_eq!(prompts, vec!["a0", "a1"]);
        assert_eq!(s.pending(), 1);
        // draining the queue removes it
        let got = s.admit(Instant::now(), 4);
        assert_eq!(got.len(), 1);
        assert!(s.is_empty());
        assert!(s.admit(Instant::now(), 4).is_empty());
        let m = s.metrics();
        assert_eq!(m.admitted, 3);
        assert_eq!(m.scheduled, 3);
        assert_eq!(m.batches, 0, "admit must not count as a new batch");
        assert_eq!(s.obs.distinct_tenants.count(), 0, "histogram counts batches only");
    }

    #[test]
    fn admit_crosses_tenants_and_takes_aged_requests_first() {
        let mut s = Scheduler::new(opts(8, 50));
        let mut keep = Vec::new();
        // a running batch's freed slot takes whatever tenant is waiting —
        // cross-tenant admission is the point of the gathered path
        let (r, rx) = req(Some("other"), "o0", Duration::ZERO);
        s.push(r);
        keep.push(rx);
        let got = s.admit(Instant::now(), 8);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].prompt, "o0", "any tenant fills a free slot");
        // an aged request is admitted ahead of a fuller fresh queue —
        // straight into the running batch, with no hold
        for p in ["a0", "a1"] {
            let (r, rx) = req(Some("a"), p, Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        let (r, rx) = req(Some("cold"), "c0", Duration::from_millis(500));
        s.push(r);
        keep.push(rx);
        let got = s.admit(Instant::now(), 8);
        let prompts: Vec<&str> = got.iter().map(|r| r.prompt.as_str()).collect();
        assert_eq!(prompts, vec!["c0", "a0", "a1"]);
        assert!(s.is_empty());
    }

    #[test]
    fn admit_for_stays_on_tenant_and_pauses_for_aged_siblings() {
        let mut s = Scheduler::new(opts(8, 50));
        let mut keep = Vec::new();
        for p in ["a0", "a1"] {
            let (r, rx) = req(Some("a"), p, Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        let (r, rx) = req(Some("b"), "b0", Duration::ZERO);
        s.push(r);
        keep.push(rx);
        // a uniform session on tenant "a" only ever refills from "a"
        let got = s.admit_for(&Some("a".into()), Instant::now(), 8);
        let prompts: Vec<&str> = got.iter().map(|r| r.prompt.as_str()).collect();
        assert_eq!(prompts, vec!["a0", "a1"], "same-tenant FIFO only");
        assert_eq!(s.pending(), 1, "the other tenant stays queued");
        // once another tenant's head has aged past the bound, admission
        // pauses even though the session's own tenant has work waiting
        let (r, rx) = req(Some("a"), "a2", Duration::ZERO);
        s.push(r);
        keep.push(rx);
        let (r, rx) = req(Some("c"), "c0", Duration::from_millis(500));
        s.push(r);
        keep.push(rx);
        assert!(
            s.admit_for(&Some("a".into()), Instant::now(), 8).is_empty(),
            "aged sibling pauses uniform refill"
        );
        assert_eq!(s.pending(), 3);
    }

    #[test]
    fn sharded_affinity_is_stable_and_push_routes_to_home_shard() {
        let s = ShardedScheduler::new(4, opts(8, 50));
        assert_eq!(s.shards(), 4);
        let a = Some("tenant-a".to_string());
        let home = s.shard_of(&a);
        assert_eq!(home, s.shard_of(&a), "assignment must be deterministic");
        let (r, _k) = req(Some("tenant-a"), "p0", Duration::ZERO);
        s.push(r);
        assert_eq!(s.pending(), 1);
        // the home worker pops it without stealing
        let (batch, stolen) = s.next_work(home, Instant::now()).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].adapter_id, a);
        assert!(!stolen);
        assert_eq!(s.steals(), 0);
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn idle_worker_steals_from_sibling_shard() {
        let s = ShardedScheduler::new(4, opts(8, 50));
        let a = Some("tenant-a".to_string());
        let home = s.shard_of(&a);
        let thief = (home + 1) % s.shards();
        let mut keep = Vec::new();
        for p in ["p0", "p1"] {
            let (r, k) = req(Some("tenant-a"), p, Duration::ZERO);
            s.push(r);
            keep.push(k);
        }
        // a non-home worker finds the batch by scanning past its own shard
        let (batch, stolen) = s.next_work(thief, Instant::now()).unwrap();
        assert_eq!(batch.len(), 2, "steals take the whole batch");
        assert!(batch.iter().all(|r| r.adapter_id == a));
        assert!(stolen);
        assert_eq!(s.steals(), 1);
    }

    #[test]
    fn sharded_admit_scans_home_shard_first_then_siblings() {
        let s = ShardedScheduler::new(2, opts(8, 50));
        let a = Some("tenant-a".to_string());
        let home = s.shard_of(&a);
        let mut keep = Vec::new();
        for p in ["a0", "a1"] {
            let (r, k) = req(Some("tenant-a"), p, Duration::ZERO);
            s.push(r);
            keep.push(k);
        }
        // a tenant whose queue lives on the OTHER shard
        let other = (0..1000)
            .map(|i| format!("other{i}"))
            .find(|c| shard_of(&Some(c.clone()), 2) != home)
            .expect("some id lands on the other shard");
        let (r, k) = req(Some(other.as_str()), "o0", Duration::ZERO);
        s.push(r);
        keep.push(k);
        assert_eq!(s.pending(), 3);
        // one free slot: the home shard's head wins
        let got = s.admit(home, Instant::now(), 1);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].prompt, "a0");
        // plenty of slots: home drains first, then the sibling shard's
        // tenant tops the batch up — cross-shard, cross-tenant admission
        let got = s.admit(home, Instant::now(), 8);
        let prompts: Vec<&str> = got.iter().map(|r| r.prompt.as_str()).collect();
        assert_eq!(prompts, vec!["a1", "o0"]);
        assert_eq!(s.pending(), 0);
        assert!(s.admit(home, Instant::now(), 8).is_empty());
    }

    #[test]
    fn concurrent_push_and_pop_drains_every_request_exactly_once() {
        // fairness under concurrent admission: producers push interleaved
        // tenants (one pre-aged, low-traffic) while consumer threads pop;
        // every request must be served exactly once and the aged tenant
        // must not starve behind the hot ones.
        let workers = 4usize;
        let per_tenant = 25usize;
        let s = std::sync::Arc::new(ShardedScheduler::new(workers, opts(4, 10)));
        let served = std::sync::Arc::new(Mutex::new(Vec::<String>::new()));
        std::thread::scope(|scope| {
            for w in 0..workers {
                let s = s.clone();
                let served = served.clone();
                scope.spawn(move || {
                    while let Some((batch, _)) = s.next_work(w, Instant::now()) {
                        let mut got = served.lock().unwrap();
                        for r in batch {
                            got.push(r.prompt.clone());
                            // replies are dropped; senders ignore the error
                            let _ = r.reply.send(Ok(String::new()));
                        }
                    }
                });
            }
            let mut keep = Vec::new();
            for i in 0..per_tenant {
                for t in ["hot-a", "hot-b", "hot-c"] {
                    let (r, k) = req(Some(t), &format!("{t}/{i}"), Duration::ZERO);
                    s.push(r);
                    keep.push(k);
                }
                if i % 8 == 0 {
                    let (r, k) =
                        req(Some("cold"), &format!("cold/{i}"), Duration::from_millis(100));
                    s.push(r);
                    keep.push(k);
                }
            }
            s.close();
            drop(keep);
        });
        let got = served.lock().unwrap();
        let total = per_tenant * 3 + per_tenant.div_ceil(8);
        assert_eq!(got.len(), total, "every request served exactly once");
        let mut sorted = got.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), total, "a request was dispatched twice");
        assert!(got.iter().any(|p| p.starts_with("cold/")), "cold tenant starved");
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn sharded_metrics_aggregate_across_shards() {
        let s = ShardedScheduler::new(3, opts(2, 50));
        let mut keep = Vec::new();
        for t in ["a", "b", "c", "d", "e"] {
            for i in 0..2 {
                let (r, k) = req(Some(t), &format!("{t}{i}"), Duration::ZERO);
                s.push(r);
                keep.push(k);
            }
        }
        // close before draining so next_work never blocks
        s.close();
        let mut batches = 0;
        while s.next_work(0, Instant::now()).is_some() {
            batches += 1;
        }
        let m = s.metrics();
        assert_eq!(m.batches, batches);
        assert_eq!(m.scheduled, 10);
        assert!(m.avg_fill() > 0.0);
    }

    #[test]
    fn bound_scheduler_reports_through_registry() {
        // after bind_obs, metrics() and the registry snapshot read the
        // same atomics — the counters must agree exactly
        let reg = Registry::new();
        let mut s = ShardedScheduler::new(2, opts(2, 50));
        s.bind_obs(&reg);
        let mut keep = Vec::new();
        for t in ["a", "b", "c"] {
            for i in 0..2 {
                let (r, k) = req(Some(t), &format!("{t}{i}"), Duration::ZERO);
                s.push(r);
                keep.push(k);
            }
        }
        s.close();
        while s.next_work(1, Instant::now()).is_some() {}
        let m = s.metrics();
        assert_eq!(m.scheduled, 6);
        let snap = reg.snapshot();
        assert_eq!(snap.sum("sched_batches_total") as usize, m.batches);
        assert_eq!(snap.sum("sched_scheduled_total") as usize, m.scheduled);
        assert_eq!(snap.gauge_peak_max("sched_queue_depth") as usize, m.max_queue_depth);
        assert_eq!(snap.sum("sched_steals_total") as usize, s.steals());
        assert_eq!(snap.sum("sched_mixed_batches_total") as usize, m.mixed_batches);
        // the distinct-tenants histogram sees exactly one observation per
        // dispatched batch, across every shard
        let hist_count: u64 = snap
            .samples
            .iter()
            .filter(|sm| sm.name == "sched_batch_distinct_tenants")
            .map(|sm| match &sm.value {
                crate::obs::Value::Histogram { count, .. } => *count,
                _ => panic!("expected a histogram"),
            })
            .sum();
        assert_eq!(hist_count as usize, m.batches);
    }

    #[test]
    fn merged_path_mixes_with_adapted_tenants() {
        // the no-adapter queue rides on the identity bank slot, so it
        // batches together with adapted tenants like any other queue
        let mut s = Scheduler::new(opts(4, 50));
        let (r1, _k1) = req(None, "m0", Duration::ZERO);
        let (r2, _k2) = req(Some("a"), "a0", Duration::ZERO);
        let (r3, _k3) = req(None, "m1", Duration::ZERO);
        s.push(r1);
        s.push(r2);
        s.push(r3);
        let batch = s.next_batch(Instant::now()).unwrap();
        let prompts: Vec<&str> = batch.iter().map(|r| r.prompt.as_str()).collect();
        assert_eq!(prompts, vec!["m0", "a0", "m1"]);
        assert_eq!(s.metrics().mixed_batches, 1);
    }

    fn kind_of(rx: &std::sync::mpsc::Receiver<Result<String>>) -> &'static str {
        match rx.try_recv().expect("a reply must be waiting") {
            Ok(_) => "ok",
            Err(e) => ServeError::of(&e).map(|s| s.kind()).unwrap_or("untyped"),
        }
    }

    #[test]
    fn queue_cap_rejects_with_typed_overloaded() {
        let mut s = Scheduler::new(SchedulerOpts {
            queue_cap: Some(2),
            ..opts(8, 50)
        });
        let mut keep = Vec::new();
        for p in ["p0", "p1"] {
            let (r, k) = req(Some("a"), p, Duration::ZERO);
            assert!(s.push(r));
            keep.push(k);
        }
        let (r, rx) = req(Some("a"), "p2", Duration::ZERO);
        assert!(!s.push(r), "push past the cap must be refused");
        match ServeError::of(&rx.try_recv().unwrap().unwrap_err()) {
            Some(ServeError::Overloaded { queue_cap }) => assert_eq!(*queue_cap, 2),
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(s.pending(), 2);
        assert_eq!(s.metrics().shed, 1);
        assert_eq!(s.metrics().deadline_expired, 0);
        // draining frees capacity: the next push is accepted again
        let _ = s.next_batch(Instant::now());
        let (r, k) = req(Some("a"), "p3", Duration::ZERO);
        assert!(s.push(r));
        keep.push(k);
    }

    #[test]
    fn expired_push_is_shed_with_deadline_exceeded() {
        let mut s = Scheduler::new(SchedulerOpts {
            deadline: Some(Duration::from_millis(20)),
            ..opts(8, 50)
        });
        // enqueued 100ms ago with a 20ms default deadline: dead on arrival
        let (r, rx) = req(Some("a"), "late", Duration::from_millis(100));
        assert!(!s.push(r));
        match ServeError::of(&rx.try_recv().unwrap().unwrap_err()) {
            Some(ServeError::DeadlineExceeded { waited_ms }) => assert!(*waited_ms >= 20),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(s.pending(), 0);
        assert_eq!(s.metrics().deadline_expired, 1);
        assert_eq!(s.metrics().shed, 1);
    }

    #[test]
    fn queued_requests_are_swept_when_their_deadline_passes() {
        let mut s = Scheduler::new(opts(8, 50));
        // explicit per-request deadline in the near future
        let (mut r, rx) = req(Some("a"), "doomed", Duration::ZERO);
        r.deadline = Some(Instant::now() - Duration::from_millis(1));
        // not expired relative to a clock just before the deadline
        let before = r.deadline.unwrap() - Duration::from_millis(5);
        assert!(!r.expired(before));
        // bypass push's entry check by backdating after enqueue: stage it
        // unexpired, then sweep with a later clock
        r.deadline = Some(Instant::now() + Duration::from_millis(5));
        assert!(s.push(r));
        let (r2, k2) = req(Some("a"), "fine", Duration::ZERO);
        assert!(s.push(r2));
        assert_eq!(s.pending(), 2);
        // dispatch with a clock past the deadline: the doomed request is
        // shed before batching, the undeadlined one is served
        let later = Instant::now() + Duration::from_millis(50);
        let batch = s.next_batch(later).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].prompt, "fine");
        assert_eq!(kind_of(&rx), "deadline_exceeded");
        assert_eq!(s.metrics().deadline_expired, 1);
        drop(k2);
    }

    #[test]
    fn requeue_goes_to_the_front_and_bypasses_the_cap() {
        let mut s = Scheduler::new(SchedulerOpts {
            queue_cap: Some(2),
            ..opts(8, 50)
        });
        let (r0, _k0) = req(Some("a"), "first", Duration::ZERO);
        let (r1, _k1) = req(Some("a"), "second", Duration::ZERO);
        assert!(s.push(r0));
        assert!(s.push(r1));
        // queue is at cap, but a crash-recovered request is re-admitted
        // anyway, ahead of the line
        let (mut rq, _kq) = req(Some("a"), "survivor", Duration::ZERO);
        rq.attempts = 1;
        assert!(s.requeue(rq));
        assert_eq!(s.pending(), 3);
        let batch = s.next_batch(Instant::now()).unwrap();
        assert_eq!(batch[0].prompt, "survivor");
        assert_eq!(batch[0].attempts, 1);
        assert_eq!(batch[1].prompt, "first");
    }

    #[test]
    fn sharded_pending_stays_consistent_through_sheds() {
        // a deadline shed inside a shard must also shrink the cross-shard
        // pending atomic, or idle workers spin forever on phantom work
        let s = ShardedScheduler::new(
            2,
            SchedulerOpts { deadline: Some(Duration::from_millis(10)), ..opts(8, 50) },
        );
        let (r, rx) = req(Some("a"), "doomed", Duration::ZERO);
        assert!(s.push(r));
        assert_eq!(s.pending(), 1);
        // past the deadline: the scan sheds it and returns no batch
        let later = Instant::now() + Duration::from_millis(100);
        s.close();
        assert!(s.next_work(0, later).is_none());
        assert_eq!(s.pending(), 0, "shed must be folded out of pending");
        assert_eq!(kind_of(&rx), "deadline_exceeded");
    }

    #[test]
    fn sharded_requeue_wakes_a_worker_and_serves_front() {
        let s = ShardedScheduler::new(2, opts(8, 50));
        let (r, _k) = req(Some("a"), "back", Duration::ZERO);
        assert!(s.push(r));
        let (mut rq, _kq) = req(Some("a"), "recovered", Duration::ZERO);
        rq.attempts = 2;
        assert!(s.requeue(rq));
        assert_eq!(s.pending(), 2);
        let (batch, _) = s.next_work(0, Instant::now()).unwrap();
        assert_eq!(batch[0].prompt, "recovered");
    }

    #[test]
    fn cancel_handle_drop_marks_cancelled_and_disarm_does_not() {
        let (mut r, _k) = req(Some("a"), "p", Duration::ZERO);
        assert!(!r.is_cancelled(), "no handle → never cancelled");
        let h = r.cancel_handle();
        assert!(!r.is_cancelled());
        drop(h);
        assert!(r.is_cancelled(), "dropping the handle cancels");

        let (mut r2, _k2) = req(Some("a"), "q", Duration::ZERO);
        let h2 = r2.cancel_handle();
        h2.disarm();
        assert!(!r2.is_cancelled(), "disarm consumes without cancelling");

        let (mut r3, _k3) = req(Some("a"), "s", Duration::ZERO);
        let h3 = r3.cancel_handle();
        h3.cancel();
        assert!(r3.is_cancelled());
    }
}
