//! Adapter-aware batch scheduler — replaces the FIFO coalescing loop.
//!
//! Adapters are per-forward host inputs, so one forward pass can serve only
//! requests that share an adapter.  The scheduler keeps a FIFO queue per
//! adapter id and, each dispatch, picks the queue with the best
//! `fill + wait/aging` score:
//!
//!   - `fill` (0..=1) favors full batches — maximum device utilization;
//!   - `wait/aging` grows without bound for a waiting queue, so a
//!     low-traffic tenant whose oldest request has waited longer than
//!     `aging` outranks even a completely full queue from a hot tenant
//!     (no starvation).
//!
//! Two dispatch granularities share those queues:
//!
//!   - [`Scheduler::next_batch`] starts a batch: it picks the winning
//!     tenant under the fill+aging score and hands over up to `max_batch`
//!     of its requests;
//!   - [`Scheduler::admit`] runs *between decode forwards* of an already
//!     running batch: it tops freed slots up with more requests from the
//!     **same** tenant (one forward serves one adapter, so cross-tenant
//!     admission is impossible), unless another tenant's oldest request
//!     has aged out — then admission is held so the running batch drains
//!     and `next_batch` can hand the device over (no starvation, same
//!     aging bound as before).
//!
//! The scheduler is pure bookkeeping (no runtime handles), so the policy is
//! unit-testable without artifacts; `now` is passed in rather than sampled.

use super::error::ServeError;
use crate::obs::{Counter, FloatCounter, Gauge, Registry};
use crate::util::sync::{get_mut_recover, lock_recover, wait_timeout_recover};
use anyhow::Result;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

/// One inference request: a prompt routed to a registered adapter
/// (`adapter_id: None` selects the merged / no-adapter fast path).
pub struct Request {
    /// Process-unique id stamped at construction; keys the trace spans
    /// (enqueue → dispatch → admit → first token → retire) in the JSONL
    /// event log so per-request phases can be joined across threads.
    pub id: u64,
    pub adapter_id: Option<String>,
    pub prompt: String,
    pub reply: Sender<Result<String>>,
    pub enqueued: Instant,
    /// Per-request cap on generated tokens (`None` = the engine default).
    /// Clamped to the engine's `max_new_tokens` at admission.
    pub max_new_tokens: Option<usize>,
    /// Per-request floor on generated tokens: the stop token is masked out
    /// of the argmax until this many tokens exist (0 = stop immediately
    /// allowed — the default).  Length control for benchmarking and for
    /// clients that want a minimum completion length.
    pub min_new_tokens: usize,
    /// Absolute deadline: the request is shed with
    /// [`ServeError::DeadlineExceeded`] if it is still queued past this
    /// instant (`None` = no deadline; the scheduler stamps its configured
    /// default at enqueue, see [`SchedulerOpts::deadline`]).
    pub deadline: Option<Instant>,
    /// Re-admissions consumed so far (session failures / worker crashes
    /// re-admit a request until this exceeds the scheduler's
    /// `max_retries`, after which it fails with
    /// [`ServeError::EngineFailure`]).
    pub attempts: usize,
    /// client-side cancellation flag, shared with a [`CancelHandle`]
    cancelled: Option<Arc<AtomicBool>>,
}

impl Request {
    /// A request with default decode limits (engine cap, no floor).
    pub fn new(
        adapter_id: Option<String>,
        prompt: String,
        reply: Sender<Result<String>>,
    ) -> Request {
        Request {
            id: NEXT_REQUEST_ID.fetch_add(1, Ordering::Relaxed),
            adapter_id,
            prompt,
            reply,
            enqueued: Instant::now(),
            max_new_tokens: None,
            min_new_tokens: 0,
            deadline: None,
            attempts: 0,
            cancelled: None,
        }
    }

    /// Attach a cancellation handle: if the handle drops (or its
    /// [`CancelHandle::cancel`] is called) while the request is in a
    /// decode slot, the slot is retired early and the request counts as
    /// `serve_cancelled_total` — the dropped-client path.  Requests
    /// without a handle are only detected as cancelled when the final
    /// reply send finds the channel closed.
    pub fn cancel_handle(&mut self) -> CancelHandle {
        let flag = Arc::new(AtomicBool::new(false));
        self.cancelled = Some(flag.clone());
        CancelHandle { flag: Some(flag) }
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.as_ref().map(|f| f.load(Ordering::Relaxed)).unwrap_or(false)
    }

    /// True iff the request carries a deadline that has passed.
    pub fn expired(&self, now: Instant) -> bool {
        self.deadline.map(|d| now >= d).unwrap_or(false)
    }
}

/// Client-held cancellation token for one [`Request`].  Dropping it —
/// which is what happens when a client goes away — marks the request
/// cancelled, so the serving loop can retire its slot early instead of
/// decoding for nobody.  Call [`CancelHandle::disarm`] on clean
/// completion to drop the handle *without* cancelling.
pub struct CancelHandle {
    flag: Option<Arc<AtomicBool>>,
}

impl CancelHandle {
    /// Cancel explicitly (same effect as dropping the handle).
    pub fn cancel(mut self) {
        if let Some(f) = self.flag.take() {
            f.store(true, Ordering::Relaxed);
        }
    }

    /// Consume the handle without cancelling (the request completed).
    pub fn disarm(mut self) {
        self.flag = None;
    }
}

impl Drop for CancelHandle {
    fn drop(&mut self) {
        if let Some(f) = self.flag.take() {
            f.store(true, Ordering::Relaxed);
        }
    }
}

/// Scheduling policy knobs.
#[derive(Clone, Debug)]
pub struct SchedulerOpts {
    /// Upper bound on requests per dispatched batch (clamped to the
    /// artifact batch by the router).
    pub max_batch: usize,
    /// A queue whose oldest request has waited this long outranks a full
    /// batch from another tenant.
    pub aging: Duration,
    /// Pending-request bound per scheduler (per *shard* in the pool):
    /// pushes beyond it are rejected with [`ServeError::Overloaded`]
    /// instead of growing the queue without limit (`None` = unbounded).
    pub queue_cap: Option<usize>,
    /// Default deadline stamped at enqueue onto requests that carry none,
    /// measured from the request's `enqueued` instant (`None` = no
    /// deadline).  Expired requests are shed with
    /// [`ServeError::DeadlineExceeded`] rather than dispatched.
    pub deadline: Option<Duration>,
    /// Per-request re-admission budget: how many times a request may be
    /// put back on the queue after a persistent session failure or a
    /// worker crash before it fails with [`ServeError::EngineFailure`].
    /// Also bounds the in-session decode-step retries.
    pub max_retries: usize,
}

impl Default for SchedulerOpts {
    fn default() -> Self {
        SchedulerOpts {
            max_batch: 8,
            aging: Duration::from_millis(50),
            queue_cap: None,
            deadline: None,
            max_retries: 2,
        }
    }
}

/// Queue-depth and batch-fill counters (reported with `ServeStats`).
#[derive(Clone, Debug, Default)]
pub struct SchedulerMetrics {
    /// batches dispatched
    pub batches: usize,
    /// requests dispatched across all batches
    pub scheduled: usize,
    /// sum of per-batch fill ratios (len / max_batch)
    pub fill_sum: f64,
    /// highest total pending count observed across all queues
    pub max_queue_depth: usize,
    /// batches where the aging term overrode the fill preference
    pub aged_batches: usize,
    /// requests admitted into an already-running batch (freed slots
    /// re-filled between forwards, the continuous-batching win)
    pub admitted: usize,
    /// admissions refused because another tenant's oldest request aged
    /// out (the running batch drains so the device can switch tenants)
    pub aging_holds: usize,
    /// requests refused or dropped before dispatch: overload rejections
    /// plus deadline sheds (`shed == overloaded + deadline_expired`)
    pub shed: usize,
    /// the deadline-shed subset of `shed`
    pub deadline_expired: usize,
}

impl SchedulerMetrics {
    pub fn avg_fill(&self) -> f64 {
        if self.batches == 0 { 0.0 } else { self.fill_sum / self.batches as f64 }
    }

    /// Build the metrics view from the scheduler's live instruments.
    /// `SchedulerMetrics` is a *snapshot*, not the source of truth — the
    /// counters live in [`SchedInstruments`] (shared with the obs
    /// registry when bound), so the end-of-run table and `--metrics-out`
    /// exposition read the same atomics.
    fn from_instruments(obs: &SchedInstruments) -> SchedulerMetrics {
        SchedulerMetrics {
            batches: obs.batches.get() as usize,
            scheduled: obs.scheduled.get() as usize,
            fill_sum: obs.fill_sum.get(),
            max_queue_depth: obs.queue_depth.peak() as usize,
            aged_batches: obs.aged_batches.get() as usize,
            admitted: obs.admitted.get() as usize,
            aging_holds: obs.aging_holds.get() as usize,
            shed: (obs.shed_overload.get() + obs.shed_deadline.get()) as usize,
            deadline_expired: obs.deadline_exceeded.get() as usize,
        }
    }

    /// Fold another scheduler's counters into this one (used to aggregate
    /// per-shard metrics into the pool-wide report).  Counters sum;
    /// `max_queue_depth` takes the max — i.e. the deepest any single
    /// shard got, a lower bound on the instantaneous global peak.
    pub fn merge(&mut self, other: &SchedulerMetrics) {
        self.batches += other.batches;
        self.scheduled += other.scheduled;
        self.fill_sum += other.fill_sum;
        self.max_queue_depth = self.max_queue_depth.max(other.max_queue_depth);
        self.aged_batches += other.aged_batches;
        self.admitted += other.admitted;
        self.aging_holds += other.aging_holds;
        self.shed += other.shed;
        self.deadline_expired += other.deadline_expired;
    }
}

/// The scheduler's counters as shared atomic instruments.  Standalone
/// `Arc`s by default (unit tests, no registry); [`Scheduler::bind_obs`]
/// swaps in registry-owned instruments under the `sched_*` metric names,
/// after which the registry snapshot and [`Scheduler::metrics`] read the
/// same storage — one instrument, many views.
struct SchedInstruments {
    batches: Arc<Counter>,
    scheduled: Arc<Counter>,
    fill_sum: Arc<FloatCounter>,
    /// live queue depth; its peak watermark is `max_queue_depth`
    queue_depth: Arc<Gauge>,
    aged_batches: Arc<Counter>,
    admitted: Arc<Counter>,
    aging_holds: Arc<Counter>,
    /// overload rejections at push (`serve_shed_total{reason=overload}`)
    shed_overload: Arc<Counter>,
    /// deadline sheds (`serve_shed_total{reason=deadline}`)
    shed_deadline: Arc<Counter>,
    /// same increments as `shed_deadline`, under the metric name the
    /// aging/deadline dashboards key on (`serve_deadline_exceeded_total`)
    deadline_exceeded: Arc<Counter>,
}

impl SchedInstruments {
    fn standalone() -> SchedInstruments {
        SchedInstruments {
            batches: Arc::new(Counter::new()),
            scheduled: Arc::new(Counter::new()),
            fill_sum: Arc::new(FloatCounter::new()),
            queue_depth: Arc::new(Gauge::new()),
            aged_batches: Arc::new(Counter::new()),
            admitted: Arc::new(Counter::new()),
            aging_holds: Arc::new(Counter::new()),
            shed_overload: Arc::new(Counter::new()),
            shed_deadline: Arc::new(Counter::new()),
            deadline_exceeded: Arc::new(Counter::new()),
        }
    }

    fn registered(reg: &Registry, shard: usize) -> SchedInstruments {
        let shard = shard.to_string();
        let labels = [("shard", shard.as_str())];
        SchedInstruments {
            batches: reg.counter("sched_batches_total", &labels),
            scheduled: reg.counter("sched_scheduled_total", &labels),
            fill_sum: reg.float_counter("sched_fill_sum", &labels),
            queue_depth: reg.gauge("sched_queue_depth", &labels),
            aged_batches: reg.counter("sched_aged_batches_total", &labels),
            admitted: reg.counter("sched_admitted_total", &labels),
            aging_holds: reg.counter("sched_aging_holds_total", &labels),
            shed_overload: reg.counter(
                "serve_shed_total",
                &[("reason", "overload"), ("shard", shard.as_str())],
            ),
            shed_deadline: reg.counter(
                "serve_shed_total",
                &[("reason", "deadline"), ("shard", shard.as_str())],
            ),
            deadline_exceeded: reg.counter("serve_deadline_exceeded_total", &labels),
        }
    }
}

/// Per-adapter FIFO queues + the dispatch policy.
pub struct Scheduler {
    opts: SchedulerOpts,
    queues: BTreeMap<Option<String>, VecDeque<Request>>,
    pending: usize,
    obs: SchedInstruments,
    /// an aging hold is in effect (dedupes `aging_holds`: the router polls
    /// `admit` after every forward, but one sustained hold is one event)
    holding: bool,
    /// queued requests carrying a deadline — the expired-sweep runs only
    /// while this is nonzero, so deadline-free workloads pay nothing
    deadlined: usize,
    /// requests shed (removed from the queues) since the last
    /// [`Scheduler::take_shed`] — the sharded front-end reads this to keep
    /// its cross-shard pending atomic in step
    recent_shed: usize,
}

impl Scheduler {
    pub fn new(opts: SchedulerOpts) -> Scheduler {
        let opts = SchedulerOpts { max_batch: opts.max_batch.max(1), ..opts };
        Scheduler {
            opts,
            queues: BTreeMap::new(),
            pending: 0,
            obs: SchedInstruments::standalone(),
            holding: false,
            deadlined: 0,
            recent_shed: 0,
        }
    }

    /// Re-home the counters into `reg` (labelled `shard=<shard>`).  Call
    /// before any traffic: binding replaces the instruments, so counts
    /// recorded earlier stay behind in the standalone atomics.
    pub fn bind_obs(&mut self, reg: &Registry, shard: usize) {
        self.obs = SchedInstruments::registered(reg, shard);
    }

    /// Enqueue one request, stamping the configured default deadline onto
    /// requests that carry none.  Returns false — with the reply already
    /// sent — when the request is refused instead: immediately shed with
    /// [`ServeError::DeadlineExceeded`] if its deadline has already
    /// passed, or rejected with [`ServeError::Overloaded`] when the queue
    /// is at `queue_cap` (backpressure instead of unbounded growth).
    pub fn push(&mut self, mut req: Request) -> bool {
        if req.deadline.is_none() {
            if let Some(d) = self.opts.deadline {
                req.deadline = Some(req.enqueued + d);
            }
        }
        let now = Instant::now();
        if req.expired(now) {
            self.reply_deadline(req, now);
            return false;
        }
        if let Some(cap) = self.opts.queue_cap {
            if self.pending >= cap {
                self.obs.shed_overload.inc();
                let _ = req
                    .reply
                    .send(Err(anyhow::Error::new(ServeError::Overloaded { queue_cap: cap })));
                return false;
            }
        }
        self.enqueue(req, false);
        true
    }

    /// Put a request back on the queue after a session failure or worker
    /// crash: front of its tenant's FIFO (it has already waited its
    /// turn), bypassing the queue cap (it was admitted once — rejecting
    /// the re-admission would turn one engine fault into client-visible
    /// overload).  Its deadline still applies.  Returns false (reply
    /// sent) iff the deadline has passed.
    pub fn requeue(&mut self, req: Request) -> bool {
        let now = Instant::now();
        if req.expired(now) {
            self.reply_deadline(req, now);
            return false;
        }
        self.enqueue(req, true);
        true
    }

    fn enqueue(&mut self, req: Request, front: bool) {
        self.pending += 1;
        if req.deadline.is_some() {
            self.deadlined += 1;
        }
        self.obs.queue_depth.set(self.pending as f64);
        let q = self.queues.entry(req.adapter_id.clone()).or_default();
        if front {
            q.push_front(req);
        } else {
            q.push_back(req);
        }
    }

    /// Shed one request with `DeadlineExceeded` (reply + counters).  The
    /// caller has already removed it from the queues / kept it out.
    fn reply_deadline(&self, req: Request, now: Instant) {
        self.obs.shed_deadline.inc();
        self.obs.deadline_exceeded.inc();
        let waited = now.saturating_duration_since(req.enqueued).as_millis() as u64;
        let _ = req
            .reply
            .send(Err(anyhow::Error::new(ServeError::DeadlineExceeded { waited_ms: waited })));
    }

    /// Drop every queued request whose deadline has passed (honoring
    /// deadlines at queue time, before any decode slot is spent on them)
    /// and reply `DeadlineExceeded` to each.  Runs at the head of every
    /// dispatch decision, so expired work also stops distorting the
    /// fill+aging scores it would otherwise inflate.  No-op unless some
    /// queued request actually carries a deadline.
    fn shed_expired(&mut self, now: Instant) {
        if self.deadlined == 0 {
            return;
        }
        let mut shed: Vec<Request> = Vec::new();
        let mut emptied: Vec<Option<String>> = Vec::new();
        for (id, q) in self.queues.iter_mut() {
            if !q.iter().any(|r| r.expired(now)) {
                continue;
            }
            let mut kept = VecDeque::with_capacity(q.len());
            for req in q.drain(..) {
                if req.expired(now) {
                    shed.push(req);
                } else {
                    kept.push_back(req);
                }
            }
            *q = kept;
            if q.is_empty() {
                emptied.push(id.clone());
            }
        }
        if shed.is_empty() {
            return;
        }
        for id in emptied {
            self.queues.remove(&id);
        }
        self.pending -= shed.len();
        self.deadlined -= shed.len();
        self.recent_shed += shed.len();
        self.obs.queue_depth.set(self.pending as f64);
        for req in shed {
            self.reply_deadline(req, now);
        }
    }

    /// Requests shed out of the queues since the last call (consumed; the
    /// sharded front-end folds this into its cross-shard pending count).
    pub(crate) fn take_shed(&mut self) -> usize {
        std::mem::take(&mut self.recent_shed)
    }

    fn note_removed(&mut self, reqs: &[Request]) {
        self.deadlined -= reqs.iter().filter(|r| r.deadline.is_some()).count();
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    pub fn is_empty(&self) -> bool {
        self.pending == 0
    }

    /// Snapshot of the scheduler counters (see
    /// [`SchedulerMetrics::from_instruments`]).
    pub fn metrics(&self) -> SchedulerMetrics {
        SchedulerMetrics::from_instruments(&self.obs)
    }

    /// Tighten `max_batch` to `cap` (idempotent; never below 1).  The
    /// worker pool calls this once the artifact batch is known, so a
    /// dispatched batch can never exceed the decode slots — oversized
    /// hand-offs would sit out the aging policy in a session's private
    /// queue (the single-worker router clamps the same way up front).
    pub fn clamp_max_batch(&mut self, cap: usize) {
        self.opts.max_batch = self.opts.max_batch.min(cap).max(1);
    }

    /// Pop the next same-adapter batch under the fill+aging policy, FIFO
    /// within the chosen tenant.  None iff nothing is pending.
    pub fn next_batch(&mut self, now: Instant) -> Option<(Option<String>, Vec<Request>)> {
        self.holding = false; // a new batch starts a new hold episode
        self.shed_expired(now);
        if self.queues.is_empty() {
            return None;
        }
        let aging = self.opts.aging.as_secs_f64().max(1e-9);
        // (score, fill, wait) of the winner + the best fill seen anywhere
        let mut chosen: Option<(Option<String>, f64, f64, f64)> = None;
        let mut max_fill = 0.0f64;
        for (id, q) in &self.queues {
            let fill = q.len().min(self.opts.max_batch) as f64 / self.opts.max_batch as f64;
            let wait = q
                .front()
                .map(|r| now.saturating_duration_since(r.enqueued).as_secs_f64())
                .unwrap_or(0.0);
            let score = fill + wait / aging;
            if chosen.as_ref().map(|(_, s, _, _)| score > *s).unwrap_or(true) {
                chosen = Some((id.clone(), score, fill, wait));
            }
            max_fill = max_fill.max(fill);
        }
        let (id, _, fill, wait) = chosen?;
        // a genuine aging override: a less-full queue won because its
        // oldest request exceeded the aging bound (microsecond wait
        // differences between equally-full queues don't count)
        if fill < max_fill && wait >= aging {
            self.obs.aged_batches.inc();
        }
        let q = self.queues.get_mut(&id)?;
        let n = q.len().min(self.opts.max_batch);
        let reqs: Vec<Request> = q.drain(..n).collect();
        if q.is_empty() {
            self.queues.remove(&id);
        }
        self.pending -= reqs.len();
        self.note_removed(&reqs);
        self.obs.queue_depth.set(self.pending as f64);
        self.obs.batches.inc();
        self.obs.scheduled.add(reqs.len() as u64);
        self.obs.fill_sum.add(reqs.len() as f64 / self.opts.max_batch as f64);
        Some((id, reqs))
    }

    /// Step-level admission for a *running* batch: pop up to `free_slots`
    /// more requests from `current`'s queue (FIFO), so freed decode slots
    /// re-fill between forwards instead of idling until the batch drains.
    ///
    /// Returns an empty vec when the current tenant's queue is dry — or
    /// when another tenant's oldest request has waited past the aging
    /// bound, in which case admission is *held*: the running batch drains
    /// naturally and the next `next_batch` call hands the device to the
    /// aged tenant.  This is the same starvation bound `next_batch`
    /// enforces, applied at step granularity.
    pub fn admit(
        &mut self,
        current: &Option<String>,
        now: Instant,
        free_slots: usize,
    ) -> Vec<Request> {
        if free_slots == 0 {
            return Vec::new();
        }
        self.shed_expired(now);
        let has_current = self.queues.get(current).map(|q| !q.is_empty()).unwrap_or(false);
        if !has_current {
            return Vec::new();
        }
        let aging = self.opts.aging;
        let aged_elsewhere = self.queues.iter().any(|(id, q)| {
            id != current
                && q.front()
                    .map(|r| now.saturating_duration_since(r.enqueued) >= aging)
                    .unwrap_or(false)
        });
        if aged_elsewhere {
            // count the hold once per episode, not once per forward polled
            if !self.holding {
                self.obs.aging_holds.inc();
                self.holding = true;
            }
            return Vec::new();
        }
        self.holding = false;
        let q = self.queues.get_mut(current).expect("checked non-empty above");
        let n = q.len().min(free_slots);
        let reqs: Vec<Request> = q.drain(..n).collect();
        if q.is_empty() {
            self.queues.remove(current);
        }
        self.pending -= reqs.len();
        self.note_removed(&reqs);
        self.obs.queue_depth.set(self.pending as f64);
        self.obs.admitted.add(reqs.len() as u64);
        self.obs.scheduled.add(reqs.len() as u64);
        reqs
    }
}

/// Stable tenant → shard assignment (FNV-1a over the adapter id; the
/// merged / no-adapter queue hashes like the empty string).  Every thread
/// must agree on this mapping, so it is a pure function of the id.
fn shard_of(id: &Option<String>, shards: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    if let Some(s) = id {
        for &b in s.as_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    (h % shards as u64) as usize
}

/// Thread-safe front-end for the worker pool: one [`Scheduler`] shard per
/// worker, tenants assigned to shards by stable hash, so each worker has
/// a *home* set of tenants (keeps one tenant's traffic on one worker —
/// full batches — instead of splitting it across replicas).
///
/// Work stealing: a worker whose home shard is dry scans the other
/// shards, home-first order, and takes a whole same-tenant batch from
/// the fullest-scoring queue there (`steals` counts those).  Stealing is
/// what bounds cross-shard starvation: the per-shard fill+aging policy
/// only sees its own tenants, so an aged tenant on a busy worker's shard
/// is picked up by whichever worker idles first.
///
/// Step-level admission ([`ShardedScheduler::admit`]) locks the running
/// tenant's home shard, so the same-shard aging hold fires exactly as in
/// single-worker serving regardless of which worker runs the session.
pub struct ShardedScheduler {
    shards: Vec<Mutex<Scheduler>>,
    /// queued requests across all shards (fast idle check without locks)
    pending: AtomicUsize,
    /// batches handed to a worker whose home shard didn't own them, one
    /// counter per worker (the thief) so steal *attribution* is visible;
    /// [`ShardedScheduler::steals`] sums them
    steal_obs: Vec<Arc<Counter>>,
    /// open flag guarded for the condvar; false once the producer closes
    gate: Mutex<bool>,
    work_ready: Condvar,
}

impl ShardedScheduler {
    pub fn new(shards: usize, opts: SchedulerOpts) -> ShardedScheduler {
        let shards = shards.max(1);
        ShardedScheduler {
            shards: (0..shards).map(|_| Mutex::new(Scheduler::new(opts.clone()))).collect(),
            pending: AtomicUsize::new(0),
            steal_obs: (0..shards).map(|_| Arc::new(Counter::new())).collect(),
            gate: Mutex::new(true),
            work_ready: Condvar::new(),
        }
    }

    /// Re-home every shard's counters plus the per-worker steal counters
    /// into `reg` (`sched_*{shard=..}`, `sched_steals_total{worker=..}`).
    /// Call before serving starts, like [`Scheduler::bind_obs`].
    pub fn bind_obs(&mut self, reg: &Registry) {
        for (i, shard) in self.shards.iter_mut().enumerate() {
            get_mut_recover(shard).bind_obs(reg, i);
        }
        self.steal_obs = (0..self.shards.len())
            .map(|w| {
                let w = w.to_string();
                reg.counter("sched_steals_total", &[("worker", w.as_str())])
            })
            .collect();
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// The shard that owns `id`'s queue (exposed for tests and metrics).
    pub fn shard_of(&self, id: &Option<String>) -> usize {
        shard_of(id, self.shards.len())
    }

    pub fn pending(&self) -> usize {
        self.pending.load(Ordering::SeqCst)
    }

    /// Batches taken by non-home workers so far (all workers summed).
    pub fn steals(&self) -> usize {
        self.steal_obs.iter().map(|c| c.get() as usize).sum()
    }

    /// Enqueue a request on its tenant's home shard and wake a worker.
    /// False (reply already sent) when the shard refused it — overloaded
    /// past its queue cap, or its deadline already expired.
    pub fn push(&self, req: Request) -> bool {
        let shard = shard_of(&req.adapter_id, self.shards.len());
        let queued = lock_recover(&self.shards[shard]).push(req);
        if queued {
            self.pending.fetch_add(1, Ordering::SeqCst);
            self.work_ready.notify_one();
        }
        queued
    }

    /// Re-admit a request after a session failure / worker crash (see
    /// [`Scheduler::requeue`]: front of its tenant's FIFO, cap bypassed,
    /// deadline still honored) and wake a worker.  Works after `close` —
    /// workers drain requeued work before exiting.
    pub fn requeue(&self, req: Request) -> bool {
        let shard = shard_of(&req.adapter_id, self.shards.len());
        let queued = lock_recover(&self.shards[shard]).requeue(req);
        if queued {
            self.pending.fetch_add(1, Ordering::SeqCst);
            self.work_ready.notify_one();
        }
        queued
    }

    /// Producer side is done: once the queues drain, `next_work` returns
    /// `None` and workers exit.
    pub fn close(&self) {
        *lock_recover(&self.gate) = false;
        self.work_ready.notify_all();
    }

    /// Blocking dispatch for worker `home`: pop the next same-tenant batch
    /// under each shard's fill+aging policy, scanning the home shard
    /// first, then stealing from siblings.  Blocks while every queue is
    /// empty but the producer is still open; `None` means shutdown (closed
    /// and drained).  `stolen` in the return is true when the batch came
    /// from a non-home shard.
    pub fn next_work(
        &self,
        home: usize,
        now: Instant,
    ) -> Option<(Option<String>, Vec<Request>, bool)> {
        let n = self.shards.len();
        let home = home % n;
        // `now` seeds the first scan (testability); it is resampled after
        // every blocking wait so aging scores never use a stale clock
        let mut now = now;
        loop {
            if self.pending.load(Ordering::SeqCst) > 0 {
                for k in 0..n {
                    let s = (home + k) % n;
                    let mut shard = lock_recover(&self.shards[s]);
                    let batch = shard.next_batch(now);
                    // deadline sheds inside the shard replied directly;
                    // fold them out of the cross-shard pending count so
                    // workers don't spin on work that no longer exists
                    let shed = shard.take_shed();
                    drop(shard);
                    if shed > 0 {
                        self.pending.fetch_sub(shed, Ordering::SeqCst);
                    }
                    if let Some((id, reqs)) = batch {
                        self.pending.fetch_sub(reqs.len(), Ordering::SeqCst);
                        if k > 0 {
                            self.steal_obs[home].inc();
                        }
                        return Some((id, reqs, k > 0));
                    }
                }
                // raced with another worker's pop; rescan
                continue;
            }
            let open = lock_recover(&self.gate);
            if self.pending.load(Ordering::SeqCst) > 0 {
                continue; // a push landed between the check and the lock
            }
            if !*open {
                return None;
            }
            // the timeout is a safety net against lost wakeups; pushes
            // notify under normal operation
            let (_guard, _timed_out) =
                wait_timeout_recover(&self.work_ready, open, Duration::from_millis(20));
            now = Instant::now();
        }
    }

    /// Step-level admission for a running session: top up `free_slots`
    /// from `current`'s home shard, FIFO, under that shard's aging hold
    /// (see [`Scheduler::admit`]).  Safe to call from any worker — the
    /// shard is chosen by tenant, not by caller.
    pub fn admit(&self, current: &Option<String>, now: Instant, free_slots: usize) -> Vec<Request> {
        let shard_idx = shard_of(current, self.shards.len());
        let mut shard = lock_recover(&self.shards[shard_idx]);
        let got = shard.admit(current, now, free_slots);
        let shed = shard.take_shed();
        drop(shard);
        if shed > 0 {
            self.pending.fetch_sub(shed, Ordering::SeqCst);
        }
        if !got.is_empty() {
            self.pending.fetch_sub(got.len(), Ordering::SeqCst);
        }
        got
    }

    /// Tighten every shard's `max_batch` to the artifact batch (see
    /// [`Scheduler::clamp_max_batch`]).  Workers call this during setup,
    /// before the go-live barrier, so no dispatch ever sees the
    /// unclamped value.
    pub fn clamp_max_batch(&self, cap: usize) {
        for shard in &self.shards {
            lock_recover(shard).clamp_max_batch(cap);
        }
    }

    /// Aggregate scheduler counters across shards (see
    /// [`SchedulerMetrics::merge`]).
    pub fn metrics(&self) -> SchedulerMetrics {
        let mut out = SchedulerMetrics::default();
        for shard in &self.shards {
            out.merge(&lock_recover(shard).metrics());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    fn req(
        id: Option<&str>,
        prompt: &str,
        age: Duration,
    ) -> (Request, std::sync::mpsc::Receiver<Result<String>>) {
        let (tx, rx) = channel();
        let mut r = Request::new(id.map(|s| s.to_string()), prompt.to_string(), tx);
        r.enqueued = Instant::now().checked_sub(age).unwrap_or_else(Instant::now);
        (r, rx)
    }

    fn opts(max_batch: usize, aging_ms: u64) -> SchedulerOpts {
        SchedulerOpts {
            max_batch,
            aging: Duration::from_millis(aging_ms),
            ..Default::default()
        }
    }

    #[test]
    fn batches_share_one_adapter_and_keep_fifo_order() {
        let mut s = Scheduler::new(opts(8, 50));
        let mut keep = Vec::new();
        for (id, p) in [("a", "a0"), ("b", "b0"), ("a", "a1"), ("b", "b1"), ("a", "a2")] {
            let (r, rx) = req(Some(id), p, Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        assert_eq!(s.pending(), 5);
        let (id1, batch1) = s.next_batch(Instant::now()).unwrap();
        // a is fuller, so it goes first; FIFO inside the tenant
        assert_eq!(id1.as_deref(), Some("a"));
        let prompts: Vec<&str> = batch1.iter().map(|r| r.prompt.as_str()).collect();
        assert_eq!(prompts, vec!["a0", "a1", "a2"]);
        let (id2, batch2) = s.next_batch(Instant::now()).unwrap();
        assert_eq!(id2.as_deref(), Some("b"));
        assert_eq!(batch2.len(), 2);
        assert!(s.next_batch(Instant::now()).is_none());
        assert!(s.is_empty());
    }

    #[test]
    fn respects_max_batch() {
        let mut s = Scheduler::new(opts(2, 50));
        let mut keep = Vec::new();
        for i in 0..5 {
            let (r, rx) = req(Some("a"), &format!("p{i}"), Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        let sizes: Vec<usize> = std::iter::from_fn(|| s.next_batch(Instant::now()))
            .map(|(_, b)| b.len())
            .collect();
        assert_eq!(sizes, vec![2, 2, 1]);
        let m = s.metrics();
        assert_eq!(m.batches, 3);
        assert_eq!(m.scheduled, 5);
        assert_eq!(m.max_queue_depth, 5);
        assert!((m.avg_fill() - (1.0 + 1.0 + 0.5) / 3.0).abs() < 1e-9);
    }

    #[test]
    fn aging_prevents_starvation_of_low_traffic_tenant() {
        let mut s = Scheduler::new(opts(8, 50));
        let mut keep = Vec::new();
        // hot tenant: a full, fresh batch
        for i in 0..8 {
            let (r, rx) = req(Some("hot"), &format!("h{i}"), Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        // cold tenant: one request that has waited 10x the aging window
        let (r, rx) = req(Some("cold"), "c0", Duration::from_millis(500));
        s.push(r);
        keep.push(rx);
        let (id, batch) = s.next_batch(Instant::now()).unwrap();
        assert_eq!(id.as_deref(), Some("cold"), "aged request must not starve");
        assert_eq!(batch.len(), 1);
        assert_eq!(s.metrics().aged_batches, 1);
        let (id2, _) = s.next_batch(Instant::now()).unwrap();
        assert_eq!(id2.as_deref(), Some("hot"));
    }

    #[test]
    fn prefers_fuller_queue_at_equal_age() {
        let mut s = Scheduler::new(opts(8, 50));
        let mut keep = Vec::new();
        for i in 0..4 {
            let (r, rx) = req(Some("big"), &format!("b{i}"), Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        let (r, rx) = req(Some("small"), "s0", Duration::ZERO);
        s.push(r);
        keep.push(rx);
        let (id, _) = s.next_batch(Instant::now()).unwrap();
        assert_eq!(id.as_deref(), Some("big"));
        assert_eq!(s.metrics().aged_batches, 0);
    }

    #[test]
    fn admit_refills_from_current_tenant_fifo() {
        let mut s = Scheduler::new(opts(8, 50));
        let mut keep = Vec::new();
        for p in ["a0", "a1", "a2"] {
            let (r, rx) = req(Some("a"), p, Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        let current = Some("a".to_string());
        // zero free slots admits nothing
        assert!(s.admit(&current, Instant::now(), 0).is_empty());
        let got = s.admit(&current, Instant::now(), 2);
        let prompts: Vec<&str> = got.iter().map(|r| r.prompt.as_str()).collect();
        assert_eq!(prompts, vec!["a0", "a1"]);
        assert_eq!(s.pending(), 1);
        // draining the queue removes it
        let got = s.admit(&current, Instant::now(), 4);
        assert_eq!(got.len(), 1);
        assert!(s.is_empty());
        assert!(s.admit(&current, Instant::now(), 4).is_empty());
        let m = s.metrics();
        assert_eq!(m.admitted, 3);
        assert_eq!(m.scheduled, 3);
        assert_eq!(m.batches, 0, "admit must not count as a new batch");
    }

    #[test]
    fn admit_never_crosses_tenants_and_holds_for_aged_queues() {
        let mut s = Scheduler::new(opts(8, 50));
        let mut keep = Vec::new();
        let (r, rx) = req(Some("other"), "o0", Duration::ZERO);
        s.push(r);
        keep.push(rx);
        // current tenant has no queue: nothing is admitted (and the other
        // tenant's request is NOT leaked into the running batch)
        let current = Some("a".to_string());
        assert!(s.admit(&current, Instant::now(), 8).is_empty());
        assert_eq!(s.pending(), 1);
        // current tenant queued, but another tenant aged out: admission is
        // held so the running batch drains and the device switches
        for p in ["a0", "a1"] {
            let (r, rx) = req(Some("a"), p, Duration::ZERO);
            s.push(r);
            keep.push(rx);
        }
        let (r, rx) = req(Some("cold"), "c0", Duration::from_millis(500));
        s.push(r);
        keep.push(rx);
        assert!(s.admit(&current, Instant::now(), 8).is_empty());
        // polled every forward while the hold persists: still one event
        assert!(s.admit(&current, Instant::now(), 8).is_empty());
        assert!(s.admit(&current, Instant::now(), 8).is_empty());
        assert_eq!(s.metrics().aging_holds, 1, "one sustained hold is one event");
        // the aged tenant wins the next batch
        let (id, _) = s.next_batch(Instant::now()).unwrap();
        assert_eq!(id.as_deref(), Some("cold"));
        // with the aged request served, admission flows again
        assert_eq!(s.admit(&current, Instant::now(), 8).len(), 2);
    }

    #[test]
    fn sharded_affinity_is_stable_and_push_routes_to_home_shard() {
        let s = ShardedScheduler::new(4, opts(8, 50));
        assert_eq!(s.shards(), 4);
        let a = Some("tenant-a".to_string());
        let home = s.shard_of(&a);
        assert_eq!(home, s.shard_of(&a), "assignment must be deterministic");
        let (r, _k) = req(Some("tenant-a"), "p0", Duration::ZERO);
        s.push(r);
        assert_eq!(s.pending(), 1);
        // the home worker pops it without stealing
        let (id, batch, stolen) = s.next_work(home, Instant::now()).unwrap();
        assert_eq!(id, a);
        assert_eq!(batch.len(), 1);
        assert!(!stolen);
        assert_eq!(s.steals(), 0);
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn idle_worker_steals_from_sibling_shard() {
        let s = ShardedScheduler::new(4, opts(8, 50));
        let a = Some("tenant-a".to_string());
        let home = s.shard_of(&a);
        let thief = (home + 1) % s.shards();
        let mut keep = Vec::new();
        for p in ["p0", "p1"] {
            let (r, k) = req(Some("tenant-a"), p, Duration::ZERO);
            s.push(r);
            keep.push(k);
        }
        // a non-home worker finds the batch by scanning past its own shard
        let (id, batch, stolen) = s.next_work(thief, Instant::now()).unwrap();
        assert_eq!(id, a);
        assert_eq!(batch.len(), 2, "steals take the whole same-tenant batch");
        assert!(stolen);
        assert_eq!(s.steals(), 1);
    }

    #[test]
    fn sharded_admit_targets_home_shard_and_holds_for_aged_tenants() {
        // regardless of which worker runs the session, admit() must hit
        // the tenant's home shard and respect its aging hold
        let s = ShardedScheduler::new(2, opts(8, 50));
        let current = Some("tenant-a".to_string());
        let mut keep = Vec::new();
        for p in ["a0", "a1"] {
            let (r, k) = req(Some("tenant-a"), p, Duration::ZERO);
            s.push(r);
            keep.push(k);
        }
        assert_eq!(s.admit(&current, Instant::now(), 1).len(), 1);
        // an aged tenant on the SAME shard halts further admission; use a
        // same-shard sibling so the hold is observable
        let sibling = (0..1000)
            .map(|i| format!("cold{i}"))
            .find(|c| shard_of(&Some(c.clone()), 2) == s.shard_of(&current))
            .expect("some id lands on the same shard");
        let (r, k) = req(Some(sibling.as_str()), "c0", Duration::from_millis(500));
        s.push(r);
        keep.push(k);
        assert!(s.admit(&current, Instant::now(), 8).is_empty());
        assert_eq!(s.metrics().aging_holds, 1);
        // the aged tenant wins the next dispatch on that shard
        let (id, _, _) = s.next_work(s.shard_of(&current), Instant::now()).unwrap();
        assert_eq!(id.as_deref(), Some(sibling.as_str()));
    }

    #[test]
    fn concurrent_push_and_pop_drains_every_request_exactly_once() {
        // fairness under concurrent admission: producers push interleaved
        // tenants (one pre-aged, low-traffic) while consumer threads pop;
        // every request must be served exactly once and the aged tenant
        // must not starve behind the hot ones.
        let workers = 4usize;
        let per_tenant = 25usize;
        let s = std::sync::Arc::new(ShardedScheduler::new(workers, opts(4, 10)));
        let served = std::sync::Arc::new(Mutex::new(Vec::<String>::new()));
        std::thread::scope(|scope| {
            for w in 0..workers {
                let s = s.clone();
                let served = served.clone();
                scope.spawn(move || {
                    while let Some((_, batch, _)) = s.next_work(w, Instant::now()) {
                        let mut got = served.lock().unwrap();
                        for r in batch {
                            got.push(r.prompt.clone());
                            // replies are dropped; senders ignore the error
                            let _ = r.reply.send(Ok(String::new()));
                        }
                    }
                });
            }
            let mut keep = Vec::new();
            for i in 0..per_tenant {
                for t in ["hot-a", "hot-b", "hot-c"] {
                    let (r, k) = req(Some(t), &format!("{t}/{i}"), Duration::ZERO);
                    s.push(r);
                    keep.push(k);
                }
                if i % 8 == 0 {
                    let (r, k) =
                        req(Some("cold"), &format!("cold/{i}"), Duration::from_millis(100));
                    s.push(r);
                    keep.push(k);
                }
            }
            s.close();
            drop(keep);
        });
        let got = served.lock().unwrap();
        let total = per_tenant * 3 + per_tenant.div_ceil(8);
        assert_eq!(got.len(), total, "every request served exactly once");
        let mut sorted = got.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), total, "a request was dispatched twice");
        assert!(got.iter().any(|p| p.starts_with("cold/")), "cold tenant starved");
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn sharded_metrics_aggregate_across_shards() {
        let s = ShardedScheduler::new(3, opts(2, 50));
        let mut keep = Vec::new();
        for t in ["a", "b", "c", "d", "e"] {
            for i in 0..2 {
                let (r, k) = req(Some(t), &format!("{t}{i}"), Duration::ZERO);
                s.push(r);
                keep.push(k);
            }
        }
        // close before draining so next_work never blocks
        s.close();
        let mut batches = 0;
        while s.next_work(0, Instant::now()).is_some() {
            batches += 1;
        }
        let m = s.metrics();
        assert_eq!(m.batches, batches);
        assert_eq!(m.scheduled, 10);
        assert!(m.avg_fill() > 0.0);
    }

    #[test]
    fn bound_scheduler_reports_through_registry() {
        // after bind_obs, metrics() and the registry snapshot read the
        // same atomics — the counters must agree exactly
        let reg = Registry::new();
        let mut s = ShardedScheduler::new(2, opts(2, 50));
        s.bind_obs(&reg);
        let mut keep = Vec::new();
        for t in ["a", "b", "c"] {
            for i in 0..2 {
                let (r, k) = req(Some(t), &format!("{t}{i}"), Duration::ZERO);
                s.push(r);
                keep.push(k);
            }
        }
        s.close();
        while s.next_work(1, Instant::now()).is_some() {}
        let m = s.metrics();
        assert_eq!(m.scheduled, 6);
        let snap = reg.snapshot();
        assert_eq!(snap.sum("sched_batches_total") as usize, m.batches);
        assert_eq!(snap.sum("sched_scheduled_total") as usize, m.scheduled);
        assert_eq!(snap.gauge_peak_max("sched_queue_depth") as usize, m.max_queue_depth);
        assert_eq!(snap.sum("sched_steals_total") as usize, s.steals());
    }

    #[test]
    fn merged_path_is_its_own_queue() {
        let mut s = Scheduler::new(opts(4, 50));
        let (r1, _k1) = req(None, "m0", Duration::ZERO);
        let (r2, _k2) = req(Some("a"), "a0", Duration::ZERO);
        let (r3, _k3) = req(None, "m1", Duration::ZERO);
        s.push(r1);
        s.push(r2);
        s.push(r3);
        let (id, batch) = s.next_batch(Instant::now()).unwrap();
        assert_eq!(id, None);
        assert_eq!(batch.len(), 2);
        let (id2, _) = s.next_batch(Instant::now()).unwrap();
        assert_eq!(id2.as_deref(), Some("a"));
    }

    fn kind_of(rx: &std::sync::mpsc::Receiver<Result<String>>) -> &'static str {
        match rx.try_recv().expect("a reply must be waiting") {
            Ok(_) => "ok",
            Err(e) => ServeError::of(&e).map(|s| s.kind()).unwrap_or("untyped"),
        }
    }

    #[test]
    fn queue_cap_rejects_with_typed_overloaded() {
        let mut s = Scheduler::new(SchedulerOpts {
            queue_cap: Some(2),
            ..opts(8, 50)
        });
        let mut keep = Vec::new();
        for p in ["p0", "p1"] {
            let (r, k) = req(Some("a"), p, Duration::ZERO);
            assert!(s.push(r));
            keep.push(k);
        }
        let (r, rx) = req(Some("a"), "p2", Duration::ZERO);
        assert!(!s.push(r), "push past the cap must be refused");
        match ServeError::of(&rx.try_recv().unwrap().unwrap_err()) {
            Some(ServeError::Overloaded { queue_cap }) => assert_eq!(*queue_cap, 2),
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(s.pending(), 2);
        assert_eq!(s.metrics().shed, 1);
        assert_eq!(s.metrics().deadline_expired, 0);
        // draining frees capacity: the next push is accepted again
        let _ = s.next_batch(Instant::now());
        let (r, k) = req(Some("a"), "p3", Duration::ZERO);
        assert!(s.push(r));
        keep.push(k);
    }

    #[test]
    fn expired_push_is_shed_with_deadline_exceeded() {
        let mut s = Scheduler::new(SchedulerOpts {
            deadline: Some(Duration::from_millis(20)),
            ..opts(8, 50)
        });
        // enqueued 100ms ago with a 20ms default deadline: dead on arrival
        let (r, rx) = req(Some("a"), "late", Duration::from_millis(100));
        assert!(!s.push(r));
        match ServeError::of(&rx.try_recv().unwrap().unwrap_err()) {
            Some(ServeError::DeadlineExceeded { waited_ms }) => assert!(*waited_ms >= 20),
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(s.pending(), 0);
        assert_eq!(s.metrics().deadline_expired, 1);
        assert_eq!(s.metrics().shed, 1);
    }

    #[test]
    fn queued_requests_are_swept_when_their_deadline_passes() {
        let mut s = Scheduler::new(opts(8, 50));
        // explicit per-request deadline in the near future
        let (mut r, rx) = req(Some("a"), "doomed", Duration::ZERO);
        r.deadline = Some(Instant::now() - Duration::from_millis(1));
        // not expired relative to a clock just before the deadline
        let before = r.deadline.unwrap() - Duration::from_millis(5);
        assert!(!r.expired(before));
        // bypass push's entry check by backdating after enqueue: stage it
        // unexpired, then sweep with a later clock
        r.deadline = Some(Instant::now() + Duration::from_millis(5));
        assert!(s.push(r));
        let (r2, k2) = req(Some("a"), "fine", Duration::ZERO);
        assert!(s.push(r2));
        assert_eq!(s.pending(), 2);
        // dispatch with a clock past the deadline: the doomed request is
        // shed before batching, the undeadlined one is served
        let later = Instant::now() + Duration::from_millis(50);
        let (_, batch) = s.next_batch(later).unwrap();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].prompt, "fine");
        assert_eq!(kind_of(&rx), "deadline_exceeded");
        assert_eq!(s.metrics().deadline_expired, 1);
        drop(k2);
    }

    #[test]
    fn requeue_goes_to_the_front_and_bypasses_the_cap() {
        let mut s = Scheduler::new(SchedulerOpts {
            queue_cap: Some(2),
            ..opts(8, 50)
        });
        let (r0, _k0) = req(Some("a"), "first", Duration::ZERO);
        let (r1, _k1) = req(Some("a"), "second", Duration::ZERO);
        assert!(s.push(r0));
        assert!(s.push(r1));
        // queue is at cap, but a crash-recovered request is re-admitted
        // anyway, ahead of the line
        let (mut rq, _kq) = req(Some("a"), "survivor", Duration::ZERO);
        rq.attempts = 1;
        assert!(s.requeue(rq));
        assert_eq!(s.pending(), 3);
        let (_, batch) = s.next_batch(Instant::now()).unwrap();
        assert_eq!(batch[0].prompt, "survivor");
        assert_eq!(batch[0].attempts, 1);
        assert_eq!(batch[1].prompt, "first");
    }

    #[test]
    fn sharded_pending_stays_consistent_through_sheds() {
        // a deadline shed inside a shard must also shrink the cross-shard
        // pending atomic, or idle workers spin forever on phantom work
        let s = ShardedScheduler::new(
            2,
            SchedulerOpts { deadline: Some(Duration::from_millis(10)), ..opts(8, 50) },
        );
        let (r, rx) = req(Some("a"), "doomed", Duration::ZERO);
        assert!(s.push(r));
        assert_eq!(s.pending(), 1);
        // past the deadline: the scan sheds it and returns no batch
        let later = Instant::now() + Duration::from_millis(100);
        s.close();
        assert!(s.next_work(0, later).is_none());
        assert_eq!(s.pending(), 0, "shed must be folded out of pending");
        assert_eq!(kind_of(&rx), "deadline_exceeded");
    }

    #[test]
    fn sharded_requeue_wakes_a_worker_and_serves_front() {
        let s = ShardedScheduler::new(2, opts(8, 50));
        let (r, _k) = req(Some("a"), "back", Duration::ZERO);
        assert!(s.push(r));
        let (mut rq, _kq) = req(Some("a"), "recovered", Duration::ZERO);
        rq.attempts = 2;
        assert!(s.requeue(rq));
        assert_eq!(s.pending(), 2);
        let (_, batch, _) = s.next_work(0, Instant::now()).unwrap();
        assert_eq!(batch[0].prompt, "recovered");
    }

    #[test]
    fn cancel_handle_drop_marks_cancelled_and_disarm_does_not() {
        let (mut r, _k) = req(Some("a"), "p", Duration::ZERO);
        assert!(!r.is_cancelled(), "no handle → never cancelled");
        let h = r.cancel_handle();
        assert!(!r.is_cancelled());
        drop(h);
        assert!(r.is_cancelled(), "dropping the handle cancels");

        let (mut r2, _k2) = req(Some("a"), "q", Duration::ZERO);
        let h2 = r2.cancel_handle();
        h2.disarm();
        assert!(!r2.is_cancelled(), "disarm consumes without cancelling");

        let (mut r3, _k3) = req(Some("a"), "s", Duration::ZERO);
        let h3 = r3.cancel_handle();
        h3.cancel();
        assert!(r3.is_cancelled());
    }
}
