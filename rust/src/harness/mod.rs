//! Experiment harness shared by examples/ and benches/: pretrained-base
//! caching, per-method table rows, and EXPERIMENTS.md section writers.
//!
//! Scale knobs (env vars, so `cargo run --example table1` is tunable
//! without recompiling):
//!   SQFT_MODEL           model config       (default sqft-tiny)
//!   SQFT_PRETRAIN_STEPS  base pretraining   (default 400)
//!   SQFT_STEPS           fine-tuning steps  (default 150)
//!   SQFT_TEST_N          test samples/task  (default 300)
//!   SQFT_TRAIN_N         train samples/task (default 3000)
//!   SQFT_SEED            RNG seed           (default 7)

use crate::data::{Dataset, Sample, Task, Tokenizer};
use crate::evalharness::EvalResult;
use crate::model::{checkpoint, init_base, ParamSet};
use crate::nls::SearchSpace;
use crate::peft::Method;
use crate::pipeline::{self, Prepared};
use crate::report::{pct, Table};
use crate::runtime::Runtime;
use crate::tensor::Rng;
use crate::train::{LossCurve, Pretrainer, TrainOpts, Trainer};
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub struct Harness {
    pub rt: Runtime,
    pub model: String,
    pub tok: Tokenizer,
    pub seed: u64,
    pub pretrain_steps: usize,
    pub steps: usize,
    pub train_n: usize,
    pub test_n: usize,
    pub ckpt_dir: PathBuf,
}

impl Harness {
    pub fn from_env() -> Result<Harness> {
        let artifacts = std::env::var("SQFT_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        let rt = Runtime::new(Path::new(&artifacts))
            .context("loading artifacts (run `make artifacts`)")?;
        Ok(Harness {
            rt,
            model: std::env::var("SQFT_MODEL").unwrap_or_else(|_| "sqft-tiny".into()),
            tok: Tokenizer::new(),
            seed: env_u64("SQFT_SEED", 7),
            pretrain_steps: env_usize("SQFT_PRETRAIN_STEPS", 400),
            steps: env_usize("SQFT_STEPS", 150),
            train_n: env_usize("SQFT_TRAIN_N", 3000),
            test_n: env_usize("SQFT_TEST_N", 300),
            ckpt_dir: PathBuf::from("checkpoints"),
        })
    }

    pub fn datasets(&self, tasks: &[Task]) -> Vec<Dataset> {
        tasks
            .iter()
            .map(|&t| {
                let n_val = if t.has_validation() { 150 } else { 0 };
                Dataset::generate(t, self.train_n, n_val, self.test_n, self.seed)
            })
            .collect()
    }

    /// Pretrain (or load cached) a base model on a task mixture.
    pub fn base_for(&self, tag: &str, train: &[Sample]) -> Result<(ParamSet, LossCurve)> {
        let path = self.ckpt_dir.join(format!(
            "{}-{}-s{}-p{}.ckpt", self.model, tag, self.seed, self.pretrain_steps));
        if path.exists() {
            let (params, _) = checkpoint::load(&path)?;
            eprintln!("[harness] loaded cached base {}", path.display());
            return Ok((params, LossCurve::default()));
        }
        eprintln!("[harness] pretraining {} on '{tag}' for {} steps...",
            self.model, self.pretrain_steps);
        let hyper = self.rt.model(&self.model)?.clone();
        let mut rng = Rng::new(self.seed);
        let base = init_base(&hyper, &mut rng);
        let mut pre = Pretrainer::new(&self.rt, &self.model, base);
        let opts = TrainOpts {
            steps: self.pretrain_steps,
            lr: 2e-3,
            log_every: (self.pretrain_steps / 20).max(1),
            seed: self.seed,
            fixed_rank: false,
        };
        let curve = pre.train(train, &self.tok, &opts)?;
        let meta = Json::obj(vec![
            ("config", Json::Str(self.model.clone())),
            ("tag", Json::Str(tag.into())),
        ]);
        checkpoint::save(&pre.base, &path, meta)?;
        Ok((pre.base, curve))
    }

    pub fn train_opts(&self) -> TrainOpts {
        TrainOpts {
            steps: self.steps,
            lr: 1e-3,
            log_every: (self.steps / 10).max(1),
            seed: self.seed,
            fixed_rank: false,
        }
    }

    /// Run prepare + finetune for one method; returns (prepared, trainer).
    pub fn tune<'a>(
        &'a self,
        pretrained: &ParamSet,
        method: Method,
        sparsity: f64,
        train: &[Sample],
    ) -> Result<(Prepared, Trainer<'a>)> {
        self.tune_opts(pretrained, method, sparsity, train, &self.train_opts())
    }

    /// `tune` with explicit TrainOpts (fixed_rank ablation etc.).
    pub fn tune_opts<'a>(
        &'a self,
        pretrained: &ParamSet,
        method: Method,
        sparsity: f64,
        train: &[Sample],
        opts: &TrainOpts,
    ) -> Result<(Prepared, Trainer<'a>)> {
        let mut rng = Rng::new(self.seed ^ 0xA5);
        let prepared = pipeline::prepare(
            &self.rt, &self.model, pretrained, method, sparsity, train,
            &self.tok, 4, &mut rng)?;
        let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
        let space = SearchSpace::new(&prepared.hyper, choices, alpha)?;
        let (trainer, _) = pipeline::finetune(
            &self.rt, &self.model, &prepared, space, train, &self.tok, opts)?;
        Ok((prepared, trainer))
    }

    /// Deployed NLS config per the paper's reference heuristic.
    pub fn deploy_config(&self, trainer: &Trainer) -> crate::nls::Config {
        if trainer.method.uses_nls() && !trainer.fixed_rank {
            trainer.space.heuristic_config()
        } else {
            trainer.space.max_config()
        }
    }

    /// Evaluate a tuned method on one test set; merged accuracy included
    /// for mergeable methods.
    pub fn eval_cell(
        &self,
        prepared: &Prepared,
        trainer: &Trainer,
        test: &[Sample],
    ) -> Result<(EvalResult, Option<EvalResult>, Option<bool>)> {
        let cfg = self.deploy_config(trainer);
        let acc = pipeline::evaluate_unmerged(
            &self.rt, &self.model, prepared, trainer, &cfg, test, &self.tok)?;
        if prepared.method.mergeable() {
            let merged = pipeline::merged_state(prepared, trainer, &cfg)?;
            let macc = pipeline::evaluate_merged(
                &self.rt, &self.model, prepared, &merged, test, &self.tok)?;
            let preserved = merged.sparsity_after >= merged.sparsity_before - 1e-9;
            Ok((acc, Some(macc), Some(preserved)))
        } else {
            Ok((acc, None, None))
        }
    }

    /// "w/o tune" baseline accuracy of a compressed model.
    pub fn baseline_acc(
        &self,
        pretrained: &ParamSet,
        method: Method,
        sparsity: f64,
        train: &[Sample],
        test: &[Sample],
    ) -> Result<EvalResult> {
        let mut rng = Rng::new(self.seed ^ 0xB6);
        let prepared = pipeline::prepare(
            &self.rt, &self.model, pretrained, method, sparsity, train,
            &self.tok, 4, &mut rng)?;
        pipeline::evaluate_base(&self.rt, &self.model, &prepared, test, &self.tok)
    }

    /// A Table 1/2/3-style row for one method.
    pub fn method_row(
        &self,
        method: Method,
        accs: &[f64],
        merged_ok: Option<bool>,
    ) -> Vec<String> {
        let merge_cell = if method.mergeable() {
            match merged_ok {
                Some(true) => "yes".to_string(),
                Some(false) => "VIOLATED".to_string(),
                None => "yes".to_string(),
            }
        } else {
            "no".to_string()
        };
        let mut row = vec![
            method.name().to_string(),
            merge_cell,
            method.final_precision().to_string(),
        ];
        row.extend(accs.iter().map(|&a| pct(a)));
        row
    }
}

/// Append a titled section (with provenance line) to EXPERIMENTS.md.
pub fn log_experiment(section: &str, body: &str) -> Result<()> {
    let path = Path::new("EXPERIMENTS.md");
    let stamp = std::process::Command::new("date")
        .arg("+%Y-%m-%d %H:%M")
        .output()
        .ok()
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .unwrap_or_default();
    let content = format!("\n## {section}\n_run: {}_\n\n{body}\n", stamp.trim());
    crate::report::append_to(path, &content)
}

/// Render a loss curve as a compact sparkline-ish text block.
pub fn render_curve(curve: &LossCurve) -> String {
    if curve.points.is_empty() {
        return "(cached base, no curve)".into();
    }
    let mut s = String::from("```\n");
    s.push_str(&curve.render());
    s.push_str("\n```\n");
    s
}

/// Markdown for a table plus the paper-expectation note.
pub fn table_with_note(t: &Table, note: &str) -> String {
    format!("{}\n_{note}_\n", t.render())
}
