//! Model-state substrate: named parameter sets, initialization matching the
//! L2 conventions, and a binary checkpoint format.
//!
//! The coordinator never does model math on these tensors — it initializes,
//! sparsifies, quantizes, merges and ships them to the XLA artifacts.

pub mod checkpoint;

use crate::runtime::ModelHyper;
use crate::tensor::{Rng, Tensor};
use anyhow::{Context, Result};
use std::collections::BTreeMap;

/// A named set of host tensors (base weights, adapters, optimizer state...).
#[derive(Clone, Debug, Default)]
pub struct ParamSet {
    map: BTreeMap<String, Tensor>,
}

impl ParamSet {
    pub fn new() -> ParamSet {
        ParamSet { map: BTreeMap::new() }
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map.get(name).with_context(|| format!("param set missing '{name}'"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        self.map.get_mut(name).with_context(|| format!("param set missing '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.map.iter()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Total element count (for storage metrics).
    pub fn total_elems(&self) -> usize {
        self.map.values().map(|t| t.len()).sum()
    }

    /// Total f32 payload in bytes — what this set costs to ship across the
    /// PJRT boundary (upload accounting in serve benches).
    pub fn total_bytes(&self) -> usize {
        self.total_elems() * std::mem::size_of::<f32>()
    }

    /// Global fraction of exact zeros across a subset of tensors.
    pub fn sparsity_of(&self, names: &[&str]) -> f64 {
        let (mut zeros, mut total) = (0usize, 0usize);
        for n in names {
            if let Some(t) = self.map.get(*n) {
                zeros += t.data().iter().filter(|&&x| x == 0.0).count();
                total += t.len();
            }
        }
        if total == 0 { 0.0 } else { zeros as f64 / total as f64 }
    }
}

/// The base weight keys in canonical (manifest) order.
pub fn base_keys() -> [&'static str; 11] {
    ["embed", "final_ln", "ln1", "ln2", "wq", "wk", "wv", "wo", "wgate", "wup", "wdown"]
}

/// Linear weights that get sparsified/quantized (everything but norms/embed).
pub fn linear_keys() -> [&'static str; 7] {
    ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"]
}

/// Initialize base weights; mirrors python/tests conventions
/// (norm gains = 1, embed std 0.02, linear std 1/sqrt(fan_in)).
pub fn init_base(m: &ModelHyper, rng: &mut Rng) -> ParamSet {
    let (d, ff, v, l) = (m.d_model, m.d_ff, m.vocab, m.n_layers);
    let mut p = ParamSet::new();
    p.insert("embed", Tensor::randn(rng, &[v, d], 0.02));
    p.insert("final_ln", Tensor::ones(&[d]));
    p.insert("ln1", Tensor::ones(&[l, d]));
    p.insert("ln2", Tensor::ones(&[l, d]));
    let lin = |rng: &mut Rng, shape: &[usize]| {
        let fan_in = shape[shape.len() - 1];
        Tensor::randn(rng, shape, 1.0 / (fan_in as f32).sqrt())
    };
    p.insert("wq", lin(rng, &[l, d, d]));
    p.insert("wk", lin(rng, &[l, d, d]));
    p.insert("wv", lin(rng, &[l, d, d]));
    p.insert("wo", lin(rng, &[l, d, d]));
    p.insert("wgate", lin(rng, &[l, ff, d]));
    p.insert("wup", lin(rng, &[l, ff, d]));
    p.insert("wdown", lin(rng, &[l, d, ff]));
    p
}

/// Adapter parameterization for one method run (LoRA init: A~N(0,0.02),
/// B=0; masks all-ones until SparsePEFT installs the Wanda masks).
///
/// NOTE: rankmask_/scale_ are deliberately NOT part of this set — they are
/// realized per NLS configuration by `nls::SearchSpace::realize` and passed
/// as a separate ParamSet.  Keeping them out prevents a stale full-rank
/// mask from shadowing the active configuration in `build_args` (earlier
/// host sets win).
pub fn init_adapters(m: &ModelHyper, rng: &mut Rng, _alpha: f32) -> ParamSet {
    let (l, r) = (m.n_layers, m.r_max);
    let mut p = ParamSet::new();
    for mod_name in &m.mods {
        let (out, inp) = m.mod_dims(mod_name);
        p.insert(&format!("a_{mod_name}"), Tensor::randn(rng, &[l, r, inp], 0.02));
        p.insert(&format!("b_{mod_name}"), Tensor::zeros(&[l, out, r]));
        p.insert(&format!("mask_{mod_name}"), Tensor::ones(&[l, out, inp]));
    }
    p
}

/// Zeroed Adam state for the adapter parameters.
pub fn init_opt(m: &ModelHyper) -> ParamSet {
    let (l, r) = (m.n_layers, m.r_max);
    let mut p = ParamSet::new();
    for kind in ["m", "v"] {
        for mod_name in &m.mods {
            let (out, inp) = m.mod_dims(mod_name);
            p.insert(&format!("{kind}_a_{mod_name}"), Tensor::zeros(&[l, r, inp]));
            p.insert(&format!("{kind}_b_{mod_name}"), Tensor::zeros(&[l, out, r]));
        }
    }
    p
}

/// Zeroed Adam state for full pretraining (one m/v per base tensor).
pub fn init_pretrain_opt(base: &ParamSet) -> ParamSet {
    let mut p = ParamSet::new();
    for kind in ["m", "v"] {
        for (n, t) in base.iter() {
            p.insert(&format!("{kind}_{n}"), Tensor::zeros(t.shape()));
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn hyper() -> ModelHyper {
        let mods: Vec<String> =
            ["q", "k", "v", "up", "down"].iter().map(|s| s.to_string()).collect();
        let mut mod_dims = BTreeMap::new();
        mod_dims.insert("q".into(), (64, 64));
        mod_dims.insert("k".into(), (64, 64));
        mod_dims.insert("v".into(), (64, 64));
        mod_dims.insert("up".into(), (128, 64));
        mod_dims.insert("down".into(), (64, 128));
        ModelHyper {
            name: "test".into(),
            vocab: 64, d_model: 64, n_layers: 2, n_heads: 2, d_ff: 128,
            seq_len: 48, batch: 8, r_max: 8, group_size: 32,
            param_count: 0, mods, mod_dims,
        }
    }

    #[test]
    fn init_base_shapes() {
        let m = hyper();
        let mut rng = Rng::new(1);
        let p = init_base(&m, &mut rng);
        assert_eq!(p.get("embed").unwrap().shape(), &[64, 64]);
        assert_eq!(p.get("wup").unwrap().shape(), &[2, 128, 64]);
        assert_eq!(p.get("ln1").unwrap().shape(), &[2, 64]);
        // norms are ones
        assert!(p.get("ln1").unwrap().data().iter().all(|&x| x == 1.0));
    }

    #[test]
    fn adapter_init_invariants() {
        let m = hyper();
        let mut rng = Rng::new(2);
        let p = init_adapters(&m, &mut rng, 16.0);
        // B = 0 at init => adapter is a no-op (LoRA convention)
        assert!(p.get("b_q").unwrap().data().iter().all(|&x| x == 0.0));
        assert!(p.get("mask_up").unwrap().data().iter().all(|&x| x == 1.0));
        assert_eq!(p.get("a_down").unwrap().shape(), &[2, 8, 128]);
        // rankmask_/scale_ must NOT be here (realized per NLS config)
        assert!(!p.contains("rankmask_q") && !p.contains("scale_q"));
    }

    #[test]
    fn sparsity_metric_over_subset() {
        let mut p = ParamSet::new();
        p.insert("a", Tensor::new(&[4], vec![0., 0., 1., 2.]).unwrap());
        p.insert("b", Tensor::new(&[2], vec![0., 5.]).unwrap());
        assert_eq!(p.sparsity_of(&["a", "b"]), 0.5);
        assert_eq!(p.sparsity_of(&["a"]), 0.5);
    }
}
