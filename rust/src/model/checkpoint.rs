//! Binary checkpoint format for ParamSets.
//!
//! Layout (v2): magic "SQFTCKP2" | u64 header_len | u32 header_crc |
//! JSON header | raw f32 data | packed u8 data.  The header maps each
//! tensor name to {shape, offset} (offsets in f32 elements into the data
//! section, in header order), and — for checkpoints carrying true-INT4
//! weights — a `packed` section mapping each packed-tensor name to
//! {shape, group_size, offset} with byte offsets into the trailing u8
//! region (`packed_bytes` records its total length, so the f32/u8 boundary
//! is explicit).  v2 adds per-section integrity: the u32 after header_len
//! is the CRC32 of the raw header bytes, and the header's `integrity`
//! object records `f32_bytes` plus CRC32s of the f32 and packed payloads
//! (`f32_crc` / `packed_crc`), so torn writes and bit-flips surface as
//! typed [`CorruptCheckpoint`] errors naming the damaged section instead
//! of confusing parse errors or silently wrong weights.
//!
//! Legacy v1 files (magic "SQFTCKP1", no header_crc word, no integrity
//! object) still load — without checksum verification.  Saves always
//! write v2, and always atomically: the container is written to a temp
//! sibling, fsynced, then renamed over the destination, so a crash
//! mid-save can't leave a truncated file and a failed overwrite leaves
//! the original intact.  Endianness: little (the only platform we
//! target); the magic encodes the version.
//!
//! Three metadata flavors share the container: base/merged model checkpoints
//! (free-form meta), adapter checkpoints (`kind: "adapter"` plus the
//! tuned NLS rank configuration) which the multi-tenant serving registry
//! loads per tenant — see `save_adapter` / `load_adapter` — and merged
//! INT4 model checkpoints (`kind: "int4-model"`, written by `pipeline
//! --out` for quantized-base mergeable methods) whose linear weights live
//! in the packed section as two-nibble codes, not dequantized f32.

use super::ParamSet;
use crate::tensor::Tensor;
use crate::util::hash::{crc32, Crc32};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::fmt;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC_V1: &[u8; 8] = b"SQFTCKP1";
const MAGIC_V2: &[u8; 8] = b"SQFTCKP2";

/// Upper bound on the JSON header; anything larger is a corrupt or hostile
/// file, not a checkpoint (headers are a few KB in practice).
const MAX_HEADER_BYTES: usize = 64 << 20;

/// The container section a corruption was detected in (see
/// [`CorruptCheckpoint`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CkptSection {
    /// The 8-byte magic/version prefix.
    Magic,
    /// The length-prefixed JSON header (incl. its CRC word and contents).
    Header,
    /// The raw f32 tensor payload.
    F32Data,
    /// The trailing packed-INT4 u8 payload.
    PackedData,
}

impl CkptSection {
    /// Stable machine-readable section name (used in error text and tests).
    pub fn name(&self) -> &'static str {
        match self {
            CkptSection::Magic => "magic",
            CkptSection::Header => "header",
            CkptSection::F32Data => "f32 payload",
            CkptSection::PackedData => "packed payload",
        }
    }
}

/// Typed checkpoint-corruption error: which section is damaged, and how.
/// Loads return this (never panic) so callers — the serving registry in
/// particular — can quarantine exactly the tenant whose file is corrupt
/// while siblings keep serving.  Downcast through `anyhow` with
/// `err.downcast_ref::<CorruptCheckpoint>()`.
#[derive(Debug, Clone)]
pub struct CorruptCheckpoint {
    pub section: CkptSection,
    pub detail: String,
}

impl fmt::Display for CorruptCheckpoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "corrupt checkpoint ({} section): {}", self.section.name(), self.detail)
    }
}

impl std::error::Error for CorruptCheckpoint {}

fn corrupt(section: CkptSection, detail: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(CorruptCheckpoint { section, detail: detail.into() })
}

/// One true-INT4 tensor as stored on disk: the *logical* (unpacked) shape,
/// the quantization group size along the trailing in-dim, and the packed
/// two-codes-per-byte payload (`quant::pack::pack_int4_stack` layout).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    pub shape: Vec<usize>,
    pub group_size: usize,
    pub data: Vec<u8>,
}

impl PackedTensor {
    /// Validate the shape/group/payload consistency invariants.
    pub fn validate(&self, name: &str) -> Result<()> {
        let inner = *self.shape.last().unwrap_or(&0);
        if self.shape.is_empty() || inner == 0 || inner % 2 != 0 {
            bail!("packed tensor '{name}': unpackable shape {:?}", self.shape);
        }
        if self.group_size == 0 || inner % self.group_size != 0 {
            bail!(
                "packed tensor '{name}': group size {} does not divide in-dim {inner}",
                self.group_size
            );
        }
        let elems: usize = self
            .shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .with_context(|| format!("packed tensor '{name}': shape overflows"))?;
        if self.data.len() != elems / 2 {
            bail!(
                "packed tensor '{name}': {} bytes for shape {:?} (want {})",
                self.data.len(),
                self.shape,
                elems / 2
            );
        }
        Ok(())
    }
}

fn tensor_bytes(t: &Tensor) -> &[u8] {
    unsafe { std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 4) }
}

/// Write a file atomically: the body streams into a temp sibling which is
/// flushed, fsynced, and renamed over `path` — a crash mid-save can't
/// leave a truncated checkpoint, and a failed overwrite leaves the
/// original intact.
fn atomic_write(
    path: &Path,
    body: impl FnOnce(&mut std::io::BufWriter<std::fs::File>) -> Result<()>,
) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut tmp_name = path
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("ckpt"));
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    let written = (|| -> Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        body(&mut w)?;
        w.flush()?;
        w.get_ref().sync_all()?;
        Ok(())
    })();
    if let Err(e) = written {
        std::fs::remove_file(&tmp).ok();
        return Err(e);
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        std::fs::remove_file(&tmp).ok();
        return Err(e).with_context(|| format!("renaming {tmp:?} into place"));
    }
    Ok(())
}

pub fn save(params: &ParamSet, path: &Path, meta: Json) -> Result<()> {
    save_packed(params, &BTreeMap::new(), path, meta)
}

/// Save a ParamSet plus true-INT4 packed tensors in the v2 (checksummed)
/// container.  With an empty `packed` map the packed section is simply
/// absent; the integrity object is always written.
pub fn save_packed(
    params: &ParamSet,
    packed: &BTreeMap<String, PackedTensor>,
    path: &Path,
    meta: Json,
) -> Result<()> {
    let mut tensors = Vec::new();
    let mut offset = 0u64;
    let mut f32_crc = Crc32::new();
    for (name, t) in params.iter() {
        tensors.push((
            name.clone(),
            Json::obj(vec![
                ("shape", Json::Arr(t.shape().iter().map(|&d| Json::Num(d as f64)).collect())),
                ("offset", Json::Num(offset as f64)),
            ]),
        ));
        offset += t.len() as u64;
        f32_crc.update(tensor_bytes(t));
    }
    let f32_bytes = offset * 4;
    let mut header_fields = vec![("meta", meta)];
    let tensors_json = Json::Obj(tensors.into_iter().collect());
    header_fields.push(("tensors", tensors_json));
    let mut packed_bytes = 0u64;
    let mut packed_crc = Crc32::new();
    if !packed.is_empty() {
        let mut entries = Vec::new();
        for (name, p) in packed {
            if params.contains(name) {
                bail!("'{name}' is both an f32 tensor and a packed tensor");
            }
            p.validate(name)?;
            entries.push((
                name.clone(),
                Json::obj(vec![
                    ("shape", Json::Arr(p.shape.iter().map(|&d| Json::Num(d as f64)).collect())),
                    ("group_size", Json::Num(p.group_size as f64)),
                    ("offset", Json::Num(packed_bytes as f64)),
                ]),
            ));
            packed_bytes += p.data.len() as u64;
            packed_crc.update(&p.data);
        }
        header_fields.push(("packed", Json::Obj(entries.into_iter().collect())));
        header_fields.push(("packed_bytes", Json::Num(packed_bytes as f64)));
    }
    header_fields.push((
        "integrity",
        Json::obj(vec![
            ("f32_bytes", Json::Num(f32_bytes as f64)),
            ("f32_crc", Json::Num(f32_crc.finish() as f64)),
            ("packed_crc", Json::Num(packed_crc.finish() as f64)),
        ]),
    ));
    let header = Json::obj(header_fields).to_string();

    atomic_write(path, |f| {
        f.write_all(MAGIC_V2)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(&crc32(header.as_bytes()).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, t) in params.iter() {
            f.write_all(tensor_bytes(t))?;
        }
        for p in packed.values() {
            f.write_all(&p.data)?;
        }
        Ok(())
    })
}

/// Parse one header number that must be a non-negative integer (tensor
/// dimensions, offsets, checksums).  Malformed headers are a typed
/// [`CorruptCheckpoint`] `Err`, never a panic.
fn header_uint(name: &str, what: &str, x: &Json) -> Result<usize> {
    let f = x.as_f64().map_err(|_| {
        corrupt(CkptSection::Header, format!("tensor '{name}': non-numeric {what}"))
    })?;
    if !f.is_finite() || f < 0.0 || f.fract() != 0.0 || f > 2f64.powi(53) {
        return Err(corrupt(
            CkptSection::Header,
            format!("tensor '{name}': invalid {what} {f}"),
        ));
    }
    Ok(f as usize)
}

/// Load a checkpoint that must not carry packed tensors (base models,
/// adapters).  A packed-tensor checkpoint here is a clear error — silently
/// dropping true-INT4 weights would "load" a model with no linear weights.
pub fn load(path: &Path) -> Result<(ParamSet, Json)> {
    let (params, packed, meta) = load_packed(path)?;
    if !packed.is_empty() {
        bail!(
            "{path:?} carries {} packed INT4 tensor(s); load it through the \
             INT4 model path (pipeline::load_int4_model / serve --merged-ckpt)",
            packed.len()
        );
    }
    Ok((params, meta))
}

/// Load a checkpoint including its packed-tensor section (empty map for
/// legacy files).  v2 files have every section checksum-verified; legacy
/// v1 files load without integrity checks.  All corruption outcomes are
/// typed [`CorruptCheckpoint`] errors naming the damaged section.
pub fn load_packed(path: &Path) -> Result<(ParamSet, BTreeMap<String, PackedTensor>, Json)> {
    load_packed_inner(path).with_context(|| format!("loading checkpoint {path:?}"))
}

fn load_packed_inner(path: &Path) -> Result<(ParamSet, BTreeMap<String, PackedTensor>, Json)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening checkpoint {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)
        .map_err(|_| corrupt(CkptSection::Magic, "file shorter than the magic prefix"))?;
    let v2 = match &magic {
        m if m == MAGIC_V2 => true,
        m if m == MAGIC_V1 => false,
        _ => return Err(corrupt(CkptSection::Magic, "not a SQFT checkpoint (bad magic)")),
    };
    let mut lenb = [0u8; 8];
    f.read_exact(&mut lenb)
        .map_err(|_| corrupt(CkptSection::Header, "truncated header length"))?;
    let hlen = u64::from_le_bytes(lenb) as usize;
    if hlen == 0 || hlen > MAX_HEADER_BYTES {
        return Err(corrupt(CkptSection::Header, format!("implausible header length {hlen}")));
    }
    let header_crc = if v2 {
        let mut crcb = [0u8; 4];
        f.read_exact(&mut crcb)
            .map_err(|_| corrupt(CkptSection::Header, "truncated header checksum"))?;
        Some(u32::from_le_bytes(crcb))
    } else {
        None
    };
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)
        .map_err(|_| corrupt(CkptSection::Header, "truncated header"))?;
    if let Some(want) = header_crc {
        let got = crc32(&hbuf);
        if got != want {
            return Err(corrupt(
                CkptSection::Header,
                format!("header checksum mismatch (stored {want:#010x}, computed {got:#010x})"),
            ));
        }
    }
    let htext = std::str::from_utf8(&hbuf)
        .map_err(|e| corrupt(CkptSection::Header, format!("header is not UTF-8: {e}")))?;
    let header = Json::parse(htext)
        .map_err(|e| corrupt(CkptSection::Header, format!("header is not valid JSON: {e}")))?;
    let meta = header
        .req("meta")
        .map_err(|_| corrupt(CkptSection::Header, "header missing 'meta'"))?
        .clone();

    let mut rest = Vec::new();
    f.read_to_end(&mut rest)?;
    // the trailing packed u8 region (absent in legacy checkpoints) is
    // delimited by the header's packed_bytes, so the f32 boundary is exact
    let packed_bytes = match header.get("packed_bytes") {
        Some(x) => header_uint("<packed>", "packed_bytes", x)?,
        None => 0,
    };
    let f32_end = if v2 {
        // v2 headers record the exact f32 payload length, so truncation is
        // attributed to the section the missing bytes belong to
        let integ = header
            .req("integrity")
            .map_err(|_| corrupt(CkptSection::Header, "v2 header missing 'integrity'"))?;
        let f32_bytes = header_uint("<integrity>", "f32_bytes", integ.req("f32_bytes")
            .map_err(|_| corrupt(CkptSection::Header, "integrity missing 'f32_bytes'"))?)?;
        let f32_crc = header_uint("<integrity>", "f32_crc", integ.req("f32_crc")
            .map_err(|_| corrupt(CkptSection::Header, "integrity missing 'f32_crc'"))?)?;
        let packed_crc = header_uint("<integrity>", "packed_crc", integ.req("packed_crc")
            .map_err(|_| corrupt(CkptSection::Header, "integrity missing 'packed_crc'"))?)?;
        if rest.len() < f32_bytes {
            return Err(corrupt(
                CkptSection::F32Data,
                format!("truncated: {} of {f32_bytes} f32-payload bytes present", rest.len()),
            ));
        }
        let total = f32_bytes + packed_bytes;
        if rest.len() != total {
            let sec =
                if packed_bytes > 0 { CkptSection::PackedData } else { CkptSection::F32Data };
            return Err(corrupt(
                sec,
                format!("payload is {} bytes, header declares {total}", rest.len()),
            ));
        }
        let got = crc32(&rest[..f32_bytes]);
        if got as usize != f32_crc {
            return Err(corrupt(
                CkptSection::F32Data,
                format!("checksum mismatch (stored {f32_crc:#010x}, computed {got:#010x})"),
            ));
        }
        let got = crc32(&rest[f32_bytes..]);
        if got as usize != packed_crc {
            return Err(corrupt(
                CkptSection::PackedData,
                format!("checksum mismatch (stored {packed_crc:#010x}, computed {got:#010x})"),
            ));
        }
        f32_bytes
    } else {
        if packed_bytes > rest.len() {
            return Err(corrupt(
                CkptSection::PackedData,
                format!("packed section ({packed_bytes} B) exceeds data"),
            ));
        }
        rest.len() - packed_bytes
    };
    if f32_end % 4 != 0 {
        return Err(corrupt(CkptSection::F32Data, "data section not f32-aligned"));
    }
    let floats: Vec<f32> = rest[..f32_end]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    let mut params = ParamSet::new();
    // (start, end, name) spans for the overlap check below
    let mut spans: Vec<(usize, usize, String)> = Vec::new();
    let tensors = header
        .req("tensors")
        .map_err(|_| corrupt(CkptSection::Header, "header missing 'tensors'"))?;
    for (name, desc) in tensors
        .as_obj()
        .map_err(|_| corrupt(CkptSection::Header, "'tensors' is not an object"))?
    {
        let shape: Vec<usize> = desc
            .req("shape")
            .map_err(|_| corrupt(CkptSection::Header, format!("tensor '{name}' missing shape")))?
            .as_arr()
            .map_err(|_| {
                corrupt(CkptSection::Header, format!("tensor '{name}' shape is not an array"))
            })?
            .iter()
            .map(|x| header_uint(name, "shape dimension", x))
            .collect::<Result<_>>()?;
        let offset = header_uint(
            name,
            "offset",
            desc.req("offset").map_err(|_| {
                corrupt(CkptSection::Header, format!("tensor '{name}' missing offset"))
            })?,
        )?;
        let n = shape.iter().try_fold(1usize, |acc, &d| acc.checked_mul(d)).ok_or_else(|| {
            corrupt(CkptSection::Header, format!("tensor '{name}' shape overflows"))
        })?;
        let end = offset.checked_add(n).ok_or_else(|| {
            corrupt(CkptSection::Header, format!("tensor '{name}' offset overflows"))
        })?;
        if end > floats.len() {
            return Err(corrupt(
                CkptSection::Header,
                format!("tensor '{name}' overruns data section"),
            ));
        }
        if n > 0 {
            spans.push((offset, end, name.clone()));
        }
        params.insert(name, Tensor::new(&shape, floats[offset..end].to_vec())?);
    }
    // tensors must not alias each other's data (duplicate or overlapping
    // offsets mean a corrupt writer, not a recoverable layout)
    spans.sort();
    for w in spans.windows(2) {
        if w[1].0 < w[0].1 {
            return Err(corrupt(
                CkptSection::Header,
                format!("tensors '{}' and '{}' overlap", w[0].2, w[1].2),
            ));
        }
    }

    let mut packed = BTreeMap::new();
    if let Some(pj) = header.get("packed") {
        let region = &rest[f32_end..];
        let mut pspans: Vec<(usize, usize, String)> = Vec::new();
        for (name, desc) in pj
            .as_obj()
            .map_err(|_| corrupt(CkptSection::Header, "'packed' is not an object"))?
        {
            let shape: Vec<usize> = desc
                .req("shape")
                .map_err(|_| {
                    corrupt(CkptSection::Header, format!("packed '{name}' missing shape"))
                })?
                .as_arr()
                .map_err(|_| {
                    corrupt(CkptSection::Header, format!("packed '{name}' shape is not an array"))
                })?
                .iter()
                .map(|x| header_uint(name, "shape dimension", x))
                .collect::<Result<_>>()?;
            let group_size = header_uint(
                name,
                "group_size",
                desc.req("group_size").map_err(|_| {
                    corrupt(CkptSection::Header, format!("packed '{name}' missing group_size"))
                })?,
            )?;
            let offset = header_uint(
                name,
                "offset",
                desc.req("offset").map_err(|_| {
                    corrupt(CkptSection::Header, format!("packed '{name}' missing offset"))
                })?,
            )?;
            let elems: usize =
                shape.iter().try_fold(1usize, |a, &d| a.checked_mul(d)).ok_or_else(|| {
                    corrupt(CkptSection::Header, format!("packed '{name}' shape overflows"))
                })?;
            let end = offset.checked_add(elems / 2).ok_or_else(|| {
                corrupt(CkptSection::Header, format!("packed '{name}' offset overflows"))
            })?;
            if end > region.len() {
                return Err(corrupt(
                    CkptSection::Header,
                    format!("packed '{name}' overruns packed section"),
                ));
            }
            let p = PackedTensor { shape, group_size, data: region[offset..end].to_vec() };
            p.validate(name)?;
            if elems > 0 {
                pspans.push((offset, end, name.clone()));
            }
            packed.insert(name.clone(), p);
        }
        pspans.sort();
        for w in pspans.windows(2) {
            if w[1].0 < w[0].1 {
                return Err(corrupt(
                    CkptSection::Header,
                    format!("packed '{}' and '{}' overlap", w[0].2, w[1].2),
                ));
            }
        }
    }
    Ok((params, packed, meta))
}

// ---------------------------------------------------------------------------
// Adapter checkpoints (multi-tenant serving)
// ---------------------------------------------------------------------------

/// A loaded per-tenant adapter checkpoint: tuned adapter tensors
/// (`a_`/`b_`/`mask_`), the realized NLS rank configuration
/// (`rankmask_`/`scale_`), and the serving metadata.
pub struct AdapterCkpt {
    pub adapters: ParamSet,
    pub rank_params: ParamSet,
    /// model config the adapter was tuned against
    pub config: String,
    /// eval artifact kind this adapter serves through ("eval" / "eval_qa")
    pub eval_kind: String,
    pub adapter_id: String,
    /// fine-tuning method (cli name) and base sparsity the adapter was
    /// exported from — the serving side must prepare a matching base
    pub method: String,
    pub sparsity: f64,
    pub meta: Json,
}

fn is_rank_param(name: &str) -> bool {
    name.starts_with("rankmask_") || name.starts_with("scale_")
}

/// Save a tuned adapter + its NLS rank configuration with adapter-aware
/// metadata (config, eval kind, method, base sparsity), so the serving
/// registry can validate it and `sqft serve` can prepare a matching base.
#[allow(clippy::too_many_arguments)]
pub fn save_adapter(
    path: &Path,
    adapters: &ParamSet,
    rank_params: &ParamSet,
    config: &str,
    eval_kind: &str,
    adapter_id: &str,
    method: &str,
    sparsity: f64,
) -> Result<()> {
    let mut combined = ParamSet::new();
    for (n, t) in adapters.iter() {
        if is_rank_param(n) {
            bail!("adapter set holds rank param '{n}'; pass it via rank_params");
        }
        combined.insert(n, t.clone());
    }
    for (n, t) in rank_params.iter() {
        if !is_rank_param(n) {
            bail!("rank param set holds non-rank tensor '{n}'");
        }
        combined.insert(n, t.clone());
    }
    let meta = Json::obj(vec![
        ("kind", Json::Str("adapter".into())),
        ("config", Json::Str(config.into())),
        ("eval_kind", Json::Str(eval_kind.into())),
        ("adapter_id", Json::Str(adapter_id.into())),
        ("method", Json::Str(method.into())),
        ("sparsity", Json::Num(sparsity)),
    ]);
    save(&combined, path, meta)
}

/// Load an adapter checkpoint written by `save_adapter`, splitting the
/// tensor set back into adapter state and rank configuration.
pub fn load_adapter(path: &Path) -> Result<AdapterCkpt> {
    let (params, meta) = load(path)?;
    let kind = meta.get("kind").and_then(|k| k.as_str().ok()).unwrap_or("");
    if kind != "adapter" {
        bail!("{path:?} is not an adapter checkpoint (kind '{kind}')");
    }
    let config = meta.req("config")?.as_str()?.to_string();
    let eval_kind = meta.req("eval_kind")?.as_str()?.to_string();
    let adapter_id = meta
        .get("adapter_id")
        .and_then(|x| x.as_str().ok())
        .unwrap_or("")
        .to_string();
    let method = meta
        .get("method")
        .and_then(|x| x.as_str().ok())
        .unwrap_or("")
        .to_string();
    let sparsity = meta.get("sparsity").and_then(|x| x.as_f64().ok()).unwrap_or(0.0);
    let mut adapters = ParamSet::new();
    let mut rank_params = ParamSet::new();
    for (n, t) in params.iter() {
        if is_rank_param(n) {
            rank_params.insert(n, t.clone());
        } else {
            adapters.insert(n, t.clone());
        }
    }
    Ok(AdapterCkpt { adapters, rank_params, config, eval_kind, adapter_id, method, sparsity, meta })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    /// Section a typed corruption error names, or None for untyped errors.
    fn section_of(e: &anyhow::Error) -> Option<CkptSection> {
        e.downcast_ref::<CorruptCheckpoint>().map(|c| c.section)
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(3);
        let mut p = ParamSet::new();
        p.insert("w1", Tensor::randn(&mut rng, &[3, 4], 1.0));
        p.insert("w2", Tensor::randn(&mut rng, &[2, 2, 2], 1.0));
        let dir = std::env::temp_dir().join("sqft_ckpt_test");
        let path = dir.join("test.ckpt");
        let meta = Json::obj(vec![("config", Json::Str("sqft-tiny".into()))]);
        save(&p, &path, meta).unwrap();
        let (q, m) = load(&path).unwrap();
        assert_eq!(m.get("config").unwrap().as_str().unwrap(), "sqft-tiny");
        assert_eq!(q.len(), 2);
        assert_eq!(q.get("w1").unwrap(), p.get("w1").unwrap());
        assert_eq!(q.get("w2").unwrap(), p.get("w2").unwrap());
        // saves are atomic: no temp sibling survives a successful write
        assert!(!dir.join("test.ckpt.tmp").exists());
        // and the container is v2
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], MAGIC_V2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("sqft_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        let e = load(&path).unwrap_err();
        assert_eq!(section_of(&e), Some(CkptSection::Magic), "{e:#}");
        // short files are a magic-section truncation, not a panic
        std::fs::write(&path, b"SQ").unwrap();
        let e = load(&path).unwrap_err();
        assert_eq!(section_of(&e), Some(CkptSection::Magic), "{e:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Hand-write a *legacy v1* container around an arbitrary header (the
    /// malformed-header tolerance below must hold for un-checksummed files).
    fn write_raw(path: &Path, header: &str, floats: &[f32]) {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC_V1);
        buf.extend_from_slice(&(header.len() as u64).to_le_bytes());
        buf.extend_from_slice(header.as_bytes());
        for f in floats {
            buf.extend_from_slice(&f.to_le_bytes());
        }
        std::fs::write(path, buf).unwrap();
    }

    #[test]
    fn malformed_headers_error_instead_of_panicking() {
        let dir = std::env::temp_dir().join("sqft_ckpt_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        let cases = [
            // non-numeric shape entry
            r#"{"meta":{},"tensors":{"w":{"shape":[2,"x"],"offset":0}}}"#,
            // negative dimension
            r#"{"meta":{},"tensors":{"w":{"shape":[-1],"offset":0}}}"#,
            // fractional dimension
            r#"{"meta":{},"tensors":{"w":{"shape":[1.5],"offset":0}}}"#,
            // fractional offset
            r#"{"meta":{},"tensors":{"w":{"shape":[2],"offset":0.5}}}"#,
            // missing offset
            r#"{"meta":{},"tensors":{"w":{"shape":[2]}}}"#,
            // overrun
            r#"{"meta":{},"tensors":{"w":{"shape":[8],"offset":0}}}"#,
        ];
        for header in cases {
            write_raw(&path, header, &[1.0, 2.0, 3.0, 4.0]);
            let e = load(&path).unwrap_err();
            assert_eq!(
                section_of(&e),
                Some(CkptSection::Header),
                "malformed header not typed: {header} -> {e:#}"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_overlapping_and_duplicate_tensor_offsets() {
        let dir = std::env::temp_dir().join("sqft_ckpt_test4");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("overlap.ckpt");
        // u spans [0,2), v spans [1,3): overlap
        write_raw(
            &path,
            r#"{"meta":{},"tensors":{"u":{"shape":[2],"offset":0},"v":{"shape":[2],"offset":1}}}"#,
            &[1.0, 2.0, 3.0, 4.0],
        );
        let e = load(&path).unwrap_err();
        assert!(format!("{e:#}").contains("overlap"), "{e:#}");
        // duplicate offsets are also an overlap
        write_raw(
            &path,
            r#"{"meta":{},"tensors":{"u":{"shape":[2],"offset":0},"v":{"shape":[2],"offset":0}}}"#,
            &[1.0, 2.0, 3.0, 4.0],
        );
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn packed_section_roundtrips_and_legacy_files_read_back() {
        let mut rng = Rng::new(9);
        let mut p = ParamSet::new();
        p.insert("embed", Tensor::randn(&mut rng, &[4, 6], 1.0));
        p.insert("qscales_wq", Tensor::randn(&mut rng, &[2, 4, 2], 0.1));
        let mut packed = BTreeMap::new();
        packed.insert(
            "packed_wq".to_string(),
            PackedTensor {
                shape: vec![2, 4, 8],
                group_size: 4,
                data: (0..32u8).collect(),
            },
        );
        let dir = std::env::temp_dir().join("sqft_ckpt_packed");
        let path = dir.join("int4.ckpt");
        let meta = Json::obj(vec![("kind", Json::Str("int4-model".into()))]);
        save_packed(&p, &packed, &path, meta).unwrap();
        let (q, pk, m) = load_packed(&path).unwrap();
        assert_eq!(m.get("kind").unwrap().as_str().unwrap(), "int4-model");
        assert_eq!(q.get("embed").unwrap(), p.get("embed").unwrap());
        assert_eq!(pk.len(), 1);
        assert_eq!(pk["packed_wq"], packed["packed_wq"]);
        // the plain loader refuses packed checkpoints instead of silently
        // dropping the INT4 weights
        let e = load(&path).unwrap_err();
        assert!(format!("{e:#}").contains("packed"), "{e:#}");
        // legacy v1 (no packed section, no integrity) files still read back
        // through both loaders, unchecked
        let legacy = dir.join("legacy.ckpt");
        {
            let mut buf = Vec::new();
            buf.extend_from_slice(MAGIC_V1);
            let mut tensors = Vec::new();
            let mut offset = 0usize;
            let mut payload = Vec::new();
            for (name, t) in p.iter() {
                tensors.push((
                    name.clone(),
                    Json::obj(vec![
                        (
                            "shape",
                            Json::Arr(t.shape().iter().map(|&d| Json::Num(d as f64)).collect()),
                        ),
                        ("offset", Json::Num(offset as f64)),
                    ]),
                ));
                offset += t.len();
                payload.extend_from_slice(tensor_bytes(t));
            }
            let header = Json::obj(vec![
                ("meta", Json::obj(vec![])),
                ("tensors", Json::Obj(tensors.into_iter().collect())),
            ])
            .to_string();
            buf.extend_from_slice(&(header.len() as u64).to_le_bytes());
            buf.extend_from_slice(header.as_bytes());
            buf.extend_from_slice(&payload);
            std::fs::write(&legacy, buf).unwrap();
        }
        let (q2, m2) = load(&legacy).unwrap();
        assert_eq!(q2.len(), 2);
        assert_eq!(q2.get("embed").unwrap(), p.get("embed").unwrap());
        let _ = m2;
        let (_, pk2, _) = load_packed(&legacy).unwrap();
        assert!(pk2.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_malformed_packed_sections() {
        let dir = std::env::temp_dir().join("sqft_ckpt_packed_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.ckpt");
        // save-side validation: payload length, odd in-dim, group size,
        // f32/packed name collision
        let p = ParamSet::new();
        let bad_len = PackedTensor { shape: vec![1, 2, 8], group_size: 4, data: vec![0; 7] };
        let mut m = BTreeMap::new();
        m.insert("x".to_string(), bad_len);
        assert!(save_packed(&p, &m, &path, Json::obj(vec![])).is_err());
        let odd = PackedTensor { shape: vec![1, 2, 5], group_size: 5, data: vec![0; 5] };
        m.insert("x".to_string(), odd);
        assert!(save_packed(&p, &m, &path, Json::obj(vec![])).is_err());
        let bad_gs = PackedTensor { shape: vec![1, 2, 8], group_size: 3, data: vec![0; 8] };
        m.insert("x".to_string(), bad_gs);
        assert!(save_packed(&p, &m, &path, Json::obj(vec![])).is_err());
        let ok = PackedTensor { shape: vec![1, 2, 8], group_size: 4, data: vec![0; 8] };
        let mut p2 = ParamSet::new();
        p2.insert("x", Tensor::zeros(&[2]));
        m.insert("x".to_string(), ok);
        assert!(save_packed(&p2, &m, &path, Json::obj(vec![])).is_err());
        // failed saves leave no temp sibling behind
        assert!(!dir.join("bad.ckpt.tmp").exists());
        // load-side validation: overruns and overlaps in the packed header
        // (legacy container so the structural checks run without checksums)
        let cases = [
            // overruns the 4-byte packed region
            (r#"{"meta":{},"tensors":{},"packed":{"w":{"shape":[2,8],"group_size":4,"offset":0}},"packed_bytes":4}"#,
             4usize),
            // packed_bytes exceeds the file payload
            (r#"{"meta":{},"tensors":{},"packed":{},"packed_bytes":64}"#, 4),
            // overlapping packed entries
            (r#"{"meta":{},"tensors":{},"packed":{"u":{"shape":[1,4],"group_size":4,"offset":0},"v":{"shape":[1,4],"group_size":4,"offset":1}},"packed_bytes":4}"#,
             4),
        ];
        for (header, nbytes) in cases {
            let mut buf = Vec::new();
            buf.extend_from_slice(MAGIC_V1);
            buf.extend_from_slice(&(header.len() as u64).to_le_bytes());
            buf.extend_from_slice(header.as_bytes());
            buf.extend_from_slice(&vec![0u8; nbytes]);
            std::fs::write(&path, buf).unwrap();
            assert!(load_packed(&path).is_err(), "accepted: {header}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn checksums_catch_payload_bitflips() {
        let mut rng = Rng::new(11);
        let mut p = ParamSet::new();
        p.insert("w", Tensor::randn(&mut rng, &[4, 4], 1.0));
        let mut packed = BTreeMap::new();
        packed.insert(
            "packed_w".to_string(),
            PackedTensor { shape: vec![1, 2, 8], group_size: 4, data: (0..8u8).collect() },
        );
        let dir = std::env::temp_dir().join("sqft_ckpt_crc");
        let path = dir.join("crc.ckpt");
        save_packed(&p, &packed, &path, Json::obj(vec![])).unwrap();
        let good = std::fs::read(&path).unwrap();
        // locate sections: magic 8 | hlen 8 | hcrc 4 | header | f32 | packed
        let hlen = u64::from_le_bytes(good[8..16].try_into().unwrap()) as usize;
        let header_start = 20;
        let f32_start = header_start + hlen;
        let packed_start = good.len() - 8;
        let flips = [
            (header_start + hlen / 2, CkptSection::Header),
            (f32_start + 5, CkptSection::F32Data),
            (packed_start + 3, CkptSection::PackedData),
        ];
        for (at, want) in flips {
            let mut bad = good.clone();
            bad[at] ^= 0x10;
            std::fs::write(&path, &bad).unwrap();
            let e = load_packed(&path).unwrap_err();
            assert_eq!(section_of(&e), Some(want), "flip at {at}: {e:#}");
        }
        // pristine bytes still load
        std::fs::write(&path, &good).unwrap();
        load_packed(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adapter_roundtrip_splits_rank_params() {
        let mut rng = Rng::new(5);
        let mut adapters = ParamSet::new();
        adapters.insert("a_q", Tensor::randn(&mut rng, &[2, 4, 8], 0.02));
        adapters.insert("b_q", Tensor::zeros(&[2, 8, 4]));
        adapters.insert("mask_q", Tensor::ones(&[2, 8, 8]));
        let mut rank = ParamSet::new();
        rank.insert("rankmask_q", Tensor::ones(&[2, 4]));
        rank.insert("scale_q", Tensor::full(&[2], 4.0));
        let dir = std::env::temp_dir().join("sqft_ckpt_test5");
        let path = dir.join("tenant0.ckpt");
        save_adapter(&path, &adapters, &rank, "sqft-tiny", "eval", "tenant0",
                     "sparsepeft", 0.5).unwrap();
        let ck = load_adapter(&path).unwrap();
        assert_eq!(ck.config, "sqft-tiny");
        assert_eq!(ck.eval_kind, "eval");
        assert_eq!(ck.adapter_id, "tenant0");
        assert_eq!(ck.method, "sparsepeft");
        assert!((ck.sparsity - 0.5).abs() < 1e-12);
        assert_eq!(ck.adapters.len(), 3);
        assert_eq!(ck.rank_params.len(), 2);
        assert_eq!(ck.adapters.get("a_q").unwrap(), adapters.get("a_q").unwrap());
        assert_eq!(ck.rank_params.get("scale_q").unwrap(), rank.get("scale_q").unwrap());
        // a base checkpoint is not an adapter checkpoint
        let base_path = dir.join("base.ckpt");
        save(&adapters, &base_path, Json::obj(vec![("config", Json::Str("x".into()))])).unwrap();
        assert!(load_adapter(&base_path).is_err());
        // rank params in the adapter set are rejected at save time
        let mut bad = ParamSet::new();
        bad.insert("rankmask_q", Tensor::ones(&[2, 4]));
        assert!(save_adapter(&path, &bad, &rank, "c", "eval", "t", "lora", 0.0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
