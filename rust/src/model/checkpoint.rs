//! Binary checkpoint format for ParamSets.
//!
//! Layout: magic "SQFTCKP1" | u64 header_len | JSON header | raw f32 data.
//! The header maps each tensor name to {shape, offset} (offsets in f32
//! elements into the data section, in header order).  Endianness: little
//! (the only platform we target); the magic encodes the version.

use super::ParamSet;
use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"SQFTCKP1";

pub fn save(params: &ParamSet, path: &Path, meta: Json) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut tensors = Vec::new();
    let mut offset = 0u64;
    for (name, t) in params.iter() {
        tensors.push((
            name.clone(),
            Json::obj(vec![
                ("shape", Json::Arr(t.shape().iter().map(|&d| Json::Num(d as f64)).collect())),
                ("offset", Json::Num(offset as f64)),
            ]),
        ));
        offset += t.len() as u64;
    }
    let header = Json::obj(vec![
        ("meta", meta),
        ("tensors", Json::Obj(tensors.into_iter().collect())),
    ])
    .to_string();

    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&(header.len() as u64).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    for (_, t) in params.iter() {
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 4)
        };
        f.write_all(bytes)?;
    }
    f.flush()?;
    Ok(())
}

pub fn load(path: &Path) -> Result<(ParamSet, Json)> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path).with_context(|| format!("opening checkpoint {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?} is not a SQFT checkpoint (bad magic)");
    }
    let mut lenb = [0u8; 8];
    f.read_exact(&mut lenb)?;
    let hlen = u64::from_le_bytes(lenb) as usize;
    let mut hbuf = vec![0u8; hlen];
    f.read_exact(&mut hbuf)?;
    let header = Json::parse(std::str::from_utf8(&hbuf)?)?;
    let meta = header.req("meta")?.clone();

    let mut rest = Vec::new();
    f.read_to_end(&mut rest)?;
    if rest.len() % 4 != 0 {
        bail!("corrupt checkpoint: data section not f32-aligned");
    }
    let floats: Vec<f32> = rest
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();

    let mut params = ParamSet::new();
    for (name, desc) in header.req("tensors")?.as_obj()? {
        let shape: Vec<usize> =
            desc.req("shape")?.as_arr()?.iter().map(|x| x.as_usize().unwrap()).collect();
        let offset = desc.req("offset")?.as_usize()?;
        let n: usize = shape.iter().product();
        if offset + n > floats.len() {
            bail!("corrupt checkpoint: tensor '{name}' overruns data section");
        }
        params.insert(name, Tensor::new(&shape, floats[offset..offset + n].to_vec())?);
    }
    Ok((params, meta))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(3);
        let mut p = ParamSet::new();
        p.insert("w1", Tensor::randn(&mut rng, &[3, 4], 1.0));
        p.insert("w2", Tensor::randn(&mut rng, &[2, 2, 2], 1.0));
        let dir = std::env::temp_dir().join("sqft_ckpt_test");
        let path = dir.join("test.ckpt");
        let meta = Json::obj(vec![("config", Json::Str("sqft-tiny".into()))]);
        save(&p, &path, meta).unwrap();
        let (q, m) = load(&path).unwrap();
        assert_eq!(m.get("config").unwrap().as_str().unwrap(), "sqft-tiny");
        assert_eq!(q.len(), 2);
        assert_eq!(q.get("w1").unwrap(), p.get("w1").unwrap());
        assert_eq!(q.get("w2").unwrap(), p.get("w2").unwrap());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("sqft_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ckpt");
        std::fs::write(&path, b"NOTACKPTxxxxxxxxxxxx").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
