//! Round-to-nearest quantization — the baseline GPTQ improves on.

use super::{group_params, qmax, QuantResult};
use crate::tensor::Tensor;
use anyhow::Result;

/// Quantize `w` (out, in) group-wise with plain rounding.  When `mask` is
/// given, masked entries are forced to code `z` (dequant exactly 0), so
/// sparsity survives quantization.
pub fn rtn_quantize(w: &Tensor, group_size: usize, bits: u32,
                    mask: Option<&Tensor>) -> Result<QuantResult> {
    let (out, inp) = (w.rows(), w.cols());
    let (scales, zeros) = group_params(w, group_size, bits, mask)?;
    let qm = qmax(bits);
    let mut codes = Tensor::zeros(&[out, inp]);
    let mut dequant = Tensor::zeros(&[out, inp]);
    for i in 0..out {
        for j in 0..inp {
            let s = scales.at2(i, j / group_size);
            let z = zeros.at2(i, j / group_size);
            let masked = mask.map(|m| m.at2(i, j) == 0.0).unwrap_or(false);
            let q = if masked {
                z
            } else {
                ((w.at2(i, j) / s).round() + z).clamp(0.0, qm)
            };
            codes.set2(i, j, q);
            dequant.set2(i, j, (q - z) * s);
        }
    }
    Ok(QuantResult { codes, scales, zeros, dequant })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn reconstruction_error_is_bounded_by_scale() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&mut rng, &[8, 32], 0.3);
        let qr = rtn_quantize(&w, 16, 4, None).unwrap();
        for i in 0..8 {
            for j in 0..32 {
                let s = qr.scales.at2(i, j / 16);
                assert!((qr.dequant.at2(i, j) - w.at2(i, j)).abs() <= 0.5 * s + 1e-6);
            }
        }
    }

    #[test]
    fn preserves_sparsity_exactly() {
        let mut rng = Rng::new(2);
        let w0 = Tensor::randn(&mut rng, &[4, 32], 0.3);
        let mask = Tensor::new(
            &[4, 32], (0..128).map(|i| ((i * 7) % 3 != 0) as i32 as f32).collect()).unwrap();
        let w = w0.mul(&mask).unwrap();
        let qr = rtn_quantize(&w, 16, 4, Some(&mask)).unwrap();
        for i in 0..4 {
            for j in 0..32 {
                if mask.at2(i, j) == 0.0 {
                    assert_eq!(qr.dequant.at2(i, j), 0.0, "sparsity lost at ({i},{j})");
                }
            }
        }
        assert!(qr.dequant.sparsity() >= w.sparsity() - 1e-9);
    }

    #[test]
    fn codes_in_range() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&mut rng, &[4, 16], 2.0);
        let qr = rtn_quantize(&w, 8, 4, None).unwrap();
        assert!(qr.codes.data().iter().all(|&c| (0.0..=15.0).contains(&c)));
        assert!(qr.codes.data().iter().all(|&c| c == c.round()));
    }
}
