//! INT4 nibble packing — true 4-bit storage for the Table 6/7 model-storage
//! and inference-memory metrics (low nibble = even column, matching the L1
//! int4 kernel's unpack order).

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Pack integer codes (out, in) with values in [0,15] into (out, in/2) bytes.
pub fn pack_int4(codes: &Tensor) -> Result<Vec<u8>> {
    let (out, inp) = (codes.rows(), codes.cols());
    if inp % 2 != 0 {
        bail!("pack_int4: odd in-dim {inp}");
    }
    let mut bytes = Vec::with_capacity(out * inp / 2);
    for i in 0..out {
        let row = codes.row(i);
        for j in (0..inp).step_by(2) {
            let lo = row[j] as u8;
            let hi = row[j + 1] as u8;
            if lo > 15 || hi > 15 || row[j] < 0.0 || row[j + 1] < 0.0 {
                bail!("pack_int4: code out of range at ({i},{j})");
            }
            bytes.push(lo | (hi << 4));
        }
    }
    Ok(bytes)
}

/// Inverse of `pack_int4`.
pub fn unpack_int4(bytes: &[u8], out: usize, inp: usize) -> Result<Tensor> {
    if bytes.len() != out * inp / 2 {
        bail!("unpack_int4: {} bytes for ({out},{inp})", bytes.len());
    }
    let mut t = Tensor::zeros(&[out, inp]);
    for i in 0..out {
        for j in (0..inp).step_by(2) {
            let b = bytes[i * inp / 2 + j / 2];
            t.set2(i, j, (b & 0xF) as f32);
            t.set2(i, j + 1, ((b >> 4) & 0xF) as f32);
        }
    }
    Ok(t)
}

/// Storage bytes of an INT4-packed matrix incl. FP16 group params
/// (scales+zeros at 2 bytes each) — used for the Table 7 storage column.
pub fn int4_storage_bytes(out: usize, inp: usize, group_size: usize) -> usize {
    out * inp / 2 + 2 * 2 * out * (inp / group_size)
}

/// FP16 storage of the same matrix.
pub fn fp16_storage_bytes(out: usize, inp: usize) -> usize {
    out * inp * 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let codes = Tensor::new(
            &[4, 8], (0..32).map(|_| rng.below(16) as f32).collect()).unwrap();
        let bytes = pack_int4(&codes).unwrap();
        assert_eq!(bytes.len(), 16);
        let back = unpack_int4(&bytes, 4, 8).unwrap();
        assert_eq!(back, codes);
    }

    #[test]
    fn nibble_order_matches_l1_kernel() {
        // kernel convention: low nibble first
        let codes = Tensor::new(&[1, 4], vec![1., 2., 3., 4.]).unwrap();
        let bytes = pack_int4(&codes).unwrap();
        assert_eq!(bytes, vec![0x21, 0x43]);
    }

    #[test]
    fn rejects_out_of_range() {
        let codes = Tensor::new(&[1, 2], vec![16., 0.]).unwrap();
        assert!(pack_int4(&codes).is_err());
        assert!(unpack_int4(&[0u8; 3], 1, 4).is_err());
    }

    #[test]
    fn storage_ratio_close_to_4x() {
        let int4 = int4_storage_bytes(1024, 1024, 32) as f64;
        let fp16 = fp16_storage_bytes(1024, 1024) as f64;
        let ratio = fp16 / int4;
        assert!(ratio > 3.0 && ratio < 4.0, "ratio={ratio}");
    }
}
