//! INT4 nibble packing — true 4-bit storage for the Table 6/7 model-storage
//! and inference-memory metrics (low nibble = even column, matching the L1
//! int4 kernel's unpack order).  The packed bytes are exactly what the
//! `eval_int4` serving artifacts take as `packed_*` u8 inputs and what the
//! checkpoint packed-tensor section stores on disk.

use crate::tensor::Tensor;
use anyhow::{bail, Result};

/// Validate one code value: finite, integral, in [0, 15].  `NaN as u8` is 0
/// and `3.7 as u8` truncates to 3 — both silently corrupt the packed bytes,
/// so every cast is gated on this check.
#[inline]
fn check_code(v: f32, i: usize, j: usize) -> Result<u8> {
    if !v.is_finite() || v.fract() != 0.0 {
        bail!("pack_int4: non-integral code {v} at ({i},{j})");
    }
    if !(0.0..=15.0).contains(&v) {
        bail!("pack_int4: code {v} out of range [0,15] at ({i},{j})");
    }
    Ok(v as u8)
}

/// Pack `rows` rows of `inp` contiguous codes each (row-major `data`).
fn pack_rows(data: &[f32], rows: usize, inp: usize) -> Result<Vec<u8>> {
    if inp % 2 != 0 {
        bail!("pack_int4: odd in-dim {inp}");
    }
    let mut bytes = Vec::with_capacity(rows * inp / 2);
    for i in 0..rows {
        let row = &data[i * inp..(i + 1) * inp];
        for j in (0..inp).step_by(2) {
            let lo = check_code(row[j], i, j)?;
            let hi = check_code(row[j + 1], i, j + 1)?;
            bytes.push(lo | (hi << 4));
        }
    }
    Ok(bytes)
}

/// Pack integer codes (out, in) with values in [0,15] into (out, in/2) bytes.
pub fn pack_int4(codes: &Tensor) -> Result<Vec<u8>> {
    pack_rows(codes.data(), codes.rows(), codes.cols())
}

/// Pack a stacked (L, out, in) code tensor layer-contiguously — the layout
/// the eval_int4 artifacts' `packed_*` inputs and the checkpoint packed
/// section use.  Bytewise identical to packing each layer and concatenating
/// (rows are contiguous either way, so no copy of the stack is made).
pub fn pack_int4_stack(codes: &Tensor) -> Result<Vec<u8>> {
    let shape = codes.shape();
    if shape.len() != 3 {
        bail!("pack_int4_stack: want a (L, out, in) stack, got {shape:?}");
    }
    pack_rows(codes.data(), shape[0] * shape[1], shape[2])
}

/// Inverse of `pack_int4`.
pub fn unpack_int4(bytes: &[u8], out: usize, inp: usize) -> Result<Tensor> {
    if inp % 2 != 0 {
        bail!("unpack_int4: odd in-dim {inp}");
    }
    if bytes.len() != out * inp / 2 {
        bail!("unpack_int4: {} bytes for ({out},{inp})", bytes.len());
    }
    let mut t = Tensor::zeros(&[out, inp]);
    for i in 0..out {
        for j in (0..inp).step_by(2) {
            let b = bytes[i * inp / 2 + j / 2];
            t.set2(i, j, (b & 0xF) as f32);
            t.set2(i, j + 1, ((b >> 4) & 0xF) as f32);
        }
    }
    Ok(t)
}

/// Inverse of `pack_int4_stack`: bytes back to a (L, out, in) code stack.
pub fn unpack_int4_stack(bytes: &[u8], shape: &[usize]) -> Result<Tensor> {
    if shape.len() != 3 {
        bail!("unpack_int4_stack: want a (L, out, in) shape, got {shape:?}");
    }
    unpack_int4(bytes, shape[0] * shape[1], shape[2])?.reshape(shape)
}

/// Storage bytes of an INT4-packed matrix incl. FP16 group params
/// (scales+zeros at 2 bytes each) — used for the Table 7 storage column.
///
/// Dims that don't pack/group evenly are an error, not a truncation: the
/// old `inp / group_size` silently dropped the trailing partial group and
/// `out * inp / 2` under-counted odd in-dims, so callers compared against
/// a footprint no real packed layout could have.
pub fn int4_storage_bytes(out: usize, inp: usize, group_size: usize) -> Result<usize> {
    if inp % 2 != 0 {
        bail!("int4_storage_bytes: odd in-dim {inp}");
    }
    if group_size == 0 || inp % group_size != 0 {
        bail!("int4_storage_bytes: group size {group_size} does not divide in-dim {inp}");
    }
    Ok(out * inp / 2 + 2 * 2 * out * (inp / group_size))
}

/// FP16 storage of the same matrix.
pub fn fp16_storage_bytes(out: usize, inp: usize) -> usize {
    out * inp * 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(1);
        let codes = Tensor::new(
            &[4, 8], (0..32).map(|_| rng.below(16) as f32).collect()).unwrap();
        let bytes = pack_int4(&codes).unwrap();
        assert_eq!(bytes.len(), 16);
        let back = unpack_int4(&bytes, 4, 8).unwrap();
        assert_eq!(back, codes);
    }

    #[test]
    fn stack_roundtrip_matches_per_layer_packing() {
        let mut rng = Rng::new(2);
        let codes = Tensor::new(
            &[3, 4, 8], (0..96).map(|_| rng.below(16) as f32).collect()).unwrap();
        let bytes = pack_int4_stack(&codes).unwrap();
        assert_eq!(bytes.len(), 48);
        let mut per_layer = Vec::new();
        for l in 0..3 {
            per_layer.extend(pack_int4(&codes.index0(l)).unwrap());
        }
        assert_eq!(bytes, per_layer);
        let back = unpack_int4_stack(&bytes, &[3, 4, 8]).unwrap();
        assert_eq!(back, codes);
        // non-3d stacks are rejected
        assert!(pack_int4_stack(&Tensor::zeros(&[4, 8])).is_err());
        assert!(unpack_int4_stack(&bytes, &[3, 4]).is_err());
    }

    #[test]
    fn nibble_order_matches_l1_kernel() {
        // kernel convention: low nibble first
        let codes = Tensor::new(&[1, 4], vec![1., 2., 3., 4.]).unwrap();
        let bytes = pack_int4(&codes).unwrap();
        assert_eq!(bytes, vec![0x21, 0x43]);
    }

    #[test]
    fn rejects_out_of_range() {
        let codes = Tensor::new(&[1, 2], vec![16., 0.]).unwrap();
        assert!(pack_int4(&codes).is_err());
        let codes = Tensor::new(&[1, 2], vec![-1., 0.]).unwrap();
        assert!(pack_int4(&codes).is_err());
        assert!(unpack_int4(&[0u8; 3], 1, 4).is_err());
    }

    #[test]
    fn rejects_non_finite_and_fractional_codes() {
        // regression: NaN compares false against both range bounds and
        // `NaN as u8` is 0, so NaN codes used to pack silently as 0
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 3.7, -0.5] {
            let codes = Tensor::new(&[1, 2], vec![bad, 1.0]).unwrap();
            let err = pack_int4(&codes).unwrap_err();
            assert!(
                format!("{err:#}").contains("code"),
                "unexpected error for {bad}: {err:#}"
            );
        }
        // -0.0 is an integral in-range value, not an error
        let codes = Tensor::new(&[1, 2], vec![-0.0, 15.0]).unwrap();
        assert_eq!(pack_int4(&codes).unwrap(), vec![0xF0]);
    }

    #[test]
    fn odd_dims_error_instead_of_truncating() {
        // regression: unpack_int4 with odd inp used to panic past the
        // buffer instead of rejecting the shape
        let codes = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert!(pack_int4(&codes).is_err());
        assert!(unpack_int4(&[0u8; 3], 2, 3).is_err());
        assert!(unpack_int4(&[0u8; 2], 1, 5).is_err());
    }

    #[test]
    fn storage_bytes_reject_non_dividing_dims() {
        // regression: inp/group_size truncated, under-counting the group
        // params of any layout a real packed matrix could not have anyway
        assert!(int4_storage_bytes(4, 10, 4).is_err());
        assert!(int4_storage_bytes(4, 7, 7).is_err());
        assert!(int4_storage_bytes(4, 16, 0).is_err());
        assert_eq!(int4_storage_bytes(4, 16, 8).unwrap(), 4 * 8 + 4 * 4 * 2);
    }

    #[test]
    fn storage_ratio_close_to_4x() {
        let int4 = int4_storage_bytes(1024, 1024, 32).unwrap() as f64;
        let fp16 = fp16_storage_bytes(1024, 1024) as f64;
        let ratio = fp16 / int4;
        assert!(ratio > 3.0 && ratio < 4.0, "ratio={ratio}");
    }
}
