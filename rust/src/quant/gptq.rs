//! GPTQ (Frantar et al. 2022) from scratch — the paper's default one-shot
//! quantizer (§2.1: argmin ||W X − Ŵ X||²).
//!
//! Per output row, columns are quantized left-to-right; the rounding error
//! of column i is propagated into the still-unquantized columns via the
//! inverse-Hessian Cholesky factor (OBQ's closed-form update, blocked as in
//! the reference implementation):
//!
//! ```text
//! U = chol(H^{-1}) (upper),  err_i = (w_i - q_i) / U[i,i]
//! w_j -= err_i * U[i,j]   for j > i
//! ```
//!
//! Sparsity interplay (SQFT runs GPTQ *after* Wanda): masked entries are
//! pinned — their code is the zero-point (dequant exactly 0) and the error
//! feedback never resurrects them; feedback into masked positions is
//! re-projected to zero.  This preserves S{W} through quantization, which
//! the paper's merge claims depend on.

use super::{group_params, qmax, QuantResult};
use crate::tensor::linalg::gptq_hinv_factor;
use crate::tensor::Tensor;
use anyhow::Result;

/// Quantize one output row: left-to-right column quantization with error
/// feedback into the row's unquantized tail.  Rows never exchange state
/// (the Hessian factor is shared read-only), which is what makes the
/// row-parallel driver below exact — identical arithmetic order per row
/// means byte-identical results at any thread count.
#[allow(clippy::too_many_arguments)]
fn quantize_row(
    wrow_in: &[f32],
    srow: &[f32],
    zrow: &[f32],
    mrow: Option<&[f32]>,
    u: &Tensor,
    group_size: usize,
    qm: f32,
    crow: &mut [f32],
    drow: &mut [f32],
) {
    let inp = wrow_in.len();
    // per-row working copy with error feedback applied
    let mut work = wrow_in.to_vec();
    for j in 0..inp {
        let s = srow[j / group_size];
        let z = zrow[j / group_size];
        let masked = mrow.map(|m| m[j] == 0.0).unwrap_or(false);
        let wv = work[j];
        let q = if masked { z } else { ((wv / s).round() + z).clamp(0.0, qm) };
        let dq = (q - z) * s;
        crow[j] = q;
        drow[j] = dq;
        // error feedback into the unquantized tail of this row
        let d = u.at2(j, j);
        if d != 0.0 {
            let err = (wv - dq) / d;
            if err != 0.0 {
                let urow = &u.data()[j * inp..(j + 1) * inp];
                for t in (j + 1)..inp {
                    work[t] -= err * urow[t];
                }
                // re-project: masked tail entries stay structurally zero
                if let Some(m) = mrow {
                    for t in (j + 1)..inp {
                        if m[t] == 0.0 {
                            work[t] = 0.0;
                        }
                    }
                }
            }
        }
    }
}

/// Quantize `w` (out, in) given the calibration Gram/Hessian `h` (in, in).
///
/// Output rows are independent (each owns its error-feedback working
/// copy), so they are fanned out across `std::thread::scope` row chunks;
/// results are byte-identical to the sequential order.
pub fn gptq_quantize(
    w: &Tensor,
    h: &Tensor,
    group_size: usize,
    bits: u32,
    mask: Option<&Tensor>,
    percdamp: f32,
) -> Result<QuantResult> {
    let (out, inp) = (w.rows(), w.cols());
    let qm = qmax(bits);
    // group params are computed from the original weights (act-order off),
    // masked-aware so the zero-point lands on the grid; rejects group
    // sizes that don't divide the in-dim (OOB reads downstream otherwise)
    let (scales, zeros) = group_params(w, group_size, bits, mask)?;
    let u = gptq_hinv_factor(h, percdamp)?; // upper triangular (in, in)

    let mut codes = Tensor::zeros(&[out, inp]);
    let mut dequant = Tensor::zeros(&[out, inp]);
    if out > 0 && inp > 0 {
        let n_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(out)
            .max(1);
        let rows_per = out.div_ceil(n_threads);
        let (scales_ref, zeros_ref, u_ref) = (&scales, &zeros, &u);
        std::thread::scope(|s| {
            for (ci, (crows, drows)) in codes
                .data_mut()
                .chunks_mut(rows_per * inp)
                .zip(dequant.data_mut().chunks_mut(rows_per * inp))
                .enumerate()
            {
                let row0 = ci * rows_per;
                s.spawn(move || {
                    for (k, (crow, drow)) in
                        crows.chunks_mut(inp).zip(drows.chunks_mut(inp)).enumerate()
                    {
                        let i = row0 + k;
                        quantize_row(
                            w.row(i),
                            scales_ref.row(i),
                            zeros_ref.row(i),
                            mask.map(|m| m.row(i)),
                            u_ref,
                            group_size,
                            qm,
                            crow,
                            drow,
                        );
                    }
                });
            }
        });
    }
    Ok(QuantResult { codes, scales, zeros, dequant })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn_quantize;
    use crate::tensor::Rng;

    fn gram(rng: &mut Rng, t: usize, n: usize) -> Tensor {
        let x = Tensor::randn(rng, &[t, n], 1.0);
        let mut h = Tensor::zeros(&[n, n]);
        x.accumulate_gram(&mut h);
        h
    }

    #[test]
    fn beats_rtn_on_weighted_error() {
        // GPTQ's whole point: lower ||(W-Ŵ)X||² than naive rounding.
        let mut rng = Rng::new(1);
        let n = 32;
        let w = Tensor::randn(&mut rng, &[16, n], 0.4);
        let h = gram(&mut rng, 128, n);
        let g = gptq_quantize(&w, &h, 16, 4, None, 0.01).unwrap();
        let r = rtn_quantize(&w, 16, 4, None).unwrap();
        let ge = g.weighted_err(&w, &h);
        let re = r.weighted_err(&w, &h);
        assert!(ge <= re * 1.001, "gptq {ge} vs rtn {re}");
        // and strictly better in the typical case
        assert!(ge < re, "gptq {ge} vs rtn {re}");
    }

    #[test]
    fn preserves_sparsity_exactly() {
        let mut rng = Rng::new(2);
        let n = 32;
        let w0 = Tensor::randn(&mut rng, &[8, n], 0.4);
        let mask_data: Vec<f32> = (0..8 * n).map(|_| (rng.next_f32() > 0.5) as i32 as f32).collect();
        let mask = Tensor::new(&[8, n], mask_data).unwrap();
        let w = w0.mul(&mask).unwrap();
        let h = gram(&mut rng, 128, n);
        let g = gptq_quantize(&w, &h, 16, 4, Some(&mask), 0.01).unwrap();
        for i in 0..8 {
            for j in 0..n {
                if mask.at2(i, j) == 0.0 {
                    assert_eq!(g.dequant.at2(i, j), 0.0, "sparsity lost at ({i},{j})");
                    assert_eq!(g.codes.at2(i, j), g.zeros.at2(i, j / 16));
                }
            }
        }
    }

    #[test]
    fn codes_in_range_and_integral() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&mut rng, &[4, 16], 1.0);
        let h = gram(&mut rng, 64, 16);
        let g = gptq_quantize(&w, &h, 8, 4, None, 0.01).unwrap();
        assert!(g.codes.data().iter().all(|&c| (0.0..=15.0).contains(&c) && c == c.round()));
    }

    #[test]
    fn dequant_consistent_with_codes() {
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&mut rng, &[4, 16], 0.5);
        let h = gram(&mut rng, 64, 16);
        let g = gptq_quantize(&w, &h, 8, 4, None, 0.01).unwrap();
        for i in 0..4 {
            for j in 0..16 {
                let s = g.scales.at2(i, j / 8);
                let z = g.zeros.at2(i, j / 8);
                let want = (g.codes.at2(i, j) - z) * s;
                assert!((g.dequant.at2(i, j) - want).abs() < 1e-6);
            }
        }
    }
}
