//! Post-training quantization substrate: RTN baseline, GPTQ (the paper's
//! default), INT4 nibble packing, and quant-parameter bookkeeping.
//!
//! Conventions match the L1/L2 layers exactly: asymmetric group-wise
//! quantization along in-features, codes in [0, 2^bits − 1], dequant
//! `s · (q − z)` (paper Eq. 3-4 with Q_p = 2^bits − 1).

pub mod gptq;
pub mod pack;
pub mod rtn;

pub use gptq::gptq_quantize;
pub use rtn::rtn_quantize;

use crate::model::ParamSet;
use crate::runtime::ModelHyper;
use crate::tensor::Tensor;
use anyhow::{bail, Result};

pub const BITS: u32 = 4;

pub fn qmax(bits: u32) -> f32 {
    ((1u32 << bits) - 1) as f32
}

/// Quantization result for one weight matrix.
#[derive(Clone, Debug)]
pub struct QuantResult {
    pub codes: Tensor,  // (out, in) integer codes as f32
    pub scales: Tensor, // (out, G)
    pub zeros: Tensor,  // (out, G)
    pub dequant: Tensor, // (out, in) = s*(q-z), the value compute sees
}

impl QuantResult {
    /// Mean squared reconstruction error vs the original weight.
    pub fn mse(&self, w: &Tensor) -> f64 {
        let mut acc = 0.0f64;
        for (a, b) in self.dequant.data().iter().zip(w.data()) {
            acc += ((a - b) as f64).powi(2);
        }
        acc / w.len() as f64
    }

    /// Activation-weighted reconstruction error ||(W-Ŵ)X||² using the
    /// calibration Gram: tr((W-Ŵ) H (W-Ŵ)^T) — GPTQ's actual objective.
    pub fn weighted_err(&self, w: &Tensor, h: &Tensor) -> f64 {
        let (out, inp) = (w.rows(), w.cols());
        let mut total = 0.0f64;
        for i in 0..out {
            let mut d = vec![0.0f64; inp];
            for j in 0..inp {
                d[j] = (w.at2(i, j) - self.dequant.at2(i, j)) as f64;
            }
            // d H d^T
            for j in 0..inp {
                if d[j] == 0.0 {
                    continue;
                }
                let hrow = &h.data()[j * inp..(j + 1) * inp];
                for k in 0..inp {
                    total += d[j] * hrow[k] as f64 * d[k];
                }
            }
        }
        total
    }
}

/// Per-group asymmetric (scale, zero) from min/max of `w[i, g*gs..(g+1)*gs]`,
/// restricted to unmasked entries when a mask is given (masked entries are
/// structurally zero and must dequantize to exactly 0, so zero-point must
/// be on the grid — we round z to an integer as GPTQ does).
///
/// The in-dimension must divide evenly into groups: a trailing partial
/// group would otherwise be silently dropped here and then indexed out of
/// bounds by every `scales.at2(i, j / group_size)` consumer downstream.
pub fn group_params(w: &Tensor, group_size: usize, bits: u32,
                    mask: Option<&Tensor>) -> Result<(Tensor, Tensor)> {
    let (out, inp) = (w.rows(), w.cols());
    if group_size == 0 || inp % group_size != 0 {
        bail!("group size {group_size} does not divide in-dim {inp} evenly");
    }
    let g = inp / group_size;
    let qm = qmax(bits);
    let mut scales = Tensor::zeros(&[out, g]);
    let mut zeros = Tensor::zeros(&[out, g]);
    for i in 0..out {
        for gi in 0..g {
            let (mut lo, mut hi) = (0.0f32, 0.0f32); // include 0 so z is on-grid
            for j in gi * group_size..(gi + 1) * group_size {
                if let Some(m) = mask {
                    if m.at2(i, j) == 0.0 {
                        continue;
                    }
                }
                lo = lo.min(w.at2(i, j));
                hi = hi.max(w.at2(i, j));
            }
            let mut scale = (hi - lo) / qm;
            if scale <= 0.0 {
                scale = 1.0;
            }
            let zero = (-lo / scale).round().clamp(0.0, qm);
            scales.set2(i, gi, scale);
            zeros.set2(i, gi, zero);
        }
    }
    Ok((scales, zeros))
}

/// Quantize every adapted-module base weight of a model with GPTQ, writing
/// qscales_/qzeros_ stacks into a ParamSet (the QA artifacts' inputs) and
/// replacing base weights with their dequantized values.  Non-adapted linear
/// weights (wo, wgate) are quantized too (whole-model INT4, as GPTQ does).
pub fn quantize_model(
    base: &mut ParamSet,
    grams: impl Fn(&str, usize) -> Result<Tensor>,
    masks: Option<&ParamSet>,
    hyper: &ModelHyper,
    use_gptq: bool,
) -> Result<(ParamSet, ParamSet)> {
    let mut qa = ParamSet::new();
    let mut codes_all = ParamSet::new();
    for wkey in crate::model::linear_keys() {
        let w_stack = base.get(wkey)?.clone();
        let mask_stack = match masks {
            Some(ms) => Some(ms.get(&format!("mask_{wkey}"))?.clone()),
            None => None,
        };
        let mut new_w = w_stack.clone();
        let mut scales_l = Vec::new();
        let mut zeros_l = Vec::new();
        let mut codes_l = Vec::new();
        for l in 0..hyper.n_layers {
            let w = w_stack.index0(l);
            let mask = mask_stack.as_ref().map(|m| m.index0(l));
            let qr = if use_gptq {
                let h = grams(wkey, l)?;
                gptq_quantize(&w, &h, hyper.group_size, BITS, mask.as_ref(), 0.01)?
            } else {
                rtn_quantize(&w, hyper.group_size, BITS, mask.as_ref())?
            };
            new_w.set_index0(l, &qr.dequant);
            scales_l.push(qr.scales);
            zeros_l.push(qr.zeros);
            codes_l.push(qr.codes);
        }
        base.insert(wkey, new_w);
        // QA params only needed for adapted modules; store all for metrics
        qa.insert(&format!("qscales_{wkey}"), Tensor::stack(&scales_l)?);
        qa.insert(&format!("qzeros_{wkey}"), Tensor::stack(&zeros_l)?);
        codes_all.insert(&format!("codes_{wkey}"), Tensor::stack(&codes_l)?);
    }
    // map adapted-module aliases (qscales_q <- qscales_wq ...)
    for m in &hyper.mods {
        let wkey = ModelHyper::weight_key(m);
        qa.insert(&format!("qscales_{m}"), qa.get(&format!("qscales_{wkey}"))?.clone());
        qa.insert(&format!("qzeros_{m}"), qa.get(&format!("qzeros_{wkey}"))?.clone());
    }
    qa.insert("qmax", Tensor::scalar(qmax(BITS)));
    Ok((qa, codes_all))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn qmax_values() {
        assert_eq!(qmax(4), 15.0);
        assert_eq!(qmax(8), 255.0);
    }

    #[test]
    fn group_params_cover_range() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&mut rng, &[4, 32], 0.5);
        let (scales, zeros) = group_params(&w, 16, 4, None).unwrap();
        assert_eq!(scales.shape(), &[4, 2]);
        // every weight quantizes within [0, 15] by construction
        for i in 0..4 {
            for j in 0..32 {
                let s = scales.at2(i, j / 16);
                let z = zeros.at2(i, j / 16);
                let q = (w.at2(i, j) / s).round() + z;
                assert!((-1.0..=16.0).contains(&q), "q={q}");
            }
        }
    }

    #[test]
    fn indivisible_group_size_is_an_error_not_oob() {
        // regression: gs = inp / g used to truncate, and every
        // `scales.at2(i, j / gs)` consumer then read out of bounds
        let mut rng = Rng::new(7);
        let w = Tensor::randn(&mut rng, &[4, 10], 0.5);
        assert!(group_params(&w, 4, 4, None).is_err());
        assert!(group_params(&w, 0, 4, None).is_err());
        assert!(group_params(&w, 10, 4, None).is_ok());
        assert!(crate::quant::rtn_quantize(&w, 3, 4, None).is_err());
        let h = Tensor::ones(&[10, 10]);
        assert!(crate::quant::gptq_quantize(&w, &h, 4, 4, None, 0.01).is_err());
    }

    #[test]
    fn zero_dequantizes_to_zero() {
        // masked (structurally zero) entries must map to code z exactly
        let mut rng = Rng::new(2);
        let w = Tensor::randn(&mut rng, &[2, 16], 0.5);
        let (scales, zeros) = group_params(&w, 8, 4, None).unwrap();
        for i in 0..2 {
            for g in 0..2 {
                let s = scales.at2(i, g);
                let z = zeros.at2(i, g);
                let q = (0.0f32 / s).round() + z;
                let dq = (q.clamp(0.0, 15.0) - z) * s;
                assert_eq!(dq, 0.0);
            }
        }
    }
}
