//! Deterministic fault injection for the serving stack.
//!
//! Production serving must degrade gracefully under partial failure, and
//! the only way to *test* that is to fail on purpose.  This module is a
//! seeded, site-keyed failpoint harness: code under test declares named
//! sites (`SITE_FORWARD`, `SITE_UPLOAD`, ...) and calls
//! [`FaultInjector::check`] at each one; a test or bench installs rules
//! that make specific hits fail.  Everything is deterministic — a rule
//! fires as a pure function of `(seed, site, hit index)` — so a chaos run
//! is replayable bit-for-bit and assertions can target "the 3rd forward
//! fails" exactly.
//!
//! Off by default and cheap when off: the default injector holds no
//! state at all (`inner: None`), so a disabled check is one branch on an
//! `Option` — no locks, no atomics, no allocation.  The serve layer
//! threads an injector handle through [`PoolOpts`](crate::serve::PoolOpts)
//! / the router; sites below the serve layer (the runtime's upload path,
//! the registry's registration path) consult a thread-local injector that
//! each worker installs for the duration of its serving loop, so no
//! runtime signature changes are needed.
//!
//! Rule anatomy (see [`FaultRule`]): a site name, a fault kind
//! ([`FaultKind::Error`] / [`FaultKind::Panic`] / [`FaultKind::Delay`]),
//! a per-hit fire probability, and an optional `[after, after+max_fires)`
//! hit window for surgically targeting "exactly the Nth hit".
//!
//! Env syntax (picked up by [`FaultInjector::from_env`], used by the
//! `serve` CLI): `SQFT_FAULTS="site=rate[:kind][,site=rate...]"` where
//! `kind` is `error` (default), `panic`, or `delay<ms>`, plus
//! `SQFT_FAULT_SEED=<u64>` (default 0).  Example:
//! `SQFT_FAULTS="engine.forward=0.05,runtime.upload=0.01:error"`.

use anyhow::{anyhow, bail, Result};
use std::cell::RefCell;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// A decode forward is about to run (checked per step, per retry).
pub const SITE_FORWARD: &str = "engine.forward";
/// A host→device upload is about to run (checked in `run_mixed`).
pub const SITE_UPLOAD: &str = "runtime.upload";
/// Latency injection point before each decode forward (use with
/// [`FaultKind::Delay`] to model a slow device without failing it).
pub const SITE_SLOW_FORWARD: &str = "engine.slow_forward";
/// A pool worker claimed a batch (use with [`FaultKind::Panic`] to model
/// a worker crash while the batch is still recoverable).
pub const SITE_WORKER_PANIC: &str = "pool.worker_panic";
/// An adapter registration is about to replay into a worker's replica.
pub const SITE_REGISTER: &str = "registry.register";
/// A KV-cache prefill forward is about to run (checked per prefill, via
/// the thread-local injector).  A fired prefill fault fails only the
/// requests that prefill was admitting — never the session's in-flight
/// rows, whose resident cache pages the failed (functional) update left
/// untouched.
pub const SITE_PREFILL: &str = "engine.prefill";
/// The cached-decode frontier/position vectors are about to upload
/// (checked per cached step, via the thread-local injector).  Plain
/// transient error: the decode step is retry-safe, so the session's
/// normal retry budget absorbs it.
pub const SITE_CACHE_UPLOAD: &str = "runtime.cache_upload";

/// What happens when a rule fires at its site.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// `check` returns an error (looks like a transient failure to the
    /// caller — the retry path's bread and butter)
    Error,
    /// `check` panics (models a crashing worker; pair with
    /// `catch_unwind` recovery)
    Panic,
    /// `check` sleeps this long, then succeeds (latency injection)
    Delay(Duration),
}

/// One failpoint rule: fire `kind` at `site` with probability `rate` per
/// hit, only for hits in `[after, ...)`, at most `max_fires` times.
#[derive(Clone, Debug)]
pub struct FaultRule {
    pub site: String,
    pub kind: FaultKind,
    /// per-hit fire probability; `>= 1.0` fires every eligible hit
    pub rate: f64,
    /// skip the first `after` hits at this site (0 = eligible at once)
    pub after: u64,
    /// stop firing after this many fires (`u64::MAX` = unlimited)
    pub max_fires: u64,
}

impl FaultRule {
    /// A rate-based rule, eligible from the first hit, unlimited fires.
    pub fn new(site: &str, kind: FaultKind, rate: f64) -> FaultRule {
        FaultRule { site: site.to_string(), kind, rate, after: 0, max_fires: u64::MAX }
    }

    /// Fire exactly once, at the `n`th hit (0-based) of `site`.
    pub fn nth(site: &str, kind: FaultKind, n: u64) -> FaultRule {
        FaultRule { site: site.to_string(), kind, rate: 1.0, after: n, max_fires: 1 }
    }

    /// Fire on every hit in `[after, after + count)` — e.g. `count`
    /// consecutive failures, enough to exhaust a retry budget and make a
    /// transient fault persistent.
    pub fn window(site: &str, kind: FaultKind, after: u64, count: u64) -> FaultRule {
        FaultRule { site: site.to_string(), kind, rate: 1.0, after, max_fires: count }
    }
}

/// Per-rule live state: the rule plus hit/fire counters.
struct RuleState {
    rule: FaultRule,
    hits: u64,
    fires: u64,
}

struct Inner {
    seed: u64,
    rules: Mutex<Vec<RuleState>>,
}

/// Cloneable handle to one fault plan (all clones share counters, so a
/// multi-worker pool sees one global hit sequence per site).  The default
/// handle is *disabled* and holds no state: checks are a single branch.
#[derive(Clone, Default)]
pub struct FaultInjector {
    inner: Option<Arc<Inner>>,
}

impl std::fmt::Debug for FaultInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("FaultInjector").field("enabled", &self.enabled()).finish()
    }
}

impl FaultInjector {
    /// The no-op injector (same as `Default`): never fires, near-zero cost.
    pub fn disabled() -> FaultInjector {
        FaultInjector::default()
    }

    /// An enabled injector with no rules yet; decisions derive from `seed`.
    pub fn seeded(seed: u64) -> FaultInjector {
        FaultInjector { inner: Some(Arc::new(Inner { seed, rules: Mutex::new(Vec::new()) })) }
    }

    /// Builder-style rule installation (panics on a disabled injector —
    /// rules on a no-op injector are a test bug, not a runtime state).
    pub fn with_rule(self, rule: FaultRule) -> FaultInjector {
        self.add_rule(rule);
        self
    }

    /// Install one rule (shared by all clones).
    pub fn add_rule(&self, rule: FaultRule) {
        let inner = self.inner.as_ref().expect("add_rule on a disabled FaultInjector");
        crate::util::sync::lock_recover(&inner.rules).push(RuleState {
            rule,
            hits: 0,
            fires: 0,
        });
    }

    /// Parse `SQFT_FAULTS` / `SQFT_FAULT_SEED` (see module docs); `None`
    /// when the env carries no fault plan.
    pub fn from_env() -> Result<Option<FaultInjector>> {
        let Ok(spec) = std::env::var("SQFT_FAULTS") else { return Ok(None) };
        if spec.trim().is_empty() {
            return Ok(None);
        }
        let seed = match std::env::var("SQFT_FAULT_SEED") {
            Ok(s) => s.parse::<u64>().map_err(|_| anyhow!("bad SQFT_FAULT_SEED '{s}'"))?,
            Err(_) => 0,
        };
        let inj = FaultInjector::seeded(seed);
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (site, rest) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("bad SQFT_FAULTS entry '{part}' (want site=rate[:kind])"))?;
            let (rate_s, kind_s) = match rest.split_once(':') {
                Some((r, k)) => (r, k),
                None => (rest, "error"),
            };
            let rate: f64 = rate_s
                .parse()
                .map_err(|_| anyhow!("bad fault rate '{rate_s}' for site '{site}'"))?;
            let kind = if kind_s == "error" {
                FaultKind::Error
            } else if kind_s == "panic" {
                FaultKind::Panic
            } else if let Some(ms) = kind_s.strip_prefix("delay") {
                let ms: u64 =
                    ms.parse().map_err(|_| anyhow!("bad delay '{kind_s}' for site '{site}'"))?;
                FaultKind::Delay(Duration::from_millis(ms))
            } else {
                bail!("bad fault kind '{kind_s}' for site '{site}' (error|panic|delay<ms>)");
            };
            inj.add_rule(FaultRule::new(site, kind, rate));
        }
        Ok(Some(inj))
    }

    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Decide whether any rule fires at this hit of `site` (advances every
    /// matching rule's hit counter either way).
    fn evaluate(&self, site: &str) -> Option<FaultKind> {
        let inner = self.inner.as_ref()?;
        let mut rules = crate::util::sync::lock_recover(&inner.rules);
        let mut fired: Option<FaultKind> = None;
        for rs in rules.iter_mut().filter(|rs| rs.rule.site == site) {
            let hit = rs.hits;
            rs.hits += 1;
            if hit < rs.rule.after || rs.fires >= rs.rule.max_fires {
                continue;
            }
            let fire = rs.rule.rate >= 1.0 || unit(inner.seed, site, hit) < rs.rule.rate;
            if fire {
                rs.fires += 1;
                // first firing rule wins, but later rules still count hits
                if fired.is_none() {
                    fired = Some(rs.rule.kind.clone());
                }
            }
        }
        fired
    }

    /// The failpoint: call at a named site.  Disabled injectors return
    /// `Ok(())` after one branch.  A firing [`FaultKind::Error`] returns
    /// `Err`, [`FaultKind::Panic`] panics, [`FaultKind::Delay`] sleeps
    /// then returns `Ok(())`.
    pub fn check(&self, site: &str) -> Result<()> {
        if self.inner.is_none() {
            return Ok(());
        }
        match self.evaluate(site) {
            None => Ok(()),
            Some(FaultKind::Error) => Err(anyhow!("injected fault at {site}")),
            Some(FaultKind::Panic) => panic!("injected fault at {site}: panic"),
            Some(FaultKind::Delay(d)) => {
                std::thread::sleep(d);
                Ok(())
            }
        }
    }

    /// Times any rule fired at `site` so far (0 for disabled injectors).
    pub fn fires(&self, site: &str) -> u64 {
        let Some(inner) = self.inner.as_ref() else { return 0 };
        crate::util::sync::lock_recover(&inner.rules)
            .iter()
            .filter(|rs| rs.rule.site == site)
            .map(|rs| rs.fires)
            .sum()
    }
}

/// FNV-1a, the same mixing the scheduler uses for shard assignment.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Uniform `[0, 1)` decision value for hit `n` of `site` under `seed` — a
/// pure function, so every replay of a seeded plan makes identical calls.
fn unit(seed: u64, site: &str, n: u64) -> f64 {
    let r = splitmix64(seed ^ fnv1a(site).wrapping_add(n.wrapping_mul(0x9e37_79b9_7f4a_7c15)));
    (r >> 11) as f64 / (1u64 << 53) as f64
}

thread_local! {
    /// The injector serving code installed for this thread (workers install
    /// theirs around the serving loop), consulted by sites below the serve
    /// layer — the runtime upload path and the registry replication path —
    /// so those layers need no signature changes to participate.
    static THREAD_INJECTOR: RefCell<FaultInjector> = RefCell::new(FaultInjector::disabled());
}

/// Install `inj` as this thread's injector until the guard drops (the
/// previous injector is restored, so nested scopes compose).
pub fn install(inj: &FaultInjector) -> InstallGuard {
    let prev = THREAD_INJECTOR.with(|t| t.replace(inj.clone()));
    InstallGuard { prev }
}

/// Check a site against the thread's installed injector (disabled by
/// default — one thread-local read and one branch when no chaos plan is
/// active).
pub fn check_thread(site: &str) -> Result<()> {
    THREAD_INJECTOR.with(|t| t.borrow().check(site))
}

/// Restores the previously installed thread injector on drop.
pub struct InstallGuard {
    prev: FaultInjector,
}

impl Drop for InstallGuard {
    fn drop(&mut self) {
        THREAD_INJECTOR.with(|t| t.replace(self.prev.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_injector_never_fires() {
        let f = FaultInjector::disabled();
        for _ in 0..100 {
            assert!(f.check(SITE_FORWARD).is_ok());
        }
        assert_eq!(f.fires(SITE_FORWARD), 0);
        assert!(!f.enabled());
    }

    #[test]
    fn nth_rule_fires_exactly_once_at_the_right_hit() {
        let f = FaultInjector::seeded(7).with_rule(FaultRule::nth(SITE_FORWARD, FaultKind::Error, 3));
        let results: Vec<bool> = (0..8).map(|_| f.check(SITE_FORWARD).is_ok()).collect();
        assert_eq!(results, vec![true, true, true, false, true, true, true, true]);
        assert_eq!(f.fires(SITE_FORWARD), 1);
    }

    #[test]
    fn window_rule_fires_consecutively_then_stops() {
        let f = FaultInjector::seeded(7)
            .with_rule(FaultRule::window(SITE_UPLOAD, FaultKind::Error, 2, 3));
        let results: Vec<bool> = (0..8).map(|_| f.check(SITE_UPLOAD).is_ok()).collect();
        assert_eq!(results, vec![true, true, false, false, false, true, true, true]);
        assert_eq!(f.fires(SITE_UPLOAD), 3);
    }

    #[test]
    fn rate_rules_are_deterministic_under_a_seed() {
        let run = |seed: u64| -> Vec<bool> {
            let f = FaultInjector::seeded(seed)
                .with_rule(FaultRule::new(SITE_FORWARD, FaultKind::Error, 0.3));
            (0..64).map(|_| f.check(SITE_FORWARD).is_err()).collect()
        };
        assert_eq!(run(42), run(42), "same seed must replay identically");
        assert_ne!(run(42), run(43), "different seeds must differ");
        let fired = run(42).iter().filter(|&&x| x).count();
        assert!(fired > 5 && fired < 30, "rate 0.3 over 64 hits fired {fired} times");
    }

    #[test]
    fn sites_are_independent() {
        let f = FaultInjector::seeded(1)
            .with_rule(FaultRule::window(SITE_FORWARD, FaultKind::Error, 0, 1));
        assert!(f.check(SITE_UPLOAD).is_ok(), "rule must not leak across sites");
        assert!(f.check(SITE_FORWARD).is_err());
        assert_eq!(f.fires(SITE_UPLOAD), 0);
    }

    #[test]
    fn delay_kind_sleeps_then_succeeds() {
        let f = FaultInjector::seeded(1).with_rule(FaultRule::window(
            SITE_SLOW_FORWARD,
            FaultKind::Delay(Duration::from_millis(5)),
            0,
            1,
        ));
        let t0 = std::time::Instant::now();
        assert!(f.check(SITE_SLOW_FORWARD).is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(5));
    }

    #[test]
    fn thread_install_scopes_and_restores() {
        assert!(check_thread(SITE_REGISTER).is_ok());
        let f = FaultInjector::seeded(1)
            .with_rule(FaultRule::new(SITE_REGISTER, FaultKind::Error, 1.0));
        {
            let _g = install(&f);
            assert!(check_thread(SITE_REGISTER).is_err());
        }
        assert!(check_thread(SITE_REGISTER).is_ok(), "guard must restore the previous injector");
        assert_eq!(f.fires(SITE_REGISTER), 1);
    }

    #[test]
    fn env_spec_parses_sites_kinds_and_seed() {
        // constructed directly (env vars are process-global; tests run in
        // parallel), exercising the same parser from_env uses
        let f = FaultInjector::seeded(9)
            .with_rule(FaultRule::new(SITE_FORWARD, FaultKind::Error, 1.0))
            .with_rule(FaultRule::new(SITE_SLOW_FORWARD, FaultKind::Delay(Duration::ZERO), 1.0));
        assert!(f.check(SITE_FORWARD).is_err());
        assert!(f.check(SITE_SLOW_FORWARD).is_ok());
    }
}
