//! Training drivers: full-weight pretraining and adapter fine-tuning.
//!
//! The loop is rust-owned; model math runs through the AOT train-step
//! artifacts (Adam inside the graph).  Frozen inputs — base weights, masks,
//! quant params — are uploaded to the device once and passed as buffers
//! every step; the trainable adapter/optimizer state round-trips the host
//! (PJRT's tuple output lands host-side anyway), which for adapters is a
//! few MB.  Under NLS the trainer samples a random sub-adapter per step
//! (weight sharing across the elastic space, paper §2.2).

use crate::data::{Batch, Batcher, Sample, Tokenizer};
use crate::model::ParamSet;
use crate::nls::SearchSpace;
use crate::peft::Method;
use crate::runtime::{args::build_args, DeviceStore, Runtime};
use crate::tensor::{Rng, Tensor};
use anyhow::Result;

/// Per-run training hyperparameters (paper Table 8 analogue).
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub steps: usize,
    pub lr: f64,
    pub log_every: usize,
    pub seed: u64,
    /// Table-5 ablation override: train the max-rank sub-adapter only
    /// (vanilla LoRA) even for NLS-capable methods.
    pub fixed_rank: bool,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts { steps: 300, lr: 3e-4, log_every: 50, seed: 7, fixed_rank: false }
    }
}

/// Loss-curve record, written into EXPERIMENTS.md by the examples.
#[derive(Clone, Debug, Default)]
pub struct LossCurve {
    pub points: Vec<(usize, f64)>,
}

impl LossCurve {
    pub fn push(&mut self, step: usize, loss: f64) {
        self.points.push((step, loss));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, l)| l)
    }

    pub fn first(&self) -> Option<f64> {
        self.points.first().map(|&(_, l)| l)
    }

    pub fn render(&self) -> String {
        self.points
            .iter()
            .map(|(s, l)| format!("step {s:>5}  loss {l:.4}"))
            .collect::<Vec<_>>()
            .join("\n")
    }
}

/// Upload every tensor of a ParamSet as device-resident buffers
/// (borrowed upload: no intermediate host clone per tensor).
pub fn upload(rt: &Runtime, store: &mut DeviceStore, set: &ParamSet) -> Result<()> {
    for (name, t) in set.iter() {
        store.put_tensor(&rt.client, name, t)?;
    }
    Ok(())
}

/// Full-weight pretraining on one task mixture (produces the "pretrained
/// base model" the SQFT pipeline starts from; also the ~100M-scale loss-
/// curve driver for EXPERIMENTS.md).
pub struct Pretrainer<'a> {
    rt: &'a Runtime,
    config: String,
    pub base: ParamSet,
    opt: ParamSet,
    step: usize,
}

impl<'a> Pretrainer<'a> {
    pub fn new(rt: &'a Runtime, config: &str, base: ParamSet) -> Pretrainer<'a> {
        let opt = crate::model::init_pretrain_opt(&base);
        Pretrainer { rt, config: config.to_string(), base, opt, step: 0 }
    }

    pub fn step_batch(&mut self, batch: &Batch, lr: f64) -> Result<f64> {
        let exe = self.rt.executable(&self.config, "pretrain")?;
        self.step += 1;
        let scalars = [("step", self.step as f32), ("lr", lr as f32)];
        let args = build_args(&exe.spec, &[], &[&self.base, &self.opt],
                              Some(batch), &scalars)?;
        let outs = exe.run_mixed(&self.rt.client, &args)?;
        // outputs: base' | m' | v' | loss, in base-spec order
        let names: Vec<String> = exe.spec.outputs.clone();
        for (name, t) in names.iter().zip(outs.iter()) {
            if name == "loss" {
                continue;
            }
            if let Some(stripped) = name.strip_prefix("m_") {
                self.opt.insert(&format!("m_{stripped}"), t.clone());
            } else if let Some(stripped) = name.strip_prefix("v_") {
                self.opt.insert(&format!("v_{stripped}"), t.clone());
            } else {
                self.base.insert(name, t.clone());
            }
        }
        Ok(outs.last().unwrap().data()[0] as f64)
    }

    /// Train on random batches from `samples` for `opts.steps` steps.
    pub fn train(&mut self, samples: &[Sample], tok: &Tokenizer,
                 opts: &TrainOpts) -> Result<LossCurve> {
        let hyper = self.rt.model(&self.config)?.clone();
        let batcher = Batcher::new(samples, tok, hyper.seq_len, hyper.batch);
        let mut rng = Rng::new(opts.seed);
        let mut curve = LossCurve::default();
        for s in 0..opts.steps {
            let batch = batcher.random_batch(&mut rng)?;
            let loss = self.step_batch(&batch, opts.lr)?;
            if s % opts.log_every == 0 || s + 1 == opts.steps {
                curve.push(s, loss);
            }
        }
        Ok(curve)
    }
}

/// Adapter fine-tuning driver for one Method.
pub struct Trainer<'a> {
    rt: &'a Runtime,
    config: String,
    pub method: Method,
    /// device-resident frozen state: base weights (+ adapter masks + QA
    /// params), uploaded once
    pub device: DeviceStore,
    /// host-held frozen adapter masks (only if not device-resident)
    pub adapters: ParamSet,
    pub opt: ParamSet,
    pub space: SearchSpace,
    step: usize,
    rng: Rng,
    /// when set, disables per-step NLS sampling (LoRA ablation)
    pub fixed_rank: bool,
}

impl<'a> Trainer<'a> {
    /// `frozen` must hold: base weights, adapter mask_ tensors, and (QA)
    /// qscales_/qzeros_ stacks.  They are uploaded once.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        rt: &'a Runtime,
        config: &str,
        method: Method,
        frozen: &ParamSet,
        adapters: ParamSet,
        space: SearchSpace,
        seed: u64,
    ) -> Result<Trainer<'a>> {
        let hyper = rt.model(config)?.clone();
        let mut device = DeviceStore::new();
        upload(rt, &mut device, frozen)?;
        let opt = crate::model::init_opt(&hyper);
        Ok(Trainer {
            rt,
            config: config.to_string(),
            method,
            device,
            adapters,
            opt,
            space,
            step: 0,
            rng: Rng::new(seed ^ 0x5157465421),
            fixed_rank: false,
        })
    }

    /// The rank configuration used for one training step: NLS samples the
    /// elastic space; LoRA always trains the max sub-adapter.
    fn step_config(&mut self) -> crate::nls::Config {
        if self.method.uses_nls() && !self.fixed_rank {
            self.space.sample(&mut self.rng)
        } else {
            self.space.max_config()
        }
    }

    pub fn step_batch(&mut self, batch: &Batch, lr: f64) -> Result<f64> {
        let exe = self.rt.executable(&self.config, self.method.train_kind())?;
        self.step += 1;
        let cfg = self.step_config();
        let rank_params = self.space.realize(&cfg)?;
        let scalars = [("step", self.step as f32), ("lr", lr as f32)];
        let args = build_args(
            &exe.spec,
            &[&self.device],
            &[&self.adapters, &rank_params, &self.opt],
            Some(batch),
            &scalars,
        )?;
        let outs = exe.run_mixed(&self.rt.client, &args)?;
        for (name, t) in exe.spec.outputs.iter().zip(outs.iter()) {
            if name == "loss" {
                continue;
            }
            if name.starts_with("m_") || name.starts_with("v_") {
                self.opt.insert(name, t.clone());
            } else {
                self.adapters.insert(name, t.clone());
            }
        }
        Ok(outs.last().unwrap().data()[0] as f64)
    }

    pub fn train(&mut self, samples: &[Sample], tok: &Tokenizer,
                 opts: &TrainOpts) -> Result<LossCurve> {
        let hyper = self.rt.model(&self.config)?.clone();
        let batcher = Batcher::new(samples, tok, hyper.seq_len, hyper.batch);
        let mut rng = Rng::new(opts.seed);
        let mut curve = LossCurve::default();
        for s in 0..opts.steps {
            let batch = batcher.random_batch(&mut rng)?;
            let loss = self.step_batch(&batch, opts.lr)?;
            if s % opts.log_every == 0 || s + 1 == opts.steps {
                curve.push(s, loss);
            }
        }
        Ok(curve)
    }

    /// Fine-tuning state size in bytes (Table 7 fine-tuning-memory proxy):
    /// trainable params + Adam moments, f32.
    pub fn trainable_bytes(&self) -> usize {
        let trainable: usize = self
            .adapters
            .iter()
            .filter(|(n, _)| n.starts_with("a_") || n.starts_with("b_"))
            .map(|(_, t)| t.len())
            .sum();
        (trainable + self.opt.total_elems()) * 4
    }
}

/// Convenience: a Tensor of ones shaped like the adapter masks (dense
/// methods pass all-ones masks).
pub fn ones_like(t: &Tensor) -> Tensor {
    Tensor::ones(t.shape())
}
