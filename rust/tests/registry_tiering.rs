//! Tiered adapter residency (ISSUE 9 acceptance): disk → host → device
//! promotion, rank-elastic degradation under a device byte budget, and
//! corruption quarantine that isolates exactly one tenant.
//!
//!   - with a device budget fitting only half the registered tenants,
//!     ALL tenants still serve (degraded ranks, zero residency
//!     refusals);
//!   - full-rank answers through the tiered path are byte-identical to
//!     the flat pre-tiering registry;
//!   - one corrupt checkpoint quarantines exactly one tenant with a
//!     typed `TenantUnavailable` refusal while siblings keep serving;
//!   - degrading or evicting a tenant that occupies a `GatheredBank`
//!     slot rewrites/backfills the slot slice before it is used again.
//!
//! Host-only tests run everywhere; device tests skip without artifacts.

use sqft::data::{Task, Tokenizer};
use sqft::model::checkpoint::save_adapter;
use sqft::model::{init_base, ParamSet};
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::Runtime;
use sqft::serve::{
    load_adapter_dir_tolerant, AdapterEntry, AdapterRegistry, Engine, Request, Router,
    SchedulerOpts,
};
use sqft::tensor::{Rng, Tensor};
use std::path::{Path, PathBuf};
use std::sync::mpsc::channel;
use std::time::Duration;

struct Fixture {
    hyper: sqft::runtime::ModelHyper,
    frozen: ParamSet,
    entries: Vec<AdapterEntry>,
}

fn fixture(rt: &Runtime, tenants: usize) -> Fixture {
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let ds = sqft::data::Dataset::generate(Task::SynBoolq, 300, 0, 30, 71);
    let base = init_base(&hyper, &mut Rng::new(33));
    let prepared = pipeline::prepare(rt, config, &base, Method::Lora, 0.0,
                                     &ds.train, &tok, 0, &mut Rng::new(34)).unwrap();
    let frozen = prepared.frozen_set().unwrap();
    let mut entries = pipeline::tenant_adapters(rt, config, &prepared, tenants,
                                                &ds.train, &tok, 2, 800).unwrap();
    // large per-tenant deltas so answers depend on which adapter (and at
    // which rank) served the request
    for (i, e) in entries.iter_mut().enumerate() {
        let mut rng = Rng::new(900 + i as u64);
        let a_shape = e.host_sets[0].get("a_q").unwrap().shape().to_vec();
        let b_shape = e.host_sets[0].get("b_q").unwrap().shape().to_vec();
        e.host_sets[0].insert("a_q", Tensor::randn(&mut rng, &a_shape, 1.0));
        e.host_sets[0].insert("b_q", Tensor::randn(&mut rng, &b_shape, 1.0));
    }
    Fixture { hyper, frozen, entries }
}

fn artifacts() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

/// Write each entry's checkpoint as `<id>.ckpt` under `dir` (fresh dir).
fn save_entries(dir: &Path, entries: &[AdapterEntry]) -> Vec<(String, PathBuf)> {
    std::fs::remove_dir_all(dir).ok();
    std::fs::create_dir_all(dir).unwrap();
    entries
        .iter()
        .map(|e| {
            let path = dir.join(format!("{}.ckpt", e.id));
            save_adapter(&path, &e.host_sets[0], &e.host_sets[1], "sqft-tiny",
                         &e.eval_kind, &e.id, "lora", 0.0)
                .unwrap();
            (e.id.clone(), path)
        })
        .collect()
}

/// Serve one request per (tenant, prompt) through a fresh Router and
/// collect per-request results in order.
fn serve_once(
    rt: &Runtime,
    frozen: &ParamSet,
    registry: AdapterRegistry,
    requests: &[(Option<String>, String)],
) -> (Vec<Result<String, String>>, sqft::serve::MultiServeStats, AdapterRegistry) {
    let engine = Engine::new(rt, "sqft-tiny", frozen, None, "eval", 4).unwrap();
    let mut router = Router::new(engine, registry);
    let (tx, rx) = channel::<Request>();
    let mut replies = Vec::new();
    for (id, p) in requests {
        let (rtx, rrx) = channel();
        tx.send(Request::new(id.clone(), p.clone(), rtx)).unwrap();
        replies.push(rrx);
    }
    drop(tx);
    let opts = SchedulerOpts { aging: Duration::from_millis(5), ..Default::default() };
    let stats = router.serve(rx, opts).unwrap();
    let out = replies
        .into_iter()
        .map(|r| r.recv().unwrap().map_err(|e| format!("{e:#}")))
        .collect();
    (out, stats, std::mem::replace(router.registry_mut(), AdapterRegistry::new(1)))
}

// ---------------------------------------------------------------------
// host-only: the tolerant directory loader (no artifacts needed)
// ---------------------------------------------------------------------

#[test]
fn tolerant_dir_load_isolates_corrupt_checkpoints_as_casualties() {
    let dir = std::env::temp_dir().join("sqft_tiering_tolerant");
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Rng::new(11);
    for id in ["good0", "good1", "torn"] {
        let mut adapters = ParamSet::new();
        adapters.insert("a_q", Tensor::randn(&mut rng, &[2, 4, 8], 0.1));
        let mut rank = ParamSet::new();
        rank.insert("rankmask_q", Tensor::ones(&[2, 4]));
        rank.insert("scale_q", Tensor::full(&[2], 2.0));
        save_adapter(&dir.join(format!("{id}.ckpt")), &adapters, &rank, "cfgX",
                     "eval", id, "lora", 0.0)
            .unwrap();
    }
    // flip one payload byte of `torn`: checksum catches it at load
    let torn = dir.join("torn.ckpt");
    let mut bytes = std::fs::read(&torn).unwrap();
    let n = bytes.len();
    bytes[n - 8] ^= 0x20;
    std::fs::write(&torn, &bytes).unwrap();

    let (good, bad) = load_adapter_dir_tolerant(&dir, "cfgX").unwrap();
    assert_eq!(good.len(), 2, "both intact tenants load");
    let mut ids: Vec<&str> = good.iter().map(|c| c.adapter_id.as_str()).collect();
    ids.sort_unstable();
    assert_eq!(ids, ["good0", "good1"]);
    assert_eq!(bad.len(), 1, "exactly the torn checkpoint is a casualty");
    assert_eq!(bad[0].0, "torn");
    assert!(bad[0].2.contains("checksum"), "reason names the integrity failure: {}", bad[0].2);
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// device tests (artifacts-guarded)
// ---------------------------------------------------------------------

/// Budget pressure degrades a sibling instead of refusing the newcomer,
/// and lifting the budget restores full rank from the host tier.
#[test]
fn budget_pressure_degrades_then_restores() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt, 2);
    let full = AdapterRegistry::entry_logical_bytes(&f.entries[0], None);
    let at4 = AdapterRegistry::entry_logical_bytes(&f.entries[1], Some(4));
    assert!(at4 < full, "rank-4 view must be cheaper than full rank");

    let mut reg = AdapterRegistry::new(8);
    reg.set_device_budget(full + at4);
    reg.set_degrade_ranks(&[4, 2]);
    for e in &f.entries {
        reg.register(&f.hyper, e.clone()).unwrap();
    }
    let (t0, t1) = (f.entries[0].id.clone(), f.entries[1].id.clone());
    reg.ensure_device(&rt, &t0).unwrap();
    reg.ensure_device(&rt, &t1).unwrap();
    assert!(reg.device_set(&t0).is_some() && reg.device_set(&t1).is_some(),
        "both tenants device-resident under pressure");
    assert_eq!(reg.degraded_rank(&t0), None, "first tenant keeps full rank");
    assert_eq!(reg.degraded_rank(&t1), Some(4), "second tenant degrades one ladder step");

    // pressure drops: the degraded tenant is restored to full rank from
    // its host copy (no disk catalog entries exist to re-read)
    reg.set_device_budget(0);
    reg.ensure_device(&rt, &t1).unwrap();
    assert_eq!(reg.degraded_rank(&t1), None, "restored to full rank");
    assert!(reg.device_set(&t1).is_some());
}

/// ISSUE 9 acceptance: a device budget fitting only half the tenants at
/// full rank still serves every tenant — degraded, never refused.
#[test]
fn half_budget_serves_all_tenants_with_zero_refusals() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt, 4);
    let full = AdapterRegistry::entry_logical_bytes(&f.entries[0], None);
    let at4 = AdapterRegistry::entry_logical_bytes(&f.entries[0], Some(4));

    let mut reg = AdapterRegistry::new(8);
    // half the fleet fits at full rank; the whole fleet fits at rank 4
    reg.set_device_budget((2 * full).max(4 * at4));
    reg.set_degrade_ranks(&[4, 2]);
    for e in &f.entries {
        reg.register(&f.hyper, e.clone()).unwrap();
    }

    let mut grng = Rng::new(91);
    let task = Task::SynBoolq;
    let mut requests: Vec<(Option<String>, String)> = Vec::new();
    for i in 0..2 * f.entries.len() {
        let e = &f.entries[i % f.entries.len()];
        requests.push((Some(e.id.clone()), task.gen_sample(&mut grng).prompt));
    }
    let (out, stats, reg) = serve_once(&rt, &f.frozen, reg, &requests);
    assert_eq!(stats.total.errors, 0, "zero residency refusals");
    assert_eq!(stats.total.served, requests.len());
    assert!(out.iter().all(|r| r.is_ok()), "every tenant answered");
    // the budget cannot hold everyone at full rank, so at least one
    // tenant must be serving degraded — and nobody was quarantined
    let degraded = f.entries.iter().filter(|e| reg.degraded_rank(&e.id).is_some()).count();
    assert!(degraded >= 1, "budget pressure must have degraded someone");
    for e in &f.entries {
        assert!(!reg.is_quarantined(&e.id));
        assert!(reg.contains(&e.id), "tenant {} must stay registered", e.id);
    }
}

/// Disk-cataloged tenants promote through host to device on first
/// traffic, and their full-rank answers are byte-identical to the flat
/// pre-tiering registry serving the same entries.
#[test]
fn disk_promotion_answers_match_flat_reference() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt, 3);
    let ckpt_dir = std::env::temp_dir().join("sqft_tiering_promote");
    let cataloged = save_entries(&ckpt_dir, &f.entries);

    let mut grng = Rng::new(92);
    let task = Task::SynBoolq;
    let mut requests: Vec<(Option<String>, String)> = Vec::new();
    for i in 0..9 {
        let id = if i % 4 == 3 {
            None // merged / no-adapter path rides along
        } else {
            Some(f.entries[i % f.entries.len()].id.clone())
        };
        requests.push((id, task.gen_sample(&mut grng).prompt));
    }

    // flat pre-tiering reference: everything resident up front
    let mut flat = AdapterRegistry::new(8);
    flat.register_all_resident(&rt, &f.hyper, f.entries.clone()).unwrap();
    assert!(!flat.tiering_enabled(), "reference runs the legacy flat path");
    let (expected, ref_stats, _) = serve_once(&rt, &f.frozen, flat, &requests);
    assert_eq!(ref_stats.total.errors, 0);

    // tiered path: empty registry, disk catalog only — unbounded budget,
    // so every promotion lands at full rank
    let mut reg = AdapterRegistry::new(8);
    for (id, path) in &cataloged {
        reg.catalog_disk(id, path.clone());
    }
    assert!(reg.tiering_enabled());
    let (got, stats, reg) = serve_once(&rt, &f.frozen, reg, &requests);
    assert_eq!(stats.total.errors, 0, "cold tenants promote instead of erroring");
    for (i, (want, have)) in expected.iter().zip(got.iter()).enumerate() {
        assert_eq!(want.as_ref().unwrap(), have.as_ref().unwrap(),
            "request {i} diverged from the flat-registry reference");
    }
    for e in &f.entries {
        assert!(reg.device_set(&e.id).is_some(), "{} promoted to device", e.id);
        assert_eq!(reg.degraded_rank(&e.id), None, "unbounded budget → full rank");
    }
    std::fs::remove_dir_all(&ckpt_dir).ok();
}

/// One corrupt checkpoint quarantines exactly one tenant: its requests
/// get the typed refusal, siblings' answers don't move.
#[test]
fn corrupt_checkpoint_quarantines_exactly_one_tenant() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt, 3);
    let ckpt_dir = std::env::temp_dir().join("sqft_tiering_quarantine");
    let cataloged = save_entries(&ckpt_dir, &f.entries);

    // reference answers from the flat registry (all three intact)
    let mut grng = Rng::new(93);
    let task = Task::SynBoolq;
    let requests: Vec<(Option<String>, String)> = f
        .entries
        .iter()
        .map(|e| (Some(e.id.clone()), task.gen_sample(&mut grng).prompt))
        .collect();
    let mut flat = AdapterRegistry::new(8);
    flat.register_all_resident(&rt, &f.hyper, f.entries.clone()).unwrap();
    let (expected, _, _) = serve_once(&rt, &f.frozen, flat, &requests);

    // flip one payload byte of the middle tenant's checkpoint
    let victim = f.entries[1].id.clone();
    let victim_path = &cataloged[1].1;
    let mut bytes = std::fs::read(victim_path).unwrap();
    let n = bytes.len();
    bytes[n - 8] ^= 0x10;
    std::fs::write(victim_path, &bytes).unwrap();

    let mut reg = AdapterRegistry::new(8);
    for (id, path) in &cataloged {
        reg.catalog_disk(id, path.clone());
    }
    let (got, stats, reg) = serve_once(&rt, &f.frozen, reg, &requests);
    assert_eq!(stats.total.errors, 1, "exactly the corrupt tenant errors");
    assert_eq!(stats.total.served, requests.len() - 1);
    for (i, e) in f.entries.iter().enumerate() {
        if e.id == victim {
            let err = got[i].as_ref().unwrap_err();
            assert!(err.contains("unavailable") && err.contains("quarantined"),
                "typed refusal names the quarantine: {err}");
        } else {
            assert_eq!(got[i].as_ref().unwrap(), expected[i].as_ref().unwrap(),
                "sibling {} must serve the reference answer", e.id);
        }
    }
    assert!(reg.is_quarantined(&victim));
    assert!(reg.quarantine_reason(&victim).unwrap().contains("checksum"));
    for e in &f.entries {
        if e.id != victim {
            assert!(!reg.is_quarantined(&e.id), "quarantine must not spread");
        }
    }
    std::fs::remove_dir_all(&ckpt_dir).ok();
}

/// ISSUE 9 satellite: a tenant occupying a `GatheredBank` slot that gets
/// degraded has its slot slice rewritten to the degraded view before the
/// bank serves again, and an evicted tenant's recycled slot is fully
/// backfilled by the next registration before it is handed out.
#[test]
fn bank_slot_is_rewritten_on_degrade_and_backfilled_on_reuse() {
    let Some(dir) = artifacts() else { return };
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt, 3);
    let full = AdapterRegistry::entry_logical_bytes(&f.entries[0], None);
    let at4 = AdapterRegistry::entry_logical_bytes(&f.entries[1], Some(4));

    let mut reg = AdapterRegistry::new(3);
    reg.enable_gathered(&f.hyper, 4).unwrap();
    reg.set_device_budget(full + at4);
    reg.set_degrade_ranks(&[4]);
    let (t0, t1) = (f.entries[0].id.clone(), f.entries[1].id.clone());
    reg.register(&f.hyper, f.entries[0].clone()).unwrap();
    reg.register(&f.hyper, f.entries[1].clone()).unwrap();
    let slot1 = reg.bank_slot(&t1).expect("t1 holds a bank slot");
    reg.ensure_device(&rt, &t0).unwrap();
    reg.ensure_device(&rt, &t1).unwrap();
    assert_eq!(reg.degraded_rank(&t1), Some(4));

    // the bank slice must now carry the degraded view, not the full-rank
    // tensors it was registered with
    let view = AdapterRegistry::degraded_view(&f.entries[1], 4).unwrap();
    for name in ["a_q", "rankmask_q", "scale_q"] {
        let want = view
            .host_sets
            .iter()
            .find_map(|s| s.get(name).ok())
            .unwrap_or_else(|| panic!("degraded view missing {name}"));
        let bank_name = match name.split_once('_') {
            Some((kind, m)) => format!("{kind}_bank_{m}"),
            None => unreachable!(),
        };
        let bank = reg.bank().unwrap().host().get(&bank_name).unwrap();
        let n = want.len();
        let got = &bank.data()[slot1 * n..(slot1 + 1) * n];
        assert_eq!(got, want.data(), "bank slice '{bank_name}' must match the degraded view");
    }

    // eviction recycles the slot; the next registration overwrites the
    // whole slice before the slot is handed out again
    assert!(reg.evict(&t1));
    assert_eq!(reg.bank_slot(&t1), None);
    reg.register(&f.hyper, f.entries[2].clone()).unwrap();
    let t2 = f.entries[2].id.clone();
    assert_eq!(reg.bank_slot(&t2), Some(slot1), "recycled slot is reused lowest-first");
    let want = f.entries[2]
        .host_sets
        .iter()
        .find_map(|s| s.get("a_q").ok())
        .unwrap();
    let bank = reg.bank().unwrap().host().get("a_bank_q").unwrap();
    let n = want.len();
    assert_eq!(&bank.data()[slot1 * n..(slot1 + 1) * n], want.data(),
        "stale degraded bytes must be gone after backfill");
}
