//! Property-based tests on the coordinator invariants (DESIGN.md §6).
//!
//! These are the claims the paper's correctness rests on, checked over
//! randomized inputs: masks survive merging, GPTQ never loses to RTN and
//! never resurrects zeros, INT4 packing round-trips, the NLS space/heuristic
//! behave as specified, and the batcher preserves counts.

use sqft::data::{Batcher, Dataset, Sample, Task, Tokenizer};
use sqft::nls::SearchSpace;
use sqft::peft::{adapter_delta, fake_quant_host};
use sqft::quant::pack::{pack_int4, unpack_int4};
use sqft::quant::{gptq_quantize, rtn_quantize};
use sqft::runtime::ModelHyper;
use sqft::sparsity::{topk_row_mask, wanda_mask_host};
use sqft::tensor::{Rng, Tensor};
use sqft::util::prop::forall;
use std::collections::BTreeMap;

fn hyper(l: usize, r: usize) -> ModelHyper {
    let mods: Vec<String> =
        ["q", "k", "v", "up", "down"].iter().map(|s| s.to_string()).collect();
    let mut mod_dims = BTreeMap::new();
    for m in &mods {
        mod_dims.insert(m.clone(), (32usize, 32usize));
    }
    ModelHyper {
        name: "prop".into(), vocab: 64, d_model: 32, n_heads: 2, d_ff: 64,
        seq_len: 48, batch: 8, r_max: r, group_size: 16, param_count: 0,
        n_layers: l, mods, mod_dims,
    }
}

#[test]
fn prop_merge_never_densifies() {
    // S{W^p + (BA)⊙M} ⊆ S{W^p} for arbitrary adapters (paper Eq. 1-2)
    forall("merge_never_densifies", 11, 60,
        |rng: &mut Rng, size| {
            let (out, inp, r) = (2 + size, 2 + size, 1 + size / 4);
            let a = Tensor::randn(rng, &[r, inp], 1.0);
            let b = Tensor::randn(rng, &[out, r], 1.0);
            let mask_data: Vec<f32> =
                (0..out * inp).map(|_| (rng.next_f32() > 0.5) as i32 as f32).collect();
            let mask = Tensor::new(&[out, inp], mask_data).unwrap();
            let rm_data: Vec<f32> =
                (0..r).map(|i| (i < 1 + rng.below(r)) as i32 as f32).collect();
            let rm = Tensor::new(&[r], rm_data).unwrap();
            let w = Tensor::randn(rng, &[out, inp], 1.0).mul(&mask).unwrap();
            (w, a, b, mask, rm)
        },
        |(w, a, b, mask, rm)| {
            let delta = adapter_delta(a, b, Some(mask), rm, 1.3).map_err(|e| e.to_string())?;
            let merged = w.add(&delta).map_err(|e| e.to_string())?;
            for i in 0..w.rows() {
                for j in 0..w.cols() {
                    if mask.at2(i, j) == 0.0 && merged.at2(i, j) != 0.0 {
                        return Err(format!("zero resurrected at ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
}

#[test]
fn prop_gptq_beats_or_matches_rtn_and_preserves_zeros() {
    forall("gptq_vs_rtn", 13, 25,
        |rng: &mut Rng, size| {
            let n = 8 + 2 * size; // even, >= group
            let out = 4 + size;
            let w0 = Tensor::randn(rng, &[out, n], 0.5);
            let mask_data: Vec<f32> =
                (0..out * n).map(|_| (rng.next_f32() > 0.4) as i32 as f32).collect();
            let mask = Tensor::new(&[out, n], mask_data).unwrap();
            let w = w0.mul(&mask).unwrap();
            let x = Tensor::randn(rng, &[3 * n, n], 1.0);
            let mut h = Tensor::zeros(&[n, n]);
            x.accumulate_gram(&mut h);
            (w, h, mask)
        },
        |(w, h, mask)| {
            let gs = if w.cols() % 8 == 0 { 8 } else { w.cols() };
            let g = gptq_quantize(w, h, gs, 4, Some(mask), 0.05)
                .map_err(|e| e.to_string())?;
            let r = rtn_quantize(w, gs, 4, Some(mask)).map_err(|e| e.to_string())?;
            let (ge, re) = (g.weighted_err(w, h), r.weighted_err(w, h));
            if ge > re * 1.05 + 1e-9 {
                return Err(format!("gptq err {ge} > rtn err {re}"));
            }
            for i in 0..w.rows() {
                for j in 0..w.cols() {
                    if mask.at2(i, j) == 0.0 && g.dequant.at2(i, j) != 0.0 {
                        return Err(format!("gptq resurrected zero at ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
}

#[test]
fn prop_int4_pack_roundtrip() {
    forall("int4_roundtrip", 17, 100,
        |rng: &mut Rng, size| {
            let (out, inp) = (1 + size, 2 * (1 + size));
            Tensor::new(&[out, inp],
                (0..out * inp).map(|_| rng.below(16) as f32).collect()).unwrap()
        },
        |codes| {
            let bytes = pack_int4(codes).map_err(|e| e.to_string())?;
            if bytes.len() != codes.len() / 2 {
                return Err("wrong packed size".into());
            }
            let back = unpack_int4(&bytes, codes.rows(), codes.cols())
                .map_err(|e| e.to_string())?;
            if &back != codes {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
}

#[test]
fn prop_int4_pack_rejects_corrupt_codes() {
    // one corrupted entry anywhere must fail the whole pack, never pack a
    // wrong byte (NaN casts to 0, fractions truncate — both silent without
    // the validation)
    forall("int4_rejects_corrupt", 23, 60,
        |rng: &mut Rng, size| {
            let (out, inp) = (1 + size, 2 * (1 + size));
            let mut codes = Tensor::new(&[out, inp],
                (0..out * inp).map(|_| rng.below(16) as f32).collect()).unwrap();
            let (i, j) = (rng.below(out), rng.below(inp));
            let bad = match rng.below(5) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                3 => rng.below(15) as f32 + 0.5,
                _ => 16.0 + rng.below(8) as f32,
            };
            codes.set2(i, j, bad);
            codes
        },
        |codes| match pack_int4(codes) {
            Err(_) => Ok(()),
            Ok(_) => Err("corrupt code packed without error".into()),
        });
}

#[test]
fn prop_fake_quant_projection_and_range() {
    // fq is idempotent and its codes stay in [0, qmax]
    forall("fake_quant_projection", 19, 60,
        |rng: &mut Rng, size| {
            let (out, g, gs) = (1 + size, 1 + size / 8, 4);
            let w = Tensor::randn(rng, &[out, g * gs], 1.0);
            let scales = Tensor::rand_uniform(rng, &[out, g], 0.02, 0.3);
            let zeros = Tensor::new(&[out, g],
                (0..out * g).map(|_| rng.below(16) as f32).collect()).unwrap();
            (w, scales, zeros)
        },
        |(w, scales, zeros)| {
            let (codes, dq) =
                fake_quant_host(w, scales, zeros, 15.0).map_err(|e| e.to_string())?;
            if codes.data().iter().any(|&c| !(0.0..=15.0).contains(&c) || c != c.round()) {
                return Err("code out of range/non-integral".into());
            }
            let (_, dq2) =
                fake_quant_host(&dq, scales, zeros, 15.0).map_err(|e| e.to_string())?;
            for (a, b) in dq.data().iter().zip(dq2.data()) {
                if (a - b).abs() > 1e-5 {
                    return Err(format!("not idempotent: {a} vs {b}"));
                }
            }
            Ok(())
        });
}

#[test]
fn prop_wanda_mask_fraction_and_monotone() {
    forall("wanda_fraction", 23, 60,
        |rng: &mut Rng, size| {
            let (m, n) = (1 + size, 4 + 2 * size);
            let w = Tensor::randn(rng, &[m, n], 1.0);
            let norms = Tensor::rand_uniform(rng, &[n], 0.01, 2.0);
            let sp = (rng.below(9) + 1) as f64 / 10.0;
            (w, norms, sp)
        },
        |(w, norms, sp)| {
            let mask = wanda_mask_host(w, norms, *sp);
            let drop = ((*sp * w.cols() as f64).round()) as usize;
            let keep = (w.cols() - drop) as f32;
            for i in 0..w.rows() {
                let kept: f32 = mask.row(i).iter().sum();
                if kept != keep {
                    return Err(format!("row {i}: kept {kept} != {keep}"));
                }
            }
            // monotone: raising sparsity never keeps a dropped weight
            let sp2 = (sp + 0.1).min(1.0);
            let mask2 = topk_row_mask(
                &{
                    let mut s = Tensor::zeros(&[w.rows(), w.cols()]);
                    for i in 0..w.rows() {
                        for j in 0..w.cols() {
                            s.set2(i, j, w.at2(i, j).abs() * norms.data()[j]);
                        }
                    }
                    s
                },
                sp2,
            );
            for (a, b) in mask2.data().iter().zip(mask.data()) {
                if *a == 1.0 && *b == 0.0 {
                    return Err("higher sparsity kept a weight lower dropped".into());
                }
            }
            Ok(())
        });
}

#[test]
fn prop_search_space_realize_prefix_and_scale() {
    forall("nls_realize", 29, 60,
        |rng: &mut Rng, size| {
            let l = 1 + size / 8;
            let r = 4 + (size / 4) * 2;
            let n_choices = 2 + rng.below(3);
            let mut choices: Vec<usize> =
                (0..n_choices).map(|_| 1 + rng.below(r)).collect();
            choices.sort_unstable();
            choices.dedup();
            let h = hyper(l, r);
            let space = SearchSpace::new(&h, choices, 2.0 * r as f32).unwrap();
            let mut rng2 = rng.fork(1);
            let cfg = space.sample(&mut rng2);
            (space, cfg)
        },
        |(space, cfg)| {
            let p = space.realize(cfg).map_err(|e| e.to_string())?;
            for (mi, m) in space.mods.iter().enumerate() {
                let rm = p.get(&format!("rankmask_{m}")).map_err(|e| e.to_string())?;
                let sc = p.get(&format!("scale_{m}")).map_err(|e| e.to_string())?;
                for l in 0..space.n_layers {
                    let r = space.rank_of(cfg, space.instance(l, mi));
                    let row = &rm.data()[l * space.r_max..(l + 1) * space.r_max];
                    // prefix of ones, then zeros
                    for (j, &v) in row.iter().enumerate() {
                        let want = (j < r) as i32 as f32;
                        if v != want {
                            return Err(format!("{m}/{l}: rankmask[{j}]={v}, want {want}"));
                        }
                    }
                    let want_scale = space.alpha / r as f32;
                    if (sc.data()[l] - want_scale).abs() > 1e-6 {
                        return Err(format!("{m}/{l}: scale {}", sc.data()[l]));
                    }
                }
            }
            // heuristic is the median choice everywhere
            let h = space.heuristic_config();
            if h.iter().any(|&i| i != space.choices.len() / 2) {
                return Err("heuristic not median".into());
            }
            Ok(())
        });
}

#[test]
fn prop_batcher_counts_and_masks() {
    forall("batcher_counts", 31, 40,
        |rng: &mut Rng, size| {
            let task = *rng.choose(&Task::all());
            let n = 1 + size * 3;
            (task, n, rng.next_u64())
        },
        |(task, n, seed)| {
            let tok = Tokenizer::new();
            let ds = Dataset::generate(*task, *n, 0, 0, *seed);
            let mut b = Batcher::new(&ds.train, &tok, 48, 8);
            let mut total = 0;
            let mut batches = 0;
            while let Some(batch) = b.next_batch().map_err(|e| e.to_string())? {
                total += batch.real;
                batches += 1;
                if batch.tokens.len() != 8 * 48 {
                    return Err("bad batch shape".into());
                }
                // every row has at least one answer position, and masked
                // targets are never PAD
                for bi in 0..batch.real {
                    let row_mask = &batch.loss_mask[bi * 48..(bi + 1) * 48];
                    if !row_mask.iter().any(|&m| m == 1.0) {
                        return Err("row without answer mask".into());
                    }
                    for (i, &m) in row_mask.iter().enumerate() {
                        if m == 1.0 && batch.targets[bi * 48 + i] == 0 {
                            return Err("masked target is PAD".into());
                        }
                    }
                }
            }
            if total != *n || batches != n.div_ceil(8) {
                return Err(format!("covered {total}/{n} in {batches} batches"));
            }
            Ok(())
        });
}

#[test]
fn prop_sample_answers_verifiable() {
    // every generated MC sample's answer is one of the chars appearing in a
    // small closed set, and math answers parse as integers
    forall("answers_verifiable", 37, 100,
        |rng: &mut Rng, _| {
            let task = *rng.choose(&Task::all());
            let mut r2 = rng.fork(2);
            (task, task.gen_sample(&mut r2))
        },
        |(task, s): &(Task, Sample)| {
            if !s.answer.ends_with('.') {
                return Err("answer must end with '.'".into());
            }
            let body = &s.answer[..s.answer.len() - 1];
            if task.is_multiple_choice() {
                if body.len() != 1 {
                    return Err(format!("MC answer '{body}' not single char"));
                }
            } else if body.parse::<i64>().is_err() {
                return Err(format!("math answer '{body}' not an integer"));
            }
            Ok(())
        });
}
