//! Multi-tenant serving integration: ≥3 adapters over one device-resident
//! frozen base.  The router's per-tenant answers must match what each
//! tenant's adapter produces through the single-adapter `generate_batch`
//! path — batching across tenants must never leak another tenant's
//! adapter into a forward pass.

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::{init_base, ParamSet};
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::Runtime;
use sqft::serve::{AdapterRegistry, Engine, Request, Router, SchedulerOpts, MERGED_ID};
use sqft::tensor::Rng;
use std::path::Path;
use std::sync::mpsc::channel;
use std::time::Duration;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

#[test]
fn multi_adapter_answers_match_single_adapter_generation() {
    let Some(rt) = runtime() else { return };
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 400, 0, 40, 21);
    let base = init_base(&hyper, &mut Rng::new(5));
    // dense LoRA base: no calibration needed, fast to prepare
    let prepared = pipeline::prepare(&rt, config, &base, Method::Lora, 0.0,
                                     &ds.train, &tok, 0, &mut Rng::new(6)).unwrap();
    let frozen = prepared.frozen_set().unwrap();
    let mut entries = pipeline::tenant_adapters(&rt, config, &prepared, 3,
                                                &ds.train, &tok, 4, 100).unwrap();
    // distinct seeds must give distinct tenant adapters
    let a0 = entries[0].host_sets[0].get("a_q").unwrap();
    let a1 = entries[1].host_sets[0].get("a_q").unwrap();
    assert_ne!(a0, a1, "tenant adapters are identical; seeds not applied");
    // a few steps on a random base leave B ≈ 0 (near-identical outputs),
    // so inject a large per-tenant delta: answers must then visibly depend
    // on which adapter served the request
    for (i, e) in entries.iter_mut().enumerate() {
        let mut rng = Rng::new(200 + i as u64);
        let a_shape = e.host_sets[0].get("a_q").unwrap().shape().to_vec();
        let b_shape = e.host_sets[0].get("b_q").unwrap().shape().to_vec();
        e.host_sets[0].insert("a_q", sqft::tensor::Tensor::randn(&mut rng, &a_shape, 1.0));
        e.host_sets[0].insert("b_q", sqft::tensor::Tensor::randn(&mut rng, &b_shape, 1.0));
    }

    let engine = Engine::new(&rt, config, &frozen, None, "eval", 4).unwrap();

    // reference answers: each tenant through the single-adapter path
    let mut grng = Rng::new(31);
    let prompts: Vec<String> =
        (0..6).map(|_| task.gen_sample(&mut grng).prompt).collect();
    let mut expected: Vec<Vec<String>> = Vec::new();
    for e in &entries {
        let sets: Vec<&ParamSet> = e.host_sets.iter().collect();
        expected.push(engine.generate_batch_for(&sets, &e.eval_kind, &prompts).unwrap());
    }
    // the tenants genuinely disagree somewhere, otherwise the test is vacuous
    assert!(
        expected.iter().any(|ans| ans != &expected[0]),
        "all tenants answer identically; multi-tenant check is vacuous"
    );

    let ids: Vec<String> = entries.iter().map(|e| e.id.clone()).collect();
    // device-resident registration: the router serves these tenants through
    // the cached path, so matching the host-upload references below is the
    // byte-identical equivalence check for the cached decode loop
    let mut registry = AdapterRegistry::new(4);
    for e in entries {
        registry.register_resident(&rt, &hyper, e).unwrap();
    }
    for id in &ids {
        assert!(registry.device_set(id).is_some(), "tenant {id} not device-resident");
    }
    let mut router = Router::new(engine, registry);

    // interleave the tenants' requests so batches must be re-grouped
    let (tx, rx) = channel::<Request>();
    let mut replies = Vec::new();
    for (pi, p) in prompts.iter().enumerate() {
        for (ti, id) in ids.iter().enumerate() {
            let (rtx, rrx) = channel();
            tx.send(Request::new(Some(id.clone()), p.clone(), rtx)).unwrap();
            replies.push((ti, pi, rrx));
        }
    }
    drop(tx);
    let opts = SchedulerOpts { max_batch: hyper.batch,
                               aging: Duration::from_millis(20),
                               ..Default::default() };
    let stats = router.serve(rx, opts).unwrap();

    for (ti, pi, rrx) in replies {
        let ans = rrx.recv().unwrap().unwrap();
        assert_eq!(ans, expected[ti][pi], "tenant {ti} prompt {pi} diverged");
    }
    assert_eq!(stats.total.served, prompts.len() * ids.len());
    assert_eq!(stats.total.errors, 0);
    assert_eq!(stats.per_tenant.len(), ids.len());
    for id in &ids {
        let s = stats.tenant(id).expect("per-tenant stats");
        assert_eq!(s.served, prompts.len(), "tenant {id}");
        assert_eq!(s.errors, 0);
        assert!(s.latency_ms.is_some());
    }
    // every forward serves one adapter, so ≥ one batch per tenant
    assert!(stats.scheduler.batches >= ids.len());
    assert_eq!(stats.scheduler.scheduled, stats.total.served);
    assert!(stats.scheduler.avg_fill() > 0.0);
    // continuous-batching bookkeeping: forwards happened, occupancy is a
    // sane ratio, and the new per-request timing summaries are populated
    assert!(stats.decode_steps > 0);
    assert!(stats.occupancy > 0.0 && stats.occupancy <= 1.0 + 1e-9);
    assert!(stats.total.ttft_ms.is_some() && stats.total.queue_ms.is_some());
    for id in &ids {
        let s = stats.tenant(id).unwrap();
        assert!(s.ttft_ms.is_some(), "tenant {id} missing ttft");
        assert!(s.queue_ms.is_some(), "tenant {id} missing queue wait");
    }
}

#[test]
fn merged_fast_path_and_unknown_adapter() {
    let Some(rt) = runtime() else { return };
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let task = Task::SynArcE;
    let ds = Dataset::generate(task, 200, 0, 20, 3);
    let base = init_base(&hyper, &mut Rng::new(8));
    let prepared = pipeline::prepare(&rt, config, &base, Method::Lora, 0.0,
                                     &ds.train, &tok, 0, &mut Rng::new(9)).unwrap();
    let frozen = prepared.frozen_set().unwrap();
    let engine = Engine::new(&rt, config, &frozen, None, "eval", 3).unwrap();

    let mut grng = Rng::new(17);
    let prompts: Vec<String> =
        (0..4).map(|_| task.gen_sample(&mut grng).prompt).collect();
    let expected = engine.generate_batch(&prompts).unwrap();

    let mut router = Router::new(engine, AdapterRegistry::new(2));
    let (tx, rx) = channel::<Request>();
    let mut replies = Vec::new();
    for p in &prompts {
        let (rtx, rrx) = channel();
        tx.send(Request::new(None, p.clone(), rtx)).unwrap();
        replies.push(rrx);
    }
    // one request for a tenant nobody registered
    let (rtx, unknown_rx) = channel();
    tx.send(Request::new(Some("nope".to_string()), prompts[0].clone(), rtx)).unwrap();
    drop(tx);

    let opts = SchedulerOpts { max_batch: hyper.batch,
                               aging: Duration::from_millis(20),
                               ..Default::default() };
    let stats = router.serve(rx, opts).unwrap();

    for (rrx, want) in replies.into_iter().zip(&expected) {
        assert_eq!(&rrx.recv().unwrap().unwrap(), want);
    }
    let err = unknown_rx.recv().unwrap();
    assert!(err.is_err(), "unknown adapter must error, not serve the base");
    assert!(format!("{:#}", err.unwrap_err()).contains("not registered"));

    let merged = stats.tenant(MERGED_ID).expect("merged-path stats");
    assert_eq!(merged.served, prompts.len());
    assert_eq!(merged.errors, 0);
    let nope = stats.tenant("nope").expect("unknown-tenant stats");
    assert_eq!(nope.errors, 1);
    assert_eq!(nope.served, 0);
    assert_eq!(stats.total.errors, 1);
}
