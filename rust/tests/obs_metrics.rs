//! Observability reconciliation (ISSUE 6 acceptance): the metrics
//! registry and the JSONL trace are two views over one instrumented
//! serving run, so they must agree *exactly* — with each other and with
//! the `ServeStats`/`PoolServeStats` assembled from the same registry.
//!
//! A 2-worker pool serves a mixed short/long multi-tenant workload
//! (per-request `max_new_tokens` caps plus one unknown tenant), then:
//!
//!   - retire/error trace events count up to `served`/`errors` and to
//!     the `serve_requests_total`/`serve_errors_total` counters;
//!   - per-request token spans (retire + error `tokens` fields) sum to
//!     `generated_tokens` == `serve_tokens_total` — token accounting is
//!     exact, not sampled;
//!   - dispatch batches map 1:1 onto decode sessions, stolen batches
//!     onto `sched_steals_total` and `serve_stolen_sessions_total`;
//!   - uploads reconcile bytewise: every upload is a whole token batch
//!     (`batch * seq * 4` bytes) or a whole `adapter_idx` vector
//!     (`batch * 4`, the gathered mixed path) — tenants are
//!     device-resident, so nothing else ever moves;
//!   - the cross-shard `SchedulerMetrics` merge equals the registry's
//!     `sched_*` sums, and `max_queue_depth` equals the queue-depth
//!     gauge's peak watermark.

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::faults::{FaultInjector, FaultKind, FaultRule, SITE_FORWARD, SITE_WORKER_PANIC};
use sqft::model::init_base;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::Runtime;
use sqft::serve::{
    serve_pool_obs, AdapterEntry, EngineSpec, PoolOpts, Request, SchedulerOpts, ServeError,
    ServeObs, SharedAdapterSource,
};
use sqft::tensor::Rng;
use sqft::util::json::Json;
use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::mpsc::channel;
use std::time::Duration;

struct Fixture {
    dir: PathBuf,
    hyper: sqft::runtime::ModelHyper,
    frozen: sqft::model::ParamSet,
    entries: Vec<AdapterEntry>,
}

fn fixture(rt: &Runtime) -> Fixture {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 300, 0, 30, 171);
    let base = init_base(&hyper, &mut Rng::new(133));
    let prepared = pipeline::prepare(rt, config, &base, Method::Lora, 0.0,
                                     &ds.train, &tok, 0, &mut Rng::new(134)).unwrap();
    let frozen = prepared.frozen_set().unwrap();
    let entries = pipeline::tenant_adapters(rt, config, &prepared, 3,
                                            &ds.train, &tok, 2, 800).unwrap();
    Fixture { dir, hyper, frozen, entries }
}

fn spec(f: &Fixture) -> EngineSpec {
    EngineSpec {
        artifacts: f.dir.clone(),
        config: "sqft-tiny".to_string(),
        frozen: f.frozen.clone(),
        eval_kind: "eval".to_string(),
        max_new_tokens: 4,
        registry_capacity: 8,
        device_budget: 0,
        degrade_ranks: Vec::new(),
    }
}

/// Parsed trace events of one kind, keyed helpers over `Json` objects.
fn events<'a>(parsed: &'a [Json], ev: &str) -> Vec<&'a Json> {
    parsed.iter().filter(|e| e.req("ev").unwrap().as_str().unwrap() == ev).collect()
}

fn num(e: &Json, key: &str) -> usize {
    e.req(key).unwrap().as_usize().unwrap()
}

#[test]
fn pool_counters_reconcile_with_trace_spans() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt);
    let task = Task::SynBoolq;
    let source = SharedAdapterSource::new(f.hyper.clone(), 8);
    source.register_all(f.entries.clone()).unwrap();

    // mixed short/long workload: even requests are capped at 2 generated
    // tokens, odd ones run to the engine default (4); one unknown tenant
    let mut grng = Rng::new(177);
    let (tx, rx) = channel::<Request>();
    let mut replies = Vec::new();
    // request id -> generated-token cap, for per-span bounds checks
    let mut caps: BTreeMap<usize, usize> = BTreeMap::new();
    let mut sent = 0usize;
    for i in 0..20 {
        let id = Some(f.entries[i % f.entries.len()].id.clone());
        let (rtx, rrx) = channel();
        let mut req = Request::new(id, task.gen_sample(&mut grng).prompt, rtx);
        if i % 2 == 0 {
            req.max_new_tokens = Some(2);
            req.min_new_tokens = 1;
        }
        caps.insert(req.id as usize, req.max_new_tokens.unwrap_or(4));
        sent += 1;
        tx.send(req).unwrap();
        replies.push(rrx);
    }
    let (rtx, rrx) = channel();
    tx.send(Request::new(Some("nope".into()), task.gen_sample(&mut grng).prompt, rtx)).unwrap();
    replies.push(rrx);
    sent += 1;
    drop(tx);

    let obs = ServeObs::with_trace();
    let stats = serve_pool_obs(
        &spec(&f),
        &source,
        rx,
        PoolOpts {
            workers: 2,
            sched: SchedulerOpts { max_batch: f.hyper.batch,
                                   aging: Duration::from_millis(20),
                                   ..Default::default() },
            ..Default::default()
        },
        obs.clone(),
    )
    .unwrap();
    for r in replies {
        let _ = r.recv().unwrap();
    }
    assert_eq!(stats.serve.total.served, sent - 1);
    assert_eq!(stats.serve.total.errors, 1, "exactly the unknown tenant errors");

    let snap = obs.registry().snapshot();
    let lines = obs.trace().expect("with_trace carries a log").lines();
    let parsed: Vec<Json> = lines.iter().map(|l| Json::parse(l).unwrap()).collect();
    let served = stats.serve.total.served;

    // lifecycle counts: every request enqueues once; every served request
    // admits, sees a first token, and retires exactly once
    assert_eq!(events(&parsed, "enqueue").len(), sent);
    assert_eq!(events(&parsed, "admit").len(), served);
    assert_eq!(events(&parsed, "first_token").len(), served);
    let retires = events(&parsed, "retire");
    let errors = events(&parsed, "error");
    assert_eq!(retires.len(), served);
    assert_eq!(errors.len(), stats.serve.total.errors);
    assert_eq!(snap.sum("serve_requests_total") as usize, served);
    assert_eq!(snap.sum("serve_errors_total") as usize, stats.serve.total.errors);

    // per-request token spans sum to the reported totals, exactly
    let retire_tokens: usize = retires.iter().map(|e| num(e, "tokens")).sum();
    let error_tokens: usize = errors.iter().map(|e| num(e, "tokens")).sum();
    assert_eq!(retire_tokens + error_tokens, stats.serve.generated_tokens);
    assert_eq!(snap.sum("serve_tokens_total") as usize, stats.serve.generated_tokens);
    for e in &retires {
        let cap = caps[&num(e, "req")];
        let tokens = num(e, "tokens");
        assert!(tokens >= 1 && tokens <= cap, "span of {tokens} tokens exceeds cap {cap}");
    }

    // each retired request went enqueue -> admit -> retire in order, on
    // one worker, out of one slot
    let admits: BTreeMap<usize, &Json> =
        events(&parsed, "admit").iter().map(|e| (num(e, "req"), *e)).collect();
    let t_ms = |e: &Json| e.req("t_ms").unwrap().as_f64().unwrap();
    for e in &retires {
        let a = admits[&num(e, "req")];
        assert_eq!(num(a, "worker"), num(e, "worker"));
        assert!(t_ms(a) <= t_ms(e), "admit after retire for req {}", num(e, "req"));
    }

    // dispatched batches map 1:1 onto decode sessions; stolen batches
    // onto the scheduler's steal count and the stolen-session counter
    let dispatches = events(&parsed, "dispatch");
    let batches: BTreeSet<usize> = dispatches.iter().map(|e| num(e, "batch")).collect();
    assert_eq!(batches.len(), snap.sum("serve_sessions_total") as usize);
    let stolen: BTreeSet<usize> = dispatches
        .iter()
        .filter(|e| matches!(e.req("stolen").unwrap(), Json::Bool(true)))
        .map(|e| num(e, "batch"))
        .collect();
    assert_eq!(stolen.len(), stats.steals);
    assert_eq!(snap.sum("sched_steals_total") as usize, stats.steals);
    assert_eq!(snap.sum("serve_stolen_sessions_total") as usize, stats.steals);

    // per-worker views are the same counters sliced by label
    let mut by_worker: BTreeMap<usize, usize> = BTreeMap::new();
    for e in &retires {
        *by_worker.entry(num(e, "worker")).or_default() += 1;
    }
    for w in &stats.per_worker {
        assert!(w.setup_error.is_none());
        assert_eq!(w.served, by_worker.get(&w.worker).copied().unwrap_or(0));
    }
    assert_eq!(stats.per_worker.iter().map(|w| w.served).sum::<usize>(), served);

    // bytewise upload reconciliation: every tenant is device-resident,
    // so what a forward moves is fully determined by its kind.  On the
    // KV-cached split a prefill ships the token batch plus the `seq_lens`
    // vector, every other forward ships only the frontier + positions
    // vectors, and gathered mixed sessions add whole per-row
    // `adapter_idx` vectors — never a partial buffer and never adapter
    // weights.  (Artifact dirs built before the split carry no prefill
    // kinds; those runs fall back to the legacy token-batch-per-step
    // contract, reconciled in the `else` arm so the test stays exact on
    // both.)
    let token_batch_bytes = (f.hyper.batch * f.hyper.seq_len * 4) as u64;
    let vec_bytes = (f.hyper.batch * 4) as u64;
    let steps = snap.sum("serve_decode_steps_total") as u64;
    let uploads = snap.sum("runtime_uploads_total") as u64;
    let prefills = snap.sum("serve_prefills_total") as u64;
    assert!(uploads >= 1);
    assert!(uploads <= steps);
    let total_bytes = snap.sum("runtime_upload_bytes_total") as u64;
    let non_idx_bytes = if prefills > 0 {
        // token batches move exactly at prefill forwards, nowhere else
        assert_eq!(uploads, prefills,
            "cached decode must confine token-batch uploads to prefills");
        prefills * (token_batch_bytes + vec_bytes) + (steps - prefills) * 2 * vec_bytes
    } else {
        uploads * token_batch_bytes
    };
    assert!(total_bytes >= non_idx_bytes,
        "{total_bytes} bytes moved, below the {non_idx_bytes}-byte floor");
    let idx_total = total_bytes - non_idx_bytes;
    assert_eq!(idx_total % vec_bytes, 0,
        "non-token upload bytes must be whole adapter_idx vectors");
    assert!(idx_total / vec_bytes <= steps,
        "at most one adapter_idx upload per forward");

    // prefill instruments reconcile three ways: the latency histogram
    // observes once per counted prefill; the cache gauge peaks at exactly
    // one resident page set (capacity × (2·L·S·d_model + vocab) f32s);
    // and the trace carries one `prefill` span per served request —
    // admission marks the row pending, so the forward producing a
    // request's first token is always a page rebuild, on the same worker,
    // timestamped between its admit and first_token spans
    let prefill_hist: u64 = snap
        .samples
        .iter()
        .filter(|sm| sm.name == "serve_prefill_ms")
        .map(|sm| match &sm.value {
            sqft::obs::Value::Histogram { count, .. } => *count,
            _ => panic!("expected a histogram"),
        })
        .sum();
    assert_eq!(prefill_hist, prefills, "serve_prefill_ms count != serve_prefills_total");
    let prefill_events = events(&parsed, "prefill");
    if prefills > 0 {
        assert_eq!(prefill_events.len(), served,
            "every served request's first token rides exactly one prefill");
        let page_bytes = (f.hyper.batch
            * (2 * f.hyper.n_layers * f.hyper.seq_len * f.hyper.d_model + f.hyper.vocab)
            * 4) as u64;
        assert_eq!(snap.gauge_peak_max("serve_cache_resident_bytes") as u64, page_bytes,
            "resident-cache gauge must peak at one full page set per worker");
        let firsts: BTreeMap<usize, &Json> =
            events(&parsed, "first_token").iter().map(|e| (num(e, "req"), *e)).collect();
        for e in &prefill_events {
            let req = num(e, "req");
            let (a, ft) = (admits[&req], firsts[&req]);
            assert_eq!(num(a, "worker"), num(e, "worker"));
            assert!(t_ms(a) <= t_ms(e) && t_ms(e) <= t_ms(ft),
                "prefill span for req {req} must land between admit and first_token");
        }
    } else {
        assert!(prefill_events.is_empty(), "prefill spans on the legacy path");
    }

    // the cross-shard SchedulerMetrics merge equals the registry's sums.
    // A request can be scheduled more than once: survivors of a rebuilt
    // session (here: gathered-ineligible requests deferred out of a mixed
    // session) are requeued and dispatched again — the trace's rebuild
    // spans account for every extra dispatch exactly.
    let requeued: usize =
        events(&parsed, "session_rebuilt").iter().map(|e| num(e, "survivors")).sum();
    let sched = &stats.serve.scheduler;
    assert_eq!(sched.scheduled, sent + requeued);
    assert_eq!(snap.sum("sched_scheduled_total") as usize, sched.scheduled);
    assert_eq!(snap.sum("sched_batches_total") as usize, sched.batches);
    assert_eq!(snap.sum("sched_admitted_total") as usize, sched.admitted);
    assert_eq!(snap.sum("sched_aged_batches_total") as usize, sched.aged_batches);
    assert_eq!(snap.sum("sched_mixed_batches_total") as usize, sched.mixed_batches);
    assert!(sched.mixed_batches >= 1,
        "a 3-tenant burst into one scheduler must produce a mixed batch");
    // the distinct-tenants histogram observes exactly once per dispatched
    // batch, so its count reconciles with the batch counter
    let hist_count: u64 = snap
        .samples
        .iter()
        .filter(|sm| sm.name == "sched_batch_distinct_tenants")
        .map(|sm| match &sm.value {
            sqft::obs::Value::Histogram { count, .. } => *count,
            _ => panic!("expected a histogram"),
        })
        .sum();
    assert_eq!(hist_count as usize, sched.batches);
    assert!((snap.sum("sched_fill_sum") - sched.fill_sum).abs() < 1e-9);
    assert_eq!(snap.gauge_peak_max("sched_queue_depth") as usize, sched.max_queue_depth);

    // latency/ttft/queue series are per-served-request, never sampled
    for name in ["serve_latency_ms", "serve_ttft_ms", "serve_queue_ms"] {
        let n: usize = snap.series_by(name, "tenant").values().map(Vec::len).sum();
        assert_eq!(n, served, "{name} must carry one sample per served request");
    }

    // a fault-free run records *zero* on every fault-path counter, and
    // the trace carries none of the fault-path events — the chaos
    // instrumentation must be invisible until something actually fails.
    // (`serve_sessions_rebuilt_total` is not in this list: deferring the
    // unknown tenant out of a mixed session is a rebuild, not a fault —
    // it reconciles against the trace instead.)
    for name in [
        "serve_retries_total",
        "serve_cancelled_total",
        "serve_shed_total",
        "serve_deadline_exceeded_total",
        "serve_worker_crashes_total",
    ] {
        assert_eq!(snap.sum(name) as usize, 0, "{name} must be 0 in a clean run");
    }
    for ev in ["retry", "cancel", "worker_crash"] {
        assert!(events(&parsed, ev).is_empty(), "unexpected {ev} event in a clean run");
    }
    assert_eq!(events(&parsed, "session_rebuilt").len(),
               snap.sum("serve_sessions_rebuilt_total") as usize);
    assert_eq!(sched.shed, 0);
    assert_eq!(sched.deadline_expired, 0);
}

/// Fault-path reconciliation: under an injected chaos plan (one transient
/// forward failure, one worker crash, one dropped client, one expired
/// deadline, a tight queue cap), the retry/shed/deadline/cancel/crash
/// counters must sum exactly against the trace events of the same run
/// *and* against the typed errors clients actually received.
#[test]
fn fault_counters_reconcile_with_trace_and_typed_errors() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt);
    let task = Task::SynBoolq;
    let source = SharedAdapterSource::new(f.hyper.clone(), 8);
    source.register_all(f.entries.clone()).unwrap();

    let mut grng = Rng::new(191);
    let (tx, rx) = channel::<Request>();
    let mut replies = Vec::new();
    let mut sent = 0usize;
    for i in 0..16 {
        let id = Some(f.entries[i % f.entries.len()].id.clone());
        let (rtx, rrx) = channel();
        let mut req = Request::new(id, task.gen_sample(&mut grng).prompt, rtx);
        if i == 0 {
            // dropped client; first in, so the queue cap can never have
            // shed it first — it must reach the fill path and be skipped
            drop(req.cancel_handle());
        }
        tx.send(req).unwrap();
        replies.push(rrx);
        sent += 1;
    }
    // one request already past its deadline (shed at push, DOA)
    let (rtx, rrx) = channel();
    let mut doa = Request::new(Some(f.entries[0].id.clone()),
                               task.gen_sample(&mut grng).prompt, rtx);
    doa.deadline = Some(std::time::Instant::now());
    tx.send(doa).unwrap();
    replies.push(rrx);
    sent += 1;
    drop(tx);

    // chaos plan: 2nd forward check errors once (transient, absorbed by
    // the retry budget), first claimed batch panics its worker (batch
    // requeued).  Everything is nth-pinned, so counts are exact.
    let faults = FaultInjector::seeded(17)
        .with_rule(FaultRule::nth(SITE_FORWARD, FaultKind::Error, 1))
        .with_rule(FaultRule::nth(SITE_WORKER_PANIC, FaultKind::Panic, 0));
    let obs = ServeObs::with_trace();
    let stats = serve_pool_obs(
        &spec(&f),
        &source,
        rx,
        PoolOpts {
            workers: 2,
            sched: SchedulerOpts {
                max_batch: f.hyper.batch,
                aging: Duration::from_millis(20),
                queue_cap: Some(4), // tight: pushes beyond 4/shard shed
                ..Default::default()
            },
            faults: faults.clone(),
        },
        obs.clone(),
    )
    .unwrap();
    assert_eq!(faults.fires(SITE_FORWARD), 1);
    assert_eq!(faults.fires(SITE_WORKER_PANIC), 1);

    // classify what clients actually got back
    let (mut ok, mut overloaded, mut deadline, mut cancelled, mut other) =
        (0usize, 0usize, 0usize, 0usize, 0usize);
    for rrx in replies {
        match rrx.recv().unwrap() {
            Ok(_) => ok += 1,
            Err(e) => match ServeError::of(&e) {
                Some(ServeError::Overloaded { .. }) => overloaded += 1,
                Some(ServeError::DeadlineExceeded { .. }) => deadline += 1,
                Some(ServeError::Cancelled) => cancelled += 1,
                _ => other += 1,
            },
        }
    }
    assert_eq!(ok + overloaded + deadline + cancelled + other, sent);
    assert_eq!(other, 0, "no untyped failures expected under this plan");
    assert_eq!(deadline, 1, "exactly the DOA request");
    assert_eq!(cancelled, 1, "exactly the dropped client");
    assert!(overloaded >= 1, "the tight queue cap must shed under an up-front burst");

    let snap = obs.registry().snapshot();
    let lines = obs.trace().expect("with_trace carries a log").lines();
    let parsed: Vec<Json> = lines.iter().map(|l| Json::parse(l).unwrap()).collect();

    // counters == typed errors clients saw
    let shed_by = snap.sum_by("serve_shed_total", "reason");
    assert_eq!(shed_by.get("overload").copied().unwrap_or(0.0) as usize, overloaded);
    assert_eq!(shed_by.get("deadline").copied().unwrap_or(0.0) as usize, deadline);
    assert_eq!(snap.sum("serve_deadline_exceeded_total") as usize, deadline);
    assert_eq!(snap.sum("serve_cancelled_total") as usize, cancelled);
    assert_eq!(snap.sum("serve_requests_total") as usize, ok);
    assert_eq!(snap.sum("serve_errors_total") as usize, 0);

    // counters == trace events of the same run
    assert_eq!(events(&parsed, "retry").len(), snap.sum("serve_retries_total") as usize);
    assert_eq!(snap.sum("serve_retries_total") as usize, 1, "the pinned transient failure");
    assert_eq!(events(&parsed, "cancel").len(), cancelled);
    assert_eq!(events(&parsed, "worker_crash").len(),
               snap.sum("serve_worker_crashes_total") as usize);
    assert_eq!(snap.sum("serve_worker_crashes_total") as usize, 1);
    assert_eq!(events(&parsed, "session_rebuilt").len(),
               snap.sum("serve_sessions_rebuilt_total") as usize);
    assert_eq!(snap.sum("serve_sessions_rebuilt_total") as usize, 1,
        "the crashed worker's batch is requeued exactly once");

    // the SchedulerMetrics view and the registry agree on sheds
    let sched = &stats.serve.scheduler;
    assert_eq!(sched.shed, overloaded + deadline);
    assert_eq!(sched.deadline_expired, deadline);

    // lifecycle closure under faults: every accepted request admits once
    // and ends exactly one way; retries/rebuilds never double-count
    let retires = events(&parsed, "retire");
    assert_eq!(retires.len(), ok);
    assert_eq!(events(&parsed, "enqueue").len(), sent);
    assert_eq!(events(&parsed, "admit").len(), ok,
        "admit events must match retires: retried steps and crash-requeued \
batches admit their requests exactly once");
    let retire_tokens: usize = retires.iter().map(|e| num(e, "tokens")).sum();
    assert_eq!(retire_tokens, stats.serve.generated_tokens);
    assert_eq!(snap.sum("serve_tokens_total") as usize, stats.serve.generated_tokens);
}
