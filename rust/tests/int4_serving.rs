//! Packed-INT4 serving path, end to end on sqft-tiny: a merged
//! QA-SparsePEFT model must serve from true packed u8 weights + group
//! params with (1) answers identical to the fake-quant f32 reference,
//! (2) only the token batch crossing the PJRT boundary per decode step,
//! (3) a device weight footprint a multiple smaller than the f32 path,
//! and (4) a lossless pack → save → load → serve round trip.
//!
//! Requires `make artifacts` (skips with a message if absent).

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::{init_base, linear_keys, ParamSet};
use sqft::nls::SearchSpace;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::{Runtime, UploadScope};
use sqft::serve::Engine;
use sqft::tensor::Rng;
use sqft::train::{Pretrainer, TrainOpts};
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

#[test]
fn int4_serving_matches_fake_quant_reference_and_stays_packed() {
    let Some(rt) = runtime() else { return };
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 400, 0, 40, 7);

    // a lightly-trained base, prepared + tuned with QA-SparsePEFT
    let mut pre = Pretrainer::new(&rt, config, init_base(&hyper, &mut Rng::new(7)));
    pre.train(&ds.train, &tok,
              &TrainOpts { steps: 20, lr: 2e-3, log_every: 20, seed: 7, fixed_rank: false })
        .unwrap();
    let prepared = pipeline::prepare(
        &rt, config, &pre.base, Method::QaSparsePeft, 0.5, &ds.train, &tok, 2,
        &mut Rng::new(9)).unwrap();
    let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
    let space = SearchSpace::new(&prepared.hyper, choices, alpha).unwrap();
    let (trainer, _) = pipeline::finetune(
        &rt, config, &prepared, space, &ds.train, &tok,
        &TrainOpts { steps: 8, lr: 1e-3, log_every: 8, seed: 11, fixed_rank: false })
        .unwrap();
    let cfg = trainer.space.heuristic_config();
    let merged = pipeline::merged_state(&prepared, &trainer, &cfg).unwrap();
    let int4 = pipeline::int4_model(&prepared, &merged).unwrap();

    // (a) dequantizing the packed codes reproduces the merged base weights
    // bit-for-bit — (q - z) * s is the same f32 arithmetic the merge ran
    let dense = int4.dequant_base().unwrap();
    for wkey in linear_keys() {
        assert_eq!(
            dense.get(wkey).unwrap(),
            merged.base.get(wkey).unwrap(),
            "{wkey}: packed codes do not reproduce the merged fake-quant values"
        );
    }

    // (b) the INT4 engine answers identically to the fake-quant f32 engine
    let mut frozen_m = ParamSet::new();
    for (n, v) in merged.base.iter() {
        frozen_m.insert(n, v.clone());
    }
    for (n, v) in pipeline::dense_adapter_masks(&hyper).iter() {
        frozen_m.insert(n, v.clone());
    }
    let engine_f32 = Engine::new(&rt, config, &frozen_m, None, "eval", 5).unwrap();
    let engine_i4 = Engine::new_int4(&rt, config, &int4, 5).unwrap();
    assert!(engine_i4.is_int4());
    let mut grng = Rng::new(3);
    let prompts: Vec<String> =
        (0..hyper.batch).map(|_| task.gen_sample(&mut grng).prompt).collect();
    let ans_f32 = engine_f32.generate_batch(&prompts).unwrap();
    let ans_i4 = engine_i4.generate_batch(&prompts).unwrap();
    assert_eq!(ans_i4, ans_f32, "INT4 serving diverged from fake-quant serving");

    // (c) steady-state decode ships only the token batch: all weight
    // inputs are device-resident packed u8 / f32 buffers.  This is the
    // legacy full-forward upload contract, so pin that leg (the cached
    // split's tighter per-step accounting lives in serve_kv_cache.rs;
    // (b) above already exercised it for both engines)
    engine_i4.set_full_forward(true);
    let scope = UploadScope::begin();
    let _ = engine_i4.generate_batch(&prompts).unwrap();
    let token_batch = (hyper.batch * hyper.seq_len * 4) as u64;
    assert_eq!(
        scope.bytes(),
        engine_i4.last_decode_uploads() as u64 * token_batch,
        "INT4 decode must upload the token batch only"
    );
    assert!(engine_i4.last_decode_uploads() <= engine_i4.last_decode_steps());

    // (d) the packed engine is resident at a fraction of the f32 engine
    let ratio = engine_f32.resident_weight_bytes() as f64
        / engine_i4.resident_weight_bytes().max(1) as f64;
    assert!(ratio >= 3.5, "INT4 resident footprint only {ratio:.2}x smaller");

    // (e) true-INT4 on disk: save → load → serve round-trips answers, and
    // the plain checkpoint loader refuses the packed file rather than
    // dropping weights
    let dir = std::env::temp_dir().join("sqft_int4_serving_test");
    std::fs::remove_dir_all(&dir).ok();
    let path = dir.join("merged_int4.ckpt");
    pipeline::save_int4_model(&int4, &path, vec![]).unwrap();
    assert!(sqft::model::checkpoint::load(&path).is_err());
    let loaded = pipeline::load_int4_model(&path).unwrap();
    assert_eq!(loaded.config, config);
    for wkey in linear_keys() {
        assert_eq!(
            loaded.packed[&format!("packed_{wkey}")],
            int4.packed[&format!("packed_{wkey}")],
            "{wkey}: packed bytes changed across the checkpoint round trip"
        );
    }
    let engine_loaded = Engine::new_int4(&rt, config, &loaded, 5).unwrap();
    let ans_loaded = engine_loaded.generate_batch(&prompts).unwrap();
    assert_eq!(ans_loaded, ans_i4, "checkpoint round trip changed answers");
    std::fs::remove_dir_all(&dir).ok();
}
