//! Corrupt-checkpoint corpus (ISSUE 9 satellite): truncations and single
//! bit-flips at every section boundary of the v2 container must come back
//! as typed [`CorruptCheckpoint`] errors naming the damaged section —
//! never a panic, never a silently wrong ParamSet.  Also pins the
//! version-compat contract: a legacy v1 container still loads (without
//! integrity checks), which is exactly why saves write v2.

use sqft::model::checkpoint::{
    load_adapter, load_packed, save_adapter, save_packed, CkptSection, CorruptCheckpoint,
    PackedTensor,
};
use sqft::model::ParamSet;
use sqft::tensor::{Rng, Tensor};
use std::collections::BTreeMap;
use std::path::PathBuf;

fn section_of(err: &anyhow::Error) -> Option<CkptSection> {
    err.downcast_ref::<CorruptCheckpoint>().map(|c| c.section)
}

struct Fixture {
    dir: PathBuf,
    /// pristine v2 container bytes (f32 params + one packed tensor)
    bytes: Vec<u8>,
    header_len: usize,
    f32_bytes: usize,
}

impl Fixture {
    fn new(name: &str) -> Fixture {
        let dir = std::env::temp_dir().join(format!("sqft_ckpt_corpus_{name}"));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(5);
        let mut p = ParamSet::new();
        p.insert("w", Tensor::randn(&mut rng, &[4, 8], 1.0));
        p.insert("v", Tensor::randn(&mut rng, &[8], 1.0));
        let mut packed = BTreeMap::new();
        packed.insert(
            "pw".to_string(),
            PackedTensor { shape: vec![2, 8], group_size: 4, data: vec![0x21; 8] },
        );
        let path = dir.join("pristine.ckpt");
        save_packed(&p, &packed, &path, sqft::util::json::Json::parse("{}").unwrap())
            .unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let header_len =
            u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let f32_bytes = (4 * 8 + 8) * 4;
        // layout sanity: magic(8) + hlen(8) + hcrc(4) + header + f32 + packed(8)
        assert_eq!(bytes.len(), 20 + header_len + f32_bytes + 8);
        assert_eq!(&bytes[..8], b"SQFTCKP2");
        Fixture { dir, bytes, header_len, f32_bytes }
    }

    fn load_variant(&self, tag: &str, bytes: &[u8]) -> anyhow::Result<()> {
        let path = self.dir.join(format!("{tag}.ckpt"));
        std::fs::write(&path, bytes).unwrap();
        load_packed(&path).map(|_| ())
    }
}

#[test]
fn truncation_at_every_boundary_names_the_right_section() {
    let f = Fixture::new("trunc");
    let hdr_end = 20 + f.header_len;
    let f32_end = hdr_end + f.f32_bytes;
    let cases: Vec<(usize, CkptSection)> = vec![
        (0, CkptSection::Magic),
        (4, CkptSection::Magic),              // mid-magic
        (8, CkptSection::Header),             // header length word missing
        (12, CkptSection::Header),            // mid-length
        (18, CkptSection::Header),            // mid header-CRC word
        (20, CkptSection::Header),            // header bytes missing
        (20 + f.header_len / 2, CkptSection::Header),
        (hdr_end, CkptSection::F32Data),      // whole f32 payload missing
        (hdr_end + f.f32_bytes / 2, CkptSection::F32Data),
        (f32_end, CkptSection::PackedData),   // whole packed payload missing
        (f32_end + 4, CkptSection::PackedData), // half the packed bytes
    ];
    for (cut, want) in cases {
        let err = f
            .load_variant(&format!("cut{cut}"), &f.bytes[..cut])
            .expect_err("truncated checkpoint must not load");
        assert_eq!(
            section_of(&err),
            Some(want),
            "truncation at {cut}: {err:#}"
        );
    }
}

#[test]
fn single_bitflip_at_every_boundary_names_the_right_section() {
    let f = Fixture::new("flip");
    let hdr_end = 20 + f.header_len;
    let f32_end = hdr_end + f.f32_bytes;
    let cases: Vec<(usize, CkptSection)> = vec![
        (0, CkptSection::Magic),            // magic first byte
        (7, CkptSection::Magic),            // magic/version last byte
        (8, CkptSection::Header),           // header length LSB
        (16, CkptSection::Header),          // stored header CRC
        (20, CkptSection::Header),          // first header byte
        (hdr_end - 1, CkptSection::Header), // last header byte
        (hdr_end, CkptSection::F32Data),    // first f32 byte
        (f32_end - 1, CkptSection::F32Data),
        (f32_end, CkptSection::PackedData), // first packed byte
        (f.bytes.len() - 1, CkptSection::PackedData),
    ];
    for (pos, want) in cases {
        let mut bytes = f.bytes.clone();
        bytes[pos] ^= 0x04;
        let err = f
            .load_variant(&format!("flip{pos}"), &bytes)
            .expect_err("bit-flipped checkpoint must not load");
        assert_eq!(section_of(&err), Some(want), "flip at {pos}: {err:#}");
    }
    // the pristine file still loads after all that
    f.load_variant("pristine2", &f.bytes).unwrap();
}

#[test]
fn legacy_v1_loads_without_integrity_and_v2_catches_what_v1_cannot() {
    let f = Fixture::new("legacy");
    // splice a v1 container out of the v2 bytes: v1 magic, same header
    // length, no CRC word, same header/payloads (the extra `integrity`
    // object in the header is ignored by the legacy path)
    let mut v1 = Vec::new();
    v1.extend_from_slice(b"SQFTCKP1");
    v1.extend_from_slice(&f.bytes[8..16]);
    v1.extend_from_slice(&f.bytes[20..]);
    f.load_variant("v1", &v1).expect("legacy v1 container must still load");
    // the same payload bit-flip a v2 load rejects sails through v1 —
    // the integrity gap that motivated the version bump
    let hdr_end_v1 = 16 + f.header_len;
    let mut v1_flip = v1.clone();
    v1_flip[hdr_end_v1] ^= 0x04;
    f.load_variant("v1flip", &v1_flip)
        .expect("v1 has no checksums; structural load succeeds");
    let mut v2_flip = f.bytes.clone();
    v2_flip[20 + f.header_len] ^= 0x04;
    let err = f.load_variant("v2flip", &v2_flip).unwrap_err();
    assert_eq!(section_of(&err), Some(CkptSection::F32Data));
}

#[test]
fn corrupt_adapter_checkpoint_is_typed_through_the_adapter_loader() {
    let dir = std::env::temp_dir().join("sqft_ckpt_corpus_adapter");
    std::fs::remove_dir_all(&dir).ok();
    let mut rng = Rng::new(9);
    let mut adapters = ParamSet::new();
    adapters.insert("a_q", Tensor::randn(&mut rng, &[2, 4, 8], 0.1));
    adapters.insert("b_q", Tensor::randn(&mut rng, &[2, 8, 4], 0.1));
    let mut rank = ParamSet::new();
    rank.insert("rankmask_q", Tensor::ones(&[2, 4]));
    rank.insert("scale_q", Tensor::full(&[2], 2.0));
    let path = dir.join("t.ckpt");
    save_adapter(&path, &adapters, &rank, "test", "eval", "t", "lora", 0.0).unwrap();
    load_adapter(&path).expect("pristine adapter loads");
    // flip one payload byte: the registry-facing loader reports a typed
    // f32-section corruption (this is what quarantines exactly one tenant)
    let mut bytes = std::fs::read(&path).unwrap();
    let n = bytes.len();
    bytes[n - 40] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();
    let err = load_adapter(&path).unwrap_err();
    assert_eq!(section_of(&err), Some(CkptSection::F32Data), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn saves_are_atomic_no_tmp_left_and_overwrite_preserves_or_replaces() {
    let dir = std::env::temp_dir().join("sqft_ckpt_corpus_atomic");
    std::fs::remove_dir_all(&dir).ok();
    let mut rng = Rng::new(3);
    let mut p = ParamSet::new();
    p.insert("w", Tensor::randn(&mut rng, &[4], 1.0));
    let path = dir.join("a.ckpt");
    save_packed(&p, &BTreeMap::new(), &path, sqft::util::json::Json::parse("{}").unwrap())
        .unwrap();
    // no temp sibling survives a successful save
    let leftovers: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().map(|x| x == "tmp").unwrap_or(false))
        .collect();
    assert!(leftovers.is_empty(), "stray temp files: {leftovers:?}");
    // overwriting with new contents fully replaces the old container
    let mut p2 = ParamSet::new();
    p2.insert("w", Tensor::full(&[4], 7.0));
    save_packed(&p2, &BTreeMap::new(), &path, sqft::util::json::Json::parse("{}").unwrap())
        .unwrap();
    let (loaded, _, _) = load_packed(&path).unwrap();
    assert_eq!(&loaded.get("w").unwrap().data()[..], &[7.0; 4]);
    std::fs::remove_dir_all(&dir).ok();
}
