//! Worker-pool serving: determinism and fairness (ISSUE 4 acceptance).
//!
//!   - N-worker `serve_pool` answers must be byte-identical per request
//!     to the single-worker `Router::serve` reference: replicas compile
//!     the same artifacts and rows decode independently, so worker
//!     count, batch composition, and steal schedule may change only the
//!     timing, never the bytes;
//!   - no tenant starves under concurrent admission: mixed batches span
//!     tenants inside one gathered session, and the uniform fallback
//!     pauses same-tenant refill whenever an aged sibling queue is
//!     waiting, so no long decode monopolizes its home worker;
//!   - the merged / no-adapter path and unknown-tenant errors behave as
//!     in single-worker serving.

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::init_base;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::Runtime;
use sqft::serve::{
    benchmark_pool, AdapterEntry, AdapterRegistry, Engine, EngineSpec, PoolOpts, Request,
    Router, SchedulerOpts, SharedAdapterSource,
};
use sqft::tensor::Rng;
use std::path::{Path, PathBuf};
use std::sync::mpsc::channel;
use std::time::Duration;

struct Fixture {
    dir: PathBuf,
    hyper: sqft::runtime::ModelHyper,
    frozen: sqft::model::ParamSet,
    entries: Vec<AdapterEntry>,
}

fn fixture(rt: &Runtime) -> Fixture {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 300, 0, 30, 71);
    let base = init_base(&hyper, &mut Rng::new(33));
    let prepared = pipeline::prepare(rt, config, &base, Method::Lora, 0.0,
                                     &ds.train, &tok, 0, &mut Rng::new(34)).unwrap();
    let frozen = prepared.frozen_set().unwrap();
    let mut entries = pipeline::tenant_adapters(rt, config, &prepared, 3,
                                                &ds.train, &tok, 2, 800).unwrap();
    // inject large per-tenant deltas so answers depend on which adapter
    // (and which replica's copy of it) served the request
    for (i, e) in entries.iter_mut().enumerate() {
        let mut rng = Rng::new(900 + i as u64);
        let a_shape = e.host_sets[0].get("a_q").unwrap().shape().to_vec();
        let b_shape = e.host_sets[0].get("b_q").unwrap().shape().to_vec();
        e.host_sets[0].insert("a_q", sqft::tensor::Tensor::randn(&mut rng, &a_shape, 1.0));
        e.host_sets[0].insert("b_q", sqft::tensor::Tensor::randn(&mut rng, &b_shape, 1.0));
    }
    Fixture { dir, hyper, frozen, entries }
}

fn spec(f: &Fixture) -> EngineSpec {
    EngineSpec {
        artifacts: f.dir.clone(),
        config: "sqft-tiny".to_string(),
        frozen: f.frozen.clone(),
        eval_kind: "eval".to_string(),
        max_new_tokens: 4,
        registry_capacity: 8,
        device_budget: 0,
        degrade_ranks: Vec::new(),
    }
}

#[test]
fn pool_answers_are_byte_identical_to_single_worker_reference() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt);
    let task = Task::SynBoolq;

    // interleaved multi-tenant workload, including merged-path traffic
    let mut grng = Rng::new(55);
    let mut requests: Vec<(Option<String>, String)> = Vec::new();
    for i in 0..18 {
        let id = if i % 4 == 3 {
            None // merged / no-adapter fast path
        } else {
            Some(f.entries[i % f.entries.len()].id.clone())
        };
        requests.push((id, task.gen_sample(&mut grng).prompt));
    }
    let opts = SchedulerOpts { max_batch: f.hyper.batch,
                               aging: Duration::from_millis(20),
                               ..Default::default() };

    // single-worker reference through the Router
    let engine = Engine::new(&rt, "sqft-tiny", &f.frozen, None, "eval", 4).unwrap();
    let mut registry = AdapterRegistry::new(8);
    registry
        .register_all_resident(&rt, &f.hyper, f.entries.clone())
        .unwrap();
    let mut router = Router::new(engine, registry);
    let (tx, rx) = channel::<Request>();
    let mut replies = Vec::new();
    for (id, p) in &requests {
        let (rtx, rrx) = channel();
        tx.send(Request::new(id.clone(), p.clone(), rtx)).unwrap();
        replies.push(rrx);
    }
    drop(tx);
    let ref_stats = router.serve(rx, opts.clone()).unwrap();
    assert_eq!(ref_stats.total.errors, 0);
    let expected: Vec<String> =
        replies.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();

    // the same workload through 1/2/3-worker pools: bytes must not move
    let source = SharedAdapterSource::new(f.hyper.clone(), 8);
    source.register_all(f.entries.clone()).unwrap();
    let spec = spec(&f);
    for workers in [1usize, 2, 3] {
        let (tx, rx) = channel::<Request>();
        let mut replies = Vec::new();
        for (id, p) in &requests {
            let (rtx, rrx) = channel();
            tx.send(Request::new(id.clone(), p.clone(), rtx)).unwrap();
            replies.push(rrx);
        }
        drop(tx);
        let stats = sqft::serve::serve_pool(
            &spec,
            &source,
            rx,
            PoolOpts { workers, sched: opts.clone(), ..Default::default() },
        )
        .unwrap();
        for (i, rrx) in replies.into_iter().enumerate() {
            let ans = rrx.recv().unwrap().unwrap();
            assert_eq!(ans, expected[i],
                "request {i} diverged from the single-worker reference at {workers} workers");
        }
        assert_eq!(stats.serve.total.served, requests.len());
        assert_eq!(stats.serve.total.errors, 0);
        assert_eq!(stats.workers, workers);
        assert_eq!(stats.per_worker.len(), workers);
        assert!(stats.per_worker.iter().all(|w| w.setup_error.is_none()));
        let served: usize = stats.per_worker.iter().map(|w| w.served).sum();
        assert_eq!(served, requests.len());
        assert_eq!(stats.serve.generated_tokens,
            ref_stats.generated_tokens,
            "token counts must match the reference at {workers} workers");
        assert!(stats.serve.total.ttft_ms.is_some() && stats.serve.total.queue_ms.is_some());
        // every tenant that sent traffic is reported
        assert_eq!(stats.serve.per_tenant.len(), ref_stats.per_tenant.len());
    }
}

#[test]
fn pool_serves_every_tenant_and_errors_unknown_ids() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt);
    let task = Task::SynBoolq;
    let source = SharedAdapterSource::new(f.hyper.clone(), 8);
    source.register_all(f.entries.clone()).unwrap();
    let spec = spec(&f);

    // fairness smoke under concurrent admission: a hot tenant floods, two
    // cold tenants trickle, plus one unknown id; nobody may starve
    let mut grng = Rng::new(77);
    let mut requests: Vec<(Option<String>, String)> = Vec::new();
    for i in 0..24 {
        // tenant 0 floods (half the traffic); tenants 1 and 2 trickle
        let idx = match i % 4 {
            0 => 1,
            1 => 2,
            _ => 0,
        };
        requests.push((Some(f.entries[idx].id.clone()), task.gen_sample(&mut grng).prompt));
    }
    requests.push((Some("nope".to_string()), task.gen_sample(&mut grng).prompt));
    let opts = SchedulerOpts { max_batch: f.hyper.batch,
                               aging: Duration::from_millis(5),
                               ..Default::default() };
    let stats = benchmark_pool(
        &spec,
        &source,
        requests.clone(),
        Duration::from_millis(1),
        PoolOpts { workers: 2, sched: opts, ..Default::default() },
    )
    .unwrap();
    assert_eq!(stats.serve.total.served + stats.serve.total.errors, requests.len());
    assert_eq!(stats.serve.total.errors, 1, "exactly the unknown tenant errors");
    let nope = stats.serve.per_tenant.iter().find(|(id, _)| id == "nope").unwrap();
    assert_eq!(nope.1.errors, 1);
    for e in &f.entries {
        let served = stats
            .serve
            .per_tenant
            .iter()
            .find(|(id, _)| id == &e.id)
            .map(|(_, s)| s.served)
            .unwrap_or(0);
        let sent = requests.iter().filter(|(id, _)| id.as_deref() == Some(e.id.as_str())).count();
        assert_eq!(served, sent, "tenant {} starved or over-served", e.id);
    }
    // scheduler accounting spans all shards
    assert_eq!(stats.serve.scheduler.scheduled, requests.len());
    assert!(stats.serve.occupancy > 0.0 && stats.serve.occupancy <= 1.0 + 1e-9);
}

/// Coordinated eviction reaches every replica: evict between two pool
/// runs over the same source; the evicted tenant then errors on all
/// workers while the survivors keep serving.
#[test]
fn coordinated_eviction_applies_across_pool_runs() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt);
    let task = Task::SynBoolq;
    let source = SharedAdapterSource::new(f.hyper.clone(), 8);
    source.register_all(f.entries.clone()).unwrap();
    let spec = spec(&f);
    let victim = f.entries[0].id.clone();
    assert!(source.evict(&victim));
    let mut grng = Rng::new(88);
    let requests: Vec<(Option<String>, String)> = f
        .entries
        .iter()
        .map(|e| (Some(e.id.clone()), task.gen_sample(&mut grng).prompt))
        .collect();
    let stats = benchmark_pool(
        &spec,
        &source,
        requests,
        Duration::ZERO,
        PoolOpts { workers: 2, sched: SchedulerOpts::default(), ..Default::default() },
    )
    .unwrap();
    assert_eq!(stats.serve.total.errors, 1, "evicted tenant must error");
    assert_eq!(stats.serve.total.served, f.entries.len() - 1);
    let v = stats.serve.per_tenant.iter().find(|(id, _)| id == &victim).unwrap();
    assert_eq!(v.1.errors, 1);
    assert_eq!(v.1.served, 0);
}
