//! Continuous-batching integration: short and long requests share a batch.
//!
//! What must hold (ISSUE 3 acceptance):
//!   - short requests complete and their freed slots are re-filled with
//!     waiting same-tenant requests while the long request is still
//!     decoding (scheduler `admitted` > 0, fewer forwards than the
//!     run-to-completion path);
//!   - every per-request answer is byte-identical to the run-to-completion
//!     host-upload reference path;
//!   - slot occupancy is strictly higher than run-to-completion on the
//!     mixed workload.

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::{init_base, ParamSet};
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::Runtime;
use sqft::serve::{AdapterRegistry, Engine, Request, Router, SchedulerOpts};
use sqft::tensor::Rng;
use std::path::Path;
use std::sync::mpsc::channel;
use std::time::Duration;

/// (prompt, per-request max_new, per-request min_new)
type Spec = (String, Option<usize>, usize);

/// Reference path: fixed batches admitted up front, never re-filled, each
/// run until its slowest row retires — the pre-continuous-batching engine
/// behavior, driven through the same slot session so answers are
/// comparable per request.  Returns (answers, forwards, slot_steps).
fn run_to_completion(
    engine: &Engine,
    sets: &[&ParamSet],
    eval_kind: &str,
    reqs: &[Spec],
) -> anyhow::Result<(Vec<String>, usize, usize)> {
    let cap = engine.artifact_batch()?;
    let mut answers = vec![String::new(); reqs.len()];
    let (mut steps, mut slot_steps) = (0usize, 0usize);
    for (ci, chunk) in reqs.chunks(cap).enumerate() {
        let mut s = engine.begin_decode()?;
        for (prompt, max_new, min_new) in chunk {
            engine.admit(&mut s, prompt, *max_new, *min_new)?;
        }
        while s.active_slots() > 0 {
            for (slot, ans) in engine.decode_step(&mut s, None, sets, eval_kind)? {
                answers[ci * cap + slot] = ans;
            }
        }
        steps += s.steps();
        slot_steps += s.slot_steps();
    }
    Ok((answers, steps, slot_steps))
}

#[test]
fn short_requests_refill_slots_while_long_request_decodes() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 300, 0, 30, 51);
    let base = init_base(&hyper, &mut Rng::new(25));
    let prepared = pipeline::prepare(&rt, config, &base, Method::Lora, 0.0,
                                     &ds.train, &tok, 0, &mut Rng::new(26)).unwrap();
    let frozen = prepared.frozen_set().unwrap();
    let entries = pipeline::tenant_adapters(&rt, config, &prepared, 1,
                                            &ds.train, &tok, 2, 900).unwrap();
    let tenant = &entries[0];

    let long_new = 6usize;
    let engine = Engine::new(&rt, config, &frozen, None, "eval", long_new).unwrap();
    let b = engine.artifact_batch().unwrap();
    assert!(b >= 2, "need at least two slots to mix short and long");

    // mixed workload: one long request (min == max forces exactly
    // `long_new` forwards) plus 2b-2 one-token requests, so the second
    // wave can only be served by re-filling slots the first wave frees
    let mut grng = Rng::new(61);
    let mut specs: Vec<Spec> = Vec::new();
    specs.push((task.gen_sample(&mut grng).prompt, Some(long_new), long_new));
    for _ in 0..(2 * b - 2) {
        specs.push((task.gen_sample(&mut grng).prompt, Some(1), 0));
    }

    // reference: run-to-completion over the host-upload path
    let sets: Vec<&ParamSet> = tenant.host_sets.iter().collect();
    let (expected, rtc_steps, rtc_slot_steps) =
        run_to_completion(&engine, &sets, &tenant.eval_kind, &specs).unwrap();
    // chunk 1 pays the long row for every short slot; chunk 2 is shorts only
    assert_eq!(rtc_steps, long_new + 1, "workload lost its mixed shape");
    let rtc_occupancy = rtc_slot_steps as f64 / (rtc_steps * b) as f64;

    // continuous: same requests through the router, device-cached tenant
    let mut registry = AdapterRegistry::new(2);
    registry.register_resident(&rt, &hyper, tenant.clone()).unwrap();
    let mut router = Router::new(engine, registry);
    let (tx, rx) = channel::<Request>();
    let mut replies = Vec::new();
    for (prompt, max_new, min_new) in &specs {
        let (rtx, rrx) = channel();
        let mut req = Request::new(Some(tenant.id.clone()), prompt.clone(), rtx);
        req.max_new_tokens = *max_new;
        req.min_new_tokens = *min_new;
        tx.send(req).unwrap();
        replies.push(rrx);
    }
    drop(tx);
    let opts = SchedulerOpts { max_batch: b, aging: Duration::from_millis(20), ..Default::default() };
    let stats = router.serve(rx, opts).unwrap();

    // per-request answers byte-identical to the host-upload reference
    for (i, rrx) in replies.into_iter().enumerate() {
        let ans = rrx.recv().unwrap().unwrap();
        assert_eq!(ans, expected[i], "request {i} diverged from the reference");
    }
    assert_eq!(stats.total.served, specs.len());
    assert_eq!(stats.total.errors, 0);

    // the second wave rode freed slots while the long request still decoded
    assert_eq!(stats.scheduler.admitted, specs.len() - b,
        "waiting requests must be admitted into the running batch");
    assert!(stats.decode_steps < rtc_steps,
        "continuous batching must need fewer forwards ({} vs {rtc_steps})",
        stats.decode_steps);
    assert!(stats.occupancy > rtc_occupancy,
        "continuous occupancy {:.3} must beat run-to-completion {rtc_occupancy:.3}",
        stats.occupancy);
    // same generated tokens, fewer forwards
    assert_eq!(stats.decode_steps, long_new,
        "the long request alone should bound the session length");
    assert!(stats.total.ttft_ms.is_some() && stats.total.queue_ms.is_some());
}
