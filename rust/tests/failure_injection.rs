//! Failure-injection tests: the coordinator must fail loudly and precisely
//! on corrupted artifacts, mismatched shapes, and invalid states — not
//! produce silently-wrong science.

use sqft::data::{Sample, Tokenizer};
use sqft::model::{checkpoint, ParamSet};
use sqft::runtime::{args::build_args, DeviceStore, HostValue, Manifest, Runtime};
use sqft::tensor::{Rng, Tensor};
use sqft::util::json::Json;
use std::path::Path;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() { Some(dir) } else { None }
}

#[test]
fn missing_manifest_is_a_clear_error() {
    let err = Manifest::load(Path::new("/nonexistent-dir")).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn corrupt_manifest_rejected() {
    let dir = std::env::temp_dir().join("sqft_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json !").unwrap();
    assert!(Manifest::load(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), r#"{"version":1}"#).unwrap();
    assert!(Manifest::load(&dir).is_err()); // missing keys
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wrong_input_shape_rejected_before_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.shape_executable("wanda_64x64").unwrap();
    let mut rng = Rng::new(1);
    let w_bad = Tensor::randn(&mut rng, &[32, 64], 1.0); // wrong rows
    let norms = Tensor::randn(&mut rng, &[64], 1.0);
    let err = exe.run(&rt.client, &[w_bad.into(), norms.into()]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("wants") && msg.contains("got"), "{msg}");
}

#[test]
fn wrong_input_count_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.shape_executable("wanda_64x64").unwrap();
    let mut rng = Rng::new(1);
    let w = Tensor::randn(&mut rng, &[64, 64], 1.0);
    let err = exe.run(&rt.client, &[w.into()]).unwrap_err();
    assert!(format!("{err:#}").contains("expected 2 inputs"));
}

#[test]
fn unknown_artifact_kinds_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    assert!(rt.executable("sqft-tiny", "nonexistent-kind").is_err());
    assert!(rt.executable("not-a-config", "eval").is_err());
    assert!(rt.shape_executable("wanda_1x1").is_err());
}

#[test]
fn build_args_reports_missing_source() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.executable("sqft-tiny", "eval").unwrap();
    let empty = ParamSet::new();
    let dev = DeviceStore::new();
    let err = match build_args(&exe.spec, &[&dev], &[&empty], None, &[]) {
        Err(e) => e,
        Ok(_) => panic!("expected error"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("no source for artifact input"), "{msg}");
}

#[test]
fn build_args_rejects_mis_shaped_host_tensor() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.executable("sqft-tiny", "eval").unwrap();
    let mut bad = ParamSet::new();
    bad.insert("embed", Tensor::zeros(&[2, 2])); // wrong shape
    let dev = DeviceStore::new();
    let err = match build_args(&exe.spec, &[&dev], &[&bad], None, &[]) {
        Err(e) => e,
        Ok(_) => panic!("expected error"),
    };
    assert!(format!("{err:#}").contains("host tensor shape"));
}

#[test]
fn truncated_checkpoint_rejected() {
    let dir = std::env::temp_dir().join("sqft_trunc_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("x.ckpt");
    let mut p = ParamSet::new();
    p.insert("w", Tensor::ones(&[8, 8]));
    checkpoint::save(&p, &path, Json::Null).unwrap();
    // truncate the data section
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 17]).unwrap();
    assert!(checkpoint::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn overlong_sample_rejected_not_truncated() {
    let tok = Tokenizer::new();
    let s = Sample {
        prompt: "Q:".to_string() + &"9+9+".repeat(30),
        answer: "1.".into(),
    };
    // silent truncation would corrupt training data; must be an error
    assert!(sqft::data::encode_sample(&tok, &s, 48).is_err());
}

#[test]
fn corrupt_hlo_text_fails_compile() {
    let Some(dir) = artifacts_dir() else { return };
    let tmp = std::env::temp_dir().join("sqft_bad_hlo");
    std::fs::create_dir_all(&tmp).unwrap();
    // copy the manifest but break one artifact file
    std::fs::copy(dir.join("manifest.json"), tmp.join("manifest.json")).unwrap();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().map(|e| e == "txt").unwrap_or(false) {
            let name = p.file_name().unwrap();
            if name.to_string_lossy() == "wanda_64x64.hlo.txt" {
                std::fs::write(tmp.join(name), "HloModule garbage !!!").unwrap();
            } else {
                std::fs::copy(&p, tmp.join(name)).unwrap();
            }
        }
    }
    let rt = Runtime::new(&tmp).unwrap();
    assert!(rt.shape_executable("wanda_64x64").is_err());
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn device_store_missing_key_is_clear() {
    let d = DeviceStore::new();
    let err = match d.get("nope") {
        Err(e) => e,
        Ok(_) => panic!("expected error"),
    };
    assert!(format!("{err:#}").contains("missing 'nope'"));
    let _ = HostValue::F32(Tensor::zeros(&[1])); // exercise the type
}
