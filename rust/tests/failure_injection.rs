//! Failure-injection tests: the coordinator must fail loudly and precisely
//! on corrupted artifacts, mismatched shapes, and invalid states — not
//! produce silently-wrong science.
//!
//! The serve-path chaos suite at the bottom drives the deterministic
//! failpoint harness (`sqft::faults`) through the worker pool: injected
//! decode failures must stay inside one session, transient failures must
//! be absorbed by the retry budget, worker panics must requeue their
//! claimed batch, and shed/cancel paths must return *typed* errors
//! ([`ServeError`]) with matching counters.

use sqft::data::{Dataset, Sample, Task, Tokenizer};
use sqft::faults::{
    FaultInjector, FaultKind, FaultRule, SITE_CACHE_UPLOAD, SITE_FORWARD, SITE_PREFILL,
    SITE_WORKER_PANIC,
};
use sqft::model::{checkpoint, init_base, ParamSet};
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::{args::build_args, DeviceStore, HostValue, Manifest, Runtime};
use sqft::serve::{
    serve_pool_obs, AdapterEntry, Engine, EngineSpec, PoolOpts, Request, Scheduler,
    SchedulerOpts, ServeError, ServeObs, SharedAdapterSource,
};
use sqft::tensor::{Rng, Tensor};
use sqft::util::json::Json;
use std::path::Path;
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() { Some(dir) } else { None }
}

#[test]
fn missing_manifest_is_a_clear_error() {
    let err = Manifest::load(Path::new("/nonexistent-dir")).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "unhelpful error: {msg}");
}

#[test]
fn corrupt_manifest_rejected() {
    let dir = std::env::temp_dir().join("sqft_bad_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{ not json !").unwrap();
    assert!(Manifest::load(&dir).is_err());
    std::fs::write(dir.join("manifest.json"), r#"{"version":1}"#).unwrap();
    assert!(Manifest::load(&dir).is_err()); // missing keys
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn wrong_input_shape_rejected_before_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.shape_executable("wanda_64x64").unwrap();
    let mut rng = Rng::new(1);
    let w_bad = Tensor::randn(&mut rng, &[32, 64], 1.0); // wrong rows
    let norms = Tensor::randn(&mut rng, &[64], 1.0);
    let err = exe.run(&rt.client, &[w_bad.into(), norms.into()]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("wants") && msg.contains("got"), "{msg}");
}

#[test]
fn wrong_input_count_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.shape_executable("wanda_64x64").unwrap();
    let mut rng = Rng::new(1);
    let w = Tensor::randn(&mut rng, &[64, 64], 1.0);
    let err = exe.run(&rt.client, &[w.into()]).unwrap_err();
    assert!(format!("{err:#}").contains("expected 2 inputs"));
}

#[test]
fn unknown_artifact_kinds_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    assert!(rt.executable("sqft-tiny", "nonexistent-kind").is_err());
    assert!(rt.executable("not-a-config", "eval").is_err());
    assert!(rt.shape_executable("wanda_1x1").is_err());
}

#[test]
fn build_args_reports_missing_source() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.executable("sqft-tiny", "eval").unwrap();
    let empty = ParamSet::new();
    let dev = DeviceStore::new();
    let err = match build_args(&exe.spec, &[&dev], &[&empty], None, &[]) {
        Err(e) => e,
        Ok(_) => panic!("expected error"),
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("no source for artifact input"), "{msg}");
}

#[test]
fn build_args_rejects_mis_shaped_host_tensor() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let exe = rt.executable("sqft-tiny", "eval").unwrap();
    let mut bad = ParamSet::new();
    bad.insert("embed", Tensor::zeros(&[2, 2])); // wrong shape
    let dev = DeviceStore::new();
    let err = match build_args(&exe.spec, &[&dev], &[&bad], None, &[]) {
        Err(e) => e,
        Ok(_) => panic!("expected error"),
    };
    assert!(format!("{err:#}").contains("host tensor shape"));
}

#[test]
fn truncated_checkpoint_rejected() {
    let dir = std::env::temp_dir().join("sqft_trunc_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("x.ckpt");
    let mut p = ParamSet::new();
    p.insert("w", Tensor::ones(&[8, 8]));
    checkpoint::save(&p, &path, Json::Null).unwrap();
    // truncate the data section
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() - 17]).unwrap();
    assert!(checkpoint::load(&path).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn overlong_sample_rejected_not_truncated() {
    let tok = Tokenizer::new();
    let s = Sample {
        prompt: "Q:".to_string() + &"9+9+".repeat(30),
        answer: "1.".into(),
    };
    // silent truncation would corrupt training data; must be an error
    assert!(sqft::data::encode_sample(&tok, &s, 48).is_err());
}

#[test]
fn corrupt_hlo_text_fails_compile() {
    let Some(dir) = artifacts_dir() else { return };
    let tmp = std::env::temp_dir().join("sqft_bad_hlo");
    std::fs::create_dir_all(&tmp).unwrap();
    // copy the manifest but break one artifact file
    std::fs::copy(dir.join("manifest.json"), tmp.join("manifest.json")).unwrap();
    for entry in std::fs::read_dir(&dir).unwrap() {
        let p = entry.unwrap().path();
        if p.extension().map(|e| e == "txt").unwrap_or(false) {
            let name = p.file_name().unwrap();
            if name.to_string_lossy() == "wanda_64x64.hlo.txt" {
                std::fs::write(tmp.join(name), "HloModule garbage !!!").unwrap();
            } else {
                std::fs::copy(&p, tmp.join(name)).unwrap();
            }
        }
    }
    let rt = Runtime::new(&tmp).unwrap();
    assert!(rt.shape_executable("wanda_64x64").is_err());
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn device_store_missing_key_is_clear() {
    let d = DeviceStore::new();
    let err = match d.get("nope") {
        Err(e) => e,
        Ok(_) => panic!("expected error"),
    };
    assert!(format!("{err:#}").contains("missing 'nope'"));
    let _ = HostValue::F32(Tensor::zeros(&[1])); // exercise the type
}

// --------------------------------------------------------------------
// serve-path chaos suite: deterministic failpoints through the pool
// --------------------------------------------------------------------

struct ServeFixture {
    hyper: sqft::runtime::ModelHyper,
    spec: EngineSpec,
    source: SharedAdapterSource,
    entries: Vec<AdapterEntry>,
}

fn serve_fixture(rt: &Runtime, dir: &Path) -> ServeFixture {
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 300, 0, 30, 71);
    let base = init_base(&hyper, &mut Rng::new(33));
    let prepared = pipeline::prepare(rt, config, &base, Method::Lora, 0.0,
                                     &ds.train, &tok, 0, &mut Rng::new(34)).unwrap();
    let frozen = prepared.frozen_set().unwrap();
    let entries = pipeline::tenant_adapters(rt, config, &prepared, 2,
                                            &ds.train, &tok, 2, 800).unwrap();
    let source = SharedAdapterSource::new(hyper.clone(), 8);
    source.register_all(entries.clone()).unwrap();
    let spec = EngineSpec {
        artifacts: dir.to_path_buf(),
        config: config.to_string(),
        frozen,
        eval_kind: "eval".to_string(),
        max_new_tokens: 4,
        registry_capacity: 8,
        device_budget: 0,
        degrade_ranks: Vec::new(),
    };
    ServeFixture { hyper, spec, source, entries }
}

fn chaos_requests(f: &ServeFixture, n: usize) -> Vec<(Option<String>, String)> {
    let task = Task::SynBoolq;
    let mut grng = Rng::new(404);
    (0..n)
        .map(|i| {
            (Some(f.entries[i % f.entries.len()].id.clone()), task.gen_sample(&mut grng).prompt)
        })
        .collect()
}

/// Run `reqs` through the pool under a fault plan; per-request results in
/// request order plus the observability context (for counter asserts).
fn run_pool_chaos(
    f: &ServeFixture,
    reqs: &[(Option<String>, String)],
    workers: usize,
    max_retries: usize,
    faults: FaultInjector,
) -> (Vec<anyhow::Result<String>>, ServeObs) {
    let (tx, rx) = channel::<Request>();
    let mut replies = Vec::new();
    for (id, p) in reqs {
        let (rtx, rrx) = channel();
        tx.send(Request::new(id.clone(), p.clone(), rtx)).unwrap();
        replies.push(rrx);
    }
    drop(tx);
    let popts = PoolOpts {
        workers,
        sched: SchedulerOpts {
            max_batch: f.hyper.batch,
            aging: Duration::from_millis(20),
            max_retries,
            ..Default::default()
        },
        faults,
    };
    let obs = ServeObs::with_trace();
    let kept = obs.clone();
    serve_pool_obs(&f.spec, &f.source, rx, popts, obs).unwrap();
    let results = replies.into_iter().map(|r| r.recv().unwrap()).collect();
    (results, kept)
}

/// One persistent decode failure (retry budget 0) fails only its own
/// session's residents — one tenant, at most one batch — while every
/// other request's answer stays byte-identical to the fault-free run;
/// a single transient failure under the default budget is absorbed
/// entirely by the retry path.
#[test]
fn injected_forward_failure_is_isolated_and_transients_are_retried() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let f = serve_fixture(&rt, &dir);
    let reqs = chaos_requests(&f, 12);
    let tenant_of = |i: usize| f.entries[i % f.entries.len()].id.clone();

    let (baseline, _) = run_pool_chaos(&f, &reqs, 1, 2, FaultInjector::disabled());
    let baseline: Vec<String> =
        baseline.into_iter().map(|r| r.expect("fault-free run must not error")).collect();

    // persistent: the 2nd forward fails, no retries left
    let inj = FaultInjector::seeded(5)
        .with_rule(FaultRule::window(SITE_FORWARD, FaultKind::Error, 1, 1));
    let (results, _obs) = run_pool_chaos(&f, &reqs, 1, 0, inj.clone());
    assert_eq!(inj.fires(SITE_FORWARD), 1);
    let mut failed_tenants: Vec<String> = Vec::new();
    for (i, r) in results.iter().enumerate() {
        match r {
            Ok(ans) => assert_eq!(ans, &baseline[i],
                "unaffected request {i} diverged from the fault-free run"),
            Err(e) => {
                let se = ServeError::of(e).expect("typed error expected");
                assert!(matches!(se, ServeError::EngineFailure { .. }), "got {se}");
                failed_tenants.push(tenant_of(i));
            }
        }
    }
    failed_tenants.dedup();
    let failed = results.iter().filter(|r| r.is_err()).count();
    assert!(failed >= 1, "the persistent failure must fail its residents");
    assert!(failed <= f.hyper.batch, "blast radius exceeded one session");
    assert_eq!(failed_tenants.len(), 1, "failures crossed tenants: {failed_tenants:?}");

    // transient: same site, but the default budget absorbs it — every
    // answer identical, the retry counted
    let inj = FaultInjector::seeded(5)
        .with_rule(FaultRule::nth(SITE_FORWARD, FaultKind::Error, 1));
    let (results, obs) = run_pool_chaos(&f, &reqs, 1, 2, inj.clone());
    assert_eq!(inj.fires(SITE_FORWARD), 1);
    for (i, r) in results.iter().enumerate() {
        let ans = r.as_ref().expect("transient failure must be absorbed by retry");
        assert_eq!(ans, &baseline[i], "request {i} diverged after an in-session retry");
    }
    let snap = obs.registry().snapshot();
    assert!(snap.sum("serve_retries_total") >= 1.0, "the retry must be counted");
    assert_eq!(snap.sum("serve_requests_total") as usize, reqs.len());

    // session failure with budget left: two consecutive failures exhaust
    // the in-session retry (budget 1), but every resident still has
    // re-admission budget — the whole session is rebuilt and every
    // request completes with baseline-identical bytes
    let inj = FaultInjector::seeded(5)
        .with_rule(FaultRule::window(SITE_FORWARD, FaultKind::Error, 1, 2));
    let (results, obs) = run_pool_chaos(&f, &reqs, 1, 1, inj.clone());
    assert_eq!(inj.fires(SITE_FORWARD), 2);
    for (i, r) in results.iter().enumerate() {
        let ans = r.as_ref().expect("re-admission must recover the session's residents");
        assert_eq!(ans, &baseline[i], "request {i} diverged after session rebuild");
    }
    let snap = obs.registry().snapshot();
    assert!(snap.sum("serve_sessions_rebuilt_total") >= 1.0,
        "survivors must be re-admitted into a fresh session");
    assert_eq!(snap.sum("serve_requests_total") as usize, reqs.len());
}

/// An injected worker panic (fired after the batch is claimed, while it
/// is still in the recovery pen) loses nothing: the batch is requeued to
/// surviving sessions, every answer matches the fault-free run, and the
/// crash + rebuild are counted.
#[test]
fn worker_panic_requeues_the_claimed_batch() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let f = serve_fixture(&rt, &dir);
    let reqs = chaos_requests(&f, 12);

    let (baseline, _) = run_pool_chaos(&f, &reqs, 2, 2, FaultInjector::disabled());
    let baseline: Vec<String> =
        baseline.into_iter().map(|r| r.expect("fault-free run must not error")).collect();

    let inj = FaultInjector::seeded(5)
        .with_rule(FaultRule::nth(SITE_WORKER_PANIC, FaultKind::Panic, 0));
    let (results, obs) = run_pool_chaos(&f, &reqs, 2, 2, inj.clone());
    assert_eq!(inj.fires(SITE_WORKER_PANIC), 1);
    for (i, r) in results.iter().enumerate() {
        let ans = r.as_ref().expect("crash recovery must not lose requests");
        assert_eq!(ans, &baseline[i], "request {i} diverged after worker-crash recovery");
    }
    let snap = obs.registry().snapshot();
    assert!(snap.sum("serve_worker_crashes_total") >= 1.0, "crash must be counted");
    assert!(snap.sum("serve_sessions_rebuilt_total") >= 1.0, "requeue must be counted");
}

/// A failed prefill (`engine.prefill`) fails only the requests it was
/// admitting: in-flight rows keep their resident cache pages and finish
/// with fault-free bytes.  With retry budget 0 the admitted requests get
/// a typed `EngineFailure`; with budget left they are requeued,
/// re-admitted, and recover completely.
#[test]
fn injected_prefill_failure_fails_only_the_admitted_requests() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let f = serve_fixture(&rt, &dir);
    {
        let probe = Engine::new(&rt, "sqft-tiny", &f.spec.frozen, None, "eval", 4).unwrap();
        if !probe.kv_cache_active("eval") {
            eprintln!("skipping: artifacts predate the KV-cache split");
            return;
        }
    }
    // 12 requests over an 8-slot artifact: the overflow wave can only be
    // admitted by mid-session refills, so the *second* prefill of the run
    // is a refill rebuild with rows already in flight
    let reqs = chaos_requests(&f, 12);
    let waiting = reqs.len() - f.hyper.batch;

    let (baseline, _) = run_pool_chaos(&f, &reqs, 1, 2, FaultInjector::disabled());
    let baseline: Vec<String> =
        baseline.into_iter().map(|r| r.expect("fault-free run must not error")).collect();

    // budget 0: the faulted refill prefill fails its admitted requests —
    // and nothing else; every in-flight row answers baseline bytes
    let inj = FaultInjector::seeded(29)
        .with_rule(FaultRule::nth(SITE_PREFILL, FaultKind::Error, 1));
    let (results, _obs) = run_pool_chaos(&f, &reqs, 1, 0, inj.clone());
    assert_eq!(inj.fires(SITE_PREFILL), 1);
    let mut failed = 0usize;
    for (i, r) in results.iter().enumerate() {
        match r {
            Ok(ans) => assert_eq!(ans, &baseline[i],
                "in-flight request {i} diverged after a refill-prefill failure"),
            Err(e) => {
                let se = ServeError::of(e).expect("typed error expected");
                assert!(matches!(se, ServeError::EngineFailure { .. }), "got {se}");
                failed += 1;
            }
        }
    }
    assert!(failed >= 1, "the faulted prefill must fail its admitted requests");
    assert!(failed <= waiting,
        "blast radius {failed} exceeded the refill wave of {waiting}: \
the prefill failure leaked into in-flight rows");

    // budget left: the same failure only costs the admitted requests one
    // re-admission attempt — everything recovers with baseline bytes
    let inj = FaultInjector::seeded(29)
        .with_rule(FaultRule::nth(SITE_PREFILL, FaultKind::Error, 1));
    let (results, obs) = run_pool_chaos(&f, &reqs, 1, 2, inj.clone());
    assert_eq!(inj.fires(SITE_PREFILL), 1);
    for (i, r) in results.iter().enumerate() {
        let ans = r.as_ref().expect("re-admission must recover the failed prefill's rows");
        assert_eq!(ans, &baseline[i], "request {i} diverged after prefill recovery");
    }
    let snap = obs.registry().snapshot();
    assert_eq!(snap.sum("serve_requests_total") as usize, reqs.len());
}

/// A transient cache-upload failure (`runtime.cache_upload`, the cached
/// decode's frontier shipment) is absorbed entirely by the in-session
/// retry budget: the cached step is retry-safe (re-running rewrites the
/// same K/V and reproduces the same logits), so every answer stays
/// byte-identical and the retry is counted.
#[test]
fn transient_cache_upload_failure_is_absorbed_by_retry() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let f = serve_fixture(&rt, &dir);
    {
        let probe = Engine::new(&rt, "sqft-tiny", &f.spec.frozen, None, "eval", 4).unwrap();
        if !probe.kv_cache_active("eval") {
            eprintln!("skipping: artifacts predate the KV-cache split");
            return;
        }
    }
    let reqs = chaos_requests(&f, 12);

    let (baseline, _) = run_pool_chaos(&f, &reqs, 1, 2, FaultInjector::disabled());
    let baseline: Vec<String> =
        baseline.into_iter().map(|r| r.expect("fault-free run must not error")).collect();

    let inj = FaultInjector::seeded(31)
        .with_rule(FaultRule::nth(SITE_CACHE_UPLOAD, FaultKind::Error, 0));
    let (results, obs) = run_pool_chaos(&f, &reqs, 1, 2, inj.clone());
    assert_eq!(inj.fires(SITE_CACHE_UPLOAD), 1);
    for (i, r) in results.iter().enumerate() {
        let ans = r.as_ref().expect("a transient cached-decode failure must be retried");
        assert_eq!(ans, &baseline[i], "request {i} diverged after a cached-step retry");
    }
    let snap = obs.registry().snapshot();
    assert!(snap.sum("serve_retries_total") >= 1.0, "the retry must be counted");
    assert_eq!(snap.sum("serve_requests_total") as usize, reqs.len());
}

/// A client that goes away (drops its [`CancelHandle`]) gets a typed
/// `Cancelled` reply instead of burning decode slots, and the drop is
/// counted as `serve_cancelled_total`; every other request is unaffected.
#[test]
fn dropped_client_cancellation_is_typed_and_counted() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::new(&dir).unwrap();
    let f = serve_fixture(&rt, &dir);
    let reqs = chaos_requests(&f, 8);

    let (tx, rx) = channel::<Request>();
    let mut replies = Vec::new();
    for (i, (id, p)) in reqs.iter().enumerate() {
        let (rtx, rrx) = channel();
        let mut req = Request::new(id.clone(), p.clone(), rtx);
        if i == 3 {
            drop(req.cancel_handle()); // the client vanishes immediately
        }
        tx.send(req).unwrap();
        replies.push(rrx);
    }
    drop(tx);
    let obs = ServeObs::with_trace();
    let kept = obs.clone();
    serve_pool_obs(
        &f.spec,
        &f.source,
        rx,
        PoolOpts {
            workers: 1,
            sched: SchedulerOpts { max_batch: f.hyper.batch, ..Default::default() },
            ..Default::default()
        },
        obs,
    )
    .unwrap();
    for (i, rrx) in replies.into_iter().enumerate() {
        let r = rrx.recv().unwrap();
        if i == 3 {
            let e = r.expect_err("cancelled request must not be served");
            assert!(
                matches!(ServeError::of(&e), Some(ServeError::Cancelled)),
                "expected typed Cancelled, got {e:#}"
            );
        } else {
            r.expect("other requests must be unaffected by the cancellation");
        }
    }
    let snap = kept.registry().snapshot();
    assert_eq!(snap.sum("serve_cancelled_total") as usize, 1);
    assert_eq!(snap.sum("serve_requests_total") as usize, reqs.len() - 1);
}

/// Backpressure is a typed refusal, not a hang: pushes beyond
/// `queue_cap` reply `Overloaded` inline, and the rejection is counted
/// as an overload shed.  Pure scheduler policy — no artifacts needed.
#[test]
fn queue_cap_overflow_replies_typed_overloaded() {
    let mut sched = Scheduler::new(SchedulerOpts {
        queue_cap: Some(2),
        ..Default::default()
    });
    let mut replies = Vec::new();
    for i in 0..4 {
        let (rtx, rrx) = channel();
        let accepted =
            sched.push(Request::new(Some("t".into()), format!("p{i}"), rtx));
        assert_eq!(accepted, i < 2, "push {i} vs cap 2");
        replies.push(rrx);
    }
    for (i, rrx) in replies.into_iter().enumerate() {
        if i < 2 {
            assert!(rrx.try_recv().is_err(), "accepted request must still be queued");
        } else {
            let e = rrx.recv().unwrap().expect_err("overflow must be refused");
            match ServeError::of(&e) {
                Some(ServeError::Overloaded { queue_cap }) => assert_eq!(*queue_cap, 2),
                other => panic!("expected Overloaded, got {other:?}"),
            }
        }
    }
    assert_eq!(sched.metrics().shed, 2);
    assert_eq!(sched.metrics().deadline_expired, 0);
}

/// Deadlines shed with their own typed error, distinct from overload:
/// a request whose deadline has already passed is refused at push (DOA)
/// and counted as a deadline shed.
#[test]
fn expired_deadline_replies_typed_deadline_exceeded() {
    let mut sched = Scheduler::new(SchedulerOpts::default());
    let (rtx, rrx) = channel();
    let mut req = Request::new(Some("t".into()), "p".into(), rtx);
    req.deadline = Some(Instant::now()); // already expired
    assert!(!sched.push(req), "DOA request must be refused");
    let e = rrx.recv().unwrap().expect_err("expired request must be shed");
    assert!(
        matches!(ServeError::of(&e), Some(ServeError::DeadlineExceeded { .. })),
        "expected typed DeadlineExceeded, got {e:#}"
    );
    assert_eq!(sched.metrics().deadline_expired, 1);
    assert_eq!(sched.metrics().shed, 1, "deadline sheds count into the shed total");
}
