//! Device-cached decode path: equivalence + upload accounting + buffer
//! lifecycle.
//!
//! Upload asserts use [`UploadScope`] — the *thread-scoped* delta of the
//! upload-byte counter — so they are exact even while sibling tests (or
//! pool workers) upload concurrently.  That is what lets this binary
//! hold several tests: the old process-wide-counter version had to be a
//! single test to keep its deltas unpolluted.
//!
//! What must hold (ISSUE 2 acceptance):
//!   - the cached path answers byte-identically to the host-upload path;
//!   - a steady-state decode step for a registered tenant uploads *only*
//!     the token batch (delta == steps * batch * seq * 4, exactly);
//!   - eviction (explicit, LRU, and same-id replacement) releases the
//!     tenant's device buffers.

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::{init_base, ParamSet};
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::{Runtime, UploadScope};
use sqft::serve::{AdapterEntry, AdapterRegistry, Engine};
use sqft::tensor::Rng;
use std::path::Path;

struct Fixture {
    rt: Runtime,
    hyper: sqft::runtime::ModelHyper,
    frozen: ParamSet,
    entries: Vec<AdapterEntry>,
    prompts: Vec<String>,
}

/// Build the shared scenario; None when artifacts are absent (CI).
fn fixture() -> Option<Fixture> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 300, 0, 30, 41);
    let base = init_base(&hyper, &mut Rng::new(15));
    let prepared = pipeline::prepare(&rt, config, &base, Method::Lora, 0.0,
                                     &ds.train, &tok, 0, &mut Rng::new(16)).unwrap();
    let frozen = prepared.frozen_set().unwrap();
    let mut entries = pipeline::tenant_adapters(&rt, config, &prepared, 2,
                                                &ds.train, &tok, 3, 500).unwrap();
    // inject large per-tenant deltas so the adapters visibly matter
    for (i, e) in entries.iter_mut().enumerate() {
        let mut rng = Rng::new(700 + i as u64);
        let a_shape = e.host_sets[0].get("a_q").unwrap().shape().to_vec();
        let b_shape = e.host_sets[0].get("b_q").unwrap().shape().to_vec();
        e.host_sets[0].insert("a_q", sqft::tensor::Tensor::randn(&mut rng, &a_shape, 1.0));
        e.host_sets[0].insert("b_q", sqft::tensor::Tensor::randn(&mut rng, &b_shape, 1.0));
    }
    let mut grng = Rng::new(43);
    let prompts: Vec<String> =
        (0..5).map(|_| task.gen_sample(&mut grng).prompt).collect();
    Some(Fixture { rt, hyper, frozen, entries, prompts })
}

#[test]
fn cached_decode_is_byte_identical_and_uploads_only_tokens() {
    let Some(f) = fixture() else { return };
    let engine = Engine::new(&f.rt, "sqft-tiny", &f.frozen, None, "eval", 4).unwrap();
    let mut registry = AdapterRegistry::new(2);
    for e in &f.entries {
        registry.register_resident(&f.rt, &f.hyper, e.clone()).unwrap();
    }
    // the cached set carries the full per-forward adapter state
    let dev0 = registry.device_set(&f.entries[0].id).expect("device set");
    assert!(dev0.contains("a_q") && dev0.contains("b_q"));
    assert!(dev0.contains("rankmask_q") && dev0.contains("scale_q"));

    // byte-identical equivalence, per tenant, with NO host fallback sets:
    // every adapter input must resolve on-device
    for e in &f.entries {
        let sets: Vec<&ParamSet> = e.host_sets.iter().collect();
        let host = engine.generate_batch_for(&sets, &e.eval_kind, &f.prompts).unwrap();
        let dev = registry.device_set(&e.id).unwrap();
        let cached = engine
            .generate_batch_cached(Some(dev), &[], &e.eval_kind, &f.prompts)
            .unwrap();
        assert_eq!(host, cached, "cached path diverged for tenant {}", e.id);
    }

    // steady-state decode uploads only the token batch — and only on
    // forwards where a *live* slot changed: retired rows no longer write
    // their stop token back into the buffer, so the upload counter is
    // exact, not merely an upper bound.  These are the *legacy-path*
    // invariants, so pin the full-forward leg (the KV-cached split has
    // its own exact accounting in serve_kv_cache.rs).
    engine.set_full_forward(true);
    let tok_bytes = (f.hyper.batch * f.hyper.seq_len * 4) as u64;
    let dev = registry.device_set(&f.entries[0].id).unwrap();
    let scope = UploadScope::begin();
    let _ = engine
        .generate_batch_cached(Some(dev), &[], &f.entries[0].eval_kind, &f.prompts)
        .unwrap();
    let cached_delta = scope.bytes();
    let steps = engine.last_decode_steps() as u64;
    let uploads = engine.last_decode_uploads() as u64;
    assert!(steps >= 1);
    assert!(uploads <= steps, "more uploads ({uploads}) than forwards ({steps})");
    assert_eq!(cached_delta, uploads * tok_bytes,
        "upload-byte delta disagrees with the engine's upload count");
    // in a run-to-completion batch every forward is preceded by a live
    // append (or the initial admission), so the counts coincide exactly
    assert_eq!(uploads, steps,
        "run-to-completion decode must upload exactly once per forward");

    // ... while the host-upload fallback ships the adapter set every step
    let sets: Vec<&ParamSet> = f.entries[0].host_sets.iter().collect();
    let scope = UploadScope::begin();
    let _ = engine.generate_batch_for(&sets, &f.entries[0].eval_kind, &f.prompts).unwrap();
    let host_delta = scope.bytes();
    let adapter_bytes: u64 =
        f.entries[0].host_sets.iter().map(|s| s.total_bytes() as u64).sum();
    assert_eq!(host_delta, steps * (tok_bytes + adapter_bytes),
        "host fallback upload accounting is off");
    assert!(host_delta > cached_delta);
}

#[test]
fn eviction_and_replacement_free_device_buffers() {
    let Some(f) = fixture() else { return };
    let mut registry = AdapterRegistry::new(2);
    for e in &f.entries {
        registry.register_resident(&f.rt, &f.hyper, e.clone()).unwrap();
    }

    // explicit eviction frees the device buffers
    let id0 = f.entries[0].id.clone();
    assert!(registry.evict(&id0));
    assert!(registry.device_set(&id0).is_none(), "evicted tenant still resident");

    // same-id host-only re-registration must drop the stale device set
    // (serving stale cached weights would be a correctness bug, not a perf
    // one)
    let id1 = f.entries[1].id.clone();
    registry.register(&f.hyper, f.entries[1].clone()).unwrap();
    assert!(registry.device_set(&id1).is_none(), "stale device set survived replace");

    // LRU eviction past capacity frees the victim's buffers too
    let mut extra = f.entries[0].clone();
    extra.id = "extra".to_string();
    registry.register_resident(&f.rt, &f.hyper, extra).unwrap(); // len 2 = cap
    let mut extra2 = f.entries[0].clone();
    extra2.id = "extra2".to_string();
    let evicted = registry.register_resident(&f.rt, &f.hyper, extra2).unwrap();
    let victim = evicted.expect("LRU eviction past capacity");
    assert!(registry.device_set(&victim).is_none(), "LRU victim still resident");
    assert!(registry.device_set("extra2").is_some());
}
