//! Integration probe: load + execute the tiny-config artifacts end to end.
//! Requires `make artifacts` (skips with a message if absent).

use sqft::runtime::{HostValue, Runtime};
use sqft::tensor::{Rng, Tensor};
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

#[test]
fn wanda_artifact_matches_host_math() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let w = Tensor::randn(&mut rng, &[64, 64], 1.0);
    let norms = Tensor::rand_uniform(&mut rng, &[64], 0.1, 2.0);
    let exe = rt.shape_executable("wanda_64x64").unwrap();
    let out = exe.run(&rt.client, &[w.clone().into(), norms.clone().into()]).unwrap();
    assert_eq!(out.len(), 1);
    for i in 0..64 {
        for j in 0..64 {
            let want = w.at2(i, j).abs() * norms.data()[j];
            assert!((out[0].at2(i, j) - want).abs() < 1e-5);
        }
    }
}

/// Random-but-plausible inputs for one artifact spec list.
fn fill_inputs(rng: &mut Rng, vocab: usize, specs: &[sqft::runtime::IoSpec]) -> Vec<HostValue> {
    let mut inputs = Vec::new();
    for spec in specs {
        match spec.dtype {
            sqft::runtime::DType::F32 => {
                let t = if spec.name.starts_with("mask") || spec.name.starts_with("rankmask") {
                    Tensor::ones(&spec.shape)
                } else if spec.name.starts_with("ln") || spec.name == "final_ln" {
                    Tensor::ones(&spec.shape)
                } else if spec.name.starts_with("qscales") {
                    Tensor::rand_uniform(rng, &spec.shape, 0.02, 0.1)
                } else {
                    Tensor::randn(rng, &spec.shape, 0.05)
                };
                inputs.push(HostValue::F32(t));
            }
            sqft::runtime::DType::I32 => {
                let n: usize = spec.shape.iter().product();
                let data: Vec<i32> = (0..n).map(|_| (rng.below(vocab)) as i32).collect();
                inputs.push(HostValue::I32(spec.shape.clone(), data));
            }
            sqft::runtime::DType::U8 => {
                let n: usize = spec.shape.iter().product();
                let data: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
                inputs.push(HostValue::U8(spec.shape.clone(), data));
            }
        }
    }
    inputs
}

#[test]
fn eval_artifact_runs_and_outputs_logits() {
    let Some(rt) = runtime() else { return };
    let m = rt.model("sqft-tiny").unwrap().clone();
    let exe = rt.executable("sqft-tiny", "eval").unwrap();
    let mut rng = Rng::new(2);
    let inputs = fill_inputs(&mut rng, m.vocab, &exe.spec.inputs);
    let out = exe.run(&rt.client, &inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape(), &[m.batch, m.seq_len, m.vocab]);
    assert!(out[0].data().iter().all(|x| x.is_finite()));
}

#[test]
fn eval_int4_artifact_accepts_packed_u8_weights() {
    let Some(rt) = runtime() else { return };
    let m = rt.model("sqft-tiny").unwrap().clone();
    let exe = rt.executable("sqft-tiny", "eval_int4").unwrap();
    // the packed stacks must be u8 in the manifest contract
    assert!(exe.spec.inputs.iter().any(
        |s| s.name.starts_with("packed_") && s.dtype == sqft::runtime::DType::U8));
    let mut rng = Rng::new(3);
    let inputs = fill_inputs(&mut rng, m.vocab, &exe.spec.inputs);
    let out = exe.run(&rt.client, &inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape(), &[m.batch, m.seq_len, m.vocab]);
    assert!(out[0].data().iter().all(|x| x.is_finite()));
}
