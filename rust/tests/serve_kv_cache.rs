//! Device-resident K/V cache (ISSUE 10 acceptance): the prefill +
//! decode_step split must be invisible in the answers and visible only
//! in the traffic.
//!
//! What must hold:
//!   - KV-cached decode answers byte-identically to the legacy
//!     full-forward path (`Engine::set_full_forward`) for all three
//!     serving kinds — uniform f32, gathered mixed-tenant, packed INT4;
//!   - a cached run uploads *exactly* `prefills × (token batch +
//!     seq_lens)` plus `(steps − prefills) × (frontier + positions)`
//!     bytes — the one-token O(1) frontier is the whole steady-state
//!     host traffic;
//!   - slot retire + refill invalidates the row's cache page: the next
//!     forward re-prefills, and refilled requests still answer
//!     byte-identically to the full-forward reference;
//!   - survivors of a rebuilt session (in-session retries exhausted)
//!     re-prefill in the fresh session and complete with fault-free
//!     bytes.
//!
//! Requires `make artifacts` built after the KV split (tests gate on
//! [`Engine::kv_cache_active`] and skip against stale artifact dirs).

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::faults::{FaultInjector, FaultKind, FaultRule, SITE_FORWARD};
use sqft::model::{init_base, ParamSet};
use sqft::nls::SearchSpace;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::{Runtime, UploadScope};
use sqft::serve::{
    serve_pool_obs, AdapterEntry, AdapterRegistry, Engine, EngineSpec, PoolOpts, Request, Router,
    SchedulerOpts, ServeObs, SharedAdapterSource, GATHERED_KIND,
};
use sqft::tensor::Rng;
use sqft::train::TrainOpts;
use std::path::Path;
use std::sync::mpsc::channel;
use std::time::Duration;

struct Fixture {
    rt: Runtime,
    hyper: sqft::runtime::ModelHyper,
    frozen: ParamSet,
    entries: Vec<AdapterEntry>,
    prompts: Vec<String>,
}

/// Shared scenario; None when artifacts are absent (CI without `make
/// artifacts`).
fn fixture(tenants: usize) -> Option<Fixture> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 300, 0, 30, 83);
    let base = init_base(&hyper, &mut Rng::new(85));
    let prepared = pipeline::prepare(&rt, config, &base, Method::Lora, 0.0,
                                     &ds.train, &tok, 0, &mut Rng::new(86)).unwrap();
    let frozen = prepared.frozen_set().unwrap();
    let mut entries = pipeline::tenant_adapters(&rt, config, &prepared, tenants,
                                                &ds.train, &tok, 2, 600).unwrap();
    // inject large per-tenant deltas so a stale or skipped adapter input
    // would visibly change answers
    for (i, e) in entries.iter_mut().enumerate() {
        let mut rng = Rng::new(900 + i as u64);
        let a_shape = e.host_sets[0].get("a_q").unwrap().shape().to_vec();
        let b_shape = e.host_sets[0].get("b_q").unwrap().shape().to_vec();
        e.host_sets[0].insert("a_q", sqft::tensor::Tensor::randn(&mut rng, &a_shape, 1.0));
        e.host_sets[0].insert("b_q", sqft::tensor::Tensor::randn(&mut rng, &b_shape, 1.0));
    }
    let mut grng = Rng::new(87);
    let prompts: Vec<String> =
        (0..5).map(|_| task.gen_sample(&mut grng).prompt).collect();
    Some(Fixture { rt, hyper, frozen, entries, prompts })
}

/// Byte-identical equivalence on the uniform f32 kind, for both adapter
/// residencies (device set and per-forward host upload), plus the exact
/// cached-path upload contract.
#[test]
fn cached_decode_matches_full_forward_and_ships_only_the_frontier() {
    let Some(f) = fixture(2) else { return };
    let engine = Engine::new(&f.rt, "sqft-tiny", &f.frozen, None, "eval", 4).unwrap();
    if !engine.kv_cache_active("eval") {
        eprintln!("skipping: artifacts predate the KV-cache split");
        return;
    }
    let mut registry = AdapterRegistry::new(2);
    for e in &f.entries {
        registry.register_resident(&f.rt, &f.hyper, e.clone()).unwrap();
    }

    for e in &f.entries {
        let dev = registry.device_set(&e.id).unwrap();
        let sets: Vec<&ParamSet> = e.host_sets.iter().collect();

        // reference: the legacy full causal forward every step
        engine.set_full_forward(true);
        let full = engine
            .generate_batch_cached(Some(dev), &[], &e.eval_kind, &f.prompts)
            .unwrap();
        assert_eq!(engine.last_decode_prefills(), 0,
            "full-forward reference must never touch the cached split");

        // KV-cached split, device-resident adapter and host-upload adapter
        engine.set_full_forward(false);
        let cached = engine
            .generate_batch_cached(Some(dev), &[], &e.eval_kind, &f.prompts)
            .unwrap();
        assert_eq!(cached, full, "cached path diverged for tenant {}", e.id);
        assert!(engine.last_decode_prefills() >= 1, "cached run must prefill");
        let host = engine.generate_batch_for(&sets, &e.eval_kind, &f.prompts).unwrap();
        assert_eq!(host, full, "host-upload cached path diverged for tenant {}", e.id);
    }

    // exact traffic: a prefill ships the token batch + seq_lens, every
    // other forward ships only the frontier + positions vectors — token
    // batches never move outside a prefill
    let dev = registry.device_set(&f.entries[0].id).unwrap();
    let scope = UploadScope::begin();
    let _ = engine
        .generate_batch_cached(Some(dev), &[], &f.entries[0].eval_kind, &f.prompts)
        .unwrap();
    let steps = engine.last_decode_steps() as u64;
    let prefills = engine.last_decode_prefills() as u64;
    assert!(prefills >= 1 && prefills <= steps);
    assert_eq!(engine.last_decode_uploads() as u64, prefills,
        "token batches must move exactly at prefills");
    let tok_bytes = (f.hyper.batch * f.hyper.seq_len * 4) as u64;
    let vec_bytes = (f.hyper.batch * 4) as u64;
    assert_eq!(
        scope.bytes(),
        prefills * (tok_bytes + vec_bytes) + (steps - prefills) * 2 * vec_bytes,
        "cached decode moved bytes outside the prefill/frontier contract"
    );
}

/// The gathered mixed-tenant kind rides the same split: a 4-tenant
/// interleaved workload through the router answers byte-identically
/// whether the mixed sessions run `prefill_gathered`/`decode_gathered`
/// or the legacy `eval_gathered` full forward.
#[test]
fn gathered_cached_decode_matches_full_forward_reference() {
    let Some(f) = fixture(4) else { return };
    let probe = Engine::new(&f.rt, "sqft-tiny", &f.frozen, None, "eval", 4).unwrap();
    if !probe.supports_gathered() || !probe.kv_cache_active(GATHERED_KIND) {
        eprintln!("skipping: artifacts lack the gathered KV-cache kinds");
        return;
    }
    let b = probe.artifact_batch().unwrap();
    drop(probe);

    // interleaved mixed-length rounds, so refills cross tenants mid-session
    let task = Task::SynBoolq;
    let mut grng = Rng::new(97);
    let lens: [(Option<usize>, usize); 3] = [(Some(1), 0), (Some(4), 4), (Some(2), 1)];
    let mut specs: Vec<(usize, String, Option<usize>, usize)> = Vec::new();
    for (max_new, min_new) in lens {
        for t in 0..4 {
            specs.push((t, task.gen_sample(&mut grng).prompt, max_new, min_new));
        }
    }

    let serve = |full_forward: bool| {
        let engine = Engine::new(&f.rt, "sqft-tiny", &f.frozen, None, "eval", 4).unwrap();
        engine.set_full_forward(full_forward);
        let mut registry = AdapterRegistry::new(4);
        for e in &f.entries {
            registry.register_resident(&f.rt, &f.hyper, e.clone()).unwrap();
        }
        let mut router = Router::new(engine, registry);
        let (tx, rx) = channel::<Request>();
        let mut replies = Vec::new();
        for (t, prompt, max_new, min_new) in &specs {
            let (rtx, rrx) = channel();
            let mut req = Request::new(Some(f.entries[*t].id.clone()), prompt.clone(), rtx);
            req.max_new_tokens = *max_new;
            req.min_new_tokens = *min_new;
            tx.send(req).unwrap();
            replies.push(rrx);
        }
        drop(tx);
        let opts = SchedulerOpts {
            max_batch: b,
            aging: Duration::from_millis(20),
            ..Default::default()
        };
        let stats = router.serve(rx, opts).unwrap();
        let answers: Vec<String> =
            replies.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();
        (answers, stats)
    };

    let (expected, ref_stats) = serve(true);
    let (answers, stats) = serve(false);
    assert!(ref_stats.scheduler.mixed_batches >= 1 && stats.scheduler.mixed_batches >= 1,
        "both legs must actually ride the gathered mixed-tenant path");
    for (i, ans) in answers.iter().enumerate() {
        assert_eq!(ans, &expected[i],
            "request {i} (tenant {}) diverged from the full-forward reference", specs[i].0);
    }
    assert_eq!(stats.total.served, specs.len());
    assert_eq!(stats.total.errors, 0);
}

/// The packed-INT4 kind rides the same split: `prefill_int4` /
/// `decode_int4` answers byte-identically to the legacy `eval_int4`
/// full forward on the same packed engine.
#[test]
fn int4_cached_decode_matches_full_forward() {
    let Some(f) = fixture(1) else { return };
    let config = "sqft-tiny";
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 300, 0, 30, 13);
    let prepared = pipeline::prepare(
        &f.rt, config, &init_base(&f.hyper, &mut Rng::new(14)), Method::QaSparsePeft, 0.5,
        &ds.train, &tok, 2, &mut Rng::new(15)).unwrap();
    let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
    let space = SearchSpace::new(&prepared.hyper, choices, alpha).unwrap();
    let (trainer, _) = pipeline::finetune(
        &f.rt, config, &prepared, space, &ds.train, &tok,
        &TrainOpts { steps: 4, lr: 1e-3, log_every: 4, seed: 17, fixed_rank: false })
        .unwrap();
    let cfg = trainer.space.heuristic_config();
    let merged = pipeline::merged_state(&prepared, &trainer, &cfg).unwrap();
    let int4 = pipeline::int4_model(&prepared, &merged).unwrap();

    let engine = Engine::new_int4(&f.rt, config, &int4, 4).unwrap();
    if !engine.kv_cache_active("eval_int4") {
        eprintln!("skipping: artifacts lack the INT4 KV-cache kinds");
        return;
    }
    engine.set_full_forward(true);
    let full = engine.generate_batch(&f.prompts).unwrap();
    assert_eq!(engine.last_decode_prefills(), 0);
    engine.set_full_forward(false);
    let cached = engine.generate_batch(&f.prompts).unwrap();
    assert!(engine.last_decode_prefills() >= 1, "INT4 cached run must prefill");
    assert_eq!(cached, full, "INT4 cached decode diverged from the full forward");
}

/// Continuous-batching refill invalidates the freed slot's cache page:
/// every refill admission forces a re-prefill, and the refilled rows
/// still answer byte-identically to the full-forward reference.
#[test]
fn slot_refill_invalidates_the_cache_page_and_reprefills() {
    let Some(f) = fixture(1) else { return };
    let long_new = 6usize;
    let engine = Engine::new(&f.rt, "sqft-tiny", &f.frozen, None, "eval", long_new).unwrap();
    if !engine.kv_cache_active("eval") {
        eprintln!("skipping: artifacts predate the KV-cache split");
        return;
    }
    let b = engine.artifact_batch().unwrap();
    assert!(b >= 2, "need at least two slots to mix short and long");

    // one long row pins the session open while 2b-2 one-token requests
    // retire and refill around it — every refill dirties a cache page
    let task = Task::SynBoolq;
    let mut grng = Rng::new(53);
    let mut specs: Vec<(String, Option<usize>, usize)> = Vec::new();
    specs.push((task.gen_sample(&mut grng).prompt, Some(long_new), long_new));
    for _ in 0..(2 * b - 2) {
        specs.push((task.gen_sample(&mut grng).prompt, Some(1), 0));
    }
    let dev_entry = &f.entries[0];
    let sets: Vec<&ParamSet> = dev_entry.host_sets.iter().collect();

    // drive one continuous session: admit until full, refill freed slots
    // from the waiting list after every step; (answers, steps, prefills)
    let drive = |_label: &str| {
        let mut s = engine.begin_decode().unwrap();
        let mut answers = vec![String::new(); specs.len()];
        let mut slot_req = vec![usize::MAX; b];
        let mut next = 0usize;
        while next < specs.len() && s.active_slots() < b {
            let (prompt, max_new, min_new) = &specs[next];
            let slot = engine.admit(&mut s, prompt, *max_new, *min_new).unwrap();
            slot_req[slot] = next;
            next += 1;
        }
        while s.active_slots() > 0 {
            for (slot, ans) in engine
                .decode_step(&mut s, None, &sets, &dev_entry.eval_kind)
                .unwrap()
            {
                answers[slot_req[slot]] = ans;
                if next < specs.len() {
                    let (prompt, max_new, min_new) = &specs[next];
                    let slot2 = engine.admit(&mut s, prompt, *max_new, *min_new).unwrap();
                    slot_req[slot2] = next;
                    next += 1;
                }
            }
        }
        assert_eq!(next, specs.len(), "every request must be admitted");
        (answers, s.steps(), s.prefills())
    };

    engine.set_full_forward(true);
    let (expected, ref_steps, ref_prefills) = drive("full");
    assert_eq!(ref_prefills, 0);
    engine.set_full_forward(false);
    let (answers, steps, prefills) = drive("cached");
    assert_eq!(answers, expected, "refilled session diverged from the reference");
    assert_eq!(steps, ref_steps, "the split must not change session length");
    // the initial admission plus every refill wave re-prefills; the long
    // row's later forwards ride the cache
    assert!(prefills >= 2, "refill admissions must invalidate and re-prefill");
    assert!(prefills < steps, "steady-state forwards must ride the resident cache");
}

/// Survivors of a rebuilt session re-prefill: exhaust the in-session
/// retry budget with pinned forward faults, forcing the pool to tear the
/// session down and re-admit its residents — the fresh session must
/// rebuild every cache page and finish with fault-free bytes.
#[test]
fn rebuilt_session_survivors_reprefill_and_match_baseline() {
    let Some(f) = fixture(2) else { return };
    {
        let probe = Engine::new(&f.rt, "sqft-tiny", &f.frozen, None, "eval", 4).unwrap();
        if !probe.kv_cache_active("eval") {
            eprintln!("skipping: artifacts predate the KV-cache split");
            return;
        }
    }
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let source = SharedAdapterSource::new(f.hyper.clone(), 8);
    source.register_all(f.entries.clone()).unwrap();
    let spec = EngineSpec {
        artifacts: dir,
        config: "sqft-tiny".to_string(),
        frozen: f.frozen.clone(),
        eval_kind: "eval".to_string(),
        max_new_tokens: 4,
        registry_capacity: 8,
        device_budget: 0,
        degrade_ranks: Vec::new(),
    };
    let task = Task::SynBoolq;
    let mut grng = Rng::new(59);
    let reqs: Vec<(Option<String>, String)> = (0..12)
        .map(|i| {
            (Some(f.entries[i % f.entries.len()].id.clone()),
             task.gen_sample(&mut grng).prompt)
        })
        .collect();

    let run = |faults: FaultInjector, max_retries: usize| {
        let (tx, rx) = channel::<Request>();
        let mut replies = Vec::new();
        for (id, p) in &reqs {
            let (rtx, rrx) = channel();
            tx.send(Request::new(id.clone(), p.clone(), rtx)).unwrap();
            replies.push(rrx);
        }
        drop(tx);
        let obs = ServeObs::new();
        let kept = obs.clone();
        serve_pool_obs(
            &spec,
            &source,
            rx,
            PoolOpts {
                workers: 1,
                sched: SchedulerOpts {
                    max_batch: f.hyper.batch,
                    aging: Duration::from_millis(20),
                    max_retries,
                    ..Default::default()
                },
                faults,
            },
            obs,
        )
        .unwrap();
        let answers: Vec<anyhow::Result<String>> =
            replies.into_iter().map(|r| r.recv().unwrap()).collect();
        (answers, kept)
    };

    let (baseline, _) = run(FaultInjector::disabled(), 1);
    let baseline: Vec<String> =
        baseline.into_iter().map(|r| r.expect("fault-free run must not error")).collect();

    // two consecutive forward failures exhaust retry budget 1 → the
    // session is torn down and every resident re-admitted
    let inj = FaultInjector::seeded(23)
        .with_rule(FaultRule::window(SITE_FORWARD, FaultKind::Error, 1, 2));
    let (results, obs) = run(inj.clone(), 1);
    assert_eq!(inj.fires(SITE_FORWARD), 2);
    for (i, r) in results.iter().enumerate() {
        let ans = r.as_ref().expect("re-admission must recover every resident");
        assert_eq!(ans, &baseline[i], "request {i} diverged after session rebuild");
    }
    let snap = obs.registry().snapshot();
    assert!(snap.sum("serve_sessions_rebuilt_total") >= 1.0,
        "the retry-exhausted session must be rebuilt");
    assert!(snap.sum("serve_prefills_total") >= 2.0,
        "rebuilt-session survivors must re-prefill their cache pages");
}
