//! End-to-end integration: the full SQFT pipeline on sqft-tiny.
//!
//! Exercises every layer: pretraining through the plain-jnp artifact,
//! Wanda calibration + masking through the calib/wanda artifacts, GPTQ on
//! the host, adapter fine-tuning through the Pallas-kernel train artifacts,
//! and the paper's central merge-equivalence claims.

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::{init_base, linear_keys};
use sqft::nls::SearchSpace;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::Runtime;
use sqft::tensor::Rng;
use sqft::train::{Pretrainer, TrainOpts};
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

#[test]
fn full_sqft_pipeline_on_tiny() {
    let Some(rt) = runtime() else { return };
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let ds = Dataset::generate(Task::SynBoolq, 800, 0, 120, 42);

    // --- 1. pretrain a base model on the task --------------------------
    let mut rng = Rng::new(7);
    let base0 = init_base(&hyper, &mut rng);
    let mut pre = Pretrainer::new(&rt, config, base0);
    let opts = TrainOpts { steps: 120, lr: 2e-3, log_every: 30, seed: 7, fixed_rank: false };
    let curve = pre.train(&ds.train, &tok, &opts).unwrap();
    assert!(curve.last().unwrap() < curve.first().unwrap(),
        "pretraining loss must fall: {:?}", curve.points);
    let pretrained = pre.base.clone();

    // --- 2. prepare: wanda 50% + gptq ----------------------------------
    let mut rng = Rng::new(9);
    let prepared = pipeline::prepare(
        &rt, config, &pretrained, Method::QaSparsePeft, 0.5,
        &ds.train, &tok, 2, &mut rng).unwrap();
    let s = prepared.measured_sparsity();
    assert!((s - 0.5).abs() < 0.02, "sparsity {s} != 0.5");
    assert!(prepared.qa.is_some() && prepared.codes.is_some());

    // dense baseline accuracy vs sparse+quant accuracy: compression hurts
    let acc_sparse = pipeline::evaluate_base(&rt, config, &prepared, &ds.test, &tok)
        .unwrap();
    // (not asserted > because tiny models are noisy; just ensure it runs)
    assert!(acc_sparse.total == 120);

    // --- 3. fine-tune with QA-SparsePEFT -------------------------------
    let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
    let space = SearchSpace::new(&prepared.hyper, choices, alpha).unwrap();
    let topts = TrainOpts { steps: 60, lr: 1e-3, log_every: 20, seed: 11, fixed_rank: false };
    let (trainer, tcurve) =
        pipeline::finetune(&rt, config, &prepared, space, &ds.train, &tok, &topts)
            .unwrap();
    assert!(tcurve.last().unwrap() < tcurve.first().unwrap(),
        "fine-tuning loss must fall: {:?}", tcurve.points);

    // --- 4. merge + the paper's equivalence claims ---------------------
    let cfg = trainer.space.heuristic_config();
    let unmerged = pipeline::evaluate_unmerged(
        &rt, config, &prepared, &trainer, &cfg, &ds.test, &tok).unwrap();
    let merged = pipeline::merged_state(&prepared, &trainer, &cfg).unwrap();
    // sparsity is preserved exactly (Eq. 2 / Eq. 3 with shared z,s)
    assert!(merged.sparsity_after >= merged.sparsity_before - 1e-9,
        "merge lost sparsity: {} -> {}", merged.sparsity_before, merged.sparsity_after);
    let macc = pipeline::evaluate_merged(
        &rt, config, &prepared, &merged, &ds.test, &tok).unwrap();
    // merged accuracy == unmerged accuracy (same function by construction)
    assert!((macc.correct as i64 - unmerged.correct as i64).abs() <= 1,
        "QA merge changed accuracy: {} vs {}", macc.accuracy(), unmerged.accuracy());

    // --- 5. non-mergeable methods refuse to merge -----------------------
    let prepared_lora = pipeline::prepare(
        &rt, config, &pretrained, Method::Lora, 0.5, &ds.train, &tok, 2,
        &mut Rng::new(13)).unwrap();
    let space2 = SearchSpace::default_for(&prepared_lora.hyper, alpha);
    let (trainer2, _) = pipeline::finetune(
        &rt, config, &prepared_lora, space2, &ds.train, &tok,
        &TrainOpts { steps: 2, lr: 1e-3, log_every: 1, seed: 1, fixed_rank: false }).unwrap();
    let cfg2 = trainer2.space.max_config();
    assert!(pipeline::merged_state(&prepared_lora, &trainer2, &cfg2).is_err());
}

#[test]
fn sparsepeft_merge_is_exact() {
    // SparsePEFT (no quant): merged forward must match unmerged bit-for-bit
    // at the logits level (modulo f32 reassociation) — paper Eq. 2.
    let Some(rt) = runtime() else { return };
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let ds = Dataset::generate(Task::SynArcE, 300, 0, 80, 5);
    let mut rng = Rng::new(3);
    let base0 = init_base(&hyper, &mut rng);
    let mut pre = Pretrainer::new(&rt, config, base0);
    pre.train(&ds.train, &tok,
              &TrainOpts { steps: 30, lr: 2e-3, log_every: 10, seed: 3, fixed_rank: false }).unwrap();

    let prepared = pipeline::prepare(
        &rt, config, &pre.base, Method::SparsePeft, 0.5, &ds.train, &tok, 2,
        &mut Rng::new(4)).unwrap();
    let (choices, alpha) = pipeline::default_space_for(&prepared.hyper);
    let space = SearchSpace::new(&prepared.hyper, choices, alpha).unwrap();
    let (trainer, _) = pipeline::finetune(
        &rt, config, &prepared, space, &ds.train, &tok,
        &TrainOpts { steps: 25, lr: 1e-3, log_every: 10, seed: 5, fixed_rank: false }).unwrap();

    let cfg = trainer.space.heuristic_config();
    let unmerged = pipeline::evaluate_unmerged(
        &rt, config, &prepared, &trainer, &cfg, &ds.test, &tok).unwrap();
    let merged = pipeline::merged_state(&prepared, &trainer, &cfg).unwrap();
    assert!(merged.sparsity_after >= merged.sparsity_before - 1e-9);
    let macc = pipeline::evaluate_merged(
        &rt, config, &prepared, &merged, &ds.test, &tok).unwrap();
    assert!((macc.correct as i64 - unmerged.correct as i64).abs() <= 1);
    // per-weight sparsity pattern is identical
    for wkey in linear_keys() {
        let before = prepared.base.get(wkey).unwrap();
        let after = merged.base.get(wkey).unwrap();
        for (b, a) in before.data().iter().zip(after.data()) {
            if *b == 0.0 {
                assert_eq!(*a, 0.0, "{wkey}: zero resurrected by merge");
            }
        }
    }
}
