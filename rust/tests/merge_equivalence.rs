//! Logit-level merge-equivalence tests (the paper's Figures 1/3 claims,
//! checked as functional identities rather than accuracy coincidences).
//!
//! For random weights/adapters/masks, the *unmerged* eval (base + adapter
//! path through the fused L1 kernels) and the *merged* eval (folded weights,
//! no adapter) must produce logits equal up to f32 reassociation noise.

use sqft::model::{init_adapters, init_base, ParamSet};
use sqft::nls::SearchSpace;
use sqft::peft::{adapter_delta, fake_quant_host};
use sqft::pipeline::dense_adapter_masks;
use sqft::runtime::{args::build_args, DeviceStore, ModelHyper, Runtime};
use sqft::tensor::{Rng, Tensor};
use sqft::train::upload;
use std::path::Path;

fn runtime() -> Option<Runtime> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("runtime"))
}

fn random_masks(hyper: &ModelHyper, rng: &mut Rng, sparsity: f32) -> ParamSet {
    let mut p = ParamSet::new();
    for m in &hyper.mods {
        let (out, inp) = hyper.mod_dims(m);
        let data: Vec<f32> = (0..hyper.n_layers * out * inp)
            .map(|_| (rng.next_f32() >= sparsity) as i32 as f32)
            .collect();
        p.insert(&format!("mask_{m}"), Tensor::new(&[hyper.n_layers, out, inp], data).unwrap());
    }
    p
}

fn random_tokens(hyper: &ModelHyper, rng: &mut Rng) -> sqft::data::Batch {
    let n = hyper.batch * hyper.seq_len;
    sqft::data::Batch {
        tokens: (0..n).map(|_| rng.below(hyper.vocab) as i32).collect(),
        targets: vec![0; n],
        loss_mask: vec![0.0; n],
        adapter_idx: Vec::new(),
        batch: hyper.batch,
        seq: hyper.seq_len,
        real: hyper.batch,
    }
}

fn eval_logits(rt: &Runtime, config: &str, kind: &str, frozen: &ParamSet,
               host: &[&ParamSet], batch: &sqft::data::Batch) -> Tensor {
    let exe = rt.executable(config, kind).unwrap();
    let mut dev = DeviceStore::new();
    upload(rt, &mut dev, frozen).unwrap();
    let args = build_args(&exe.spec, &[&dev], host, Some(batch), &[]).unwrap();
    exe.run_mixed(&rt.client, &args).unwrap().remove(0)
}

/// Fold adapters into base on the host (Eq. 2 / Eq. 3).
fn fold(hyper: &ModelHyper, base: &ParamSet, adapters: &ParamSet,
        masks: &ParamSet, rank: &ParamSet,
        qa: Option<(&ParamSet, f32)>) -> ParamSet {
    let mut merged = base.clone();
    for m in &hyper.mods {
        let wkey = ModelHyper::weight_key(m);
        let mut w = merged.get(wkey).unwrap().clone();
        for l in 0..hyper.n_layers {
            let delta = adapter_delta(
                &adapters.get(&format!("a_{m}")).unwrap().index0(l),
                &adapters.get(&format!("b_{m}")).unwrap().index0(l),
                Some(&masks.get(&format!("mask_{m}")).unwrap().index0(l)),
                &rank.get(&format!("rankmask_{m}")).unwrap().index0(l),
                rank.get(&format!("scale_{m}")).unwrap().data()[l]).unwrap();
            let mut folded = w.index0(l).add(&delta).unwrap();
            if let Some((qa, qmax)) = qa {
                let (_, dq) = fake_quant_host(
                    &folded,
                    &qa.get(&format!("qscales_{m}")).unwrap().index0(l),
                    &qa.get(&format!("qzeros_{m}")).unwrap().index0(l),
                    qmax).unwrap();
                folded = dq;
            }
            w.set_index0(l, &folded);
        }
        merged.insert(wkey, w);
    }
    merged
}

#[test]
fn sparsepeft_logits_match_after_merge() {
    let Some(rt) = runtime() else { return };
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let mut rng = Rng::new(21);
    let base = init_base(&hyper, &mut rng);
    let mut adapters = init_adapters(&hyper, &mut rng, 4.0);
    // non-trivial B so the adapter actually does something
    for m in &hyper.mods {
        let b = adapters.get(&format!("b_{m}")).unwrap();
        adapters.insert(&format!("b_{m}"), Tensor::randn(&mut rng, b.shape(), 0.05));
    }
    let masks = random_masks(&hyper, &mut rng, 0.5);
    let space = SearchSpace::default_for(&hyper, 4.0);
    let cfg = space.heuristic_config();
    let rank = space.realize(&cfg).unwrap();
    let batch = random_tokens(&hyper, &mut rng);

    // unmerged: base + masked adapter path
    let mut frozen = base.clone();
    for (n, t) in masks.iter() {
        frozen.insert(n, t.clone());
    }
    let unmerged = eval_logits(&rt, config, "eval", &frozen, &[&adapters, &rank], &batch);

    // merged: folded weights, no-op adapter
    let merged_base = fold(&hyper, &base, &adapters, &masks, &rank, None);
    let mut frozen_m = merged_base.clone();
    for (n, t) in dense_adapter_masks(&hyper).iter() {
        frozen_m.insert(n, t.clone());
    }
    let mut noop = init_adapters(&hyper, &mut Rng::new(1), 1.0);
    for m in &hyper.mods {
        let b = noop.get(&format!("b_{m}")).unwrap();
        noop.insert(&format!("b_{m}"), Tensor::zeros(b.shape()));
    }
    let merged = eval_logits(&rt, config, "eval", &frozen_m, &[&noop, &rank], &batch);

    let mut max_abs = 0.0f32;
    let mut scale = 0.0f32;
    for (a, b) in unmerged.data().iter().zip(merged.data()) {
        max_abs = max_abs.max((a - b).abs());
        scale = scale.max(a.abs());
    }
    assert!(max_abs <= 1e-3 * scale.max(1.0),
        "merged logits deviate: max_abs={max_abs} scale={scale}");
}

#[test]
fn qa_sparsepeft_logits_match_after_merge() {
    let Some(rt) = runtime() else { return };
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let mut rng = Rng::new(31);
    let base = init_base(&hyper, &mut rng);
    let mut adapters = init_adapters(&hyper, &mut rng, 4.0);
    for m in &hyper.mods {
        let b = adapters.get(&format!("b_{m}")).unwrap();
        adapters.insert(&format!("b_{m}"), Tensor::randn(&mut rng, b.shape(), 0.05));
    }
    let masks = random_masks(&hyper, &mut rng, 0.5);
    // shared quant params
    let mut qa = ParamSet::new();
    for m in &hyper.mods {
        let (out, _) = hyper.mod_dims(m);
        let g = hyper.mod_groups(m);
        qa.insert(&format!("qscales_{m}"),
                  Tensor::rand_uniform(&mut rng, &[hyper.n_layers, out, g], 0.01, 0.08));
        qa.insert(&format!("qzeros_{m}"),
                  Tensor::new(&[hyper.n_layers, out, g],
                      (0..hyper.n_layers * out * g).map(|_| rng.below(16) as f32)
                          .collect()).unwrap());
    }
    qa.insert("qmax", Tensor::scalar(15.0));
    let space = SearchSpace::default_for(&hyper, 4.0);
    let cfg = space.heuristic_config();
    let rank = space.realize(&cfg).unwrap();
    let batch = random_tokens(&hyper, &mut rng);

    // unmerged through eval_qa (on-the-fly fake-quantized merge)
    let mut frozen = base.clone();
    for (n, t) in masks.iter() {
        frozen.insert(n, t.clone());
    }
    for (n, t) in qa.iter() {
        frozen.insert(n, t.clone());
    }
    let unmerged =
        eval_logits(&rt, config, "eval_qa", &frozen, &[&adapters, &rank], &batch);

    // merged via Eq. 3 on the host, then plain eval
    let merged_base = fold(&hyper, &base, &adapters, &masks, &rank, Some((&qa, 15.0)));
    let mut frozen_m = merged_base.clone();
    for (n, t) in dense_adapter_masks(&hyper).iter() {
        frozen_m.insert(n, t.clone());
    }
    let mut noop = init_adapters(&hyper, &mut Rng::new(1), 1.0);
    for m in &hyper.mods {
        let b = noop.get(&format!("b_{m}")).unwrap();
        noop.insert(&format!("b_{m}"), Tensor::zeros(b.shape()));
    }
    let merged = eval_logits(&rt, config, "eval", &frozen_m, &[&noop, &rank], &batch);

    let mut max_abs = 0.0f32;
    let mut scale = 0.0f32;
    for (a, b) in unmerged.data().iter().zip(merged.data()) {
        max_abs = max_abs.max((a - b).abs());
        scale = scale.max(a.abs());
    }
    // rounding boundaries can flip a code when host/XLA f32 orders differ;
    // the tolerance reflects one quant step through the network
    assert!(max_abs <= 5e-3 * scale.max(1.0),
        "QA merged logits deviate: max_abs={max_abs} scale={scale}");
}
