//! Mixed-tenant gathered-decode integration (ISSUE 8 acceptance).
//!
//! What must hold:
//!   - a long-tail workload of 8 tenants x 1 request each decodes in ONE
//!     mixed session over the gathered banks: a single dispatched batch,
//!     `decode_steps` == the per-request length, and slot occupancy ~= 8
//!     of 8 — not 8 sequential single-row sessions;
//!   - every answer is byte-identical to the same-tenant baseline (each
//!     tenant decoded alone through the uniform host-upload path);
//!   - an interleaved 4-tenant workload with mixed lengths also matches
//!     the per-tenant reference answer-for-answer, with per-tenant FIFO
//!     order preserved and freed slots re-filled across tenants;
//!   - the mixed-batch counters fire (`sched_mixed_batches_total`).

use sqft::data::{Dataset, Task, Tokenizer};
use sqft::model::{init_base, ParamSet};
use sqft::peft::Method;
use sqft::pipeline;
use sqft::runtime::Runtime;
use sqft::serve::{AdapterEntry, AdapterRegistry, Engine, Request, Router, SchedulerOpts};
use sqft::tensor::Rng;
use std::path::Path;
use std::sync::mpsc::channel;
use std::time::Duration;

/// (tenant index, prompt, per-request max_new, per-request min_new)
type Spec = (usize, String, Option<usize>, usize);

struct Fixture {
    hyper: sqft::runtime::ModelHyper,
    frozen: ParamSet,
    entries: Vec<AdapterEntry>,
}

fn fixture(rt: &Runtime, tenants: usize) -> Fixture {
    let config = "sqft-tiny";
    let hyper = rt.model(config).unwrap().clone();
    let tok = Tokenizer::new();
    let task = Task::SynBoolq;
    let ds = Dataset::generate(task, 300, 0, 30, 221);
    let base = init_base(&hyper, &mut Rng::new(223));
    let prepared = pipeline::prepare(rt, config, &base, Method::Lora, 0.0,
                                     &ds.train, &tok, 0, &mut Rng::new(224)).unwrap();
    let frozen = prepared.frozen_set().unwrap();
    let entries = pipeline::tenant_adapters(rt, config, &prepared, tenants,
                                            &ds.train, &tok, 2, 700).unwrap();
    Fixture { hyper, frozen, entries }
}

/// Same-tenant baseline: each tenant's requests decoded alone through the
/// uniform host-upload path (adapter host sets re-uploaded per forward —
/// the reference the gathered kernel must reproduce byte-for-byte).
fn uniform_reference(engine: &Engine, entries: &[AdapterEntry], specs: &[Spec]) -> Vec<String> {
    let cap = engine.artifact_batch().unwrap();
    let mut answers = vec![String::new(); specs.len()];
    for (t, entry) in entries.iter().enumerate() {
        let mine: Vec<(usize, &Spec)> =
            specs.iter().enumerate().filter(|(_, s)| s.0 == t).collect();
        let sets: Vec<&ParamSet> = entry.host_sets.iter().collect();
        for chunk in mine.chunks(cap) {
            let mut s = engine.begin_decode().unwrap();
            let mut slot_to_req = Vec::new();
            for (i, (_, prompt, max_new, min_new)) in chunk {
                engine.admit(&mut s, prompt, *max_new, *min_new).unwrap();
                slot_to_req.push(*i);
            }
            while s.active_slots() > 0 {
                for (slot, ans) in
                    engine.decode_step(&mut s, None, &sets, &entry.eval_kind).unwrap()
                {
                    answers[slot_to_req[slot]] = ans;
                }
            }
        }
    }
    answers
}

/// Queue every spec up front (tagged with its tenant), serve through the
/// router, and return (per-request answers, stats).
fn serve_specs(
    engine: Engine,
    registry: AdapterRegistry,
    entries: &[AdapterEntry],
    specs: &[Spec],
    max_batch: usize,
) -> (Vec<String>, sqft::serve::MultiServeStats) {
    let mut router = Router::new(engine, registry);
    let (tx, rx) = channel::<Request>();
    let mut replies = Vec::new();
    for (t, prompt, max_new, min_new) in specs {
        let (rtx, rrx) = channel();
        let mut req = Request::new(Some(entries[*t].id.clone()), prompt.clone(), rtx);
        req.max_new_tokens = *max_new;
        req.min_new_tokens = *min_new;
        tx.send(req).unwrap();
        replies.push(rrx);
    }
    drop(tx);
    let opts = SchedulerOpts {
        max_batch,
        aging: Duration::from_millis(20),
        ..Default::default()
    };
    let stats = router.serve(rx, opts).unwrap();
    let answers: Vec<String> =
        replies.into_iter().map(|r| r.recv().unwrap().unwrap()).collect();
    (answers, stats)
}

#[test]
fn eight_tenant_long_tail_decodes_in_one_mixed_session() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt, 8);
    let new_tokens = 3usize;
    let engine = Engine::new(&rt, "sqft-tiny", &f.frozen, None, "eval", 4).unwrap();
    if !engine.supports_gathered() {
        eprintln!("skipping: artifacts lack the eval_gathered kind");
        return;
    }
    let b = engine.artifact_batch().unwrap();
    assert_eq!(b, 8, "the long-tail acceptance shape needs an 8-slot artifact");

    // the S-LoRA long tail: 8 tenants, one request each, equal length
    // (min == max pins every row to exactly `new_tokens` forwards)
    let task = Task::SynBoolq;
    let mut grng = Rng::new(229);
    let specs: Vec<Spec> = (0..8)
        .map(|t| (t, task.gen_sample(&mut grng).prompt, Some(new_tokens), new_tokens))
        .collect();
    let expected = uniform_reference(&engine, &f.entries, &specs);

    let mut registry = AdapterRegistry::new(8);
    for e in &f.entries {
        registry.register_resident(&rt, &f.hyper, e.clone()).unwrap();
    }
    let (answers, stats) = serve_specs(engine, registry, &f.entries, &specs, b);

    // byte-identical to the same-tenant baseline, tenant by tenant
    for (i, ans) in answers.iter().enumerate() {
        assert_eq!(ans, &expected[i], "tenant {} diverged from its baseline", specs[i].0);
    }
    assert_eq!(stats.total.served, 8);
    assert_eq!(stats.total.errors, 0);

    // ONE mixed session served all 8 tenants: a single dispatched batch,
    // exactly `new_tokens` forwards total (not 8 x new_tokens), and all
    // 8 slots occupied on every forward
    assert_eq!(stats.scheduler.batches, 1, "one dispatch must cover all 8 tenants");
    assert_eq!(stats.scheduler.mixed_batches, 1);
    assert_eq!(stats.decode_steps, new_tokens,
        "8 tenants must share every forward, not decode sequentially");
    let occupied = stats.occupancy * b as f64;
    assert!(occupied > 7.9,
        "mean occupied slots {occupied:.2} must be ~8 of 8 on the long tail");
}

#[test]
fn interleaved_four_tenant_workload_matches_per_tenant_reference() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::new(&dir).expect("runtime");
    let f = fixture(&rt, 4);
    let engine = Engine::new(&rt, "sqft-tiny", &f.frozen, None, "eval", 4).unwrap();
    if !engine.supports_gathered() {
        eprintln!("skipping: artifacts lack the eval_gathered kind");
        return;
    }
    let b = engine.artifact_batch().unwrap();

    // 3 interleaved rounds over 4 tenants with mixed lengths, so the
    // second wave can only ride slots freed mid-session — across tenants
    let task = Task::SynBoolq;
    let mut grng = Rng::new(233);
    let lens: [(Option<usize>, usize); 3] = [(Some(1), 0), (Some(4), 4), (Some(2), 1)];
    let mut specs: Vec<Spec> = Vec::new();
    for (max_new, min_new) in lens {
        for t in 0..4 {
            specs.push((t, task.gen_sample(&mut grng).prompt, max_new, min_new));
        }
    }
    let expected = uniform_reference(&engine, &f.entries, &specs);

    let mut registry = AdapterRegistry::new(4);
    for e in &f.entries {
        registry.register_resident(&rt, &f.hyper, e.clone()).unwrap();
    }
    let (answers, stats) = serve_specs(engine, registry, &f.entries, &specs, b);

    for (i, ans) in answers.iter().enumerate() {
        assert_eq!(ans, &expected[i],
            "request {i} (tenant {}) diverged from the per-tenant reference", specs[i].0);
    }
    assert_eq!(stats.total.served, specs.len());
    assert_eq!(stats.total.errors, 0);
    assert!(stats.scheduler.mixed_batches >= 1, "batches must span tenants");
    assert!(stats.scheduler.admitted >= specs.len() - b,
        "the overflow wave must be admitted into the running session");
}
