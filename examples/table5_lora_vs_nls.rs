//! Paper Table 5 (+ Table 9 with SQFT_SPARSITIES): LoRA (fixed rank) vs
//! NLS (elastic rank) ablation across sparsity levels, for every SQFT
//! pipeline variant.
//!
//!   cargo run --release --example table5_lora_vs_nls
//!   SQFT_SPARSITIES=0.2,0.3,0.4,0.5,0.6,0.7 cargo run --release \
//!     --example table5_lora_vs_nls        # Table 9 range

use sqft::data::Task;
use sqft::harness::{self, Harness};
use sqft::peft::Method;
use sqft::report::{pct, Table};

fn sparsities() -> Vec<f64> {
    std::env::var("SQFT_SPARSITIES")
        .ok()
        .map(|s| s.split(',').filter_map(|x| x.trim().parse().ok()).collect())
        .unwrap_or_else(|| vec![0.3, 0.5, 0.7])
}

fn main() -> anyhow::Result<()> {
    let h = Harness::from_env()?;
    let task = Task::SynGsm;
    let ds = &h.datasets(&[task])[0];
    let (base, _) = h.base_for(task.name(), &ds.train)?;

    let mut t = Table::new(
        &format!("Table 5 — LoRA vs NLS ({} on {})", h.model, task.name()),
        &["Sparsity", "Method", "Mergeable", "Final Precision",
          "LoRA Acc(%)", "NLS Acc(%)", "Delta"]);

    let mut nls_wins = 0usize;
    let mut cells = 0usize;
    for &sp in &sparsities() {
        for method in [Method::Shears, Method::SparsePeft,
                       Method::Sqft, Method::QaSparsePeft] {
            let mut accs = [0.0f64; 2];
            for (i, fixed) in [(0usize, true), (1usize, false)] {
                let mut opts = h.train_opts();
                opts.fixed_rank = fixed;
                let (prepared, trainer) =
                    h.tune_opts(&base, method, sp, &ds.train, &opts)?;
                let (a, m, _) = h.eval_cell(&prepared, &trainer, &ds.test)?;
                accs[i] = m.map(|x| x.accuracy()).unwrap_or(a.accuracy());
            }
            cells += 1;
            if accs[1] >= accs[0] {
                nls_wins += 1;
            }
            t.row(vec![
                format!("{:.0}%", sp * 100.0),
                method.name().into(),
                if method.mergeable() { "yes" } else { "no" }.into(),
                method.final_precision().into(),
                pct(accs[0]),
                pct(accs[1]),
                format!("{:+.1}", (accs[1] - accs[0]) * 100.0),
            ]);
            eprintln!("[table5] s={sp} {}: lora {} nls {}", method.name(),
                pct(accs[0]), pct(accs[1]));
        }
    }

    print!("{}", t.render());
    println!("NLS >= LoRA in {nls_wins}/{cells} cells");
    harness::log_experiment(
        &format!("Table 5/9 ({} / {})", h.model, task.name()),
        &harness::table_with_note(&t,
            &format!("paper-shape: NLS beats or matches fixed-rank LoRA \
                      (here {nls_wins}/{cells} cells)")))?;
    Ok(())
}
