//! Paper Table 1: adapting models to GSM8K (syn-gsm analogue) at 50%
//! sparsity — all methods, with and without quantization.
//!
//!   cargo run --release --example table1_gsm8k
//!   SQFT_MODEL=sqft-small cargo run --release --example table1_gsm8k
//!
//! Expected shape (paper): sparse w/o tune craters; all fine-tunes recover;
//! SparsePEFT ≈ (or >) LoRA/Shears while uniquely mergeable; QA-SparsePEFT
//! ≈ GPTQ+LoRA/SQFT while producing a pure-INT4 merged model.

use sqft::data::Task;
use sqft::harness::{self, Harness};
use sqft::peft::Method;
use sqft::report::{pct, Table};

fn main() -> anyhow::Result<()> {
    let h = Harness::from_env()?;
    let task = Task::SynGsm;
    let ds = &h.datasets(&[task])[0];
    let (base, _) = h.base_for(task.name(), &ds.train)?;
    let sparsity = 0.5;

    let mut t = Table::new(
        &format!("Table 1 — {} on {} (50% sparsity)", h.model, task.name()),
        &["Method", "Mergeable", "Final Precision", "Test Acc(%)"]);

    // dense reference
    let dense = h.baseline_acc(&base, Method::Lora, 0.0, &ds.train, &ds.test)?;
    t.row(vec!["w/o tune (dense)".into(), "-".into(), "FP16".into(),
               pct(dense.accuracy())]);

    // --- w/o quantization block ---------------------------------------
    let sp_untuned =
        h.baseline_acc(&base, Method::SparsePeft, sparsity, &ds.train, &ds.test)?;
    t.row(vec!["w/o tune (50% sparse)".into(), "-".into(), "FP16".into(),
               pct(sp_untuned.accuracy())]);
    for method in [Method::Lora, Method::Shears, Method::SparsePeft] {
        let (prepared, trainer) = h.tune(&base, method, sparsity, &ds.train)?;
        let (acc, macc, ok) = h.eval_cell(&prepared, &trainer, &ds.test)?;
        let shown = macc.map(|m| m.accuracy()).unwrap_or(acc.accuracy());
        t.row(h.method_row(method, &[shown], ok));
        eprintln!("[table1] {} done: {}", method.name(), pct(shown));
    }

    // --- quantization block ---------------------------------------------
    let q_untuned =
        h.baseline_acc(&base, Method::QaSparsePeft, sparsity, &ds.train, &ds.test)?;
    t.row(vec!["w/o tune (sparse+INT4)".into(), "-".into(), "INT4".into(),
               pct(q_untuned.accuracy())]);
    for method in [Method::GptqLora, Method::Sqft, Method::QaSparsePeft] {
        let (prepared, trainer) = h.tune(&base, method, sparsity, &ds.train)?;
        let (acc, macc, ok) = h.eval_cell(&prepared, &trainer, &ds.test)?;
        let shown = macc.map(|m| m.accuracy()).unwrap_or(acc.accuracy());
        t.row(h.method_row(method, &[shown], ok));
        eprintln!("[table1] {} done: {}", method.name(), pct(shown));
    }

    print!("{}", t.render());
    harness::log_experiment(
        &format!("Table 1 ({} / {})", h.model, task.name()),
        &harness::table_with_note(&t,
            "paper-shape: compression craters accuracy, every fine-tune \
             recovers it; only SparsePEFT/QA-SparsePEFT rows are mergeable \
             (their accuracy is reported post-merge)"))?;
    Ok(())
}
