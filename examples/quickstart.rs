//! Quickstart: the smallest end-to-end SQFT run.
//!
//!   cargo run --release --example quickstart
//!
//! Pretrains a tiny base model on a synthetic reasoning task, sparsifies it
//! to 50% with Wanda, fine-tunes with SparsePEFT (elastic NLS adapters),
//! merges the adapters back *without losing a single zero*, and prints the
//! accuracy story — the paper's Figure 1 problem and §2.3 solution in one
//! screen of output.

use sqft::data::Task;
use sqft::harness::Harness;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::report::pct;

fn main() -> anyhow::Result<()> {
    let h = Harness::from_env()?;
    let task = Task::SynBoolq;
    let ds = &h.datasets(&[task])[0];

    println!("model={} task={}", h.model, task.name());
    let (base, _) = h.base_for(task.name(), &ds.train)?;

    // dense baseline
    let dense = h.baseline_acc(&base, Method::Lora, 0.0, &ds.train, &ds.test)?;
    println!("dense, w/o tune:           {:>5}%", pct(dense.accuracy()));

    // 50% sparse, untuned — accuracy craters (paper Table 1 's 12.5 row)
    let sparse = h.baseline_acc(&base, Method::SparsePeft, 0.5, &ds.train, &ds.test)?;
    println!("50% sparse, w/o tune:      {:>5}%", pct(sparse.accuracy()));

    // SQFT + SparsePEFT: recover accuracy with mergeable adapters
    let (prepared, trainer) = h.tune(&base, Method::SparsePeft, 0.5, &ds.train)?;
    let (acc, macc, preserved) = h.eval_cell(&prepared, &trainer, &ds.test)?;
    println!("SQFT+SparsePEFT tuned:     {:>5}%", pct(acc.accuracy()));
    let macc = macc.unwrap();
    println!("       merged:             {:>5}%  (sparsity preserved: {})",
        pct(macc.accuracy()), preserved.unwrap());
    // f32 reassociation between the fused-kernel forward and the host merge
    // can flip a borderline sample; the paper's criterion is no loss at
    // reported precision (0.1%)
    assert!(
        (acc.accuracy() - macc.accuracy()).abs() <= 1.0 / acc.total as f64 + 1e-9,
        "paper claim: merging must not change accuracy ({} vs {})",
        acc.correct, macc.correct);
    println!("\nmerge preserves accuracy and sparsity (paper Eq. 1-2)");
    Ok(())
}
