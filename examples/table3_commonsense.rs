//! Paper Table 3: commonsense reasoning — unified training over the seven
//! synthetic MC tasks, per-task + average accuracy, all methods at 50%.
//!
//!   cargo run --release --example table3_commonsense

use sqft::data::{Dataset, Task};
use sqft::harness::{self, Harness};
use sqft::peft::Method;
use sqft::report::{pct, Table};

fn main() -> anyhow::Result<()> {
    let h = Harness::from_env()?;
    let tasks = Task::commonsense();
    let datasets = h.datasets(&tasks);
    let unified = Dataset::unified(&datasets, h.seed);
    let (base, _) = h.base_for("commonsense", &unified)?;
    let sparsity = 0.5;

    let mut headers: Vec<String> =
        vec!["Method".into(), "Mergeable".into(), "Precision".into()];
    headers.extend(tasks.iter().map(|t| t.name().to_string()));
    headers.push("Average".into());
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        &format!("Table 3 — {} commonsense reasoning (50% sparsity)", h.model),
        &hdr_refs);

    // dense + untuned references
    for (label, method, sp) in [
        ("w/o tune (dense)", Method::Lora, 0.0),
        ("w/o tune (50% sparse)", Method::SparsePeft, sparsity),
    ] {
        let mut accs = Vec::new();
        for ds in &datasets {
            accs.push(h.baseline_acc(&base, method, sp, &unified, &ds.test)?
                .accuracy());
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        let mut row = vec![label.to_string(), "-".into(),
                           if sp > 0.0 { "FP16" } else { "FP16" }.into()];
        row.extend(accs.iter().map(|&a| pct(a)));
        row.push(pct(avg));
        t.row(row);
    }

    for method in [Method::Lora, Method::Shears, Method::SparsePeft,
                   Method::GptqLora, Method::Sqft, Method::QaSparsePeft] {
        let (prepared, trainer) = h.tune(&base, method, sparsity, &unified)?;
        let mut accs = Vec::new();
        let mut ok = None;
        for ds in &datasets {
            let (a, m, o) = h.eval_cell(&prepared, &trainer, &ds.test)?;
            accs.push(m.map(|x| x.accuracy()).unwrap_or(a.accuracy()));
            ok = ok.or(o);
        }
        let avg = accs.iter().sum::<f64>() / accs.len() as f64;
        accs.push(avg);
        t.row(h.method_row(method, &accs, ok));
        eprintln!("[table3] {} avg {}", method.name(), pct(avg));
    }

    print!("{}", t.render());
    harness::log_experiment(
        &format!("Table 3 ({} / commonsense)", h.model),
        &harness::table_with_note(&t,
            "paper-shape: all methods within a band; QA-SparsePEFT gives the \
             most efficient (INT4, merged) model at competitive accuracy"))?;
    Ok(())
}
