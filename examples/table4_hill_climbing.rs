//! Paper Table 4 + Figure 4: hill-climbing (Algorithm 1) over the NLS
//! space vs the median heuristic, validated on the three tasks that have
//! validation splits (Arc-e/Arc-c/OBQA analogues), plus the rank
//! distribution of the discovered configuration.
//!
//!   cargo run --release --example table4_hill_climbing

use sqft::data::{Dataset, Task};
use sqft::harness::{self, Harness};
use sqft::nls::hill_climb;
use sqft::peft::Method;
use sqft::pipeline;
use sqft::report::{pct, Table};
use sqft::tensor::Rng;

fn main() -> anyhow::Result<()> {
    let h = Harness::from_env()?;
    let tasks = Task::commonsense();
    let datasets = h.datasets(&tasks);
    let unified = Dataset::unified(&datasets, h.seed);
    let (base, _) = h.base_for("commonsense", &unified)?;
    let val_tasks: Vec<_> =
        datasets.iter().filter(|d| d.task.has_validation()).collect();
    let val_samples: Vec<_> =
        val_tasks.iter().flat_map(|d| d.val.clone()).collect();

    let mut t = Table::new(
        &format!("Table 4 — hill-climbing vs heuristic ({})", h.model),
        &["Method", "Sub-Adapter", "Val Acc(%)", "Test Avg(%)", "Mean rank"]);

    for method in [Method::SparsePeft, Method::QaSparsePeft] {
        let (prepared, trainer) = h.tune(&base, method, 0.5, &unified)?;
        let heuristic = trainer.space.heuristic_config();
        let eval_val = |cfg: &sqft::nls::Config| -> anyhow::Result<f64> {
            Ok(pipeline::evaluate_unmerged(
                &h.rt, &h.model, &prepared, &trainer, cfg, &val_samples, &h.tok)?
                .accuracy())
        };
        let mut rng = Rng::new(h.seed ^ 0x41);
        let res = {
            let space = trainer.space.clone();
            let mut f = |cfg: &sqft::nls::Config| eval_val(cfg);
            hill_climb(&space, heuristic.clone(), 6, 4, 2, &mut f, &mut rng)?
        };
        for (label, cfg, val_acc) in [
            ("Heuristic", &heuristic, res.trace[0].1),
            ("Hill-climbing", &res.best, res.best_score),
        ] {
            let mut test_avg = 0.0;
            for ds in &datasets {
                test_avg += pipeline::evaluate_unmerged(
                    &h.rt, &h.model, &prepared, &trainer, cfg, &ds.test, &h.tok)?
                    .accuracy();
            }
            test_avg /= datasets.len() as f64;
            t.row(vec![method.name().into(), label.into(), pct(val_acc),
                       pct(test_avg),
                       format!("{:.1}", trainer.space.mean_rank(cfg))]);
        }
        // Figure 4: rank distribution of the discovered configuration
        println!("Figure 4 — adapter rank distribution ({}):", method.name());
        for (module, ranks) in trainer.space.rank_histogram(&res.best) {
            println!("  {module:>5}: {ranks:?}");
        }
        eprintln!("[table4] {} evaluated {} configs", method.name(), res.evaluated);
    }

    print!("{}", t.render());
    harness::log_experiment(
        &format!("Table 4 + Fig 4 ({})", h.model),
        &harness::table_with_note(&t,
            "paper-shape: hill-climbing val acc >= heuristic val acc (Alg. 1 \
             is monotone); test accuracy improves or holds"))?;
    Ok(())
}
